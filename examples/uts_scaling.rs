//! UTS scaling demo (paper §2.5, Figs 2–4 in miniature).
//!
//! Runs UTS-G on the thread runtime across 1..=8 oversubscribed places
//! (functional check), then sweeps 1..=1024 simulated places on the
//! Blue Gene/Q profile and prints the throughput/efficiency series —
//! the same curve shape as the paper's Figure 3.
//!
//! ```bash
//! cargo run --release --example uts_scaling [depth]
//! ```

use glb::apps::uts::{sequential_count, UtsParams, UtsQueue};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::harness::{calibrate_uts_cost, Table};
use glb::place::run_threads;
use glb::sim::{run_sim, BGQ};
use glb::util::timefmt::fmt_rate;

fn main() {
    let depth = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(9u32);
    let up = UtsParams { b0: 4.0, seed: 19, max_depth: depth };
    let expect = sequential_count(&up);
    println!("geometric tree b0=4 r=19 d={depth}: {expect} nodes\n");

    // Functional: real threads.
    for p in [1usize, 2, 4, 8] {
        let cfg = GlbConfig::new(p, GlbParams::default());
        let out = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(out.result, expect);
        println!(
            "threads p={p:<2} -> {} (wall, 1-core oversubscribed)",
            fmt_rate(out.units_per_sec())
        );
    }

    // Scaling shape: the BGQ-profile simulator.
    println!("\nsimulated Blue Gene/Q sweep (virtual time):");
    let cost = calibrate_uts_cost();
    let mut table = Table::new(&["places", "nodes/s", "efficiency"]);
    let mut base = None;
    for p in [1usize, 4, 16, 64, 256, 1024] {
        let cfg = GlbConfig::new(p, GlbParams::default());
        let (out, _) =
            run_sim(&cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(out.result, expect);
        let rate = out.units_per_sec();
        let b = *base.get_or_insert(rate);
        table.row(&[p.to_string(), fmt_rate(rate), format!("{:.3}", rate / p as f64 / b)]);
    }
    print!("{}", table.render());
}
