//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Proves all layers compose on the request path:
//!
//!   L3 rust GLB workers (threads, lifeline stealing, termination)
//!     -> vertex-interval tasks drained in batches
//!   L2/L1 AOT artifact (JAX batched Brandes calling the Pallas frontier
//!     kernel, lowered to HLO text at `make artifacts`)
//!     -> executed through the PJRT CPU client (runtime::DeviceService)
//!
//! on the SSCA2 kernel-4 workload (R-MAT graph, exact betweenness), and
//! reports the paper's headline metric (edges/s + per-place balance),
//! cross-validated against the sparse CPU engine.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_bc_pjrt
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::Instant;

use glb::apps::bc::{sequential_bc, BcQueue, Graph, RmatParams};
use glb::glb::task_queue::VecSumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::place::run_threads;
use glb::runtime::{default_artifact_dir, DeviceService};
use glb::util::timefmt::{fmt_ns, fmt_rate};

fn main() -> anyhow::Result<()> {
    let scale = 8u32; // 256 vertices -> matches the default n=256 artifact
    let places = 4usize;
    let g = Arc::new(Graph::rmat(RmatParams { scale, ..Default::default() }));
    println!("[1/4] workload: SSCA2 R-MAT scale {scale} (n={}, m={})", g.n(), g.m());

    let t = Instant::now();
    let svc = DeviceService::start(&default_artifact_dir(), g.dense_adjacency(), g.n())?;
    let handle = svc.handle();
    println!(
        "[2/4] PJRT engine up in {}: batched Brandes artifact n={} S={}",
        fmt_ns(t.elapsed().as_nanos() as u64),
        handle.n(),
        handle.batch()
    );

    let n = g.n() as u32;
    let cfg = GlbConfig::new(places, GlbParams::default().with_n(64).with_l(2));
    let t = Instant::now();
    let h2 = handle.clone();
    let out = run_threads(
        &cfg,
        move |_, _| BcQueue::dense(h2.clone()),
        |q| q.assign(0, n),
        &VecSumReducer,
    );
    let wall = t.elapsed().as_nanos() as u64;
    let edges: u64 = out.log.per_place.iter().map(|s| s.units).sum();
    println!(
        "[3/4] GLB run: {places} places, {} edges traversed in {} -> {}",
        edges,
        fmt_ns(wall),
        fmt_rate(edges as f64 * 1e9 / wall as f64)
    );
    for (i, s) in out.log.per_place.iter().enumerate() {
        println!(
            "      place {i}: {:>9} edges, {:>3} chunks, {} loot bags in",
            s.units, s.chunks, s.loot_bags_received
        );
    }

    let t = Instant::now();
    let (want, want_edges) = sequential_bc(&g);
    let sparse_ns = t.elapsed().as_nanos() as u64;
    let max_err = out
        .result
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0f64, f64::max);
    println!(
        "[4/4] validation vs sparse CPU Brandes ({}): max rel err {max_err:.2e}, edges {} vs {}",
        fmt_ns(sparse_ns),
        edges,
        want_edges
    );
    anyhow::ensure!(max_err < 1e-3, "betweenness mismatch");
    anyhow::ensure!(edges == want_edges, "edge-count mismatch");
    println!("\nE2E OK: all three layers compose.");
    Ok(())
}
