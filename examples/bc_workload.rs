//! BC workload-distribution demo (paper §2.6, Figs 6/8/10 in miniature).
//!
//! Compares the legacy static-randomized BC against BC-G on the same
//! R-MAT graph: prints per-place busy times, means and standard
//! deviations — the paper's headline BC result is the σ collapse
//! (e.g. 4.027 → 1.141 on BGQ; 58.463 → 1.482 on Power 775).
//!
//! ```bash
//! cargo run --release --example bc_workload [scale] [places]
//! ```

use std::sync::Arc;

use glb::apps::bc::{Graph, InterruptibleBcQueue, RmatParams};
use glb::baselines::legacy_bc::run_legacy_bc_sim;
use glb::glb::task_queue::VecSumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::harness::calibrate_bc_cost;
use glb::sim::{run_sim, BGQ};
use glb::util::stats::{mean, stddev};

fn main() {
    let mut args = std::env::args().skip(1);
    let scale: u32 = args.next().and_then(|s| s.parse().ok()).unwrap_or(12);
    let places: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(32);

    let g = Arc::new(Graph::rmat(RmatParams { scale, ..Default::default() }));
    println!("R-MAT scale {scale}: n={} m={}, {places} places (BGQ profile)\n", g.n(), g.m());
    let cost = calibrate_bc_cost(&g);

    // Legacy: static randomized, no stealing.
    let legacy = run_legacy_bc_sim(&g, places, 42, cost.ns_per_unit, BGQ.compute_scale);
    let legacy_s: Vec<f64> = legacy.busy_ns.iter().map(|&x| x as f64 / 1e9).collect();

    // BC-G: same static seed layout, stealing enabled — the paper's
    // final variant: interruptible vertices (§2.6.2), max w, minimal
    // effective granularity (8192-edge chunks).
    let n = g.n() as u32;
    let gg = g.clone();
    let cfg = GlbConfig::new(places, GlbParams::default().with_n(8192).with_w(4).with_l(2));
    let (run, _) = run_sim(
        &cfg,
        &BGQ,
        cost,
        move |i, np| {
            let mut q = InterruptibleBcQueue::new(gg.clone());
            let per = n / np as u32;
            let lo = i as u32 * per;
            let hi = if i == np - 1 { n } else { lo + per };
            q.assign(lo, hi);
            q
        },
        |_| {},
        &VecSumReducer,
    );
    let glb_s: Vec<f64> = run.log.per_place.iter().map(|s| s.process_ns as f64 / 1e9).collect();

    // The maps must agree (same graph, same sources).
    let max_err = run
        .result
        .iter()
        .zip(&legacy.bc)
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0f64, f64::max);
    println!("betweenness maps agree to {max_err:.2e} (legacy vs GLB)\n");
    assert!(max_err < 1e-9);

    println!("workload distribution (busy seconds per place, virtual):");
    println!("  BC   : mean={:.4} sd={:.4} makespan={:.4}", mean(&legacy_s), stddev(&legacy_s), legacy.elapsed_ns as f64 / 1e9);
    println!("  BC-G : mean={:.4} sd={:.4} makespan={:.4}", mean(&glb_s), stddev(&glb_s), run.elapsed_ns as f64 / 1e9);
    let improvement = stddev(&legacy_s) / stddev(&glb_s).max(1e-12);
    println!("\nGLB reduced the workload σ by {improvement:.1}x");

    // A terminal bar chart, like the paper's bundled bars.
    let max = legacy_s.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    println!("\nplace  BC {:28} BC-G", "");
    for i in 0..places.min(40) {
        let bar = |v: f64| "#".repeat((v / max * 28.0).round() as usize);
        println!("{i:>5}  {:<30} {:<30}", bar(legacy_s[i]), bar(glb_s[i]));
    }
}
