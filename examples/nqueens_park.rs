//! N-Queens state-space search (paper §2.1's example domain), with the
//! per-worker accounting table (§2.4's logging feature).
//!
//! ```bash
//! cargo run --release --example nqueens_park [board-size] [places]
//! ```

use glb::apps::nqueens::{NQueensQueue, KNOWN};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::place::run_threads;
use glb::util::timefmt::fmt_ns;

fn main() {
    let mut args = std::env::args().skip(1);
    let board: u8 = args.next().and_then(|s| s.parse().ok()).unwrap_or(11);
    let places: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(6);

    let cfg = GlbConfig::new(places, GlbParams::default().with_n(128).with_l(2));
    let out = run_threads(
        &cfg,
        move |_, _| NQueensQueue::new(board),
        |q| q.init_root(),
        &SumReducer,
    );

    println!(
        "nqueens({board}) = {} solutions in {} on {places} places",
        out.result,
        fmt_ns(out.elapsed_ns)
    );
    if (board as usize) < KNOWN.len() {
        assert_eq!(out.result, KNOWN[board as usize], "known count mismatch");
        println!("matches the known count ✓");
    }
    println!("\nper-worker log (paper §2.4):");
    print!("{}", out.log.render());
}
