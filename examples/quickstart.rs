//! Quickstart: the paper's appendix Fibonacci example, in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Mirrors Fig. 11 of the paper: the user supplies a `TaskQueue`
//! (here the prebuilt [`FibQueue`]), a root initializer, and a reducer;
//! GLB handles distribution, stealing, termination and reduction.

use glb::apps::fib::{fib, FibQueue};
use glb::glb::task_queue::SumReducer;
use glb::glb::{GlbConfig, GlbParams};
use glb::place::run_threads;

fn main() {
    let n = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(26u64);
    let places = 4;

    // GLBParameters.Default equivalent; see paper §2.4 for n/w/l/z.
    let cfg = GlbConfig::new(places, GlbParams::default().with_n(256));

    let out = run_threads(
        &cfg,
        |_place, _p| FibQueue::new(), // queue factory, one per place
        |q| q.init(n),                // root task at place 0
        &SumReducer,                  // commutative+associative reduce
    );

    println!("fib-glb({n}) = {} (expected {})", out.result, fib(n));
    println!(
        "{} places, {} tasks processed, {} steal responses shipped work",
        places,
        out.log.total().items_processed,
        out.log.total().loot_bags_received,
    );
    assert_eq!(out.result, fib(n));
}
