"""L1 kernel correctness: Pallas vs pure-jnp oracles.

Hypothesis sweeps shapes; every case asserts allclose against ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bc_frontier import frontier_matmul, vmem_bytes
from compile.kernels.ref import matmul_ref, uts_expand_ref
from compile.kernels.uts_expand import uts_expand


def _rand(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


class TestFrontierMatmul:
    @pytest.mark.parametrize(
        "n,k,s", [(8, 8, 4), (16, 16, 16), (64, 64, 32), (128, 128, 8), (256, 256, 32)]
    )
    def test_matches_ref_square(self, n, k, s):
        a = _rand((n, k), seed=n + s)
        x = _rand((k, s), seed=n * 31 + s)
        got = np.asarray(frontier_matmul(jnp.asarray(a), jnp.asarray(x)))
        want = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([4, 8, 12, 32, 48, 64]),
        k=st.sampled_from([4, 8, 16, 32, 64]),
        s=st.sampled_from([1, 2, 4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, n, k, s, seed):
        a = _rand((n, k), seed=seed)
        x = _rand((k, s), seed=seed ^ 0x5EED)
        got = np.asarray(frontier_matmul(jnp.asarray(a), jnp.asarray(x)))
        want = a.astype(np.float64) @ x.astype(np.float64)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bn,bs,bk", [(4, 4, 4), (8, 2, 16), (16, 16, 8)])
    def test_block_shapes_do_not_change_result(self, bn, bs, bk):
        a = _rand((32, 32), seed=1)
        x = _rand((32, 16), seed=2)
        got = np.asarray(
            frontier_matmul(jnp.asarray(a), jnp.asarray(x), bn=bn, bs=bs, bk=bk)
        )
        want = np.asarray(matmul_ref(jnp.asarray(a), jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_non_power_of_two_dims(self):
        # _pick_block falls back to divisors for odd shapes.
        a = _rand((24, 36), seed=3)
        x = _rand((36, 12), seed=4)
        got = np.asarray(frontier_matmul(jnp.asarray(a), jnp.asarray(x)))
        np.testing.assert_allclose(got, a @ x, rtol=1e-5, atol=1e-5)

    def test_zero_input(self):
        a = jnp.zeros((16, 16), jnp.float32)
        x = jnp.zeros((16, 8), jnp.float32)
        assert np.all(np.asarray(frontier_matmul(a, x)) == 0)

    def test_vmem_estimate_default_tiles_fit(self):
        # Default 256/128/256 tiles: A 256x256 + X 256x128 + O 256x128
        # = 448 KiB — far under the 16 MiB VMEM budget even double-buffered.
        assert vmem_bytes(256, 128, 256) < 1 << 20


class TestUtsExpand:
    @pytest.mark.parametrize("b", [1, 16, 256, 1000])
    def test_matches_ref(self, b):
        h = np.random.default_rng(b).integers(0, 2**32, size=b, dtype=np.uint32)
        got = np.asarray(uts_expand(jnp.asarray(h)))
        want = uts_expand_ref(h)
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 512),
        seed=st.integers(0, 2**31 - 1),
        b0=st.sampled_from([1.5, 4.0, 8.0]),
    )
    def test_matches_ref_hypothesis(self, b, seed, b0):
        h = np.random.default_rng(seed).integers(0, 2**32, size=b, dtype=np.uint32)
        got = np.asarray(uts_expand(jnp.asarray(h), b0=b0))
        want = uts_expand_ref(h, b0=b0)
        np.testing.assert_array_equal(got, want)

    def test_mean_tracks_b0(self):
        h = np.random.default_rng(7).integers(0, 2**32, size=200_000, dtype=np.uint32)
        kids = np.asarray(uts_expand(jnp.asarray(h), b0=4.0))
        assert abs(kids.mean() - 4.0) < 0.05
        assert kids.min() >= 0
        assert kids.max() > 12, "geometric long tail"
