"""L2 model correctness: batched dense Brandes vs the loop oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    brandes_ref,
    path_adjacency,
    random_adjacency,
    star_adjacency,
)
from compile.model import brandes_batch_jit


def run_model(adj, sources, use_kernel=True):
    bc, edges, levels = brandes_batch_jit(
        jnp.asarray(adj), jnp.asarray(np.asarray(sources, dtype=np.int32)),
        use_kernel=use_kernel,
    )
    return np.asarray(bc, dtype=np.float64), float(edges), int(levels)


class TestBrandesBatch:
    def test_path_graph_analytic(self):
        adj = path_adjacency(5)
        bc, edges, levels = run_model(adj, list(range(5)))
        np.testing.assert_allclose(bc, [0.0, 6.0, 8.0, 6.0, 0.0], atol=1e-4)
        assert levels == 5  # path diameter + 1 BFS rounds for the end source
        # 5 sources x 8 directed edges fully visited.
        assert edges == 5 * 8

    def test_star_graph_analytic(self):
        adj = star_adjacency(4)
        bc, _e, _l = run_model(adj, list(range(5)))
        np.testing.assert_allclose(bc, [12.0, 0, 0, 0, 0], atol=1e-4)

    @pytest.mark.parametrize("n,density,seed", [(16, 0.2, 0), (32, 0.1, 1), (64, 0.05, 2)])
    def test_random_graphs_match_oracle(self, n, density, seed):
        adj = random_adjacency(n, density, seed)
        sources = list(range(n))
        bc, edges, _ = run_model(adj, sources)
        want, want_edges = brandes_ref(adj, sources)
        np.testing.assert_allclose(bc, want, rtol=1e-3, atol=1e-3)
        # Model counts sum-of-degrees over visited vertices per source —
        # identical to the oracle's per-edge counting on full BFS.
        assert edges == want_edges

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([8, 16, 24]),
        density=st.sampled_from([0.08, 0.2, 0.5]),
        seed=st.integers(0, 10_000),
    )
    def test_random_graphs_hypothesis(self, n, density, seed):
        adj = random_adjacency(n, density, seed)
        srcs = list(range(0, n, 2))
        bc, _e, _l = run_model(adj, srcs)
        want, _ = brandes_ref(adj, srcs)
        np.testing.assert_allclose(bc, want, rtol=1e-3, atol=1e-3)

    def test_padding_slots_contribute_nothing(self):
        adj = random_adjacency(16, 0.2, 3)
        bc_padded, e_padded, _ = run_model(adj, [3, 5, -1, -1])
        bc_exact, e_exact, _ = run_model(adj, [3, 5])
        np.testing.assert_allclose(bc_padded, bc_exact, atol=1e-5)
        assert e_padded == e_exact

    def test_batch_split_invariance(self):
        # sum over one big batch == sum over two half batches.
        adj = random_adjacency(24, 0.15, 4)
        whole, e_whole, _ = run_model(adj, list(range(24)))
        a, ea, _ = run_model(adj, list(range(12)))
        b, eb, _ = run_model(adj, list(range(12, 24)))
        np.testing.assert_allclose(whole, a + b, rtol=1e-4, atol=1e-4)
        assert e_whole == ea + eb

    def test_kernel_and_ref_matmul_agree(self):
        adj = random_adjacency(32, 0.12, 5)
        srcs = list(range(16))
        k, ek, _ = run_model(adj, srcs, use_kernel=True)
        r, er, _ = run_model(adj, srcs, use_kernel=False)
        np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-5)
        assert ek == er

    def test_disconnected_components_early_exit(self):
        # Two 4-cliques: BFS from any source exhausts in 2 levels
        # (early-exit is the imbalance mechanism — see DESIGN.md).
        n = 8
        adj = np.zeros((n, n), dtype=np.float32)
        adj[:4, :4] = 1.0
        adj[4:, 4:] = 1.0
        np.fill_diagonal(adj, 0.0)
        _bc, edges, levels = run_model(adj, [0])
        assert levels <= 2
        assert edges == 4 * 3  # the source's component only

    def test_empty_batch_is_zero(self):
        adj = random_adjacency(8, 0.3, 6)
        bc, edges, levels = run_model(adj, [-1, -1])
        assert np.all(bc == 0)
        assert edges == 0
        assert levels == 0

    def test_isolated_source(self):
        adj = np.zeros((6, 6), dtype=np.float32)
        adj[1, 2] = 1.0
        bc, edges, _ = run_model(adj, [0])
        assert np.all(bc == 0)
        assert edges == 0
