"""Resource-analysis sanity: the DESIGN.md §8 claims are derivable."""

from compile.analysis import estimate, render_table, MXU_DIM, VMEM_BUDGET


class TestKernelEstimate:
    def test_default_tiles_fit_vmem(self):
        # The DESIGN.md claim: default 256x256x128 tiles double-buffered
        # stay well under the 16 MiB budget.
        e = estimate(1024, 1024, 128)
        assert e.bn == 256 and e.bk == 256 and e.bs == 128
        assert e.fits_vmem
        assert e.vmem_double_buffered < 2 << 20  # < 2 MiB

    def test_small_shapes_underfill_mxu(self):
        small = estimate(64, 64, 16)
        big = estimate(4096, 4096, 128)
        assert small.mxu_fill < big.mxu_fill
        assert big.mxu_fill == 1.0, "128-wide tiles fill the array"

    def test_flops_formula(self):
        e = estimate(256, 256, 32)
        assert e.flops == 2 * 256 * 256 * 32

    def test_arithmetic_intensity_grows_with_s(self):
        # Bigger source batches amortize the A stream.
        lo = estimate(1024, 1024, 16)
        hi = estimate(1024, 1024, 512)
        assert hi.arithmetic_intensity > lo.arithmetic_intensity

    def test_vmem_budget_enforced_somewhere(self):
        # A pathological giant tile must be flagged.
        e = estimate(16384, 16384, 4096, bn=16384, bk=16384, bs=4096)
        assert not e.fits_vmem
        assert VMEM_BUDGET == 16 << 20 and MXU_DIM == 128

    def test_table_renders(self):
        t = render_table([(256, 32), (1024, 128)])
        assert "VMEM" in t and "256" in t and "1024" in t
