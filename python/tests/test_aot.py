"""AOT pipeline: lowering produces loadable HLO text + a valid manifest."""

import pathlib
import subprocess
import sys

import pytest

from compile.aot import lower_brandes, lower_uts_expand, parse_sizes


class TestLowering:
    def test_brandes_hlo_text_shape(self):
        text = lower_brandes(16, 4)
        assert "ENTRY" in text
        assert "while" in text.lower(), "forward/backward loops must lower to HLO While"
        # Inputs appear with the right shapes.
        assert "f32[16,16]" in text
        assert "s32[4]" in text

    def test_uts_expand_hlo_text(self):
        text = lower_uts_expand(64)
        assert "ENTRY" in text
        assert "u32[64]" in text
        assert "s32[64]" in text

    def test_parse_sizes(self):
        assert parse_sizes("256:32,1024:64") == [(256, 32), (1024, 64)]
        assert parse_sizes("128") == [(128, 32)]
        assert parse_sizes(" 64:8 , ") == [(64, 8)]


class TestEndToEndAot:
    @pytest.fixture(scope="class")
    def artifact_dir(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out-dir",
                str(out),
                "--bc-sizes",
                "16:4",
                "--uts-batches",
                "32",
            ],
            check=True,
            cwd=pathlib.Path(__file__).resolve().parents[1],
        )
        return out

    def test_files_written(self, artifact_dir):
        names = {p.name for p in artifact_dir.iterdir()}
        assert "bc_brandes_n16_s4.hlo.txt" in names
        assert "uts_expand_b32.hlo.txt" in names
        assert "manifest.txt" in names

    def test_manifest_contents(self, artifact_dir):
        text = (artifact_dir / "manifest.txt").read_text()
        assert "kind=bc_brandes n=16 s=4 file=bc_brandes_n16_s4.hlo.txt" in text
        assert "kind=uts_expand b=32" in text
