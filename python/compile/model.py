"""L2 JAX model: batched dense Brandes betweenness centrality.

One call computes the betweenness contribution of a *batch* of S source
vertices against the replicated N-vertex graph — the unit of work a GLB
worker requests from the PJRT engine when draining a BC vertex-interval
task (rust/src/apps/bc/queue.rs, Dense engine).

Structure (all shapes static; S slots padded with source id -1):

* forward: level-synchronous BFS with shortest-path counting. The carry
  is (level, frontier, sigma, dist), all [N, S]; each level is one
  ``adj_T @ (sigma * frontier)`` through the L1 Pallas kernel. A
  ``lax.while_loop`` exits as soon as the *whole batch's* frontier is
  empty — batches of sources from small components finish in a couple of
  iterations, which is exactly the per-source imbalance the paper's BC
  exhibits (DESIGN.md "Imbalance fidelity").
* backward: dependency accumulation from the deepest level down, one
  ``adj @ coef`` kernel call per level, also a while_loop (trip count =
  the forward level count, dynamic).
* outputs: (bc[N] f32, edges f32 scalar, levels i32 scalar) — partial
  betweenness summed over the batch, edges traversed (sum of out-degrees
  of visited vertices, the paper's BC work metric), and the BFS depth.

Python/JAX run only at build time: ``aot.py`` lowers this function to
HLO text per (N, S) configuration.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.bc_frontier import frontier_matmul
from .kernels.ref import matmul_ref

_INF = jnp.float32(jnp.inf)


def brandes_batch(adj, sources, *, use_kernel: bool = True):
    """Batched Brandes. adj: f32[N, N]; sources: i32[S] (-1 = padding)."""
    n = adj.shape[0]
    s = sources.shape[0]
    mm = frontier_matmul if use_kernel else matmul_ref
    adj_t = adj.T

    valid = (sources >= 0).astype(jnp.float32)  # [S]
    src = jnp.clip(sources, 0, n - 1)
    x0 = jax.nn.one_hot(src, n, dtype=jnp.float32).T * valid  # [N, S]

    sigma0 = x0
    dist0 = jnp.where(x0 > 0, 0.0, _INF)  # [N, S]
    frontier0 = x0

    def fwd_cond(c):
        _level, frontier, _sigma, _dist = c
        return jnp.any(frontier > 0)

    def fwd_body(c):
        level, frontier, sigma, dist = c
        # Path counts arriving one hop out from the current frontier.
        contrib = mm(adj_t, sigma * frontier)  # [N, S]
        new = (contrib > 0) & jnp.isinf(dist)
        dist = jnp.where(new, jnp.float32(level + 1), dist)
        sigma = sigma + jnp.where(new, contrib, 0.0)
        return level + 1, new.astype(jnp.float32), sigma, dist

    levels, _f, sigma, dist = lax.while_loop(
        fwd_cond, fwd_body, (jnp.int32(0), frontier0, sigma0, dist0)
    )

    # Backward sweep: lev runs levels-1 .. 0; vertices at lev+1 feed lev.
    safe_sigma = jnp.maximum(sigma, 1.0)

    def bwd_cond(c):
        lev, _delta = c
        return lev >= 0

    def bwd_body(c):
        lev, delta = c
        flev = jnp.float32(lev)
        coef = jnp.where(dist == flev + 1.0, (1.0 + delta) / safe_sigma, 0.0)
        back = mm(adj, coef)  # back[v] = sum_w adj[v, w] * coef[w]
        delta = delta + jnp.where(dist == flev, sigma * back, 0.0)
        return lev - 1, delta

    _lev, delta = lax.while_loop(
        bwd_cond, bwd_body, (levels - 1, jnp.zeros_like(dist0))
    )

    visited = jnp.isfinite(dist)
    # Exclude each batch's own source (dist == 0) from its contribution.
    bc = jnp.sum(jnp.where(visited & (dist > 0), delta, 0.0), axis=1)  # [N]
    deg = jnp.sum(adj, axis=1)  # out-degrees [N]
    edges = jnp.sum(visited.astype(jnp.float32) * deg[:, None])
    return bc, edges, levels


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def brandes_batch_jit(adj, sources, use_kernel: bool = True):
    return brandes_batch(adj, sources, use_kernel=use_kernel)
