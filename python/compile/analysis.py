"""L1 kernel resource analysis: VMEM footprint + MXU utilization
estimates for the Pallas frontier matmul on real TPU hardware.

`interpret=True` gives CPU-numpy timings only, which are *not* a TPU
proxy — so TPU efficiency is estimated structurally from the BlockSpec,
per the DESIGN.md §8 methodology:

* VMEM working set = A tile + X tile + output accumulator (f32), double-
  buffered for the HBM→VMEM pipeline;
* MXU work = 2·N·K·S FLOPs per batch; utilization bound = ratio of
  MXU-shaped dims (multiples of 128 fill the systolic array; smaller
  S under-fills the lane dimension);
* HBM traffic per batch = A streamed once per S-panel + X/O tiles.

Usage:  python -m compile.analysis [--n 256 --s 64]
Also consumed by tests (pure functions, no side effects).
"""

import argparse
from dataclasses import dataclass

from .kernels.bc_frontier import vmem_bytes

MXU_DIM = 128  # systolic array edge (TPU v2+)
VMEM_BUDGET = 16 << 20  # ~16 MiB/core


@dataclass
class KernelEstimate:
    n: int
    k: int
    s: int
    bn: int
    bk: int
    bs: int
    vmem_single: int
    vmem_double_buffered: int
    flops: int
    hbm_bytes: int
    mxu_fill: float

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_double_buffered <= VMEM_BUDGET

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per HBM byte — compare against the TPU roofline knee."""
        return self.flops / max(self.hbm_bytes, 1)


def estimate(n: int, k: int, s: int, bn: int = 256, bk: int = 256, bs: int = 128) -> KernelEstimate:
    """Resource estimate for one `frontier_matmul(a[N,K], x[K,S])` call."""
    bn, bk, bs = min(bn, n), min(bk, k), min(bs, s)
    single = vmem_bytes(bn, bs, bk)
    # MXU fill: each dim contributes min(dim, 128)/128 of the array.
    fill = (min(bn, MXU_DIM) / MXU_DIM) * (min(bs, MXU_DIM) / MXU_DIM)
    # HBM: A streamed once per S-panel, X once per N-panel, O written once.
    s_panels = max(s // bs, 1)
    n_panels = max(n // bn, 1)
    hbm = 4 * (n * k * s_panels + k * s * n_panels + n * s)
    return KernelEstimate(
        n=n,
        k=k,
        s=s,
        bn=bn,
        bk=bk,
        bs=bs,
        vmem_single=single,
        vmem_double_buffered=2 * single,
        flops=2 * n * k * s,
        hbm_bytes=hbm,
        mxu_fill=fill,
    )


def render_table(shapes) -> str:
    rows = [
        f"{'N':>6} {'S':>5} {'tile':>12} {'VMEM(2x)':>10} {'fits':>5} "
        f"{'MFLOP':>8} {'AI':>6} {'MXU fill':>9}"
    ]
    for n, s in shapes:
        e = estimate(n, n, s)
        rows.append(
            f"{e.n:>6} {e.s:>5} {f'{e.bn}x{e.bk}x{e.bs}':>12} "
            f"{e.vmem_double_buffered / 1024:>9.0f}K {'y' if e.fits_vmem else 'N':>5} "
            f"{e.flops / 1e6:>8.2f} {e.arithmetic_intensity:>6.1f} {e.mxu_fill:>9.2f}"
        )
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=0, help="single shape to analyse")
    ap.add_argument("--s", type=int, default=64)
    args = ap.parse_args()
    shapes = (
        [(args.n, args.s)]
        if args.n
        else [(64, 16), (256, 32), (256, 64), (1024, 128), (4096, 128), (8192, 256)]
    )
    print(render_table(shapes))


if __name__ == "__main__":
    main()
