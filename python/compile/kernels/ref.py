"""Pure-jnp / numpy oracles for the L1 kernels and the L2 model.

The CORE correctness chain:

    numpy Brandes (this file, loops, f64)
      == jnp dense batched Brandes (model.brandes_batch with ref matmul)
      == Pallas-kernel batched Brandes (model.brandes_batch, default)
      == rust sparse Brandes (cross-checked in rust tests via fixtures)
"""

import jax.numpy as jnp
import numpy as np


def matmul_ref(a, x):
    """Oracle for kernels.bc_frontier.frontier_matmul."""
    return jnp.dot(a, x, preferred_element_type=jnp.float32)


def uts_expand_ref(h, b0: float = 4.0):
    """Oracle for kernels.uts_expand.uts_expand (same f32 arithmetic)."""
    u = (np.asarray(h, dtype=np.uint32) & np.uint32(0x7FFFFFFF)).astype(
        np.float32
    ) / np.float32(2**31)
    p = np.float32(1.0 / (1.0 + b0))
    return np.floor(np.log1p(-u) / np.log1p(-p)).astype(np.int32)


def brandes_ref(adj: np.ndarray, sources) -> tuple[np.ndarray, int]:
    """Loop-and-queue Brandes in f64 over a dense adjacency.

    Returns (partial betweenness over the given sources, edges traversed).
    Matches rust/src/apps/bc/brandes.rs semantics (directed edges, ordered
    pairs, source excluded).
    """
    n = adj.shape[0]
    assert adj.shape == (n, n)
    nbrs = [np.nonzero(adj[v])[0] for v in range(n)]
    bc = np.zeros(n, dtype=np.float64)
    edges = 0
    for s in sources:
        if s < 0:
            continue
        dist = np.full(n, -1, dtype=np.int64)
        sigma = np.zeros(n, dtype=np.float64)
        dist[s] = 0
        sigma[s] = 1.0
        order = [s]
        head = 0
        while head < len(order):
            v = order[head]
            head += 1
            for w in nbrs[v]:
                edges += 1
                if dist[w] < 0:
                    dist[w] = dist[v] + 1
                    order.append(w)
                if dist[w] == dist[v] + 1:
                    sigma[w] += sigma[v]
        delta = np.zeros(n, dtype=np.float64)
        for v in reversed(order):
            for w in nbrs[v]:
                if dist[w] == dist[v] + 1:
                    delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
            if v != s:
                bc[v] += delta[v]
    return bc, edges


def random_adjacency(n: int, density: float, seed: int) -> np.ndarray:
    """Random directed 0/1 adjacency without self-loops."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    return adj


def path_adjacency(n: int) -> np.ndarray:
    """Undirected path as a dense adjacency."""
    adj = np.zeros((n, n), dtype=np.float32)
    for i in range(n - 1):
        adj[i, i + 1] = 1.0
        adj[i + 1, i] = 1.0
    return adj


def star_adjacency(k: int) -> np.ndarray:
    """Undirected star: center 0, k leaves."""
    adj = np.zeros((k + 1, k + 1), dtype=np.float32)
    for i in range(1, k + 1):
        adj[0, i] = 1.0
        adj[i, 0] = 1.0
    return adj
