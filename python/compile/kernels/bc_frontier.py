"""L1 Pallas kernel: blocked frontier matmul for batched Brandes.

The hot operation of dense level-synchronous Brandes is ``A @ X`` where
``A`` is the (possibly transposed) N x N adjacency and ``X`` an N x S
batch panel (sigma-weighted frontier on the forward sweep, dependency
coefficients on the backward sweep).

Hardware adaptation (DESIGN.md "Hardware-Adaptation"): the paper's
CPU-cluster inner loop becomes an MXU-shaped tiled matmul. ``BlockSpec``
expresses the HBM->VMEM schedule: the grid walks (rows, batch, K) so each
(bn x bk) @ (bk x bs) tile pass streams A once per batch column and
accumulates f32 partials in the output tile, which stays resident across
the K dimension (``dimension_semantics``: K is the innermost, sequential
axis). Tile sizes default to 128/256 — MXU-native multiples that keep
double-buffered tiles well under the ~16 MiB VMEM budget (see
DESIGN.md section Perf for the footprint table).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the rust CPU client. On a real TPU the identical kernel
body compiles through Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, x_ref, o_ref):
    """One (bn x bk) @ (bk x bs) tile pass, accumulating into o_ref."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (tiles must evenly
    divide the operand: Brandes shapes are powers of two by construction,
    so this is nearly always ``preferred`` itself)."""
    b = min(dim, preferred)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bn", "bs", "bk"))
def frontier_matmul(a, x, *, bn: int = 256, bs: int = 128, bk: int = 256):
    """``a @ x`` via the Pallas tiled kernel.

    a: f32[N, K], x: f32[K, S] -> f32[N, S].
    """
    n, k = a.shape
    k2, s = x.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bn = _pick_block(n, bn)
    bs = _pick_block(s, bs)
    bk = _pick_block(k, bk)
    grid = (n // bn, s // bs, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bs), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn, bs), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, s), jnp.float32),
        interpret=True,
    )(a, x)


def vmem_bytes(bn: int, bs: int, bk: int) -> int:
    """Estimated VMEM working set of one grid step (A tile + X tile +
    output accumulator, f32), for the DESIGN.md roofline table."""
    return 4 * (bn * bk + bk * bs + bn * bs)
