"""L1 Pallas kernel: vectorized UTS geometric child counts.

Demonstrates the integer/elementwise Pallas path: given a batch of node
hash words (the first 32 bits of each UTS descriptor), produce each
node's child count under the fixed geometric law with mean ``b0``
(paper section 2.5.1):

    u        = (h & 0x7fffffff) / 2^31
    children = floor(log(1 - u) / log(1 - p)),   p = 1 / (1 + b0)

This mirrors ``rust/src/apps/uts/sha1rand.rs::geometric_children`` (the
request-path implementation); the artifact exists to exercise a second,
non-matmul kernel through the full AOT pipeline and for batch-expansion
experiments. VPU-only: no MXU work, one load + a handful of
transcendentals + one store per lane.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expand_kernel(h_ref, o_ref, *, b0: float):
    h = h_ref[...]
    u = (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.float32) / jnp.float32(2**31)
    p = jnp.float32(1.0 / (1.0 + b0))
    denom = jnp.log1p(-p)
    # u < 1 strictly (31-bit mantissa), so log1p(-u) is finite.
    kids = jnp.floor(jnp.log1p(-u) / denom)
    o_ref[...] = kids.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("b0", "block"))
def uts_expand(h, *, b0: float = 4.0, block: int = 256):
    """Child counts for a batch of node hash words.

    h: u32[B] -> i32[B].
    """
    (b,) = h.shape
    blk = min(b, block)
    while b % blk:
        blk -= 1
    return pl.pallas_call(
        functools.partial(_expand_kernel, b0=b0),
        grid=(b // blk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(h)
