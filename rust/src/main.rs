//! The `glb` launcher binary. See [`glb::cli::USAGE`].

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use glb::apps::bc::{sequential_bc, BcQueue, Graph, RmatParams};
use glb::apps::fib::{fib, FibQueue};
use glb::apps::nqueens::{NQueensQueue, KNOWN};
use glb::apps::uts::{UtsParams, UtsQueue};
use glb::cli::{glb_params_from, tcp_opts_from, transport_from, Args, TransportKind, USAGE};
use glb::glb::task_queue::{SumReducer, VecSumReducer};
use glb::glb::GlbConfig;
use glb::harness::{calibrate_bc_cost, calibrate_uts_cost, fig_bc_perf, fig_bc_workload, fig_uts, FigOpts};
use glb::launch::report::{build_rank_report, rank_report_line, rank_report_requested};
use glb::place::{
    net_stats, run_sockets_reduced, run_threads, serve, wire_bytes, JobSpec, NetStats,
    SocketRunOpts, SubmitClient,
};
use glb::runtime::{default_artifact_dir, DeviceService};
use glb::sim::{run_sim, ArchProfile, BGQ};
use glb::util::json::Value;
use glb::util::timefmt::{fmt_count, fmt_ns, fmt_rate};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
        print!("{USAGE}");
        return;
    }
    let cmd = argv[0].clone();
    let rest = &argv[1..];
    let code = match dispatch(&cmd, rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

const COMMON: &[&str] = &[
    "places", "threads", "sim", "arch", "n", "w", "l", "z", "seed", "workers-per-node",
    "random-only", "rounds", "log", "csv", "autotune", "transport", "rank", "peers", "port",
    "host", "bind", "advertise", "tolerate-failures", "stats-interval", "adapt", "report",
];

fn dispatch(cmd: &str, rest: &[String]) -> Result<()> {
    match cmd {
        "uts" => cmd_uts(rest),
        "bc" => cmd_bc(rest),
        "fib" => cmd_fib(rest),
        "nqueens" => cmd_nqueens(rest),
        "fig" => cmd_fig(rest),
        "serve" => cmd_serve(rest),
        "submit" => cmd_submit(rest),
        "launch" => glb::launch::cmd_launch(rest),
        "bench" => glb::launch::cmd_bench(rest),
        "calibrate" => cmd_calibrate(),
        "smoke" => {
            println!("platform={}", glb::smoke()?);
            Ok(())
        }
        "lint" => cmd_lint(rest),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn arch_from(args: &Args) -> Result<&'static ArchProfile> {
    let name = args.get("arch").unwrap_or("bgq");
    ArchProfile::by_name(name).ok_or_else(|| anyhow!("unknown --arch {name}"))
}

fn socket_opts_from(t: &glb::cli::TcpOpts) -> SocketRunOpts {
    SocketRunOpts {
        rank: t.rank,
        ranks: t.peers,
        host: t.host.clone(),
        port: t.port,
        bind: t.bind.clone(),
        advertise: t.advertise.clone(),
        tolerate_failures: t.tolerate_failures,
        stats_interval: t.stats_interval_ms.map(std::time::Duration::from_millis),
        adapt: t.adapt,
        ..Default::default()
    }
}

fn finish<R>(out: &glb::glb::RunOutput<R>, unit: &str, log: bool) {
    println!(
        "elapsed={}  rate={} {unit}",
        fmt_ns(out.elapsed_ns),
        fmt_rate(out.units_per_sec()),
    );
    if log {
        print!("{}", out.log.render());
    }
}

/// `--report PATH` on a single-process run: write the same fleet-report
/// schema the launcher produces, with this run as its only rank — CI
/// diffs a thread run's report against a launched fleet's bit-for-bit
/// on the result field.
fn write_report_if_asked<R>(
    app: &str,
    transport: &str,
    args: &Args,
    result_json: Value,
    out: &glb::glb::RunOutput<R>,
) -> Result<()> {
    let Some(path) = args.get("report") else { return Ok(()) };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let rank = build_rank_report(
        app,
        transport,
        (0, 1),
        result_json,
        out.elapsed_ns,
        &out.log,
        (0, 0),
        NetStats::default(),
    );
    let fleet = glb::launch::report::aggregate_fleet(
        app,
        &argv,
        vec![rank],
        out.elapsed_ns as f64 / 1e9,
        &[],
    )?;
    std::fs::write(path, fleet.render_pretty())
        .with_context(|| format!("write run report {path}"))?;
    println!("run report -> {path}");
    Ok(())
}

/// Print this tcp rank's report line when a launcher parent asked for it
/// (`GLB_RANK_REPORT=1`); the launcher aggregates the fleet.
fn emit_rank_report<R>(
    app: &str,
    rank: usize,
    ranks: usize,
    result_json: Value,
    out: &glb::glb::RunOutput<R>,
) {
    if rank_report_requested() {
        let r = build_rank_report(
            app,
            "tcp",
            (rank, ranks),
            result_json,
            out.elapsed_ns,
            &out.log,
            wire_bytes(),
            net_stats(),
        );
        println!("{}", rank_report_line(&r));
    }
}

/// BC's reduced result, summarized for reports (the full betweenness
/// vector is too large to log per rank).
fn bc_result_json(bc: &[f64]) -> Value {
    Value::obj(vec![
        ("len", Value::Int(bc.len() as i64)),
        ("sum", Value::Float(bc.iter().sum::<f64>())),
    ])
}

fn cmd_uts(rest: &[String]) -> Result<()> {
    let mut known = COMMON.to_vec();
    known.extend(["depth", "b0", "seed-tree"]);
    let args =
        Args::parse(rest, &["threads", "sim", "log", "csv", "random-only", "autotune", "adapt"])?;
    args.ensure_known(&known)?;
    let up = UtsParams {
        b0: args.parse_opt("b0", 4.0f64)?,
        seed: args.parse_opt("seed-tree", 19u32)?,
        max_depth: args.parse_opt("depth", 10u32)?,
    };
    let transport = transport_from(&args)?;
    if transport == TransportKind::Tcp {
        // One process per GLB node: this invocation runs rank R of a
        // --peers N fleet and reports its local share of the count.
        if args.flag("autotune") {
            bail!("--autotune is not supported with --transport tcp yet");
        }
        if args.get("report").is_some() {
            bail!("use `glb launch --report` to aggregate a fleet report (not per rank)");
        }
        let t = tcp_opts_from(&args)?;
        let params = glb_params_from(&args)?;
        let p = args.parse_opt("places", t.peers * params.workers_per_node)?;
        let cfg = GlbConfig::new(p, params);
        let opts = socket_opts_from(&t);
        let out = run_sockets_reduced(
            &cfg,
            &opts,
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        )?;
        if t.rank == 0 {
            println!(
                "uts-g(tcp mesh of {}) places={p} depth={} nodes={}",
                t.peers,
                up.max_depth,
                fmt_count(out.result)
            );
        } else {
            println!(
                "uts-g(tcp rank {}/{}) places={p} depth={} local-nodes={}",
                t.rank,
                t.peers,
                up.max_depth,
                fmt_count(out.result)
            );
        }
        finish(&out, "nodes/s", args.flag("log"));
        emit_rank_report("uts", t.rank, t.peers, Value::Int(out.result as i64), &out);
        return Ok(());
    }
    let p = args.parse_opt("places", 4usize)?;
    let params = if args.flag("autotune") {
        let tuned = glb::glb::autotune::autotune_uts(p);
        println!(
            "autotuned: n={} w={} l={} workers-per-node={} (paper future-work item 4)",
            tuned.n, tuned.w, tuned.l, tuned.workers_per_node
        );
        tuned
    } else {
        glb_params_from(&args)?
    };
    let cfg = GlbConfig::new(p, params);
    if transport == TransportKind::Sim {
        let arch = arch_from(&args)?;
        let cost = calibrate_uts_cost();
        let (out, rep) = if args.flag("adapt") {
            glb::sim::run_sim_adaptive(
                &cfg,
                arch,
                cost,
                glb::glb::AdaptiveConfig::default(),
                20_000, // observe every 20µs of virtual time
                |_, _| UtsQueue::new(up),
                |q| q.init_root(),
                &SumReducer,
            )
        } else {
            run_sim(&cfg, arch, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer)
        };
        println!("uts-g(sim/{}) places={p} depth={} nodes={}", arch.name, up.max_depth, fmt_count(out.result));
        println!("virtual messages={} events={}", rep.messages, rep.events);
        if args.flag("adapt") {
            let retunes: u64 = out.log.per_place.iter().map(|s| s.retunes).sum();
            println!("adaptive: {retunes} mid-run retune(s)");
        }
        finish(&out, "nodes/s", args.flag("log"));
        write_report_if_asked("uts", "sim", &args, Value::Int(out.result as i64), &out)?;
    } else {
        if args.flag("adapt") {
            bail!("--adapt needs --transport tcp or --sim (the thread runtime has no telemetry plane yet)");
        }
        let out = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        println!("uts-g(threads) places={p} depth={} nodes={}", up.max_depth, fmt_count(out.result));
        finish(&out, "nodes/s", args.flag("log"));
        write_report_if_asked("uts", "thread", &args, Value::Int(out.result as i64), &out)?;
    }
    Ok(())
}

fn cmd_bc(rest: &[String]) -> Result<()> {
    let mut known = COMMON.to_vec();
    known.extend(["scale", "engine", "verify"]);
    let args =
        Args::parse(rest, &["threads", "sim", "log", "csv", "random-only", "verify", "adapt"])?;
    args.ensure_known(&known)?;
    let scale = args.parse_opt("scale", 9u32)?;
    let engine = args.get("engine").unwrap_or("sparse");
    if transport_from(&args)? == TransportKind::Tcp {
        // Fleet BC: every rank builds the same deterministic R-MAT graph
        // and runs its share of source vertices; the per-rank partial
        // betweenness vectors are element-wise summed at rank 0 during
        // result collection (run_sockets_reduced + VecSumReducer).
        if engine != "sparse" {
            bail!("--transport tcp supports --engine sparse (dense is PJRT, single-process)");
        }
        if args.get("report").is_some() {
            bail!("use `glb launch --report` to aggregate a fleet report (not per rank)");
        }
        let t = tcp_opts_from(&args)?;
        let params = glb_params_from(&args)?;
        let p = args.parse_opt("places", t.peers * params.workers_per_node)?;
        let g = Arc::new(Graph::rmat(RmatParams { scale, ..Default::default() }));
        let n = g.n() as u32;
        println!("graph: n={} m={} (SSCA2 R-MAT scale {scale})", g.n(), g.m());
        let cfg = GlbConfig::new(p, params);
        let opts = socket_opts_from(&t);
        let gg = g.clone();
        let out = run_sockets_reduced(
            &cfg,
            &opts,
            move |i, np| seeded_queue(&gg, i, np, n),
            |_| {},
            &VecSumReducer,
        )?;
        if t.rank == 0 {
            let top = top_vertices(&out.result, 5);
            println!(
                "bc-g(tcp mesh of {}) places={p} engine=sparse; top-5 betweenness \
                 vertices: {top:?}",
                t.peers
            );
            if args.flag("verify") {
                verify_bc(&g, &out.result)?;
            }
        } else {
            println!("bc-g(tcp rank {}/{}) places={p} engine=sparse", t.rank, t.peers);
            if args.flag("verify") {
                println!("verify: skipped on spokes (rank 0 holds the fleet-wide reduction)");
            }
        }
        finish(&out, "edges/s", args.flag("log"));
        emit_rank_report("bc", t.rank, t.peers, bc_result_json(&out.result), &out);
        return Ok(());
    }
    if args.flag("adapt") {
        bail!("--adapt needs --transport tcp (use `glb uts --sim --adapt` for the sim ablation)");
    }
    let p = args.parse_opt("places", 4usize)?;
    let params = glb_params_from(&args)?;
    let g = Arc::new(Graph::rmat(RmatParams { scale, ..Default::default() }));
    let n = g.n() as u32;
    println!("graph: n={} m={} (SSCA2 R-MAT scale {scale})", g.n(), g.m());
    let cfg = GlbConfig::new(p, params);

    let out = match engine {
        "sparse" => {
            if args.flag("sim") {
                let arch = arch_from(&args)?;
                let cost = calibrate_bc_cost(&g);
                let gg = g.clone();
                let (out, _) = run_sim(
                    &cfg,
                    arch,
                    cost,
                    move |i, np| seeded_queue(&gg, i, np, n),
                    |_| {},
                    &VecSumReducer,
                );
                out
            } else {
                let gg = g.clone();
                run_threads(&cfg, move |i, np| seeded_queue(&gg, i, np, n), |_| {}, &VecSumReducer)
            }
        }
        "dense" => {
            let svc = DeviceService::start(&default_artifact_dir(), g.dense_adjacency(), g.n())?;
            let handle = svc.handle();
            println!("device: PJRT batched Brandes (S={})", handle.batch());
            run_threads(
                &cfg,
                move |i, np| {
                    let mut q = BcQueue::dense(handle.clone());
                    let per = n / np as u32;
                    let lo = i as u32 * per;
                    let hi = if i == np - 1 { n } else { lo + per };
                    q.assign(lo, hi);
                    q
                },
                |_| {},
                &VecSumReducer,
            )
        }
        other => bail!("unknown --engine {other} (sparse|dense)"),
    };

    let top = top_vertices(&out.result, 5);
    println!("bc-g places={p} engine={engine}; top-5 betweenness vertices: {top:?}");
    if args.flag("verify") {
        verify_bc(&g, &out.result)?;
    }
    finish(&out, "edges/s", args.flag("log"));
    let transport = if args.flag("sim") { "sim" } else { "thread" };
    write_report_if_asked("bc", transport, &args, bc_result_json(&out.result), &out)?;
    Ok(())
}

/// Check a betweenness map against sequential Brandes on the same graph.
fn verify_bc(g: &Graph, result: &[f64]) -> Result<()> {
    let (expect, _) = sequential_bc(g);
    let worst = result
        .iter()
        .zip(&expect)
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0f64, f64::max);
    println!("verify: max relative error vs sequential = {worst:.2e}");
    if worst > 1e-3 {
        bail!("verification failed (rel err {worst:.2e})");
    }
    Ok(())
}

fn seeded_queue(g: &Arc<Graph>, i: usize, np: usize, n: u32) -> BcQueue {
    let mut q = BcQueue::sparse(g.clone());
    let per = n / np as u32;
    let lo = i as u32 * per;
    let hi = if i == np - 1 { n } else { lo + per };
    q.assign(lo, hi);
    q
}

fn top_vertices(bc: &[f64], k: usize) -> Vec<(usize, f64)> {
    let mut idx: Vec<usize> = (0..bc.len()).collect();
    idx.sort_by(|&a, &b| bc[b].partial_cmp(&bc[a]).unwrap());
    idx.into_iter().take(k).map(|i| (i, (bc[i] * 100.0).round() / 100.0)).collect()
}

fn cmd_fib(rest: &[String]) -> Result<()> {
    let mut known = COMMON.to_vec();
    known.push("fib-n");
    let args = Args::parse(rest, &["threads", "sim", "log", "csv", "random-only", "adapt"])?;
    args.ensure_known(&known)?;
    let n = args.parse_opt("fib-n", 24u64)?;
    if transport_from(&args)? == TransportKind::Tcp {
        // Fleet fib: rank 0 seeds the root task, work spreads over the
        // mesh, and rank 0 gathers the fleet-wide sum. Small enough to be
        // the second chaos-test workload next to UTS.
        if args.get("report").is_some() {
            bail!("use `glb launch --report` to aggregate a fleet report (not per rank)");
        }
        let t = tcp_opts_from(&args)?;
        let params = glb_params_from(&args)?;
        let p = args.parse_opt("places", t.peers * params.workers_per_node)?;
        let cfg = GlbConfig::new(p, params);
        let opts = socket_opts_from(&t);
        let out = run_sockets_reduced(
            &cfg,
            &opts,
            |_, _| FibQueue::new(),
            move |q| q.init(n),
            &SumReducer,
        )?;
        if t.rank == 0 {
            println!("fib-glb({n}) = {} (closed form {})", out.result, fib(n));
            if out.result != fib(n) {
                bail!("fib mismatch!");
            }
        } else {
            println!("fib-glb({n}) tcp rank {}/{} local-sum={}", t.rank, t.peers, out.result);
        }
        finish(&out, "tasks/s", args.flag("log"));
        emit_rank_report("fib", t.rank, t.peers, Value::Int(out.result as i64), &out);
        return Ok(());
    }
    if args.flag("adapt") {
        bail!("--adapt needs --transport tcp (use `glb uts --sim --adapt` for the sim ablation)");
    }
    let p = args.parse_opt("places", 4usize)?;
    let cfg = GlbConfig::new(p, glb_params_from(&args)?);
    let out = run_threads(&cfg, |_, _| FibQueue::new(), |q| q.init(n), &SumReducer);
    println!("fib-glb({n}) = {} (closed form {})", out.result, fib(n));
    finish(&out, "tasks/s", args.flag("log"));
    if out.result != fib(n) {
        bail!("fib mismatch!");
    }
    write_report_if_asked("fib", "thread", &args, Value::Int(out.result as i64), &out)?;
    Ok(())
}

fn cmd_nqueens(rest: &[String]) -> Result<()> {
    let mut known = COMMON.to_vec();
    known.push("board");
    let args = Args::parse(rest, &["threads", "sim", "log", "csv", "random-only", "adapt"])?;
    args.ensure_known(&known)?;
    let b = args.parse_opt("board", 10u8)?;
    if transport_from(&args)? == TransportKind::Tcp {
        // Fleet N-Queens: rank 0 seeds the empty board, partial
        // placements travel as 13-byte wire entries, rank 0 gathers the
        // fleet-wide solution count.
        if args.get("report").is_some() {
            bail!("use `glb launch --report` to aggregate a fleet report (not per rank)");
        }
        let t = tcp_opts_from(&args)?;
        let params = glb_params_from(&args)?;
        let p = args.parse_opt("places", t.peers * params.workers_per_node)?;
        let cfg = GlbConfig::new(p, params);
        let opts = socket_opts_from(&t);
        let out = run_sockets_reduced(
            &cfg,
            &opts,
            move |_, _| NQueensQueue::new(b),
            |q| q.init_root(),
            &SumReducer,
        )?;
        if t.rank == 0 {
            println!("nqueens({b}) = {} solutions", out.result);
            if (b as usize) < KNOWN.len() && out.result != KNOWN[b as usize] {
                bail!("nqueens mismatch: expected {}", KNOWN[b as usize]);
            }
        } else {
            println!("nqueens({b}) tcp rank {}/{} local-count={}", t.rank, t.peers, out.result);
        }
        finish(&out, "boards/s", args.flag("log"));
        emit_rank_report("nqueens", t.rank, t.peers, Value::Int(out.result as i64), &out);
        return Ok(());
    }
    if args.flag("adapt") {
        bail!("--adapt needs --transport tcp (use `glb uts --sim --adapt` for the sim ablation)");
    }
    let p = args.parse_opt("places", 4usize)?;
    let cfg = GlbConfig::new(p, glb_params_from(&args)?);
    let out = run_threads(&cfg, move |_, _| NQueensQueue::new(b), |q| q.init_root(), &SumReducer);
    println!("nqueens({b}) = {} solutions", out.result);
    finish(&out, "boards/s", args.flag("log"));
    write_report_if_asked("nqueens", "thread", &args, Value::Int(out.result as i64), &out)?;
    Ok(())
}

fn cmd_fig(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["csv", "log"])?;
    args.ensure_known(&[
        "id", "csv", "log", "places", "depth", "scale", "n", "w", "l", "z", "seed",
        "workers-per-node",
    ])?;
    let id: u32 = args.parse_opt("id", 0u32)?;
    if !(2..=10).contains(&id) {
        bail!("--id must be 2..=10 (paper figures)");
    }
    // Defaults chosen so each figure finishes in tens of seconds on one
    // core; override with --places/--depth/--scale for bigger sweeps.
    let mut opts = FigOpts {
        csv: args.flag("csv"),
        params: glb_params_from(&args)?,
        ..Default::default()
    };
    let default_places: &[usize] = match id {
        2 | 3 | 4 => &[1, 4, 16, 64, 256],
        _ => &[1, 4, 16, 32],
    };
    opts.places = args.parse_list("places", default_places)?;
    opts.uts_depth = args.parse_opt("depth", 9u32)?;
    opts.bc_scale = args.parse_opt("scale", 12u32)?;
    if id >= 5 && args.get("n").is_none() {
        // BC-G defaults (paper §2.6): interruptible edge budget + max w.
        opts.params = opts.params.with_n(8192).with_w(4).with_l(2);
    }

    match id {
        2 => print!("{}", fig_uts(&glb::sim::POWER775, &opts).text),
        3 => print!("{}", fig_uts(&BGQ, &opts).text),
        4 => print!("{}", fig_uts(&glb::sim::K, &opts).text),
        5 | 7 | 9 => {
            let arch = match id {
                5 => &BGQ,
                7 => &glb::sim::K,
                _ => &glb::sim::POWER775,
            };
            print!("{}", fig_bc_perf(arch, &opts).text);
        }
        6 | 8 | 10 => {
            let arch = match id {
                6 => &BGQ,
                8 => &glb::sim::K,
                _ => &glb::sim::POWER775,
            };
            let (t, summary) = fig_bc_workload(arch, &opts);
            println!("{summary}");
            if args.flag("log") {
                print!("{}", if opts.csv { t.to_csv() } else { t.render() });
            }
        }
        _ => unreachable!(),
    }
    Ok(())
}

/// `glb serve` — boot this rank of a resident fleet and process
/// streamed jobs until a client sends `Ctrl::Shutdown`. One process per
/// rank, exactly like the one-shot tcp transport, but the mesh and
/// control links outlive every job.
fn cmd_serve(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    args.ensure_known(&["rank", "peers", "port", "host", "bind", "advertise"])?;
    let t = tcp_opts_from(&args)?;
    serve(&socket_opts_from(&t))
}

/// `glb submit <uts|bc|fib> …` — ship one job (or `--repeat N` copies)
/// to a resident fleet started with `glb serve`, block for each result,
/// and print it. `--shutdown` retires the fleet afterwards.
fn cmd_submit(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &["shutdown"])?;
    args.ensure_known(&[
        "host", "port", "timeout", "repeat", "shutdown", // client knobs
        "depth", "b0", "seed-tree", "fib-n", "scale", // app knobs
        "n", "w", "l", "z", "seed", // GLB knobs
    ])?;
    let host = args.get("host").unwrap_or("127.0.0.1");
    let port: u16 = args.parse_opt("port", 7117u16)?;
    let timeout = Duration::from_secs(args.parse_opt("timeout", 30u64)?);
    let repeat: usize = args.parse_opt("repeat", 1usize)?;
    let spec = match args.positional.first().map(String::as_str) {
        Some("uts") => JobSpec::uts(
            UtsParams {
                b0: args.parse_opt("b0", 4.0f64)?,
                seed: args.parse_opt("seed-tree", 19u32)?,
                max_depth: args.parse_opt("depth", 10u32)?,
            },
            glb_params_from(&args)?,
        ),
        Some("fib") => JobSpec::fib(args.parse_opt("fib-n", 24u64)?, glb_params_from(&args)?),
        Some("bc") => JobSpec::bc(args.parse_opt("scale", 9u32)?, glb_params_from(&args)?),
        Some(other) => bail!("unknown app {other:?} (uts|bc|fib)"),
        None if args.flag("shutdown") => {
            // Bare `glb submit --shutdown`: retire the fleet, no job.
            let client = SubmitClient::connect(host, port, timeout)?;
            client.shutdown()?;
            println!("fleet at {host}:{port} asked to shut down");
            return Ok(());
        }
        None => bail!("submit needs an app: glb submit <uts|bc|fib> [options]\n\n{USAGE}"),
    };
    let mut client = SubmitClient::connect(host, port, timeout)?;
    for i in 1..=repeat {
        let t0 = Instant::now();
        let res = client.submit(&spec)?;
        println!(
            "job {i}/{repeat} [{}] -> {}  elapsed={}",
            spec.format(),
            res.summary(),
            fmt_ns(t0.elapsed().as_nanos() as u64),
        );
    }
    if args.flag("shutdown") {
        client.shutdown()?;
        println!("fleet at {host}:{port} asked to shut down");
    }
    Ok(())
}

fn cmd_calibrate() -> Result<()> {
    let uts = calibrate_uts_cost();
    println!("uts: {:.1} ns/node (SHA-1 expansion)", uts.ns_per_unit);
    let g = Graph::rmat(RmatParams { scale: 10, ..Default::default() });
    let bc = calibrate_bc_cost(&g);
    println!("bc : {:.2} ns/edge (sparse Brandes, scale-10 R-MAT)", bc.ns_per_unit);
    Ok(())
}

/// `glb lint [--root DIR]` — run the protocol/concurrency invariant
/// checker over the source tree (see [`glb::analysis`]). Exits nonzero
/// iff any finding survives; CI runs this as a hard gate.
fn cmd_lint(rest: &[String]) -> Result<()> {
    let args = Args::parse(rest, &[])?;
    args.ensure_known(&["root"])?;
    let root = args.get("root").unwrap_or(".");
    let findings = glb::analysis::lint_tree(std::path::Path::new(root))?;
    print!("{}", glb::analysis::render(&findings));
    if !findings.is_empty() {
        bail!("glb lint: {} invariant finding(s)", findings.len());
    }
    Ok(())
}
