//! The `TaskBag` abstraction (paper §2.3).
//!
//! A task bag is a container of *relocatable* tasks. GLB moves work between
//! places by calling `split` on a victim's bag and `merge` on the thief's.
//! Relocatability is enforced at compile time by the `Send + 'static`
//! bound — a bag is handed to another place (thread) by value.
//!
//! The paper ships a default `ArrayList`-based bag whose `split` removes
//! half of the elements from the end and whose `merge` appends; that is
//! [`ArrayListTaskBag`] below. Applications with richer structure (UTS
//! node ranges, BC vertex intervals) implement the trait directly.

/// A splittable, mergeable multiset of tasks.
pub trait TaskBag: Send + 'static {
    /// Number of task items currently in the bag. GLB uses this only as a
    /// heuristic (whether the bag is worth splitting); it need not equal
    /// the eventual amount of *work* (e.g. UTS subtree sizes are unknown).
    fn size(&self) -> usize;

    /// Split off roughly half of the bag. Returns `None` when the bag is
    /// too small to split (the paper: "returns null if the TaskBag is too
    /// small to split").
    fn split(&mut self) -> Option<Self>
    where
        Self: Sized;

    /// Merge another bag into this one.
    fn merge(&mut self, other: Self);

    /// True when there is nothing left to process.
    fn is_empty(&self) -> bool {
        self.size() == 0
    }
}

/// The default bag: a `Vec` of task items; `split` removes the second half
/// from the end (constant amortized per item, preserving LIFO depth-first
/// order for the retained half), `merge` appends.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ArrayListTaskBag<T> {
    items: Vec<T>,
}

impl<T> ArrayListTaskBag<T> {
    pub fn new() -> Self {
        Self { items: Vec::new() }
    }

    pub fn from_vec(items: Vec<T>) -> Self {
        Self { items }
    }

    /// Push a task (LIFO end).
    #[inline]
    pub fn push(&mut self, t: T) {
        self.items.push(t);
    }

    /// Pop the most recently pushed task (depth-first order).
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        self.items.pop()
    }

    pub fn items(&self) -> &[T] {
        &self.items
    }

    pub fn into_vec(self) -> Vec<T> {
        self.items
    }
}

impl<T: Send + 'static> TaskBag for ArrayListTaskBag<T> {
    fn size(&self) -> usize {
        self.items.len()
    }

    fn split(&mut self) -> Option<Self> {
        if self.items.len() < 2 {
            return None;
        }
        // Give away the *older* half (front of the Vec): those are the
        // shallower, typically larger tasks in depth-first expansions —
        // the classic steal-from-the-top policy. `split_off` keeps the
        // tail for the loot-free path cheap.
        let keep_from = self.items.len() / 2;
        let tail = self.items.split_off(keep_from);
        let head = std::mem::replace(&mut self.items, tail);
        Some(Self { items: head })
    }

    fn merge(&mut self, other: Self) {
        // Merge under the live tasks so the local LIFO tail keeps priority.
        let mut incoming = other.items;
        std::mem::swap(&mut self.items, &mut incoming);
        self.items.extend(incoming);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_halves_and_preserves_items() {
        let mut bag = ArrayListTaskBag::from_vec((0..10).collect::<Vec<i32>>());
        let loot = bag.split().expect("bag of 10 splits");
        assert_eq!(bag.size() + loot.size(), 10);
        assert_eq!(loot.size(), 5);
        let mut all: Vec<i32> =
            bag.items().iter().chain(loot.items().iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn split_gives_older_half() {
        let mut bag = ArrayListTaskBag::from_vec(vec![0, 1, 2, 3]);
        let loot = bag.split().unwrap();
        assert_eq!(loot.items(), &[0, 1]); // shallow/old tasks travel
        assert_eq!(bag.items(), &[2, 3]);
    }

    #[test]
    fn too_small_to_split() {
        let mut empty: ArrayListTaskBag<u8> = ArrayListTaskBag::new();
        assert!(empty.split().is_none());
        let mut one = ArrayListTaskBag::from_vec(vec![1u8]);
        assert!(one.split().is_none());
        let mut two = ArrayListTaskBag::from_vec(vec![1u8, 2]);
        assert!(two.split().is_some());
    }

    #[test]
    fn odd_split_sizes() {
        let mut bag = ArrayListTaskBag::from_vec((0..7).collect::<Vec<i32>>());
        let loot = bag.split().unwrap();
        assert_eq!(loot.size(), 3);
        assert_eq!(bag.size(), 4);
    }

    #[test]
    fn merge_appends_and_keeps_local_tail() {
        let mut bag = ArrayListTaskBag::from_vec(vec![10, 11]);
        bag.merge(ArrayListTaskBag::from_vec(vec![1, 2, 3]));
        assert_eq!(bag.size(), 5);
        // Local tasks (10, 11) must still be on top of the LIFO order.
        assert_eq!(bag.pop(), Some(11));
        assert_eq!(bag.pop(), Some(10));
        assert_eq!(bag.pop(), Some(3));
    }

    #[test]
    fn merge_into_empty() {
        let mut bag: ArrayListTaskBag<i32> = ArrayListTaskBag::new();
        bag.merge(ArrayListTaskBag::from_vec(vec![5, 6]));
        assert_eq!(bag.size(), 2);
    }

    #[test]
    fn push_pop_lifo() {
        let mut bag = ArrayListTaskBag::new();
        bag.push(1);
        bag.push(2);
        assert_eq!(bag.pop(), Some(2));
        assert_eq!(bag.pop(), Some(1));
        assert_eq!(bag.pop(), None);
        assert!(bag.is_empty());
    }

    #[test]
    fn repeated_splits_drain_to_singletons() {
        let mut bag = ArrayListTaskBag::from_vec((0..64).collect::<Vec<i32>>());
        let mut loots = Vec::new();
        while let Some(l) = bag.split() {
            loots.push(l);
        }
        assert_eq!(bag.size(), 1, "splitting stops at a singleton");
        let sum: usize = bag.size() + loots.iter().map(|l| l.size()).sum::<usize>();
        assert_eq!(sum, 64, "items conserved across all splits");
    }
}
