//! # GLB — lifeline-based global load balancing
//!
//! The library core: everything the paper's §2 describes, independent of
//! how places are realized. Users implement [`TaskBag`] + [`TaskQueue`]
//! (or reuse [`ArrayListTaskBag`]), pick [`GlbParams`], and run under
//! either execution substrate:
//!
//! * [`crate::place::run_threads`] — one OS thread per place (real
//!   concurrency, wall-clock timing);
//! * [`crate::sim::run_sim`] — deterministic discrete-event simulation of
//!   up to tens of thousands of places with per-architecture latency
//!   models (virtual-clock timing; reproduces the paper's scaling
//!   figures).
//!
//! ```no_run
//! use glb::glb::{GlbConfig, GlbParams, SumReducer};
//! use glb::apps::fib::FibQueue;
//!
//! let cfg = GlbConfig::new(4, GlbParams::default().with_n(64));
//! let out = glb::place::run_threads(
//!     &cfg,
//!     |_, _| FibQueue::new(),            // per-place queue factory
//!     |q: &mut FibQueue| q.init(20),     // root initialization (place 0)
//!     &SumReducer,
//! );
//! assert_eq!(out.result, 6765);
//! ```
//!
//! ## Hierarchical topology
//!
//! By default every worker is a flat place running the full lifeline
//! protocol, exactly as in the paper. Setting
//! [`GlbParams::with_workers_per_node`] (CLI: `--workers-per-node`)
//! groups workers into nodes ([`topology`]): within a node work moves
//! through a shared-memory [`NodeBag`] (message-free donate/take plus
//! direct wake-up pushes), and only each node's representative runs the
//! lifeline protocol, with the hypercube built over node ids — so
//! cross-node traffic scales with the node count, not the worker count.
//! The reduced result is identical either way; only *who moves work*
//! changes:
//!
//! ```no_run
//! use glb::glb::{GlbConfig, GlbParams, SumReducer};
//! use glb::apps::fib::FibQueue;
//!
//! // 8 workers on 2 nodes: reps 0 and 4 steal across nodes, everyone
//! // shares locally through the node bag.
//! let params = GlbParams::default().with_n(64).with_workers_per_node(4);
//! let cfg = GlbConfig::new(8, params);
//! let out = glb::place::run_threads(
//!     &cfg,
//!     |_, _| FibQueue::new(),
//!     |q: &mut FibQueue| q.init(20),
//!     &SumReducer,
//! );
//! assert_eq!(out.result, 6765); // same reduction as the flat run
//! println!("{}", out.log.render()); // includes the per-node rollup
//! ```

pub mod autotune;
pub mod lifeline;
pub mod logger;
pub mod message;
pub mod metrics;
pub mod params;
pub mod task_bag;
pub mod task_queue;
pub mod termination;
pub mod topology;
pub mod wire;
pub mod worker;

pub use autotune::{
    autotune, AdaptiveConfig, AdaptiveController, ControllerSample, Retune, WorkloadProfile,
};
pub use lifeline::{LifelineGraph, VictimSelector};
pub use logger::{RunLog, WorkerStats};
pub use message::{Effect, Msg, PlaceId};
pub use metrics::{MetricsHub, StatsBank, StatsSnapshot};
pub use params::GlbParams;
pub use task_bag::{ArrayListTaskBag, TaskBag};
pub use task_queue::{FnReducer, ProcessOutcome, Reducer, SumReducer, TaskQueue, VecSumReducer};
pub use termination::{AtomicLedger, CreditHome, CreditLedger, CreditRoot, Ledger, SimLedger};
pub use topology::{NodeBag, Topology};
pub use wire::{WireCodec, WireError};
pub use worker::{Phase, StepOutcome, Worker};

/// A GLB run configuration: place count + tuning parameters.
#[derive(Debug, Clone, Copy)]
pub struct GlbConfig {
    /// Number of places.
    pub p: usize,
    /// Tuning parameters (paper §2.4).
    pub params: GlbParams,
}

impl GlbConfig {
    pub fn new(p: usize, params: GlbParams) -> Self {
        assert!(p >= 1, "need at least one place");
        params.validate().expect("invalid GLB parameters");
        Self { p, params }
    }

    /// The hierarchical topology of this run (flat when
    /// `params.workers_per_node == 1`).
    pub fn topology(&self) -> Topology {
        Topology::new(self.p, self.params.workers_per_node)
    }
}

/// The outcome of a GLB run under either substrate.
#[derive(Debug, Clone)]
pub struct RunOutput<R> {
    /// The reduced result (paper: the single value of type `Z`).
    pub result: R,
    /// Per-place accounting (paper §2.4 logging).
    pub log: RunLog,
    /// End-to-end run time in ns — wall clock under threads, virtual time
    /// under the simulator.
    pub elapsed_ns: u64,
}

impl<R> RunOutput<R> {
    /// Throughput in `units`/s (UTS: nodes/s; BC: edges/s) — the paper's
    /// primary y-axis.
    pub fn units_per_sec(&self) -> f64 {
        let total: u64 = self.log.per_place.iter().map(|s| s.units).sum();
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        total as f64 * 1e9 / self.elapsed_ns as f64
    }

    /// Efficiency = units/s/place normalized by a single-place baseline
    /// rate — the paper's secondary y-axis.
    pub fn efficiency_vs(&self, single_place_units_per_sec: f64) -> f64 {
        if single_place_units_per_sec <= 0.0 {
            return 0.0;
        }
        (self.units_per_sec() / self.log.per_place.len() as f64) / single_place_units_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validates() {
        let c = GlbConfig::new(4, GlbParams::default());
        assert_eq!(c.p, 4);
    }

    #[test]
    #[should_panic(expected = "at least one place")]
    fn zero_places_rejected() {
        GlbConfig::new(0, GlbParams::default());
    }

    #[test]
    fn run_output_rates() {
        let mut log = RunLog::default();
        log.per_place = vec![
            WorkerStats { units: 500, ..Default::default() },
            WorkerStats { units: 500, ..Default::default() },
        ];
        let out = RunOutput { result: 0u64, log, elapsed_ns: 1_000_000_000 };
        assert!((out.units_per_sec() - 1000.0).abs() < 1e-9);
        assert!((out.efficiency_vs(500.0) - 1.0).abs() < 1e-9);
        assert_eq!(out.efficiency_vs(0.0), 0.0);
    }
}
