//! The lifeline graph (paper §2.4, item 2; Saraswat et al. PPoPP'11 §4).
//!
//! Places are laid out as an `l`-ary `z`-dimensional cube: place `p` is the
//! base-`l` numeral `(d_{z-1} … d_1 d_0)`. Place `p`'s *outgoing* lifelines
//! (the buddies it steals from when random stealing fails) are the `z`
//! places obtained by decrementing one digit modulo `l`; its *incoming*
//! lifelines (the thieves it must remember and later feed) are the
//! increments. When `l^z > P` some numerals do not exist; following the
//! X10 GLB library we keep decrementing that digit until the numeral is a
//! real place, which preserves the cycle structure per dimension.
//!
//! The paper's required properties hold by construction and are checked by
//! the tests (plus the property suite in `rust/tests/properties.rs`):
//!
//! * **connected** — work can flow from any place to any other (each
//!   dimension's digit positions form a cycle, and cycles compose);
//! * **low diameter** — `O(z · l)` hops;
//! * **low out-degree** — at most `z` lifelines per place.

use crate::util::SplitMix64;

/// The lifeline topology for one place.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LifelineGraph {
    /// This place.
    pub place: usize,
    /// Total number of places.
    pub p: usize,
    /// Outgoing lifelines: places this place steals from on starvation.
    pub outgoing: Vec<usize>,
}

impl LifelineGraph {
    /// Build the lifeline set for `place` in an `l`-ary `z`-cube over `p`
    /// places.
    ///
    /// If `l^z < p` the numeral space cannot address every place and the
    /// digit-decrement graph degenerates into disjoint cycles (found by
    /// the `lifeline-topology` property test), so `z` is raised to the
    /// smallest dimension that covers `p` — connectivity is a *library
    /// guarantee* (paper §2.4: "it is a fully connected directed graph"),
    /// not a user obligation.
    pub fn new(place: usize, p: usize, l: usize, z: usize) -> Self {
        assert!(place < p, "place {place} out of range (P={p})");
        assert!(l >= 2 && z >= 1);
        let z = z.max(super::params::derive_z(p, l));
        let mut outgoing = Vec::with_capacity(z);
        let mut stride = 1usize; // l^k for dimension k
        for _dim in 0..z {
            let digit = (place / stride) % l;
            // Decrement this digit (cyclically), skipping numerals >= p by
            // continuing to decrement — this keeps each dimension a single
            // cycle over the places that exist in that slice.
            let mut steps = 1usize;
            let buddy = loop {
                if steps > l {
                    break None; // dimension degenerate (no other place)
                }
                let nd = (digit + l - steps % l) % l;
                let cand = place - digit * stride + nd * stride;
                if cand < p && cand != place {
                    break Some(cand);
                }
                if cand == place {
                    break None;
                }
                steps += 1;
            };
            if let Some(b) = buddy {
                if !outgoing.contains(&b) {
                    outgoing.push(b);
                }
            }
            stride = stride.saturating_mul(l);
            if stride >= p && _dim + 1 < z {
                // Higher dimensions have digit 0 for every existing place
                // only when p <= stride; decrementing digit 0 in a dead
                // dimension wraps to a numeral >= p which then walks down —
                // still fine, loop above handles it, but we can stop early
                // when no higher digit can differ.
                if p <= stride {
                    break;
                }
            }
        }
        Self { place, p, outgoing }
    }

    /// Incoming lifelines: the set of places that list `self.place` in
    /// their outgoing lifelines. O(P·z) — used by tests/diagnostics only;
    /// the protocol discovers incoming thieves dynamically.
    pub fn incoming(p: usize, l: usize, z: usize, place: usize) -> Vec<usize> {
        (0..p)
            .filter(|&q| q != place)
            .filter(|&q| LifelineGraph::new(q, p, l, z).outgoing.contains(&place))
            .collect()
    }

    /// Re-knit the cube over a *sparse* member set (crash recovery:
    /// `members` are the sorted surviving place ids, `place` included).
    /// Members are densely renumbered, the cube is built over the dense
    /// space, and the edges mapped back — so the survivors again form a
    /// connected low-diameter lifeline graph with no edge at a dead
    /// place, the same guarantee [`LifelineGraph::new`] gives a
    /// freshly-bootstrapped fleet of `members.len()` places.
    pub fn over_members(place: usize, members: &[usize], l: usize, z: usize) -> Self {
        let dense = members
            .iter()
            .position(|&m| m == place)
            .expect("re-knitting place must be a surviving member");
        let g = LifelineGraph::new(dense, members.len(), l, z);
        Self { place, p: members.len(), outgoing: g.outgoing.iter().map(|&b| members[b]).collect() }
    }
}

/// Uniform random victim selection excluding self (paper §2.4 item 2,
/// first round: "chooses at most w random victims").
#[derive(Debug, Clone)]
pub struct VictimSelector {
    /// This place's index in the victim domain (identity for the dense
    /// bootstrap domain; its position in `members` for a sparse one).
    place: usize,
    p: usize,
    rng: SplitMix64,
    /// Sparse victim domain (crash recovery); `None` = dense `0..p`.
    members: Option<Vec<usize>>,
}

impl VictimSelector {
    pub fn new(place: usize, p: usize, seed: u64) -> Self {
        // Per-place independent stream.
        let rng = SplitMix64::new(crate::util::rng::mix64(seed ^ (place as u64).wrapping_mul(0x9E37_79B9)));
        Self { place, p, rng, members: None }
    }

    /// A selector over a *sparse* member set (crash recovery: `members`
    /// are the surviving place ids, `place` included). Picks stay uniform
    /// over the other survivors; the stream is seeded per real place id,
    /// so survivors keep independent streams across re-knits.
    pub fn over_members(place: usize, members: &[usize], seed: u64) -> Self {
        let dense = members
            .iter()
            .position(|&m| m == place)
            .expect("victim-selecting place must be a surviving member");
        let rng = SplitMix64::new(crate::util::rng::mix64(seed ^ (place as u64).wrapping_mul(0x9E37_79B9)));
        Self { place: dense, p: members.len(), rng, members: Some(members.to_vec()) }
    }

    /// Pick a victim uniformly among the other `p - 1` places; `None` when
    /// running single-place.
    #[inline]
    pub fn pick(&mut self) -> Option<usize> {
        if self.p < 2 {
            return None;
        }
        let v = self.rng.next_below(self.p as u64 - 1) as usize;
        let idx = if v >= self.place { v + 1 } else { v };
        Some(match &self.members {
            Some(m) => m[idx],
            None => idx,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::{HashSet, VecDeque};

    fn reaches_all(p: usize, l: usize, z: usize) -> bool {
        // BFS over the directed lifeline graph from every vertex would be
        // O(P^2); strong connectivity of a composition of cycles follows
        // from reachability from vertex 0 plus reachability *to* vertex 0,
        // but for the small test sizes we just BFS from each vertex.
        for start in 0..p {
            let mut seen = HashSet::from([start]);
            let mut q = VecDeque::from([start]);
            while let Some(v) = q.pop_front() {
                for &n in &LifelineGraph::new(v, p, l, z).outgoing {
                    if seen.insert(n) {
                        q.push_back(n);
                    }
                }
            }
            if seen.len() != p {
                return false;
            }
        }
        true
    }

    #[test]
    fn outdegree_at_most_z() {
        for &(p, l, z) in &[(16usize, 2usize, 4usize), (10, 3, 3), (32, 32, 1), (100, 4, 4)] {
            for place in 0..p {
                let g = LifelineGraph::new(place, p, l, z);
                assert!(g.outgoing.len() <= z, "P={p} l={l} z={z} place={place}: {:?}", g.outgoing);
                assert!(!g.outgoing.contains(&place), "no self-lifelines");
                assert!(g.outgoing.iter().all(|&b| b < p), "buddies must exist");
            }
        }
    }

    #[test]
    fn binary_hypercube_neighbours() {
        // P=8, l=2, z=3: decrementing a digit mod 2 flips a bit — the
        // classic binary hypercube.
        for place in 0..8usize {
            let g = LifelineGraph::new(place, 8, 2, 3);
            let expect: HashSet<usize> = (0..3).map(|k| place ^ (1 << k)).collect();
            assert_eq!(g.outgoing.iter().copied().collect::<HashSet<_>>(), expect);
        }
    }

    #[test]
    fn connected_for_various_sizes() {
        assert!(reaches_all(2, 2, 1));
        assert!(reaches_all(8, 2, 3));
        assert!(reaches_all(9, 3, 2));
        assert!(reaches_all(13, 2, 4)); // non-power-of-two place count
        assert!(reaches_all(37, 4, 3));
        assert!(reaches_all(60, 32, 2));
    }

    #[test]
    fn single_place_has_no_lifelines() {
        let g = LifelineGraph::new(0, 1, 2, 1);
        assert!(g.outgoing.is_empty());
    }

    #[test]
    fn two_places_point_at_each_other() {
        let a = LifelineGraph::new(0, 2, 2, 1);
        let b = LifelineGraph::new(1, 2, 2, 1);
        assert_eq!(a.outgoing, vec![1]);
        assert_eq!(b.outgoing, vec![0]);
    }

    #[test]
    fn incoming_is_inverse_of_outgoing() {
        let (p, l, z) = (12usize, 3usize, 3usize);
        for place in 0..p {
            for &b in &LifelineGraph::new(place, p, l, z).outgoing {
                let inc = LifelineGraph::incoming(p, l, z, b);
                assert!(inc.contains(&place), "{place} -> {b} must be in incoming({b})");
            }
        }
    }

    #[test]
    fn over_members_reknits_a_connected_graph_avoiding_the_dead() {
        // 4-place fleet loses place 2: the survivors' re-knit graph must
        // be connected, self-free, and never point at the dead place.
        let members = [0usize, 1, 3];
        let graphs: Vec<_> =
            members.iter().map(|&m| LifelineGraph::over_members(m, &members, 2, 2)).collect();
        for (g, &m) in graphs.iter().zip(&members) {
            assert!(!g.outgoing.is_empty(), "survivor {m} must keep a lifeline");
            assert!(!g.outgoing.contains(&m), "no self-lifelines");
            assert!(!g.outgoing.contains(&2), "no lifeline at the dead place");
            assert!(g.outgoing.iter().all(|b| members.contains(b)));
        }
        // Reachability over the mapped-back edges.
        let mut seen = HashSet::from([0usize]);
        let mut q = VecDeque::from([0usize]);
        while let Some(v) = q.pop_front() {
            let g = graphs[members.iter().position(|&m| m == v).unwrap()].clone();
            for n in g.outgoing {
                if seen.insert(n) {
                    q.push_back(n);
                }
            }
        }
        assert_eq!(seen.len(), members.len(), "survivors stay connected");
    }

    #[test]
    fn over_members_full_set_matches_dense_graph() {
        let members: Vec<usize> = (0..8).collect();
        for place in 0..8 {
            let dense = LifelineGraph::new(place, 8, 2, 3);
            let sparse = LifelineGraph::over_members(place, &members, 2, 3);
            assert_eq!(dense.outgoing, sparse.outgoing, "place {place}");
        }
    }

    #[test]
    fn sparse_victim_selector_covers_survivors_only() {
        let members = [0usize, 1, 3, 4, 7];
        let mut sel = VictimSelector::over_members(3, &members, 99);
        let mut seen = HashSet::new();
        for _ in 0..2000 {
            let v = sel.pick().unwrap();
            assert_ne!(v, 3, "never self");
            assert!(members.contains(&v), "victim {v} must be a survivor");
            seen.insert(v);
        }
        assert_eq!(seen.len(), members.len() - 1, "all other survivors picked");
    }

    #[test]
    fn sparse_victim_selector_lone_survivor_picks_none() {
        assert!(VictimSelector::over_members(5, &[5], 1).pick().is_none());
    }

    #[test]
    fn victim_selector_never_self_and_covers() {
        let p = 9;
        let mut sel = VictimSelector::new(4, p, 123);
        let mut seen = HashSet::new();
        for _ in 0..2000 {
            let v = sel.pick().unwrap();
            assert_ne!(v, 4);
            assert!(v < p);
            seen.insert(v);
        }
        assert_eq!(seen.len(), p - 1, "all other places should be picked eventually");
    }

    #[test]
    fn victim_selector_single_place() {
        assert!(VictimSelector::new(0, 1, 1).pick().is_none());
    }

    #[test]
    fn victim_streams_differ_across_places() {
        let mut a = VictimSelector::new(0, 64, 7);
        let mut b = VictimSelector::new(1, 64, 7);
        let same = (0..64).filter(|_| a.pick() == b.pick()).count();
        assert!(same < 16, "streams should be (mostly) independent: {same}");
    }
}
