//! Live per-rank telemetry: lock-free gauge publication from the worker
//! hot path, compact [`StatsSnapshot`]s for the control plane, and the
//! rank-0 bank that folds them into a fleet-wide view.
//!
//! RunLog is post-mortem only — serialized once, after the last worker
//! joins. This module is the live plane the ROADMAP asks for: each
//! worker *publishes* its counters into a [`MetricsHub`] slot (plain
//! relaxed atomic stores — no locks, no allocation, nothing shared
//! between workers), the rank's reactor *samples* the hub on a periodic
//! timer, wraps the totals in a [`StatsSnapshot`], and ships it to
//! rank 0 as a `Ctrl::Stats` frame riding the existing batched control
//! link. Rank 0 banks the latest snapshot per rank ([`StatsBank`]) and
//! prints one aggregated fleet line per interval.
//!
//! Everything on the wire is a cumulative integer counter; rates
//! (tasks/s, bytes/s, frames/s) are derived downstream from consecutive
//! samples, so a lost or reordered snapshot skews nothing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::logger::WorkerStats;

/// One rank's gauges at one instant. All counters are cumulative since
/// the run started (`bag_depth`, `credit_pool` and `out_queue` are
/// levels, not counters). `last` marks the teardown snapshot taken after
/// every worker joined — its worker-sourced fields equal the rank's
/// final `RunLog` totals exactly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    /// The reporting rank.
    pub rank: u64,
    /// Sample sequence number within this rank (monotonic).
    pub seq: u64,
    /// Milliseconds since this rank armed its stats plane.
    pub elapsed_ms: u64,
    /// Total tasks currently queued across this rank's bags.
    pub bag_depth: u64,
    /// Work items processed (drives the tasks/s expansion rate).
    pub items: u64,
    /// Steal requests this rank has sent (random + lifeline).
    pub steals_out: u64,
    /// Steal requests this rank has answered with loot.
    pub steals_in: u64,
    /// Loot bags shipped to thieves.
    pub loot_sent: u64,
    /// Loot bags merged from victims.
    pub loot_recv: u64,
    /// Chunks that came up empty (the starvation signal the adaptive
    /// controller watches).
    pub starvations: u64,
    /// Credit atoms currently pooled in this rank's ledger.
    pub credit_pool: u64,
    /// Post-bootstrap wire bytes sent / received by this process.
    pub wire_tx: u64,
    pub wire_rx: u64,
    /// Frames flushed to / decoded off this process's sockets.
    pub frames_tx: u64,
    pub frames_rx: u64,
    /// Frames currently parked in this rank's write queues.
    pub out_queue: u64,
    /// Teardown snapshot: the rank's workers have all finished.
    pub last: bool,
}

impl StatsSnapshot {
    /// Fold another rank's snapshot into a fleet-wide sum: counters and
    /// levels add, `elapsed_ms`/`seq` take the max, `last` only holds if
    /// every folded snapshot was final.
    pub fn absorb(&mut self, o: &StatsSnapshot) {
        self.seq = self.seq.max(o.seq);
        self.elapsed_ms = self.elapsed_ms.max(o.elapsed_ms);
        self.bag_depth += o.bag_depth;
        self.items += o.items;
        self.steals_out += o.steals_out;
        self.steals_in += o.steals_in;
        self.loot_sent += o.loot_sent;
        self.loot_recv += o.loot_recv;
        self.starvations += o.starvations;
        self.credit_pool += o.credit_pool;
        self.wire_tx += o.wire_tx;
        self.wire_rx += o.wire_rx;
        self.frames_tx += o.frames_tx;
        self.frames_rx += o.frames_rx;
        self.out_queue += o.out_queue;
        self.last &= o.last;
    }
}

/// One worker's published gauge slot. Plain relaxed atomics: each field
/// is independently meaningful (cumulative counter or level), so no
/// cross-field consistency is needed and the publish path costs a
/// handful of uncontended stores.
#[derive(Default)]
struct WorkerGauges {
    bag_depth: AtomicU64,
    items: AtomicU64,
    steals_out: AtomicU64,
    steals_in: AtomicU64,
    loot_sent: AtomicU64,
    loot_recv: AtomicU64,
    starvations: AtomicU64,
}

/// The rank-local gauge board: one slot per hosted worker, published by
/// the worker threads and sampled by the reactor's stats timer (and by
/// the teardown path for the exact final snapshot).
#[derive(Default)]
pub struct MetricsHub {
    slots: Vec<WorkerGauges>,
}

impl MetricsHub {
    pub fn new(workers: usize) -> Self {
        Self { slots: (0..workers).map(|_| WorkerGauges::default()).collect() }
    }

    /// Publish one worker's current counters (hot path: relaxed stores).
    pub fn publish(&self, slot: usize, bag_depth: usize, stats: &WorkerStats) {
        let g = &self.slots[slot];
        g.bag_depth.store(bag_depth as u64, Ordering::Relaxed);
        g.items.store(stats.items_processed, Ordering::Relaxed);
        g.steals_out
            .store(stats.random_steals_sent + stats.lifeline_steals_sent, Ordering::Relaxed);
        g.steals_in.store(
            stats.random_steals_perpetrated + stats.lifeline_steals_perpetrated,
            Ordering::Relaxed,
        );
        g.loot_sent.store(stats.loot_bags_sent, Ordering::Relaxed);
        g.loot_recv.store(stats.loot_bags_received, Ordering::Relaxed);
        g.starvations.store(stats.starvations, Ordering::Relaxed);
    }

    /// Sum every worker slot into a partially filled snapshot (the
    /// caller adds the rank-level fields: credit pool, wire counters,
    /// queue depths).
    pub fn fold(&self) -> StatsSnapshot {
        let mut s = StatsSnapshot::default();
        for g in &self.slots {
            s.bag_depth += g.bag_depth.load(Ordering::Relaxed);
            s.items += g.items.load(Ordering::Relaxed);
            s.steals_out += g.steals_out.load(Ordering::Relaxed);
            s.steals_in += g.steals_in.load(Ordering::Relaxed);
            s.loot_sent += g.loot_sent.load(Ordering::Relaxed);
            s.loot_recv += g.loot_recv.load(Ordering::Relaxed);
            s.starvations += g.starvations.load(Ordering::Relaxed);
        }
        s
    }
}

/// Rank 0's board of the latest snapshot per rank. The reactor banks
/// inbound `Ctrl::Stats` frames here; the periodic printer and the
/// teardown path read it to build the fleet-wide aggregate.
pub struct StatsBank {
    slots: Mutex<Vec<Option<StatsSnapshot>>>,
}

impl StatsBank {
    pub fn new(ranks: usize) -> Self {
        Self { slots: Mutex::new((0..ranks).map(|_| None).collect()) }
    }

    /// Bank `snap` as its rank's latest sample (stale out-of-order
    /// samples are dropped by sequence number; a `last` snapshot always
    /// wins).
    pub fn bank(&self, snap: StatsSnapshot) {
        let mut slots = self.slots.lock().unwrap();
        let Some(slot) = slots.get_mut(snap.rank as usize) else { return };
        match slot {
            Some(prev) if !snap.last && (prev.last || prev.seq >= snap.seq) => {}
            _ => *slot = Some(snap),
        }
    }

    /// The latest banked snapshot per rank (`None` = nothing heard yet).
    pub fn latest(&self) -> Vec<Option<StatsSnapshot>> {
        self.slots.lock().unwrap().clone()
    }

    /// Fold every banked snapshot into one fleet-wide sum, with the
    /// count of ranks heard from.
    pub fn fleet(&self) -> (StatsSnapshot, usize) {
        let slots = self.slots.lock().unwrap();
        let mut sum = StatsSnapshot { last: true, ..StatsSnapshot::default() };
        let mut heard = 0;
        for snap in slots.iter().flatten() {
            sum.absorb(snap);
            heard += 1;
        }
        (sum, heard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(items: u64, loot_sent: u64) -> WorkerStats {
        WorkerStats {
            items_processed: items,
            loot_bags_sent: loot_sent,
            random_steals_sent: 2,
            lifeline_steals_sent: 3,
            ..WorkerStats::default()
        }
    }

    #[test]
    fn hub_folds_worker_slots() {
        let hub = MetricsHub::new(2);
        hub.publish(0, 4, &stats(10, 1));
        hub.publish(1, 6, &stats(20, 2));
        let s = hub.fold();
        assert_eq!(s.bag_depth, 10);
        assert_eq!(s.items, 30);
        assert_eq!(s.loot_sent, 3);
        assert_eq!(s.steals_out, 10);
        // Re-publishing a slot overwrites (cumulative counters, not adds).
        hub.publish(1, 0, &stats(25, 2));
        assert_eq!(hub.fold().items, 35);
    }

    #[test]
    fn bank_keeps_latest_by_seq_and_final_wins() {
        let bank = StatsBank::new(2);
        bank.bank(StatsSnapshot { rank: 1, seq: 3, items: 30, ..Default::default() });
        bank.bank(StatsSnapshot { rank: 1, seq: 2, items: 20, ..Default::default() });
        assert_eq!(bank.latest()[1].unwrap().items, 30, "stale sample dropped");
        bank.bank(StatsSnapshot { rank: 1, seq: 1, items: 99, last: true, ..Default::default() });
        assert_eq!(bank.latest()[1].unwrap().items, 99, "final snapshot always wins");
        bank.bank(StatsSnapshot { rank: 1, seq: 9, items: 1, ..Default::default() });
        assert_eq!(bank.latest()[1].unwrap().items, 99, "nothing after final");
        // Out-of-range ranks are ignored, not a panic.
        bank.bank(StatsSnapshot { rank: 7, ..Default::default() });
        let (fleet, heard) = bank.fleet();
        assert_eq!((fleet.items, heard), (99, 1));
    }

    #[test]
    fn fleet_fold_sums_and_tracks_finality() {
        let bank = StatsBank::new(3);
        bank.bank(StatsSnapshot { rank: 0, seq: 1, items: 5, last: true, ..Default::default() });
        bank.bank(StatsSnapshot { rank: 2, seq: 4, items: 7, bag_depth: 3, ..Default::default() });
        let (fleet, heard) = bank.fleet();
        assert_eq!(heard, 2);
        assert_eq!(fleet.items, 12);
        assert_eq!(fleet.bag_depth, 3);
        assert_eq!(fleet.seq, 4);
        assert!(!fleet.last, "one rank still live");
        bank.bank(StatsSnapshot { rank: 2, seq: 5, items: 9, last: true, ..Default::default() });
        let (fleet, _) = bank.fleet();
        assert!(fleet.last, "every banked snapshot final");
        assert_eq!(fleet.items, 14);
    }
}
