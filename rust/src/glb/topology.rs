//! The hierarchical topology layer: multi-worker nodes with two-level
//! load balancing.
//!
//! The paper treats every core as a flat place, but its own §2.6/Fig 4
//! discussion (and the simulator's NIC-occupancy model in
//! [`crate::sim::arch`]) shows that inter-node messaging is the
//! bottleneck once many places share a node. This module introduces an
//! explicit node layer:
//!
//! * [`Topology`] maps the `P` workers onto `ceil(P / workers_per_node)`
//!   nodes (the last node may be ragged). Worker `node * W` is the node's
//!   **representative**.
//! * Within a node, workers share work through a [`NodeBag`] — a
//!   lock-light shared-memory exchange with local *donate*/*take* (no
//!   messages, no per-item ledger traffic: a parked shard carries one
//!   work token exactly like a loot message in flight).
//! * Across nodes, only each node's representative runs the lifeline
//!   protocol, and the lifeline hypercube is built over **node ids**, so
//!   cross-node traffic scales with the node count instead of the worker
//!   count.
//!
//! `workers_per_node = 1` (the default) is the paper's flat layout: every
//! worker is its own node's representative, the [`NodeBag`] is never
//! touched, and the protocol is bit-for-bit the original one.
//!
//! Starvation under `workers_per_node > 1` resolves in this order:
//!
//! 1. take a parked shard from the node bag (shared memory, message-free);
//! 2. representatives only: `w` random steals against other nodes'
//!    representatives, then the node-level lifelines;
//! 3. register as *hungry* in the node bag and go idle — the next local
//!    worker with surplus wakes the sleeper with a direct intra-node loot
//!    push (cheap: same-node messages skip the simulated NIC entirely).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::message::PlaceId;

/// Mapping of workers (places) onto nodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    p: usize,
    wpn: usize,
}

impl Topology {
    /// `p` workers grouped `workers_per_node` per node (clamped to ≥ 1).
    pub fn new(p: usize, workers_per_node: usize) -> Self {
        assert!(p >= 1, "need at least one worker");
        Self { p, wpn: workers_per_node.max(1) }
    }

    /// Total workers (places).
    pub fn places(&self) -> usize {
        self.p
    }

    /// Workers per node (the last node may hold fewer).
    pub fn workers_per_node(&self) -> usize {
        self.wpn
    }

    /// Flat layout? (every worker is its own node)
    pub fn is_flat(&self) -> bool {
        self.wpn == 1
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.p.div_ceil(self.wpn)
    }

    /// Node id of a worker.
    #[inline]
    pub fn node_of(&self, worker: PlaceId) -> usize {
        worker / self.wpn
    }

    /// The representative worker of a node (its first worker): the one
    /// worker on the node that runs the inter-node lifeline protocol.
    #[inline]
    pub fn representative(&self, node: usize) -> PlaceId {
        node * self.wpn
    }

    /// Whether `worker` is its node's representative.
    #[inline]
    pub fn is_representative(&self, worker: PlaceId) -> bool {
        worker % self.wpn == 0
    }

    /// Number of workers on `node` (ragged last node aware).
    pub fn node_size(&self, node: usize) -> usize {
        let lo = node * self.wpn;
        debug_assert!(lo < self.p, "node {node} out of range");
        (self.p - lo).min(self.wpn)
    }

    /// The workers of `node`, as a place-id range.
    pub fn workers_of(&self, node: usize) -> std::ops::Range<PlaceId> {
        let lo = node * self.wpn;
        lo..(lo + self.wpn).min(self.p)
    }

    /// Do two workers share a node?
    #[inline]
    pub fn same_node(&self, a: PlaceId, b: PlaceId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// Allocate one shared [`NodeBag`] per node for the runtimes to hand
    /// to their workers ([`None`] under the flat layout, which never
    /// touches a bag). Index the result with [`Topology::node_of`].
    pub fn make_node_bags<B>(&self) -> Option<Vec<Arc<NodeBag<B>>>> {
        if self.is_flat() {
            None
        } else {
            Some((0..self.nodes()).map(|_| Arc::new(NodeBag::new())).collect())
        }
    }
}

struct NodeBagInner<B> {
    /// Parked work shards. Each shard holds one work token (the donor
    /// increments the ledger before parking; the taker balances it),
    /// exactly like a loot message in flight — which keeps the global
    /// termination invariant intact with zero extra coordination.
    shards: Vec<B>,
    /// Local workers that starved with nothing to take: the next donor
    /// wakes them with a direct intra-node loot push.
    hungry: VecDeque<PlaceId>,
}

/// The per-node shared-memory work exchange. One instance is shared (via
/// `Arc`) by all workers of a node; a single short-critical-section mutex
/// guards it — contention is bounded by the node size, never by the
/// global worker count, and no operation allocates while holding it.
pub struct NodeBag<B> {
    inner: Mutex<NodeBagInner<B>>,
}

impl<B> Default for NodeBag<B> {
    fn default() -> Self {
        Self::new()
    }
}

impl<B> NodeBag<B> {
    pub fn new() -> Self {
        Self { inner: Mutex::new(NodeBagInner { shards: Vec::new(), hungry: VecDeque::new() }) }
    }

    /// Park a work shard for local takers. The caller must have acquired
    /// the shard's work token (ledger increment) *before* donating.
    pub fn donate(&self, bag: B) {
        self.inner.lock().unwrap().shards.push(bag);
    }

    /// Take one parked shard, if any. The caller must settle the shard's
    /// work token (destroy it while holding its own, or adopt it).
    pub fn take(&self) -> Option<B> {
        self.inner.lock().unwrap().shards.pop()
    }

    /// Number of parked shards.
    pub fn shards(&self) -> usize {
        self.inner.lock().unwrap().shards.len()
    }

    /// Record a starved local worker awaiting a wake-up push (idempotent).
    pub fn register_hungry(&self, worker: PlaceId) {
        let mut g = self.inner.lock().unwrap();
        if !g.hungry.contains(&worker) {
            g.hungry.push_back(worker);
        }
    }

    /// Pop the longest-waiting hungry worker other than `not` (a donor
    /// cannot push to itself; a stale self-entry is simply discarded —
    /// the caller is demonstrably not hungry).
    pub fn pop_hungry(&self, not: PlaceId) -> Option<PlaceId> {
        let mut g = self.inner.lock().unwrap();
        while let Some(w) = g.hungry.pop_front() {
            if w != not {
                return Some(w);
            }
        }
        None
    }

    /// Put a popped-but-unfed worker back at the front of the queue.
    pub fn unpop_hungry(&self, worker: PlaceId) {
        let mut g = self.inner.lock().unwrap();
        if !g.hungry.contains(&worker) {
            g.hungry.push_front(worker);
        }
    }

    /// Number of registered hungry workers.
    pub fn hungry(&self) -> usize {
        self.inner.lock().unwrap().hungry.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_topology_is_identity() {
        let t = Topology::new(5, 1);
        assert!(t.is_flat());
        assert_eq!(t.nodes(), 5);
        for w in 0..5 {
            assert_eq!(t.node_of(w), w);
            assert_eq!(t.representative(w), w);
            assert!(t.is_representative(w));
            assert_eq!(t.node_size(w), 1);
        }
        assert!(!t.same_node(0, 4));
    }

    #[test]
    fn grouped_topology_maps_nodes() {
        let t = Topology::new(8, 4);
        assert_eq!(t.nodes(), 2);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert_eq!(t.representative(1), 4);
        assert!(t.is_representative(4));
        assert!(!t.is_representative(5));
        assert_eq!(t.workers_of(1), 4..8);
        assert!(t.same_node(5, 7));
        assert!(!t.same_node(3, 4));
    }

    #[test]
    fn ragged_last_node() {
        let t = Topology::new(10, 4);
        assert_eq!(t.nodes(), 3);
        assert_eq!(t.node_size(0), 4);
        assert_eq!(t.node_size(2), 2);
        assert_eq!(t.workers_of(2), 8..10);
        assert_eq!(t.node_of(9), 2);
    }

    #[test]
    fn oversized_wpn_collapses_to_one_node() {
        let t = Topology::new(3, 16);
        assert_eq!(t.nodes(), 1);
        assert_eq!(t.node_size(0), 3);
        assert!(t.same_node(0, 2));
    }

    #[test]
    fn node_bag_parks_and_takes_lifo() {
        let nb: NodeBag<Vec<u8>> = NodeBag::new();
        assert!(nb.take().is_none());
        nb.donate(vec![1]);
        nb.donate(vec![2]);
        assert_eq!(nb.shards(), 2);
        assert_eq!(nb.take(), Some(vec![2]));
        assert_eq!(nb.take(), Some(vec![1]));
        assert!(nb.take().is_none());
    }

    #[test]
    fn hungry_queue_dedups_and_skips_self() {
        let nb: NodeBag<Vec<u8>> = NodeBag::new();
        nb.register_hungry(3);
        nb.register_hungry(3);
        nb.register_hungry(1);
        assert_eq!(nb.hungry(), 2);
        // Worker 3's own stale entry is dropped when it donates.
        assert_eq!(nb.pop_hungry(3), Some(1));
        assert_eq!(nb.hungry(), 0);
        assert_eq!(nb.pop_hungry(0), None);
    }

    #[test]
    fn unpop_restores_front_position() {
        let nb: NodeBag<Vec<u8>> = NodeBag::new();
        nb.register_hungry(1);
        nb.register_hungry(2);
        let w = nb.pop_hungry(0).unwrap();
        assert_eq!(w, 1);
        nb.unpop_hungry(w);
        assert_eq!(nb.pop_hungry(0), Some(1), "unpopped worker keeps its place in line");
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let nb: Arc<NodeBag<Vec<u32>>> = Arc::new(NodeBag::new());
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let nb = nb.clone();
                std::thread::spawn(move || {
                    for k in 0..100u32 {
                        nb.donate(vec![i * 1000 + k]);
                        let _ = nb.take();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Every donate was matched by a take attempt; at most the races'
        // leftovers remain, and nothing was lost or duplicated.
        assert!(nb.shards() <= 400);
    }
}
