//! GLB parameter auto-tuning — the paper's future-work item (4):
//! "Provide a mechanism to auto-tune GLB parameters (e.g., task
//! granularity, size of random victims/lifeline buddies)."
//!
//! The tuner encodes the paper's own §2.4 guidance as a model:
//!
//! * **granularity `n`** — a worker should probe its mailbox every
//!   `target_probe_us` of compute (too-large `n` hurts steal response
//!   latency — the §2.6 BC lesson; too-small `n` wastes time probing),
//!   so `n ≈ target_probe_us / per_item_us`, clamped to a sane range;
//! * **random victims `w`** — more victims help only while the chance of
//!   finding a loaded victim is low; scale gently with `log2 P` (the
//!   paper found "only improved slightly" beyond small `w`);
//! * **lifeline arity `l`** — small arity (deep cube) gives more
//!   lifelines per place, which wins when starvation is frequent
//!   (irregular workloads); large arity (shallow cube) reduces buddy
//!   traffic for regular workloads. We pick `l = 2` below the crossover
//!   place count and `l = 32` (the X10 default) above, with `z`
//!   derived.
//! * **node grouping `workers_per_node`** — workers that share a machine
//!   should share a [`crate::glb::topology::NodeBag`] instead of
//!   message-stealing from each other, so the tuner reads the machine
//!   shape (`std::thread::available_parallelism`) and groups up to one
//!   core's worth of workers per node, preferring an even divisor of the
//!   place count so no node is ragged. Before this, `--autotune`
//!   silently produced flat topologies on many-core hosts.
//!
//! The model's choices are validated against brute-force sweeps in the
//! ablation bench — see EXPERIMENTS.md.

use super::params::GlbParams;

/// Tuning knobs for the closed-loop [`AdaptiveController`] (the mid-run
/// half of auto-tuning, driven by the live-telemetry gauges; `--adapt`).
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveConfig {
    /// Consecutive hungry observations (starvation counter rising) a
    /// worker must accumulate before the controller intervenes — the
    /// dwell filters one-off starvation episodes from persistent
    /// imbalance.
    pub dwell: u32,
    /// Lifeline arity adopted on intervention. Lowering the arity
    /// *deepens* the derived hypercube, giving every node more
    /// lifelines — the paper's deep-cube prescription for irregular
    /// workloads, applied only once the run proves irregular.
    pub l: usize,
    /// Granularity divisor applied on intervention (smaller chunks probe
    /// the mailbox more often, so steal requests stop languishing).
    pub n_shrink: usize,
    /// Floor for the shrunken granularity.
    pub n_floor: usize,
    /// Interventions allowed per worker (one decisive switch by
    /// default — repeated shrinking would grind granularity to dust).
    pub max_retunes: u32,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self { dwell: 3, l: 2, n_shrink: 4, n_floor: 16, max_retunes: 1 }
    }
}

/// One observation of a worker's live gauges, in whichever clock domain
/// the runtime has (wall time under sockets, ticks under the sim — the
/// controller only compares consecutive samples, never reads a clock).
#[derive(Debug, Clone, Copy, Default)]
pub struct ControllerSample {
    /// Cumulative task items processed.
    pub items: u64,
    /// Cumulative starvation episodes.
    pub starvations: u64,
    /// Current bag depth.
    pub bag_depth: u64,
}

/// A recommended mid-run parameter change, to be applied through
/// [`crate::glb::worker::Worker::try_retune`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Retune {
    pub l: usize,
    pub n: usize,
}

/// Per-worker closed-loop tuner (Boulmier et al.'s
/// imbalance-anticipation idea, reduced to the signal GLB actually
/// exposes): watch the starvation counter across consecutive telemetry
/// observations, and once a worker has starved in `dwell` consecutive
/// windows — persistent imbalance, not a blip — recommend the deep-cube
/// / fine-grain parameter point. The caller applies the recommendation
/// at the next protocol-safe moment and [`AdaptiveController::confirm`]s.
#[derive(Debug)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    prev: Option<ControllerSample>,
    hungry: u32,
    applied: u32,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        Self { cfg, prev: None, hungry: 0, applied: 0 }
    }

    /// Feed one observation; `current_n` is the worker's present
    /// granularity. Returns a recommendation once the hungry streak
    /// reaches the dwell (and keeps returning it until the caller
    /// manages to apply it — a worker mid-steal just retries later).
    pub fn observe(&mut self, sample: ControllerSample, current_n: usize) -> Option<Retune> {
        if self.applied >= self.cfg.max_retunes {
            return None;
        }
        if let Some(prev) = self.prev {
            if sample.starvations > prev.starvations {
                self.hungry += 1;
            } else {
                self.hungry = 0;
            }
        }
        self.prev = Some(sample);
        (self.hungry >= self.cfg.dwell).then(|| Retune {
            l: self.cfg.l,
            n: (current_n / self.cfg.n_shrink).max(self.cfg.n_floor).min(current_n),
        })
    }

    /// The caller applied the recommendation; stop recommending.
    pub fn confirm(&mut self) {
        self.applied += 1;
        self.hungry = 0;
    }

    /// Interventions applied so far.
    pub fn applied(&self) -> u32 {
        self.applied
    }
}

/// Workload description for tuning.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadProfile {
    /// Measured (or estimated) ns of compute per task item.
    pub ns_per_item: f64,
    /// How irregular the workload is: `0.0` = perfectly uniform
    /// (BC-like, statically balanceable), `1.0` = wildly irregular
    /// (UTS-like). Drives the responsiveness/throughput trade-off.
    pub irregularity: f64,
}

impl WorkloadProfile {
    pub fn new(ns_per_item: f64, irregularity: f64) -> Self {
        Self { ns_per_item: ns_per_item.max(0.1), irregularity: irregularity.clamp(0.0, 1.0) }
    }
}

/// Auto-tune GLB parameters for `p` places running `workload`.
pub fn autotune(p: usize, workload: WorkloadProfile) -> GlbParams {
    // Probe interval target: irregular workloads need fast response
    // (steal requests must not languish behind a long chunk); uniform
    // workloads amortize. Interpolate 50µs (irregular) .. 400µs (uniform).
    let target_probe_ns = 50_000.0 + (1.0 - workload.irregularity) * 350_000.0;
    let n = (target_probe_ns / workload.ns_per_item).round().clamp(1.0, 65_536.0) as usize;

    // w: 1 for small machines, +1 per ~quadrupling beyond 16 places,
    // capped at 4 (diminishing returns, paper §3.6: "improved slightly").
    let mut w = 1usize;
    let mut cap = 16usize;
    while cap < p && w < 4 {
        cap *= 4;
        w += 1;
    }

    // l: deep binary cubes respond better for irregular workloads or
    // large machines; the shallow X10 default is fine otherwise.
    let l = if workload.irregularity > 0.5 || p > 512 { 2 } else { 32 };

    GlbParams::default()
        .with_n(n)
        .with_w(w)
        .with_l(l)
        .with_workers_per_node(default_workers_per_node(p))
}

/// Node grouping for `p` places on a machine with `cores` hardware
/// threads: the largest divisor of `p` not exceeding the core count (so
/// nodes are even and the grouping never outgrows shared memory).
/// `1` (flat) when either side offers no grouping.
pub fn workers_per_node_for(p: usize, cores: usize) -> usize {
    if p <= 1 || cores <= 1 {
        return 1;
    }
    let target = cores.min(p);
    (1..=target).rev().find(|d| p % d == 0).unwrap_or(1)
}

/// [`workers_per_node_for`] against this machine's
/// `std::thread::available_parallelism`.
pub fn default_workers_per_node(p: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    workers_per_node_for(p, cores)
}

/// Convenience: tune for UTS on this machine (measures the SHA-1 rate).
pub fn autotune_uts(p: usize) -> GlbParams {
    let cost = crate::harness::calibrate_uts_cost();
    autotune(p, WorkloadProfile::new(cost.ns_per_unit, 1.0))
}

/// Convenience: tune for BC over a given graph (measures edge rate; the
/// per-"item" cost under the interruptible queue is one edge scan).
pub fn autotune_bc(p: usize, g: &crate::apps::bc::Graph) -> GlbParams {
    let cost = crate::harness::calibrate_bc_cost(g);
    // BC is uniform-ish per edge but needs responsiveness at the tail:
    // treat as moderately irregular.
    autotune(p, WorkloadProfile::new(cost.ns_per_unit, 0.6)).with_w(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn granularity_tracks_item_cost() {
        // Expensive items -> small n; cheap items -> large n.
        let heavy = autotune(64, WorkloadProfile::new(50_000.0, 1.0)); // 50µs/item
        let light = autotune(64, WorkloadProfile::new(5.0, 1.0)); // 5ns/item
        assert!(heavy.n <= 2, "50µs items: probe every item, n={}", heavy.n);
        assert!(light.n >= 5_000, "5ns items: big chunks, n={}", light.n);
    }

    #[test]
    fn uniform_workloads_get_bigger_chunks() {
        let irregular = autotune(64, WorkloadProfile::new(100.0, 1.0));
        let uniform = autotune(64, WorkloadProfile::new(100.0, 0.0));
        assert!(uniform.n > 2 * irregular.n);
    }

    #[test]
    fn w_grows_gently_with_places() {
        assert_eq!(autotune(4, WorkloadProfile::new(100.0, 1.0)).w, 1);
        assert_eq!(autotune(64, WorkloadProfile::new(100.0, 1.0)).w, 2);
        assert!(autotune(16_384, WorkloadProfile::new(100.0, 1.0)).w <= 4);
    }

    #[test]
    fn deep_cube_for_irregular_or_large() {
        assert_eq!(autotune(64, WorkloadProfile::new(100.0, 1.0)).l, 2);
        assert_eq!(autotune(64, WorkloadProfile::new(100.0, 0.0)).l, 32);
        assert_eq!(autotune(2048, WorkloadProfile::new(100.0, 0.0)).l, 2);
    }

    #[test]
    fn tuned_params_validate_and_run() {
        use crate::apps::uts::{sequential_count, UtsParams, UtsQueue};
        use crate::glb::task_queue::SumReducer;
        use crate::glb::GlbConfig;
        use crate::sim::{run_sim, CostModel, BGQ};
        let params = autotune(16, WorkloadProfile::new(150.0, 1.0));
        params.validate().unwrap();
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
        let cfg = GlbConfig::new(16, params);
        let (out, _) = run_sim(
            &cfg,
            &BGQ,
            CostModel::new(150.0, 60, 32),
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, sequential_count(&up));
    }

    #[test]
    fn node_grouping_tracks_machine_shape() {
        // Even divisors, capped by cores, never ragged.
        assert_eq!(workers_per_node_for(64, 16), 16);
        assert_eq!(workers_per_node_for(64, 12), 8, "largest divisor <= cores");
        assert_eq!(workers_per_node_for(10, 4), 2);
        assert_eq!(workers_per_node_for(7, 4), 1, "prime places stay flat below p cores");
        assert_eq!(workers_per_node_for(7, 8), 7, "whole machine fits one node");
        assert_eq!(workers_per_node_for(1, 64), 1);
        assert_eq!(workers_per_node_for(64, 1), 1, "single core: flat");
    }

    #[test]
    fn autotune_groups_workers_and_stays_valid() {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        for p in [1usize, 2, 7, 16, 60, 256] {
            let params = autotune(p, WorkloadProfile::new(100.0, 1.0));
            params.validate().expect("autotuned params validate");
            assert_eq!(params.workers_per_node, workers_per_node_for(p, cores), "p={p}");
            assert!(params.workers_per_node <= p.max(1));
            if params.workers_per_node > 1 {
                assert_eq!(p % params.workers_per_node, 0, "grouping divides p");
            }
        }
    }

    #[test]
    fn profile_clamps_inputs() {
        let p = WorkloadProfile::new(-5.0, 7.0);
        assert!(p.ns_per_item > 0.0);
        assert_eq!(p.irregularity, 1.0);
    }

    fn sample(starvations: u64) -> ControllerSample {
        ControllerSample { starvations, ..Default::default() }
    }

    #[test]
    fn controller_waits_out_the_dwell_then_recommends() {
        let mut c = AdaptiveController::new(AdaptiveConfig::default());
        assert_eq!(c.observe(sample(0), 511), None, "first sample only establishes a base");
        assert_eq!(c.observe(sample(1), 511), None);
        assert_eq!(c.observe(sample(2), 511), None);
        let r = c.observe(sample(3), 511).expect("three rising windows = persistent imbalance");
        assert_eq!(r, Retune { l: 2, n: 127 });
        // Unapplied recommendations repeat until confirmed...
        assert_eq!(c.observe(sample(4), 511), Some(Retune { l: 2, n: 127 }));
        c.confirm();
        assert_eq!(c.applied(), 1);
        // ...and the one-shot budget silences the controller for good.
        for s in 5..20 {
            assert_eq!(c.observe(sample(s), 127), None);
        }
    }

    #[test]
    fn controller_streak_resets_on_a_quiet_window() {
        let mut c = AdaptiveController::new(AdaptiveConfig::default());
        c.observe(sample(0), 511);
        c.observe(sample(1), 511);
        c.observe(sample(2), 511);
        assert_eq!(c.observe(sample(2), 511), None, "quiet window breaks the streak");
        c.observe(sample(3), 511);
        c.observe(sample(4), 511);
        assert_eq!(c.observe(sample(5), 511), Some(Retune { l: 2, n: 127 }));
    }

    #[test]
    fn controller_respects_the_granularity_floor() {
        let mut c = AdaptiveController::new(AdaptiveConfig { dwell: 1, ..Default::default() });
        c.observe(sample(0), 20);
        let r = c.observe(sample(1), 20).expect("dwell of one fires immediately");
        assert_eq!(r.n, 16, "floor, not 20/4");
        let mut c2 = AdaptiveController::new(AdaptiveConfig { dwell: 1, ..Default::default() });
        c2.observe(sample(0), 8);
        assert_eq!(c2.observe(sample(1), 8).unwrap().n, 8, "never grow n past its current value");
    }
}
