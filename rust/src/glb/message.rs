//! Steal-protocol messages exchanged between places.
//!
//! The X10 implementation uses synchronous `at` calls for random steals and
//! asynchronous activations for lifeline pushes; both runtimes here use a
//! uniform asynchronous request/response protocol with identical semantics:
//!
//! * `Steal { thief, lifeline }` — work request. A victim that cannot share
//!   answers `Loot { bag: None }`; a *lifeline* request is additionally
//!   remembered by the victim, which will push loot later when it gets
//!   work (paper §2.4: "it will still remember the request and try to
//!   satisfy the request when it gets work from others").
//! * `Loot { victim, bag, lifeline }` — response to a steal, or (with
//!   `lifeline = true` and an unexpected `victim`) a deferred lifeline
//!   push.
//! * `Terminate` — broadcast by the worker that observes global
//!   quiescence.

/// Identifier of a place (0-based, dense).
pub type PlaceId = usize;

/// A protocol message carrying bags of type `B`.
///
/// `nonce` pairs responses with requests. X10's random steals are
/// synchronous `at` calls, so a thief can never confuse a deferred
/// lifeline push with the response it is waiting for; under fully
/// asynchronous messaging the two are otherwise indistinguishable (same
/// victim, same kind), which would corrupt the steal loop — see the
/// `push_race_with_outstanding_request` test.
///
/// `credit` is the distributed-termination weight a loot bag carries
/// (see [`crate::glb::termination`]): the victim detaches it from its
/// rank's credit pool ([`crate::glb::termination::Ledger::export_credit`])
/// and the thief absorbs it. Ledgers with a genuinely global token count
/// (the thread runtime's atomic, the simulator's cell) ship `0`; a
/// refusal (`bag: None`) never carries credit.
#[derive(Debug, PartialEq)]
pub enum Msg<B> {
    /// Work request from `thief`.
    Steal { thief: PlaceId, lifeline: bool, nonce: u64 },
    /// Response to a steal (`bag: None` = refusal, echoing the request's
    /// `nonce`) or an unsolicited lifeline push (`bag: Some`,
    /// `lifeline: true`, `nonce: None`).
    Loot { victim: PlaceId, bag: Option<B>, lifeline: bool, nonce: Option<u64>, credit: u64 },
    /// Global quiescence: unblock and finish.
    Terminate,
}

impl<B> Msg<B> {
    /// Rough wire size in bytes, for the simulator's bandwidth/occupancy
    /// model. `item_bytes` is the application's per-task serialized size.
    /// The envelope is the socket codec's *actual* fixed message framing
    /// ([`crate::glb::wire::ENVELOPE_BYTES`]: length prefix + prelude,
    /// credit word included), pinned by test to `wire::encode_frame` for
    /// bag-less messages. Bag payloads are approximated by
    /// `item_bytes × items` (the codec adds a 4-byte count word). The
    /// mesh transport's per-frame destination prefix
    /// ([`crate::glb::wire::DATA_ROUTE_BYTES`]) is *not* part of this
    /// point-to-point figure — the simulator adds it per cross-node
    /// message, matching what the socket runtime actually puts on the
    /// wire.
    pub fn wire_bytes(&self, item_bytes: usize, bag_items: impl Fn(&B) -> usize) -> usize {
        const HEADER: usize = crate::glb::wire::ENVELOPE_BYTES;
        match self {
            Msg::Steal { .. } | Msg::Terminate => HEADER,
            Msg::Loot { bag: None, .. } => HEADER,
            Msg::Loot { bag: Some(b), .. } => HEADER + item_bytes * bag_items(b),
        }
    }

    /// Message kind as a short static label (diagnostics / sim traces).
    pub fn kind(&self) -> &'static str {
        match self {
            Msg::Steal { lifeline: false, .. } => "steal",
            Msg::Steal { lifeline: true, .. } => "steal-lifeline",
            Msg::Loot { bag: Some(_), lifeline: false, .. } => "loot",
            Msg::Loot { bag: Some(_), lifeline: true, .. } => "loot-lifeline",
            Msg::Loot { bag: None, .. } => "refusal",
            Msg::Terminate => "terminate",
        }
    }
}

/// Effects a worker asks its runtime to carry out. Keeping I/O out of the
/// worker lets the thread runtime and the discrete-event simulator share
/// the exact same protocol engine.
#[derive(Debug)]
pub enum Effect<B> {
    /// Send `msg` to place `to`.
    Send { to: PlaceId, msg: Msg<B> },
    /// This worker observed the global token count hit zero: the whole
    /// computation is quiescent. The runtime must broadcast `Terminate`.
    Quiescent,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_scale_with_bag() {
        use crate::glb::wire::ENVELOPE_BYTES;
        let len = |b: &Vec<u32>| b.len();
        let steal: Msg<Vec<u32>> = Msg::Steal { thief: 1, lifeline: false, nonce: 0 };
        assert_eq!(steal.wire_bytes(8, len), ENVELOPE_BYTES);
        let loot = Msg::Loot {
            victim: 0,
            bag: Some(vec![1, 2, 3]),
            lifeline: false,
            nonce: Some(0),
            credit: 5,
        };
        assert_eq!(loot.wire_bytes(8, len), ENVELOPE_BYTES + 24);
        let refusal: Msg<Vec<u32>> =
            Msg::Loot { victim: 0, bag: None, lifeline: true, nonce: Some(1), credit: 0 };
        assert_eq!(refusal.wire_bytes(8, len), ENVELOPE_BYTES);
    }

    #[test]
    fn bagless_wire_bytes_match_the_codec_exactly() {
        // The sim's per-message accounting (`wire_bytes`) must equal the
        // socket codec's real frame length for every bag-less message,
        // and envelope + per-entry bytes for loot.
        use crate::glb::task_bag::ArrayListTaskBag;
        use crate::glb::wire::{self, BAG_LEN_BYTES};
        type Bag = ArrayListTaskBag<u64>;
        let items = |b: &Bag| b.items().len();
        let bagless = [
            Msg::<Bag>::Steal { thief: 1, lifeline: true, nonce: 3 },
            Msg::<Bag>::Loot { victim: 2, bag: None, lifeline: false, nonce: Some(7), credit: 0 },
            Msg::<Bag>::Terminate,
        ];
        for m in bagless {
            assert_eq!(wire::encode_frame(&m).len(), m.wire_bytes(8, items), "{}", m.kind());
        }
        let loot = Msg::<Bag>::Loot {
            victim: 0,
            bag: Some(ArrayListTaskBag::from_vec(vec![1u64, 2, 3])),
            lifeline: true,
            nonce: None,
            credit: 9,
        };
        // u64 items are 8 bytes each; the codec adds only the bag count.
        assert_eq!(wire::encode_frame(&loot).len(), loot.wire_bytes(8, items) + BAG_LEN_BYTES);
    }

    #[test]
    fn kinds() {
        let m: Msg<Vec<u32>> = Msg::Steal { thief: 0, lifeline: true, nonce: 0 };
        assert_eq!(m.kind(), "steal-lifeline");
        let t: Msg<Vec<u32>> = Msg::Terminate;
        assert_eq!(t.kind(), "terminate");
    }
}
