//! Length-prefixed wire codec for the process-level socket transport
//! ([`crate::place::socket`]). Hand-rolled and offline-safe — the
//! vendored registry has no `serde`, and the protocol's message shapes
//! are small and fixed enough that an explicit byte layout is both
//! simpler and auditable.
//!
//! ## Frame layout
//!
//! ```text
//! frame    := len:u32le ++ body                  (len = body.len())
//! msg body := tag:u8                             (0 Steal, 1 Loot, 2 Terminate)
//!             lifeline:u8                        (0 | 1)
//!             place:u64le                        (thief / victim; 0 for Terminate)
//!             nonce_tag:u8  nonce:u64le          (tag 0 => nonce field is 0)
//!             credit:u64le                       (termination credit; loot-with-bag only)
//!             bag_tag:u8                         (1 iff a bag payload follows)
//!             [bag]
//! bag      := count:u32le ++ count * entry       (entry layout per bag type)
//! ```
//!
//! Every variant writes the full fixed prelude, so the framing overhead
//! of *any* message is exactly [`ENVELOPE_BYTES`] — the constant
//! [`Msg::wire_bytes`]'s `HEADER` is derived from, which keeps the
//! simulator's bandwidth/occupancy accounting aligned with what the TCP
//! transport actually puts on the wire.
//!
//! The socket runtime wraps message bodies in a *data frame* that leads
//! with the destination place ([`DATA_ROUTE_BYTES`]) and the job epoch
//! ([`DATA_JOB_BYTES`]) — mesh links are per-rank, a rank may host
//! several places, and a resident fleet (`glb serve`) runs a stream of
//! jobs over the same links, so every mesh frame names the job it
//! belongs to. A route word of [`FENCE_ROUTE`] marks a *fence*: the
//! sender promises no further frames for that job, which is how a
//! per-job reactor knows the link is drained without closing it. The
//! control plane (bootstrap, job submission, credit
//! deposits/replenishes, result gathering) speaks [`Ctrl`] frames over
//! the rank-0 control link.
//!
//! Decoding is total: truncated or malformed input returns a
//! [`WireError`], never panics and never allocates proportionally to a
//! corrupt length field (entries are decoded one at a time, so a lying
//! `count` hits [`WireError::Truncated`] first).

use std::io::{self, Read, Write};

use super::message::{Msg, PlaceId};
use super::metrics::StatsSnapshot;
use super::task_bag::ArrayListTaskBag;

/// Bytes of the `len` prefix in front of every frame body.
pub const FRAME_LEN_BYTES: usize = 4;
/// Fixed bytes of every encoded message body (prelude before the bag).
pub const MSG_FIXED_BYTES: usize = 28;
/// Total framing overhead of any message: length prefix + fixed prelude.
pub const ENVELOPE_BYTES: usize = FRAME_LEN_BYTES + MSG_FIXED_BYTES;
/// Every bag encoding leads with a u32 entry count.
pub const BAG_LEN_BYTES: usize = 4;
/// Destination-place prefix of a mesh data frame (a rank can host
/// several places, so frames are addressed per *place*).
pub const DATA_ROUTE_BYTES: usize = 8;
/// Job-epoch word of a mesh data frame, after the route. One-shot runs
/// are job `0`; a resident fleet stamps every frame with the current
/// job so back-to-back jobs can never cross-steal or cross-credit.
pub const DATA_JOB_BYTES: usize = 8;
/// Route sentinel of a *fence* frame: not a place, but the sender's
/// promise that no more frames for the named job will follow on this
/// link. The body is exactly the route word plus the job word.
pub const FENCE_ROUTE: u64 = u64::MAX;
/// Upper bound accepted by [`read_frame`] (a corrupt length field must
/// not trigger a giant allocation).
pub const MAX_FRAME_BYTES: usize = 1 << 28;

const TAG_STEAL: u8 = 0;
const TAG_LOOT: u8 = 1;
const TAG_TERMINATE: u8 = 2;

/// Why a decode failed. All variants are errors, never panics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ended before the value was complete.
    Truncated,
    /// An unknown enum tag or non-boolean flag byte.
    BadTag(u8),
    /// Bytes left over after a complete decode.
    Trailing(usize),
    /// A structurally invalid value (e.g. an empty child range).
    Invalid(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "wire frame truncated"),
            WireError::BadTag(t) => write!(f, "bad wire tag byte {t:#04x}"),
            WireError::Trailing(n) => write!(f, "{n} trailing bytes after decode"),
            WireError::Invalid(what) => write!(f, "invalid wire value: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Cursor over a byte slice; every accessor fails with
/// [`WireError::Truncated`] instead of slicing out of bounds.
pub struct Reader<'a> {
    buf: &'a [u8],
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len()
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() < n {
            return Err(WireError::Truncated);
        }
        let (head, tail) = self.buf.split_at(n);
        self.buf = tail;
        Ok(head)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.bytes(1)?[0])
    }

    /// A strict boolean byte (0 or 1; anything else is [`WireError::BadTag`]).
    pub fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(WireError::BadTag(b)),
        }
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// A value with a self-delimiting byte encoding. Task bags implement this
/// to travel between processes; `encode` followed by `decode` must be the
/// identity (property-checked in `rust/tests/properties.rs`).
///
/// `encode` is the *into-buffer* path: it appends to whatever `Vec` the
/// caller hands it, so the socket runtime's pooled frame buffers
/// ([`BufferPool`]) serialize whole frames without a per-message
/// allocation. [`WireCodec::decode_slice`] is the matching
/// slice-borrowing decode: it reads straight out of a staged receive
/// buffer ([`FrameAssembler`]) with no intermediate copy.
pub trait WireCodec: Sized {
    fn encode(&self, out: &mut Vec<u8>);
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError>;

    /// Decode a value from the front of a borrowed slice, returning it
    /// with the number of bytes consumed. Trailing bytes are the
    /// caller's business (frames carry several values back to back).
    fn decode_slice(buf: &[u8]) -> Result<(Self, usize), WireError> {
        let mut r = Reader::new(buf);
        let v = Self::decode(&mut r)?;
        let used = buf.len() - r.remaining();
        Ok((v, used))
    }
}

impl WireCodec for u32 {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, *self);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.u32()
    }
}

impl WireCodec for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u64(out, *self);
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        r.u64()
    }
}

/// `f64` travels as its IEEE-754 bit pattern (exact round-trip — the
/// fleet BC reduction must be bit-identical to a local one).
impl WireCodec for f64 {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u64(out, self.to_bits());
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(f64::from_bits(r.u64()?))
    }
}

/// Counted sequence of any codec-able element (per-rank result vectors:
/// the BC partial betweenness map is a `Vec<f64>`).
impl<T: WireCodec> WireCodec for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, self.len() as u32);
        for item in self {
            item.encode(out);
        }
    }
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let count = r.u32()? as usize;
        let mut items = Vec::new();
        for _ in 0..count {
            items.push(T::decode(r)?);
        }
        Ok(items)
    }
}

/// The default bag ships as a plain counted item array.
impl<T: WireCodec + Send + 'static> WireCodec for ArrayListTaskBag<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, self.items().len() as u32);
        for item in self.items() {
            item.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let count = r.u32()? as usize;
        let mut items = Vec::new();
        for _ in 0..count {
            items.push(T::decode(r)?);
        }
        Ok(Self::from_vec(items))
    }
}

/// Encode a message body (no length prefix) into `out`.
pub fn encode_msg_body<B: WireCodec>(msg: &Msg<B>, out: &mut Vec<u8>) {
    match msg {
        Msg::Steal { thief, lifeline, nonce } => {
            put_u8(out, TAG_STEAL);
            put_u8(out, *lifeline as u8);
            put_u64(out, *thief as u64);
            put_u8(out, 1);
            put_u64(out, *nonce);
            put_u64(out, 0);
            put_u8(out, 0);
        }
        Msg::Loot { victim, bag, lifeline, nonce, credit } => {
            put_u8(out, TAG_LOOT);
            put_u8(out, *lifeline as u8);
            put_u64(out, *victim as u64);
            put_u8(out, nonce.is_some() as u8);
            put_u64(out, nonce.unwrap_or(0));
            put_u64(out, *credit);
            put_u8(out, bag.is_some() as u8);
            if let Some(b) = bag {
                b.encode(out);
            }
        }
        Msg::Terminate => {
            put_u8(out, TAG_TERMINATE);
            put_u8(out, 0);
            put_u64(out, 0);
            put_u8(out, 0);
            put_u64(out, 0);
            put_u64(out, 0);
            put_u8(out, 0);
        }
    }
}

/// Decode a message body (no length prefix). Rejects trailing bytes.
pub fn decode_msg_body<B: WireCodec>(buf: &[u8]) -> Result<Msg<B>, WireError> {
    let mut r = Reader::new(buf);
    let tag = r.u8()?;
    let lifeline = r.bool()?;
    let place = r.u64()? as PlaceId;
    let nonce_present = r.bool()?;
    let nonce = r.u64()?;
    let credit = r.u64()?;
    let bag_present = r.bool()?;
    let msg = match tag {
        TAG_STEAL => {
            if !nonce_present || bag_present {
                return Err(WireError::Invalid("steal envelope flags"));
            }
            if credit != 0 {
                return Err(WireError::Invalid("steal carries credit"));
            }
            Msg::Steal { thief: place, lifeline, nonce }
        }
        TAG_LOOT => {
            if !bag_present && credit != 0 {
                return Err(WireError::Invalid("refusal carries credit"));
            }
            let bag = if bag_present { Some(B::decode(&mut r)?) } else { None };
            Msg::Loot {
                victim: place,
                bag,
                lifeline,
                nonce: nonce_present.then_some(nonce),
                credit,
            }
        }
        TAG_TERMINATE => {
            if lifeline || nonce_present || bag_present || place != 0 || nonce != 0 || credit != 0 {
                return Err(WireError::Invalid("terminate envelope not blank"));
            }
            Msg::Terminate
        }
        t => return Err(WireError::BadTag(t)),
    };
    match r.remaining() {
        0 => Ok(msg),
        n => Err(WireError::Trailing(n)),
    }
}

/// Encode a mesh data-frame body: destination place + job epoch +
/// message body.
pub fn encode_data_frame_body<B: WireCodec>(to: PlaceId, job: u64, msg: &Msg<B>) -> Vec<u8> {
    let mut body = Vec::with_capacity(DATA_ROUTE_BYTES + DATA_JOB_BYTES + MSG_FIXED_BYTES);
    put_u64(&mut body, to as u64);
    put_u64(&mut body, job);
    encode_msg_body(msg, &mut body);
    body
}

/// Decode a mesh data-frame body into `(destination place, job, message)`.
/// The route word must not be the fence sentinel (fences carry no
/// message — check [`fence_job`] first).
pub fn decode_data_frame_body<B: WireCodec>(
    buf: &[u8],
) -> Result<(PlaceId, u64, Msg<B>), WireError> {
    let mut r = Reader::new(buf);
    let route = r.u64()?;
    if route == FENCE_ROUTE {
        return Err(WireError::Invalid("fence frame where a message was expected"));
    }
    let job = r.u64()?;
    let rest = r.remaining();
    let msg = decode_msg_body(r.bytes(rest)?)?;
    Ok((route as PlaceId, job, msg))
}

/// If `body` is a fence frame, its job epoch. Fences are exactly the
/// [`FENCE_ROUTE`] route word plus the job word — anything else under a
/// fence route is a corrupt peer, reported as an error.
pub fn fence_job(body: &[u8]) -> Result<Option<u64>, WireError> {
    let mut r = Reader::new(body);
    if r.u64()? != FENCE_ROUTE {
        return Ok(None);
    }
    let job = r.u64()?;
    match r.remaining() {
        0 => Ok(Some(job)),
        n => Err(WireError::Trailing(n)),
    }
}

// ---------------------------------------------------------------------
// fleet control plane
// ---------------------------------------------------------------------

const CTRL_REGISTER: u8 = 0;
const CTRL_PEER_MAP: u8 = 1;
const CTRL_READY: u8 = 2;
const CTRL_GO: u8 = 3;
const CTRL_DEPOSIT: u8 = 4;
const CTRL_REPLENISH: u8 = 5;
const CTRL_GRANT: u8 = 6;
const CTRL_RESULT: u8 = 7;
const CTRL_JOIN: u8 = 8;
const CTRL_LEAVE: u8 = 9;
const CTRL_ACK: u8 = 10;
const CTRL_RECONCILE: u8 = 11;
const CTRL_STATS: u8 = 12;
const CTRL_SUBMIT: u8 = 13;
const CTRL_JOB_RESULT: u8 = 14;
const CTRL_SHUTDOWN: u8 = 15;

/// Fleet control-plane messages, exchanged as length-prefixed frames on
/// each rank's control link to rank 0. Rank 0 is bootstrap + credit root
/// only: after [`Ctrl::Go`] the only steady-state control traffic is
/// asynchronous [`Ctrl::Deposit`]s (idle ranks returning termination
/// credit) and the rare [`Ctrl::Replenish`]/[`Ctrl::Grant`] pair — no
/// data frame ever crosses the control link.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Ctrl {
    /// rank → root: my rank and the `ip:port` my mesh listener accepts on.
    Register { rank: u64, addr: String },
    /// root → rank: every rank's mesh address, indexed by rank. `epoch`
    /// counts membership changes: `0` is the bootstrap view, and every
    /// crash-recovery reconfiguration re-publishes the map with the
    /// epoch bumped (dead ranks keep their slot as an empty string).
    PeerMap { epoch: u64, addrs: Vec<String> },
    /// rank → root: mesh wired, workers constructed, initial tokens held.
    Ready { rank: u64 },
    /// root → rank: the whole fleet is ready; start the steal protocol.
    Go,
    /// rank → root: this rank went idle; here is its whole credit pool.
    /// `job` names the epoch the atoms belong to (0 for one-shot runs),
    /// so a resident fleet's credit books never mix jobs.
    Deposit { job: u64, atoms: u64 },
    /// rank → root: credit pool exhausted; mint `want` fresh atoms for
    /// job `job`.
    Replenish { job: u64, want: u64 },
    /// root → rank: the freshly minted atoms (reply to `Replenish`).
    Grant { job: u64, atoms: u64 },
    /// rank → root: the rank's encoded local result for job `job`, for
    /// the fleet-wide reduction at rank 0.
    Result { job: u64, bytes: Vec<u8> },
    /// rank → root: a (re)joining rank announces its mesh address under
    /// the membership epoch it last saw. Carried by the dynamic
    /// membership provider; the socket runtime does not accept joins
    /// mid-run yet.
    Join { epoch: u64, rank: u64, addr: String },
    /// root → survivors: `rank` crashed; the view advances to `epoch`.
    /// Survivors re-knit their lifelines over the shrunken member set
    /// and reconcile their in-flight loot ledgers for the dead rank.
    Leave { epoch: u64, rank: u64 },
    /// rank → root (then root → victims): an idle-point checkpoint.
    /// `result` is the rank's encoded partial result (empty when the
    /// root forwards), and `acked` lists cumulative per-victim counts of
    /// loot bags this rank has merged — the victims prune their
    /// in-flight retention ledgers up to those counts.
    Ack { rank: u64, result: Vec<u8>, acked: Vec<(u64, u64)> },
    /// survivor → root after a [`Ctrl::Leave`]: `sent`/`received` are
    /// the total credit atoms this rank attached to loot for the dead
    /// rank (net of re-imported unacknowledged entries) and received
    /// from it. The root solves for the atoms that died with the rank
    /// and reclaims them, keeping `recovered == total` reachable.
    Reconcile { rank: u64, sent: u64, received: u64 },
    /// rank → root: a live telemetry sample (periodic while `--stats`
    /// is armed, plus one final `last` snapshot at teardown). Purely
    /// advisory — losing one skews nothing, since every field is a
    /// cumulative counter or an instantaneous level.
    Stats(StatsSnapshot),
    /// submitter → root, then root → ranks: run job `job`. `spec` is
    /// the job's `key=value` description (see
    /// [`crate::place::service::JobSpec`]) and `bag` the serialized
    /// root task bag, decoded and merged into place 0's queue (empty
    /// when every rank derives its own share from the spec, as BC
    /// does).
    Submit { job: u64, spec: String, bag: Vec<u8> },
    /// root → submitter: job `job` finished; `bytes` is the encoded
    /// fleet-wide reduced result.
    JobResult { job: u64, bytes: Vec<u8> },
    /// submitter → root, then root → ranks: drain and exit. The resident
    /// fleet finishes in-flight work, then every rank tears down
    /// cleanly.
    Shutdown,
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_str(r: &mut Reader<'_>) -> Result<String, WireError> {
    let len = r.u32()? as usize;
    let bytes = r.bytes(len)?;
    String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Invalid("non-utf8 string"))
}

impl Ctrl {
    /// Encode as a frame body (wrap with [`write_frame`] to send).
    pub fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Ctrl::Register { rank, addr } => {
                put_u8(out, CTRL_REGISTER);
                put_u64(out, *rank);
                put_str(out, addr);
            }
            Ctrl::PeerMap { epoch, addrs } => {
                put_u8(out, CTRL_PEER_MAP);
                put_u64(out, *epoch);
                put_u32(out, addrs.len() as u32);
                for a in addrs {
                    put_str(out, a);
                }
            }
            Ctrl::Ready { rank } => {
                put_u8(out, CTRL_READY);
                put_u64(out, *rank);
            }
            Ctrl::Go => put_u8(out, CTRL_GO),
            Ctrl::Deposit { job, atoms } => {
                put_u8(out, CTRL_DEPOSIT);
                put_u64(out, *job);
                put_u64(out, *atoms);
            }
            Ctrl::Replenish { job, want } => {
                put_u8(out, CTRL_REPLENISH);
                put_u64(out, *job);
                put_u64(out, *want);
            }
            Ctrl::Grant { job, atoms } => {
                put_u8(out, CTRL_GRANT);
                put_u64(out, *job);
                put_u64(out, *atoms);
            }
            Ctrl::Result { job, bytes } => {
                put_u8(out, CTRL_RESULT);
                put_u64(out, *job);
                put_u32(out, bytes.len() as u32);
                out.extend_from_slice(bytes);
            }
            Ctrl::Join { epoch, rank, addr } => {
                put_u8(out, CTRL_JOIN);
                put_u64(out, *epoch);
                put_u64(out, *rank);
                put_str(out, addr);
            }
            Ctrl::Leave { epoch, rank } => {
                put_u8(out, CTRL_LEAVE);
                put_u64(out, *epoch);
                put_u64(out, *rank);
            }
            Ctrl::Ack { rank, result, acked } => {
                put_u8(out, CTRL_ACK);
                put_u64(out, *rank);
                put_u32(out, result.len() as u32);
                out.extend_from_slice(result);
                put_u32(out, acked.len() as u32);
                for (victim, merged) in acked {
                    put_u64(out, *victim);
                    put_u64(out, *merged);
                }
            }
            Ctrl::Reconcile { rank, sent, received } => {
                put_u8(out, CTRL_RECONCILE);
                put_u64(out, *rank);
                put_u64(out, *sent);
                put_u64(out, *received);
            }
            Ctrl::Submit { job, spec, bag } => {
                put_u8(out, CTRL_SUBMIT);
                put_u64(out, *job);
                put_str(out, spec);
                put_u32(out, bag.len() as u32);
                out.extend_from_slice(bag);
            }
            Ctrl::JobResult { job, bytes } => {
                put_u8(out, CTRL_JOB_RESULT);
                put_u64(out, *job);
                put_u32(out, bytes.len() as u32);
                out.extend_from_slice(bytes);
            }
            Ctrl::Shutdown => put_u8(out, CTRL_SHUTDOWN),
            Ctrl::Stats(s) => {
                put_u8(out, CTRL_STATS);
                put_u64(out, s.rank);
                put_u64(out, s.seq);
                put_u64(out, s.elapsed_ms);
                put_u64(out, s.bag_depth);
                put_u64(out, s.items);
                put_u64(out, s.steals_out);
                put_u64(out, s.steals_in);
                put_u64(out, s.loot_sent);
                put_u64(out, s.loot_recv);
                put_u64(out, s.starvations);
                put_u64(out, s.credit_pool);
                put_u64(out, s.wire_tx);
                put_u64(out, s.wire_rx);
                put_u64(out, s.frames_tx);
                put_u64(out, s.frames_rx);
                put_u64(out, s.out_queue);
                put_u8(out, s.last as u8);
            }
        }
    }

    /// Convenience: encoded frame body.
    pub fn to_body(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16);
        self.encode(&mut out);
        out
    }

    /// Decode a control frame body. Total, like [`decode_msg_body`]:
    /// truncation and bad tags are errors, trailing bytes are rejected.
    pub fn decode(buf: &[u8]) -> Result<Self, WireError> {
        let mut r = Reader::new(buf);
        let msg = match r.u8()? {
            CTRL_REGISTER => Ctrl::Register { rank: r.u64()?, addr: get_str(&mut r)? },
            CTRL_PEER_MAP => {
                let epoch = r.u64()?;
                let count = r.u32()? as usize;
                let mut addrs = Vec::new();
                for _ in 0..count {
                    addrs.push(get_str(&mut r)?);
                }
                Ctrl::PeerMap { epoch, addrs }
            }
            CTRL_READY => Ctrl::Ready { rank: r.u64()? },
            CTRL_GO => Ctrl::Go,
            CTRL_DEPOSIT => Ctrl::Deposit { job: r.u64()?, atoms: r.u64()? },
            CTRL_REPLENISH => Ctrl::Replenish { job: r.u64()?, want: r.u64()? },
            CTRL_GRANT => Ctrl::Grant { job: r.u64()?, atoms: r.u64()? },
            CTRL_RESULT => {
                let job = r.u64()?;
                let len = r.u32()? as usize;
                Ctrl::Result { job, bytes: r.bytes(len)?.to_vec() }
            }
            CTRL_JOIN => {
                Ctrl::Join { epoch: r.u64()?, rank: r.u64()?, addr: get_str(&mut r)? }
            }
            CTRL_LEAVE => Ctrl::Leave { epoch: r.u64()?, rank: r.u64()? },
            CTRL_ACK => {
                let rank = r.u64()?;
                let len = r.u32()? as usize;
                let result = r.bytes(len)?.to_vec();
                let count = r.u32()? as usize;
                let mut acked = Vec::new();
                for _ in 0..count {
                    acked.push((r.u64()?, r.u64()?));
                }
                Ctrl::Ack { rank, result, acked }
            }
            CTRL_RECONCILE => {
                Ctrl::Reconcile { rank: r.u64()?, sent: r.u64()?, received: r.u64()? }
            }
            CTRL_SUBMIT => {
                let job = r.u64()?;
                let spec = get_str(&mut r)?;
                let len = r.u32()? as usize;
                Ctrl::Submit { job, spec, bag: r.bytes(len)?.to_vec() }
            }
            CTRL_JOB_RESULT => {
                let job = r.u64()?;
                let len = r.u32()? as usize;
                Ctrl::JobResult { job, bytes: r.bytes(len)?.to_vec() }
            }
            CTRL_SHUTDOWN => Ctrl::Shutdown,
            CTRL_STATS => Ctrl::Stats(StatsSnapshot {
                rank: r.u64()?,
                seq: r.u64()?,
                elapsed_ms: r.u64()?,
                bag_depth: r.u64()?,
                items: r.u64()?,
                steals_out: r.u64()?,
                steals_in: r.u64()?,
                loot_sent: r.u64()?,
                loot_recv: r.u64()?,
                starvations: r.u64()?,
                credit_pool: r.u64()?,
                wire_tx: r.u64()?,
                wire_rx: r.u64()?,
                frames_tx: r.u64()?,
                frames_rx: r.u64()?,
                out_queue: r.u64()?,
                last: r.bool()?,
            }),
            t => return Err(WireError::BadTag(t)),
        };
        match r.remaining() {
            0 => Ok(msg),
            n => Err(WireError::Trailing(n)),
        }
    }
}

/// Wrap an already-encoded body in a length-prefixed frame.
pub fn frame(body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_LEN_BYTES + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

/// Reserve a length prefix at the current end of `out` and return its
/// offset. Write the frame body, then patch the prefix with
/// [`end_frame`]. This is the zero-copy path: the body is encoded
/// directly into the (pooled) output buffer, never into a scratch `Vec`.
pub fn begin_frame(out: &mut Vec<u8>) -> usize {
    let at = out.len();
    put_u32(out, 0);
    at
}

/// Patch the length prefix reserved by [`begin_frame`] at `at` to cover
/// everything appended since. Returns the body length.
pub fn end_frame(out: &mut Vec<u8>, at: usize) -> usize {
    let body_len = out.len() - at - FRAME_LEN_BYTES;
    out[at..at + FRAME_LEN_BYTES].copy_from_slice(&(body_len as u32).to_le_bytes());
    body_len
}

/// Encode a complete mesh data frame (length prefix + route + job +
/// message body) into `out`, appending. Returns the frame's *body*
/// length (what the length prefix says), so callers can enforce
/// [`MAX_FRAME_BYTES`] sender-side like [`write_frame`] does.
pub fn encode_data_frame_into<B: WireCodec>(
    to: PlaceId,
    job: u64,
    msg: &Msg<B>,
    out: &mut Vec<u8>,
) -> usize {
    let at = begin_frame(out);
    put_u64(out, to as u64);
    put_u64(out, job);
    encode_msg_body(msg, out);
    end_frame(out, at)
}

/// Encode a complete fence frame (length prefix + [`FENCE_ROUTE`] + job
/// word) into `out`, appending. Returns the frame's body length.
pub fn encode_fence_frame_into(job: u64, out: &mut Vec<u8>) -> usize {
    let at = begin_frame(out);
    put_u64(out, FENCE_ROUTE);
    put_u64(out, job);
    end_frame(out, at)
}

/// Encode a complete control frame (length prefix + [`Ctrl`] body) into
/// `out`, appending. Returns the frame's body length.
pub fn encode_ctrl_frame_into(c: &Ctrl, out: &mut Vec<u8>) -> usize {
    let at = begin_frame(out);
    c.encode(out);
    end_frame(out, at)
}

// ---------------------------------------------------------------------
// pooled frame buffers + staged nonblocking frame assembly
// ---------------------------------------------------------------------

/// How much capacity a recycled buffer may keep. Bags are usually tiny
/// (steal/credit frames are [`ENVELOPE_BYTES`] + 8), but a giant loot
/// frame would otherwise pin its high-water allocation in the pool
/// forever.
const POOL_KEEP_CAPACITY: usize = 64 * 1024;
/// Buffers retained per pool; beyond this, returned buffers are freed.
const POOL_KEEP_COUNT: usize = 256;

/// A free list of frame buffers shared by a rank's senders and its I/O
/// reactor. Steady-state loot/credit traffic encodes into a recycled
/// `Vec` ([`BufferPool::get`]) and returns it once the reactor has
/// flushed the frame ([`BufferPool::put_arc`]) — no allocation per
/// message once the pool is warm. Retention ledgers in tolerant mode
/// hold a clone of the same `Arc`, so a retained frame simply stays
/// alive until its idle-point `Ack` prunes it, at which point the buffer
/// drops back into the pool.
#[derive(Default)]
pub struct BufferPool {
    free: std::sync::Mutex<Vec<Vec<u8>>>,
}

impl BufferPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cleared buffer, recycled when one is pooled.
    pub fn get(&self) -> Vec<u8> {
        let mut buf = self.free.lock().unwrap().pop().unwrap_or_default();
        buf.clear();
        buf
    }

    /// Return a buffer to the pool (bounded: oversized or surplus
    /// buffers are simply dropped).
    pub fn put(&self, buf: Vec<u8>) {
        if buf.capacity() == 0 || buf.capacity() > POOL_KEEP_CAPACITY {
            return;
        }
        let mut free = self.free.lock().unwrap();
        if free.len() < POOL_KEEP_COUNT {
            free.push(buf);
        }
    }

    /// Recycle a frame the reactor has finished sending. The queue holds
    /// frames behind `Arc`s because tolerant-mode retention may keep a
    /// clone; the buffer only returns to the pool once the last holder
    /// lets go.
    pub fn put_arc(&self, frame: std::sync::Arc<Vec<u8>>) {
        if let Ok(buf) = std::sync::Arc::try_unwrap(frame) {
            self.put(buf);
        }
    }

    /// Buffers currently pooled (test observability).
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// When the consumed prefix of the staging buffer grows past this, the
/// unconsumed tail is slid back to the front (amortized O(1) per byte).
const ASSEMBLER_COMPACT_AT: usize = 32 * 1024;

/// Per-peer staged read buffer for a nonblocking socket: raw bytes land
/// in [`FrameAssembler::read_space`] / [`FrameAssembler::commit`] (or
/// [`FrameAssembler::feed`]), and [`FrameAssembler::next_frame`] yields
/// complete length-prefixed frame bodies *borrowed in place* — a frame
/// is only ever copied out of the kernel once, no matter how the bytes
/// were split across reads.
pub struct FrameAssembler {
    buf: Vec<u8>,
    /// Start of unconsumed bytes.
    pos: usize,
    /// End of valid bytes (`pos..filled` is unconsumed).
    filled: usize,
    /// Frame-length cap, as in [`read_frame`].
    max: usize,
}

impl FrameAssembler {
    pub fn new(max: usize) -> Self {
        Self { buf: Vec::new(), pos: 0, filled: 0, max }
    }

    /// Unconsumed bytes currently staged (partial or undrained frames).
    pub fn buffered(&self) -> usize {
        self.filled - self.pos
    }

    /// A writable slice of at least `min` bytes at the end of the staged
    /// data, for a nonblocking `read` to fill. Follow with
    /// [`FrameAssembler::commit`] for however many bytes landed.
    pub fn read_space(&mut self, min: usize) -> &mut [u8] {
        if self.pos == self.filled {
            // Everything consumed: restart at the front for free.
            self.pos = 0;
            self.filled = 0;
        } else if self.pos >= ASSEMBLER_COMPACT_AT {
            self.buf.copy_within(self.pos..self.filled, 0);
            self.filled -= self.pos;
            self.pos = 0;
        }
        if self.buf.len() < self.filled + min {
            self.buf.resize(self.filled + min, 0);
        }
        &mut self.buf[self.filled..]
    }

    /// Mark `n` bytes of the last [`FrameAssembler::read_space`] slice
    /// as filled by the kernel.
    pub fn commit(&mut self, n: usize) {
        self.filled += n;
        debug_assert!(self.filled <= self.buf.len());
    }

    /// Copy-in path for tests and non-socket sources.
    pub fn feed(&mut self, chunk: &[u8]) {
        let space = self.read_space(chunk.len());
        space[..chunk.len()].copy_from_slice(chunk);
        self.commit(chunk.len());
    }

    /// The next complete frame body, borrowed from the staging buffer,
    /// or `Ok(None)` if more bytes are needed. A length prefix over the
    /// cap is an error (corrupt peer), as in [`read_frame`].
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, WireError> {
        let avail = self.filled - self.pos;
        if avail < FRAME_LEN_BYTES {
            return Ok(None);
        }
        let len4: [u8; 4] = self.buf[self.pos..self.pos + FRAME_LEN_BYTES].try_into().unwrap();
        let len = u32::from_le_bytes(len4) as usize;
        if len > self.max {
            return Err(WireError::Invalid("frame exceeds length cap"));
        }
        if avail < FRAME_LEN_BYTES + len {
            return Ok(None);
        }
        let start = self.pos + FRAME_LEN_BYTES;
        self.pos = start + len;
        Ok(Some(&self.buf[start..start + len]))
    }
}

/// Encode a message as a complete length-prefixed frame.
pub fn encode_frame<B: WireCodec>(msg: &Msg<B>) -> Vec<u8> {
    let mut body = Vec::with_capacity(MSG_FIXED_BYTES);
    encode_msg_body(msg, &mut body);
    frame(body)
}

/// Decode a complete length-prefixed frame. The length prefix must match
/// the slice exactly (truncated input is [`WireError::Truncated`], excess
/// is [`WireError::Trailing`]).
pub fn decode_frame<B: WireCodec>(buf: &[u8]) -> Result<Msg<B>, WireError> {
    let mut r = Reader::new(buf);
    let len = r.u32()? as usize;
    if r.remaining() < len {
        return Err(WireError::Truncated);
    }
    if r.remaining() > len {
        return Err(WireError::Trailing(r.remaining() - len));
    }
    decode_msg_body(r.bytes(len)?)
}

/// `read_exact`, except a clean EOF *before the first byte* returns
/// `Ok(false)` (the peer shut down between frames — normal teardown).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "eof mid-frame"));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Write one length-prefixed frame. Bodies over [`MAX_FRAME_BYTES`] are
/// refused here, on the sender — otherwise the receiver's cap check
/// would silently drop the link and hang the peer waiting on it.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    if body.len() > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {} bytes exceeds cap {MAX_FRAME_BYTES}", body.len()),
        ));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)
}

/// Read one length-prefixed frame body. `Ok(None)` = clean peer shutdown
/// between frames; mid-frame EOF and over-`max` lengths are I/O errors.
pub fn read_frame(r: &mut impl Read, max: usize) -> io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    if !read_exact_or_eof(r, &mut len4)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > max {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds cap {max}"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(body))
}

#[cfg(test)]
mod tests {
    use super::*;

    type Bag = ArrayListTaskBag<u64>;

    /// Every field distinct and nonzero, so a decode that swaps or drops
    /// a field cannot still compare equal.
    fn sample_snapshot() -> StatsSnapshot {
        StatsSnapshot {
            rank: 1,
            seq: 2,
            elapsed_ms: 3,
            bag_depth: 4,
            items: 5,
            steals_out: 6,
            steals_in: 7,
            loot_sent: 8,
            loot_recv: 9,
            starvations: 10,
            credit_pool: 11,
            wire_tx: u64::MAX,
            wire_rx: 13,
            frames_tx: 14,
            frames_rx: 15,
            out_queue: 16,
            last: false,
        }
    }

    #[test]
    fn fixed_prelude_is_the_documented_size() {
        for msg in [
            Msg::<Bag>::Steal { thief: 3, lifeline: true, nonce: 9 },
            Msg::<Bag>::Loot { victim: 1, bag: None, lifeline: false, nonce: Some(4), credit: 0 },
            Msg::<Bag>::Terminate,
        ] {
            let mut body = Vec::new();
            encode_msg_body(&msg, &mut body);
            assert_eq!(body.len(), MSG_FIXED_BYTES, "{}", msg.kind());
            assert_eq!(encode_frame(&msg).len(), ENVELOPE_BYTES, "{}", msg.kind());
        }
    }

    #[test]
    fn roundtrips_every_variant() {
        let msgs = [
            Msg::<Bag>::Steal { thief: 7, lifeline: false, nonce: 41 },
            Msg::<Bag>::Steal { thief: 0, lifeline: true, nonce: u64::MAX },
            Msg::<Bag>::Loot { victim: 2, bag: None, lifeline: true, nonce: Some(5), credit: 0 },
            Msg::<Bag>::Loot {
                victim: 9,
                bag: Some(ArrayListTaskBag::from_vec(vec![1u64, 2, 3])),
                lifeline: false,
                nonce: None,
                credit: 0,
            },
            Msg::<Bag>::Loot {
                victim: 3,
                bag: Some(ArrayListTaskBag::from_vec(vec![4u64])),
                lifeline: true,
                nonce: Some(8),
                credit: u64::MAX,
            },
            Msg::<Bag>::Terminate,
        ];
        for msg in msgs {
            let frame = encode_frame(&msg);
            let back: Msg<Bag> = decode_frame(&frame).expect("decode");
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn truncated_frames_error_not_panic() {
        let msg = Msg::<Bag>::Loot {
            victim: 4,
            bag: Some(ArrayListTaskBag::from_vec(vec![10u64, 20, 30, 40])),
            lifeline: true,
            nonce: Some(77),
            credit: 12,
        };
        let frame = encode_frame(&msg);
        for cut in 0..frame.len() {
            assert!(decode_frame::<Bag>(&frame[..cut]).is_err(), "cut at {cut}");
        }
        let mut extended = frame.clone();
        extended.push(0);
        assert_eq!(decode_frame::<Bag>(&extended), Err(WireError::Trailing(1)));
    }

    #[test]
    fn credit_on_non_loot_is_rejected() {
        // Steal, refusal and Terminate must all carry a zero credit word;
        // a corrupted one is an Invalid, never silently accepted.
        let credit_at = 1 + 1 + 8 + 1 + 8; // tag, lifeline, place, nonce_tag, nonce
        for msg in [
            Msg::<Bag>::Steal { thief: 3, lifeline: false, nonce: 9 },
            Msg::<Bag>::Loot { victim: 1, bag: None, lifeline: true, nonce: Some(4), credit: 0 },
            Msg::<Bag>::Terminate,
        ] {
            let mut body = Vec::new();
            encode_msg_body(&msg, &mut body);
            body[credit_at] = 1;
            assert!(
                matches!(decode_msg_body::<Bag>(&body), Err(WireError::Invalid(_))),
                "{} must reject stray credit",
                msg.kind()
            );
        }
    }

    #[test]
    fn bad_tags_are_rejected() {
        let mut body = Vec::new();
        encode_msg_body(&Msg::<Bag>::Terminate, &mut body);
        body[0] = 9; // unknown message tag
        assert_eq!(decode_msg_body::<Bag>(&body), Err(WireError::BadTag(9)));
        body[0] = TAG_STEAL;
        body[1] = 2; // non-boolean lifeline byte
        assert_eq!(decode_msg_body::<Bag>(&body), Err(WireError::BadTag(2)));
    }

    #[test]
    fn lying_count_hits_truncated_not_alloc() {
        // A bag that claims u32::MAX entries but carries none.
        let mut body = Vec::new();
        encode_msg_body(
            &Msg::<Bag>::Loot {
                victim: 0,
                bag: Some(ArrayListTaskBag::from_vec(Vec::new())),
                lifeline: false,
                nonce: None,
                credit: 1,
            },
            &mut body,
        );
        let count_at = MSG_FIXED_BYTES; // bag count is the first bag field
        body[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(decode_msg_body::<Bag>(&body), Err(WireError::Truncated));
    }

    #[test]
    fn data_frames_route_and_roundtrip() {
        let msg = Msg::<Bag>::Loot {
            victim: 2,
            bag: Some(ArrayListTaskBag::from_vec(vec![5u64, 6])),
            lifeline: false,
            nonce: Some(3),
            credit: 7,
        };
        let body = encode_data_frame_body(11, 42, &msg);
        assert_eq!(
            body.len(),
            DATA_ROUTE_BYTES + DATA_JOB_BYTES + MSG_FIXED_BYTES + BAG_LEN_BYTES + 16
        );
        assert_eq!(fence_job(&body), Ok(None), "a routed frame is not a fence");
        let (to, job, back) = decode_data_frame_body::<Bag>(&body).expect("decode");
        assert_eq!((to, job), (11, 42));
        assert_eq!(back, msg);
        // Truncation safety: every strict prefix errors.
        for cut in 0..body.len() {
            assert!(decode_data_frame_body::<Bag>(&body[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn fence_frames_roundtrip_and_reject_messages() {
        let mut out = Vec::new();
        let body_len = encode_fence_frame_into(7, &mut out);
        assert_eq!(body_len, DATA_ROUTE_BYTES + DATA_JOB_BYTES);
        let body = &out[FRAME_LEN_BYTES..];
        assert_eq!(fence_job(body), Ok(Some(7)));
        // A fence body is not a message: the data-frame decoder refuses
        // it instead of conjuring a place out of the sentinel.
        assert!(decode_data_frame_body::<Bag>(body).is_err());
        // A fence with trailing bytes is a corrupt peer, not a fence.
        let mut long = body.to_vec();
        long.push(0);
        assert!(fence_job(&long).is_err());
        assert_eq!(fence_job(&[1]), Err(WireError::Truncated));
    }

    #[test]
    fn ctrl_frames_roundtrip() {
        let msgs = [
            Ctrl::Register { rank: 3, addr: "10.0.0.7:4471".into() },
            Ctrl::PeerMap {
                epoch: 0,
                addrs: vec!["127.0.0.1:7117".into(), "127.0.0.1:9000".into(), String::new()],
            },
            Ctrl::PeerMap { epoch: 3, addrs: vec!["127.0.0.1:7117".into(), String::new()] },
            Ctrl::Ready { rank: 2 },
            Ctrl::Go,
            Ctrl::Deposit { job: 0, atoms: u64::MAX },
            Ctrl::Replenish { job: 3, want: 1 << 20 },
            Ctrl::Grant { job: 3, atoms: 1 << 20 },
            Ctrl::Result { job: 1, bytes: vec![1, 2, 3, 0xFF] },
            Ctrl::Result { job: 0, bytes: Vec::new() },
            Ctrl::Submit { job: 9, spec: "app=uts depth=8".into(), bag: vec![0xAA, 0, 1] },
            Ctrl::Submit { job: 0, spec: String::new(), bag: Vec::new() },
            Ctrl::JobResult { job: 9, bytes: vec![4, 5, 6] },
            Ctrl::JobResult { job: u64::MAX, bytes: Vec::new() },
            Ctrl::Shutdown,
            Ctrl::Join { epoch: 2, rank: 5, addr: "10.1.2.3:999".into() },
            Ctrl::Leave { epoch: 7, rank: 2 },
            Ctrl::Ack { rank: 1, result: vec![0xAB, 0xCD], acked: vec![(0, 3), (2, 17)] },
            Ctrl::Ack { rank: 3, result: Vec::new(), acked: Vec::new() },
            Ctrl::Reconcile { rank: 2, sent: u64::MAX, received: 41314 },
            Ctrl::Stats(sample_snapshot()),
            Ctrl::Stats(StatsSnapshot { rank: 3, last: true, ..Default::default() }),
        ];
        for msg in msgs {
            let body = msg.to_body();
            assert_eq!(Ctrl::decode(&body).expect("decode"), msg);
        }
    }

    #[test]
    fn ctrl_frames_truncation_safe() {
        let msgs = [
            Ctrl::Register { rank: 1, addr: "192.168.0.1:81".into() },
            Ctrl::PeerMap { epoch: 1, addrs: vec!["a:1".into(), "b:2".into()] },
            Ctrl::Ready { rank: 9 },
            Ctrl::Deposit { job: 2, atoms: 77 },
            Ctrl::Replenish { job: 2, want: 5 },
            Ctrl::Grant { job: 2, atoms: 5 },
            Ctrl::Result { job: 2, bytes: vec![9; 32] },
            Ctrl::Submit { job: 4, spec: "app=fib n=30".into(), bag: vec![1, 2, 3] },
            Ctrl::JobResult { job: 4, bytes: vec![8; 12] },
            Ctrl::Join { epoch: 4, rank: 6, addr: "c:3".into() },
            Ctrl::Leave { epoch: 5, rank: 1 },
            Ctrl::Ack { rank: 2, result: vec![7; 9], acked: vec![(1, 2), (3, 4)] },
            Ctrl::Reconcile { rank: 1, sent: 10, received: 20 },
            Ctrl::Stats(sample_snapshot()),
        ];
        for msg in msgs {
            let body = msg.to_body();
            for cut in 0..body.len() {
                assert!(Ctrl::decode(&body[..cut]).is_err(), "{msg:?} cut at {cut}");
            }
            let mut extended = body.clone();
            extended.push(0);
            assert_eq!(Ctrl::decode(&extended), Err(WireError::Trailing(1)));
        }
        assert_eq!(Ctrl::decode(&[0xEE]), Err(WireError::BadTag(0xEE)));
        // A Stats frame whose `last` byte is neither 0 nor 1 is hostile,
        // not a silent truthy cast.
        let mut lying_bool = Ctrl::Stats(sample_snapshot()).to_body();
        let at = lying_bool.len() - 1;
        lying_bool[at] = 2;
        assert_eq!(Ctrl::decode(&lying_bool), Err(WireError::BadTag(2)));
        // A lying Result length cannot over-allocate: the byte slice is
        // bounds-checked before the copy.
        let mut lying = Ctrl::Result { job: 0, bytes: vec![1] }.to_body();
        let len_at = 1 + 8; // tag, job
        lying[len_at..len_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Ctrl::decode(&lying), Err(WireError::Truncated));
        // Same for a lying Submit bag length (spec "x" is 1 byte).
        let mut lying = Ctrl::Submit { job: 0, spec: "x".into(), bag: vec![1] }.to_body();
        let len_at = 1 + 8 + 4 + 1; // tag, job, spec len, spec bytes
        lying[len_at..len_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(Ctrl::decode(&lying), Err(WireError::Truncated));
    }

    #[test]
    fn f64_vectors_roundtrip_bit_exact() {
        let vals = vec![0.0f64, -0.0, 1.5, f64::MAX, f64::MIN_POSITIVE, 1.0 / 3.0];
        let mut out = Vec::new();
        vals.encode(&mut out);
        let mut r = Reader::new(&out);
        let back = Vec::<f64>::decode(&mut r).expect("decode");
        assert_eq!(r.remaining(), 0);
        assert_eq!(back.len(), vals.len());
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact round-trip");
        }
        // Truncated vector errors (the count word promises 6 elements, so
        // every strict prefix runs out of bytes).
        for cut in 0..out.len() {
            let mut r = Reader::new(&out[..cut]);
            assert_eq!(Vec::<f64>::decode(&mut r), Err(WireError::Truncated), "cut at {cut}");
        }
    }

    #[test]
    fn frame_io_roundtrip_and_clean_eof() {
        let msg = Msg::<Bag>::Steal { thief: 5, lifeline: true, nonce: 12 };
        let mut body = Vec::new();
        encode_msg_body(&msg, &mut body);
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &body).unwrap();
        write_frame(&mut pipe, &body).unwrap();
        let mut cursor = &pipe[..];
        for _ in 0..2 {
            let got = read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().expect("frame");
            assert_eq!(decode_msg_body::<Bag>(&got).unwrap(), msg);
        }
        assert!(read_frame(&mut cursor, MAX_FRAME_BYTES).unwrap().is_none(), "clean eof");
        // Mid-frame EOF is an error, not a clean shutdown.
        let mut partial = &pipe[..7];
        assert!(read_frame(&mut partial, MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn oversized_frame_is_rejected_without_alloc() {
        let mut pipe = Vec::new();
        pipe.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cursor = &pipe[..];
        assert!(read_frame(&mut cursor, MAX_FRAME_BYTES).is_err());
    }

    #[test]
    fn into_buffer_data_frames_match_the_allocating_path() {
        let msgs = [
            Msg::<Bag>::Steal { thief: 7, lifeline: false, nonce: 41 },
            Msg::<Bag>::Loot {
                victim: 9,
                bag: Some(ArrayListTaskBag::from_vec(vec![1u64, 2, 3])),
                lifeline: false,
                nonce: None,
                credit: 17,
            },
            Msg::<Bag>::Terminate,
        ];
        for msg in msgs {
            let old = frame(encode_data_frame_body(5, 13, &msg));
            let mut new = Vec::new();
            let body_len = encode_data_frame_into(5, 13, &msg, &mut new);
            assert_eq!(new, old, "{}", msg.kind());
            assert_eq!(body_len + FRAME_LEN_BYTES, old.len());
        }
    }

    #[test]
    fn into_buffer_frames_append_without_clobbering() {
        // Batched sends stack several frames in one buffer; each must
        // patch only its own length prefix.
        let mut buf = Vec::new();
        encode_ctrl_frame_into(&Ctrl::Deposit { job: 0, atoms: 3 }, &mut buf);
        let first = buf.clone();
        encode_ctrl_frame_into(&Ctrl::Grant { job: 0, atoms: 9 }, &mut buf);
        assert_eq!(&buf[..first.len()], &first[..]);
        assert_eq!(buf[first.len()..], frame(Ctrl::Grant { job: 0, atoms: 9 }.to_body()));
    }

    #[test]
    fn decode_slice_reports_consumed_bytes() {
        let mut out = Vec::new();
        42u64.encode(&mut out);
        7u32.encode(&mut out);
        let (a, used_a) = u64::decode_slice(&out).expect("u64");
        assert_eq!((a, used_a), (42, 8));
        let (b, used_b) = u32::decode_slice(&out[used_a..]).expect("u32");
        assert_eq!((b, used_b), (7, 4));
        assert_eq!(used_a + used_b, out.len());
    }

    #[test]
    fn buffer_pool_recycles_and_bounds() {
        let pool = BufferPool::new();
        let mut buf = pool.get();
        assert!(buf.is_empty());
        buf.extend_from_slice(&[1, 2, 3]);
        pool.put(buf);
        assert_eq!(pool.pooled(), 1);
        // The recycled buffer comes back cleared.
        assert!(pool.get().is_empty());
        assert_eq!(pool.pooled(), 0);
        // Oversized buffers are not retained.
        pool.put(Vec::with_capacity(POOL_KEEP_CAPACITY + 1));
        assert_eq!(pool.pooled(), 0);
        // put_arc only recycles the last holder.
        let shared = std::sync::Arc::new(vec![9u8; 4]);
        let retained = std::sync::Arc::clone(&shared);
        pool.put_arc(shared);
        assert_eq!(pool.pooled(), 0, "retained clone keeps the buffer out");
        pool.put_arc(retained);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn assembler_reassembles_frames_across_arbitrary_splits() {
        let bodies: Vec<Vec<u8>> = vec![
            Ctrl::Deposit { job: 0, atoms: 1 }.to_body(),
            Ctrl::Register { rank: 2, addr: "10.0.0.9:1234".into() }.to_body(),
            Vec::new(), // zero-length body is a legal frame
            Ctrl::Ack { rank: 1, result: vec![1; 60], acked: vec![(0, 2)] }.to_body(),
        ];
        let mut stream = Vec::new();
        for b in &bodies {
            stream.extend_from_slice(&frame(b.clone()));
        }
        // Split the byte stream at every position, including one byte at
        // a time, and require the identical frame sequence back.
        for split in 0..=stream.len() {
            let mut asm = FrameAssembler::new(MAX_FRAME_BYTES);
            let mut got: Vec<Vec<u8>> = Vec::new();
            for chunk in [&stream[..split], &stream[split..]] {
                for byte in chunk.chunks(1 + split % 3) {
                    asm.feed(byte);
                    while let Some(f) = asm.next_frame().expect("well-formed") {
                        got.push(f.to_vec());
                    }
                }
            }
            assert_eq!(got, bodies, "split at {split}");
            assert_eq!(asm.buffered(), 0);
        }
    }

    #[test]
    fn assembler_read_space_commit_path_matches_feed() {
        let body = Ctrl::Replenish { job: 0, want: 1 << 20 }.to_body();
        let bytes = frame(body.clone());
        let mut asm = FrameAssembler::new(MAX_FRAME_BYTES);
        let mut sent = 0;
        while sent < bytes.len() {
            let n = (bytes.len() - sent).min(3);
            let space = asm.read_space(n);
            space[..n].copy_from_slice(&bytes[sent..sent + n]);
            asm.commit(n);
            sent += n;
        }
        assert_eq!(asm.next_frame().unwrap(), Some(&body[..]));
        assert_eq!(asm.next_frame().unwrap(), None);
    }

    #[test]
    fn assembler_rejects_oversized_length_prefix() {
        let mut asm = FrameAssembler::new(64);
        asm.feed(&(65u32).to_le_bytes());
        assert!(asm.next_frame().is_err());
    }

    #[test]
    fn assembler_compacts_instead_of_growing_forever() {
        let body = vec![7u8; 100];
        let bytes = frame(body.clone());
        let mut asm = FrameAssembler::new(MAX_FRAME_BYTES);
        // Push far more traffic than the compaction threshold; the
        // staging buffer must stay bounded near one frame + threshold.
        for _ in 0..2000 {
            asm.feed(&bytes);
            assert_eq!(asm.next_frame().unwrap(), Some(&body[..]));
        }
        assert!(
            asm.buf.len() < ASSEMBLER_COMPACT_AT + 2 * bytes.len(),
            "staging buffer grew to {}",
            asm.buf.len()
        );
    }
}
