//! The `TaskQueue` abstraction (paper §2.3) — the user-provided sequential
//! computation plus the split/merge wrappers around its task bag.
//!
//! A `TaskQueue` lives on exactly one place. GLB calls:
//!
//! * [`TaskQueue::process`] repeatedly while work remains (paper: "It
//!   processes n items if available and returns true; otherwise it
//!   processes all available (< n) items and returns false");
//! * [`TaskQueue::split`] on steal victims and [`TaskQueue::merge`] on
//!   thieves;
//! * [`TaskQueue::result`] once, at termination, and folds the per-place
//!   results with the user's [`Reducer`].

use super::task_bag::TaskBag;

/// Outcome of one `process(n)` call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProcessOutcome {
    /// Whether tasks remain in the local bag (the paper's boolean).
    pub has_more: bool,
    /// Abstract work units completed by this call — UTS reports tree nodes
    /// counted, BC reports edges traversed. Used for throughput reporting
    /// and for virtual-time accounting in the simulator runtime.
    pub units: u64,
}

impl ProcessOutcome {
    pub fn new(has_more: bool, units: u64) -> Self {
        Self { has_more, units }
    }
}

/// User-provided sequential computation over a task bag.
pub trait TaskQueue: Send + 'static {
    /// The bag type work is moved in.
    type Bag: TaskBag;
    /// Per-place result type (paper: the `Z` of the reduction).
    type Result: Send + Clone + 'static;

    /// Process up to `n` task items.
    fn process(&mut self, n: usize) -> ProcessOutcome;

    /// Split off roughly half of the local bag for a thief, or `None` if
    /// there is too little work to share.
    fn split(&mut self) -> Option<Self::Bag>;

    /// Merge stolen loot into the local bag.
    fn merge(&mut self, bag: Self::Bag);

    /// Current local result (called after global quiescence).
    fn result(&self) -> Self::Result;

    /// Number of task items currently in the local bag.
    fn bag_size(&self) -> usize;
}

/// Commutative, associative reduction of per-place results (paper §2.1:
/// "the user supplied reduction operator is assumed to be associative and
/// commutative, [so] the result of execution of the problem is
/// determinate").
pub trait Reducer<R>: Send + Sync + 'static {
    fn identity(&self) -> R;
    fn reduce(&self, a: R, b: R) -> R;

    /// Fold a collection of per-place results.
    fn reduce_all<I: IntoIterator<Item = R>>(&self, results: I) -> R {
        results.into_iter().fold(self.identity(), |a, b| self.reduce(a, b))
    }
}

/// Reduction by closure pair — the common case.
pub struct FnReducer<R, F> {
    identity: R,
    f: F,
}

impl<R: Clone, F: Fn(R, R) -> R> FnReducer<R, F> {
    pub fn new(identity: R, f: F) -> Self {
        Self { identity, f }
    }
}

impl<R, F> Reducer<R> for FnReducer<R, F>
where
    R: Clone + Send + Sync + 'static,
    F: Fn(R, R) -> R + Send + Sync + 'static,
{
    fn identity(&self) -> R {
        self.identity.clone()
    }
    fn reduce(&self, a: R, b: R) -> R {
        (self.f)(a, b)
    }
}

/// Sum reduction for numeric results (UTS node counts, Fib).
#[derive(Debug, Clone, Copy, Default)]
pub struct SumReducer;

macro_rules! impl_sum_reducer {
    ($($t:ty),*) => {$(
        impl Reducer<$t> for SumReducer {
            fn identity(&self) -> $t { 0 as $t }
            fn reduce(&self, a: $t, b: $t) -> $t { a + b }
        }
    )*};
}
impl_sum_reducer!(u64, i64, f64);

/// Element-wise vector sum (BC betweenness maps).
#[derive(Debug, Clone, Copy, Default)]
pub struct VecSumReducer;

impl Reducer<Vec<f64>> for VecSumReducer {
    fn identity(&self) -> Vec<f64> {
        Vec::new()
    }
    fn reduce(&self, mut a: Vec<f64>, b: Vec<f64>) -> Vec<f64> {
        if a.is_empty() {
            return b;
        }
        if b.is_empty() {
            return a;
        }
        assert_eq!(a.len(), b.len(), "betweenness maps must agree in length");
        for (x, y) in a.iter_mut().zip(b) {
            *x += y;
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sum_reducer_folds() {
        let r = SumReducer;
        assert_eq!(r.reduce_all(vec![1u64, 2, 3, 4]), 10);
        assert_eq!(Reducer::<u64>::identity(&r), 0);
    }

    #[test]
    fn vec_sum_handles_identity_on_either_side() {
        let r = VecSumReducer;
        let a = vec![1.0, 2.0];
        assert_eq!(r.reduce(Vec::new(), a.clone()), a);
        assert_eq!(r.reduce(a.clone(), Vec::new()), a);
        assert_eq!(r.reduce(vec![1.0, 2.0], vec![10.0, 20.0]), vec![11.0, 22.0]);
    }

    #[test]
    #[should_panic(expected = "must agree in length")]
    fn vec_sum_rejects_mismatched_lengths() {
        VecSumReducer.reduce(vec![1.0], vec![1.0, 2.0]);
    }

    #[test]
    fn fn_reducer_works() {
        let r = FnReducer::new(1u64, |a, b| a * b);
        assert_eq!(r.reduce_all(vec![2, 3, 4]), 24);
    }

    #[test]
    fn reduce_all_order_independent_for_commutative_op() {
        let r = SumReducer;
        let mut xs = vec![5u64, 9, 1, 7];
        let a = r.reduce_all(xs.clone());
        xs.reverse();
        assert_eq!(a, r.reduce_all(xs));
    }
}
