//! GLB tuning parameters (paper §2.4).
//!
//! The paper exposes three user-facing knobs:
//!
//! * `n` — task granularity: how many task items a worker processes per
//!   `process(n)` call before probing its mailbox for steal requests.
//! * `w` — number of random-steal attempts per starvation episode.
//! * `z` — dimension of the lifeline hypercube. Together with the arity
//!   `l` this fixes the lifeline graph: places are digits of a base-`l`
//!   number with `z` digits and each place steals from / is fed by its
//!   `z` cyclic neighbours (see [`crate::glb::lifeline`]).
//!
//! Defaults follow the X10 GLB library that shipped with X10 2.4
//! (`GLBParameters.Default`): `n = 511`, `w = 1`, `l = 32`, with `z`
//! derived from the place count at startup.

/// Work-stealing policy. [`StealPolicy::Lifeline`] is the paper's
/// algorithm; [`StealPolicy::RandomOnly`] is the classic distributed
/// work-stealing comparator (random victims with retry rounds, no
/// lifelines) used by the ablation benches to quantify what lifelines
/// buy. Random-only workers that exhaust their rounds idle permanently —
/// correct (termination still detects quiescence) but they can never be
/// re-activated, which is precisely the deficiency lifelines fix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealPolicy {
    /// Two rounds: `w` random victims, then the lifeline hypercube.
    Lifeline,
    /// `rounds` rounds of `w` random victims each; no lifelines.
    RandomOnly { rounds: usize },
}

/// Tunable parameters for a GLB run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GlbParams {
    /// Task granularity: items per `process` call between mailbox probes.
    pub n: usize,
    /// Random-steal attempts per starvation episode.
    pub w: usize,
    /// Arity of the lifeline cube (`l` in the paper).
    pub l: usize,
    /// Lifeline cube dimension; `0` means "derive from the place count"
    /// (smallest `z` with `l^z >= P`).
    pub z: usize,
    /// Seed for the victim-selection RNGs (per-place streams are split off
    /// deterministically, so a run is reproducible given the seed).
    pub seed: u64,
    /// Minimum bag size a victim must hold before it will satisfy a steal
    /// (a bag of fewer than `2` items cannot be split by the default bag).
    pub steal_threshold: usize,
    /// Steal policy (lifeline vs random-only ablation).
    pub policy: StealPolicy,
    /// Hierarchical topology: how many workers share a node
    /// (see [`crate::glb::topology`]). `1` (the default) is the paper's
    /// flat layout — every place runs the full lifeline protocol. With
    /// `> 1`, workers on a node share work through a shared-memory node
    /// bag and only each node's representative runs the lifeline
    /// protocol, with the hypercube built over *nodes*.
    pub workers_per_node: usize,
}

impl Default for GlbParams {
    fn default() -> Self {
        Self {
            n: 511,
            w: 1,
            l: 32,
            z: 0,
            seed: 0x51F3_11FE,
            steal_threshold: 2,
            policy: StealPolicy::Lifeline,
            workers_per_node: 1,
        }
    }
}

impl GlbParams {
    /// Resolve the lifeline dimension for `p` places: the configured `z`
    /// if nonzero, else the smallest `z` such that `l^z >= p`.
    pub fn resolve_z(&self, p: usize) -> usize {
        if self.z != 0 {
            return self.z;
        }
        derive_z(p, self.l)
    }

    /// Builder-style setters (ergonomics for examples/benches).
    pub fn with_n(mut self, n: usize) -> Self {
        self.n = n.max(1);
        self
    }
    pub fn with_w(mut self, w: usize) -> Self {
        self.w = w;
        self
    }
    pub fn with_l(mut self, l: usize) -> Self {
        self.l = l.max(2);
        self
    }
    pub fn with_z(mut self, z: usize) -> Self {
        self.z = z;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_policy(mut self, policy: StealPolicy) -> Self {
        self.policy = policy;
        self
    }
    pub fn with_workers_per_node(mut self, workers_per_node: usize) -> Self {
        self.workers_per_node = workers_per_node.max(1);
        self
    }

    /// Total random-steal attempts per starvation episode under the
    /// configured policy.
    pub fn random_budget(&self) -> usize {
        match self.policy {
            StealPolicy::Lifeline => self.w,
            StealPolicy::RandomOnly { rounds } => self.w.max(1) * rounds.max(1),
        }
    }

    /// Validate parameter sanity; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if self.n == 0 {
            return Err("granularity n must be >= 1".into());
        }
        if self.l < 2 {
            return Err("lifeline arity l must be >= 2".into());
        }
        if self.workers_per_node == 0 {
            return Err("workers_per_node must be >= 1 (1 = flat topology)".into());
        }
        Ok(())
    }
}

/// Smallest `z` with `l^z >= p` (and `z >= 1`).
pub fn derive_z(p: usize, l: usize) -> usize {
    debug_assert!(l >= 2);
    let mut z = 1usize;
    let mut cap = l as u128;
    while cap < p as u128 {
        cap *= l as u128;
        z += 1;
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_x10_glb() {
        let p = GlbParams::default();
        assert_eq!(p.n, 511);
        assert_eq!(p.w, 1);
        assert_eq!(p.l, 32);
        assert_eq!(p.z, 0);
        assert_eq!(p.workers_per_node, 1, "flat topology by default");
    }

    #[test]
    fn derive_z_small_counts() {
        assert_eq!(derive_z(1, 2), 1);
        assert_eq!(derive_z(2, 2), 1);
        assert_eq!(derive_z(3, 2), 2);
        assert_eq!(derive_z(4, 2), 2);
        assert_eq!(derive_z(5, 2), 3);
        assert_eq!(derive_z(1024, 32), 2);
        assert_eq!(derive_z(1025, 32), 3);
        assert_eq!(derive_z(16384, 32), 3);
    }

    #[test]
    fn resolve_z_prefers_explicit() {
        let p = GlbParams::default().with_z(5);
        assert_eq!(p.resolve_z(4), 5);
        let q = GlbParams::default();
        assert_eq!(q.resolve_z(1024), 2);
    }

    #[test]
    fn validation() {
        assert!(GlbParams::default().validate().is_ok());
        assert!(GlbParams { n: 0, ..Default::default() }.validate().is_err());
        assert!(GlbParams { l: 1, ..Default::default() }.validate().is_err());
        assert!(GlbParams { workers_per_node: 0, ..Default::default() }.validate().is_err());
        assert!(GlbParams::default().with_workers_per_node(8).validate().is_ok());
    }

    #[test]
    fn builders_clamp() {
        assert_eq!(GlbParams::default().with_n(0).n, 1);
        assert_eq!(GlbParams::default().with_l(0).l, 2);
        assert_eq!(GlbParams::default().with_workers_per_node(0).workers_per_node, 1);
        assert_eq!(GlbParams::default().with_workers_per_node(16).workers_per_node, 16);
    }
}
