//! Distributed termination detection (paper §2.4 item 3).
//!
//! GLB terminates "when all Workers run out of work". The lifeline paper
//! piggybacks on X10's `finish`; we implement the equivalent *work-token*
//! ledger:
//!
//! * every place whose bag is non-empty holds one token;
//! * every loot message in flight holds one token (the victim increments
//!   the count **before** sending);
//! * a worker releases its token only after its bag is empty, its `w`
//!   random steals were refused, and it has registered with every
//!   lifeline buddy;
//! * a thief that receives loot while it still holds a token destroys the
//!   message token (decrement); an idle thief adopts it (no change).
//!
//! Invariant: the count is zero **iff** every bag is empty and no loot is
//! in flight — at that instant no message of any kind is in flight (steal
//! requests and refusals are only outstanding while their thief still
//! holds a token), so the detecting worker can broadcast `Terminate`
//! without racing anything. This is checked by the property tests.

use std::cell::Cell;
use std::rc::Rc;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Arc;

/// Abstract global token counter so the same worker engine runs under the
/// multi-threaded runtime (atomic) and the discrete-event simulator
/// (plain cell).
pub trait Ledger {
    /// Acquire one token.
    fn incr(&self);
    /// Release one token; `true` when the count reached zero (global
    /// quiescence observed by this caller, exactly once).
    fn decr(&self) -> bool;
    /// Current count (diagnostics, post-run assertions).
    fn value(&self) -> i64;
}

/// Thread-safe ledger for the thread runtime.
#[derive(Debug, Default)]
pub struct AtomicLedger {
    count: AtomicI64,
}

impl AtomicLedger {
    pub fn new() -> Arc<Self> {
        Arc::new(Self { count: AtomicI64::new(0) })
    }
}

impl Ledger for Arc<AtomicLedger> {
    fn incr(&self) {
        self.count.fetch_add(1, Ordering::AcqRel);
    }

    fn decr(&self) -> bool {
        let prev = self.count.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev >= 1, "token ledger underflow (prev={prev})");
        prev == 1
    }

    fn value(&self) -> i64 {
        self.count.load(Ordering::Acquire)
    }
}

/// Single-threaded ledger for the simulator runtime.
#[derive(Debug, Clone, Default)]
pub struct SimLedger {
    count: Rc<Cell<i64>>,
}

impl SimLedger {
    pub fn new() -> Self {
        Self { count: Rc::new(Cell::new(0)) }
    }
}

impl Ledger for SimLedger {
    fn incr(&self) {
        self.count.set(self.count.get() + 1);
    }

    fn decr(&self) -> bool {
        let v = self.count.get() - 1;
        debug_assert!(v >= 0, "token ledger underflow");
        self.count.set(v);
        v == 0
    }

    fn value(&self) -> i64 {
        self.count.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_ledger_detects_zero_once() {
        let l = AtomicLedger::new();
        l.incr();
        l.incr();
        assert_eq!(l.value(), 2);
        assert!(!l.decr());
        assert!(l.decr());
        assert_eq!(l.value(), 0);
    }

    #[test]
    fn sim_ledger_detects_zero() {
        let l = SimLedger::new();
        l.incr();
        assert!(!{
            l.incr();
            l.decr()
        });
        assert!(l.decr());
    }

    #[test]
    fn atomic_ledger_concurrent_balance() {
        let l = AtomicLedger::new();
        // Pre-charge so no thread transiently sees zero mid-run.
        l.incr();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        l.incr();
                        assert!(!l.decr(), "count must stay above zero while pre-charged");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(l.decr(), "final release must observe zero");
        assert_eq!(l.value(), 0);
    }
}
