//! Distributed termination detection (paper §2.4 item 3).
//!
//! GLB terminates "when all Workers run out of work". The lifeline paper
//! piggybacks on X10's `finish`; we implement the equivalent *work-token*
//! ledger:
//!
//! * every place whose bag is non-empty holds one token;
//! * every loot message in flight holds one token (the victim increments
//!   the count **before** sending);
//! * a worker releases its token only after its bag is empty, its `w`
//!   random steals were refused, and it has registered with every
//!   lifeline buddy;
//! * a thief that receives loot while it still holds a token destroys the
//!   message token (decrement); an idle thief adopts it (no change).
//!
//! Invariant: the count is zero **iff** every bag is empty and no loot is
//! in flight — at that instant no message of any kind is in flight (steal
//! requests and refusals are only outstanding while their thief still
//! holds a token), so the detecting worker can broadcast `Terminate`
//! without racing anything. This is checked by the property tests.
//!
//! ## Distributed detection: credit (weight) throwing
//!
//! A single shared counter is fine in one address space but serializes a
//! multi-process fleet on whoever hosts it. The socket runtime instead
//! uses Mattern-style *credit throwing*, split across three pieces:
//!
//! * [`CreditLedger`] — one per rank. `incr`/`decr` touch only a
//!   rank-local token count (no I/O); the rank additionally holds a pool
//!   of indivisible *credit atoms*. A loot message leaving the rank
//!   detaches atoms ([`Ledger::export_credit`]) that travel inside the
//!   message; the receiving rank absorbs them
//!   ([`Ledger::import_credit`]). When a rank's token count hits zero it
//!   deposits its whole pool to the root, asynchronously.
//! * [`CreditHome`] — how a ledger reaches the root: an async `deposit`
//!   plus a synchronous `replenish` for the pool-exhaustion case — the
//!   *only* synchronous credit operation, amortized over many
//!   cross-rank loot sends (see [`MAX_ATTACH_ATOMS`] for the honest
//!   worst-case cadence), never per steal/loot event.
//! * [`CreditRoot`] — the detector. Conservation is the whole proof:
//!   every atom ever minted is either recovered at the root, in some
//!   rank's pool, or attached to an in-flight message/deposit; a rank
//!   holding tokens always holds ≥ 1 atom, and a loot message in flight
//!   always carries ≥ 1 atom. So `recovered == total` **iff** no rank
//!   holds a token and no loot is in flight — global quiescence — and
//!   because replenishes grow `total` before the fresh atoms circulate,
//!   the root can never observe equality early. Detection is therefore
//!   asynchronous (the last deposit's arrival), and the root — not a
//!   worker — broadcasts `Terminate` via [`CreditRoot::on_quiescent`].
//!
//! Conservation under arbitrary message delay/reordering is checked by
//! `prop_credit_conserved_under_reorder` in `rust/tests/properties.rs`.
//!
//! On the socket fleet every credit movement (`Deposit`/`Replenish`/
//! `Grant` control frames, atoms riding loot messages) is queued on the
//! rank's I/O reactor and coalesced into batched `writev` sends with
//! whatever mesh traffic is pending ([`crate::place::reactor`]) — credit
//! traffic costs no extra syscalls or wakeups of its own. None of the
//! proofs above care: conservation is about *which* atoms exist, not
//! when frames flush, and the asynchronous deposit contract was already
//! "eventually arrives, in order per link".

use std::cell::Cell;
use std::rc::Rc;
use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Abstract global token counter so the same worker engine runs under the
/// multi-threaded runtime (atomic), the discrete-event simulator (plain
/// cell), and the socket fleet (rank-local credit ledger).
pub trait Ledger {
    /// Acquire one token.
    fn incr(&self);
    /// Release one token; `true` when the count reached zero (global
    /// quiescence observed by this caller, exactly once). Distributed
    /// ledgers always return `false` — their quiescence signal is the
    /// root detector's, not the caller's.
    fn decr(&self) -> bool;
    /// Current count (diagnostics, post-run assertions). For
    /// [`CreditLedger`] this is the *local* token count.
    fn value(&self) -> i64;

    /// Detach credit for a token leaving this ledger's domain attached to
    /// an outbound loot message. The caller must have acquired the
    /// message's token ([`Ledger::incr`]) first; the token count drops by
    /// one and the returned atoms travel with the message. Ledgers whose
    /// token count is already global ship no credit (`0`).
    fn export_credit(&self) -> u64 {
        0
    }

    /// Absorb the credit of an arriving loot message, accounting its
    /// token locally. The receiver then either destroys the token
    /// ([`Ledger::decr`], active thief) or adopts it (idle thief, no
    /// call) — exactly the flat protocol's choreography.
    fn import_credit(&self, _atoms: u64) {}
}

/// Thread-safe ledger for the thread runtime.
#[derive(Debug, Default)]
pub struct AtomicLedger {
    count: AtomicI64,
}

impl AtomicLedger {
    pub fn new() -> Arc<Self> {
        Arc::new(Self { count: AtomicI64::new(0) })
    }
}

impl Ledger for Arc<AtomicLedger> {
    fn incr(&self) {
        self.count.fetch_add(1, Ordering::AcqRel);
    }

    fn decr(&self) -> bool {
        let prev = self.count.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev >= 1, "token ledger underflow (prev={prev})");
        prev == 1
    }

    fn value(&self) -> i64 {
        self.count.load(Ordering::Acquire)
    }
}

/// Single-threaded ledger for the simulator runtime.
#[derive(Debug, Clone, Default)]
pub struct SimLedger {
    count: Rc<Cell<i64>>,
}

impl SimLedger {
    pub fn new() -> Self {
        Self { count: Rc::new(Cell::new(0)) }
    }
}

impl Ledger for SimLedger {
    fn incr(&self) {
        self.count.set(self.count.get() + 1);
    }

    fn decr(&self) -> bool {
        let v = self.count.get() - 1;
        debug_assert!(v >= 0, "token ledger underflow");
        self.count.set(v);
        v == 0
    }

    fn value(&self) -> i64 {
        self.count.get()
    }
}

// ---------------------------------------------------------------------
// credit-based distributed termination
// ---------------------------------------------------------------------

/// Atoms granted to every rank's pool at fleet start.
pub const INITIAL_RANK_ATOMS: u64 = 1 << 20;
/// Atoms minted per synchronous replenish (pool exhaustion fallback).
pub const REPLENISH_ATOMS: u64 = 1 << 20;
/// Cap on atoms attached to one loot message — enough for the receiver
/// to fan work out further without immediately replenishing, small
/// enough that one chatty rank cannot drain its pool in a few sends.
///
/// Worst-case replenish cadence, for honesty's sake: a rank that only
/// *exports* (never imports, never idles) halves its pool per send
/// under this cap, so a fresh [`REPLENISH_ATOMS`] pool sustains a few
/// dozen consecutive exports before one synchronous replenish; and
/// because an idle rank must deposit its *whole* pool (holding any
/// back would block detection), a freshly revived rank restarts from
/// whatever its reviving loot carried (≤ this cap). So the replenish
/// RPC is exhaustion-only and amortized over dozens-to-thousands of
/// cross-rank loot sends depending on traffic shape — not one per
/// steal/loot event like the old hub ledger, but also not vanishingly
/// rare on adversarial export-only schedules.
pub const MAX_ATTACH_ATOMS: u64 = 1 << 16;

/// A rank's channel back to the credit root.
pub trait CreditHome: Send + Sync {
    /// Asynchronously return `atoms` to the root (the rank went idle, or
    /// is topping the root up after an export emptied it).
    fn deposit(&self, atoms: u64);
    /// Synchronously obtain `want` freshly minted atoms. The root must
    /// grow its `total` **before** this returns, so a minted atom can
    /// never be outstanding without the root knowing it exists — the
    /// property that makes early detection impossible.
    fn replenish(&self, want: u64) -> u64;
}

#[derive(Debug)]
struct CreditState {
    /// Tokens held by this rank's workers, parked node-bag shards, and
    /// in-rank loot messages.
    tokens: i64,
    /// Credit atoms backing those tokens. Invariant: `pool >= 1` whenever
    /// `tokens >= 1`.
    pool: u64,
}

/// Rank-local work-token ledger with credit throwing (see module docs).
/// `incr`/`decr` are pure local mutations; the only I/O is the async
/// deposit when the rank goes idle and the rare synchronous replenish.
pub struct CreditLedger {
    state: Mutex<CreditState>,
    home: Arc<dyn CreditHome>,
}

impl CreditLedger {
    pub fn new(home: Arc<dyn CreditHome>, initial_atoms: u64) -> Arc<Self> {
        assert!(initial_atoms >= 1, "a rank needs at least one credit atom");
        Arc::new(Self { state: Mutex::new(CreditState { tokens: 0, pool: initial_atoms }), home })
    }

    /// Current local token count.
    pub fn tokens(&self) -> i64 {
        self.state.lock().unwrap().tokens
    }

    /// Current credit pool (diagnostics and the conservation property).
    pub fn pool(&self) -> u64 {
        self.state.lock().unwrap().pool
    }
}

impl Ledger for Arc<CreditLedger> {
    fn incr(&self) {
        let mut s = self.state.lock().unwrap();
        debug_assert!(s.pool >= 1, "token acquired on an empty credit pool");
        s.tokens += 1;
    }

    fn decr(&self) -> bool {
        let deposit = {
            let mut s = self.state.lock().unwrap();
            s.tokens -= 1;
            debug_assert!(s.tokens >= 0, "credit ledger token underflow");
            if s.tokens == 0 {
                std::mem::take(&mut s.pool)
            } else {
                0
            }
        };
        if deposit > 0 {
            self.home.deposit(deposit);
        }
        // Local zero is not global quiescence; the root detects.
        false
    }

    fn value(&self) -> i64 {
        self.tokens()
    }

    fn export_credit(&self) -> u64 {
        loop {
            let (attach, deposit) = {
                let mut s = self.state.lock().unwrap();
                debug_assert!(s.tokens >= 1, "export without a message token");
                // Keep one atom per invariant if tokens remain after the
                // message token leaves.
                let keep: u64 = if s.tokens > 1 { 1 } else { 0 };
                if s.pool < 1 + keep {
                    // Pool exhausted (needs ~REPLENISH_ATOMS exports
                    // between imports): mint more, synchronously, then
                    // retry under a fresh lock.
                    drop(s);
                    let got = self.home.replenish(REPLENISH_ATOMS);
                    assert!(got >= 1, "credit root must grant at least one atom");
                    self.state.lock().unwrap().pool += got;
                    continue;
                }
                s.tokens -= 1;
                let attach = (s.pool / 2).max(1).min(s.pool - keep).min(MAX_ATTACH_ATOMS);
                s.pool -= attach;
                let deposit = if s.tokens == 0 { std::mem::take(&mut s.pool) } else { 0 };
                (attach, deposit)
            };
            if deposit > 0 {
                self.home.deposit(deposit);
            }
            return attach;
        }
    }

    fn import_credit(&self, atoms: u64) {
        debug_assert!(atoms >= 1, "a credited loot message must carry atoms");
        let mut s = self.state.lock().unwrap();
        s.pool += atoms;
        s.tokens += 1;
    }
}

#[derive(Debug, Default)]
struct RootState {
    /// All atoms ever minted (initial grants + replenishes).
    total: u64,
    /// Atoms deposited back by idle ranks.
    recovered: u64,
    /// Detection enabled (set once the whole fleet has started; before
    /// that every rank still holds its unreturned initial grant anyway).
    armed: bool,
    /// Quiescence hook already fired.
    fired: bool,
}

/// The credit root: tracks minted vs recovered atoms and fires the
/// quiescence hook exactly once when they meet (see module docs for why
/// equality is exact and never early).
///
/// A root is bound to one *job epoch*: a resident fleet (`glb serve`)
/// builds a fresh root per submitted job, so atoms minted for one job
/// can never balance another job's books. One-shot runs use epoch 0.
#[derive(Default)]
pub struct CreditRoot {
    epoch: u64,
    state: Mutex<RootState>,
    on_quiescent: OnceLock<Box<dyn Fn() + Send + Sync>>,
}

impl CreditRoot {
    pub fn new() -> Arc<Self> {
        Self::for_epoch(0)
    }

    /// A fresh root for the given job epoch (see type docs).
    pub fn for_epoch(epoch: u64) -> Arc<Self> {
        Arc::new(Self { epoch, ..Self::default() })
    }

    /// The job epoch this root's books belong to.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Register the callback run (once, on whichever thread detects) when
    /// all credit has been recovered.
    pub fn on_quiescent(&self, hook: impl Fn() + Send + Sync + 'static) {
        if self.on_quiescent.set(Box::new(hook)).is_err() {
            panic!("quiescence hook already set");
        }
    }

    /// Record `atoms` handed out as a rank's initial pool.
    pub fn grant(&self, atoms: u64) {
        self.state.lock().unwrap().total += atoms;
    }

    /// Enable detection. Call after every rank holds its initial grant
    /// and before any rank can deposit. (Fires immediately in the
    /// degenerate case where everything was already recovered.)
    pub fn arm(&self) {
        let fire = {
            let mut s = self.state.lock().unwrap();
            s.armed = true;
            if !s.fired && s.recovered == s.total {
                s.fired = true;
                true
            } else {
                false
            }
        };
        if fire {
            if let Some(hook) = self.on_quiescent.get() {
                hook();
            }
        }
    }

    /// An idle rank returned `atoms`. May fire the quiescence hook.
    pub fn deposit(&self, atoms: u64) {
        let fire = {
            let mut s = self.state.lock().unwrap();
            s.recovered += atoms;
            assert!(
                s.recovered <= s.total,
                "credit over-recovered: {} of {}",
                s.recovered,
                s.total
            );
            if s.armed && !s.fired && s.recovered == s.total {
                s.fired = true;
                true
            } else {
                false
            }
        };
        if fire {
            if let Some(hook) = self.on_quiescent.get() {
                hook();
            }
        }
    }

    /// Mint `want` fresh atoms for a starved rank. `total` grows before
    /// the atoms are released to the caller, so detection stays exact.
    pub fn mint(&self, want: u64) -> u64 {
        let want = want.max(1);
        self.state.lock().unwrap().total += want;
        want
    }

    /// Reclaim the atoms that died with a crashed rank — its pool plus
    /// the credit of loot delivered to it but never re-exported, as
    /// solved from the survivors' [`crate::glb::wire::Ctrl::Reconcile`]
    /// books (`granted − deposited + Σsent − Σreceived`). Accounting-wise
    /// this is a deposit made on the dead rank's behalf: it may complete
    /// the recovery and fire the quiescence hook.
    pub fn reclaim(&self, atoms: u64) {
        self.deposit(atoms);
    }

    /// `(total, recovered)` — for assertions and the conservation tests.
    pub fn totals(&self) -> (u64, u64) {
        let s = self.state.lock().unwrap();
        (s.total, s.recovered)
    }

    /// Atoms still outstanding (in rank pools or attached to messages).
    pub fn outstanding(&self) -> u64 {
        let s = self.state.lock().unwrap();
        s.total - s.recovered
    }

    /// Has the quiescence hook fired?
    pub fn quiescent(&self) -> bool {
        self.state.lock().unwrap().fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_ledger_detects_zero_once() {
        let l = AtomicLedger::new();
        l.incr();
        l.incr();
        assert_eq!(l.value(), 2);
        assert!(!l.decr());
        assert!(l.decr());
        assert_eq!(l.value(), 0);
    }

    #[test]
    fn sim_ledger_detects_zero() {
        let l = SimLedger::new();
        l.incr();
        assert!(!{
            l.incr();
            l.decr()
        });
        assert!(l.decr());
    }

    /// Test home: deposits go straight to the root, replenishes mint.
    struct DirectHome(Arc<CreditRoot>);
    impl CreditHome for DirectHome {
        fn deposit(&self, atoms: u64) {
            self.0.deposit(atoms);
        }
        fn replenish(&self, want: u64) -> u64 {
            self.0.mint(want)
        }
    }

    fn rank(root: &Arc<CreditRoot>, atoms: u64) -> Arc<CreditLedger> {
        root.grant(atoms);
        CreditLedger::new(Arc::new(DirectHome(root.clone())), atoms)
    }

    #[test]
    fn credit_idle_rank_deposits_whole_pool() {
        let root = CreditRoot::new();
        let l = rank(&root, 100);
        root.arm();
        l.incr();
        l.incr();
        assert_eq!(l.value(), 2);
        assert!(!l.decr());
        assert!(!root.quiescent(), "a token is still held");
        assert!(!l.decr(), "distributed ledgers never observe zero locally");
        assert_eq!(l.pool(), 0, "idle rank returned everything");
        assert!(root.quiescent(), "root recovered all atoms");
    }

    #[test]
    fn credit_travels_with_loot_and_detection_waits_for_it() {
        let root = CreditRoot::new();
        let victim = rank(&root, 64);
        let thief = rank(&root, 64);
        root.arm();
        victim.incr(); // victim's own token
        victim.incr(); // the loot message's token
        let attached = victim.export_credit();
        assert!(attached >= 1);
        assert_eq!(victim.tokens(), 1);
        assert!(!victim.decr()); // victim finishes; pool (minus loot) deposited
        assert!(!root.quiescent(), "loot credit is still in flight");
        thief.import_credit(attached); // loot lands on an idle thief
        assert_eq!(thief.tokens(), 1);
        assert!(!thief.decr());
        assert!(root.quiescent(), "last deposit completes the recovery");
        let (total, recovered) = root.totals();
        assert_eq!(total, recovered);
        assert_eq!(total, 128, "no mint was needed");
    }

    #[test]
    fn credit_exhausted_pool_replenishes_and_total_grows_first() {
        let root = CreditRoot::new();
        let l = rank(&root, 1);
        root.arm();
        l.incr(); // worker token
        l.incr(); // loot token — pool of 1 cannot keep 1 AND attach 1
        let attached = l.export_credit();
        assert!(attached >= 1);
        assert!(l.pool() >= 1, "invariant: tokens held => pool non-empty");
        let (total, _) = root.totals();
        assert_eq!(total, 1 + REPLENISH_ATOMS, "mint grew total before the atoms moved");
        // Wind down: destroy the in-flight credit as an active import.
        l.import_credit(attached);
        assert!(!l.decr());
        assert!(!l.decr());
        assert!(root.quiescent());
    }

    #[test]
    fn credit_attach_is_capped_and_leaves_a_reserve() {
        let root = CreditRoot::new();
        let l = rank(&root, INITIAL_RANK_ATOMS);
        root.arm();
        l.incr();
        l.incr();
        let attached = l.export_credit();
        assert!(attached <= MAX_ATTACH_ATOMS);
        assert_eq!(l.pool(), INITIAL_RANK_ATOMS - attached);
        // Balance the books so the run quiesces.
        l.import_credit(attached);
        assert!(!l.decr());
        assert!(!l.decr());
        assert!(root.quiescent());
    }

    #[test]
    fn credit_roots_are_bound_to_their_job_epoch() {
        assert_eq!(CreditRoot::new().epoch(), 0, "one-shot runs are epoch 0");
        let root = CreditRoot::for_epoch(7);
        assert_eq!(root.epoch(), 7);
        // Epoch changes nothing about the books themselves.
        root.grant(3);
        root.arm();
        root.deposit(3);
        assert!(root.quiescent());
    }

    #[test]
    fn credit_root_never_fires_twice_or_early() {
        let root = CreditRoot::new();
        let fired = Arc::new(AtomicI64::new(0));
        let f = fired.clone();
        root.on_quiescent(move || {
            f.fetch_add(1, Ordering::SeqCst);
        });
        root.grant(10);
        root.arm();
        root.deposit(4);
        assert_eq!(fired.load(Ordering::SeqCst), 0, "partial recovery must not fire");
        root.deposit(6);
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // A later mint/deposit cycle cannot re-fire.
        let got = root.mint(5);
        root.deposit(got);
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn reclaim_recovers_a_dead_ranks_atoms() {
        // Rank death: the rank received its grant, deposited part of its
        // pool, exported some credit to a survivor, then crashed holding
        // the rest. The root reclaims exactly the dead balance and the
        // survivor's deposit completes detection.
        let root = CreditRoot::new();
        root.grant(100); // dead rank's grant
        let survivor = rank(&root, 50);
        root.arm();
        root.deposit(30); // dead rank deposited 30 while alive
        survivor.import_credit(20); // loot (20 atoms) from the dead rank landed
        // dead = granted(100) − deposited(30) − sent_to_survivor(20) = 50.
        root.reclaim(50);
        assert!(!root.quiescent(), "survivor still holds atoms");
        assert!(!survivor.decr(), "survivor idles, deposits 50 + 20");
        assert!(root.quiescent(), "books balance after the reclaim");
        let (total, recovered) = root.totals();
        assert_eq!(total, recovered);
        assert_eq!(total, 150);
    }

    #[test]
    fn atomic_ledger_concurrent_balance() {
        let l = AtomicLedger::new();
        // Pre-charge so no thread transiently sees zero mid-run.
        l.incr();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        l.incr();
                        assert!(!l.decr(), "count must stay above zero while pre-charged");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(l.decr(), "final release must observe zero");
        assert_eq!(l.value(), 0);
    }
}
