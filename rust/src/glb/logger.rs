//! Per-worker accounting (paper §2.4, "logging functionalities"):
//!
//! 1. how much time each worker spent *processing* and *distributing*
//!    work,
//! 2. how many (random/lifeline) stealing requests it sent and received,
//! 3. how many (random/lifeline) stealings it perpetrated (= successful
//!    steals), and
//! 4. how much workload (task items) it received/sent.

use crate::util::json::Value;
use crate::util::timefmt::{fmt_count, fmt_ns};

/// Counters and timers for one worker. Counts are updated by the protocol
/// engine; times are charged by the runtime (wall clock under threads,
/// virtual clock under the simulator).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerStats {
    /// Task items fully processed.
    pub items_processed: u64,
    /// Abstract work units (see `ProcessOutcome::units`).
    pub units: u64,
    /// `process(n)` chunk invocations.
    pub chunks: u64,
    /// Times this worker ran dry and entered the steal protocol.
    pub starvations: u64,
    /// Mid-run adaptive retunes applied ([`crate::glb::worker::Worker::try_retune`]);
    /// zero unless `--adapt` closed the telemetry loop.
    pub retunes: u64,

    /// ns spent inside `process`.
    pub process_ns: u64,
    /// ns spent splitting/sending loot to thieves.
    pub distribute_ns: u64,
    /// ns spent stealing / idling (everything that is not the two above).
    pub wait_ns: u64,

    /// Steal requests sent (attempts), by kind.
    pub random_steals_sent: u64,
    pub lifeline_steals_sent: u64,
    /// Steal requests received, by kind.
    pub random_steals_received: u64,
    pub lifeline_steals_received: u64,
    /// Successful steals perpetrated by this worker (loot actually merged),
    /// by kind of the request that produced it.
    pub random_steals_perpetrated: u64,
    pub lifeline_steals_perpetrated: u64,

    /// Task items shipped to and received from other places.
    pub loot_items_sent: u64,
    pub loot_items_received: u64,
    /// Loot messages (bags) sent/received.
    pub loot_bags_sent: u64,
    pub loot_bags_received: u64,

    /// Hierarchical topology ([`crate::glb::topology`]) counters — all
    /// zero under the flat layout.
    ///
    /// Shards parked in the shared node bag.
    pub node_donations: u64,
    /// Shards taken from the node bag (including shards a dry
    /// representative forwarded to remote thieves).
    pub node_takes: u64,
    /// Direct intra-node wake-up pushes sent to hungry local peers
    /// (also counted in `loot_bags_sent`).
    pub node_loot_sent: u64,
    /// Intra-node wake-up pushes received (also in `loot_bags_received`).
    pub node_loot_received: u64,
}

impl WorkerStats {
    /// Busy time = processing + distributing (the per-place "calculation
    /// time" bar of the paper's workload-distribution figures).
    pub fn busy_ns(&self) -> u64 {
        self.process_ns + self.distribute_ns
    }

    /// Merge counters from another worker (for aggregate reports).
    pub fn merge(&mut self, o: &WorkerStats) {
        self.items_processed += o.items_processed;
        self.units += o.units;
        self.chunks += o.chunks;
        self.starvations += o.starvations;
        self.retunes += o.retunes;
        self.process_ns += o.process_ns;
        self.distribute_ns += o.distribute_ns;
        self.wait_ns += o.wait_ns;
        self.random_steals_sent += o.random_steals_sent;
        self.lifeline_steals_sent += o.lifeline_steals_sent;
        self.random_steals_received += o.random_steals_received;
        self.lifeline_steals_received += o.lifeline_steals_received;
        self.random_steals_perpetrated += o.random_steals_perpetrated;
        self.lifeline_steals_perpetrated += o.lifeline_steals_perpetrated;
        self.loot_items_sent += o.loot_items_sent;
        self.loot_items_received += o.loot_items_received;
        self.loot_bags_sent += o.loot_bags_sent;
        self.loot_bags_received += o.loot_bags_received;
        self.node_donations += o.node_donations;
        self.node_takes += o.node_takes;
        self.node_loot_sent += o.node_loot_sent;
        self.node_loot_received += o.node_loot_received;
    }

    /// One row of the `--log` table.
    pub fn row(&self, place: usize) -> String {
        format!(
            "{place:>5}  {:>12}  {:>10}  {:>10}  {:>6}/{:<6}  {:>6}/{:<6}  {:>6}/{:<6}  {:>10}/{:<10}",
            fmt_count(self.items_processed),
            fmt_ns(self.process_ns),
            fmt_ns(self.distribute_ns),
            self.random_steals_sent,
            self.lifeline_steals_sent,
            self.random_steals_received,
            self.lifeline_steals_received,
            self.random_steals_perpetrated,
            self.lifeline_steals_perpetrated,
            fmt_count(self.loot_items_sent),
            fmt_count(self.loot_items_received),
        )
    }

    /// The machine-readable form of this row, consumed by the fleet
    /// report aggregation ([`crate::launch`]). Every field is an exact
    /// [`Value::Int`] so counters survive the JSON round-trip
    /// bit-identically.
    pub fn to_json(&self) -> Value {
        let n = |v: u64| Value::Int(v as i64);
        Value::obj(vec![
            ("items_processed", n(self.items_processed)),
            ("units", n(self.units)),
            ("chunks", n(self.chunks)),
            ("starvations", n(self.starvations)),
            ("retunes", n(self.retunes)),
            ("process_ns", n(self.process_ns)),
            ("distribute_ns", n(self.distribute_ns)),
            ("wait_ns", n(self.wait_ns)),
            ("random_steals_sent", n(self.random_steals_sent)),
            ("lifeline_steals_sent", n(self.lifeline_steals_sent)),
            ("random_steals_received", n(self.random_steals_received)),
            ("lifeline_steals_received", n(self.lifeline_steals_received)),
            ("random_steals_perpetrated", n(self.random_steals_perpetrated)),
            ("lifeline_steals_perpetrated", n(self.lifeline_steals_perpetrated)),
            ("loot_items_sent", n(self.loot_items_sent)),
            ("loot_items_received", n(self.loot_items_received)),
            ("loot_bags_sent", n(self.loot_bags_sent)),
            ("loot_bags_received", n(self.loot_bags_received)),
            ("node_donations", n(self.node_donations)),
            ("node_takes", n(self.node_takes)),
            ("node_loot_sent", n(self.node_loot_sent)),
            ("node_loot_received", n(self.node_loot_received)),
        ])
    }

    /// Header matching [`WorkerStats::row`].
    pub fn header() -> String {
        format!(
            "{:>5}  {:>12}  {:>10}  {:>10}  {:^13}  {:^13}  {:^13}  {:^21}",
            "place",
            "items",
            "process",
            "distrib",
            "sent r/l",
            "recv r/l",
            "perp r/l",
            "loot items out/in"
        )
    }
}

/// Aggregate view over all places, printed by `glb ... --log`. With a
/// hierarchical topology the log also rolls the per-worker rows up into
/// per-node rows (the two-level view: intra-node sharing vs inter-node
/// stealing).
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub per_place: Vec<WorkerStats>,
    /// Workers per node of the run that produced this log (`1` = flat;
    /// `0` only via `Default` and treated as flat).
    pub workers_per_node: usize,
}

impl RunLog {
    pub fn new(per_place: Vec<WorkerStats>) -> Self {
        Self { per_place, workers_per_node: 1 }
    }

    /// [`RunLog::new`] tagged with the run's hierarchical topology.
    pub fn with_topology(per_place: Vec<WorkerStats>, workers_per_node: usize) -> Self {
        Self { per_place, workers_per_node: workers_per_node.max(1) }
    }

    pub fn total(&self) -> WorkerStats {
        let mut t = WorkerStats::default();
        for s in &self.per_place {
            t.merge(s);
        }
        t
    }

    /// Per-node rollup: consecutive chunks of `workers_per_node` workers
    /// merged into one row each (the last node may be ragged). Under the
    /// flat layout this is just `per_place`.
    pub fn per_node(&self) -> Vec<WorkerStats> {
        let wpn = self.workers_per_node.max(1);
        self.per_place
            .chunks(wpn)
            .map(|workers| {
                let mut t = WorkerStats::default();
                for s in workers {
                    t.merge(s);
                }
                t
            })
            .collect()
    }

    /// Per-place busy times in seconds (workload-distribution figures).
    pub fn busy_secs(&self) -> Vec<f64> {
        self.per_place.iter().map(|s| s.busy_ns() as f64 / 1e9).collect()
    }

    /// The machine-readable form of the whole log: per-place stats plus
    /// the merged totals (so consumers need not re-sum).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("workers_per_node", Value::Int(self.workers_per_node.max(1) as i64)),
            ("totals", self.total().to_json()),
            ("per_place", Value::Arr(self.per_place.iter().map(WorkerStats::to_json).collect())),
        ])
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&WorkerStats::header());
        out.push('\n');
        for (i, s) in self.per_place.iter().enumerate() {
            out.push_str(&s.row(i));
            out.push('\n');
        }
        let t = self.total();
        out.push_str(&format!(
            "total  items={} units={} starvations={} loot_bags={}/{}\n",
            fmt_count(t.items_processed),
            fmt_count(t.units),
            t.starvations,
            t.loot_bags_sent,
            t.loot_bags_received,
        ));
        if self.workers_per_node > 1 {
            out.push_str(&format!(
                "-- per-node rollup (workers_per_node={}; \"place\" column = node id) --\n",
                self.workers_per_node
            ));
            out.push_str(&WorkerStats::header());
            out.push('\n');
            for (node, s) in self.per_node().iter().enumerate() {
                out.push_str(&s.row(node));
                out.push('\n');
            }
            out.push_str(&format!(
                "node-bag  donations={} takes={} local pushes={}/{}\n",
                t.node_donations, t.node_takes, t.node_loot_sent, t.node_loot_received,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = WorkerStats { items_processed: 3, process_ns: 100, ..Default::default() };
        let b = WorkerStats { items_processed: 4, process_ns: 50, loot_items_sent: 7, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.items_processed, 7);
        assert_eq!(a.process_ns, 150);
        assert_eq!(a.loot_items_sent, 7);
    }

    #[test]
    fn busy_is_process_plus_distribute() {
        let s = WorkerStats { process_ns: 70, distribute_ns: 30, wait_ns: 1000, ..Default::default() };
        assert_eq!(s.busy_ns(), 100);
    }

    #[test]
    fn render_contains_totals() {
        let log = RunLog::new(vec![
            WorkerStats { items_processed: 5, ..Default::default() },
            WorkerStats { items_processed: 6, ..Default::default() },
        ]);
        let text = log.render();
        assert!(text.contains("items=11"), "{text}");
        assert_eq!(log.busy_secs().len(), 2);
    }

    #[test]
    fn per_node_rollup_merges_worker_chunks() {
        let stats = |items| WorkerStats { items_processed: items, ..Default::default() };
        let log = RunLog::with_topology(vec![stats(1), stats(2), stats(4), stats(8), stats(16)], 2);
        let nodes = log.per_node();
        assert_eq!(nodes.len(), 3, "5 workers at 2/node = 3 nodes (last ragged)");
        assert_eq!(nodes[0].items_processed, 3);
        assert_eq!(nodes[1].items_processed, 12);
        assert_eq!(nodes[2].items_processed, 16);
        let text = log.render();
        assert!(text.contains("per-node rollup"), "{text}");
    }

    #[test]
    fn flat_log_has_no_rollup_section() {
        let log = RunLog::new(vec![WorkerStats::default()]);
        assert!(!log.render().contains("per-node rollup"));
        assert_eq!(log.per_node().len(), 1);
    }

    #[test]
    fn json_emit_roundtrips_counters_exactly() {
        let log = RunLog::with_topology(
            vec![
                WorkerStats { items_processed: 5, loot_bags_sent: 2, ..Default::default() },
                WorkerStats { items_processed: 6, node_takes: 3, ..Default::default() },
            ],
            2,
        );
        let v = Value::parse(&log.to_json().render()).unwrap();
        assert_eq!(v.get("workers_per_node").and_then(Value::as_u64), Some(2));
        let totals = v.get("totals").expect("totals");
        assert_eq!(totals.get("items_processed").and_then(Value::as_u64), Some(11));
        assert_eq!(totals.get("node_takes").and_then(Value::as_u64), Some(3));
        let per_place = v.get("per_place").and_then(Value::as_arr).expect("per_place");
        assert_eq!(per_place.len(), 2);
        assert_eq!(per_place[1].get("items_processed").and_then(Value::as_u64), Some(6));
    }

    #[test]
    fn merge_includes_node_counters() {
        let mut a = WorkerStats { node_donations: 1, node_takes: 2, ..Default::default() };
        let b = WorkerStats { node_donations: 3, node_loot_sent: 5, node_loot_received: 7, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.node_donations, 4);
        assert_eq!(a.node_takes, 2);
        assert_eq!(a.node_loot_sent, 5);
        assert_eq!(a.node_loot_received, 7);
    }
}
