//! Per-worker accounting (paper §2.4, "logging functionalities"):
//!
//! 1. how much time each worker spent *processing* and *distributing*
//!    work,
//! 2. how many (random/lifeline) stealing requests it sent and received,
//! 3. how many (random/lifeline) stealings it perpetrated (= successful
//!    steals), and
//! 4. how much workload (task items) it received/sent.

use crate::util::timefmt::{fmt_count, fmt_ns};

/// Counters and timers for one worker. Counts are updated by the protocol
/// engine; times are charged by the runtime (wall clock under threads,
/// virtual clock under the simulator).
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkerStats {
    /// Task items fully processed.
    pub items_processed: u64,
    /// Abstract work units (see `ProcessOutcome::units`).
    pub units: u64,
    /// `process(n)` chunk invocations.
    pub chunks: u64,
    /// Times this worker ran dry and entered the steal protocol.
    pub starvations: u64,

    /// ns spent inside `process`.
    pub process_ns: u64,
    /// ns spent splitting/sending loot to thieves.
    pub distribute_ns: u64,
    /// ns spent stealing / idling (everything that is not the two above).
    pub wait_ns: u64,

    /// Steal requests sent (attempts), by kind.
    pub random_steals_sent: u64,
    pub lifeline_steals_sent: u64,
    /// Steal requests received, by kind.
    pub random_steals_received: u64,
    pub lifeline_steals_received: u64,
    /// Successful steals perpetrated by this worker (loot actually merged),
    /// by kind of the request that produced it.
    pub random_steals_perpetrated: u64,
    pub lifeline_steals_perpetrated: u64,

    /// Task items shipped to and received from other places.
    pub loot_items_sent: u64,
    pub loot_items_received: u64,
    /// Loot messages (bags) sent/received.
    pub loot_bags_sent: u64,
    pub loot_bags_received: u64,
}

impl WorkerStats {
    /// Busy time = processing + distributing (the per-place "calculation
    /// time" bar of the paper's workload-distribution figures).
    pub fn busy_ns(&self) -> u64 {
        self.process_ns + self.distribute_ns
    }

    /// Merge counters from another worker (for aggregate reports).
    pub fn merge(&mut self, o: &WorkerStats) {
        self.items_processed += o.items_processed;
        self.units += o.units;
        self.chunks += o.chunks;
        self.starvations += o.starvations;
        self.process_ns += o.process_ns;
        self.distribute_ns += o.distribute_ns;
        self.wait_ns += o.wait_ns;
        self.random_steals_sent += o.random_steals_sent;
        self.lifeline_steals_sent += o.lifeline_steals_sent;
        self.random_steals_received += o.random_steals_received;
        self.lifeline_steals_received += o.lifeline_steals_received;
        self.random_steals_perpetrated += o.random_steals_perpetrated;
        self.lifeline_steals_perpetrated += o.lifeline_steals_perpetrated;
        self.loot_items_sent += o.loot_items_sent;
        self.loot_items_received += o.loot_items_received;
        self.loot_bags_sent += o.loot_bags_sent;
        self.loot_bags_received += o.loot_bags_received;
    }

    /// One row of the `--log` table.
    pub fn row(&self, place: usize) -> String {
        format!(
            "{place:>5}  {:>12}  {:>10}  {:>10}  {:>6}/{:<6}  {:>6}/{:<6}  {:>6}/{:<6}  {:>10}/{:<10}",
            fmt_count(self.items_processed),
            fmt_ns(self.process_ns),
            fmt_ns(self.distribute_ns),
            self.random_steals_sent,
            self.lifeline_steals_sent,
            self.random_steals_received,
            self.lifeline_steals_received,
            self.random_steals_perpetrated,
            self.lifeline_steals_perpetrated,
            fmt_count(self.loot_items_sent),
            fmt_count(self.loot_items_received),
        )
    }

    /// Header matching [`WorkerStats::row`].
    pub fn header() -> String {
        format!(
            "{:>5}  {:>12}  {:>10}  {:>10}  {:^13}  {:^13}  {:^13}  {:^21}",
            "place",
            "items",
            "process",
            "distrib",
            "sent r/l",
            "recv r/l",
            "perp r/l",
            "loot items out/in"
        )
    }
}

/// Aggregate view over all places, printed by `glb ... --log`.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub per_place: Vec<WorkerStats>,
}

impl RunLog {
    pub fn new(per_place: Vec<WorkerStats>) -> Self {
        Self { per_place }
    }

    pub fn total(&self) -> WorkerStats {
        let mut t = WorkerStats::default();
        for s in &self.per_place {
            t.merge(s);
        }
        t
    }

    /// Per-place busy times in seconds (workload-distribution figures).
    pub fn busy_secs(&self) -> Vec<f64> {
        self.per_place.iter().map(|s| s.busy_ns() as f64 / 1e9).collect()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&WorkerStats::header());
        out.push('\n');
        for (i, s) in self.per_place.iter().enumerate() {
            out.push_str(&s.row(i));
            out.push('\n');
        }
        let t = self.total();
        out.push_str(&format!(
            "total  items={} units={} starvations={} loot_bags={}/{}\n",
            fmt_count(t.items_processed),
            fmt_count(t.units),
            t.starvations,
            t.loot_bags_sent,
            t.loot_bags_received,
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = WorkerStats { items_processed: 3, process_ns: 100, ..Default::default() };
        let b = WorkerStats { items_processed: 4, process_ns: 50, loot_items_sent: 7, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.items_processed, 7);
        assert_eq!(a.process_ns, 150);
        assert_eq!(a.loot_items_sent, 7);
    }

    #[test]
    fn busy_is_process_plus_distribute() {
        let s = WorkerStats { process_ns: 70, distribute_ns: 30, wait_ns: 1000, ..Default::default() };
        assert_eq!(s.busy_ns(), 100);
    }

    #[test]
    fn render_contains_totals() {
        let log = RunLog::new(vec![
            WorkerStats { items_processed: 5, ..Default::default() },
            WorkerStats { items_processed: 6, ..Default::default() },
        ]);
        let text = log.render();
        assert!(text.contains("items=11"), "{text}");
        assert_eq!(log.busy_secs().len(), 2);
    }
}
