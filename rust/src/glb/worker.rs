//! The GLB worker protocol engine (paper §2.4), extended with the
//! hierarchical topology layer ([`crate::glb::topology`]).
//!
//! A [`Worker`] is a pure state machine: it never blocks, sleeps, or sends
//! anything itself — it emits [`Effect`]s for its runtime to carry out.
//! Both the thread runtime ([`crate::place`]) and the discrete-event
//! simulator ([`crate::sim`]) drive the *same* engine, so every protocol
//! property validated under the deterministic simulator also holds for the
//! real concurrent execution, modulo message interleavings — which the
//! thread-runtime stress tests cover.
//!
//! Lifecycle (paper §2.4 items 1–3):
//!
//! ```text
//!          ┌────────── merged loot ───────────────┐
//!          v                                      │
//!   Working ──bag empty──> WaitRandom(0..w) ──all refused──> WaitLifeline(0..z)
//!      │  ^                                                       │
//!      │  └── unsolicited lifeline push (reactivation) ── Idle <──┘ (all refused,
//!      │                                                   │       token released)
//!   respond to steals,                                Terminate
//!   distribute to recorded                                 │
//!   lifeline thieves                                      Done
//! ```
//!
//! With `workers_per_node > 1` the steal path is two-level. On
//! starvation a worker first *takes* a parked shard from its node's
//! shared-memory [`NodeBag`] (no messages); only the node's
//! representative then escalates to the original protocol above, run
//! over **node ids** (random victims and lifeline buddies are other
//! nodes' representatives). Non-representatives instead register as
//! *hungry* and idle until a local donor wakes them with a direct
//! intra-node loot push. With `workers_per_node = 1` (default) every
//! branch of the hierarchical path is dead and the engine is exactly the
//! paper's flat protocol.

use std::sync::Arc;

use super::lifeline::{LifelineGraph, VictimSelector};
use super::logger::WorkerStats;
use super::message::{Effect, Msg, PlaceId};
use super::params::GlbParams;
use super::task_bag::TaskBag;
use super::task_queue::TaskQueue;
use super::termination::Ledger;
use super::topology::{NodeBag, Topology};

/// What the worker is doing between runtime invocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Has (or believes it has) local work; runtime should keep calling
    /// [`Worker::step`].
    Working,
    /// Awaiting a response to random-steal attempt `attempt` from `victim`.
    WaitRandom { attempt: usize, victim: PlaceId },
    /// Awaiting a response to a lifeline steal from `outgoing[idx]`.
    WaitLifeline { idx: usize },
    /// Out of work, token released, registered on all lifelines (and, on
    /// a shared node, in the node bag's hungry queue); waiting for a
    /// lifeline/local push or `Terminate`.
    Idle,
    /// Finished (observed or was told about global quiescence).
    Done,
}

/// Result of a [`Worker::step`] call, for runtime scheduling decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepOutcome {
    /// Work units completed in this chunk (virtual-time cost basis).
    pub units: u64,
    /// Task items processed in this chunk.
    pub items: u64,
    /// Whether the worker is still `Working` afterwards.
    pub more: bool,
}

/// The protocol engine for one place.
pub struct Worker<Q: TaskQueue, L: Ledger> {
    id: PlaceId,
    p: usize,
    params: GlbParams,
    queue: Q,
    phase: Phase,
    /// Whether this worker currently holds a work token.
    active: bool,
    /// Outgoing lifelines (representatives of the node-level buddies we
    /// steal from; empty for non-representatives).
    outgoing: Vec<PlaceId>,
    /// Incoming lifeline thieves that we refused and must feed later.
    /// Small (≤ z of the inverse graph), so a Vec beats a HashSet.
    recorded_thieves: Vec<PlaceId>,
    /// Random victim selection over *node ids* (flat: node id = place id).
    victims: VictimSelector,
    ledger: L,
    stats: WorkerStats,
    /// Set once this worker (alone, globally) observed quiescence.
    observed_quiescence: bool,
    /// Monotonic request id; the nonce of the next steal request.
    next_nonce: u64,
    /// Nonce of the in-flight request (`WaitRandom`/`WaitLifeline` only).
    outstanding: Option<u64>,
    /// Hierarchical topology (flat when `workers_per_node == 1`).
    topo: Topology,
    /// Cached topology facts for this worker.
    node: usize,
    nodes: usize,
    node_size: usize,
    is_rep: bool,
    /// The node's shared work exchange; `None` under the flat layout.
    node_bag: Option<Arc<NodeBag<Q::Bag>>>,
}

impl<Q: TaskQueue, L: Ledger> Worker<Q, L> {
    /// Create the worker for `id` of `p` places with no shared node bag
    /// (the flat layout, or a degraded hierarchical one — see
    /// [`Worker::with_node_bag`]). **Must** be called for every place
    /// before any worker is driven: construction acquires the initial
    /// work token for non-empty queues, and the termination invariant
    /// needs all initial tokens counted before the first steal.
    pub fn new(id: PlaceId, p: usize, params: GlbParams, queue: Q, ledger: L) -> Self {
        Self::with_node_bag(id, p, params, queue, ledger, None)
    }

    /// [`Worker::new`] with the node's shared [`NodeBag`]. Runtimes pass
    /// the same `Arc` to every worker of a node when
    /// `params.workers_per_node > 1`; without it the worker still builds
    /// its lifelines over nodes but cannot share work locally.
    pub fn with_node_bag(
        id: PlaceId,
        p: usize,
        params: GlbParams,
        queue: Q,
        ledger: L,
        node_bag: Option<Arc<NodeBag<Q::Bag>>>,
    ) -> Self {
        let topo = Topology::new(p, params.workers_per_node);
        let nodes = topo.nodes();
        let node = topo.node_of(id);
        let node_size = topo.node_size(node);
        let is_rep = topo.is_representative(id);
        let z = params.resolve_z(nodes);
        // The lifeline hypercube spans *nodes*; only representatives own
        // outgoing lifelines, pointed at the buddy nodes' representatives.
        // Flat layout: node id = place id, representative = identity — the
        // exact original graph.
        let outgoing: Vec<PlaceId> = if is_rep && nodes > 1 {
            LifelineGraph::new(node, nodes, params.l, z)
                .outgoing
                .iter()
                .map(|&buddy| topo.representative(buddy))
                .collect()
        } else {
            Vec::new()
        };
        let active = queue.bag_size() > 0;
        if active {
            ledger.incr();
        }
        let phase = if active { Phase::Working } else { Phase::Idle };
        // Note: an initially-empty worker starts Idle *without* having
        // registered on its lifelines — correct: lifeline registration is
        // only required before *releasing a token*, and this worker never
        // held one. It will be fed by random/lifeline thieves finding it
        // only if it acquires work; to receive work it must be discovered
        // as a *thief*, which happens on its first starvation — but it
        // starts starved. So: empty-start workers immediately run the
        // steal protocol when kicked by the runtime via `kick_if_empty`.
        Self {
            id,
            p,
            params,
            queue,
            phase,
            active,
            outgoing,
            recorded_thieves: Vec::new(),
            victims: VictimSelector::new(node, nodes, params.seed),
            ledger,
            stats: WorkerStats::default(),
            observed_quiescence: false,
            next_nonce: 0,
            outstanding: None,
            topo,
            node,
            nodes,
            node_size,
            is_rep,
            node_bag,
        }
    }

    pub fn id(&self) -> PlaceId {
        self.id
    }
    /// Total number of places in this run.
    pub fn places(&self) -> usize {
        self.p
    }
    /// This worker's node id.
    pub fn node(&self) -> usize {
        self.node
    }
    /// Whether this worker runs the inter-node lifeline protocol.
    pub fn is_representative(&self) -> bool {
        self.is_rep
    }
    /// Outgoing lifelines (empty for non-representatives).
    pub fn lifelines(&self) -> &[PlaceId] {
        &self.outgoing
    }
    pub fn phase(&self) -> Phase {
        self.phase
    }
    pub fn stats(&self) -> &WorkerStats {
        &self.stats
    }
    pub fn stats_mut(&mut self) -> &mut WorkerStats {
        &mut self.stats
    }
    pub fn queue(&self) -> &Q {
        &self.queue
    }
    pub fn into_parts(self) -> (Q, WorkerStats) {
        (self.queue, self.stats)
    }
    /// Did *this* worker observe the count hit zero? (exactly one does)
    pub fn observed_quiescence(&self) -> bool {
        self.observed_quiescence
    }

    /// Whether this worker shares a node bag with local peers (always
    /// false under the flat layout, so every hierarchical branch is dead
    /// there).
    fn node_shared(&self) -> bool {
        self.node_bag.is_some() && self.node_size > 1
    }

    /// Start the steal protocol for workers that begin with an empty bag
    /// (all places except the root under dynamic initialization). Runtimes
    /// call this exactly once, after all workers are constructed.
    pub fn kick_if_empty(&mut self, effects: &mut Vec<Effect<Q::Bag>>) {
        if self.phase == Phase::Idle && !self.active {
            // Enter stealing as if we had just starved; we hold no token,
            // so acquire one first (a stealing worker is "active" for
            // termination purposes only if it might still receive work
            // from an in-flight response... it cannot: it has sent
            // nothing. But the steal it is *about to send* needs the
            // usual accounting: thief holds a token while any response
            // is outstanding).
            self.active = true;
            self.ledger.incr();
            self.start_stealing(effects);
        }
    }

    /// Re-knit this worker's view of the fleet after a membership change
    /// (crash recovery): `members` are the sorted surviving *node* ids,
    /// this worker's node included. Outgoing lifelines are rebuilt over
    /// the survivors ([`LifelineGraph::over_members`]), random victims
    /// are drawn from survivors only, and recorded lifeline thieves at
    /// dead nodes are forgotten (their loot would go nowhere).
    ///
    /// Only call between protocol episodes — `Working` or `Idle`, never
    /// with a steal outstanding: `WaitLifeline` indexes into the old
    /// `outgoing` and the in-flight response still references the old
    /// victim. The socket runtime defers re-knits accordingly. An idle
    /// caller must follow up with [`Worker::kick_if_empty`]-style
    /// lifeline re-registration by its own means (the runtime re-pumps).
    pub fn rewire(&mut self, members: &[usize]) {
        debug_assert!(
            matches!(self.phase, Phase::Working | Phase::Idle | Phase::Done),
            "rewire mid-steal (phase {:?})",
            self.phase
        );
        debug_assert!(self.outstanding.is_none(), "rewire with a steal in flight");
        debug_assert!(members.contains(&self.node), "rewiring node must survive");
        let z = self.params.resolve_z(members.len());
        self.outgoing = if self.is_rep && members.len() > 1 {
            LifelineGraph::over_members(self.node, members, self.params.l, z)
                .outgoing
                .iter()
                .map(|&buddy| self.topo.representative(buddy))
                .collect()
        } else {
            Vec::new()
        };
        self.victims = VictimSelector::over_members(self.node, members, self.params.seed);
        let topo = self.topo;
        self.recorded_thieves.retain(|&t| members.contains(&topo.node_of(t)));
    }

    /// Adaptively retune task granularity `n` and lifeline arity `l`
    /// mid-run (the closed-loop half of the live-telemetry plane), then
    /// rebuild the lifeline cube and victim stream under the new arity.
    /// Lowering `l` raises the derived cube dimension, so a starving
    /// fleet gets *more* lifelines per node.
    ///
    /// Returns `false` without touching anything when the worker is not
    /// at a safe point — only `Working` with no steal in flight
    /// qualifies: `WaitRandom`/`WaitLifeline` have a response in flight
    /// that indexes the old graph, and an `Idle` worker is registered on
    /// its old lifelines (stale registrations at *other* nodes are
    /// harmless — an unsolicited push from an old buddy still merges —
    /// but our own registration set must stay consistent). Callers just
    /// retry at the next observation.
    pub fn try_retune(&mut self, l: usize, n: usize) -> bool {
        if self.phase != Phase::Working || self.outstanding.is_some() {
            return false;
        }
        self.params = self.params.with_l(l).with_n(n);
        let z = self.params.resolve_z(self.nodes);
        self.outgoing = if self.is_rep && self.nodes > 1 {
            LifelineGraph::new(self.node, self.nodes, self.params.l, z)
                .outgoing
                .iter()
                .map(|&buddy| self.topo.representative(buddy))
                .collect()
        } else {
            Vec::new()
        };
        self.victims = VictimSelector::new(self.node, self.nodes, self.params.seed);
        self.stats.retunes += 1;
        true
    }

    /// The worker's current tuning parameters (post-retune view).
    pub fn params(&self) -> &GlbParams {
        &self.params
    }

    /// One processing chunk (paper §2.4 item 1: "repeatedly calls
    /// process(n) ... between each process(n) call, Worker probes the
    /// network"). The runtime is responsible for draining the mailbox
    /// into [`Worker::on_msg`] *before* each step.
    pub fn step(&mut self, effects: &mut Vec<Effect<Q::Bag>>) -> StepOutcome {
        debug_assert_eq!(self.phase, Phase::Working, "step() only while Working");
        // Feed recorded lifeline thieves *before* the chunk (X10 GLB's
        // `distribute()` runs between `process(n)` calls): a starving
        // buddy should not wait for our whole next chunk.
        self.distribute(effects);
        let before = self.queue.bag_size() as u64;
        let outcome = self.queue.process(self.params.n);
        let after = self.queue.bag_size() as u64;
        // Items processed is not simply n (expansion adds tasks); derive
        // conservatively for stats: consumed = before + spawned - after.
        // Applications report exact units; items is best-effort here.
        let items = (self.params.n as u64).min(before.max(1));
        self.stats.chunks += 1;
        self.stats.units += outcome.units;
        self.stats.items_processed += items.min(before + outcome.units);
        let _ = after;

        if !outcome.has_more {
            self.starve(effects);
        }
        StepOutcome { units: outcome.units, items, more: self.phase == Phase::Working }
    }

    /// Handle one incoming message. May be called in any phase.
    pub fn on_msg(&mut self, msg: Msg<Q::Bag>, effects: &mut Vec<Effect<Q::Bag>>) {
        match msg {
            Msg::Steal { thief, lifeline, nonce } => self.on_steal(thief, lifeline, nonce, effects),
            Msg::Loot { victim, bag, lifeline, nonce, credit } => {
                self.on_loot(victim, bag, lifeline, nonce, credit, effects)
            }
            Msg::Terminate => {
                debug_assert!(
                    !self.active,
                    "place {}: Terminate while holding a token (phase {:?})",
                    self.id, self.phase
                );
                self.phase = Phase::Done;
            }
        }
    }

    // ------------------------------------------------------------------
    // internals
    // ------------------------------------------------------------------

    fn on_steal(
        &mut self,
        thief: PlaceId,
        lifeline: bool,
        nonce: u64,
        effects: &mut Vec<Effect<Q::Bag>>,
    ) {
        if lifeline {
            self.stats.lifeline_steals_received += 1;
        } else {
            self.stats.random_steals_received += 1;
        }
        let loot = if self.queue.bag_size() >= self.params.steal_threshold {
            self.queue.split()
        } else {
            None
        };
        match loot {
            Some(bag) => {
                self.send_loot(thief, bag, lifeline, Some(nonce), effects);
            }
            None => {
                // A representative whose own queue is dry may still hold
                // node-level surplus: forward a parked shard so remote
                // thieves see the node's aggregate work.
                let shard = match &self.node_bag {
                    Some(nb) if self.node_size > 1 => nb.take(),
                    _ => None,
                };
                if let Some(bag) = shard {
                    self.stats.node_takes += 1;
                    // Token choreography: the loot token (send_loot's
                    // increment) must exist before the shard token dies,
                    // or an idle victim could transiently zero the ledger.
                    self.send_loot(thief, bag, lifeline, Some(nonce), effects);
                    let zero = self.ledger.decr();
                    debug_assert!(!zero, "the loot token was just acquired");
                } else {
                    if lifeline && !self.recorded_thieves.contains(&thief) {
                        self.recorded_thieves.push(thief);
                    }
                    effects.push(Effect::Send {
                        to: thief,
                        msg: Msg::Loot {
                            victim: self.id,
                            bag: None,
                            lifeline,
                            nonce: Some(nonce),
                            credit: 0,
                        },
                    });
                }
            }
        }
    }

    fn send_loot(
        &mut self,
        thief: PlaceId,
        bag: Q::Bag,
        lifeline: bool,
        nonce: Option<u64>,
        effects: &mut Vec<Effect<Q::Bag>>,
    ) {
        // The message token must exist before the send is visible; under
        // a credit ledger the token then leaves with the message as
        // attached credit (a no-op `0` for globally-counted ledgers).
        self.ledger.incr();
        let credit = self.ledger.export_credit();
        let items = bag.size() as u64;
        self.stats.loot_items_sent += items;
        self.stats.loot_bags_sent += 1;
        effects.push(Effect::Send {
            to: thief,
            msg: Msg::Loot { victim: self.id, bag: Some(bag), lifeline, nonce, credit },
        });
    }

    /// Push loot to recorded lifeline thieves and hungry local peers, and
    /// keep the node bag primed (called with surplus work). Pushes carry
    /// `nonce: None` — they answer no request.
    fn distribute(&mut self, effects: &mut Vec<Effect<Q::Bag>>) {
        while !self.recorded_thieves.is_empty()
            && self.queue.bag_size() >= self.params.steal_threshold
        {
            match self.queue.split() {
                Some(bag) => {
                    let thief = self.recorded_thieves.remove(0);
                    self.send_loot(thief, bag, true, None, effects);
                }
                None => break,
            }
        }
        if !self.node_shared() {
            return;
        }
        // Wake hungry local peers with direct intra-node pushes (cheap:
        // same-node messages never touch the NIC).
        while self.queue.bag_size() >= self.params.steal_threshold {
            let peer = match &self.node_bag {
                Some(nb) => nb.pop_hungry(self.id),
                None => None,
            };
            let Some(peer) = peer else { break };
            match self.queue.split() {
                Some(bag) => {
                    self.stats.node_loot_sent += 1;
                    self.send_loot(peer, bag, false, None, effects);
                }
                None => {
                    // The queue would not split after all: the peer is
                    // still hungry.
                    if let Some(nb) = &self.node_bag {
                        nb.unpop_hungry(peer);
                    }
                    break;
                }
            }
        }
        // Keep one shard parked so the next local starvation resolves in
        // shared memory, without any message at all.
        let parked = match &self.node_bag {
            Some(nb) => nb.shards(),
            None => 0,
        };
        if parked == 0 && self.queue.bag_size() >= 2 * self.params.steal_threshold.max(1) {
            if let Some(bag) = self.queue.split() {
                // The parked shard holds one work token, exactly like a
                // loot message in flight.
                self.ledger.incr();
                self.stats.node_donations += 1;
                if let Some(nb) = &self.node_bag {
                    nb.donate(bag);
                }
            }
        }
    }

    /// Bag ran dry: enter the steal protocol (or quiesce on 1 place).
    fn starve(&mut self, effects: &mut Vec<Effect<Q::Bag>>) {
        debug_assert!(self.active);
        self.stats.starvations += 1;
        self.start_stealing(effects);
    }

    fn start_stealing(&mut self, effects: &mut Vec<Effect<Q::Bag>>) {
        // Level 1: the shared-memory node bag (message-free).
        if self.take_from_node_bag() {
            return;
        }
        // Level 2: the inter-node protocol — representatives only. A
        // non-representative instead parks itself as hungry (inside
        // `release_token`) and waits for a local wake-up push.
        if !self.is_rep || self.nodes == 1 {
            self.release_token(effects);
            return;
        }
        if !self.try_random_steal(0, effects) && !self.try_lifeline_steal(0, effects) {
            self.release_token(effects);
        }
    }

    /// Try to resolve a starvation locally: merge one shard parked in the
    /// shared node bag. The shard's work token dies against the one we
    /// hold — the same accounting as loot reaching an active thief.
    fn take_from_node_bag(&mut self) -> bool {
        if !self.node_shared() {
            return false;
        }
        let shard = match &self.node_bag {
            Some(nb) => nb.take(),
            None => None,
        };
        let Some(bag) = shard else { return false };
        debug_assert!(self.active, "taking requires holding our own token");
        self.stats.node_takes += 1;
        self.queue.merge(bag);
        let zero = self.ledger.decr();
        debug_assert!(!zero, "count cannot reach zero while a worker holds a token");
        self.phase = Phase::Working;
        true
    }

    /// Send random-steal attempt `attempt` if budget remains (under
    /// `RandomOnly` the budget is `w × rounds`). Victims are *nodes*; the
    /// request goes to the victim node's representative. Returns whether
    /// a request was sent (phase updated).
    fn try_random_steal(&mut self, attempt: usize, effects: &mut Vec<Effect<Q::Bag>>) -> bool {
        if attempt >= self.params.random_budget() {
            return false;
        }
        match self.victims.pick() {
            Some(victim_node) => {
                let victim = self.topo.representative(victim_node);
                self.stats.random_steals_sent += 1;
                self.phase = Phase::WaitRandom { attempt, victim };
                let nonce = self.fresh_nonce();
                effects.push(Effect::Send {
                    to: victim,
                    msg: Msg::Steal { thief: self.id, lifeline: false, nonce },
                });
                true
            }
            None => false,
        }
    }

    /// Send lifeline-steal to `outgoing[idx]` if it exists (never under
    /// the `RandomOnly` ablation policy).
    fn try_lifeline_steal(&mut self, idx: usize, effects: &mut Vec<Effect<Q::Bag>>) -> bool {
        if matches!(self.params.policy, super::params::StealPolicy::RandomOnly { .. }) {
            return false;
        }
        if idx >= self.outgoing.len() {
            return false;
        }
        let victim = self.outgoing[idx];
        self.stats.lifeline_steals_sent += 1;
        self.phase = Phase::WaitLifeline { idx };
        let nonce = self.fresh_nonce();
        effects.push(Effect::Send {
            to: victim,
            msg: Msg::Steal { thief: self.id, lifeline: true, nonce },
        });
        true
    }

    fn fresh_nonce(&mut self) -> u64 {
        let n = self.next_nonce;
        self.next_nonce += 1;
        debug_assert!(self.outstanding.is_none(), "one in-flight request at a time");
        self.outstanding = Some(n);
        n
    }

    fn release_token(&mut self, effects: &mut Vec<Effect<Q::Bag>>) {
        debug_assert!(self.active);
        if self.node_shared() {
            // Local peers with surplus revive us via a direct push;
            // remote revival (representatives only) goes through the
            // lifelines registered above.
            if let Some(nb) = &self.node_bag {
                nb.register_hungry(self.id);
            }
        }
        self.active = false;
        self.phase = Phase::Idle;
        if self.ledger.decr() {
            self.observed_quiescence = true;
            self.phase = Phase::Done;
            effects.push(Effect::Quiescent);
        }
    }

    fn on_loot(
        &mut self,
        victim: PlaceId,
        bag: Option<Q::Bag>,
        lifeline: bool,
        nonce: Option<u64>,
        credit: u64,
        effects: &mut Vec<Effect<Q::Bag>>,
    ) {
        // Is this the response to our in-flight request? Unsolicited
        // lifeline/local pushes carry `nonce: None` and never match.
        let awaited = nonce.is_some() && nonce == self.outstanding;
        if awaited {
            self.outstanding = None;
            debug_assert!(
                matches!(self.phase, Phase::WaitRandom { .. } | Phase::WaitLifeline { .. }),
                "place {}: response while not waiting",
                self.id
            );
        }

        match bag {
            Some(bag) => {
                // Absorb the message's termination credit first: its token
                // is accounted locally before the bag is observable, then
                // either destroyed (active thief, `decr` below) or adopted
                // (idle thief) — the flat protocol's exact choreography.
                self.ledger.import_credit(credit);
                let items = bag.size() as u64;
                self.stats.loot_items_received += items;
                self.stats.loot_bags_received += 1;
                if self.topo.same_node(victim, self.id) {
                    // An intra-node wake-up push from a local donor
                    // (never solicited: steal requests only cross nodes).
                    debug_assert!(!awaited);
                    self.stats.node_loot_received += 1;
                } else if lifeline {
                    self.stats.lifeline_steals_perpetrated += 1;
                } else {
                    self.stats.random_steals_perpetrated += 1;
                }
                self.queue.merge(bag);
                if self.active {
                    // We still hold our token: the message token dies.
                    let zero = self.ledger.decr();
                    debug_assert!(!zero, "count cannot reach zero while a worker holds a token");
                } else {
                    // Idle thief adopts the message token.
                    debug_assert_eq!(self.phase, Phase::Idle);
                    self.active = true;
                }
                if awaited || self.phase == Phase::Idle {
                    self.phase = Phase::Working;
                }
                // If not awaited and not idle (an unsolicited push while we
                // wait on someone else), stay in the wait phase: the
                // outstanding response will arrive and `on_loot(None)`
                // below returns us to Working because the bag is non-empty.
            }
            None => {
                if !awaited {
                    // With nonce-matched responses this cannot happen:
                    // every request gets exactly one response and the
                    // thief never abandons an outstanding request.
                    debug_assert!(awaited, "place {}: refusal with stale nonce {nonce:?}", self.id);
                    return;
                }
                let _ = victim;
                if self.queue.bag_size() > 0 {
                    // Reactivated by an unsolicited push while waiting.
                    self.phase = Phase::Working;
                    return;
                }
                let advanced = match self.phase {
                    Phase::WaitRandom { attempt, .. } => {
                        self.try_random_steal(attempt + 1, effects)
                            || self.try_lifeline_steal(0, effects)
                    }
                    Phase::WaitLifeline { idx } => self.try_lifeline_steal(idx + 1, effects),
                    _ => unreachable!(),
                };
                if !advanced {
                    self.release_token(effects);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Single-worker protocol unit tests; multi-worker integration lives in
    //! `rust/tests/glb_integration.rs` and the deterministic simulator
    //! tests in `rust/tests/sim_integration.rs`.
    use super::*;
    use crate::glb::task_bag::ArrayListTaskBag;
    use crate::glb::task_queue::ProcessOutcome;
    use crate::glb::termination::SimLedger;

    /// Trivial queue: each task is `k` and processing it just counts it.
    struct CountQueue {
        bag: ArrayListTaskBag<u64>,
        counted: u64,
    }

    impl CountQueue {
        fn with(n: usize) -> Self {
            Self { bag: ArrayListTaskBag::from_vec((0..n as u64).collect()), counted: 0 }
        }
    }

    impl TaskQueue for CountQueue {
        type Bag = ArrayListTaskBag<u64>;
        type Result = u64;

        fn process(&mut self, n: usize) -> ProcessOutcome {
            let mut done = 0;
            while done < n {
                match self.bag.pop() {
                    Some(_) => {
                        self.counted += 1;
                        done += 1;
                    }
                    None => break,
                }
            }
            ProcessOutcome::new(self.bag.size() > 0, done as u64)
        }

        fn split(&mut self) -> Option<Self::Bag> {
            TaskBag::split(&mut self.bag)
        }
        fn merge(&mut self, bag: Self::Bag) {
            TaskBag::merge(&mut self.bag, bag);
        }
        fn result(&self) -> u64 {
            self.counted
        }
        fn bag_size(&self) -> usize {
            self.bag.size()
        }
    }

    fn params() -> GlbParams {
        GlbParams::default().with_n(4).with_w(1).with_l(2)
    }

    #[test]
    fn single_place_drains_and_quiesces() {
        let ledger = SimLedger::new();
        let mut w = Worker::new(0, 1, params(), CountQueue::with(10), ledger.clone());
        let mut fx = Vec::new();
        let mut steps = 0;
        while w.phase() == Phase::Working {
            w.step(&mut fx);
            steps += 1;
            assert!(steps < 100);
        }
        assert_eq!(w.phase(), Phase::Done);
        assert!(w.observed_quiescence());
        assert!(matches!(fx.last(), Some(Effect::Quiescent)));
        assert_eq!(w.queue().result(), 10);
        assert_eq!(ledger.value(), 0);
    }

    #[test]
    fn empty_worker_starts_idle_without_token() {
        let ledger = SimLedger::new();
        let w = Worker::new(1, 4, params(), CountQueue::with(0), ledger.clone());
        assert_eq!(w.phase(), Phase::Idle);
        assert_eq!(ledger.value(), 0);
    }

    #[test]
    fn kick_if_empty_starts_steal_protocol() {
        let ledger = SimLedger::new();
        ledger.incr(); // pretend some other place holds work
        let mut w = Worker::new(1, 4, params(), CountQueue::with(0), ledger.clone());
        let mut fx = Vec::new();
        w.kick_if_empty(&mut fx);
        assert!(matches!(w.phase(), Phase::WaitRandom { .. }));
        assert_eq!(fx.len(), 1);
        assert!(matches!(&fx[0], Effect::Send { msg: Msg::Steal { lifeline: false, .. }, .. }));
        assert_eq!(ledger.value(), 2, "stealing worker holds a token");
    }

    #[test]
    fn starving_worker_walks_random_then_lifelines_then_idles() {
        let ledger = SimLedger::new();
        ledger.incr(); // external work exists, so no quiescence here
        let mut w = Worker::new(0, 4, params().with_w(2), CountQueue::with(3), ledger.clone());
        let mut fx = Vec::new();
        // Drain the 3 tasks (n=4 per chunk): one step empties the bag and
        // fires the first random steal.
        w.step(&mut fx);
        let v1 = match w.phase() {
            Phase::WaitRandom { attempt: 0, victim } => victim,
            ph => panic!("expected WaitRandom(0), got {ph:?}"),
        };
        // Refusal 1 -> second random attempt.
        fx.clear();
        w.on_msg(
            Msg::Loot { victim: v1, bag: None, lifeline: false, nonce: Some(0), credit: 0 },
            &mut fx,
        );
        let v2 = match w.phase() {
            Phase::WaitRandom { attempt: 1, victim } => victim,
            ph => panic!("expected WaitRandom(1), got {ph:?}"),
        };
        // Refusal 2 -> first lifeline.
        fx.clear();
        w.on_msg(
            Msg::Loot { victim: v2, bag: None, lifeline: false, nonce: Some(1), credit: 0 },
            &mut fx,
        );
        assert!(matches!(w.phase(), Phase::WaitLifeline { idx: 0 }));
        let ll0 = match &fx[0] {
            Effect::Send { to, msg: Msg::Steal { lifeline: true, .. } } => *to,
            e => panic!("expected lifeline steal, got {e:?}"),
        };
        // Lifeline refusals until exhausted -> Idle with token released.
        let mut current = ll0;
        let mut nonce = 2u64; // requests 0,1 were the random attempts
        loop {
            fx.clear();
            w.on_msg(
                Msg::Loot {
                    victim: current,
                    bag: None,
                    lifeline: true,
                    nonce: Some(nonce),
                    credit: 0,
                },
                &mut fx,
            );
            nonce += 1;
            match w.phase() {
                Phase::WaitLifeline { idx } => {
                    assert!(idx < 4);
                    current = match &fx[0] {
                        Effect::Send { to, .. } => *to,
                        e => panic!("{e:?}"),
                    };
                }
                Phase::Idle => break,
                ph => panic!("unexpected {ph:?}"),
            }
        }
        assert_eq!(ledger.value(), 1, "worker released its token");
        assert_eq!(w.stats().random_steals_sent, 2);
        assert!(w.stats().lifeline_steals_sent >= 1);
    }

    #[test]
    fn victim_with_work_shares_and_charges_token() {
        let ledger = SimLedger::new();
        let mut w = Worker::new(0, 4, params(), CountQueue::with(100), ledger.clone());
        assert_eq!(ledger.value(), 1);
        let mut fx = Vec::new();
        w.on_msg(Msg::Steal { thief: 2, lifeline: false, nonce: 77 }, &mut fx);
        assert_eq!(ledger.value(), 2, "loot in flight holds a token");
        match &fx[0] {
            Effect::Send { to: 2, msg: Msg::Loot { bag: Some(b), lifeline: false, .. } } => {
                assert_eq!(b.size(), 50);
            }
            e => panic!("expected loot, got {e:?}"),
        }
        assert_eq!(w.stats().loot_items_sent, 50);
        assert_eq!(w.stats().random_steals_received, 1);
    }

    #[test]
    fn victim_without_work_records_lifeline_thief_and_feeds_later() {
        let ledger = SimLedger::new();
        let mut w = Worker::new(0, 4, params(), CountQueue::with(1), ledger.clone());
        let mut fx = Vec::new();
        // Lifeline steal arrives; bag has 1 item (< threshold 2): refuse+record.
        w.on_msg(Msg::Steal { thief: 3, lifeline: true, nonce: 78 }, &mut fx);
        assert!(matches!(
            &fx[0],
            Effect::Send { to: 3, msg: Msg::Loot { bag: None, lifeline: true, .. } }
        ));
        // Now loot arrives from elsewhere, giving surplus. (A real victim
        // increments the ledger before sending; simulate the in-flight
        // message token.)
        ledger.incr();
        fx.clear();
        w.on_msg(
            Msg::Loot {
                victim: 1,
                bag: Some(ArrayListTaskBag::from_vec(vec![7, 8, 9, 10])),
                lifeline: false,
                nonce: None,
                credit: 0,
            },
            &mut fx,
        );
        // Next step distributes to the recorded thief.
        fx.clear();
        w.step(&mut fx);
        let pushed = fx.iter().any(|e| {
            matches!(
                e,
                Effect::Send { to: 3, msg: Msg::Loot { bag: Some(_), lifeline: true, .. } }
            )
        });
        assert!(pushed, "recorded lifeline thief must be fed: {fx:?}");
    }

    #[test]
    fn random_refusal_is_not_recorded() {
        let ledger = SimLedger::new();
        let mut w = Worker::new(0, 4, params(), CountQueue::with(0), ledger.clone());
        let mut fx = Vec::new();
        w.on_msg(Msg::Steal { thief: 3, lifeline: false, nonce: 79 }, &mut fx);
        w.on_msg(
            Msg::Loot {
                victim: 1,
                bag: Some(ArrayListTaskBag::from_vec(vec![1, 2, 3, 4])),
                lifeline: true,
                nonce: None,
                credit: 0,
            },
            &mut fx,
        );
        fx.clear();
        w.step(&mut fx);
        let pushed = fx.iter().any(|e| matches!(e, Effect::Send { to: 3, .. }));
        assert!(!pushed, "random thieves are not remembered");
    }

    #[test]
    fn idle_worker_adopts_lifeline_loot_token() {
        let ledger = SimLedger::new();
        ledger.incr(); // the eventual victim's token
        let mut w = Worker::new(1, 2, params().with_w(0), CountQueue::with(0), ledger.clone());
        let mut fx = Vec::new();
        w.kick_if_empty(&mut fx);
        // w=0 so it goes straight to its lifeline (place 0).
        assert!(matches!(w.phase(), Phase::WaitLifeline { idx: 0 }));
        w.on_msg(
            Msg::Loot { victim: 0, bag: None, lifeline: true, nonce: Some(0), credit: 0 },
            &mut fx,
        );
        assert_eq!(w.phase(), Phase::Idle);
        assert_eq!(ledger.value(), 1, "thief token released; victim token still out");
        // Lifeline push arrives: adopt the message token, resume. (The
        // sender incremented the ledger before sending.)
        ledger.incr();
        w.on_msg(
            Msg::Loot {
                victim: 0,
                bag: Some(ArrayListTaskBag::from_vec(vec![1, 2])),
                lifeline: true,
                nonce: None,
                credit: 0,
            },
            &mut fx,
        );
        assert_eq!(w.phase(), Phase::Working);
        assert_eq!(ledger.value(), 2, "adopted token + victim token");
    }

    #[test]
    fn unsolicited_push_while_waiting_resumes_after_refusal() {
        let ledger = SimLedger::new();
        ledger.incr(); // external token so quiescence never fires here
        let mut w = Worker::new(0, 8, params().with_w(1), CountQueue::with(2), ledger.clone());
        let mut fx = Vec::new();
        w.step(&mut fx); // drains 2 tasks, enters WaitRandom
        let victim = match w.phase() {
            Phase::WaitRandom { victim, .. } => victim,
            ph => panic!("{ph:?}"),
        };
        // An old lifeline buddy pushes loot before the refusal arrives.
        w.on_msg(
            Msg::Loot {
                victim: 99,
                bag: Some(ArrayListTaskBag::from_vec(vec![5, 6, 7])),
                lifeline: true,
                nonce: None,
                credit: 0,
            },
            &mut fx,
        );
        assert!(matches!(w.phase(), Phase::WaitRandom { .. }), "still awaiting the response");
        // The awaited refusal now lands: back to Working (bag non-empty).
        w.on_msg(
            Msg::Loot { victim, bag: None, lifeline: false, nonce: Some(0), credit: 0 },
            &mut fx,
        );
        assert_eq!(w.phase(), Phase::Working);
    }

    #[test]
    fn rewire_drops_dead_lifelines_victims_and_thieves() {
        let ledger = SimLedger::new();
        ledger.incr(); // external work exists
        let mut w = Worker::new(0, 4, params(), CountQueue::with(0), ledger.clone());
        let mut fx = Vec::new();
        // A lifeline thief at the (about to die) place 2 gets recorded.
        w.on_msg(Msg::Steal { thief: 2, lifeline: true, nonce: 7 }, &mut fx);
        assert_eq!(w.lifelines(), &[1, 2], "bootstrap binary 2-cube from place 0");
        // Place 2 dies; survivors are {0, 1, 3}.
        w.rewire(&[0, 1, 3]);
        assert_eq!(w.lifelines(), &[1, 3], "re-knit cube spans survivors only");
        // Random victims only ever land on survivors.
        let mut sel_hits = std::collections::HashSet::new();
        for _ in 0..200 {
            // Starve-with-work cycle: hand the worker loot, drain it, and
            // watch where the random steal goes.
            fx.clear();
            ledger.incr();
            w.on_msg(
                Msg::Loot {
                    victim: 1,
                    bag: Some(ArrayListTaskBag::from_vec(vec![1])),
                    lifeline: false,
                    nonce: None,
                    credit: 0,
                },
                &mut fx,
            );
            fx.clear();
            w.step(&mut fx);
            let victim = match w.phase() {
                Phase::WaitRandom { victim, .. } => victim,
                ph => panic!("expected WaitRandom, got {ph:?}"),
            };
            assert_ne!(victim, 2, "dead place picked as random victim");
            sel_hits.insert(victim);
            // Refuse so the worker returns to a known state; then revive
            // it via the nonce-matched refusal path with a non-empty bag.
            let nonce = match &fx[0] {
                Effect::Send { msg: Msg::Steal { nonce, .. }, .. } => *nonce,
                e => panic!("{e:?}"),
            };
            fx.clear();
            ledger.incr();
            w.on_msg(
                Msg::Loot {
                    victim,
                    bag: Some(ArrayListTaskBag::from_vec(vec![9])),
                    lifeline: false,
                    nonce: Some(nonce),
                    credit: 0,
                },
                &mut fx,
            );
            assert_eq!(w.phase(), Phase::Working);
            fx.clear();
            w.step(&mut fx); // drain the single item; ends in WaitRandom again
            // Leave the worker back in Working for the next round.
            let (victim, nonce) = match (w.phase(), &fx[0]) {
                (
                    Phase::WaitRandom { victim, .. },
                    Effect::Send { msg: Msg::Steal { nonce, .. }, .. },
                ) => (victim, *nonce),
                (ph, e) => panic!("{ph:?} {e:?}"),
            };
            assert_ne!(victim, 2);
            sel_hits.insert(victim);
            fx.clear();
            ledger.incr();
            w.on_msg(
                Msg::Loot {
                    victim,
                    bag: Some(ArrayListTaskBag::from_vec(vec![3])),
                    lifeline: false,
                    nonce: Some(nonce),
                    credit: 0,
                },
                &mut fx,
            );
        }
        assert_eq!(
            sel_hits,
            std::collections::HashSet::from([1, 3]),
            "victims drawn from both survivors and only survivors"
        );
        // The recorded thief at the dead place was forgotten: surplus is
        // never pushed to place 2.
        fx.clear();
        ledger.incr();
        w.on_msg(
            Msg::Loot {
                victim: 1,
                bag: Some(ArrayListTaskBag::from_vec(vec![1, 2, 3, 4, 5, 6])),
                lifeline: false,
                nonce: None,
                credit: 0,
            },
            &mut fx,
        );
        fx.clear();
        w.step(&mut fx);
        assert!(
            !fx.iter().any(|e| matches!(e, Effect::Send { to: 2, .. })),
            "dead recorded thief must not be fed: {fx:?}"
        );
    }

    #[test]
    fn terminate_moves_to_done() {
        let ledger = SimLedger::new();
        let mut w = Worker::new(1, 4, params(), CountQueue::with(0), ledger);
        let mut fx = Vec::new();
        w.on_msg(Msg::Terminate, &mut fx);
        assert_eq!(w.phase(), Phase::Done);
        assert!(!w.observed_quiescence());
    }

    // ------------------------------------------------------------------
    // hierarchical topology
    // ------------------------------------------------------------------

    use crate::glb::topology::NodeBag;
    use std::sync::Arc;

    #[test]
    fn flat_worker_never_touches_node_bag() {
        let ledger = SimLedger::new();
        ledger.incr(); // external work exists
        let nb = Arc::new(NodeBag::new());
        let mut w =
            Worker::with_node_bag(0, 4, params(), CountQueue::with(3), ledger, Some(nb.clone()));
        let mut fx = Vec::new();
        w.step(&mut fx); // drains and enters the steal protocol
        assert!(matches!(w.phase(), Phase::WaitRandom { .. }));
        assert_eq!(nb.shards(), 0);
        assert_eq!(nb.hungry(), 0);
        assert_eq!(w.stats().node_takes + w.stats().node_donations, 0);
    }

    #[test]
    fn lifelines_span_nodes_and_only_reps_have_them() {
        // p = 8, wpn = 2 -> 4 nodes; l = 2, z = 2 is the binary 2-cube
        // over nodes, so node 1's buddies are nodes 0 and 3, i.e. the
        // representative workers 0 and 6.
        let hp = params().with_workers_per_node(2);
        let rep = Worker::new(2, 8, hp, CountQueue::with(1), SimLedger::new());
        assert!(rep.is_representative());
        assert_eq!(rep.node(), 1);
        assert_eq!(rep.lifelines(), &[0, 6]);
        let nonrep = Worker::new(3, 8, hp, CountQueue::with(1), SimLedger::new());
        assert!(!nonrep.is_representative());
        assert!(nonrep.lifelines().is_empty(), "non-reps never run the lifeline protocol");
    }

    #[test]
    fn non_rep_starves_locally_and_is_revived_by_push() {
        let ledger = SimLedger::new();
        ledger.incr(); // external work exists somewhere
        let nb = Arc::new(NodeBag::new());
        let hp = params().with_workers_per_node(4);
        let mut w =
            Worker::with_node_bag(1, 4, hp, CountQueue::with(0), ledger.clone(), Some(nb.clone()));
        let mut fx = Vec::new();
        w.kick_if_empty(&mut fx);
        assert_eq!(w.phase(), Phase::Idle);
        assert!(fx.is_empty(), "intra-node starvation sends no messages: {fx:?}");
        assert_eq!(nb.hungry(), 1);
        assert_eq!(ledger.value(), 1, "kick token released");
        // A local donor (worker 0, same node) pushes loot directly.
        ledger.incr(); // the donor's in-flight loot token
        w.on_msg(
            Msg::Loot {
                victim: 0,
                bag: Some(ArrayListTaskBag::from_vec(vec![1, 2])),
                lifeline: false,
                nonce: None,
                credit: 0,
            },
            &mut fx,
        );
        assert_eq!(w.phase(), Phase::Working);
        assert_eq!(w.stats().node_loot_received, 1);
        assert_eq!(ledger.value(), 2, "adopted the push token");
    }

    #[test]
    fn donor_feeds_hungry_peer_with_direct_push() {
        let ledger = SimLedger::new();
        let nb = Arc::new(NodeBag::new());
        let hp = params().with_workers_per_node(2);
        let mut w =
            Worker::with_node_bag(0, 2, hp, CountQueue::with(16), ledger, Some(nb.clone()));
        nb.register_hungry(1);
        let mut fx = Vec::new();
        w.step(&mut fx);
        let pushed = fx.iter().any(|e| {
            matches!(e, Effect::Send { to: 1, msg: Msg::Loot { bag: Some(_), nonce: None, .. } })
        });
        assert!(pushed, "hungry peer must be woken with loot: {fx:?}");
        assert_eq!(w.stats().node_loot_sent, 1);
        assert_eq!(nb.hungry(), 0);
    }

    #[test]
    fn surplus_parks_one_shard_and_starving_peer_takes_it_silently() {
        let ledger = SimLedger::new();
        let nb = Arc::new(NodeBag::new());
        let hp = params().with_workers_per_node(2);
        let mut a =
            Worker::with_node_bag(0, 2, hp, CountQueue::with(64), ledger.clone(), Some(nb.clone()));
        let mut fx = Vec::new();
        a.step(&mut fx);
        assert_eq!(nb.shards(), 1, "donor parks a shard for local takers");
        assert_eq!(a.stats().node_donations, 1);
        assert!(fx.is_empty(), "parking is message-free: {fx:?}");
        // Worker 1 starves: it takes the shard without sending anything.
        let mut b =
            Worker::with_node_bag(1, 2, hp, CountQueue::with(0), ledger.clone(), Some(nb.clone()));
        let mut fxb = Vec::new();
        b.kick_if_empty(&mut fxb);
        assert_eq!(b.phase(), Phase::Working);
        assert!(fxb.is_empty(), "intra-node takes are message-free: {fxb:?}");
        assert_eq!(nb.shards(), 0);
        assert_eq!(b.stats().node_takes, 1);
        assert_eq!(ledger.value(), 2, "a's token + b's token; the shard token died");
    }

    #[test]
    fn dry_rep_forwards_parked_shard_to_remote_thief() {
        let ledger = SimLedger::new();
        ledger.incr(); // the parked shard's token (a local peer donated it)
        let nb = Arc::new(NodeBag::new());
        nb.donate(ArrayListTaskBag::from_vec(vec![9, 9, 9, 9]));
        let hp = params().with_workers_per_node(2);
        // p = 4, wpn = 2: nodes {0,1} and {2,3}; worker 0 represents node 0.
        let mut w =
            Worker::with_node_bag(0, 4, hp, CountQueue::with(0), ledger.clone(), Some(nb.clone()));
        let mut fx = Vec::new();
        w.on_msg(Msg::Steal { thief: 2, lifeline: false, nonce: 5 }, &mut fx);
        match &fx[0] {
            Effect::Send { to: 2, msg: Msg::Loot { bag: Some(b), nonce: Some(5), .. } } => {
                assert_eq!(b.size(), 4, "the whole parked shard is forwarded");
            }
            e => panic!("expected forwarded loot, got {e:?}"),
        }
        assert_eq!(nb.shards(), 0);
        assert_eq!(w.stats().node_takes, 1);
        assert_eq!(ledger.value(), 1, "the shard token became the loot token");
    }

    #[test]
    fn rep_random_victims_are_other_nodes_representatives() {
        let ledger = SimLedger::new();
        ledger.incr();
        let hp = params().with_workers_per_node(4);
        // p = 16, wpn = 4 -> nodes 0..4 with representatives {0, 4, 8, 12}.
        let mut w = Worker::with_node_bag(0, 16, hp, CountQueue::with(2), ledger, None);
        let mut fx = Vec::new();
        w.step(&mut fx); // drains, starves, sends a random steal
        match w.phase() {
            Phase::WaitRandom { victim, .. } => {
                assert!(victim % 4 == 0 && victim != 0, "victim {victim} must be a remote rep");
            }
            ph => panic!("expected WaitRandom, got {ph:?}"),
        }
    }
}
