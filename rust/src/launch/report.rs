//! Machine-readable run reports: per-rank JSON lines, the aggregated
//! fleet report, and the `glb bench` perf-trajectory schema.
//!
//! Every rank of a launched fleet prints its [`crate::glb::RunLog`] (plus
//! result, wall time, and wire-byte totals) as one JSON line behind the
//! [`RANK_REPORT_MARKER`] when [`RANK_REPORT_ENV`] is set — the stdout
//! analogue of the paper's per-place accounting tables (§2.4), but in a
//! form CI can diff. The launcher folds those lines into a single fleet
//! report (`--report out.json`), and `glb bench` wraps repeated warmed
//! runs of pinned configs into `BENCH_glb.json`, which CI uploads and
//! diffs against `bench/baseline.json`.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::glb::RunLog;
use crate::place::NetStats;
use crate::util::json::Value;

/// Marker prefix of a rank's JSON report line on stdout.
pub const RANK_REPORT_MARKER: &str = "GLB-RANK-REPORT ";
/// Marker prefix of rank 0's per-interval live-telemetry JSON lines
/// (emitted by `--stats` runs; see `crate::place::socket`).
pub const LIVE_STATS_MARKER: &str = "GLB-LIVE-STATS ";
/// Marker prefix of a resident fleet's per-job JSON report lines
/// (emitted by rank 0 of a `glb serve` fleet after every job; see
/// `crate::place::service`).
pub const SERVE_REPORT_MARKER: &str = "GLB-SERVE-REPORT ";
/// Environment variable the launcher sets so ranks emit report lines.
pub const RANK_REPORT_ENV: &str = "GLB_RANK_REPORT";

pub const RANK_SCHEMA: &str = "glb-rank-report/v1";
pub const FLEET_SCHEMA: &str = "glb-fleet-report/v1";
pub const BENCH_SCHEMA: &str = "glb-bench/v1";
/// One job's report line from a resident fleet's rank 0.
pub const SERVE_JOB_SCHEMA: &str = "glb-serve-report/v1";
/// The aggregated document a launched `glb serve` fleet leaves behind.
pub const SERVE_FLEET_SCHEMA: &str = "glb-serve-fleet/v1";

/// Whether this process was asked (by a launcher parent) to emit its
/// rank report line.
pub fn rank_report_requested() -> bool {
    std::env::var(RANK_REPORT_ENV).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Build one rank's report. `rank_of` is `(rank, ranks)`; `result` is
/// the app's reduced value as JSON (exact [`Value::Int`] for counting
/// apps — the fleet/thread bit-identity check in CI depends on it).
/// `net` is the rank's reactor counter snapshot
/// ([`crate::place::net_stats`]; all-zero for thread/sim transports).
pub fn build_rank_report(
    app: &str,
    transport: &str,
    rank_of: (usize, usize),
    result: Value,
    elapsed_ns: u64,
    log: &RunLog,
    wire: (u64, u64),
    net: NetStats,
) -> Value {
    Value::obj(vec![
        ("schema", Value::Str(RANK_SCHEMA.into())),
        ("app", Value::Str(app.into())),
        ("transport", Value::Str(transport.into())),
        ("rank", Value::Int(rank_of.0 as i64)),
        ("ranks", Value::Int(rank_of.1 as i64)),
        ("places", Value::Int(log.per_place.len() as i64)),
        ("result", result),
        ("elapsed_ns", Value::Int(elapsed_ns as i64)),
        ("wall_time_s", Value::Float(elapsed_ns as f64 / 1e9)),
        ("wire_tx_bytes", Value::Int(wire.0 as i64)),
        ("wire_rx_bytes", Value::Int(wire.1 as i64)),
        ("frames_sent", Value::Int(net.frames_tx as i64)),
        ("frames_recv", Value::Int(net.frames_rx as i64)),
        ("batches", Value::Int(net.batches as i64)),
        ("steal_latency_us", Value::Float(net.steal_latency_us)),
        ("steal_samples", Value::Int(net.steal_samples as i64)),
        ("io_threads", Value::Int(net.io_threads as i64)),
        ("log", log.to_json()),
    ])
}

/// The stdout line for a rank report.
pub fn rank_report_line(report: &Value) -> String {
    format!("{RANK_REPORT_MARKER}{}", report.render())
}

/// The last rank-report line in a rank's captured stdout, if any.
pub fn find_rank_report(stdout: &[String]) -> Option<&String> {
    stdout.iter().rev().find(|l| l.starts_with(RANK_REPORT_MARKER))
}

/// Parse (and schema-check) one rank-report line.
pub fn parse_rank_report(line: &str) -> Result<Value> {
    let body = line
        .strip_prefix(RANK_REPORT_MARKER)
        .ok_or_else(|| anyhow!("not a rank report line: {line:?}"))?;
    let v = Value::parse(body).map_err(|e| anyhow!("rank report JSON: {e}"))?;
    match v.get("schema").and_then(Value::as_str) {
        Some(RANK_SCHEMA) => Ok(v),
        other => bail!("rank report schema {other:?} (expected {RANK_SCHEMA:?})"),
    }
}

/// Element-wise sum of two flat integer objects (the `RunLog` totals).
/// Keys missing on either side count as zero; key order follows `a`
/// with `b`-only keys appended.
fn sum_int_objects(a: &Value, b: &Value) -> Value {
    let empty: &[(String, Value)] = &[];
    let (pa, pb) = match (a, b) {
        (Value::Obj(pa), Value::Obj(pb)) => (pa.as_slice(), pb.as_slice()),
        (Value::Obj(pa), _) => (pa.as_slice(), empty),
        (_, Value::Obj(pb)) => (empty, pb.as_slice()),
        _ => (empty, empty),
    };
    let mut out: Vec<(String, Value)> = Vec::with_capacity(pa.len().max(pb.len()));
    for (k, va) in pa {
        let sum = va.as_i64().unwrap_or(0)
            + pb.iter().find(|(kb, _)| kb == k).and_then(|(_, vb)| vb.as_i64()).unwrap_or(0);
        out.push((k.clone(), Value::Int(sum)));
    }
    for (k, vb) in pb {
        if !pa.iter().any(|(ka, _)| ka == k) {
            out.push((k.clone(), Value::Int(vb.as_i64().unwrap_or(0))));
        }
    }
    Value::Obj(out)
}

/// Fold per-rank reports into the single fleet report the launcher
/// writes: rank 0's reduced result (with `run_sockets_reduced` that is
/// the fleet-wide value), summed counters and wire bytes, and the full
/// per-rank reports for drill-down. `dead_ranks` lists ranks whose
/// deaths the fleet absorbed (`--tolerate-failures`): they owe no
/// report, and the output records them under `"dead_ranks"`.
pub fn aggregate_fleet(
    app: &str,
    app_argv: &[String],
    mut rank_reports: Vec<Value>,
    wall_time_s: f64,
    dead_ranks: &[usize],
) -> Result<Value> {
    if rank_reports.is_empty() {
        bail!("no rank reports to aggregate");
    }
    for r in &rank_reports {
        if r.get("rank").and_then(Value::as_u64).is_none() {
            bail!("rank report lacks a numeric \"rank\" field");
        }
    }
    rank_reports.sort_by_key(|r| r.get("rank").and_then(Value::as_u64).unwrap_or(u64::MAX));
    let n = rank_reports.len() + dead_ranks.len();
    if dead_ranks.contains(&0) {
        bail!("rank 0 cannot be a tolerated death (it aggregates the fleet)");
    }
    let mut expected: Vec<usize> = (0..n).filter(|r| !dead_ranks.contains(r)).collect();
    expected.truncate(rank_reports.len());
    for (r, &want) in rank_reports.iter().zip(&expected) {
        let rank = r.get("rank").and_then(Value::as_u64).expect("checked above");
        if rank != want as u64 {
            bail!("fleet reports are not ranks 0..{n}: missing or duplicate rank {want}");
        }
    }
    let mut places = 0i64;
    let (mut tx, mut rx) = (0i64, 0i64);
    let (mut frames_tx, mut frames_rx, mut batches) = (0i64, 0i64, 0i64);
    let (mut lat_weighted_us, mut lat_samples) = (0.0f64, 0i64);
    let mut io_threads = 0i64;
    let mut totals = Value::Obj(Vec::new());
    for r in &rank_reports {
        places += r.get("places").and_then(Value::as_i64).unwrap_or(0);
        tx += r.get("wire_tx_bytes").and_then(Value::as_i64).unwrap_or(0);
        rx += r.get("wire_rx_bytes").and_then(Value::as_i64).unwrap_or(0);
        frames_tx += r.get("frames_sent").and_then(Value::as_i64).unwrap_or(0);
        frames_rx += r.get("frames_recv").and_then(Value::as_i64).unwrap_or(0);
        batches += r.get("batches").and_then(Value::as_i64).unwrap_or(0);
        let samples = r.get("steal_samples").and_then(Value::as_i64).unwrap_or(0);
        lat_samples += samples;
        lat_weighted_us += samples as f64
            * r.get("steal_latency_us").and_then(Value::as_f64).unwrap_or(0.0);
        io_threads += r.get("io_threads").and_then(Value::as_i64).unwrap_or(0);
        if let Some(t) = r.get("log").and_then(|l| l.get("totals")) {
            totals = sum_int_objects(&totals, t);
        }
    }
    // Sample-weighted fleet-wide mean steal round-trip.
    let steal_latency_us =
        if lat_samples > 0 { lat_weighted_us / lat_samples as f64 } else { 0.0 };
    let result = rank_reports[0].get("result").cloned().unwrap_or(Value::Null);
    Ok(Value::obj(vec![
        ("schema", Value::Str(FLEET_SCHEMA.into())),
        ("app", Value::Str(app.into())),
        ("argv", Value::Arr(app_argv.iter().map(|a| Value::Str(a.clone())).collect())),
        ("ranks", Value::Int(n as i64)),
        ("dead_ranks", Value::Arr(dead_ranks.iter().map(|&d| Value::Int(d as i64)).collect())),
        ("places", Value::Int(places)),
        ("wall_time_s", Value::Float(wall_time_s)),
        ("result", result),
        ("wire_tx_bytes", Value::Int(tx)),
        ("wire_rx_bytes", Value::Int(rx)),
        ("frames_sent", Value::Int(frames_tx)),
        ("frames_recv", Value::Int(frames_rx)),
        ("batches", Value::Int(batches)),
        ("steal_latency_us", Value::Float(steal_latency_us)),
        ("steal_samples", Value::Int(lat_samples)),
        ("io_threads", Value::Int(io_threads)),
        ("totals", totals),
        ("per_rank", Value::Arr(rank_reports)),
    ]))
}

/// Parse every live-stats marker line in rank 0's captured stdout, in
/// emission order — the `--stats` time series. An unparsable marker line
/// is an error (the emitter is ours; garbage means a real bug), but a
/// stream with no markers is just a run without `--stats`.
pub fn extract_live_stats(stdout: &[String]) -> Result<Vec<Value>> {
    stdout
        .iter()
        .filter_map(|l| l.strip_prefix(LIVE_STATS_MARKER))
        .map(|body| Value::parse(body).map_err(|e| anyhow!("live-stats line: {e}")))
        .collect()
}

/// Append the `--stats` time series to a fleet report under
/// `"live_stats"` (glb-fleet-report/v1 keeps the key absent when the
/// run had no telemetry, so old consumers see an unchanged document).
pub fn attach_live_stats(fleet: &mut Value, series: Vec<Value>) {
    if let Value::Obj(pairs) = fleet {
        pairs.push(("live_stats".into(), Value::Arr(series)));
    }
}

/// Parse (and schema-check) every per-job serve-report marker line in a
/// resident fleet's rank-0 stdout, in submission order. As with live
/// stats, an unparsable marker is an error (the emitter is ours); a
/// stream with no markers is a fleet that served no jobs.
pub fn extract_serve_reports(stdout: &[String]) -> Result<Vec<Value>> {
    stdout
        .iter()
        .filter_map(|l| l.strip_prefix(SERVE_REPORT_MARKER))
        .map(|body| {
            let v = Value::parse(body).map_err(|e| anyhow!("serve report line: {e}"))?;
            match v.get("schema").and_then(Value::as_str) {
                Some(SERVE_JOB_SCHEMA) => Ok(v),
                other => bail!("serve report schema {other:?} (expected {SERVE_JOB_SCHEMA:?})"),
            }
        })
        .collect()
}

/// Fold a retired resident fleet's per-job reports into one document:
/// the serve analogue of [`aggregate_fleet`], keyed by jobs instead of
/// ranks (`wall_time_s` spans boot to shutdown; `busy_ns` sums the
/// per-job elapsed times, so `busy_ns / wall_time` is the fleet's duty
/// cycle).
pub fn aggregate_serve_fleet(
    ranks: usize,
    app_argv: &[String],
    jobs: Vec<Value>,
    wall_time_s: f64,
) -> Value {
    let busy_ns: i64 =
        jobs.iter().filter_map(|j| j.get("elapsed_ns").and_then(Value::as_i64)).sum();
    Value::obj(vec![
        ("schema", Value::Str(SERVE_FLEET_SCHEMA.into())),
        ("argv", Value::Arr(app_argv.iter().map(|a| Value::Str(a.clone())).collect())),
        ("ranks", Value::Int(ranks as i64)),
        ("jobs_served", Value::Int(jobs.len() as i64)),
        ("wall_time_s", Value::Float(wall_time_s)),
        ("busy_ns", Value::Int(busy_ns)),
        ("jobs", Value::Arr(jobs)),
    ])
}

/// Read and schema-check a fleet report written by `--report`.
pub fn load_fleet_report(path: &Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read fleet report {}", path.display()))?;
    let v = Value::parse(&text).map_err(|e| anyhow!("fleet report {}: {e}", path.display()))?;
    match v.get("schema").and_then(Value::as_str) {
        Some(FLEET_SCHEMA) => Ok(v),
        other => bail!("fleet report schema {other:?} (expected {FLEET_SCHEMA:?})"),
    }
}

/// One `glb bench` entry: the timed runs of one pinned config, plus the
/// result/wire summary of its final fleet.
pub fn bench_entry(
    name: &str,
    np: usize,
    warmups: usize,
    repeats: usize,
    wall_times_s: &[f64],
    fleet: &Value,
) -> Value {
    let best = wall_times_s.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = if wall_times_s.is_empty() {
        0.0
    } else {
        wall_times_s.iter().sum::<f64>() / wall_times_s.len() as f64
    };
    // Frame throughput of the final fleet at the best wall time; null
    // when the transport reported no frames (thread runs) or no timings.
    let frames_per_sec = match fleet.get("frames_sent").and_then(Value::as_i64) {
        Some(f) if f > 0 && best.is_finite() && best > 0.0 => Value::Float(f as f64 / best),
        _ => Value::Null,
    };
    Value::obj(vec![
        ("name", Value::Str(name.into())),
        ("app", fleet.get("app").cloned().unwrap_or(Value::Null)),
        ("argv", fleet.get("argv").cloned().unwrap_or(Value::Null)),
        ("ranks", Value::Int(np as i64)),
        ("warmups", Value::Int(warmups as i64)),
        ("repeats", Value::Int(repeats as i64)),
        ("wall_times_s", Value::Arr(wall_times_s.iter().map(|t| Value::Float(*t)).collect())),
        ("best_s", Value::Float(best)),
        ("mean_s", Value::Float(mean)),
        ("result", fleet.get("result").cloned().unwrap_or(Value::Null)),
        ("wire_tx_bytes", fleet.get("wire_tx_bytes").cloned().unwrap_or(Value::Null)),
        ("wire_rx_bytes", fleet.get("wire_rx_bytes").cloned().unwrap_or(Value::Null)),
        ("frames_sent", fleet.get("frames_sent").cloned().unwrap_or(Value::Null)),
        ("frames_per_sec", frames_per_sec),
        ("steal_latency_us", fleet.get("steal_latency_us").cloned().unwrap_or(Value::Null)),
    ])
}

/// The `BENCH_glb.json` document.
pub fn bench_report(entries: Vec<Value>) -> Value {
    Value::obj(vec![
        ("schema", Value::Str(BENCH_SCHEMA.into())),
        ("bench", Value::Arr(entries)),
    ])
}

/// How far two float results may drift before they count as different.
/// Integer results (UTS node counts) are bit-deterministic and compared
/// exactly; float results (BC betweenness sums) depend on f64 summation
/// grouping, which follows the nondeterministic steal schedule, so they
/// only have to agree to within this relative tolerance.
const RESULT_REL_TOL: f64 = 1e-6;

/// `None` if the two result values agree (exact for ints/strings/bools,
/// within [`RESULT_REL_TOL`] for floats, recursively for arrays and
/// objects); otherwise a human-readable reason. A `Null` on either side
/// means "not comparable" and always agrees.
fn result_mismatch(cur: &Value, base: &Value) -> Option<String> {
    match (cur, base) {
        (Value::Null, _) | (_, Value::Null) => None,
        (Value::Int(a), Value::Int(b)) => {
            (a != b).then(|| format!("{a} != {b} (exact integer result)"))
        }
        (Value::Arr(a), Value::Arr(b)) => {
            if a.len() != b.len() {
                return Some(format!("array lengths differ ({} vs {})", a.len(), b.len()));
            }
            a.iter().zip(b).find_map(|(x, y)| result_mismatch(x, y))
        }
        (Value::Obj(a), Value::Obj(b)) => {
            if a.len() != b.len() {
                return Some(format!("object sizes differ ({} vs {})", a.len(), b.len()));
            }
            a.iter().find_map(|(k, x)| match base.get(k) {
                None => Some(format!("baseline lacks key {k:?}")),
                Some(y) => result_mismatch(x, y),
            })
        }
        _ => match (cur.as_f64(), base.as_f64()) {
            // Mixed/float numerics: steal-schedule summation noise is
            // expected; real regressions are far outside the tolerance.
            (Some(a), Some(b)) => {
                let scale = a.abs().max(b.abs()).max(1.0);
                ((a - b).abs() > RESULT_REL_TOL * scale)
                    .then(|| format!("{a} vs {b} (beyond rel tol {RESULT_REL_TOL:e})"))
            }
            _ => (cur != base).then(|| format!("{} != {}", cur.render(), base.render())),
        },
    }
}

/// Diff a fresh bench report against a committed baseline. Wall-time
/// drift beyond `band` (relative, vs `best_s`) prints `BENCH-WARN` lines
/// and is counted but non-fatal — machine speed varies; the trajectory
/// is the point. A *result* disagreement (see [`result_mismatch`]: exact
/// for integer results, small relative tolerance for float ones) is a
/// hard error — that is a correctness regression, not noise. Baseline
/// entries with `"result": null` skip the check (used when a baseline
/// predates a refresh).
pub fn compare_with_baseline(current: &Value, baseline_path: &str, band: f64) -> Result<usize> {
    let text = std::fs::read_to_string(baseline_path)
        .with_context(|| format!("read bench baseline {baseline_path}"))?;
    let base = Value::parse(&text).map_err(|e| anyhow!("baseline {baseline_path}: {e}"))?;
    if base.get("schema").and_then(Value::as_str) != Some(BENCH_SCHEMA) {
        bail!("baseline {baseline_path} is not a {BENCH_SCHEMA:?} document");
    }
    let empty: Vec<Value> = Vec::new();
    let cur_entries = current.get("bench").and_then(Value::as_arr).unwrap_or(&empty);
    let base_entries = base.get("bench").and_then(Value::as_arr).unwrap_or(&empty);
    let mut warnings = 0usize;
    for cur in cur_entries {
        let name = cur.get("name").and_then(Value::as_str).unwrap_or("?");
        let Some(b) = base_entries
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some(name))
        else {
            println!("BENCH-WARN {name}: no baseline entry (new bench? refresh the baseline)");
            warnings += 1;
            continue;
        };
        let base_result = b.get("result").cloned().unwrap_or(Value::Null);
        let cur_result = cur.get("result").cloned().unwrap_or(Value::Null);
        if let Some(why) = result_mismatch(&cur_result, &base_result) {
            bail!(
                "bench {name}: result changed vs baseline ({why}) — beyond summation \
                 noise, this is a correctness regression"
            );
        }
        let (cur_best, base_best) = (
            cur.get("best_s").and_then(Value::as_f64).unwrap_or(0.0),
            b.get("best_s").and_then(Value::as_f64).unwrap_or(0.0),
        );
        if base_best > 0.0 && cur_best > 0.0 {
            let rel = (cur_best - base_best) / base_best;
            if rel.abs() > band {
                println!(
                    "BENCH-WARN {name}: best wall time {cur_best:.3}s vs baseline \
                     {base_best:.3}s ({rel:+.0}% beyond the ±{band:.0}% band)",
                    rel = rel * 100.0,
                    band = band * 100.0,
                );
                warnings += 1;
            }
        }
        // Frame throughput is warn-only like wall time (it is wall time,
        // restated per frame); entries predating the field (null/absent
        // on either side) skip the check.
        let (cur_fps, base_fps) = (
            cur.get("frames_per_sec").and_then(Value::as_f64),
            b.get("frames_per_sec").and_then(Value::as_f64),
        );
        if let (Some(cf), Some(bf)) = (cur_fps, base_fps) {
            if bf > 0.0 && cf > 0.0 {
                let rel = (cf - bf) / bf;
                if rel.abs() > band {
                    println!(
                        "BENCH-WARN {name}: frames/sec {cf:.0} vs baseline {bf:.0} \
                         ({rel:+.0}% beyond the ±{band:.0}% band)",
                        rel = rel * 100.0,
                        band = band * 100.0,
                    );
                    warnings += 1;
                }
            }
        }
    }
    for b in base_entries {
        let name = b.get("name").and_then(Value::as_str).unwrap_or("?");
        if !cur_entries
            .iter()
            .any(|e| e.get("name").and_then(Value::as_str) == Some(name))
        {
            println!("BENCH-WARN {name}: in the baseline but not in this run");
            warnings += 1;
        }
    }
    Ok(warnings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glb::WorkerStats;

    fn mk_rank(rank: usize, ranks: usize, result: u64, items: u64) -> Value {
        let log = RunLog::new(vec![WorkerStats {
            items_processed: items,
            loot_bags_sent: rank as u64,
            ..Default::default()
        }]);
        build_rank_report(
            "uts",
            "tcp",
            (rank, ranks),
            Value::Int(result as i64),
            1_000_000,
            &log,
            (100 * rank as u64, 50),
            NetStats {
                frames_tx: 10 + rank as u64,
                frames_rx: 10,
                batches: 4,
                steal_latency_us: 100.0 * (rank + 1) as f64,
                steal_samples: rank as u64,
                io_threads: 1,
            },
        )
    }

    #[test]
    fn rank_report_lines_roundtrip() {
        let report = mk_rank(2, 4, 123, 7);
        let line = rank_report_line(&report);
        assert!(line.starts_with(RANK_REPORT_MARKER));
        let back = parse_rank_report(&line).unwrap();
        assert_eq!(back, report);
        let lines = vec!["noise".to_string(), line.clone(), "more noise".to_string()];
        assert_eq!(find_rank_report(&lines), Some(&line));
        assert!(find_rank_report(&["noise".to_string()]).is_none());
        assert!(parse_rank_report("GLB-RANK-REPORT {not json").is_err());
        assert!(parse_rank_report("GLB-RANK-REPORT {\"schema\":\"nope\"}").is_err());
    }

    #[test]
    fn fleet_aggregation_sums_and_keeps_rank0_result() {
        // Deliberately out of order: aggregation sorts by rank.
        let reports = vec![mk_rank(1, 2, 40, 11), mk_rank(0, 2, 100, 5)];
        let fleet = aggregate_fleet("uts", &["uts".to_string()], reports, 2.5, &[]).unwrap();
        assert_eq!(fleet.get("schema").and_then(Value::as_str), Some(FLEET_SCHEMA));
        assert_eq!(fleet.get("ranks").and_then(Value::as_u64), Some(2));
        assert_eq!(fleet.get("places").and_then(Value::as_u64), Some(2));
        assert_eq!(
            fleet.get("result").and_then(Value::as_u64),
            Some(100),
            "rank 0 holds the fleet-wide reduction"
        );
        assert_eq!(fleet.get("wire_tx_bytes").and_then(Value::as_u64), Some(100));
        assert_eq!(fleet.get("wire_rx_bytes").and_then(Value::as_u64), Some(100));
        assert_eq!(fleet.get("frames_sent").and_then(Value::as_u64), Some(21));
        assert_eq!(fleet.get("frames_recv").and_then(Value::as_u64), Some(20));
        assert_eq!(fleet.get("batches").and_then(Value::as_u64), Some(8));
        assert_eq!(fleet.get("io_threads").and_then(Value::as_u64), Some(2));
        // Rank 0 has no steal samples, so the fleet mean is rank 1's.
        assert_eq!(fleet.get("steal_samples").and_then(Value::as_u64), Some(1));
        assert_eq!(fleet.get("steal_latency_us").and_then(Value::as_f64), Some(200.0));
        let totals = fleet.get("totals").expect("totals");
        assert_eq!(totals.get("items_processed").and_then(Value::as_u64), Some(16));
        assert_eq!(totals.get("loot_bags_sent").and_then(Value::as_u64), Some(1));
        let per_rank = fleet.get("per_rank").and_then(Value::as_arr).unwrap();
        assert_eq!(per_rank[0].get("rank").and_then(Value::as_u64), Some(0));
    }

    #[test]
    fn fleet_aggregation_rejects_rank_gaps() {
        let err =
            aggregate_fleet("uts", &[], vec![mk_rank(0, 3, 1, 1), mk_rank(2, 3, 1, 1)], 1.0, &[])
                .unwrap_err();
        assert!(format!("{err:#}").contains("missing or duplicate rank 1"), "{err:#}");
        assert!(aggregate_fleet("uts", &[], vec![], 1.0, &[]).is_err());
    }

    #[test]
    fn fleet_aggregation_accounts_for_tolerated_deaths() {
        // A 3-rank fleet whose rank 1 died: the gap is legal exactly
        // when the launcher flags it, and the report records it.
        let reports = vec![mk_rank(0, 3, 100, 5), mk_rank(2, 3, 40, 11)];
        let fleet = aggregate_fleet("uts", &[], reports.clone(), 1.0, &[1]).unwrap();
        assert_eq!(fleet.get("ranks").and_then(Value::as_u64), Some(3));
        let dead = fleet.get("dead_ranks").and_then(Value::as_arr).unwrap();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].as_u64(), Some(1));
        assert_eq!(fleet.get("result").and_then(Value::as_u64), Some(100));
        // Rank 0 can never be a tolerated death.
        let err = aggregate_fleet("uts", &[], reports, 1.0, &[0]).unwrap_err();
        assert!(format!("{err:#}").contains("rank 0"), "{err:#}");
    }

    #[test]
    fn live_stats_lines_extract_and_attach() {
        let stdout = vec![
            "launching 2 rank(s)".to_string(),
            format!("{LIVE_STATS_MARKER}{{\"t_ms\":100,\"tasks\":5,\"last\":false}}"),
            "glb stats t=0.2s ...".to_string(),
            format!("{LIVE_STATS_MARKER}{{\"t_ms\":200,\"tasks\":11,\"last\":true}}"),
        ];
        let series = extract_live_stats(&stdout).unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].get("t_ms").and_then(Value::as_u64), Some(100));
        assert_eq!(series[1].get("last"), Some(&Value::Bool(true)));
        // Emission order is preserved (the series is a time axis).
        assert_eq!(series[1].get("tasks").and_then(Value::as_u64), Some(11));

        let mut fleet =
            aggregate_fleet("uts", &["uts".to_string()], vec![mk_rank(0, 1, 9, 9)], 0.5, &[])
                .unwrap();
        assert!(fleet.get("live_stats").is_none(), "absent until attached");
        attach_live_stats(&mut fleet, series);
        let attached = fleet.get("live_stats").and_then(Value::as_arr).unwrap();
        assert_eq!(attached.len(), 2);
        // The document still parses back identically after attachment.
        assert_eq!(Value::parse(&fleet.render_pretty()).unwrap(), fleet);

        // No markers: an empty series, not an error.
        assert_eq!(extract_live_stats(&["plain".to_string()]).unwrap().len(), 0);
        // A corrupt marker line is a bug in the emitter, not noise.
        assert!(extract_live_stats(&[format!("{LIVE_STATS_MARKER}{{oops")]).is_err());
    }

    #[test]
    fn serve_reports_extract_and_aggregate() {
        let stdout = vec![
            "glb serve: fleet of 4 rank(s) resident on port 7117".to_string(),
            format!(
                "{SERVE_REPORT_MARKER}{{\"schema\":\"glb-serve-report/v1\",\"job\":1,\
                 \"spec\":\"app=fib fib-n=20\",\"ranks\":4,\"elapsed_ns\":1000,\
                 \"result\":{{\"kind\":\"u64\",\"value\":6765}}}}"
            ),
            "job 1 ...".to_string(),
            format!(
                "{SERVE_REPORT_MARKER}{{\"schema\":\"glb-serve-report/v1\",\"job\":2,\
                 \"spec\":\"app=bc scale=7\",\"ranks\":4,\"elapsed_ns\":2500,\
                 \"result\":{{\"kind\":\"vec_f64\",\"len\":128,\"sum\":1.25e3}}}}"
            ),
        ];
        let jobs = extract_serve_reports(&stdout).unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("job").and_then(Value::as_u64), Some(1));
        assert_eq!(
            jobs[1].get("result").and_then(|r| r.get("sum")).and_then(Value::as_f64),
            Some(1250.0),
            "exponent floats must parse"
        );
        let fleet =
            aggregate_serve_fleet(4, &["serve".to_string()], jobs, 9.5);
        assert_eq!(fleet.get("schema").and_then(Value::as_str), Some(SERVE_FLEET_SCHEMA));
        assert_eq!(fleet.get("jobs_served").and_then(Value::as_u64), Some(2));
        assert_eq!(fleet.get("busy_ns").and_then(Value::as_u64), Some(3500));
        assert_eq!(fleet.get("ranks").and_then(Value::as_u64), Some(4));
        assert_eq!(Value::parse(&fleet.render_pretty()).unwrap(), fleet);
        // No markers: a fleet that served nothing, not an error.
        assert_eq!(extract_serve_reports(&["plain".to_string()]).unwrap().len(), 0);
        // Corrupt or wrong-schema markers are bugs in the emitter.
        assert!(extract_serve_reports(&[format!("{SERVE_REPORT_MARKER}{{oops")]).is_err());
        assert!(extract_serve_reports(&[format!(
            "{SERVE_REPORT_MARKER}{{\"schema\":\"nope\"}}"
        )])
        .is_err());
    }

    #[test]
    fn fleet_report_file_roundtrips() {
        let fleet =
            aggregate_fleet("uts", &["uts".to_string()], vec![mk_rank(0, 1, 9, 9)], 0.5, &[])
                .unwrap();
        let dir = std::env::temp_dir();
        let path = dir.join(format!("glb-report-test-{}.json", std::process::id()));
        std::fs::write(&path, fleet.render_pretty()).unwrap();
        let back = load_fleet_report(&path).unwrap();
        assert_eq!(back, fleet, "pretty render must parse back identically");
        std::fs::remove_file(&path).ok();
        assert!(load_fleet_report(Path::new("/nonexistent/fleet.json")).is_err());
    }

    #[test]
    fn bench_entries_summarize_times() {
        let fleet =
            aggregate_fleet("uts", &["uts".to_string()], vec![mk_rank(0, 1, 41314, 3)], 1.0, &[])
                .unwrap();
        let e = bench_entry("uts-d8", 2, 1, 3, &[1.5, 1.0, 2.0], &fleet);
        assert_eq!(e.get("best_s").and_then(Value::as_f64), Some(1.0));
        assert_eq!(e.get("mean_s").and_then(Value::as_f64), Some(1.5));
        assert_eq!(e.get("result").and_then(Value::as_u64), Some(41314));
        // 10 fleet frames over the 1.0s best run.
        assert_eq!(e.get("frames_per_sec").and_then(Value::as_f64), Some(10.0));
        assert_eq!(e.get("steal_latency_us").and_then(Value::as_f64), Some(0.0));
        let doc = bench_report(vec![e]);
        assert_eq!(doc.get("schema").and_then(Value::as_str), Some(BENCH_SCHEMA));
        assert_eq!(Value::parse(&doc.render_pretty()).unwrap(), doc);
    }

    #[test]
    fn baseline_compare_warns_on_drift_and_fails_on_result_change() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("glb-baseline-test-{}.json", std::process::id()));
        let entry = |name: &str, best: f64, result: Value| {
            Value::obj(vec![
                ("name", Value::Str(name.into())),
                ("best_s", Value::Float(best)),
                ("result", result),
            ])
        };
        let baseline = bench_report(vec![
            entry("stable", 1.0, Value::Int(42)),
            entry("slow", 1.0, Value::Null),
            entry("gone", 1.0, Value::Null),
        ]);
        std::fs::write(&path, baseline.render_pretty()).unwrap();
        let current = bench_report(vec![
            entry("stable", 1.1, Value::Int(42)),
            entry("slow", 2.0, Value::Int(7)),
        ]);
        // stable: within band; slow: +100% drift (warn); gone: missing (warn).
        let warnings = compare_with_baseline(&current, path.to_str().unwrap(), 0.30).unwrap();
        assert_eq!(warnings, 2);
        // A changed result against a non-null baseline is fatal.
        let bad = bench_report(vec![entry("stable", 1.0, Value::Int(41))]);
        let err = compare_with_baseline(&bad, path.to_str().unwrap(), 0.30).unwrap_err();
        assert!(format!("{err:#}").contains("correctness regression"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn baseline_compare_diffs_frames_per_sec_warn_only() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("glb-baseline-fps-test-{}.json", std::process::id()));
        let entry = |fps: Value| {
            Value::obj(vec![
                ("name", Value::Str("uts-d8".into())),
                ("best_s", Value::Float(1.0)),
                ("result", Value::Null),
                ("frames_per_sec", fps),
            ])
        };
        std::fs::write(&path, bench_report(vec![entry(Value::Float(1000.0))]).render_pretty())
            .unwrap();
        // Halved throughput: a warning, never a failure.
        let current = bench_report(vec![entry(Value::Float(500.0))]);
        assert_eq!(compare_with_baseline(&current, path.to_str().unwrap(), 0.30).unwrap(), 1);
        // Null on either side (a baseline predating the field) skips it.
        let current = bench_report(vec![entry(Value::Null)]);
        assert_eq!(compare_with_baseline(&current, path.to_str().unwrap(), 0.30).unwrap(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn result_comparison_is_exact_for_ints_and_tolerant_for_floats() {
        assert!(result_mismatch(&Value::Int(41314), &Value::Int(41314)).is_none());
        assert!(result_mismatch(&Value::Int(41314), &Value::Int(41315)).is_some());
        // Last-ulp f64 summation noise (steal-schedule grouping) agrees...
        let a = Value::obj(vec![("len", Value::Int(128)), ("sum", Value::Float(1234.5000000001))]);
        let b = Value::obj(vec![("len", Value::Int(128)), ("sum", Value::Float(1234.5))]);
        assert!(result_mismatch(&a, &b).is_none());
        // ...a real change does not, and neither does a shape change.
        let c = Value::obj(vec![("len", Value::Int(128)), ("sum", Value::Float(1240.0))]);
        assert!(result_mismatch(&c, &b).is_some());
        assert!(result_mismatch(&a, &Value::Int(3)).is_some());
        // Null on either side means "not comparable": always agrees.
        assert!(result_mismatch(&a, &Value::Null).is_none());
        assert!(result_mismatch(&Value::Null, &b).is_none());
    }

    #[test]
    fn sum_int_objects_unions_keys() {
        let a = Value::obj(vec![("x", Value::Int(2)), ("y", Value::Int(3))]);
        let b = Value::obj(vec![("y", Value::Int(10)), ("z", Value::Int(1))]);
        let s = sum_int_objects(&a, &b);
        assert_eq!(s.get("x").and_then(Value::as_i64), Some(2));
        assert_eq!(s.get("y").and_then(Value::as_i64), Some(13));
        assert_eq!(s.get("z").and_then(Value::as_i64), Some(1));
        assert_eq!(sum_int_objects(&Value::Obj(vec![]), &a), a);
    }
}
