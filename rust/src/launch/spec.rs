//! Fleet specification: which ranks run where, with which flags.
//!
//! `glb launch` owns a small set of options (`--np/--hosts/--ssh/--bin/
//! --port/--report/--timeout`) that it consumes wherever they appear on
//! the command line; every other token passes through verbatim to the
//! launched app. From the spec it derives each rank's full flag set —
//! `--rank/--peers/--port`, plus the rank-0 bind/advertise split and
//! per-spoke `--advertise` addresses for multi-host fleets — so the
//! flags that PR 3 left to be typed by hand per rank are now computed in
//! exactly one place.

use std::net::TcpListener;
use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::{report, RankCmd};

/// Options the launcher consumes (everything else passes through).
/// `tolerate-failures` is consumed *and* re-derived per rank: the
/// launcher needs it for its own fail-fast budget, the runtime needs it
/// to arm recovery.
const LAUNCHER_OPTS: &[&str] =
    &["np", "hosts", "ssh", "bin", "port", "report", "timeout", "tolerate-failures"];

/// Flags the launcher derives per rank; passing them through is an
/// error, not a silent override.
const DERIVED_OPTS: &[&str] = &["rank", "peers", "host", "bind", "advertise"];

/// Apps that speak the tcp fleet protocol (and emit rank reports),
/// plus `serve` — the resident fleet, which emits per-job serve
/// reports instead of one rank report at exit.
const FLEET_APPS: &[&str] = &["uts", "bc", "fib", "nqueens", "serve"];

/// Where the ranks run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Placement {
    /// `--np N`: N ranks on this machine, spawned directly.
    Local { np: usize },
    /// `--hosts FILE`: one entry per rank (hosts-file `slots=K` lines
    /// already expanded), reached through an ssh command template.
    Hosts { ranks: Vec<String> },
}

/// A parsed `glb launch` invocation.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub placement: Placement,
    /// Rank 0's rendezvous port; `0` = pick a free ephemeral port at
    /// [`FleetSpec::plan`] time (local fleets only).
    pub port: u16,
    /// The app command for every rank: app name first, then the
    /// passthrough flags (with `--transport tcp` guaranteed present).
    pub app_argv: Vec<String>,
    /// Where to write the aggregated fleet report.
    pub report: Option<PathBuf>,
    /// Fleet watchdog deadline.
    pub deadline: Duration,
    /// Binary to run (default: this executable locally, `glb` on PATH
    /// over ssh).
    pub bin: Option<String>,
    /// ssh command template for `--hosts` fleets (split on whitespace;
    /// host and remote command are appended).
    pub ssh: String,
    /// Spoke deaths to absorb instead of failing the fleet — threaded
    /// both into the engine's fail-fast budget and into every rank's
    /// argv so the runtime arms crash recovery.
    pub tolerate_failures: usize,
    /// Live-telemetry sampling interval: `--stats[=MS]` (default 1000ms
    /// when the value is left off). Re-derived per rank as
    /// `--stats-interval MS` so every rank samples on the same cadence.
    pub stats_interval_ms: Option<u64>,
}

/// The spawnable form of a spec: one command per rank.
pub struct FleetPlan {
    pub ranks: usize,
    pub port: u16,
    pub cmds: Vec<RankCmd>,
    /// Human-readable command lines, indexed by rank (logged by the CLI).
    pub cmdlines: Vec<String>,
}

impl FleetSpec {
    /// Parse the raw tokens after `glb launch`.
    pub fn parse(raw: &[String]) -> Result<Self> {
        let mut np: Option<usize> = None;
        let mut hosts_file: Option<String> = None;
        let mut ssh: Option<String> = None;
        let mut bin: Option<String> = None;
        let mut port: Option<u16> = None;
        let mut report: Option<PathBuf> = None;
        let mut timeout_s: u64 = 600;
        let mut tolerate_failures: usize = 0;
        let mut stats_interval_ms: Option<u64> = None;
        let mut passthrough: Vec<String> = Vec::new();

        let mut it = raw.iter();
        while let Some(tok) = it.next() {
            let Some(stripped) = tok.strip_prefix("--") else {
                passthrough.push(tok.clone());
                continue;
            };
            let (name, inline) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            if DERIVED_OPTS.contains(&name) {
                bail!(
                    "--{name} is derived per rank by `glb launch` \
                     (it computes rank/peers/port and the bind/advertise split); drop it"
                );
            }
            // `--stats[=MS]` is the one launcher option whose value is
            // optional: a bare `--stats` must not eat the next token
            // (usually the app name), so it is handled before the
            // value-taking loop below.
            if name == "stats" {
                let ms: u64 = match inline {
                    Some(v) => v.parse().map_err(|e| anyhow!("--stats={v}: {e}"))?,
                    None => 1000,
                };
                if ms == 0 {
                    bail!("--stats interval must be >= 1 (milliseconds)");
                }
                stats_interval_ms = Some(ms);
                continue;
            }
            if !LAUNCHER_OPTS.contains(&name) {
                passthrough.push(tok.clone());
                continue;
            }
            let value = match inline {
                Some(v) => v,
                None => it.next().with_context(|| format!("--{name} needs a value"))?.clone(),
            };
            match name {
                "np" => np = Some(value.parse().map_err(|e| anyhow!("--np {value}: {e}"))?),
                "hosts" => hosts_file = Some(value),
                "ssh" => ssh = Some(value),
                "bin" => bin = Some(value),
                "port" => port = Some(value.parse().map_err(|e| anyhow!("--port {value}: {e}"))?),
                "report" => report = Some(PathBuf::from(value)),
                "timeout" => {
                    timeout_s = value.parse().map_err(|e| anyhow!("--timeout {value}: {e}"))?
                }
                "tolerate-failures" => {
                    tolerate_failures = value
                        .parse()
                        .map_err(|e| anyhow!("--tolerate-failures {value}: {e}"))?
                }
                _ => unreachable!("LAUNCHER_OPTS covers the match"),
            }
        }

        let placement = match (np, hosts_file) {
            (Some(_), Some(_)) => bail!("--np and --hosts are mutually exclusive"),
            (None, None) => bail!("`glb launch` needs --np N (localhost) or --hosts FILE"),
            (Some(0), None) => bail!("--np must be >= 1"),
            (Some(n), None) => Placement::Local { np: n },
            (None, Some(f)) => {
                let text = std::fs::read_to_string(&f)
                    .with_context(|| format!("read hosts file {f}"))?;
                Placement::Hosts { ranks: parse_hosts_text(&text)? }
            }
        };

        // The first positional is the app; it must come before its own
        // options so we never mistake an option value for the app name.
        let app_pos = passthrough.iter().position(|t| !t.starts_with("--"));
        let app = match app_pos {
            Some(0) => passthrough.remove(0),
            Some(_) => {
                bail!("put the app name (one of {}) before its options", FLEET_APPS.join("|"))
            }
            None => bail!("`glb launch` needs an app to run (one of {})", FLEET_APPS.join("|")),
        };
        if !FLEET_APPS.contains(&app.as_str()) {
            let apps = FLEET_APPS.join("|");
            bail!("`glb launch` drives tcp fleets; app must be one of {apps}, got {app:?}");
        }

        // A launched fleet is by definition tcp; fill the flag in when
        // the user leaves it implicit, reject contradictions. `serve`
        // is the exception: it is tcp by construction and takes no
        // --transport flag at all.
        let resident = app == "serve";
        if resident {
            if option_value(&passthrough, "transport").is_some() {
                bail!("`glb serve` is always tcp; drop --transport");
            }
            if tolerate_failures > 0 {
                bail!("a resident `glb serve` fleet does not support --tolerate-failures yet");
            }
            if stats_interval_ms.is_some() {
                bail!("a resident `glb serve` fleet does not support --stats yet");
            }
        } else {
            match option_value(&passthrough, "transport") {
                None => {
                    passthrough.push("--transport".into());
                    passthrough.push("tcp".into());
                }
                Some("tcp") => {}
                Some(other) => {
                    bail!("`glb launch` runs --transport tcp fleets, not --transport {other}")
                }
            }
        }

        let mut app_argv = vec![app];
        app_argv.extend(passthrough);

        let port = match (&placement, port) {
            (_, Some(p)) => {
                if matches!(placement, Placement::Hosts { .. }) && p == 0 {
                    bail!("multi-host fleets need a fixed --port (spokes must dial rank 0)");
                }
                p
            }
            // A resident fleet's port is its service address — submit
            // clients must be able to find it, so default it to the
            // well-known port instead of an ephemeral one.
            (Placement::Local { .. }, None) if resident => 7117,
            (Placement::Local { .. }, None) => 0, // ephemeral, picked at plan time
            (Placement::Hosts { .. }, None) => 7117,
        };

        Ok(FleetSpec {
            placement,
            port,
            app_argv,
            report,
            deadline: Duration::from_secs(timeout_s),
            bin,
            ssh: ssh.unwrap_or_else(|| "ssh -o BatchMode=yes".into()),
            tolerate_failures,
            stats_interval_ms,
        })
    }

    /// The launched app's name.
    pub fn app(&self) -> &str {
        &self.app_argv[0]
    }

    /// Total ranks the spec describes.
    pub fn ranks(&self) -> usize {
        match &self.placement {
            Placement::Local { np } => *np,
            Placement::Hosts { ranks } => ranks.len(),
        }
    }

    /// Derive rank `rank`'s full app argv (flags included).
    fn rank_argv(&self, rank: usize, ranks: usize, port: u16) -> Vec<String> {
        let mut v = self.app_argv.clone();
        let mut push = |flag: &str, val: String| {
            v.push(flag.into());
            v.push(val);
        };
        push("--rank", rank.to_string());
        push("--peers", ranks.to_string());
        push("--port", port.to_string());
        if self.tolerate_failures > 0 {
            push("--tolerate-failures", self.tolerate_failures.to_string());
        }
        if let Some(ms) = self.stats_interval_ms {
            push("--stats-interval", ms.to_string());
        }
        match &self.placement {
            Placement::Local { .. } => {
                push("--host", "127.0.0.1".into());
                if rank == 0 {
                    push("--bind", "0.0.0.0".into());
                }
            }
            Placement::Hosts { ranks: hosts } => {
                // Every rank dials rank 0's host; rank 0 binds the
                // wildcard (its advertised address is often not locally
                // bindable), spokes advertise their own hosts-file
                // address so multi-homed boxes mesh correctly.
                push("--host", host_addr(&hosts[0]).into());
                if rank == 0 {
                    push("--bind", "0.0.0.0".into());
                } else {
                    push("--advertise", host_addr(&hosts[rank]).into());
                }
            }
        }
        v
    }

    /// Resolve the spec into spawnable per-rank commands.
    pub fn plan(&self) -> Result<FleetPlan> {
        let ranks = self.ranks();
        let port = if self.port == 0 { free_port()? } else { self.port };
        let mut cmds = Vec::with_capacity(ranks);
        let mut cmdlines = Vec::with_capacity(ranks);
        for rank in 0..ranks {
            let argv = self.rank_argv(rank, ranks, port);
            match &self.placement {
                Placement::Local { .. } => {
                    let bin = match &self.bin {
                        Some(b) => PathBuf::from(b),
                        None => std::env::current_exe().context("resolve this glb binary")?,
                    };
                    let mut cmd = Command::new(&bin);
                    cmd.args(&argv).env(report::RANK_REPORT_ENV, "1");
                    cmdlines.push(format!("{} {}", bin.display(), argv.join(" ")));
                    cmds.push(RankCmd { rank, cmd });
                }
                Placement::Hosts { ranks: hosts } => {
                    let bin = self.bin.as_deref().unwrap_or("glb");
                    let remote = format!(
                        "{}=1 {} {}",
                        report::RANK_REPORT_ENV,
                        shell_quote(bin),
                        argv.iter().map(|a| shell_quote(a)).collect::<Vec<_>>().join(" "),
                    );
                    let mut ssh_words = self.ssh.split_whitespace();
                    let ssh0 = ssh_words
                        .next()
                        .ok_or_else(|| anyhow!("--ssh template must name a command"))?;
                    let mut cmd = Command::new(ssh0);
                    cmd.args(ssh_words).arg(&hosts[rank]).arg(&remote);
                    cmdlines.push(format!("{} {} {remote}", self.ssh, hosts[rank]));
                    cmds.push(RankCmd { rank, cmd });
                }
            }
        }
        Ok(FleetPlan { ranks, port, cmds, cmdlines })
    }
}

/// The value of `--name v` / `--name=v` in a token stream, if present.
fn option_value<'a>(tokens: &'a [String], name: &str) -> Option<&'a str> {
    let flag = format!("--{name}");
    let inline = format!("--{name}=");
    for (i, t) in tokens.iter().enumerate() {
        if *t == flag {
            return tokens.get(i + 1).map(|s| s.as_str());
        }
        if let Some(v) = t.strip_prefix(&inline) {
            return Some(v);
        }
    }
    None
}

/// Parse a hosts file: one host per line (`host` or `user@host`), an
/// optional `slots=N` to run N ranks there, `#` comments. Returns one
/// entry per rank.
pub fn parse_hosts_text(text: &str) -> Result<Vec<String>> {
    let mut ranks = Vec::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw_line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let host = parts.next().expect("non-empty line has a first token").to_string();
        if host.starts_with('-') {
            bail!("hosts file line {line_no}: {host:?} is not a hostname");
        }
        let mut slots = 1usize;
        for extra in parts {
            match extra.split_once('=') {
                Some(("slots", v)) => {
                    slots = v
                        .parse()
                        .map_err(|e| anyhow!("hosts file line {line_no}: slots={v:?}: {e}"))?
                }
                _ => bail!(
                    "hosts file line {line_no}: unexpected token {extra:?} \
                     (only `slots=N` is understood)"
                ),
            }
        }
        if slots == 0 {
            bail!("hosts file line {line_no}: slots must be >= 1");
        }
        for _ in 0..slots {
            ranks.push(host.clone());
        }
    }
    if ranks.is_empty() {
        bail!("hosts file lists no hosts");
    }
    Ok(ranks)
}

/// The dialable address of a hosts-file entry (`user@addr` -> `addr`).
fn host_addr(entry: &str) -> &str {
    entry.rsplit_once('@').map_or(entry, |(_, addr)| addr)
}

/// Quote a string for the remote shell behind ssh.
fn shell_quote(s: &str) -> String {
    let plain = !s.is_empty()
        && s.bytes()
            .all(|b| b.is_ascii_alphanumeric() || b"@%+=:,./-_".contains(&b));
    if plain {
        s.to_string()
    } else {
        format!("'{}'", s.replace('\'', "'\\''"))
    }
}

/// A currently-free localhost port for a local fleet's rendezvous
/// (bound briefly, then released for rank 0 to claim). Shared with the
/// test harness via [`crate::testkit::fleet::free_port`].
pub(crate) fn free_port() -> Result<u16> {
    let l = TcpListener::bind(("127.0.0.1", 0)).context("probe for a free port")?;
    Ok(l.local_addr().context("free-port local addr")?.port())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn local_spec_derives_every_ranks_flags() {
        let spec =
            FleetSpec::parse(&s(&["--np", "3", "uts", "--depth", "6", "--report", "out.json"]))
                .unwrap();
        assert_eq!(spec.ranks(), 3);
        assert_eq!(spec.app(), "uts");
        assert_eq!(spec.report.as_deref(), Some(std::path::Path::new("out.json")));
        // --transport tcp is filled in when left implicit.
        assert_eq!(option_value(&spec.app_argv, "transport"), Some("tcp"));
        let r0 = spec.rank_argv(0, 3, 7001);
        assert_eq!(option_value(&r0, "rank"), Some("0"));
        assert_eq!(option_value(&r0, "peers"), Some("3"));
        assert_eq!(option_value(&r0, "port"), Some("7001"));
        assert_eq!(option_value(&r0, "bind"), Some("0.0.0.0"), "rank 0 splits bind/advertise");
        let r2 = spec.rank_argv(2, 3, 7001);
        assert_eq!(option_value(&r2, "rank"), Some("2"));
        assert_eq!(option_value(&r2, "host"), Some("127.0.0.1"));
        assert_eq!(option_value(&r2, "bind"), None, "spokes bind their own listeners");
    }

    #[test]
    fn tolerate_failures_is_consumed_and_rederived_per_rank() {
        let spec = FleetSpec::parse(&s(&[
            "--np",
            "4",
            "--tolerate-failures",
            "1",
            "uts",
            "--depth",
            "8",
        ]))
        .unwrap();
        assert_eq!(spec.tolerate_failures, 1);
        assert_eq!(
            option_value(&spec.app_argv, "tolerate-failures"),
            None,
            "consumed, not passed through raw: {:?}",
            spec.app_argv
        );
        for rank in 0..4 {
            let argv = spec.rank_argv(rank, 4, 7001);
            assert_eq!(option_value(&argv, "tolerate-failures"), Some("1"), "rank {rank}");
        }
        // Default stays fail-fast, with no flag on any rank.
        let spec = FleetSpec::parse(&s(&["--np", "2", "fib", "--n", "20"])).unwrap();
        assert_eq!(spec.tolerate_failures, 0);
        assert_eq!(spec.app(), "fib", "fib speaks the tcp fleet protocol");
        assert_eq!(option_value(&spec.rank_argv(0, 2, 7001), "tolerate-failures"), None);
    }

    #[test]
    fn stats_flag_is_consumed_and_rederived_per_rank() {
        // Bare --stats defaults to 1000ms and must not eat the app name.
        let spec = FleetSpec::parse(&s(&["--np", "2", "--stats", "uts", "--depth", "6"])).unwrap();
        assert_eq!(spec.stats_interval_ms, Some(1000));
        assert_eq!(spec.app(), "uts");
        for rank in 0..2 {
            let argv = spec.rank_argv(rank, 2, 7001);
            assert_eq!(option_value(&argv, "stats-interval"), Some("1000"), "rank {rank}");
        }
        // Inline value overrides the default.
        let spec = FleetSpec::parse(&s(&["--np", "2", "--stats=250", "uts"])).unwrap();
        assert_eq!(spec.stats_interval_ms, Some(250));
        assert_eq!(option_value(&spec.rank_argv(1, 2, 7001), "stats-interval"), Some("250"));
        // Off by default: no flag on any rank.
        let spec = FleetSpec::parse(&s(&["--np", "2", "uts"])).unwrap();
        assert_eq!(spec.stats_interval_ms, None);
        assert_eq!(option_value(&spec.rank_argv(0, 2, 7001), "stats-interval"), None);
        // A zero interval is a user error, not a divide-by-zero later.
        let err = FleetSpec::parse(&s(&["--np", "2", "--stats=0", "uts"])).unwrap_err();
        assert!(format!("{err:#}").contains(">= 1"), "{err:#}");
    }

    #[test]
    fn serve_fleets_are_resident_and_keep_a_fixed_port() {
        let spec = FleetSpec::parse(&s(&["--np", "4", "serve"])).unwrap();
        assert_eq!(spec.app(), "serve");
        assert_eq!(spec.port, 7117, "submit clients need a well-known port");
        // serve takes no --transport flag; none may be injected.
        assert_eq!(option_value(&spec.app_argv, "transport"), None);
        let r0 = spec.rank_argv(0, 4, 7117);
        assert_eq!(option_value(&r0, "rank"), Some("0"));
        assert_eq!(option_value(&r0, "peers"), Some("4"));
        assert_eq!(option_value(&r0, "bind"), Some("0.0.0.0"));
        // An explicit port still wins.
        let spec = FleetSpec::parse(&s(&["--np", "2", "--port", "7300", "serve"])).unwrap();
        assert_eq!(spec.port, 7300);
        // Unsupported launcher knobs fail loudly instead of wedging ranks.
        for argv in [
            vec!["--np", "2", "--tolerate-failures", "1", "serve"],
            vec!["--np", "2", "--stats", "serve"],
            vec!["--np", "2", "serve", "--transport", "tcp"],
        ] {
            assert!(FleetSpec::parse(&s(&argv)).is_err(), "{argv:?}");
        }
    }

    #[test]
    fn nqueens_speaks_the_fleet_protocol() {
        let spec = FleetSpec::parse(&s(&["--np", "2", "nqueens", "--n", "10"])).unwrap();
        assert_eq!(spec.app(), "nqueens");
    }

    #[test]
    fn explicit_tcp_transport_is_accepted_verbatim() {
        let spec =
            FleetSpec::parse(&s(&["--np", "4", "uts", "--depth", "6", "--transport", "tcp"]))
                .unwrap();
        let tcp_count = spec.app_argv.iter().filter(|t| t.as_str() == "--transport").count();
        assert_eq!(tcp_count, 1, "no duplicate --transport: {:?}", spec.app_argv);
    }

    #[test]
    fn derived_flags_are_rejected_in_passthrough() {
        for flag in ["--rank", "--peers", "--host", "--bind", "--advertise"] {
            let err = FleetSpec::parse(&s(&["--np", "2", "uts", flag, "1"])).unwrap_err();
            assert!(format!("{err:#}").contains("derived"), "{flag}: {err:#}");
        }
    }

    #[test]
    fn spec_validation_errors_are_clear() {
        let cases: &[(&[&str], &str)] = &[
            (&["uts"], "--np"),
            (&["--np", "2"], "needs an app"),
            (&["--np", "0", "uts"], "--np must be"),
            (&["--np", "2", "fig"], "must be one of"),
            (&["--np", "2", "--depth", "6", "uts"], "before its options"),
            (&["--np", "2", "uts", "--transport", "sim"], "not --transport sim"),
            (&["--np", "2", "--hosts", "h.txt", "uts"], "mutually exclusive"),
            (&["--np"], "needs a value"),
        ];
        for (argv, needle) in cases {
            let err = FleetSpec::parse(&s(argv)).unwrap_err();
            assert!(
                format!("{err:#}").contains(needle),
                "{argv:?}: expected {needle:?} in {err:#}"
            );
        }
    }

    #[test]
    fn hosts_text_expands_slots_and_strips_comments() {
        let ranks = parse_hosts_text(
            "# fleet\nalpha\nbeta slots=2   # two ranks here\nuser@gamma\n\n",
        )
        .unwrap();
        assert_eq!(ranks, vec!["alpha", "beta", "beta", "user@gamma"]);
        assert_eq!(host_addr("user@gamma"), "gamma");
        assert_eq!(host_addr("alpha"), "alpha");
    }

    #[test]
    fn malformed_hosts_files_are_rejected_with_line_numbers() {
        let cases: &[(&str, &str)] = &[
            ("", "no hosts"),
            ("# only comments\n", "no hosts"),
            ("alpha slots=banana", "line 1"),
            ("alpha\nbeta slots=0", "line 2"),
            ("alpha cores=4", "unexpected token"),
            ("--np", "not a hostname"),
        ];
        for (text, needle) in cases {
            let err = parse_hosts_text(text).unwrap_err();
            assert!(
                format!("{err:#}").contains(needle),
                "{text:?}: expected {needle:?} in {err:#}"
            );
        }
    }

    #[test]
    fn multi_host_ranks_dial_rank0_and_advertise_themselves() {
        let spec = FleetSpec {
            placement: Placement::Hosts { ranks: vec!["user@alpha".into(), "beta".into()] },
            port: 7117,
            app_argv: s(&["uts", "--transport", "tcp"]),
            report: None,
            deadline: Duration::from_secs(10),
            bin: None,
            ssh: "ssh -o BatchMode=yes".into(),
            tolerate_failures: 0,
            stats_interval_ms: None,
        };
        let r0 = spec.rank_argv(0, 2, 7117);
        assert_eq!(option_value(&r0, "host"), Some("alpha"), "user@ stripped for dialing");
        assert_eq!(option_value(&r0, "bind"), Some("0.0.0.0"));
        let r1 = spec.rank_argv(1, 2, 7117);
        assert_eq!(option_value(&r1, "host"), Some("alpha"), "spokes dial rank 0");
        assert_eq!(option_value(&r1, "advertise"), Some("beta"));
        let plan = spec.plan().unwrap();
        assert_eq!(plan.ranks, 2);
        assert!(plan.cmdlines[1].starts_with("ssh -o BatchMode=yes beta "), "{}", plan.cmdlines[1]);
        assert!(plan.cmdlines[1].contains("GLB_RANK_REPORT=1"), "{}", plan.cmdlines[1]);
    }

    #[test]
    fn shell_quoting_protects_the_remote_line() {
        assert_eq!(shell_quote("plain-0.7/ok"), "plain-0.7/ok");
        assert_eq!(shell_quote("has space"), "'has space'");
        assert_eq!(shell_quote("don't"), "'don'\\''t'");
        assert_eq!(shell_quote(""), "''");
    }

    #[test]
    fn local_port_defaults_to_ephemeral_and_hosts_to_fixed() {
        let local = FleetSpec::parse(&s(&["--np", "2", "uts"])).unwrap();
        assert_eq!(local.port, 0, "resolved to a free port at plan time");
        let plan = local.plan().unwrap();
        assert_ne!(plan.port, 0);
        assert_eq!(plan.cmds.len(), 2);
        // Multi-host: port 0 cannot work (spokes must dial a known port).
        let err = FleetSpec::parse(&s(&["--hosts", "/nonexistent-hosts-file", "uts"]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("read hosts file"), "{err:#}");
    }
}
