//! `glb launch` — the multi-host fleet launcher, and the engine under it.
//!
//! The paper's results come from launching one process per place across
//! whole machines; PR 3/4 gave this repo a process-level mesh runtime
//! but left every rank to be started by hand with matching
//! `--rank/--peers/--port` flags. This module closes that gap:
//!
//! * [`spec`] parses a fleet specification (`--np N` for localhost,
//!   `--hosts FILE` + an ssh command template for multi-host) and
//!   derives every rank's consistent flag set (rank/peers/port and the
//!   bind/advertise split);
//! * the engine here ([`run_fleet`]) spawns the ranks, streams their
//!   output with `[rank k]` prefixes, watchdogs the fleet, and fails
//!   fast — one rank dying kills the survivors and surfaces that rank's
//!   output, instead of waiting out the deadline;
//! * [`report`] aggregates the per-rank `RunLog` JSON lines (emitted on
//!   a marker when [`report::RANK_REPORT_ENV`] is set) into one
//!   machine-readable fleet report, and gives `glb bench` its
//!   `BENCH_glb.json` schema — the CI perf trajectory.
//!
//! The same engine drives three consumers — `glb launch`, `glb bench`,
//! and the [`crate::testkit::fleet`] test harness — so tests, CLI users,
//! and CI all exercise one spawn/watchdog/collect code path.

pub mod report;
pub mod spec;

use std::io::Read;
use std::process::{Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// One rank's command, ready to spawn (stdin/stdout/stderr are
/// configured by the engine).
pub struct RankCmd {
    pub rank: usize,
    pub cmd: Command,
}

/// One rank's captured output after a fully successful fleet run.
#[derive(Debug)]
pub struct RankRun {
    pub rank: usize,
    pub stdout: Vec<String>,
    pub stderr: Vec<String>,
    /// The rank exited nonzero but its death was within the fleet's
    /// `--tolerate-failures` budget (so the run as a whole succeeded).
    pub died: bool,
}

/// Engine knobs.
pub struct EngineOpts {
    /// Kill the fleet and fail if it has not finished by then.
    pub deadline: Duration,
    /// Stream child output live with a `[rank k]` prefix (the CLI path);
    /// marker lines (rank reports, testkit result lines) are captured
    /// but not echoed.
    pub echo: bool,
    /// How many spoke deaths (nonzero exits of ranks other than 0) the
    /// launcher absorbs without killing the fleet — the process-level
    /// counterpart of the runtime's `--tolerate-failures`, which lets
    /// the surviving ranks re-knit and finish. `0` keeps the historical
    /// fail-fast semantics for every nonzero exit.
    pub tolerate_failures: usize,
}

/// Result/report marker lines are machine-to-machine traffic; the echo
/// stream skips them so a `--report` run stays readable.
fn is_marker_line(line: &str) -> bool {
    line.starts_with(report::RANK_REPORT_MARKER)
        || line.starts_with(report::LIVE_STATS_MARKER)
        || line.starts_with(report::SERVE_REPORT_MARKER)
        || line.starts_with(crate::testkit::fleet::LOG_PREFIX)
}

/// Drain one child stream line-by-line into `buf`, echoing as we go when
/// asked. Runs on its own thread; exits when the child closes the pipe.
fn stream_reader(stream: impl Read, buf: Arc<Mutex<Vec<String>>>, echo: Option<(usize, bool)>) {
    let reader = std::io::BufReader::new(stream);
    for line in std::io::BufRead::lines(reader) {
        let line = match line {
            Ok(l) => l,
            // Invalid UTF-8: `lines` has already consumed the bad line's
            // bytes — keep draining so a child emitting binary garbage
            // never blocks on a full pipe waiting for us.
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => continue,
            Err(_) => return,
        };
        if let Some((rank, to_stderr)) = echo {
            if !is_marker_line(&line) {
                if to_stderr {
                    eprintln!("[rank {rank}] {line}");
                } else {
                    println!("[rank {rank}] {line}");
                }
            }
        }
        buf.lock().unwrap().push(line);
    }
}

struct Proc {
    rank: usize,
    child: std::process::Child,
    stdout: Arc<Mutex<Vec<String>>>,
    stderr: Arc<Mutex<Vec<String>>>,
    readers: Vec<std::thread::JoinHandle<()>>,
}

/// Kill and reap every process not already reaped, then join all reader
/// threads (the kill closes the pipes, so the readers finish).
fn tear_down(procs: &mut [Proc], reaped: &[bool]) {
    for (i, p) in procs.iter_mut().enumerate() {
        if !reaped[i] {
            let _ = p.child.kill();
        }
    }
    for (i, p) in procs.iter_mut().enumerate() {
        if !reaped[i] {
            let _ = p.child.wait();
        }
    }
    for p in procs.iter_mut() {
        for h in p.readers.drain(..) {
            let _ = h.join();
        }
    }
}

fn captured(buf: &Arc<Mutex<Vec<String>>>) -> String {
    buf.lock().unwrap().join("\n")
}

/// The last `n` captured lines of a stream, for error reports.
fn tail(buf: &Arc<Mutex<Vec<String>>>, n: usize) -> String {
    let lines = buf.lock().unwrap();
    let start = lines.len().saturating_sub(n);
    lines[start..].join("\n")
}

/// Spawn every rank, stream/capture their output, and wait for the whole
/// fleet. Fail-fast semantics: the first rank to exit nonzero kills the
/// survivors immediately and the error carries that rank's output; a
/// fleet that outlives `deadline` is killed and reported as a timeout.
/// Returns the per-rank captured output (sorted by rank) only when every
/// rank exited zero.
pub fn run_fleet(cmds: Vec<RankCmd>, opts: &EngineOpts) -> Result<Vec<RankRun>> {
    if cmds.is_empty() {
        bail!("a fleet needs at least one rank");
    }
    let mut procs: Vec<Proc> = Vec::with_capacity(cmds.len());
    for RankCmd { rank, mut cmd } in cmds {
        cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::piped());
        let mut child = match cmd.spawn() {
            Ok(c) => c,
            Err(e) => {
                let reaped = vec![false; procs.len()];
                tear_down(&mut procs, &reaped);
                return Err(anyhow!(e)).with_context(|| format!("spawn fleet rank {rank}"));
            }
        };
        let stdout = Arc::new(Mutex::new(Vec::new()));
        let stderr = Arc::new(Mutex::new(Vec::new()));
        let mut readers = Vec::with_capacity(2);
        if let Some(s) = child.stdout.take() {
            let buf = stdout.clone();
            let echo = opts.echo.then_some((rank, false));
            readers.push(
                std::thread::Builder::new()
                    .name(format!("glb-launch-out-{rank}"))
                    .spawn(move || stream_reader(s, buf, echo))
                    .expect("spawn stdout reader"),
            );
        }
        if let Some(s) = child.stderr.take() {
            let buf = stderr.clone();
            let echo = opts.echo.then_some((rank, true));
            readers.push(
                std::thread::Builder::new()
                    .name(format!("glb-launch-err-{rank}"))
                    .spawn(move || stream_reader(s, buf, echo))
                    .expect("spawn stderr reader"),
            );
        }
        procs.push(Proc { rank, child, stdout, stderr, readers });
    }

    let give_up = Instant::now() + opts.deadline;
    let n = procs.len();
    let mut reaped = vec![false; n];
    let mut died = vec![false; n];
    let mut deaths = 0usize;
    loop {
        let mut all_done = true;
        for i in 0..n {
            if reaped[i] {
                continue;
            }
            let polled = procs[i].child.try_wait();
            match polled {
                Ok(Some(status)) => {
                    reaped[i] = true;
                    if !status.success() {
                        // A spoke death within the tolerance budget is
                        // absorbed: the surviving ranks re-knit and the
                        // fleet runs on. Rank 0 (bootstrap + credit
                        // root) dying is always fatal.
                        if procs[i].rank != 0 && deaths < opts.tolerate_failures {
                            deaths += 1;
                            died[i] = true;
                            if opts.echo {
                                eprintln!(
                                    "[launcher] rank {} exited with {status}; \
                                     within --tolerate-failures, fleet continues",
                                    procs[i].rank
                                );
                            }
                            continue;
                        }
                        // Fail fast: don't let the survivors burn the
                        // rest of the deadline on a lost run.
                        let survivors = reaped.iter().filter(|r| !**r).count();
                        tear_down(&mut procs, &reaped);
                        bail!(
                            "fleet rank {rank} exited with {status} \
                             (killed {survivors} surviving rank(s))\n\
                             --- stdout (rank {rank})\n{out}\n\
                             --- stderr (rank {rank})\n{err}",
                            rank = procs[i].rank,
                            out = captured(&procs[i].stdout),
                            err = captured(&procs[i].stderr),
                        );
                    }
                }
                Ok(None) => all_done = false,
                Err(e) => {
                    tear_down(&mut procs, &reaped);
                    return Err(anyhow!(e)).context("poll fleet child");
                }
            }
        }
        if all_done {
            break;
        }
        if Instant::now() > give_up {
            tear_down(&mut procs, &reaped);
            // Unlike the fail-fast path there is no single culprit, so
            // attach every rank's output tail — a hang diagnosed from CI
            // logs has nothing else to go on.
            let mut detail = String::new();
            for p in &procs {
                detail.push_str(&format!(
                    "\n--- rank {} tail\nstdout:\n{}\nstderr:\n{}",
                    p.rank,
                    tail(&p.stdout, 10),
                    tail(&p.stderr, 10),
                ));
            }
            bail!("fleet timed out after {:?}{detail}", opts.deadline);
        }
        std::thread::sleep(Duration::from_millis(15));
    }

    let mut runs: Vec<RankRun> = procs
        .into_iter()
        .zip(died)
        .map(|(mut p, died)| {
            for h in p.readers.drain(..) {
                let _ = h.join();
            }
            RankRun {
                rank: p.rank,
                stdout: std::mem::take(&mut *p.stdout.lock().unwrap()),
                stderr: std::mem::take(&mut *p.stderr.lock().unwrap()),
                died,
            }
        })
        .collect();
    runs.sort_by_key(|r| r.rank);
    Ok(runs)
}

/// Every surviving rank's report line, parsed — survivors that emitted
/// none are an error (the app must be a tcp-fleet-capable command);
/// tolerated-dead ranks are skipped (their deaths are in the report's
/// `dead_ranks`, their work in the survivors' recovered totals).
fn collect_rank_reports(runs: &[RankRun]) -> Result<Vec<Value>> {
    runs.iter()
        .filter(|r| !r.died)
        .map(|r| {
            let line = report::find_rank_report(&r.stdout).ok_or_else(|| {
                anyhow!(
                    "rank {} exited cleanly but emitted no rank report \
                     (the launched app must support --transport tcp: uts|bc|fib|nqueens)",
                    r.rank
                )
            })?;
            report::parse_rank_report(line).with_context(|| format!("rank {} report", r.rank))
        })
        .collect()
}

/// `glb launch [--np N | --hosts FILE] [--port P] [--report OUT] <app> ...`
pub fn cmd_launch(rest: &[String]) -> Result<()> {
    let spec = spec::FleetSpec::parse(rest)?;
    let plan = spec.plan()?;
    println!(
        "launching {} rank(s) of `glb {}` (rendezvous port {})",
        plan.ranks,
        spec.app_argv.join(" "),
        plan.port
    );
    for (rank, line) in plan.cmdlines.iter().enumerate() {
        println!("  rank {rank}: {line}");
    }
    let t0 = Instant::now();
    let runs = run_fleet(
        plan.cmds,
        &EngineOpts {
            deadline: spec.deadline,
            echo: true,
            tolerate_failures: spec.tolerate_failures,
        },
    )?;
    let wall_time_s = t0.elapsed().as_secs_f64();
    if spec.app() == "serve" {
        // A resident fleet runs until a client retires it; its record is
        // rank 0's per-job report lines, not one rank report at exit.
        let jobs = report::extract_serve_reports(&runs[0].stdout)?;
        let fleet =
            report::aggregate_serve_fleet(plan.ranks, &spec.app_argv, jobs, wall_time_s);
        if let Some(path) = &spec.report {
            std::fs::write(path, fleet.render_pretty())
                .with_context(|| format!("write serve report {}", path.display()))?;
            println!("serve report -> {}", path.display());
        }
        println!(
            "resident fleet retired after {wall_time_s:.3}s: {} job(s) served",
            fleet.get("jobs_served").and_then(Value::as_u64).unwrap_or(0),
        );
        return Ok(());
    }
    let dead: Vec<usize> = runs.iter().filter(|r| r.died).map(|r| r.rank).collect();
    if !dead.is_empty() {
        println!("fleet absorbed {} rank death(s): {dead:?}", dead.len());
    }
    let reports = collect_rank_reports(&runs)?;
    let mut fleet =
        report::aggregate_fleet(spec.app(), &spec.app_argv, reports, wall_time_s, &dead)?;
    // Rank 0's stdout carries the per-interval fleet telemetry markers
    // on a `--stats` run; fold them into the report as a time series.
    // (runs are sorted by rank, and rank 0 is never a tolerated death.)
    let live = report::extract_live_stats(&runs[0].stdout)?;
    if !live.is_empty() {
        report::attach_live_stats(&mut fleet, live);
    }
    if let Some(path) = &spec.report {
        std::fs::write(path, fleet.render_pretty())
            .with_context(|| format!("write fleet report {}", path.display()))?;
        println!("fleet report -> {}", path.display());
    }
    println!(
        "fleet done in {wall_time_s:.3}s: result={} wire {} B out / {} B in",
        fleet.get("result").map(Value::render).unwrap_or_else(|| "?".into()),
        fleet.get("wire_tx_bytes").and_then(Value::as_u64).unwrap_or(0),
        fleet.get("wire_rx_bytes").and_then(Value::as_u64).unwrap_or(0),
    );
    Ok(())
}

/// The pinned perf-trajectory configurations. Keep these stable across
/// PRs: `bench/baseline.json` and the CI artifact diff only mean
/// something if successive runs measure the same work.
const BENCH_CONFIGS: &[(&str, &[&str])] = &[
    ("uts-d8", &["uts", "--depth", "8", "--transport", "tcp"]),
    ("bc-s7", &["bc", "--scale", "7", "--transport", "tcp"]),
];

/// `glb bench` — run the pinned configs through the launcher (warmed,
/// repeated), write `BENCH_glb.json`, and optionally diff against a
/// committed baseline: warn-only on wall-time drift, hard error on a
/// result mismatch (exact for integer results; float results tolerate
/// steal-schedule f64 summation noise — see
/// [`report::compare_with_baseline`]).
pub fn cmd_bench(rest: &[String]) -> Result<()> {
    let args = crate::cli::Args::parse(rest, &[])?;
    args.ensure_known(&["report", "baseline", "repeats", "warmup", "np", "band", "timeout"])?;
    let report_path = args.get("report").unwrap_or("BENCH_glb.json");
    let repeats: usize = args.parse_opt("repeats", 3usize)?;
    let warmup: usize = args.parse_opt("warmup", 1usize)?;
    let np: usize = args.parse_opt("np", 2usize)?;
    let band: f64 = args.parse_opt("band", 0.30f64)?;
    let timeout_s: u64 = args.parse_opt("timeout", 600u64)?;
    if repeats == 0 {
        bail!("--repeats must be >= 1");
    }

    let mut entries = Vec::new();
    for &(name, argv) in BENCH_CONFIGS {
        println!(
            "bench {name}: {warmup} warmup + {repeats} timed run(s) of `glb {}` over {np} rank(s)",
            argv.join(" ")
        );
        let mut raw: Vec<String> = vec!["--np".into(), np.to_string()];
        raw.push("--timeout".into());
        raw.push(timeout_s.to_string());
        raw.extend(argv.iter().map(|a| a.to_string()));
        let mut times: Vec<f64> = Vec::with_capacity(repeats);
        let mut last_fleet: Option<Value> = None;
        for i in 0..warmup + repeats {
            // A fresh plan per run: each fleet picks a fresh rendezvous
            // port, so back-to-back runs never trip over TIME_WAIT.
            let spec = spec::FleetSpec::parse(&raw)?;
            let plan = spec.plan()?;
            let t0 = Instant::now();
            let runs = run_fleet(
                plan.cmds,
                &EngineOpts { deadline: spec.deadline, echo: false, tolerate_failures: 0 },
            )
            .with_context(|| format!("bench {name} run {i}"))?;
            let wall = t0.elapsed().as_secs_f64();
            let reports = collect_rank_reports(&runs)?;
            let fleet = report::aggregate_fleet(spec.app(), &spec.app_argv, reports, wall, &[])?;
            if i < warmup {
                println!("  warmup {}: {wall:.3}s", i + 1);
            } else {
                println!("  run {}: {wall:.3}s", i - warmup + 1);
                times.push(wall);
            }
            last_fleet = Some(fleet);
        }
        let fleet = last_fleet.expect("at least one timed run");
        entries.push(report::bench_entry(name, np, warmup, repeats, &times, &fleet));
    }
    let bench = report::bench_report(entries);
    std::fs::write(report_path, bench.render_pretty())
        .with_context(|| format!("write bench report {report_path}"))?;
    println!("bench report -> {report_path}");

    if let Some(baseline) = args.get("baseline") {
        let warnings = report::compare_with_baseline(&bench, baseline, band)?;
        if warnings == 0 {
            println!("baseline {baseline}: all wall times within ±{:.0}%", band * 100.0);
        } else {
            println!(
                "baseline {baseline}: {warnings} deviation(s) beyond ±{:.0}% (warn-only gate)",
                band * 100.0
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sh(rank: usize, script: &str) -> RankCmd {
        let mut cmd = Command::new("sh");
        cmd.args(["-c", script]);
        RankCmd { rank, cmd }
    }

    #[test]
    fn engine_collects_output_per_rank() {
        let runs = run_fleet(
            vec![sh(0, "echo out-zero; echo err-zero >&2"), sh(1, "echo out-one")],
            &EngineOpts { deadline: Duration::from_secs(30), echo: false, tolerate_failures: 0 },
        )
        .expect("both ranks exit zero");
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].stdout, vec!["out-zero".to_string()]);
        assert_eq!(runs[0].stderr, vec!["err-zero".to_string()]);
        assert_eq!(runs[1].rank, 1);
        assert_eq!(runs[1].stdout, vec!["out-one".to_string()]);
    }

    #[test]
    fn engine_fails_fast_on_a_dying_rank() {
        // Rank 1 exits nonzero immediately; rank 0 would sleep 30s. The
        // engine must kill rank 0 and return long before either the
        // sleep or the deadline runs out.
        let t0 = Instant::now();
        let err = run_fleet(
            vec![sh(0, "sleep 30"), sh(1, "echo doomed >&2; exit 7")],
            &EngineOpts { deadline: Duration::from_secs(60), echo: false, tolerate_failures: 0 },
        )
        .expect_err("a nonzero rank must fail the fleet");
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("doomed"), "failure must carry the rank's stderr: {msg}");
        assert!(msg.contains("killed 1 surviving rank"), "{msg}");
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "fail-fast took {:?} — the engine waited for the survivors",
            t0.elapsed()
        );
    }

    #[test]
    fn engine_kills_a_wedged_fleet_at_the_deadline() {
        let t0 = Instant::now();
        let err = run_fleet(
            vec![sh(0, "sleep 30")],
            &EngineOpts { deadline: Duration::from_millis(300), echo: false, tolerate_failures: 0 },
        )
        .expect_err("a wedged fleet must time out");
        assert!(format!("{err:#}").contains("timed out"), "{err:#}");
        assert!(t0.elapsed() < Duration::from_secs(20), "kill took {:?}", t0.elapsed());
    }

    #[test]
    fn empty_fleet_is_rejected() {
        let err = run_fleet(
            vec![],
            &EngineOpts { deadline: Duration::from_secs(1), echo: false, tolerate_failures: 0 },
        )
        .expect_err("no ranks");
        assert!(format!("{err:#}").contains("at least one rank"));
    }

    #[test]
    fn engine_tolerates_spoke_deaths_within_the_budget() {
        // Rank 1 dies; with a budget of 1 the fleet completes, the dead
        // rank is flagged, and rank 0's output is intact.
        let runs = run_fleet(
            vec![sh(0, "sleep 0.2; echo done"), sh(1, "exit 9")],
            &EngineOpts { deadline: Duration::from_secs(30), echo: false, tolerate_failures: 1 },
        )
        .expect("one death is within the budget");
        assert!(runs[1].died, "the dead rank is flagged");
        assert!(!runs[0].died);
        assert_eq!(runs[0].stdout, vec!["done".to_string()]);

        // A second death exceeds the budget: fail fast as before.
        let t0 = Instant::now();
        let err = run_fleet(
            vec![sh(0, "sleep 30"), sh(1, "exit 9"), sh(2, "exit 9")],
            &EngineOpts { deadline: Duration::from_secs(60), echo: false, tolerate_failures: 1 },
        )
        .expect_err("the second death exceeds the budget");
        assert!(format!("{err:#}").contains("exited with"), "{err:#}");
        assert!(t0.elapsed() < Duration::from_secs(20), "fail-fast took {:?}", t0.elapsed());

        // Rank 0 (bootstrap + credit root) dying is never tolerable.
        let err = run_fleet(
            vec![sh(0, "exit 3"), sh(1, "sleep 30")],
            &EngineOpts { deadline: Duration::from_secs(60), echo: false, tolerate_failures: 5 },
        )
        .expect_err("rank 0 dying is always fatal");
        assert!(format!("{err:#}").contains("rank 0"), "{err:#}");
    }
}
