//! The paper's comparators (§3.2, §3.6).
//!
//! * [`legacy_bc`] — the legacy BC code: a **static randomized partition**
//!   of source vertices with no work stealing. "The legacy BC
//!   implementation randomizes which vertices to compute on each place,
//!   which effectively reduces the imbalance among places" (§3.6).
//! * [`legacy_uts`] — the hand-tuned UTS comparator, modelled two ways:
//!   as GLB with the tuned parameter set the X10 petascale code used
//!   (the paper's point is that the *library* matches the hand-tuned
//!   code), and as classic random-only distributed work stealing (the
//!   ablation quantifying what lifelines buy).
//! * [`static_uts`] — naive static UTS partitioning (splitting the root
//!   frontier once, no stealing) to demonstrate why UTS "is a case that
//!   static load-balancing does not work" (§2.5.1).

pub mod legacy_bc;
pub mod legacy_uts;
pub mod static_uts;

pub use legacy_bc::{run_legacy_bc_sim, run_legacy_bc_threads, LegacyBcOutput};
pub use legacy_uts::{legacy_uts_params, random_only_params};
pub use static_uts::run_static_uts_sim;
