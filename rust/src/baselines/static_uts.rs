//! Naive static UTS: split the frontier once at startup, never steal.
//!
//! Demonstrates the paper's §2.5.1 claim that "UTS is a case that static
//! load-balancing does not work": subtree sizes under the geometric law
//! are wildly uneven and unknowable in advance, so the makespan is
//! dominated by whichever place drew the largest subtree.

use crate::apps::uts::{UtsBag, UtsParams, UtsTree};
use crate::glb::task_bag::TaskBag;

/// Result of an analytic static-UTS run on the virtual clock.
#[derive(Debug, Clone)]
pub struct StaticUtsOutput {
    pub total_nodes: u64,
    /// Per-place nodes counted.
    pub per_place: Vec<u64>,
    /// Virtual makespan (slowest place), ns.
    pub elapsed_ns: u64,
}

/// Split the root frontier round-robin into `p` shares and count each to
/// completion with zero communication.
pub fn run_static_uts_sim(up: &UtsParams, p: usize, ns_per_node: f64) -> StaticUtsOutput {
    let tree = UtsTree::new(*up);
    // Deal the root's children ranges out by repeated halving: bag 0
    // holds the root, then each empty place grabs half of the largest.
    let mut bags: Vec<UtsBag> = Vec::with_capacity(p);
    bags.push(UtsBag::with_root(&tree));
    while bags.len() < p {
        // Find the widest bag and halve it (best case for static).
        let widest = (0..bags.len()).max_by_key(|&i| bags[i].size()).unwrap();
        match bags[widest].split() {
            Some(half) => bags.push(half),
            None => bags.push(UtsBag::new()),
        }
    }
    let mut per_place = Vec::with_capacity(p);
    let mut total = 1u64; // root
    for mut bag in bags {
        let mut c = 0u64;
        loop {
            let (k, more) = bag.expand_some(&tree, 1 << 16);
            c += k;
            if !more {
                break;
            }
        }
        total += c;
        per_place.push(c);
    }
    let elapsed_ns = per_place.iter().map(|&c| (c as f64 * ns_per_node) as u64).max().unwrap_or(0);
    StaticUtsOutput { total_nodes: total, per_place, elapsed_ns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::sequential_count;
    use crate::util::stats::{mean, stddev};

    #[test]
    fn static_counts_the_same_tree() {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
        let expect = sequential_count(&up);
        for &p in &[1usize, 4, 16] {
            let out = run_static_uts_sim(&up, p, 100.0);
            assert_eq!(out.total_nodes, expect, "p={p}");
        }
    }

    #[test]
    fn static_is_badly_imbalanced() {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 8 };
        let out = run_static_uts_sim(&up, 16, 100.0);
        let xs: Vec<f64> = out.per_place.iter().map(|&c| c as f64).collect();
        let rel = stddev(&xs) / mean(&xs).max(1e-12);
        assert!(rel > 0.5, "geometric subtrees should spread wildly, rel-std={rel:.3}");
    }

    #[test]
    fn static_makespan_exceeds_ideal() {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 8 };
        let p = 16;
        let out = run_static_uts_sim(&up, p, 100.0);
        let ideal_ns = (out.total_nodes as f64 * 100.0 / p as f64) as u64;
        assert!(
            out.elapsed_ns > 2 * ideal_ns,
            "static makespan {} should be >2x ideal {}",
            out.elapsed_ns,
            ideal_ns
        );
    }
}
