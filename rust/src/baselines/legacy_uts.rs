//! UTS comparators.
//!
//! The paper's "legacy" UTS is the hand-tuned lifeline work-stealer that
//! won the HPCC 2012 award [25] — algorithmically the same lifeline
//! scheme as GLB, with hand-picked constants. We model it as GLB with
//! the petascale code's tuning (larger chunks, two random victims, a
//! binary lifeline cube): the paper's claim that "UTS-G achieves similar
//! (or better) performance compared to UTS" is a claim about *library
//! overhead*, not about a different algorithm.
//!
//! [`random_only_params`] is the classic random-victim work stealing
//! (Dinan et al.-style, no lifelines) used as the ablation baseline.

use crate::glb::params::{GlbParams, StealPolicy};

/// The hand-tuned legacy configuration: two random victims per episode
/// and a binary lifeline cube (the petascale UTS code's choices), with a
/// chunk size in the same regime as the library default. Its throughput
/// should *track* UTS-G (Figs 2–4: "UTS-G achieves similar (or better)
/// performance compared to UTS").
pub fn legacy_uts_params() -> GlbParams {
    GlbParams::default().with_n(1024).with_w(2).with_l(2)
}

/// Classic random-only work stealing: `rounds` rounds of `w` random
/// victims, no lifelines.
pub fn random_only_params(w: usize, rounds: usize) -> GlbParams {
    GlbParams::default().with_w(w).with_policy(StealPolicy::RandomOnly { rounds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::{sequential_count, UtsParams, UtsQueue};
    use crate::glb::task_queue::SumReducer;
    use crate::glb::GlbConfig;
    use crate::place::run_threads;
    use crate::sim::{run_sim, CostModel, BGQ};

    #[test]
    fn legacy_params_count_correctly() {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
        let expect = sequential_count(&up);
        let cfg = GlbConfig::new(4, legacy_uts_params().with_n(64));
        let out = run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(out.result, expect);
    }

    #[test]
    fn random_only_still_counts_correctly() {
        // The ablation policy must stay *correct* — only slower.
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
        let expect = sequential_count(&up);
        for &p in &[2usize, 8] {
            let cfg = GlbConfig::new(p, random_only_params(2, 4).with_n(64));
            let out =
                run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
            assert_eq!(out.result, expect, "p={p}");
        }
    }

    #[test]
    fn random_only_uses_no_lifelines() {
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 6 };
        let cfg = GlbConfig::new(8, random_only_params(1, 3).with_n(32));
        let (out, _) = run_sim(
            &cfg,
            &BGQ,
            CostModel::new(180.0, 60, 28),
            |_, _| UtsQueue::new(up),
            |q| q.init_root(),
            &SumReducer,
        );
        let t = out.log.total();
        assert_eq!(t.lifeline_steals_sent, 0);
        assert_eq!(t.lifeline_steals_perpetrated, 0);
        assert!(t.random_steals_sent > 0);
    }

    #[test]
    fn lifelines_beat_random_only_at_scale() {
        // The ablation shape: with many places and a deep tree, lifeline
        // stealing finishes sooner in virtual time than random-only with
        // the same budget, because starved places are re-fed instead of
        // idling forever.
        let up = UtsParams { b0: 4.0, seed: 19, max_depth: 7 };
        let cost = CostModel::new(180.0, 60, 28);
        let p = 64;
        let lifeline_cfg = GlbConfig::new(p, GlbParams::default().with_n(128).with_l(2));
        let random_cfg = GlbConfig::new(p, random_only_params(1, 1).with_n(128));
        let (a, _) = run_sim(&lifeline_cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        let (b, _) = run_sim(&random_cfg, &BGQ, cost, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
        assert_eq!(a.result, b.result);
        assert!(
            a.elapsed_ns < b.elapsed_ns,
            "lifelines {} should beat random-only {}",
            a.elapsed_ns,
            b.elapsed_ns
        );
    }
}
