//! Legacy BC: static randomized source partition, no stealing (§3.6).
//!
//! Each place receives a random subset of the N sources (a seeded global
//! shuffle sliced into P equal chunks) and computes them to completion
//! with zero communication; an allreduce folds the betweenness maps. The
//! per-place busy times are the bars of the paper's workload-distribution
//! figures (Figs 6, 8, 10) — their spread is what GLB flattens.

use std::sync::Arc;
use std::time::Instant;

use crate::apps::bc::{brandes_source, BrandesScratch, Graph};
use crate::util::SplitMix64;

/// Output of a legacy-BC run.
#[derive(Debug, Clone)]
pub struct LegacyBcOutput {
    /// Element-wise-summed betweenness map.
    pub bc: Vec<f64>,
    /// Per-place busy time, ns (wall clock under threads, virtual under
    /// the analytic simulator).
    pub busy_ns: Vec<u64>,
    /// Per-place edges traversed.
    pub units: Vec<u64>,
    /// Makespan, ns (the slowest place — static schedules end when the
    /// last place finishes).
    pub elapsed_ns: u64,
}

impl LegacyBcOutput {
    /// Aggregate throughput in edges/s.
    pub fn units_per_sec(&self) -> f64 {
        let total: u64 = self.units.iter().sum();
        if self.elapsed_ns == 0 {
            return 0.0;
        }
        total as f64 * 1e9 / self.elapsed_ns as f64
    }
}

/// The randomized static assignment: a seeded shuffle of `0..n` sliced
/// into `p` equal chunks.
pub fn randomized_assignment(n: usize, p: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut vertices: Vec<u32> = (0..n as u32).collect();
    SplitMix64::new(seed).shuffle(&mut vertices);
    let mut out = vec![Vec::new(); p];
    for (i, v) in vertices.into_iter().enumerate() {
        out[i % p].push(v);
    }
    out
}

/// Run legacy BC with real threads (wall-clock busy times).
pub fn run_legacy_bc_threads(g: &Arc<Graph>, p: usize, seed: u64) -> LegacyBcOutput {
    let assign = randomized_assignment(g.n(), p, seed);
    let t0 = Instant::now();
    let handles: Vec<_> = assign
        .into_iter()
        .map(|sources| {
            let g = g.clone();
            std::thread::spawn(move || {
                let t = Instant::now();
                let mut bc = vec![0.0; g.n()];
                let mut scratch = BrandesScratch::new(g.n());
                let mut units = 0u64;
                for &s in &sources {
                    units += brandes_source(&g, s, &mut bc, &mut scratch);
                }
                (bc, units, t.elapsed().as_nanos() as u64)
            })
        })
        .collect();
    let mut bc = vec![0.0; g.n()];
    let mut busy_ns = Vec::with_capacity(p);
    let mut units = Vec::with_capacity(p);
    for h in handles {
        let (b, u, t) = h.join().expect("legacy place panicked");
        for (acc, x) in bc.iter_mut().zip(b) {
            *acc += x;
        }
        busy_ns.push(t);
        units.push(u);
    }
    LegacyBcOutput { bc, busy_ns, units, elapsed_ns: t0.elapsed().as_nanos() as u64 }
}

/// Run legacy BC analytically on the virtual clock: with zero
/// communication the makespan is exactly the slowest place's work. Uses
/// the same `ns_per_unit` cost model as the GLB simulator so the two are
/// comparable (Figs 5/7/9).
pub fn run_legacy_bc_sim(
    g: &Graph,
    p: usize,
    seed: u64,
    ns_per_unit: f64,
    compute_scale: f64,
) -> LegacyBcOutput {
    let assign = randomized_assignment(g.n(), p, seed);
    let mut bc = vec![0.0; g.n()];
    let mut scratch = BrandesScratch::new(g.n());
    let mut busy_ns = Vec::with_capacity(p);
    let mut units = Vec::with_capacity(p);
    for sources in assign {
        let mut u = 0u64;
        for &s in &sources {
            u += brandes_source(g, s, &mut bc, &mut scratch);
        }
        busy_ns.push((u as f64 * ns_per_unit / compute_scale) as u64);
        units.push(u);
    }
    let elapsed_ns = busy_ns.iter().copied().max().unwrap_or(0);
    LegacyBcOutput { bc, busy_ns, units, elapsed_ns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::bc::{sequential_bc, RmatParams};
    use crate::util::stats::{mean, stddev};

    #[test]
    fn assignment_is_a_partition() {
        let a = randomized_assignment(100, 7, 3);
        assert_eq!(a.len(), 7);
        let mut all: Vec<u32> = a.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn threads_match_sequential() {
        let g = Arc::new(Graph::rmat(RmatParams { scale: 6, ..Default::default() }));
        let (expect, _) = sequential_bc(&g);
        let out = run_legacy_bc_threads(&g, 4, 42);
        for (i, (x, y)) in out.bc.iter().zip(&expect).enumerate() {
            assert!((x - y).abs() < 1e-9, "bc[{i}]");
        }
        assert_eq!(out.busy_ns.len(), 4);
    }

    #[test]
    fn sim_match_and_makespan() {
        let g = Graph::rmat(RmatParams { scale: 6, ..Default::default() });
        let (expect, _) = sequential_bc(&g);
        let out = run_legacy_bc_sim(&g, 8, 42, 2.0, 1.0);
        for (x, y) in out.bc.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-9);
        }
        assert_eq!(out.elapsed_ns, *out.busy_ns.iter().max().unwrap());
    }

    #[test]
    fn randomization_reduces_imbalance() {
        // §3.6(2): random assignment beats contiguous blocks on skewed
        // work. Compare busy-time spreads on the triangular graph.
        let g = Graph::triangular(128);
        let p = 8;
        // Contiguous assignment.
        let mut contiguous = vec![0u64; p];
        {
            let mut bc = vec![0.0; g.n()];
            let mut sc = BrandesScratch::new(g.n());
            for (i, chunk) in (0..g.n() as u32).collect::<Vec<_>>().chunks(g.n() / p).enumerate()
            {
                for &s in chunk {
                    contiguous[i.min(p - 1)] += brandes_source(&g, s, &mut bc, &mut sc);
                }
            }
        }
        let rand_out = run_legacy_bc_sim(&g, p, 7, 1.0, 1.0);
        let c: Vec<f64> = contiguous.iter().map(|&x| x as f64).collect();
        let r: Vec<f64> = rand_out.units.iter().map(|&x| x as f64).collect();
        let rel = |xs: &[f64]| stddev(xs) / mean(xs).max(1e-12);
        assert!(
            rel(&r) < rel(&c),
            "randomized spread {:.3} should beat contiguous {:.3}",
            rel(&r),
            rel(&c)
        );
    }
}
