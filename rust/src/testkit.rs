//! A small property-testing kit (the offline environment has no
//! `proptest`): seeded random case generation with failure reporting.
//!
//! [`check_cases`] runs a property over `iters` generated cases; on
//! failure it panics with the *seed* of the failing case so the exact
//! input replays deterministically:
//!
//! ```
//! use glb::testkit::{check_cases, Gen};
//! check_cases("sum-commutes", 100, |g: &mut Gen| {
//!     let (a, b) = (g.u64(0..1000), g.u64(0..1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::SplitMix64;

/// Random case generator handed to properties.
pub struct Gen {
    rng: SplitMix64,
    /// The seed that reproduces this case.
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), seed }
    }

    /// Uniform u64 in `[lo, hi)`.
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end);
        range.start + self.rng.next_below(range.end - range.start)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Bernoulli.
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0..xs.len())]
    }

    /// A vector of `len` values drawn by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `prop` over `iters` seeded cases. Honours `GLB_PROP_SEED` (replay
/// a single failing case) and `GLB_PROP_ITERS` (override the count).
pub fn check_cases(name: &str, iters: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Ok(s) = std::env::var("GLB_PROP_SEED") {
        let seed: u64 = s.parse().expect("GLB_PROP_SEED must be a u64");
        let mut g = Gen::from_seed(seed);
        prop(&mut g);
        return;
    }
    let iters = std::env::var("GLB_PROP_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(iters);
    for i in 0..iters {
        // Derive case seeds from the property name so distinct properties
        // explore distinct inputs.
        let base = name.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
        let seed = crate::util::rng::mix64(base ^ i);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::from_seed(seed);
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {i} (replay with GLB_PROP_SEED={seed}):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_ranges_hold() {
        let mut g = Gen::from_seed(1);
        for _ in 0..1000 {
            let v = g.u64(5..10);
            assert!((5..10).contains(&v));
            let u = g.usize(0..3);
            assert!(u < 3);
        }
    }

    #[test]
    fn check_cases_passes_good_property() {
        check_cases("addition-commutes", 50, |g| {
            let (a, b) = (g.u64(0..1000), g.u64(0..1000));
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "replay with GLB_PROP_SEED=")]
    fn check_cases_reports_seed_on_failure() {
        check_cases("always-fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn choose_and_vec() {
        let mut g = Gen::from_seed(2);
        let v = g.vec(10, |g| g.u64(0..5));
        assert_eq!(v.len(), 10);
        let x = *g.choose(&[1, 2, 3]);
        assert!([1, 2, 3].contains(&x));
    }
}
