//! A small property-testing kit (the offline environment has no
//! `proptest`): seeded random case generation with failure reporting,
//! plus [`fleet`] — a process-fleet launcher for multi-process socket
//! transport tests.
//!
//! [`check_cases`] runs a property over `iters` generated cases; on
//! failure it panics with the *seed* of the failing case so the exact
//! input replays deterministically:
//!
//! ```
//! use glb::testkit::{check_cases, Gen};
//! check_cases("sum-commutes", 100, |g: &mut Gen| {
//!     let (a, b) = (g.u64(0..1000), g.u64(0..1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::SplitMix64;

/// Random case generator handed to properties.
pub struct Gen {
    rng: SplitMix64,
    /// The seed that reproduces this case.
    pub seed: u64,
}

impl Gen {
    pub fn from_seed(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), seed }
    }

    /// Uniform u64 in `[lo, hi)`.
    pub fn u64(&mut self, range: std::ops::Range<u64>) -> u64 {
        assert!(range.start < range.end);
        range.start + self.rng.next_below(range.end - range.start)
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    /// Bernoulli.
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0..xs.len())]
    }

    /// A vector of `len` values drawn by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Self) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `prop` over `iters` seeded cases. Honours `GLB_PROP_SEED` (replay
/// a single failing case) and `GLB_PROP_ITERS` (override the count).
pub fn check_cases(name: &str, iters: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    if let Ok(s) = std::env::var("GLB_PROP_SEED") {
        let seed: u64 = s.parse().expect("GLB_PROP_SEED must be a u64");
        let mut g = Gen::from_seed(seed);
        prop(&mut g);
        return;
    }
    let iters = std::env::var("GLB_PROP_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(iters);
    for i in 0..iters {
        // Derive case seeds from the property name so distinct properties
        // explore distinct inputs.
        let base = name.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
        let seed = crate::util::rng::mix64(base ^ i);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::from_seed(seed);
            prop(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {i} (replay with GLB_PROP_SEED={seed}):\n{msg}"
            );
        }
    }
}

pub mod fleet {
    //! Deterministic multi-process test harness for the socket transport
    //! ([`crate::place::socket`]).
    //!
    //! A fleet test re-executes **its own test binary** once per rank
    //! (the classic self-exec pattern: `current_exe()` + `--exact
    //! <test>` + role environment variables), so the children run the
    //! exact code under test with no extra binaries to build. The test
    //! function checks [`child_role`] first: `Some` means "I am rank N
    //! of a fleet — run the child body and [`emit`] my `RunLog` fields";
    //! `None` means "I am the orchestrator — [`run`] the fleet and
    //! assert over the collected [`ProcLog`]s".
    //!
    //! Children print their results as single `GLB-FLEET key=value ...`
    //! lines on stdout; everything else (libtest chatter) is ignored by
    //! the parser. A watchdog kills the fleet after a deadline so a
    //! protocol hang fails the test instead of wedging CI.

    use std::collections::HashMap;
    use std::process::Command;
    use std::time::Duration;

    use crate::launch::{run_fleet, EngineOpts, RankCmd};

    const ENV_RANK: &str = "GLB_FLEET_RANK";
    const ENV_RANKS: &str = "GLB_FLEET_RANKS";
    const ENV_PORT: &str = "GLB_FLEET_PORT";
    const ENV_HOST: &str = "GLB_FLEET_HOST";
    const ENV_BIND: &str = "GLB_FLEET_BIND";

    /// Marker prefix of a child's result line on stdout.
    pub const LOG_PREFIX: &str = "GLB-FLEET";

    /// This process's role in a fleet, if it was spawned as a child.
    #[derive(Debug, Clone)]
    pub struct ChildRole {
        pub rank: usize,
        pub ranks: usize,
        pub port: u16,
        /// Rank 0's advertised host (what the fleet dials).
        pub host: String,
        /// Rank 0's bind address, when split from `host` — the harness
        /// always splits (wildcard bind, loopback advertise) so every
        /// fleet test exercises the bind/advertise separation.
        pub bind: Option<String>,
    }

    /// `Some` iff the process was spawned by [`run`] (fleet environment
    /// variables present and well-formed).
    pub fn child_role() -> Option<ChildRole> {
        let rank = std::env::var(ENV_RANK).ok()?.parse().ok()?;
        let ranks = std::env::var(ENV_RANKS).ok()?.parse().ok()?;
        let port = std::env::var(ENV_PORT).ok()?.parse().ok()?;
        let host = std::env::var(ENV_HOST).unwrap_or_else(|_| "127.0.0.1".into());
        let bind = std::env::var(ENV_BIND).ok();
        Some(ChildRole { rank, ranks, port, host, bind })
    }

    /// Pick a currently-free localhost port for the fleet rendezvous.
    /// (Bound briefly, then released for rank 0 to claim — the window is
    /// tiny and ephemeral ports make collisions vanishingly rare.) The
    /// probe itself lives with the launcher, which needs it for the same
    /// job ([`crate::launch::spec`]).
    pub fn free_port() -> u16 {
        crate::launch::spec::free_port().expect("bind ephemeral port")
    }

    /// Print a child's result line for the orchestrator to collect.
    pub fn emit(rank: usize, fields: &[(&str, String)]) {
        let mut line = format!("{LOG_PREFIX} rank={rank}");
        for (k, v) in fields {
            line.push(' ');
            line.push_str(k);
            line.push('=');
            line.push_str(v);
        }
        println!("{line}");
    }

    /// One child's parsed result line.
    #[derive(Debug, Clone)]
    pub struct ProcLog {
        pub rank: usize,
        fields: HashMap<String, String>,
    }

    impl ProcLog {
        pub fn get(&self, key: &str) -> Option<&str> {
            self.fields.get(key).map(|s| s.as_str())
        }

        /// A required numeric field.
        pub fn u64(&self, key: &str) -> u64 {
            self.get(key)
                .unwrap_or_else(|| panic!("fleet log of rank {} lacks {key:?}", self.rank))
                .parse()
                .unwrap_or_else(|e| panic!("fleet log field {key:?}: {e}"))
        }
    }

    fn parse_line(line: &str) -> ProcLog {
        let mut fields = HashMap::new();
        for pair in line.split_whitespace().skip(1) {
            if let Some((k, v)) = pair.split_once('=') {
                fields.insert(k.to_string(), v.to_string());
            }
        }
        let rank = fields
            .get("rank")
            .and_then(|r| r.parse().ok())
            .unwrap_or_else(|| panic!("fleet log line lacks a rank: {line:?}"));
        ProcLog { rank, fields }
    }

    /// Spawn `ranks` children of the current test binary re-entering
    /// `exact_test`, wait for all of them, and return their result logs
    /// sorted by rank. Panics if any child fails or emits no result
    /// line.
    ///
    /// The spawn/stream/watchdog loop is the launcher engine
    /// ([`crate::launch::run_fleet`]) — the same code path `glb launch`
    /// and `glb bench` drive — so its fail-fast semantics hold here too:
    /// the first rank to exit nonzero kills the survivors and fails the
    /// test immediately instead of waiting out `deadline`.
    pub fn run(exact_test: &str, ranks: usize, port: u16, deadline: Duration) -> Vec<ProcLog> {
        assert!(ranks >= 1);
        let exe = std::env::current_exe().expect("current_exe");
        let cmds: Vec<RankCmd> = (0..ranks)
            .map(|rank| {
                // `--include-ignored`: fleet tests are `#[ignore]`d so the
                // plain `cargo test` pass doesn't race several process
                // fleets at once; the child must still run them.
                let mut cmd = Command::new(&exe);
                cmd.args([
                    exact_test,
                    "--exact",
                    "--include-ignored",
                    "--test-threads",
                    "1",
                    "--nocapture",
                ])
                .env(ENV_RANK, rank.to_string())
                .env(ENV_RANKS, ranks.to_string())
                .env(ENV_PORT, port.to_string())
                .env(ENV_HOST, "127.0.0.1")
                .env(ENV_BIND, "0.0.0.0");
                RankCmd { rank, cmd }
            })
            .collect();
        let runs = run_fleet(cmds, &EngineOpts { deadline, echo: false, tolerate_failures: 0 })
            .unwrap_or_else(|e| panic!("fleet {exact_test:?} failed: {e:#}"));

        let mut logs: Vec<ProcLog> = Vec::with_capacity(ranks);
        for r in &runs {
            let line = r.stdout.iter().find(|l| l.starts_with(LOG_PREFIX)).unwrap_or_else(|| {
                panic!(
                    "fleet rank {} emitted no {LOG_PREFIX} line:\n{}",
                    r.rank,
                    r.stdout.join("\n")
                )
            });
            let log = parse_line(line);
            assert_eq!(log.rank, r.rank, "child reported the wrong rank");
            logs.push(log);
        }
        logs.sort_by_key(|l| l.rank);
        logs
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn log_lines_roundtrip() {
            let log = parse_line("GLB-FLEET rank=2 result=1023 loot=4");
            assert_eq!(log.rank, 2);
            assert_eq!(log.u64("result"), 1023);
            assert_eq!(log.u64("loot"), 4);
            assert_eq!(log.get("missing"), None);
        }

        #[test]
        fn non_children_have_no_role() {
            // The test harness itself is never spawned with the fleet
            // environment, so the orchestrator path must be taken.
            assert!(child_role().is_none());
        }

        #[test]
        fn free_ports_are_usable() {
            let p = free_port();
            assert_ne!(p, 0);
            // The port was released and can be bound again immediately.
            std::net::TcpListener::bind(("127.0.0.1", p)).expect("rebind freed port");
        }
    }
}

pub mod chaos {
    //! Deterministic fault injection for the crash-tolerance tests: kill
    //! a chosen fleet rank at a chosen protocol phase, hard enough to
    //! look exactly like a machine loss (SIGKILL — no unwinding, no
    //! socket shutdown handshakes, no exit handlers).
    //!
    //! The runtime plants [`die_point`] calls at the interesting sites
    //! (mid-steal, while-idle, during-deposit). They are no-ops unless
    //! the environment arms this process:
    //!
    //! * `GLB_CHAOS_DIE` — the die-point name ([`MID_STEAL`],
    //!   [`WHILE_IDLE`], [`DURING_DEPOSIT`]);
    //! * `GLB_CHAOS_RANK` — the fleet rank that dies (every rank of a
    //!   launched fleet inherits the same environment, so the rank check
    //!   selects the victim);
    //! * `GLB_CHAOS_AFTER` — die on the Nth hit of the point (default 1,
    //!   which is also the setting the exactness argument in
    //!   `place/socket.rs` covers).
    //!
    //! [`arm`] latches the plan once per process (first caller wins —
    //! a real fleet process runs exactly one rank, and the in-process
    //! multi-rank tests never set the environment).

    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::OnceLock;

    /// Die right after putting a steal request on the wire.
    pub const MID_STEAL: &str = "mid-steal";
    /// Die at the idle wait, after depositing all credit.
    pub const WHILE_IDLE: &str = "while-idle";
    /// Die right after writing a credit deposit to the root.
    pub const DURING_DEPOSIT: &str = "during-deposit";

    pub const ENV_DIE: &str = "GLB_CHAOS_DIE";
    pub const ENV_RANK: &str = "GLB_CHAOS_RANK";
    pub const ENV_AFTER: &str = "GLB_CHAOS_AFTER";

    struct Plan {
        point: String,
        after: u64,
    }

    static PLAN: OnceLock<Option<Plan>> = OnceLock::new();
    static HITS: AtomicU64 = AtomicU64::new(0);

    /// Latch this process's fault plan from the environment. Called by
    /// the socket runtime with its fleet rank; a no-op unless
    /// `GLB_CHAOS_RANK` names exactly that rank.
    pub fn arm(rank: usize) {
        let _ = PLAN.set(plan_from_env(rank));
    }

    fn plan_from_env(rank: usize) -> Option<Plan> {
        let point = std::env::var(ENV_DIE).ok()?;
        let target: usize = std::env::var(ENV_RANK).ok()?.parse().ok()?;
        if target != rank {
            return None;
        }
        let after = std::env::var(ENV_AFTER).ok().and_then(|s| s.parse().ok()).unwrap_or(1);
        Some(Plan { point, after })
    }

    /// A possible crash site. No-op unless [`arm`] matched this process
    /// and `point` is the armed one; the `GLB_CHAOS_AFTER`th matching
    /// hit never returns.
    pub fn die_point(point: &str) {
        let Some(plan) = PLAN.get().and_then(|p| p.as_ref()) else { return };
        if plan.point != point {
            return;
        }
        if HITS.fetch_add(1, Ordering::SeqCst) + 1 >= plan.after {
            die();
        }
    }

    /// SIGKILL ourselves — the one signal that cannot be caught, so the
    /// death is indistinguishable from a machine loss. `abort` is the
    /// (also cleanup-free) fallback for environments without `sh`.
    fn die() -> ! {
        let pid = std::process::id();
        let _ = std::process::Command::new("sh")
            .arg("-c")
            .arg(format!("kill -9 {pid}"))
            .status();
        std::process::abort();
    }

    #[cfg(test)]
    mod tests {
        #[test]
        fn unarmed_die_points_are_no_ops() {
            // The test environment never sets GLB_CHAOS_*, so arming and
            // hitting every point must be survivable.
            super::arm(0);
            super::die_point(super::MID_STEAL);
            super::die_point(super::WHILE_IDLE);
            super::die_point(super::DURING_DEPOSIT);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_ranges_hold() {
        let mut g = Gen::from_seed(1);
        for _ in 0..1000 {
            let v = g.u64(5..10);
            assert!((5..10).contains(&v));
            let u = g.usize(0..3);
            assert!(u < 3);
        }
    }

    #[test]
    fn check_cases_passes_good_property() {
        check_cases("addition-commutes", 50, |g| {
            let (a, b) = (g.u64(0..1000), g.u64(0..1000));
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "replay with GLB_PROP_SEED=")]
    fn check_cases_reports_seed_on_failure() {
        check_cases("always-fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn choose_and_vec() {
        let mut g = Gen::from_seed(2);
        let v = g.vec(10, |g| g.u64(0..5));
        assert_eq!(v.len(), 10);
        let x = *g.choose(&[1, 2, 3]);
        assert!([1, 2, 3].contains(&x));
    }
}
