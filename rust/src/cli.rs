//! The `glb` launcher CLI (hand-rolled: the offline registry has no
//! `clap`).
//!
//! ```text
//! glb uts      --places 8 --depth 10 [--threads|--sim --arch bgq] [--log]
//! glb bc       --places 8 --scale 10 [--engine sparse|dense] [--log]
//! glb fib      --fib-n 30 --places 4
//! glb nqueens  --board 10 --places 4
//! glb fig      --id 2..=10 [--csv] [--places 1,2,4,...]
//! glb launch   --np 4 uts --depth 10 [--report fleet.json]
//! glb serve    --rank R --peers N [--port 7117]
//! glb submit   uts --depth 8 [--repeat 100] [--shutdown]
//! glb bench | calibrate | smoke | lint
//! ```
//!
//! See [`USAGE`] for the full option reference (also `glb --help`).

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed `--key value` / `--flag` arguments plus positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0] and the subcommand). Options
    /// listed in `flags` take no value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                if flag_names.contains(&name) {
                    if inline.is_some() {
                        bail!("--{name} takes no value");
                    }
                    out.flags.push(name.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} needs a value"))?
                            .clone(),
                    };
                    out.options.insert(name.to_string(), v);
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn parse_opt<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} {v}: {e}")),
        }
    }

    /// Comma-separated usize list (e.g. `--places 1,2,4,8`).
    pub fn parse_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow!("--{name} {s}: {e}")))
                .collect(),
        }
    }

    /// Reject unknown options (catch typos early).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

/// Which execution substrate a command should run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// One OS thread per place in this process (default).
    Thread,
    /// Deterministic discrete-event simulation.
    Sim,
    /// One OS process per GLB node over TCP ([`crate::place::socket`]).
    Tcp,
}

/// TCP fleet membership (`--transport tcp` only).
#[derive(Debug, Clone)]
pub struct TcpOpts {
    /// This process's rank (0 = the bootstrap/discovery rank).
    pub rank: usize,
    /// Total processes in the fleet.
    pub peers: usize,
    /// Rank 0's rendezvous port.
    pub port: u16,
    /// Rank 0's *advertised* host (what the fleet dials).
    pub host: String,
    /// Rank 0's *bind* address. Defaults to `0.0.0.0` when `--host` is
    /// given (an advertised public address is often not locally
    /// bindable), else to `host` itself.
    pub bind: Option<String>,
    /// This rank's advertised mesh IP (spokes on multi-homed hosts).
    /// `None` advertises the interface this host reaches rank 0 from.
    pub advertise: Option<String>,
    /// How many spoke crashes the fleet absorbs before giving up
    /// (0 = any rank death is fatal, the pre-fault-tolerance behaviour).
    pub tolerate_failures: usize,
    /// Live telemetry sample interval in ms (`--stats-interval`);
    /// `None` keeps the telemetry plane disarmed.
    pub stats_interval_ms: Option<u64>,
    /// Closed-loop adaptive retuning from the live gauges (`--adapt`).
    pub adapt: bool,
}

/// Resolve `--transport tcp|thread|sim`; the legacy `--sim` / `--threads`
/// flags keep working when `--transport` is absent.
pub fn transport_from(args: &Args) -> Result<TransportKind> {
    match args.get("transport") {
        Some("tcp") => Ok(TransportKind::Tcp),
        Some("thread") | Some("threads") => Ok(TransportKind::Thread),
        Some("sim") => Ok(TransportKind::Sim),
        Some(other) => bail!("unknown --transport {other} (tcp|thread|sim)"),
        None if args.flag("sim") => Ok(TransportKind::Sim),
        None => Ok(TransportKind::Thread),
    }
}

/// Parse `--rank`/`--peers`/`--port`/`--host` for a TCP fleet member.
pub fn tcp_opts_from(args: &Args) -> Result<TcpOpts> {
    let peers: usize = args
        .get("peers")
        .context("--transport tcp needs --peers (total processes in the fleet)")?
        .parse()
        .map_err(|e| anyhow!("--peers: {e}"))?;
    if peers == 0 {
        bail!("--peers must be >= 1");
    }
    let rank: usize = args
        .get("rank")
        .context("--transport tcp needs --rank (this process's rank, 0-based)")?
        .parse()
        .map_err(|e| anyhow!("--rank: {e}"))?;
    if rank >= peers {
        bail!("--rank {rank} out of range for --peers {peers}");
    }
    let explicit_host = args.get("host");
    let bind = match args.get("bind") {
        Some(b) => Some(b.to_string()),
        None => explicit_host.map(|_| "0.0.0.0".to_string()),
    };
    Ok(TcpOpts {
        rank,
        peers,
        port: args.parse_opt("port", 7117u16)?,
        host: explicit_host.unwrap_or("127.0.0.1").to_string(),
        bind,
        advertise: args.get("advertise").map(String::from),
        tolerate_failures: args.parse_opt("tolerate-failures", 0usize)?,
        stats_interval_ms: match args.get("stats-interval") {
            None => None,
            Some(v) => {
                let ms: u64 = v.parse().map_err(|e| anyhow!("--stats-interval {v}: {e}"))?;
                if ms == 0 {
                    bail!("--stats-interval must be >= 1 (milliseconds)");
                }
                Some(ms)
            }
        },
        adapt: args.flag("adapt"),
    })
}

/// Shared GLB parameter flags
/// (`--n --w --l --z --seed --workers-per-node --random-only`).
pub fn glb_params_from(args: &Args) -> Result<crate::glb::GlbParams> {
    use crate::glb::params::StealPolicy;
    let wpn: usize = args.parse_opt("workers-per-node", 1usize)?;
    if wpn == 0 {
        bail!("--workers-per-node must be >= 1 (1 = flat topology)");
    }
    let mut p = crate::glb::GlbParams::default()
        .with_n(args.parse_opt("n", 511usize)?)
        .with_w(args.parse_opt("w", 1usize)?)
        .with_l(args.parse_opt("l", 32usize)?)
        .with_z(args.parse_opt("z", 0usize)?)
        .with_seed(args.parse_opt("seed", 0x51F3_11FEu64)?)
        .with_workers_per_node(wpn);
    if args.flag("random-only") {
        p = p.with_policy(StealPolicy::RandomOnly { rounds: args.parse_opt("rounds", 2usize)? });
    }
    p.validate().map_err(|e| anyhow!(e))?;
    Ok(p)
}

pub const USAGE: &str = "\
glb — lifeline-based global load balancing (GLB, CS.DC 2013 reproduction)

USAGE: glb <command> [options]

COMMANDS
  uts        Unbalanced Tree Search        --places --depth --b0 --seed-tree
  bc         Betweenness Centrality        --places --scale --engine sparse|dense
  fib        Fibonacci (appendix demo)     --fib-n --places [--transport tcp]
  nqueens    N-Queens                      --board --places [--transport tcp]
  fig        regenerate a paper figure     --id 2..10 [--csv] [--places a,b,c]
  launch     spawn + watchdog a whole tcp fleet (one process per rank):
               glb launch --np 4 uts --depth 10 --report fleet.json
               glb launch --hosts fleet.txt --port 7117 uts --depth 13
             launcher options: --np N | --hosts FILE (host [slots=K], # cmnt)
               --ssh 'ssh -o BatchMode=yes' --bin /path/to/glb (remote)
               --port P --timeout SECS --report OUT.json
             everything else passes through to the app; --rank/--peers/
             --host/--bind/--advertise are derived per rank
  serve      boot this rank of a *resident* fleet: the mesh stays up and
             processes streamed jobs until a client shuts it down
               glb serve --rank 0 --peers 4 &   # … ranks 1..3 likewise
               glb launch --np 4 serve          # launcher derives the flags
             options: --rank R --peers N --port P --host H --bind A
                      --advertise IP  (same meanings as --transport tcp);
             rank 0 prints one GLB-SERVE-REPORT line per job (aggregated
             into glb-serve-fleet/v1 by `glb launch --report`)
  submit     ship jobs to a resident fleet and print each result:
               glb submit uts --depth 10            # one job
               glb submit bc --scale 9 --repeat 50  # 50 back-to-back jobs
               glb submit fib --fib-n 24 --shutdown # run, then retire fleet
               glb submit --shutdown                # just retire the fleet
             options: --host H --port P --timeout SECS --repeat K
                      --shutdown, app knobs (--depth --b0 --seed-tree |
                      --scale | --fib-n) and GLB knobs (--n --w --l --z
                      --seed)
  bench      run the pinned perf configs via the launcher and write
             BENCH_glb.json   [--repeats 3 --warmup 1 --np 2]
             [--baseline bench/baseline.json --band 0.30] (warn-only gate)
  calibrate  print this machine's cost models
  smoke      check the PJRT runtime wiring
  lint       protocol/concurrency invariant checker over the source tree
             (wire-tag registry, SAFETY audit, atomic orderings, hot-path
             panics) — nonzero exit on any finding   [--root DIR]

COMMON OPTIONS
  --threads | --sim      substrate (default: threads for apps, sim for figs)
  --transport KIND       tcp|thread|sim — tcp runs this process as one GLB
                         node of a multi-process mesh fleet (uts, bc, fib,
                         nqueens); launch one process per node:
                           glb uts --transport tcp --peers 4 --rank 0 ...
                           glb uts --transport tcp --peers 4 --rank 1 ...
  --rank R --peers N     fleet membership (tcp; rank 0 is bootstrap only —
                         steady-state traffic flows spoke-to-spoke)
  --port P --host H      rank 0 rendezvous (default 7117 on 127.0.0.1)
  --bind A               rank 0 bind address when --host is not locally
                         bindable (default 0.0.0.0 whenever --host is set)
  --advertise IP         this rank's mesh IP for peers to dial (multi-homed
                         spokes; default: the interface that reaches rank 0)
  --tolerate-failures K  survive up to K spoke crashes (tcp, one worker per
                         node): survivors re-knit the lifeline graph, re-run
                         retained un-acked loot, and rank 0 reclaims the dead
                         rank's credit — results stay exact. Rank 0 itself is
                         never expendable. `glb launch` forwards this to every
                         rank and keeps the fleet alive through K deaths.
  --stats-interval MS    tcp: sample live gauges every MS ms and ship them to
                         rank 0, which prints one fleet summary line per
                         interval (launcher shorthand: --stats[=MS], default
                         1000); the series lands in the fleet report as
                         \"live_stats\"
  --adapt                tcp: close the telemetry loop — workers retune loot
                         granularity and lifeline arity mid-run on persistent
                         starvation (off by default; not with
                         --tolerate-failures)
  --arch NAME            sim architecture: power775|bgq|k|ideal (default bgq)
  --n --w --l --z        GLB tuning parameters (paper §2.4)
  --workers-per-node K   hierarchical topology: K workers share a node bag
                         and one representative runs the lifelines over
                         nodes (default 1 = the paper's flat layout)
  --random-only          ablation: random-victim stealing, no lifelines
  --log                  print the per-worker accounting table (§2.4),
                         plus the per-node rollup when K > 1
  --report PATH          write the run's machine-readable report JSON
                         (thread/sim runs; a launched fleet's aggregated
                         report comes from `glb launch --report`)
  --csv                  machine-readable figure output
";

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::parse(&s(&["--places", "8", "--log", "--depth=10", "pos"]), &["log"])
            .unwrap();
        assert_eq!(a.get("places"), Some("8"));
        assert_eq!(a.get("depth"), Some("10"));
        assert!(a.flag("log"));
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn typed_parsing_and_defaults() {
        let a = Args::parse(&s(&["--places", "8"]), &[]).unwrap();
        assert_eq!(a.parse_opt("places", 1usize).unwrap(), 8);
        assert_eq!(a.parse_opt("depth", 13u32).unwrap(), 13);
        assert!(a.parse_opt::<usize>("places", 0).is_ok());
        let bad = Args::parse(&s(&["--places", "x"]), &[]).unwrap();
        assert!(bad.parse_opt::<usize>("places", 0).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&s(&["--places", "1,2, 4"]), &[]).unwrap();
        assert_eq!(a.parse_list("places", &[9]).unwrap(), vec![1, 2, 4]);
        let d = Args::parse(&[], &[]).unwrap();
        assert_eq!(d.parse_list("places", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&s(&["--places"]), &[]).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = Args::parse(&s(&["--plcaes", "8"]), &[]).unwrap();
        assert!(a.ensure_known(&["places"]).is_err());
        let ok = Args::parse(&s(&["--places", "8"]), &[]).unwrap();
        assert!(ok.ensure_known(&["places"]).is_ok());
    }

    #[test]
    fn glb_params_flags() {
        let a = Args::parse(&s(&["--n", "64", "--w", "3", "--random-only"]), &["random-only"])
            .unwrap();
        let p = glb_params_from(&a).unwrap();
        assert_eq!(p.n, 64);
        assert_eq!(p.w, 3);
        assert_eq!(p.random_budget(), 6);
        assert_eq!(p.workers_per_node, 1, "flat unless asked otherwise");
    }

    #[test]
    fn transport_selection() {
        let d = Args::parse(&[], &["sim"]).unwrap();
        assert_eq!(transport_from(&d).unwrap(), TransportKind::Thread);
        let sim_flag = Args::parse(&s(&["--sim"]), &["sim"]).unwrap();
        assert_eq!(transport_from(&sim_flag).unwrap(), TransportKind::Sim);
        let tcp = Args::parse(&s(&["--transport", "tcp"]), &[]).unwrap();
        assert_eq!(transport_from(&tcp).unwrap(), TransportKind::Tcp);
        // Explicit --transport wins over the legacy flag.
        let both = Args::parse(&s(&["--transport", "thread", "--sim"]), &["sim"]).unwrap();
        assert_eq!(transport_from(&both).unwrap(), TransportKind::Thread);
        let bad = Args::parse(&s(&["--transport", "carrier-pigeon"]), &[]).unwrap();
        assert!(transport_from(&bad).is_err());
    }

    #[test]
    fn tcp_opts_parsing() {
        let a = Args::parse(&s(&["--rank", "2", "--peers", "4"]), &[]).unwrap();
        let t = tcp_opts_from(&a).unwrap();
        assert_eq!((t.rank, t.peers, t.port), (2, 4, 7117));
        assert_eq!(t.host, "127.0.0.1");
        assert_eq!(t.bind, None, "default host binds itself");
        assert_eq!(t.tolerate_failures, 0, "fail-fast unless asked otherwise");
        let ft = Args::parse(
            &s(&["--rank", "2", "--peers", "4", "--tolerate-failures", "1"]),
            &[],
        )
        .unwrap();
        assert_eq!(tcp_opts_from(&ft).unwrap().tolerate_failures, 1);
        let full =
            Args::parse(&s(&["--rank", "0", "--peers", "2", "--port", "9000", "--host", "h"]), &[])
                .unwrap();
        let t = tcp_opts_from(&full).unwrap();
        assert_eq!((t.port, t.host.as_str()), (9000, "h"));
        // rank must be < peers, and both are required.
        let oob = Args::parse(&s(&["--rank", "4", "--peers", "4"]), &[]).unwrap();
        assert!(tcp_opts_from(&oob).is_err());
        let missing = Args::parse(&s(&["--rank", "0"]), &[]).unwrap();
        assert!(tcp_opts_from(&missing).is_err());
    }

    #[test]
    fn bind_splits_from_advertised_host() {
        // --host alone: advertise the public address, bind the wildcard
        // (the advertised address is often not locally bindable).
        let a = Args::parse(&s(&["--rank", "0", "--peers", "2", "--host", "203.0.113.9"]), &[])
            .unwrap();
        let t = tcp_opts_from(&a).unwrap();
        assert_eq!(t.host, "203.0.113.9");
        assert_eq!(t.bind.as_deref(), Some("0.0.0.0"));
        // Explicit --bind wins.
        let b = Args::parse(
            &s(&["--rank", "0", "--peers", "2", "--host", "203.0.113.9", "--bind", "10.0.0.2"]),
            &[],
        )
        .unwrap();
        let t = tcp_opts_from(&b).unwrap();
        assert_eq!(t.bind.as_deref(), Some("10.0.0.2"));
        // Multi-homed spokes can pin their advertised mesh IP.
        let c = Args::parse(
            &s(&["--rank", "1", "--peers", "2", "--advertise", "10.0.0.7"]),
            &[],
        )
        .unwrap();
        let t = tcp_opts_from(&c).unwrap();
        assert_eq!(t.advertise.as_deref(), Some("10.0.0.7"));
        assert_eq!(t.bind, None);
    }

    #[test]
    fn stats_and_adapt_flags() {
        let off = Args::parse(&s(&["--rank", "0", "--peers", "2"]), &["adapt"]).unwrap();
        let t = tcp_opts_from(&off).unwrap();
        assert_eq!(t.stats_interval_ms, None, "telemetry disarmed by default");
        assert!(!t.adapt);
        let on = Args::parse(
            &s(&["--rank", "1", "--peers", "2", "--stats-interval", "250", "--adapt"]),
            &["adapt"],
        )
        .unwrap();
        let t = tcp_opts_from(&on).unwrap();
        assert_eq!(t.stats_interval_ms, Some(250));
        assert!(t.adapt);
        let zero =
            Args::parse(&s(&["--rank", "0", "--peers", "2", "--stats-interval", "0"]), &[])
                .unwrap();
        assert!(tcp_opts_from(&zero).is_err(), "a zero interval would busy-spin the reactor");
    }

    #[test]
    fn workers_per_node_flag() {
        let a = Args::parse(&s(&["--workers-per-node", "16"]), &[]).unwrap();
        assert_eq!(glb_params_from(&a).unwrap().workers_per_node, 16);
        let zero = Args::parse(&s(&["--workers-per-node", "0"]), &[]).unwrap();
        let err = glb_params_from(&zero).unwrap_err();
        assert!(format!("{err}").contains("workers-per-node"), "{err}");
    }
}
