//! The PJRT execution engine (single-thread owner of all PJRT state).
//!
//! Calling convention of the `bc_brandes` artifact (must match
//! `python/compile/aot.py::lower_brandes`):
//!
//! * inputs: `adj : f32[N, N]` (dense 0/1 adjacency, row = source),
//!   `sources : i32[S]` (source vertex ids; `-1` = padding slot, which
//!   contributes nothing);
//! * output: 1-tuple of a tuple `(bc : f32[N], edges : f32[], levels :
//!   i32[])` — the batch's partial betweenness contribution, the number
//!   of edges traversed (for TEPS reporting) and the BFS levels executed
//!   (for the imbalance model: small components exit early).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// Output of one batched-Brandes execution.
#[derive(Debug, Clone, PartialEq)]
pub struct BrandesOut {
    /// Partial betweenness contribution of this source batch, length N.
    pub bc: Vec<f32>,
    /// Edges traversed (work units; the paper's BC throughput metric).
    pub edges: u64,
    /// BFS levels executed before the whole batch's frontier emptied.
    pub levels: u32,
}

/// Owns the PJRT client and a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Self { client, dir: dir.to_path_buf(), manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (with caching) an artifact by file name.
    pub fn load(&mut self, file: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(file) {
            let path = self.dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.cache.insert(file.to_string(), exe);
        }
        Ok(&self.cache[file])
    }

    /// Build a [`BrandesEngine`] for an `N`-vertex graph given as a dense
    /// row-major 0/1 adjacency. Picks the manifest's largest batch size.
    ///
    /// The adjacency is uploaded to the device **once** and kept resident
    /// (`PjRtBuffer`); per-call inputs are only the S source ids — this
    /// is the §Perf optimization that removes the N²-float host→device
    /// copy from every call (see EXPERIMENTS.md §Perf).
    pub fn brandes(&mut self, adj: &[f32], n: usize) -> Result<BrandesEngine> {
        self.brandes_with_batch(adj, n, None)
    }

    /// [`Engine::brandes`] with an upper bound on the source batch size
    /// (picks the largest artifact with `S <= max_s`). Smaller batches
    /// exit the level loop earlier on shallow sources; see the §Perf
    /// batch-size sweep in EXPERIMENTS.md.
    pub fn brandes_with_batch(
        &mut self,
        adj: &[f32],
        n: usize,
        max_s: Option<i64>,
    ) -> Result<BrandesEngine> {
        if adj.len() != n * n {
            bail!("adjacency must be {n}x{n}, got {} elements", adj.len());
        }
        let entry = self
            .manifest
            .find_brandes(n as i64, max_s)
            .with_context(|| format!("no bc_brandes artifact for n={n}; rerun `make artifacts` (see python/compile/aot.py --bc-sizes)"))?
            .clone();
        let s = entry.attr("s")? as usize;
        let file = entry.file.clone();
        self.load(&file)?;
        let adj_buf = self
            .client
            .buffer_from_host_buffer(adj, &[n, n], None)
            .context("uploading adjacency to device")?;
        Ok(BrandesEngine { file, n, s, adj_buf })
    }

    /// Execute one batched-Brandes call. `sources` length must be ≤ S;
    /// the engine pads with `-1` (ignored slots).
    pub fn run_brandes(&mut self, be: &BrandesEngine, sources: &[u32]) -> Result<BrandesOut> {
        if sources.len() > be.s {
            bail!("batch of {} exceeds artifact S={}", sources.len(), be.s);
        }
        if sources.is_empty() {
            return Ok(BrandesOut { bc: vec![0.0; be.n], edges: 0, levels: 0 });
        }
        let mut src: Vec<i32> = sources.iter().map(|&v| v as i32).collect();
        src.resize(be.s, -1);
        let src_buf = self.client.buffer_from_host_buffer(&src, &[be.s], None)?;
        let file = be.file.clone();
        let exe = self.load(&file)?;
        let result = exe.execute_b::<&xla::PjRtBuffer>(&[&be.adj_buf, &src_buf])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is the flat
        // 3-tuple (bc, edges, levels).
        let (bc_l, edges_l, levels_l) = result.to_tuple3()?;
        let bc = bc_l.to_vec::<f32>()?;
        let edges = edges_l.to_vec::<f32>()?[0] as u64;
        let levels = levels_l.to_vec::<i32>()?[0] as u32;
        Ok(BrandesOut { bc, edges, levels })
    }
}

/// A compiled batched-Brandes executable bound to one replicated graph
/// (adjacency resident on the device).
pub struct BrandesEngine {
    file: String,
    /// Vertex count.
    pub n: usize,
    /// Max sources per call (the artifact's S).
    pub s: usize,
    adj_buf: xla::PjRtBuffer,
}

#[cfg(test)]
mod tests {
    //! Engine tests that need real artifacts live in
    //! `rust/tests/runtime_integration.rs` (they require `make artifacts`).
    use super::*;

    #[test]
    fn engine_requires_manifest() {
        let dir = std::env::temp_dir().join("glb-missing-artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = match Engine::new(&dir) {
            Ok(_) => panic!("engine must fail without a manifest"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
    }

    #[test]
    fn brandes_rejects_bad_adjacency() {
        let dir = std::env::temp_dir().join("glb-empty-manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "").unwrap();
        let mut eng = Engine::new(&dir).unwrap();
        assert!(eng.brandes(&[0.0; 10], 4).is_err(), "10 != 4*4");
        assert!(eng.brandes(&[0.0; 16], 4).is_err(), "no artifact for n=4");
    }
}
