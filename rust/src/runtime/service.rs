//! The device service: a dedicated thread owning the PJRT [`Engine`],
//! serving batched-Brandes requests from GLB places over channels.
//!
//! Rationale: the `xla` crate's PJRT wrappers are `!Send`, and a real
//! deployment would funnel accelerator work through an offload queue
//! anyway (one device per node, many places). The handle is cheap to
//! clone; requests block the calling place until the reply arrives —
//! matching the synchronous `process(n)` contract of GLB task queues.

use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::engine::{BrandesOut, Engine};

enum Req {
    Brandes { sources: Vec<u32>, reply: Sender<Result<BrandesOut>> },
    Shutdown,
}

/// Clonable, `Send` handle to the device service. Each clone owns its own
/// mpsc `Sender` (already `Clone + Send`), so concurrent GLB places
/// enqueue offload requests without ever serializing on a lock — the
/// request channel itself is the queue.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<Req>,
    n: usize,
    s: usize,
}

impl DeviceHandle {
    /// Graph size the service was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Max sources per call.
    pub fn batch(&self) -> usize {
        self.s
    }

    /// Execute one batched-Brandes call (blocking).
    pub fn brandes(&self, sources: &[u32]) -> Result<BrandesOut> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Brandes { sources: sources.to_vec(), reply })
            .map_err(|_| anyhow!("device service stopped"))?;
        rx.recv().map_err(|_| anyhow!("device service dropped reply"))?
    }
}

/// The running service; dropping it shuts the engine thread down.
pub struct DeviceService {
    handle: DeviceHandle,
    join: Option<JoinHandle<()>>,
    tx: Sender<Req>,
}

impl DeviceService {
    /// Start the engine thread for an `n`-vertex dense adjacency.
    pub fn start(artifact_dir: &Path, adj: Vec<f32>, n: usize) -> Result<Self> {
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<(usize, usize)>>();
        let dir = artifact_dir.to_path_buf();
        let join = std::thread::Builder::new()
            .name("glb-device".into())
            .spawn(move || engine_main(dir, adj, n, rx, ready_tx))
            .context("spawning device service")?;
        let (n, s) = ready_rx
            .recv()
            .map_err(|_| anyhow!("device service died during startup"))??;
        let handle = DeviceHandle { tx: tx.clone(), n, s };
        Ok(Self { handle, join: Some(join), tx })
    }

    pub fn handle(&self) -> DeviceHandle {
        self.handle.clone()
    }
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn engine_main(
    dir: std::path::PathBuf,
    adj: Vec<f32>,
    n: usize,
    rx: Receiver<Req>,
    ready: Sender<Result<(usize, usize)>>,
) {
    let mut engine = match Engine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let be = match engine.brandes(&adj, n) {
        Ok(b) => b,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok((be.n, be.s)));
    while let Ok(req) = rx.recv() {
        match req {
            Req::Brandes { sources, reply } => {
                let _ = reply.send(engine.run_brandes(&be, &sources));
            }
            Req::Shutdown => break,
        }
    }
}
