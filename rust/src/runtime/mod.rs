//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs only at build time (`make artifacts`): `python/compile/aot.py`
//! lowers the L2 JAX model (which calls the L1 Pallas kernels) to **HLO
//! text** under `artifacts/`. This module is the request-path bridge: it
//! parses the artifact manifest, compiles the HLO on the PJRT CPU client
//! (`xla` crate), keeps the replicated BC graph resident as a device
//! buffer, and executes batched Brandes calls issued by GLB workers.
//!
//! HLO *text* — not serialized `HloModuleProto` — is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! `/opt/xla-example/README.md`).
//!
//! The `xla` crate's wrappers are `!Send` (raw C++ pointers), so all PJRT
//! state lives on one dedicated **device service** thread
//! ([`service::DeviceService`]); GLB places call it through a clonable
//! [`service::DeviceHandle`] — the same shape as a real accelerator
//! offload queue.

pub mod engine;
pub mod manifest;
pub mod service;

pub use engine::{BrandesEngine, BrandesOut, Engine};
pub use manifest::{Manifest, ManifestEntry};
pub use service::{DeviceHandle, DeviceService};

use std::path::PathBuf;

/// Default artifact directory (relative to the repo root / CWD).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("GLB_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("artifacts"))
}
