//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`, one line per
//! artifact, as whitespace-separated `key=value` pairs (no JSON — the
//! offline environment has no serde_json and the format does not warrant
//! one):
//!
//! ```text
//! kind=bc_brandes n=256 s=32 maxl=64 file=bc_brandes_n256_s32.hlo.txt
//! kind=uts_expand b=256 file=uts_expand_b256.hlo.txt
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact kind (`bc_brandes`, `uts_expand`, ...).
    pub kind: String,
    /// HLO text file name, relative to the artifact dir.
    pub file: String,
    /// All remaining integer attributes (`n`, `s`, `maxl`, `b`, ...).
    pub attrs: HashMap<String, i64>,
}

impl ManifestEntry {
    /// Required integer attribute.
    pub fn attr(&self, key: &str) -> Result<i64> {
        self.attrs.get(key).copied().with_context(|| {
            format!("artifact {} ({}) missing attribute {key}", self.kind, self.file)
        })
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kind = None;
            let mut file = None;
            let mut attrs = HashMap::new();
            for tok in line.split_whitespace() {
                let (k, v) = tok
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad token {tok:?}", lineno + 1))?;
                match k {
                    "kind" => kind = Some(v.to_string()),
                    "file" => file = Some(v.to_string()),
                    _ => {
                        let n: i64 = v.parse().with_context(|| {
                            format!("manifest line {}: non-integer {k}={v}", lineno + 1)
                        })?;
                        attrs.insert(k.to_string(), n);
                    }
                }
            }
            let (Some(kind), Some(file)) = (kind, file) else {
                bail!("manifest line {}: needs kind= and file=", lineno + 1);
            };
            entries.push(ManifestEntry { kind, file, attrs });
        }
        Ok(Self { entries })
    }

    /// Load `manifest.txt` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text)
    }

    /// All entries of a kind.
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ManifestEntry> {
        self.entries.iter().filter(move |e| e.kind == kind)
    }

    /// The `bc_brandes` entry with the given graph size, preferring the
    /// largest source batch ≤ `max_s` (or the largest available).
    pub fn find_brandes(&self, n: i64, max_s: Option<i64>) -> Option<&ManifestEntry> {
        self.of_kind("bc_brandes")
            .filter(|e| e.attr("n").ok() == Some(n))
            .filter(|e| max_s.is_none_or(|m| e.attr("s").unwrap_or(i64::MAX) <= m))
            .max_by_key(|e| e.attr("s").unwrap_or(0))
    }

    /// Absolute path for an entry.
    pub fn path(&self, dir: &Path, e: &ManifestEntry) -> PathBuf {
        dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line

kind=bc_brandes n=256 s=32 maxl=64 file=bc_brandes_n256_s32.hlo.txt
kind=bc_brandes n=256 s=8 maxl=64 file=bc_brandes_n256_s8.hlo.txt
kind=uts_expand b=256 file=uts_expand_b256.hlo.txt
";

    #[test]
    fn parses_entries_and_attrs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = &m.entries[0];
        assert_eq!(e.kind, "bc_brandes");
        assert_eq!(e.attr("n").unwrap(), 256);
        assert_eq!(e.attr("s").unwrap(), 32);
        assert_eq!(e.file, "bc_brandes_n256_s32.hlo.txt");
        assert!(e.attr("missing").is_err());
    }

    #[test]
    fn find_brandes_prefers_largest_batch() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.find_brandes(256, None).unwrap().attr("s").unwrap(), 32);
        assert_eq!(m.find_brandes(256, Some(16)).unwrap().attr("s").unwrap(), 8);
        assert!(m.find_brandes(1024, None).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("kind=x file").is_err());
        assert!(Manifest::parse("kind=x n=abc file=f").is_err());
        assert!(Manifest::parse("n=3 file=f").is_err());
        assert!(Manifest::parse("").unwrap().entries.is_empty());
    }

    #[test]
    fn of_kind_filters() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.of_kind("bc_brandes").count(), 2);
        assert_eq!(m.of_kind("uts_expand").count(), 1);
        assert_eq!(m.of_kind("nope").count(), 0);
    }
}
