//! Architecture profiles for the discrete-event simulator (paper §3.3).
//!
//! The paper evaluates on three machines; none are available here, so the
//! simulator models the *ratios that matter to the load balancer*: message
//! latency (intra- vs inter-node, per torus hop), NIC serialization and
//! per-message occupancy (shared by all places of a node), per-message
//! software handling cost, and relative single-core compute speed.
//!
//! Parameter values are order-of-magnitude figures assembled from the
//! machines' public specifications (P775 hub all-to-all ~1–2 µs MPI
//! latency; BGQ 5-D torus ~2.5 µs neighbour latency, 1.6 GHz in-order A2
//! cores; K Tofu 6-D mesh/torus ~3 µs, 5 GB/s links, 8 places sharing a
//! NIC). Absolute numbers are NOT the reproduction target — the scaling
//! *shape* under each profile is (see EXPERIMENTS.md).

/// Interconnect + compute model for one machine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchProfile {
    pub name: &'static str,
    /// X10 places per physical node (paper §3.3: 32 on P775, 16 on BGQ
    /// in c16 mode, 8 on K).
    pub places_per_node: usize,
    /// Same-node place-to-place latency (shared memory transport), ns.
    pub intra_node_ns: u64,
    /// Cross-node base latency, ns.
    pub inter_node_base_ns: u64,
    /// Additional latency per torus hop, ns.
    pub per_hop_ns: u64,
    /// Torus dimensionality used for hop counting (0 = all-to-all: one
    /// hop between any two nodes, the P775 hub model).
    pub torus_dims: usize,
    /// NIC serialization bandwidth, bytes/ns (= GB/s).
    pub nic_bytes_per_ns: f64,
    /// Per-message NIC occupancy, ns (shared by the node's places; this
    /// is what makes many-places-per-node contend).
    pub nic_msg_overhead_ns: u64,
    /// Software cost to handle one incoming message, ns.
    pub handle_ns: u64,
    /// Single-core speed multiplier applied to the app cost model
    /// (1.0 = the reference core the cost models were calibrated on).
    pub compute_scale: f64,
}

/// IBM Power 775 (paper: 2 drawers, 32 places/octant, hub-chip
/// all-to-all optical interconnect).
pub const POWER775: ArchProfile = ArchProfile {
    name: "power775",
    places_per_node: 32,
    intra_node_ns: 400,
    inter_node_base_ns: 1_800,
    per_hop_ns: 0,
    torus_dims: 0, // hub: direct
    nic_bytes_per_ns: 12.0,
    nic_msg_overhead_ns: 250,
    handle_ns: 150,
    compute_scale: 1.0,
};

/// Blue Gene/Q (Vesta; c16 mode: 1 place per A2 core, 5-D torus).
pub const BGQ: ArchProfile = ArchProfile {
    name: "bgq",
    places_per_node: 16,
    intra_node_ns: 500,
    inter_node_base_ns: 2_400,
    per_hop_ns: 45,
    torus_dims: 5,
    nic_bytes_per_ns: 1.8,
    nic_msg_overhead_ns: 500,
    handle_ns: 350,
    compute_scale: 0.38, // 1.6 GHz in-order A2 vs 3.8 GHz P7
};

/// K computer (RIKEN; SPARC64 VIIIfx, Tofu 6-D mesh/torus, 8 places/node).
pub const K: ArchProfile = ArchProfile {
    name: "k",
    places_per_node: 8,
    intra_node_ns: 450,
    inter_node_base_ns: 2_900,
    per_hop_ns: 120,
    torus_dims: 3, // Tofu's 6-D folded: effective 3-D torus for hop counts
    nic_bytes_per_ns: 5.0,
    nic_msg_overhead_ns: 900,
    handle_ns: 300,
    compute_scale: 0.52, // 2.0 GHz SPARC64 VIIIfx
};

/// An idealized zero-latency machine (protocol testing, ablations).
pub const IDEAL: ArchProfile = ArchProfile {
    name: "ideal",
    places_per_node: 1,
    intra_node_ns: 0,
    inter_node_base_ns: 0,
    per_hop_ns: 0,
    torus_dims: 0,
    nic_bytes_per_ns: f64::INFINITY,
    nic_msg_overhead_ns: 0,
    handle_ns: 0,
    compute_scale: 1.0,
};

impl ArchProfile {
    /// Look up a profile by CLI name.
    pub fn by_name(name: &str) -> Option<&'static ArchProfile> {
        match name {
            "power775" | "p775" | "power" => Some(&POWER775),
            "bgq" | "bluegene" => Some(&BGQ),
            "k" => Some(&K),
            "ideal" => Some(&IDEAL),
            _ => None,
        }
    }

    /// Node id of a place. The hardware grid is fixed by the profile —
    /// a run's GLB topology ([`crate::glb::topology`]) is a *software*
    /// overlay on it, so sweeping `workers_per_node` compares groupings
    /// on the *same* simulated machine. Set `workers_per_node =
    /// places_per_node` to align one GLB node per physical node (the
    /// deployment the hierarchy is designed for: every intra-node push
    /// and bag transfer then stays off the NIC).
    #[inline]
    pub fn node_of(&self, place: usize) -> usize {
        place / self.places_per_node
    }

    /// Torus hop count between two nodes for `total_nodes` in the system.
    /// Nodes are laid out on a near-cubic `torus_dims`-dimensional cyclic
    /// grid; all-to-all profiles report one hop.
    pub fn hops(&self, a: usize, b: usize, total_nodes: usize) -> u64 {
        if a == b {
            return 0;
        }
        if self.torus_dims == 0 || total_nodes <= 2 {
            return 1;
        }
        let side = (total_nodes as f64).powf(1.0 / self.torus_dims as f64).ceil().max(2.0) as usize;
        let mut hops = 0u64;
        let (mut ra, mut rb) = (a, b);
        for _ in 0..self.torus_dims {
            let (ca, cb) = (ra % side, rb % side);
            let d = ca.abs_diff(cb);
            hops += d.min(side - d) as u64; // cyclic distance
            ra /= side;
            rb /= side;
        }
        hops.max(1)
    }

    /// Wire latency (excluding NIC occupancy queueing, which the simulator
    /// models statefully) for a message of `bytes` from `from` to `to`.
    pub fn wire_latency_ns(&self, from: usize, to: usize, bytes: usize, total_places: usize) -> u64 {
        let (na, nb) = (self.node_of(from), self.node_of(to));
        if na == nb {
            return self.intra_node_ns;
        }
        let total_nodes = total_places.div_ceil(self.places_per_node);
        let ser = if self.nic_bytes_per_ns.is_finite() {
            (bytes as f64 / self.nic_bytes_per_ns) as u64
        } else {
            0
        };
        self.inter_node_base_ns + self.per_hop_ns * self.hops(na, nb, total_nodes) + ser
    }

    /// Scale an app compute cost (ns on the reference core) to this core.
    #[inline]
    pub fn compute_ns(&self, reference_ns: f64) -> u64 {
        (reference_ns / self.compute_scale) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup() {
        assert_eq!(ArchProfile::by_name("bgq").unwrap().name, "bgq");
        assert_eq!(ArchProfile::by_name("power775").unwrap().name, "power775");
        assert_eq!(ArchProfile::by_name("k").unwrap().name, "k");
        assert!(ArchProfile::by_name("cray").is_none());
    }

    #[test]
    fn node_mapping() {
        assert_eq!(BGQ.node_of(0), 0);
        assert_eq!(BGQ.node_of(15), 0);
        assert_eq!(BGQ.node_of(16), 1);
    }

    #[test]
    fn intra_beats_inter() {
        let p = 64;
        let same = BGQ.wire_latency_ns(0, 1, 64, p);
        let cross = BGQ.wire_latency_ns(0, 17, 64, p);
        assert!(same < cross, "{same} vs {cross}");
        assert_eq!(same, BGQ.intra_node_ns);
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let nodes = 64;
        for &(a, b) in &[(0usize, 5usize), (3, 60), (10, 11)] {
            assert_eq!(K.hops(a, b, nodes), K.hops(b, a, nodes));
        }
        assert_eq!(K.hops(7, 7, nodes), 0);
        assert_eq!(POWER775.hops(0, 63, nodes), 1, "hub is one hop");
    }

    #[test]
    fn cyclic_distance_wraps() {
        // side = 4 for 64 nodes in 3-D: node 0 (0,0,0) vs node 3 (3,0,0)
        // is 1 hop around the ring, not 3.
        assert_eq!(K.hops(0, 3, 64), 1);
    }

    #[test]
    fn larger_messages_serialize_longer() {
        let small = BGQ.wire_latency_ns(0, 17, 64, 64);
        let large = BGQ.wire_latency_ns(0, 17, 64 + 8192, 64);
        assert!(large > small + 4000, "{large} vs {small}: 8KiB at 1.8 B/ns ≈ 4.5µs");
    }

    #[test]
    fn compute_scaling() {
        assert_eq!(POWER775.compute_ns(100.0), 100);
        assert!(BGQ.compute_ns(100.0) > 250, "BGQ cores are ~2.6x slower");
    }

    #[test]
    fn ideal_is_free() {
        assert_eq!(IDEAL.wire_latency_ns(0, 1, 1 << 20, 1024), 0);
    }
}
