//! Deterministic discrete-event simulation of a GLB deployment.
//!
//! The paper's evaluation runs up to 16,384 cores; this container has one.
//! The simulator executes the **real** GLB protocol (the same
//! [`Worker`](crate::glb::Worker) engine as the thread runtime) and the
//! **real** application compute, but charges time on a virtual clock using
//! an [`ArchProfile`] (latency, NIC occupancy, core speed) and an
//! application [`CostModel`] (ns per work unit, calibrated against real
//! single-core measurements — see `harness::calibrate`).
//!
//! Modelling choices (all on the conservative side for a load balancer):
//!
//! * a `Working` place answers messages only at `process(n)` chunk
//!   boundaries — exactly the paper's "probes the network ... between
//!   each process(n) call", and the mechanism behind its §2.6 BC
//!   responsiveness discussion;
//! * a waiting/idle place handles messages immediately (plus a software
//!   handling cost);
//! * cross-node messages serialize through the sender node's NIC: a
//!   per-message occupancy charge on a shared `nic_free_at` clock models
//!   the contention of many places per node (this is what bends the K
//!   curve past 4 K places, Fig 4); intra-node deliveries skip the NIC
//!   entirely and pay only the shared-memory latency;
//! * the hardware node grid is fixed by the [`ArchProfile`]; a
//!   hierarchical GLB topology (`workers_per_node > 1`, see
//!   [`crate::glb::topology`]) is a software overlay on it, so sweeping
//!   the grouping compares configurations on the *same* machine. With
//!   `workers_per_node = places_per_node` (one GLB node per physical
//!   node — the intended deployment) the [`SimReport::cross_messages`]
//!   counter directly measures what the two-level balancer saves;
//! * the virtual clock is `u64` ns; event order is total (time, seq), so
//!   runs are bit-for-bit reproducible for a given seed.

pub mod arch;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::glb::autotune::{AdaptiveConfig, AdaptiveController, ControllerSample};
use crate::glb::message::{Effect, Msg};
use crate::glb::task_queue::{Reducer, TaskQueue};
use crate::glb::termination::{Ledger, SimLedger};
use crate::glb::worker::{Phase, Worker};
use crate::glb::{GlbConfig, RunLog, RunOutput};
pub use arch::{ArchProfile, BGQ, IDEAL, K, POWER775};

/// Application compute-cost model for virtual-time accounting, calibrated
/// on the reference core (this machine) and scaled by the profile's
/// `compute_scale`.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// ns of compute per work unit (UTS: per node; BC: per edge).
    pub ns_per_unit: f64,
    /// Fixed ns overhead per `process(n)` chunk (loop setup, probe).
    pub chunk_overhead_ns: u64,
    /// Serialized bytes per task item (loot message sizing).
    pub item_bytes: usize,
}

impl CostModel {
    pub fn new(ns_per_unit: f64, chunk_overhead_ns: u64, item_bytes: usize) -> Self {
        Self { ns_per_unit, chunk_overhead_ns, item_bytes }
    }
}

/// Event payloads. `Tick` = a working place finishes its current chunk;
/// `Deliver` = a message arrives at a place.
enum Ev<B> {
    Tick(usize),
    Deliver(usize, Msg<B>),
}

/// Min-heap entry: (time, seq) is a total order → deterministic replay.
struct Entry<B> {
    t: u64,
    seq: u64,
    ev: Ev<B>,
}

impl<B> PartialEq for Entry<B> {
    fn eq(&self, o: &Self) -> bool {
        (self.t, self.seq) == (o.t, o.seq)
    }
}
impl<B> Eq for Entry<B> {}
impl<B> PartialOrd for Entry<B> {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl<B> Ord for Entry<B> {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        (self.t, self.seq).cmp(&(o.t, o.seq))
    }
}

/// Simulation report: the standard [`RunOutput`] plus simulator counters.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Total messages delivered.
    pub messages: u64,
    /// Messages that crossed a node boundary (and thus paid the NIC
    /// occupancy + inter-node latency). `messages - cross_messages` were
    /// intra-node deliveries that skipped the NIC entirely — the quantity
    /// the hierarchical topology ([`crate::glb::topology`]) is designed
    /// to maximize.
    pub cross_messages: u64,
    /// Total events processed.
    pub events: u64,
    /// Virtual ns the busiest place computed for (critical path lower
    /// bound).
    pub max_busy_ns: u64,
}

/// Run a GLB computation on the simulator. Mirrors
/// [`crate::place::run_threads`]; see there for the factory/root-init
/// contract.
pub fn run_sim<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    arch: &ArchProfile,
    cost: CostModel,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> (RunOutput<Q::Result>, SimReport)
where
    Q: TaskQueue,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sim_jitter(cfg, arch, cost, 0, factory, root_init, reducer)
}

/// [`run_sim`] with the **closed-loop adaptive tuner** armed: every
/// place runs its own [`AdaptiveController`] over its live gauges,
/// observed on the virtual clock every `obs_interval_ns` at chunk /
/// delivery boundaries, and retunes loot granularity and lifeline arity
/// mid-run when they show persistent starvation — the deterministic
/// twin of the socket runtime's `--adapt`, used for the static-vs-
/// adaptive ablation (the reduced result is identical either way; only
/// the schedule, and with it the virtual makespan, changes).
pub fn run_sim_adaptive<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    arch: &ArchProfile,
    cost: CostModel,
    adapt: AdaptiveConfig,
    obs_interval_ns: u64,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> (RunOutput<Q::Result>, SimReport)
where
    Q: TaskQueue,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    Sim::new(cfg, arch, cost, 0, Some((adapt, obs_interval_ns)), factory, root_init).run(reducer)
}

/// [`run_sim`] with **fault/jitter injection**: every message delivery is
/// delayed by a deterministic pseudo-random extra `0..=jitter_ns`.
/// Because latencies vary per message, deliveries *reorder across
/// senders* (and, with large jitter, effectively adversarially) — the
/// protocol's correctness must not depend on timing (see the
/// `prop_sim_survives_message_jitter` property test).
pub fn run_sim_jitter<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    arch: &ArchProfile,
    cost: CostModel,
    jitter_ns: u64,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> (RunOutput<Q::Result>, SimReport)
where
    Q: TaskQueue,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    Sim::new(cfg, arch, cost, jitter_ns, None, factory, root_init).run(reducer)
}

/// The simulator's adaptive-tuning plane: one controller per place plus
/// a per-place next-observation deadline on the virtual clock.
struct AdaptPlane {
    ctrls: Vec<AdaptiveController>,
    next_obs: Vec<u64>,
    interval: u64,
}

struct Sim<Q: TaskQueue> {
    p: usize,
    arch: ArchProfile,
    cost: CostModel,
    workers: Vec<Worker<Q, SimLedger>>,
    ledger: SimLedger,
    heap: BinaryHeap<Reverse<Entry<Q::Bag>>>,
    /// Messages that arrived while the place was mid-chunk.
    inboxes: Vec<VecDeque<Msg<Q::Bag>>>,
    /// Whether a Tick is scheduled for the place (i.e. it is mid-chunk).
    ticking: Vec<bool>,
    /// The run's GLB topology grouping (for the per-node log rollup;
    /// message accounting always uses the profile's hardware grid).
    glb_wpn: usize,
    /// Next free time of each node's NIC (cross-node send serialization).
    nic_free_at: Vec<u64>,
    /// Fault injection: extra pseudo-random delay per delivery.
    jitter_ns: u64,
    jitter_rng: crate::util::SplitMix64,
    /// Closed-loop tuning, when armed (see [`run_sim_adaptive`]).
    adapt: Option<AdaptPlane>,
    seq: u64,
    now: u64,
    messages: u64,
    cross_messages: u64,
    events: u64,
    done: bool,
}

impl<Q: TaskQueue> Sim<Q> {
    fn new<FQ, FI>(
        cfg: &GlbConfig,
        arch: &ArchProfile,
        cost: CostModel,
        jitter_ns: u64,
        adapt: Option<(AdaptiveConfig, u64)>,
        mut factory: FQ,
        root_init: FI,
    ) -> Self
    where
        FQ: FnMut(usize, usize) -> Q,
        FI: FnOnce(&mut Q),
    {
        let p = cfg.p;
        let ledger = SimLedger::new();
        let mut queues: Vec<Q> = (0..p).map(|i| factory(i, p)).collect();
        root_init(&mut queues[0]);
        // Hierarchical topology: shared node bags, one per GLB node
        // (flat runs allocate none — the seed-identical fast path).
        let topo = cfg.topology();
        let node_bags = topo.make_node_bags::<Q::Bag>();
        let workers: Vec<_> = queues
            .into_iter()
            .enumerate()
            .map(|(i, q)| {
                let nb = node_bags.as_ref().map(|bags| bags[topo.node_of(i)].clone());
                Worker::with_node_bag(i, p, cfg.params, q, ledger.clone(), nb)
            })
            .collect();
        let nodes = p.div_ceil(arch.places_per_node);
        let mut sim = Self {
            p,
            arch: *arch,
            cost,
            workers,
            ledger,
            heap: BinaryHeap::new(),
            inboxes: (0..p).map(|_| VecDeque::new()).collect(),
            ticking: vec![false; p],
            glb_wpn: cfg.params.workers_per_node,
            nic_free_at: vec![0; nodes],
            jitter_ns,
            jitter_rng: crate::util::SplitMix64::new(cfg.params.seed ^ 0x7177E2),
            adapt: adapt.map(|(cfg, interval)| AdaptPlane {
                ctrls: (0..p).map(|_| AdaptiveController::new(cfg)).collect(),
                next_obs: vec![interval; p],
                interval,
            }),
            seq: 0,
            now: 0,
            messages: 0,
            cross_messages: 0,
            events: 0,
            done: false,
        };
        // Kick empty workers into the steal protocol, then schedule the
        // first chunk of every working place — all at t = 0.
        let mut fx = Vec::new();
        for i in 0..p {
            sim.workers[i].kick_if_empty(&mut fx);
            sim.carry_out(i, 0, &mut fx);
        }
        for i in 0..p {
            if sim.workers[i].phase() == Phase::Working {
                sim.schedule_tick(i, 0);
            }
        }
        sim
    }

    fn push(&mut self, t: u64, ev: Ev<Q::Bag>) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { t, seq, ev }));
    }

    fn schedule_tick(&mut self, place: usize, t: u64) {
        debug_assert!(!self.ticking[place]);
        self.ticking[place] = true;
        self.push(t, Ev::Tick(place));
    }

    /// Send effects produced at virtual time `t` by `from`.
    fn carry_out(&mut self, from: usize, t: u64, fx: &mut Vec<Effect<Q::Bag>>) {
        for e in fx.drain(..) {
            match e {
                Effect::Send { to, msg } => {
                    let (na, nb) = (self.arch.node_of(from), self.arch.node_of(to));
                    let deliver_at = if na == nb {
                        // Intra-node: shared-memory latency, no NIC charge.
                        t + self.arch.intra_node_ns
                    } else {
                        self.cross_messages += 1;
                        // Cross-node messages serialize what the socket
                        // transport actually frames: the codec envelope
                        // plus the mesh data frame's destination and
                        // job-epoch prefix words.
                        let bytes = msg.wire_bytes(self.cost.item_bytes, |b: &Q::Bag| {
                            use crate::glb::task_bag::TaskBag;
                            b.size()
                        }) + crate::glb::wire::DATA_ROUTE_BYTES
                            + crate::glb::wire::DATA_JOB_BYTES;
                        // Occupy the source NIC: per-message overhead +
                        // serialization, shared by the node's places.
                        let occupy = self.arch.nic_msg_overhead_ns
                            + if self.arch.nic_bytes_per_ns.is_finite() {
                                (bytes as f64 / self.arch.nic_bytes_per_ns) as u64
                            } else {
                                0
                            };
                        let start = self.nic_free_at[na].max(t);
                        self.nic_free_at[na] = start + occupy;
                        start
                            + occupy
                            + self.arch.inter_node_base_ns
                            + self.arch.per_hop_ns * self.arch.hops(na, nb, self.nic_free_at.len())
                    };
                    let deliver_at = if self.jitter_ns > 0 {
                        deliver_at + self.jitter_rng.next_below(self.jitter_ns + 1)
                    } else {
                        deliver_at
                    };
                    self.messages += 1;
                    self.push(deliver_at, Ev::Deliver(to, msg));
                }
                Effect::Quiescent => {
                    self.done = true;
                }
            }
        }
    }

    /// Feed place `pl`'s gauges to its controller if its observation
    /// deadline has passed (virtual time `t`). The controller keeps
    /// recommending until the retune lands — [`Worker::try_retune`]
    /// refuses outside `Working`-with-no-outstanding-steal, so a
    /// starving place picks the change up at its next working boundary.
    fn observe_adapt(&mut self, pl: usize, t: u64) {
        let Some(ad) = &mut self.adapt else { return };
        if t < ad.next_obs[pl] {
            return;
        }
        ad.next_obs[pl] = t + ad.interval;
        let w = &mut self.workers[pl];
        let s = w.stats();
        let sample = ControllerSample {
            items: s.items_processed,
            starvations: s.starvations,
            bag_depth: w.queue().bag_size() as u64,
        };
        let n = w.params().n;
        if let Some(r) = ad.ctrls[pl].observe(sample, n) {
            if w.try_retune(r.l, r.n) {
                ad.ctrls[pl].confirm();
            }
        }
    }

    fn run<R: Reducer<Q::Result>>(mut self, reducer: &R) -> (RunOutput<Q::Result>, SimReport) {
        let mut fx: Vec<Effect<Q::Bag>> = Vec::with_capacity(8);
        if self.ledger.value() == 0 {
            self.done = true; // nothing was seeded anywhere
        }
        while !self.done {
            let Reverse(Entry { t, ev, .. }) = match self.heap.pop() {
                Some(e) => e,
                None => break,
            };
            self.now = t;
            self.events += 1;
            match ev {
                Ev::Tick(pl) => {
                    self.ticking[pl] = false;
                    // Chunk boundary: probe (drain inbox), then one chunk.
                    let mut handle_ns = 0;
                    while let Some(m) = self.inboxes[pl].pop_front() {
                        self.workers[pl].on_msg(m, &mut fx);
                        handle_ns += self.arch.handle_ns;
                    }
                    self.workers[pl].stats_mut().distribute_ns += handle_ns;
                    let t = t + handle_ns;
                    self.carry_out(pl, t, &mut fx);
                    if self.done {
                        self.now = t;
                        break;
                    }
                    if self.workers[pl].phase() != Phase::Working {
                        continue;
                    }
                    let outcome = self.workers[pl].step(&mut fx);
                    let cost_ns = self.arch.compute_ns(outcome.units as f64 * self.cost.ns_per_unit)
                        + self.cost.chunk_overhead_ns;
                    self.workers[pl].stats_mut().process_ns += cost_ns;
                    let end = t + cost_ns;
                    // Effects (steal requests, loot) leave at chunk end.
                    self.carry_out(pl, end, &mut fx);
                    if self.done {
                        // Quiescence observed at the end of this chunk: the
                        // makespan includes the chunk that drained the
                        // last work.
                        self.now = end;
                        break;
                    }
                    self.observe_adapt(pl, end);
                    if self.workers[pl].phase() == Phase::Working {
                        self.schedule_tick(pl, end);
                    }
                }
                Ev::Deliver(pl, msg) => {
                    if self.ticking[pl] {
                        // Mid-chunk: queue for the next boundary.
                        self.inboxes[pl].push_back(msg);
                        continue;
                    }
                    let was = self.workers[pl].phase();
                    self.workers[pl].on_msg(msg, &mut fx);
                    self.workers[pl].stats_mut().distribute_ns += self.arch.handle_ns;
                    let t = t + self.arch.handle_ns;
                    self.carry_out(pl, t, &mut fx);
                    if self.done {
                        self.now = t;
                        break;
                    }
                    self.observe_adapt(pl, t);
                    if self.workers[pl].phase() == Phase::Working && was != Phase::Working {
                        self.schedule_tick(pl, t);
                    }
                }
            }
        }

        debug_assert!(self.done, "simulation drained its event queue without quiescing");
        debug_assert_eq!(self.ledger.value(), 0, "tokens must balance at termination");

        let elapsed_ns = self.now;
        let mut stats = Vec::with_capacity(self.p);
        let mut results = Vec::with_capacity(self.p);
        let mut max_busy = 0;
        for w in self.workers {
            let (q, s) = w.into_parts();
            max_busy = max_busy.max(s.busy_ns());
            stats.push(s);
            results.push(q.result());
        }
        let out = RunOutput {
            result: reducer.reduce_all(results),
            log: RunLog::with_topology(stats, self.glb_wpn),
            elapsed_ns,
        };
        let report = SimReport {
            messages: self.messages,
            cross_messages: self.cross_messages,
            events: self.events,
            max_busy_ns: max_busy,
        };
        (out, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glb::task_bag::{ArrayListTaskBag, TaskBag};
    use crate::glb::task_queue::{ProcessOutcome, SumReducer};
    use crate::glb::GlbParams;

    /// The same binary-tree toy workload as the thread-runtime tests.
    struct TreeQueue {
        bag: ArrayListTaskBag<u32>,
        processed: u64,
    }

    impl TaskQueue for TreeQueue {
        type Bag = ArrayListTaskBag<u32>;
        type Result = u64;
        fn process(&mut self, n: usize) -> ProcessOutcome {
            let mut c = 0u64;
            while (c as usize) < n {
                match self.bag.pop() {
                    Some(v) => {
                        self.processed += 1;
                        c += 1;
                        if v > 0 {
                            self.bag.push(v - 1);
                            self.bag.push(v - 1);
                        }
                    }
                    None => break,
                }
            }
            ProcessOutcome::new(self.bag.size() > 0, c)
        }
        fn split(&mut self) -> Option<Self::Bag> {
            self.bag.split()
        }
        fn merge(&mut self, bag: Self::Bag) {
            TaskBag::merge(&mut self.bag, bag)
        }
        fn result(&self) -> u64 {
            self.processed
        }
        fn bag_size(&self) -> usize {
            self.bag.size()
        }
    }

    fn run(p: usize, root: u32, arch: &ArchProfile) -> (RunOutput<u64>, SimReport) {
        let cfg = GlbConfig::new(p, GlbParams::default().with_n(8).with_l(2));
        run_sim(
            &cfg,
            arch,
            CostModel::new(100.0, 50, 8),
            |_, _| TreeQueue { bag: ArrayListTaskBag::new(), processed: 0 },
            |q| q.bag.push(root),
            &SumReducer,
        )
    }

    #[test]
    fn sim_counts_tree_correctly() {
        for &p in &[1usize, 2, 4, 16, 64] {
            let (out, _) = run(p, 12, &BGQ);
            assert_eq!(out.result, (1 << 13) - 1, "p={p}");
        }
    }

    #[test]
    fn sim_is_deterministic() {
        let (a, ra) = run(32, 13, &K);
        let (b, rb) = run(32, 13, &K);
        assert_eq!(a.result, b.result);
        assert_eq!(a.elapsed_ns, b.elapsed_ns, "virtual time must replay exactly");
        assert_eq!(ra.messages, rb.messages);
        assert_eq!(ra.events, rb.events);
    }

    #[test]
    fn more_places_run_faster_in_virtual_time() {
        let (one, _) = run(1, 14, &POWER775);
        let (sixteen, _) = run(16, 14, &POWER775);
        assert_eq!(one.result, sixteen.result);
        assert!(
            (sixteen.elapsed_ns as f64) < one.elapsed_ns as f64 / 8.0,
            "16 places should be >8x faster: {} vs {}",
            sixteen.elapsed_ns,
            one.elapsed_ns
        );
    }

    #[test]
    fn slow_cores_take_longer() {
        let (p7, _) = run(4, 12, &POWER775);
        let (a2, _) = run(4, 12, &BGQ);
        assert!(a2.elapsed_ns > p7.elapsed_ns, "{} vs {}", a2.elapsed_ns, p7.elapsed_ns);
    }

    #[test]
    fn empty_workload_terminates() {
        let cfg = GlbConfig::new(4, GlbParams::default().with_l(2));
        let (out, _) = run_sim(
            &cfg,
            &IDEAL,
            CostModel::new(1.0, 0, 8),
            |_, _| TreeQueue { bag: ArrayListTaskBag::new(), processed: 0 },
            |_| {},
            &SumReducer,
        );
        assert_eq!(out.result, 0);
    }

    #[test]
    fn work_spreads_across_sim_places() {
        let (out, rep) = run(16, 14, &BGQ);
        let active = out.log.per_place.iter().filter(|s| s.units > 0).count();
        assert!(active >= 12, "most places should contribute, got {active}");
        assert!(rep.messages > 0);
    }

    #[test]
    fn hierarchical_sim_is_deterministic_and_correct() {
        let run_hier = || {
            let params = GlbParams::default().with_n(8).with_l(2).with_workers_per_node(8);
            let cfg = GlbConfig::new(32, params);
            run_sim(
                &cfg,
                &K,
                CostModel::new(100.0, 50, 8),
                |_, _| TreeQueue { bag: ArrayListTaskBag::new(), processed: 0 },
                |q| q.bag.push(13),
                &SumReducer,
            )
        };
        let (a, ra) = run_hier();
        let (b, rb) = run_hier();
        assert_eq!(a.result, (1 << 14) - 1);
        assert_eq!(a.result, b.result);
        assert_eq!(a.elapsed_ns, b.elapsed_ns, "hierarchical runs replay exactly");
        assert_eq!(ra.messages, rb.messages);
        assert_eq!(ra.cross_messages, rb.cross_messages);
        assert!(ra.cross_messages <= ra.messages);
    }

    #[test]
    fn flat_report_counts_cross_node_messages() {
        // 16 places on BGQ (16 places/node) fit one hardware node: every
        // delivery is intra-node. 64 places span 4 nodes: some must cross.
        let (_, one_node) = run(16, 10, &BGQ);
        assert_eq!(one_node.cross_messages, 0, "single node: nothing crosses");
        let (_, four_nodes) = run(64, 10, &BGQ);
        assert!(four_nodes.cross_messages > 0, "4 nodes must exchange work");
        assert!(four_nodes.cross_messages <= four_nodes.messages);
    }

    /// The static-vs-adaptive ablation fixture: a deliberately
    /// pathological tuning point for a skewed workload. `l = 64` on 64
    /// places derives a 1-dimensional lifeline cube — a ring — so
    /// root-seeded work trickles place-to-place, and `n = 256` keeps
    /// victims unresponsive between probes. Everything the adaptive
    /// controller is built to detect and fix.
    fn skewed_cfg() -> GlbConfig {
        GlbConfig::new(64, GlbParams::default().with_n(256).with_l(64))
    }

    fn run_skewed_static() -> (RunOutput<u64>, SimReport) {
        run_sim(
            &skewed_cfg(),
            &K,
            CostModel::new(100.0, 50, 8),
            |_, _| TreeQueue { bag: ArrayListTaskBag::new(), processed: 0 },
            |q| q.bag.push(16),
            &SumReducer,
        )
    }

    fn run_skewed_adaptive() -> (RunOutput<u64>, SimReport) {
        run_sim_adaptive(
            &skewed_cfg(),
            &K,
            CostModel::new(100.0, 50, 8),
            crate::glb::AdaptiveConfig::default(),
            20_000, // observe every 20µs of virtual time
            |_, _| TreeQueue { bag: ArrayListTaskBag::new(), processed: 0 },
            |q| q.bag.push(16),
            &SumReducer,
        )
    }

    #[test]
    fn adaptive_sim_is_deterministic() {
        let (a, ra) = run_skewed_adaptive();
        let (b, rb) = run_skewed_adaptive();
        assert_eq!(a.result, b.result);
        assert_eq!(a.elapsed_ns, b.elapsed_ns, "adaptive runs must replay exactly");
        assert_eq!(ra.messages, rb.messages);
        assert_eq!(ra.events, rb.events);
    }

    #[test]
    fn adaptive_sim_reduces_idle_on_skewed_load() {
        let (stat, _) = run_skewed_static();
        let (adap, _) = run_skewed_adaptive();
        // Correctness is schedule-independent: the reduction must match.
        assert_eq!(adap.result, (1 << 17) - 1);
        assert_eq!(adap.result, stat.result);
        // The controller must actually have intervened mid-run.
        let retunes: u64 = adap.log.per_place.iter().map(|s| s.retunes).sum();
        assert!(retunes >= 1, "persistent ring starvation must trigger a retune");
        let static_retunes: u64 = stat.log.per_place.iter().map(|s| s.retunes).sum();
        assert_eq!(static_retunes, 0, "the static baseline never retunes");
        // And the intervention must pay: deep-cube lifelines + finer
        // chunks spread the skewed load faster, so the virtual makespan
        // (and with it aggregate idle time) shrinks.
        assert!(
            adap.elapsed_ns < stat.elapsed_ns,
            "adaptive {} ns should beat static {} ns on the skewed ring",
            adap.elapsed_ns,
            stat.elapsed_ns
        );
        let idle = |out: &RunOutput<u64>| {
            let busy: u64 = out.log.per_place.iter().map(|s| s.busy_ns()).sum();
            (64 * out.elapsed_ns).saturating_sub(busy)
        };
        assert!(
            idle(&adap) < idle(&stat),
            "aggregate idle must shrink: adaptive {} vs static {}",
            idle(&adap),
            idle(&stat)
        );
    }

    #[test]
    fn statically_seeded_sim() {
        let cfg = GlbConfig::new(8, GlbParams::default().with_n(16).with_l(2));
        let (out, _) = run_sim(
            &cfg,
            &BGQ,
            CostModel::new(10.0, 10, 8),
            |_, _| {
                let mut q = TreeQueue { bag: ArrayListTaskBag::new(), processed: 0 };
                q.bag.push(9);
                q
            },
            |_| {},
            &SumReducer,
        );
        assert_eq!(out.result, 8 * ((1 << 10) - 1));
    }
}
