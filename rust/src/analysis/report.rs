//! Findings and rendering for `glb lint`.
//!
//! A [`Finding`] is one violated invariant at one source location. The
//! CLI prints every finding in `path:line: [rule] message` form (the
//! same shape rustc diagnostics and grep output use, so editors and CI
//! annotate them for free) followed by a per-rule summary, and exits
//! nonzero iff any finding exists.

use std::fmt;

/// The five invariant families `glb lint` enforces. See
/// [`crate::analysis`] for what each one protects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Wire-tag registry: `Msg`/`Ctrl` tags unique + dense, every
    /// variant exercised by all four wire property families.
    WireRegistry,
    /// Every wire tag in the registry is documented in
    /// `docs/wire-protocol.md`, and the doc names no stale tags.
    WireDoc,
    /// Every `unsafe` region carries a `// SAFETY:` justification.
    UnsafeSafety,
    /// `Ordering::Relaxed` only at allowlisted gauge/counter sites.
    AtomicOrdering,
    /// No `unwrap()`/`expect()` in declared reactor/socket hot regions.
    HotPathPanic,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::WireRegistry => "wire-registry",
            Rule::WireDoc => "wire-doc",
            Rule::UnsafeSafety => "unsafe-safety",
            Rule::AtomicOrdering => "atomic-ordering",
            Rule::HotPathPanic => "hot-path-panic",
        }
    }

    pub const ALL: [Rule; 5] = [
        Rule::WireRegistry,
        Rule::WireDoc,
        Rule::UnsafeSafety,
        Rule::AtomicOrdering,
        Rule::HotPathPanic,
    ];
}

/// One violated invariant at one source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: Rule,
    /// Path as given to the linter (repo-relative for `lint_tree`).
    pub path: String,
    /// 1-based line number (1 for file-scope findings).
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path,
            self.line,
            self.rule.name(),
            self.message
        )
    }
}

/// Render a full lint report: one line per finding plus a summary line
/// (always ends with a newline; empty findings render the clean banner).
pub fn render(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.to_string());
        out.push('\n');
    }
    if findings.is_empty() {
        out.push_str("glb lint: clean (5 rule families, 0 findings)\n");
    } else {
        let mut counts = String::new();
        for rule in Rule::ALL {
            let n = findings.iter().filter(|f| f.rule == rule).count();
            if n > 0 {
                if !counts.is_empty() {
                    counts.push_str(", ");
                }
                counts.push_str(&format!("{} {}", n, rule.name()));
            }
        }
        out.push_str(&format!(
            "glb lint: {} finding(s) ({counts})\n",
            findings.len()
        ));
    }
    out
}
