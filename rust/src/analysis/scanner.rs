//! A lightweight Rust source scanner for the invariant lint.
//!
//! This is deliberately **not** a parser: the rules in
//! [`crate::analysis::rules`] only need to (a) find tokens that are
//! really code rather than comment/string text, (b) map byte offsets to
//! lines, (c) recover the extent of a named `fn` body, and (d) know
//! which regions are test code. So the scanner does one pass that
//! blanks comment and string *contents* to spaces — preserving byte
//! offsets and newlines exactly, so every offset into the cleaned text
//! is also an offset into the raw text — and a few brace-matching
//! helpers on top. No dependencies, no syntax tree, no surprises when
//! rustc's grammar grows.

use std::ops::Range;

/// One scanned source file: the raw text plus its cleaned shadow.
pub struct Source {
    /// Path as given by the caller (repo-relative under `lint_tree`).
    pub path: String,
    /// Original text, used for reading comment lines (SAFETY audit).
    pub raw: String,
    /// Same length as `raw`, with comment bodies and string/char
    /// literal contents replaced by spaces (newlines kept).
    pub code: String,
    /// Byte offset of the start of each line, for offset→line mapping.
    line_starts: Vec<usize>,
}

impl Source {
    pub fn new(path: impl Into<String>, raw: impl Into<String>) -> Self {
        let raw = raw.into();
        let code = clean(&raw);
        let mut line_starts = vec![0];
        for (i, b) in raw.bytes().enumerate() {
            if b == b'\n' {
                line_starts.push(i + 1);
            }
        }
        Self {
            path: path.into(),
            raw,
            code,
            line_starts,
        }
    }

    /// 1-based line number containing byte offset `off`.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_starts.binary_search(&off) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Raw text of 1-based line `line` (without the newline), or ""
    /// when out of range.
    pub fn line_text(&self, line: usize) -> &str {
        if line == 0 || line > self.line_starts.len() {
            return "";
        }
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .map_or(self.raw.len(), |&next| next.saturating_sub(1));
        &self.raw[start..end.max(start)]
    }

    /// Byte offsets of every occurrence of `word` in the cleaned text
    /// where both neighbours are non-identifier bytes (so `unsafe`
    /// does not match inside `unsafe_op_in_unsafe_fn`).
    pub fn find_word(&self, word: &str) -> Vec<usize> {
        let mut out = Vec::new();
        let code = self.code.as_bytes();
        let mut from = 0;
        while let Some(rel) = self.code[from..].find(word) {
            let at = from + rel;
            let before_ok = at == 0 || !is_ident_byte(code[at - 1]);
            let end = at + word.len();
            let after_ok = end >= code.len() || !is_ident_byte(code[end]);
            if before_ok && after_ok {
                out.push(at);
            }
            from = at + 1;
        }
        out
    }

    /// Byte offsets of every occurrence of `needle` in the cleaned
    /// text, with no boundary requirements (for `.unwrap()`-style
    /// punctuation-anchored needles).
    pub fn find_str(&self, needle: &str) -> Vec<usize> {
        let mut out = Vec::new();
        let mut from = 0;
        while let Some(rel) = self.code[from..].find(needle) {
            out.push(from + rel);
            from = from + rel + 1;
        }
        out
    }

    /// Start offset of the statement containing `off`: one past the
    /// previous `;`, `{` or `}` in the cleaned text (0 at file start).
    /// Lets a rule match a symbol against its whole (possibly
    /// multi-line) statement rather than a single line.
    pub fn statement_start(&self, off: usize) -> usize {
        let code = self.code.as_bytes();
        let mut i = off;
        while i > 0 {
            let b = code[i - 1];
            if b == b';' || b == b'{' || b == b'}' {
                return i;
            }
            i -= 1;
        }
        0
    }

    /// Body extents (from `{` to the matching `}`, inclusive) of every
    /// `fn` named exactly `name`. Multiple matches are real in this
    /// tree: cfg-gated platform backends define the same method twice.
    pub fn fn_bodies(&self, name: &str) -> Vec<Range<usize>> {
        let code = self.code.as_bytes();
        let mut out = Vec::new();
        for at in self.find_word("fn") {
            let mut i = at + 2;
            while i < code.len() && code[i].is_ascii_whitespace() {
                i += 1;
            }
            let ident_start = i;
            while i < code.len() && is_ident_byte(code[i]) {
                i += 1;
            }
            if &self.code[ident_start..i] != name {
                continue;
            }
            // Walk the signature (generics, params, return type, where
            // clause) to the body `{`. `->` must not close an angle
            // bracket; a `;` at top level means a bodyless trait decl.
            let mut paren = 0i32;
            let mut angle = 0i32;
            let mut prev = 0u8;
            while i < code.len() {
                let b = code[i];
                match b {
                    b'(' => paren += 1,
                    b')' => paren -= 1,
                    b'<' => angle += 1,
                    b'>' if prev != b'-' => angle -= 1,
                    b'{' if paren == 0 && angle <= 0 => break,
                    b';' if paren == 0 => break,
                    _ => {}
                }
                if !b.is_ascii_whitespace() {
                    prev = b;
                }
                i += 1;
            }
            if i >= code.len() || code[i] != b'{' {
                continue;
            }
            if let Some(end) = match_brace(code, i) {
                out.push(i..end + 1);
            }
        }
        out
    }

    /// Extents of test code: every `#[cfg(test)]` or `#[test]`
    /// attribute's following braced item (the `mod tests { .. }` body
    /// or the test fn body).
    pub fn test_regions(&self) -> Vec<Range<usize>> {
        let code = self.code.as_bytes();
        let mut out = Vec::new();
        for marker in ["#[cfg(test)]", "#[test]"] {
            for at in self.find_str(marker) {
                let mut i = at + marker.len();
                while i < code.len() && code[i] != b'{' && code[i] != b';' {
                    i += 1;
                }
                if i >= code.len() || code[i] != b'{' {
                    continue;
                }
                if let Some(end) = match_brace(code, i) {
                    out.push(at..end + 1);
                }
            }
        }
        out
    }
}

/// True iff `off` falls inside any of `ranges`.
pub fn in_ranges(ranges: &[Range<usize>], off: usize) -> bool {
    ranges.iter().any(|r| r.contains(&off))
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Offset of the `}` matching the `{` at `open`, if the file is
/// balanced (the cleaned text has no braces inside literals, so plain
/// depth counting is exact).
fn match_brace(code: &[u8], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (i, &b) in code.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Blank comment bodies and string/char contents to spaces, keeping
/// newlines and all delimiters, so byte offsets and line numbers in the
/// result match the raw text exactly.
fn clean(raw: &str) -> String {
    let b = raw.as_bytes();
    let mut out = b.to_vec();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'/' if b.get(i + 1) == Some(&b'/') => {
                while i < b.len() && b[i] != b'\n' {
                    out[i] = b' ';
                    i += 1;
                }
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                out[i] = b' ';
                out[i + 1] = b' ';
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        out[i] = b' ';
                        out[i + 1] = b' ';
                        i += 2;
                    } else {
                        if b[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                }
            }
            b'r' | b'b' => {
                // r"..", r#".."#, br".." — no escapes; closed by a
                // quote followed by the same number of hashes. A bare
                // `b".."` byte string falls through to the `"` arm.
                if let Some((quote, hashes)) = raw_string_open(b, i) {
                    i = quote + 1;
                    while i < b.len() {
                        if b[i] == b'"' && closes_raw(b, i, hashes) {
                            i += 1 + hashes;
                            break;
                        }
                        if b[i] != b'\n' {
                            out[i] = b' ';
                        }
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            b'"' => {
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => {
                            out[i] = b' ';
                            if i + 1 < b.len() && b[i + 1] != b'\n' {
                                out[i + 1] = b' ';
                            }
                            i += 2;
                        }
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => i += 1,
                        _ => {
                            out[i] = b' ';
                            i += 1;
                        }
                    }
                }
            }
            b'\'' => {
                if b.get(i + 1) == Some(&b'\\') {
                    // Escaped char literal: blank through the close.
                    out[i + 1] = b' ';
                    let mut j = i + 2;
                    while j < b.len() && b[j] != b'\'' {
                        if b[j] != b'\n' {
                            out[j] = b' ';
                        }
                        j += 1;
                    }
                    i = j + 1;
                } else if b.get(i + 2) == Some(&b'\'') && b.get(i + 1) != Some(&b'\'') {
                    // Simple one-byte char literal 'x' (incl. '"').
                    out[i + 1] = b' ';
                    i += 3;
                } else {
                    // Lifetime: keep the identifier, skip the quote.
                    i += 1;
                }
            }
            _ => i += 1,
        }
    }
    match String::from_utf8(out) {
        Ok(s) => s,
        // Blanking is byte-for-byte and only writes ASCII spaces, so
        // this arm is unreachable for valid input; fall back to the
        // raw text rather than panic inside the linter.
        Err(_) => raw.to_string(),
    }
}

/// If `b[i]` starts a raw (byte) string literal token, return the
/// offset of its opening quote and the hash count.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    if i > 0 && is_ident_byte(b[i - 1]) {
        return None; // mid-identifier `r`/`b`
    }
    let mut j = i;
    if b[j] == b'b' {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j, hashes))
    } else {
        None
    }
}

fn closes_raw(b: &[u8], quote: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| b.get(quote + k) == Some(&b'#'))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cleaning_blanks_comments_and_strings_but_keeps_offsets() {
        let raw = "let a = 1; // trailing unwrap()\nlet s = \"unsafe { }\";\n";
        let src = Source::new("x.rs", raw);
        assert_eq!(src.raw.len(), src.code.len());
        assert!(src.find_word("unsafe").is_empty());
        assert!(src.find_str(".unwrap()").is_empty());
        assert_eq!(src.find_word("let").len(), 2);
    }

    #[test]
    fn char_literals_and_lifetimes_do_not_derail_the_scan() {
        let raw = "fn f<'a>(x: &'a str) -> char { let q = '\"'; let n = '\\n'; q }\n";
        let src = Source::new("x.rs", raw);
        // The '"' char literal must not open a string that swallows
        // the rest of the file.
        assert_eq!(src.find_word("q").len(), 2);
        assert_eq!(src.fn_bodies("f").len(), 1);
    }

    #[test]
    fn fn_bodies_handles_generics_and_return_arrows() {
        let raw = "impl T { fn wait<E: Copy>(&self, v: Vec<E>) -> io::Result<()> { v.len(); Ok(()) } }\nfn wait2() {}\n";
        let src = Source::new("x.rs", raw);
        let bodies = src.fn_bodies("wait");
        assert_eq!(bodies.len(), 1);
        assert!(src.code[bodies[0].clone()].contains("v.len()"));
        assert!(src.fn_bodies("missing").is_empty());
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let raw = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn helper() { x.unwrap() }\n}\n";
        let src = Source::new("x.rs", raw);
        let regions = src.test_regions();
        assert_eq!(regions.len(), 1);
        let at = src.find_str(".unwrap()")[0];
        assert!(in_ranges(&regions, at));
    }

    #[test]
    fn statement_start_spans_multi_line_calls() {
        let raw = "fn f() {\n    COUNTER.fetch_add(\n        1,\n        Ordering::Relaxed,\n    );\n}\n";
        let src = Source::new("x.rs", raw);
        let at = src.find_str("Ordering::Relaxed")[0];
        let span = &src.code[src.statement_start(at)..at];
        assert!(span.contains("COUNTER"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let raw = "let s = r#\"unsafe .unwrap() \"quoted\" \"#; let t = 1;\n";
        let src = Source::new("x.rs", raw);
        assert!(src.find_word("unsafe").is_empty());
        assert_eq!(src.find_word("t").len(), 1);
    }
}
