//! `glb lint` — the protocol/concurrency invariant checker.
//!
//! The GLB runtime hides its hardest invariants inside hand-rolled
//! code: the wire codec's tag registry, the reactor's raw epoll
//! syscalls, the credit-termination atomics. Convention is not an
//! enforcement mechanism, so this module machine-checks five rule
//! families over the source tree (dependency-free — a small scanner in
//! [`scanner`], rules + allowlists in [`rules`], rendering in
//! [`report`]):
//!
//! 1. **wire-registry** — every `Msg`/`Ctrl` tag constant in
//!    `glb/wire.rs` is unique and dense, `CTRL_VARIANTS` in
//!    `rust/tests/properties.rs` matches the registry, every variant is
//!    constructed by the property generators, and all four coverage
//!    families (round-trip, split-point truncation, hostile bytes,
//!    pooled bit-identity) exist and sweep the registry. Adding a tag
//!    without all four fails the build.
//! 2. **wire-doc** — the normative protocol spec
//!    `docs/wire-protocol.md` names every `TAG_`/`CTRL_` constant in
//!    the registry, and names no tag that the registry lacks. Code and
//!    spec cannot drift apart silently in either direction.
//! 3. **unsafe-safety** — every `unsafe` region carries a
//!    `// SAFETY:` justification ( `unsafe_op_in_unsafe_fn` is denied
//!    at the crate root on top).
//! 4. **atomic-ordering** — `Ordering::Relaxed` only at allowlisted
//!    gauge/counter statements, each with a recorded rationale
//!    ([`rules::RELAXED_ALLOWLIST`]).
//! 5. **hot-path-panic** — no `unwrap()`/`expect()` in the declared
//!    reactor event-loop and steady-state socket paths
//!    ([`rules::HOT_REGIONS`]); test code is exempt.
//!
//! Three enforcement surfaces share this one implementation: the
//! `glb lint` CLI verb, the `analysis_lint` tier-1 test asserting the
//! real tree lints clean, and a hard CI gate.

pub mod report;
pub mod rules;
pub mod scanner;

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use report::{render, Finding, Rule};
use scanner::Source;

/// One input file for [`lint_sources`]: rule applicability is decided
/// by path suffix, so fixtures can impersonate real tree locations.
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// Lint an explicit set of sources. Paths containing `tests/` are
/// exempt from the unsafe/ordering/panic rules (they feed the
/// wire-registry cross-reference instead); everything else gets all
/// five families. Findings come back sorted by (path, line).
///
/// Markdown files (`.md`) are not Rust: they bypass the scanner (whose
/// comment/string blanking would mangle prose) and feed only the
/// wire-doc cross-check as raw text.
pub fn lint_sources(files: &[SourceFile]) -> Vec<Finding> {
    let mut docs: Vec<(String, String)> = Vec::new();
    let mut sources: Vec<Source> = Vec::new();
    for f in files {
        if f.path.ends_with(".md") {
            docs.push((f.path.clone(), f.text.clone()));
        } else {
            sources.push(Source::new(f.path.clone(), f.text.clone()));
        }
    }
    let mut out = Vec::new();
    rules::check_wire_registry(&sources, &mut out);
    rules::check_wire_doc(&sources, &docs, &mut out);
    for src in &sources {
        if src.path.contains("tests/") {
            continue;
        }
        rules::check_unsafe_safety(src, &mut out);
        rules::check_atomic_ordering(src, &mut out);
    }
    rules::check_hot_path_panics(&sources, &mut out);
    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    out
}

/// Lint the repo tree rooted at `root` (the directory holding
/// `rust/src`): every `.rs` under `rust/src`, the wire property suite
/// `rust/tests/properties.rs`, and the protocol spec
/// `docs/wire-protocol.md` (whose absence is itself a wire-doc finding
/// whenever the tree has a wire registry to document).
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>> {
    let src_dir = root.join("rust/src");
    if !src_dir.is_dir() {
        anyhow::bail!(
            "{} has no rust/src directory; pass the repo root via --root",
            root.display()
        );
    }
    let mut paths = Vec::new();
    collect_rs(&src_dir, &mut paths)?;
    let props = root.join("rust/tests/properties.rs");
    if props.is_file() {
        paths.push(props);
    }
    let doc = root.join("docs/wire-protocol.md");
    if doc.is_file() {
        paths.push(doc.clone());
    }
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let text = fs::read_to_string(&p).with_context(|| format!("read {}", p.display()))?;
        let rel = p
            .strip_prefix(root)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile { path: rel, text });
    }
    let mut findings = lint_sources(&files);
    if !doc.is_file() && src_dir.join("glb/wire.rs").is_file() {
        findings.push(Finding {
            rule: Rule::WireDoc,
            path: "docs/wire-protocol.md".to_string(),
            line: 1,
            message: "missing protocol spec: every wire tag in rust/src/glb/wire.rs \
                      must be documented in docs/wire-protocol.md"
                .to_string(),
        });
        findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("list {}", dir.display()))? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
