//! The five invariant rule families behind `glb lint`.
//!
//! Each rule is a function from scanned sources to findings. The
//! allowlists live here too, next to the code they police, so loosening
//! an invariant is a reviewed diff to a rationale table — not a silent
//! drift.

use super::report::{Finding, Rule};
use super::scanner::{in_ranges, Source};

/// One permitted `Ordering::Relaxed` site: the statement containing the
/// `Relaxed` must mention `symbol` (or the whole file is cleared with
/// `"*"`), and the entry records *why* relaxed is correct there.
pub struct RelaxedAllow {
    /// Path suffix the entry applies to (e.g. `"place/socket.rs"`).
    pub path: &'static str,
    /// Symbol that must appear in the same statement, or `"*"`.
    pub symbol: &'static str,
    /// Why no stronger ordering is needed — shown in docs, kept next
    /// to the grant so reviewers see the argument, not just the hole.
    pub rationale: &'static str,
}

/// Every `Ordering::Relaxed` the runtime is allowed to contain.
///
/// The shape of a legitimate entry: a **monotonic gauge or counter**
/// whose readers tolerate staleness and never derive cross-variable
/// invariants from it. Anything coordinating control flow (shutdown
/// flags, credit books, retention ledgers) must use Acquire/Release or
/// SeqCst and therefore never lands here.
pub const RELAXED_ALLOWLIST: &[RelaxedAllow] = &[
    RelaxedAllow {
        path: "glb/metrics.rs",
        symbol: "*",
        rationale: "per-worker live gauges: independent cumulative counters published \
                    wait-free from the hot loop; each field is self-consistent and the \
                    sampler tolerates inter-field skew by design",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "MISROUTED_FRAMES",
        rationale: "protocol-violation counter asserted after threads join (join is the \
                    synchronization edge)",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "CROSS_EPOCH_FRAMES",
        rationale: "cross-epoch audit counter: the fence barrier makes stale frames \
                    structurally unreachable, so this only tallies would-be leaks for \
                    tests that assert zero after threads join",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "WIRE_TX_BYTES",
        rationale: "monotonic wire-byte counter; fleet conservation is checked only \
                    after the reactor thread is joined",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "WIRE_RX_BYTES",
        rationale: "monotonic wire-byte counter; see WIRE_TX_BYTES",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "FRAMES_TX",
        rationale: "reactor throughput counter feeding telemetry snapshots; staleness \
                    shifts a rate sample, never correctness",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "FRAMES_RX",
        rationale: "reactor throughput counter; see FRAMES_TX",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "BATCHES",
        rationale: "writev batch counter; see FRAMES_TX",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "STEAL_LAT_NS_SUM",
        rationale: "latency accumulator pair read only for reporting; a torn \
                    sum/count snapshot skews one sample of an average",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "STEAL_LAT_COUNT",
        rationale: "latency accumulator pair; see STEAL_LAT_NS_SUM",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "IO_THREADS",
        rationale: "io-thread spawn accounting: written before spawn / in reactor \
                    teardown, read after join, so the thread lifecycle already orders \
                    every access",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: "IO_THREADS_LIVE",
        rationale: "io-thread liveness gauge; see IO_THREADS",
    },
    RelaxedAllow {
        path: "place/socket.rs",
        symbol: ".seq",
        rationale: "per-rank stats sequence number: receiver de-duplicates by value, \
                    no ordering with the sampled gauges is assumed",
    },
    RelaxedAllow {
        path: "place/network.rs",
        symbol: "spurious_wakeups",
        rationale: "test-instrumentation wakeup counter in the legacy router; nothing \
                    reads it for control flow",
    },
];

/// A declared hot region for the panic lint: every body of `fn {func}`
/// in `path` must be free of `unwrap()`/`expect()`.
pub struct HotRegion {
    pub path: &'static str,
    pub func: &'static str,
    /// What makes this path hot — printed with the finding.
    pub why: &'static str,
}

/// The reactor event loop and the steady-state socket send/receive
/// paths. One-time setup (bootstrap handshakes, thread spawns) and
/// worker-side blocking control RPCs are deliberately *not* listed:
/// panicking there is a loud startup failure, not a mid-run hang.
pub const HOT_REGIONS: &[HotRegion] = &[
    HotRegion {
        path: "place/reactor.rs",
        func: "wait",
        why: "poller wait is the reactor's idle point; every frame passes it",
    },
    HotRegion {
        path: "place/reactor.rs",
        func: "push",
        why: "worker-side enqueue runs once per outbound frame",
    },
    HotRegion {
        path: "place/reactor.rs",
        func: "flush",
        why: "writev flush runs on every writable edge",
    },
    HotRegion {
        path: "place/reactor.rs",
        func: "wake",
        why: "cross-thread wakeup rides every enqueue",
    },
    HotRegion {
        path: "place/reactor.rs",
        func: "drain",
        why: "waker drain runs on every reactor wakeup",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "run",
        why: "the reactor event loop: a panic here hangs the whole fleet",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "flush_one",
        why: "steady-state socket send path",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "read_ready",
        why: "steady-state socket receive path",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "drain_frames",
        why: "per-frame decode/dispatch loop",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "on_mesh_msg",
        why: "per-message mesh dispatch (steal/loot/terminate)",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "on_root_ctrl",
        why: "credit/ack control frames arrive here throughout the run",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "on_spoke_ctrl",
        why: "replenish/stats control frames arrive here throughout the run",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "send_wire",
        why: "worker-side encode+enqueue runs once per outbound message",
    },
    HotRegion {
        path: "place/socket.rs",
        func: "purge_peer_marks",
        why: "runs from the reactor on peer close/leave",
    },
];

/// The four wire property families every `Msg`/`Ctrl` variant must be
/// exercised by, and the `rust/tests/properties.rs` fns that carry
/// each family. A new tag constant fails the build until all four
/// cover it (enforced via the dense-registry + `CTRL_VARIANTS` +
/// variant-reference checks below).
const WIRE_COVERAGE_FAMILIES: &[(&str, &[&str])] = &[
    (
        "round-trip",
        &[
            "prop_wire_roundtrip_every_msg_variant_uts",
            "prop_ctrl_roundtrip_every_variant",
        ],
    ),
    (
        "split-point truncation",
        &[
            "prop_wire_truncated_frames_error_not_panic",
            "prop_frame_assembler_decodes_any_split_points",
        ],
    ),
    ("hostile bytes", &["prop_ctrl_hostile_bytes_error_not_panic"]),
    (
        "pooled bit-identity",
        &["prop_pooled_encode_matches_allocating_encode_byte_for_byte"],
    ),
];

/// Property fns that must iterate the whole `Ctrl` registry: each must
/// reference `CTRL_VARIANTS` in its body, so widening the registry
/// automatically widens the fuzz loop (or fails the variant-count
/// check).
const CTRL_SWEEP_FNS: &[&str] = &[
    "prop_ctrl_roundtrip_every_variant",
    "prop_ctrl_hostile_bytes_error_not_panic",
    "prop_pooled_encode_matches_allocating_encode_byte_for_byte",
];

/// Rule 1 — wire-tag registry. Needs both `glb/wire.rs` and
/// `rust/tests/properties.rs` in the lint set; silently inert when
/// wire.rs is absent (fixture runs for other rules).
pub fn check_wire_registry(sources: &[Source], out: &mut Vec<Finding>) {
    let Some(wire) = sources.iter().find(|s| s.path.ends_with("glb/wire.rs")) else {
        return;
    };
    let Some(props) = sources.iter().find(|s| s.path.ends_with("properties.rs")) else {
        out.push(Finding {
            rule: Rule::WireRegistry,
            path: wire.path.clone(),
            line: 1,
            message: "wire.rs is in the lint set but rust/tests/properties.rs is not; \
                      tag coverage cannot be proven"
                .into(),
        });
        return;
    };

    let msg_tags = parse_tags(wire, "TAG_");
    let ctrl_tags = parse_tags(wire, "CTRL_");
    check_dense(wire, "Msg", &msg_tags, out);
    check_dense(wire, "Ctrl", &ctrl_tags, out);

    // properties.rs must pin the Ctrl variant count: its sweep loops
    // run 0..CTRL_VARIANTS, so a new tag without a matching bump is a
    // build break, and a bump without generator arms panics the tests.
    match parse_usize_const(props, "CTRL_VARIANTS") {
        None => out.push(Finding {
            rule: Rule::WireRegistry,
            path: props.path.clone(),
            line: 1,
            message: "properties.rs must declare `const CTRL_VARIANTS: usize = <n>` \
                      matching the Ctrl tag registry"
                .into(),
        }),
        Some((n, line)) if n != ctrl_tags.len() => out.push(Finding {
            rule: Rule::WireRegistry,
            path: props.path.clone(),
            line,
            message: format!(
                "CTRL_VARIANTS is {n} but glb/wire.rs declares {} Ctrl tags; \
                 the property sweeps no longer span the registry",
                ctrl_tags.len()
            ),
        }),
        Some(_) => {}
    }

    // Every tag's enum variant must appear in the property generators.
    for (family, tags) in [("Msg", &msg_tags), ("Ctrl", &ctrl_tags)] {
        for tag in tags {
            let variant = variant_name(&tag.name);
            let needle = format!("{family}::{variant}");
            if props.find_str(&needle).is_empty() {
                out.push(Finding {
                    rule: Rule::WireRegistry,
                    path: wire.path.clone(),
                    line: tag.line,
                    message: format!(
                        "{} declares wire tag {} but properties.rs never constructs \
                         `{needle}`: the variant is outside the round-trip/truncation/\
                         hostile-bytes/pooled fuzz generators",
                        wire.path, tag.name
                    ),
                });
            }
        }
    }

    // All four coverage families must be present by name…
    for (family, fns) in WIRE_COVERAGE_FAMILIES {
        for f in *fns {
            if props.fn_bodies(f).is_empty() {
                out.push(Finding {
                    rule: Rule::WireRegistry,
                    path: props.path.clone(),
                    line: 1,
                    message: format!(
                        "missing `fn {f}`: the {family} coverage family no longer \
                         exercises the wire registry"
                    ),
                });
            }
        }
    }
    // …and the Ctrl-sweeping ones must actually loop the registry.
    for f in CTRL_SWEEP_FNS {
        for body in props.fn_bodies(f) {
            let text = &props.code[body.clone()];
            if !text.contains("CTRL_VARIANTS") {
                out.push(Finding {
                    rule: Rule::WireRegistry,
                    path: props.path.clone(),
                    line: props.line_of(body.start),
                    message: format!(
                        "`fn {f}` does not iterate CTRL_VARIANTS; a new Ctrl tag \
                         would silently escape this family"
                    ),
                });
            }
        }
    }
}

/// Rule 2 — every `unsafe` region carries a `// SAFETY:` comment, on
/// the same line or in the comment block directly above.
pub fn check_unsafe_safety(src: &Source, out: &mut Vec<Finding>) {
    for at in src.find_word("unsafe") {
        let line = src.line_of(at);
        if has_safety_comment(src, line) {
            continue;
        }
        out.push(Finding {
            rule: Rule::UnsafeSafety,
            path: src.path.clone(),
            line,
            message: "unsafe region without a `// SAFETY:` justification comment \
                      (same line or the comment block directly above)"
                .into(),
        });
    }
}

fn has_safety_comment(src: &Source, line: usize) -> bool {
    if src.line_text(line).contains("SAFETY:") {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        let text = src.line_text(l);
        let trimmed = text.trim_start();
        if trimmed.starts_with("//") {
            if text.contains("SAFETY:") {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Rule 3 — `Ordering::Relaxed` only at allowlisted sites. Matching is
/// per *statement* (back to the previous `;`/`{`/`}`), so multi-line
/// `fetch_add` calls still see their symbol.
pub fn check_atomic_ordering(src: &Source, out: &mut Vec<Finding>) {
    let tests = src.test_regions();
    for at in src.find_str("Ordering::Relaxed") {
        if in_ranges(&tests, at) {
            continue;
        }
        let stmt = &src.code[src.statement_start(at)..at];
        let allowed = RELAXED_ALLOWLIST.iter().any(|a| {
            src.path.ends_with(a.path) && (a.symbol == "*" || stmt.contains(a.symbol))
        });
        if !allowed {
            out.push(Finding {
                rule: Rule::AtomicOrdering,
                path: src.path.clone(),
                line: src.line_of(at),
                message: "Ordering::Relaxed outside the declared gauge/counter \
                          allowlist; use the weakest ordering that is still correct \
                          and record the rationale in analysis/rules.rs"
                    .into(),
            });
        }
    }
}

/// Rule 4 — no `unwrap()`/`expect()` inside declared hot regions.
/// Test code inside those files is exempt; a declared region whose fn
/// disappeared is itself a finding (renames must update the table).
pub fn check_hot_path_panics(sources: &[Source], out: &mut Vec<Finding>) {
    for region in HOT_REGIONS {
        let Some(src) = sources.iter().find(|s| s.path.ends_with(region.path)) else {
            continue;
        };
        let bodies = src.fn_bodies(region.func);
        if bodies.is_empty() {
            out.push(Finding {
                rule: Rule::HotPathPanic,
                path: src.path.clone(),
                line: 1,
                message: format!(
                    "declared hot region `fn {}` not found (renamed? update \
                     HOT_REGIONS in analysis/rules.rs)",
                    region.func
                ),
            });
            continue;
        }
        let tests = src.test_regions();
        for body in bodies {
            for needle in [".unwrap()", ".expect("] {
                for at in src.find_str(needle) {
                    if !body.contains(&at) || in_ranges(&tests, at) {
                        continue;
                    }
                    out.push(Finding {
                        rule: Rule::HotPathPanic,
                        path: src.path.clone(),
                        line: src.line_of(at),
                        message: format!(
                            "`{needle}` in hot region `fn {}` ({}); propagate or \
                             absorb the error instead of panicking mid-run",
                            region.func, region.why
                        ),
                    });
                }
            }
        }
    }
}

/// Rule 2 — wire-protocol doc cross-check. The normative spec in
/// `docs/wire-protocol.md` must name every `TAG_`/`CTRL_` constant in
/// `glb/wire.rs` (a tag the doc lacks means the spec drifted behind
/// the code), and every tag-shaped token in the doc must exist in the
/// registry (a tag the code lacks means the spec describes frames the
/// runtime cannot produce). Inert when either file is absent from the
/// lint set — [`super::lint_tree`] turns a missing doc into a finding
/// itself, so fixture runs for other rules stay clean.
pub fn check_wire_doc(sources: &[Source], docs: &[(String, String)], out: &mut Vec<Finding>) {
    let Some(wire) = sources.iter().find(|s| s.path.ends_with("glb/wire.rs")) else {
        return;
    };
    let Some((doc_path, doc_text)) = docs.iter().find(|(p, _)| p.ends_with("wire-protocol.md"))
    else {
        return;
    };
    let mut tags = parse_tags(wire, "TAG_");
    tags.extend(parse_tags(wire, "CTRL_"));
    for tag in &tags {
        if !doc_text.contains(&tag.name) {
            out.push(Finding {
                rule: Rule::WireDoc,
                path: wire.path.clone(),
                line: tag.line,
                message: format!(
                    "wire tag {} is not documented in {doc_path}; the protocol spec \
                     has drifted behind the registry",
                    tag.name
                ),
            });
        }
    }
    let known: Vec<&str> = tags.iter().map(|t| t.name.as_str()).collect();
    for (idx, line) in doc_text.lines().enumerate() {
        for token in tag_tokens(line) {
            // CTRL_VARIANTS is the property-suite pin, not a tag; the
            // doc is allowed (encouraged) to explain it.
            if token == "CTRL_VARIANTS" || known.contains(&token) {
                continue;
            }
            out.push(Finding {
                rule: Rule::WireDoc,
                path: doc_path.clone(),
                line: idx + 1,
                message: format!(
                    "{token} is documented but not declared in glb/wire.rs; remove \
                     or rename the stale spec entry"
                ),
            });
        }
    }
}

/// Tag-shaped tokens in one doc line: maximal identifier runs that
/// start with `TAG_` or `CTRL_` and use only the registry's
/// SCREAMING_SNAKE alphabet.
fn tag_tokens(line: &str) -> Vec<&str> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_' {
            let start = i;
            while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
                i += 1;
            }
            let word = &line[start..i];
            if (word.starts_with("TAG_") || word.starts_with("CTRL_"))
                && word
                    .bytes()
                    .all(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_')
            {
                out.push(word);
            }
        } else {
            i += 1;
        }
    }
    out
}

/// A `const <PREFIX><NAME>: u8 = <value>;` wire-tag declaration.
struct TagConst {
    name: String,
    value: u64,
    line: usize,
}

fn parse_tags(src: &Source, prefix: &str) -> Vec<TagConst> {
    let code = src.code.as_bytes();
    let mut out = Vec::new();
    for at in src.find_word("const") {
        let mut i = at + "const".len();
        while i < code.len() && code[i].is_ascii_whitespace() {
            i += 1;
        }
        let start = i;
        while i < code.len() && (code[i].is_ascii_alphanumeric() || code[i] == b'_') {
            i += 1;
        }
        let name = &src.code[start..i];
        if !name.starts_with(prefix) {
            continue;
        }
        let rest = &src.code[i..];
        let Some(tail) = rest.trim_start().strip_prefix(':') else {
            continue;
        };
        let Some(val_text) = tail.trim_start().strip_prefix("u8") else {
            continue;
        };
        let Some(eq) = val_text.trim_start().strip_prefix('=') else {
            continue;
        };
        let digits: String = eq
            .trim_start()
            .chars()
            .take_while(char::is_ascii_digit)
            .collect();
        if let Ok(value) = digits.parse::<u64>() {
            out.push(TagConst {
                name: name.to_string(),
                value,
                line: src.line_of(at),
            });
        }
    }
    out
}

fn parse_usize_const(src: &Source, name: &str) -> Option<(usize, usize)> {
    for at in src.find_word(name) {
        let rest = &src.code[at + name.len()..];
        let Some(tail) = rest.trim_start().strip_prefix(':') else {
            continue;
        };
        let Some(val_text) = tail.trim_start().strip_prefix("usize") else {
            continue;
        };
        let Some(eq) = val_text.trim_start().strip_prefix('=') else {
            continue;
        };
        let digits: String = eq
            .trim_start()
            .chars()
            .take_while(char::is_ascii_digit)
            .collect();
        if let Ok(v) = digits.parse::<usize>() {
            return Some((v, src.line_of(at)));
        }
    }
    None
}

/// Tags must be unique and dense (0..n): a gap or duplicate means a
/// decoder match arm and the fuzz sweep disagree about the registry.
fn check_dense(wire: &Source, family: &str, tags: &[TagConst], out: &mut Vec<Finding>) {
    let mut values: Vec<u64> = tags.iter().map(|t| t.value).collect();
    values.sort_unstable();
    for (i, t) in tags.iter().enumerate() {
        if tags[..i].iter().any(|p| p.value == t.value) {
            out.push(Finding {
                rule: Rule::WireRegistry,
                path: wire.path.clone(),
                line: t.line,
                message: format!(
                    "{family} tag {} reuses wire value {}; tags must be unique",
                    t.name, t.value
                ),
            });
        }
    }
    let dense = values.iter().enumerate().all(|(i, &v)| v == i as u64);
    if !dense && !tags.is_empty() {
        out.push(Finding {
            rule: Rule::WireRegistry,
            path: wire.path.clone(),
            line: tags[0].line,
            message: format!(
                "{family} tag values are not dense 0..{}; decoders and property \
                 sweeps assume a gap-free registry",
                tags.len()
            ),
        });
    }
}

/// `TAG_STEAL` → `Steal`, `CTRL_PEER_MAP` → `PeerMap`.
fn variant_name(tag: &str) -> String {
    let bare = tag.split_once('_').map_or(tag, |(_, rest)| rest);
    let mut out = String::new();
    for part in bare.split('_') {
        let mut chars = part.chars();
        if let Some(first) = chars.next() {
            out.push(first.to_ascii_uppercase());
            for c in chars {
                out.push(c.to_ascii_lowercase());
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_names_follow_the_codec_naming() {
        assert_eq!(variant_name("TAG_STEAL"), "Steal");
        assert_eq!(variant_name("CTRL_PEER_MAP"), "PeerMap");
        assert_eq!(variant_name("CTRL_STATS"), "Stats");
    }

    #[test]
    fn tag_parsing_reads_const_u8_declarations() {
        let src = Source::new(
            "glb/wire.rs",
            "const TAG_A: u8 = 0;\nconst TAG_B: u8 = 1;\nconst OTHER: usize = 9;\n",
        );
        let tags = parse_tags(&src, "TAG_");
        assert_eq!(tags.len(), 2);
        assert_eq!(tags[1].value, 1);
        assert_eq!(tags[1].line, 2);
    }

    #[test]
    fn dense_check_flags_gaps_and_duplicates() {
        let src = Source::new(
            "glb/wire.rs",
            "const TAG_A: u8 = 0;\nconst TAG_B: u8 = 2;\nconst TAG_C: u8 = 2;\n",
        );
        let tags = parse_tags(&src, "TAG_");
        let mut out = Vec::new();
        check_dense(&src, "Msg", &tags, &mut out);
        assert_eq!(out.len(), 2);
    }
}
