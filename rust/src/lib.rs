//! # glb-rs — Lifeline-based Global Load Balancing
//!
//! A production-oriented reproduction of *“GLB: Lifeline-based Global Load
//! Balancing library in X10”* (Zhang et al., CS.DC 2013) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the GLB coordinator: task bags/queues, the
//!   lifeline work-stealing protocol, termination detection, two execution
//!   substrates (threads and a deterministic discrete-event simulator with
//!   Power 775 / Blue Gene/Q / K interconnect models), the benchmark apps
//!   (UTS, BC, Fib, N-Queens), the legacy baselines, and the figure
//!   harness.
//! * **L2 (python/compile/model.py, build-time)** — batched Brandes
//!   betweenness-centrality forward/backward as a JAX program, lowered
//!   once to HLO text.
//! * **L1 (python/compile/kernels/, build-time)** — the Pallas frontier
//!   matmul kernel the L2 model calls, verified against pure-jnp oracles.
//!
//! At runtime only Rust executes: `runtime::Engine` loads the AOT HLO
//! artifacts via the PJRT C API and the BC task queues invoke them on the
//! request path.

// Every unsafe operation must sit in its own `unsafe { .. }` block with a
// `// SAFETY:` justification — enforced mechanically by `glb lint`
// ([`analysis`]), which also polices atomic orderings, hot-path panics,
// and the wire-tag/property-test registry.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod apps;
pub mod baselines;
pub mod cli;
pub mod glb;
pub mod harness;
pub mod launch;
pub mod place;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;

/// Smoke helper used by integration tests: confirm a PJRT CPU client can
/// be constructed (validates the xla_extension wiring).
pub fn smoke() -> anyhow::Result<String> {
    let c = xla::PjRtClient::cpu()?;
    Ok(c.platform_name())
}
