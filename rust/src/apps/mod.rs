//! Benchmark applications on top of the GLB core (paper §2.5, §2.6,
//! §2.1 and the appendix):
//!
//! * [`uts`] — Unbalanced Tree Search (geometric law, SHA-1 splittable
//!   RNG), the paper's dynamically-balanced workload;
//! * [`bc`] — Betweenness Centrality over SSCA2/R-MAT graphs (sparse CPU
//!   Brandes and the dense batched PJRT engine), the paper's
//!   statically-balanceable workload;
//! * [`fib`] — the appendix's pedagogical Fibonacci example;
//! * [`nqueens`] — N-Queens, the §2.1 state-space-search family.

pub mod bc;
pub mod fib;
pub mod nqueens;
pub mod uts;
