//! The appendix's pedagogical Fibonacci example (paper Fig. 11).
//!
//! A task is an integer `x`: processing it adds `x` to the local result
//! when `x < 2`, otherwise it pushes tasks `x-1` and `x-2`. When all bags
//! drain, the sum-reduction over places is `fib(n)`. Deliberately the
//! worst possible way to compute Fibonacci — and exactly the paper's
//! demonstration of how little users must write.

use crate::glb::task_bag::{ArrayListTaskBag, TaskBag};
use crate::glb::task_queue::{ProcessOutcome, TaskQueue};

/// The Fibonacci task queue of Fig. 11 (`FibTQ`).
#[derive(Default)]
pub struct FibQueue {
    bag: ArrayListTaskBag<u64>,
    result: u64,
}

impl FibQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Root initialization (`init(n)` in the paper's listing).
    pub fn init(&mut self, n: u64) {
        self.bag.push(n);
    }
}

impl TaskQueue for FibQueue {
    type Bag = ArrayListTaskBag<u64>;
    type Result = u64;

    fn process(&mut self, n: usize) -> ProcessOutcome {
        let mut done = 0u64;
        while (done as usize) < n {
            match self.bag.pop() {
                Some(x) => {
                    done += 1;
                    if x < 2 {
                        self.result += x;
                    } else {
                        self.bag.push(x - 1);
                        self.bag.push(x - 2);
                    }
                }
                None => break,
            }
        }
        ProcessOutcome::new(self.bag.size() > 0, done)
    }

    fn split(&mut self) -> Option<Self::Bag> {
        self.bag.split()
    }

    fn merge(&mut self, bag: Self::Bag) {
        TaskBag::merge(&mut self.bag, bag);
    }

    fn result(&self) -> u64 {
        self.result
    }

    fn bag_size(&self) -> usize {
        self.bag.size()
    }
}

/// Closed-form check value.
pub fn fib(n: u64) -> u64 {
    let (mut a, mut b) = (0u64, 1u64);
    for _ in 0..n {
        let c = a + b;
        a = b;
        b = c;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glb::task_queue::SumReducer;
    use crate::glb::{GlbConfig, GlbParams};
    use crate::place::run_threads;
    use crate::sim::{run_sim, CostModel, IDEAL};

    #[test]
    fn fib_closed_form() {
        assert_eq!(fib(0), 0);
        assert_eq!(fib(1), 1);
        assert_eq!(fib(10), 55);
        assert_eq!(fib(20), 6765);
    }

    #[test]
    fn glb_fib_matches_threads() {
        for &(p, n) in &[(1usize, 16u64), (4, 18), (8, 20)] {
            let cfg = GlbConfig::new(p, GlbParams::default().with_n(32).with_l(2));
            let out = run_threads(&cfg, |_, _| FibQueue::new(), |q| q.init(n), &SumReducer);
            assert_eq!(out.result, fib(n), "p={p} n={n}");
        }
    }

    /// The fib bag crosses process boundaries on the tcp transport; its
    /// wire form must round-trip exactly and reject truncation cleanly
    /// (a crash-recovered retention ledger replays these bytes verbatim).
    #[test]
    fn fib_bag_round_trips_on_the_wire() {
        use crate::glb::wire::{Reader, WireCodec};
        let mut q = FibQueue::new();
        q.init(17);
        q.process(9);
        let bag = q.split().expect("a processed fib queue has tasks to split");
        let want = bag.items().to_vec();
        assert!(!want.is_empty());
        let mut buf = Vec::new();
        bag.encode(&mut buf);
        let got = ArrayListTaskBag::<u64>::decode(&mut Reader::new(&buf)).unwrap();
        assert_eq!(got.items(), &want[..], "encode→decode must be identity");
        // Every truncation of a valid encoding is a clean decode error,
        // never a panic or a silently short bag.
        for cut in 0..buf.len() {
            assert!(
                ArrayListTaskBag::<u64>::decode(&mut Reader::new(&buf[..cut])).is_err(),
                "truncation at {cut}/{} must fail to decode",
                buf.len()
            );
        }
    }

    #[test]
    fn glb_fib_matches_sim() {
        let cfg = GlbConfig::new(16, GlbParams::default().with_n(16).with_l(2));
        let (out, _) = run_sim(
            &cfg,
            &IDEAL,
            CostModel::new(5.0, 10, 8),
            |_, _| FibQueue::new(),
            |q| q.init(19),
            &SumReducer,
        );
        assert_eq!(out.result, fib(19));
    }
}
