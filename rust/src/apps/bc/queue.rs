//! The BC task queue (paper §2.6.2): `process(n)` computes Brandes for
//! the first `n` pending source vertices; `reduce()` adds betweenness
//! maps element-wise.
//!
//! Two engines drain the same bag:
//!
//! * [`BcEngine::Sparse`] — CPU Brandes on the replicated CSR graph;
//! * [`BcEngine::Dense`] — batched dense Brandes through the PJRT device
//!   service (the L1/L2 AOT artifact). Sources are batched up to the
//!   artifact's `S`; partial betweenness comes back as `f32` and is
//!   accumulated in `f64`.

use std::sync::Arc;

use super::bag::BcBag;
use super::brandes::{brandes_source, BrandesScratch};
use super::graph::Graph;
use crate::glb::task_bag::TaskBag;
use crate::glb::task_queue::{ProcessOutcome, TaskQueue};
use crate::runtime::DeviceHandle;

/// The compute engine for BC tasks.
pub enum BcEngine {
    /// Sparse CPU Brandes on the replicated graph.
    Sparse { graph: Arc<Graph>, scratch: BrandesScratch },
    /// Batched dense Brandes on the PJRT device service.
    Dense { handle: DeviceHandle },
}

/// Per-place BC state.
pub struct BcQueue {
    engine: BcEngine,
    bag: BcBag,
    bc: Vec<f64>,
    /// Edges traversed locally (work units / TEPS accounting).
    edges: u64,
    /// Scratch buffer for popped sources.
    batch: Vec<u32>,
}

impl BcQueue {
    /// Sparse-engine queue over a replicated graph.
    pub fn sparse(graph: Arc<Graph>) -> Self {
        let n = graph.n();
        Self {
            engine: BcEngine::Sparse { scratch: BrandesScratch::new(n), graph },
            bag: BcBag::new(),
            bc: vec![0.0; n],
            edges: 0,
            batch: Vec::new(),
        }
    }

    /// Dense-engine queue speaking to the device service.
    pub fn dense(handle: DeviceHandle) -> Self {
        let n = handle.n();
        Self {
            engine: BcEngine::Dense { handle },
            bag: BcBag::new(),
            bc: vec![0.0; n],
            edges: 0,
            batch: Vec::new(),
        }
    }

    /// Statically assign the interval `[lo, hi)` to this place (legacy
    /// layout) or seed the whole range at the root (GLB layout).
    pub fn assign(&mut self, lo: u32, hi: u32) {
        TaskBag::merge(&mut self.bag, BcBag::interval(lo, hi));
    }

    /// Assign an explicit set of source vertices (the randomized legacy
    /// layout).
    pub fn assign_sources(&mut self, sources: &[u32]) {
        for &s in sources {
            TaskBag::merge(&mut self.bag, BcBag::interval(s, s + 1));
        }
    }

    pub fn edges(&self) -> u64 {
        self.edges
    }

    pub fn bc(&self) -> &[f64] {
        &self.bc
    }
}

impl TaskQueue for BcQueue {
    type Bag = BcBag;
    type Result = Vec<f64>;

    fn process(&mut self, n: usize) -> ProcessOutcome {
        let before = self.edges;
        match &mut self.engine {
            BcEngine::Sparse { graph, scratch } => {
                self.batch.clear();
                self.bag.take(n, &mut self.batch);
                for &s in &self.batch {
                    self.edges += brandes_source(graph, s, &mut self.bc, scratch);
                }
            }
            BcEngine::Dense { handle } => {
                let mut remaining = n;
                while remaining > 0 && self.bag.size() > 0 {
                    let k = remaining.min(handle.batch());
                    self.batch.clear();
                    self.bag.take(k, &mut self.batch);
                    let out = handle
                        .brandes(&self.batch)
                        .expect("device service failed (artifacts missing or shape mismatch)");
                    debug_assert_eq!(out.bc.len(), self.bc.len());
                    for (acc, x) in self.bc.iter_mut().zip(&out.bc) {
                        *acc += *x as f64;
                    }
                    self.edges += out.edges;
                    remaining -= self.batch.len();
                }
            }
        }
        ProcessOutcome::new(self.bag.size() > 0, self.edges - before)
    }

    fn split(&mut self) -> Option<BcBag> {
        self.bag.split()
    }

    fn merge(&mut self, bag: BcBag) {
        TaskBag::merge(&mut self.bag, bag);
    }

    fn result(&self) -> Vec<f64> {
        self.bc.clone()
    }

    fn bag_size(&self) -> usize {
        self.bag.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::bc::sequential_bc;
    use crate::glb::task_queue::VecSumReducer;
    use crate::glb::{GlbConfig, GlbParams};
    use crate::place::run_threads;
    use crate::sim::{run_sim, CostModel, POWER775};

    fn close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-6 * (1.0 + y.abs()), "bc[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn glb_bc_matches_sequential_threads() {
        let g = Arc::new(Graph::rmat(crate::apps::bc::RmatParams {
            scale: 7,
            ..Default::default()
        }));
        let (expect, _) = sequential_bc(&g);
        for &p in &[1usize, 4] {
            let cfg = GlbConfig::new(p, GlbParams::default().with_n(4).with_l(2));
            let n = g.n() as u32;
            let gg = g.clone();
            let out = run_threads(
                &cfg,
                move |_, _| BcQueue::sparse(gg.clone()),
                |q| q.assign(0, n),
                &VecSumReducer,
            );
            close(&out.result, &expect);
        }
    }

    #[test]
    fn glb_bc_matches_sequential_sim() {
        let g = Arc::new(Graph::rmat(crate::apps::bc::RmatParams {
            scale: 6,
            ..Default::default()
        }));
        let (expect, _) = sequential_bc(&g);
        let cfg = GlbConfig::new(8, GlbParams::default().with_n(2).with_l(2));
        let n = g.n() as u32;
        let gg = g.clone();
        let (out, _) = run_sim(
            &cfg,
            &POWER775,
            CostModel::new(3.0, 80, 8),
            move |_, _| BcQueue::sparse(gg.clone()),
            |q| q.assign(0, n),
            &VecSumReducer,
        );
        close(&out.result, &expect);
    }

    #[test]
    fn static_assignment_matches_dynamic() {
        // Seeding each place a slice (BC's "static" layout) must still
        // produce the full map, since GLB only *rebalances*.
        let g = Arc::new(Graph::rmat(crate::apps::bc::RmatParams {
            scale: 6,
            ..Default::default()
        }));
        let (expect, _) = sequential_bc(&g);
        let p = 4usize;
        let n = g.n() as u32;
        let per = n / p as u32;
        let cfg = GlbConfig::new(p, GlbParams::default().with_n(8).with_l(2));
        let gg = g.clone();
        let out = run_threads(
            &cfg,
            move |i, np| {
                let mut q = BcQueue::sparse(gg.clone());
                let lo = i as u32 * per;
                let hi = if i == np - 1 { n } else { lo + per };
                q.assign(lo, hi);
                q
            },
            |_| {},
            &VecSumReducer,
        );
        close(&out.result, &expect);
    }

    #[test]
    fn edges_are_counted_as_units() {
        let g = Arc::new(Graph::path(32));
        let cfg = GlbConfig::new(2, GlbParams::default().with_n(4).with_l(2));
        let n = g.n() as u32;
        let gg = g.clone();
        let out = run_threads(
            &cfg,
            move |_, _| BcQueue::sparse(gg.clone()),
            |q| q.assign(0, n),
            &VecSumReducer,
        );
        let total_units: u64 = out.log.per_place.iter().map(|s| s.units).sum();
        // Each of 32 BFS traversals touches all 62 directed edges.
        assert_eq!(total_units, 32 * 62);
    }
}
