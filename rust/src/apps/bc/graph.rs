//! Graph representation (CSR) and generators.
//!
//! The paper's BC benchmark draws its input from SSCA2 v2.2: an R-MAT
//! power-law generator. We implement R-MAT with the SSCA2 parameters
//! (a=0.55, b=0.1, c=0.1, d=0.25, edge factor 8) plus the deterministic
//! test graphs (path/star/cycle/two-components) and the paper's §2.6.1
//! degenerate triangular DAG that motivates dynamic balancing.

use crate::util::SplitMix64;

/// R-MAT generation parameters.
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    /// log2 of the vertex count (SSCA2 SCALE).
    pub scale: u32,
    /// Edges per vertex.
    pub edge_factor: u32,
    /// Quadrant probabilities (must sum to 1).
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Generator seed.
    pub seed: u64,
}

impl Default for RmatParams {
    fn default() -> Self {
        // SSCA2 v2.2 parameter set.
        Self { scale: 10, edge_factor: 8, a: 0.55, b: 0.1, c: 0.1, seed: 0x55CA2 }
    }
}

/// Directed graph in CSR form. BC treats edges as directed (the SSCA2
/// generator emits directed edges); undirected test graphs insert both
/// arcs.
#[derive(Debug, Clone)]
pub struct Graph {
    offsets: Vec<u32>,
    targets: Vec<u32>,
}

impl Graph {
    /// Build from an edge list (deduplicated, self-loops dropped).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut uniq: Vec<(u32, u32)> =
            edges.iter().copied().filter(|&(u, v)| u != v).collect();
        uniq.sort_unstable();
        uniq.dedup();
        let mut offsets = vec![0u32; n + 1];
        for &(u, _) in &uniq {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let targets = uniq.iter().map(|&(_, v)| v).collect();
        Self { offsets, targets }
    }

    /// Vertex count.
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Directed edge count.
    pub fn m(&self) -> usize {
        self.targets.len()
    }

    /// Out-neighbours of `v`.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.targets[self.offsets[v as usize] as usize..self.offsets[v as usize + 1] as usize]
    }

    /// Out-degree of `v`.
    #[inline]
    pub fn degree(&self, v: u32) -> u32 {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    /// Dense row-major 0/1 adjacency (`adj[u*n + v] = 1` iff `u -> v`)
    /// for the PJRT engine. O(n^2) memory — BC's replicated-graph
    /// assumption makes this the intended regime.
    pub fn dense_adjacency(&self) -> Vec<f32> {
        let n = self.n();
        let mut adj = vec![0.0f32; n * n];
        for u in 0..n as u32 {
            for &v in self.neighbors(u) {
                adj[u as usize * n + v as usize] = 1.0;
            }
        }
        adj
    }

    // ----------------------------------------------------------------
    // generators
    // ----------------------------------------------------------------

    /// SSCA2-style R-MAT graph with `2^scale` vertices.
    pub fn rmat(p: RmatParams) -> Self {
        let n = 1usize << p.scale;
        let m = n * p.edge_factor as usize;
        let mut rng = SplitMix64::new(p.seed);
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            let (mut u, mut v) = (0usize, 0usize);
            let mut span = n >> 1;
            while span > 0 {
                let r = rng.next_f64();
                // Slightly perturb quadrant probabilities per level, as
                // the R-MAT paper prescribes, to avoid degenerate
                // striping.
                let noise = 0.95 + 0.1 * rng.next_f64();
                let (pa, pb, pc) = (p.a * noise, p.b, p.c);
                let total = pa + pb + pc + (1.0 - p.a - p.b - p.c);
                let r = r * total;
                if r < pa {
                    // top-left
                } else if r < pa + pb {
                    v += span;
                } else if r < pa + pb + pc {
                    u += span;
                } else {
                    u += span;
                    v += span;
                }
                span >>= 1;
            }
            edges.push((u as u32, v as u32));
        }
        Self::from_edges(n, &edges)
    }

    /// Undirected path `0 - 1 - ... - (n-1)`.
    pub fn path(n: usize) -> Self {
        let mut e = Vec::new();
        for i in 0..n.saturating_sub(1) as u32 {
            e.push((i, i + 1));
            e.push((i + 1, i));
        }
        Self::from_edges(n, &e)
    }

    /// Undirected star: center 0, leaves `1..=k`.
    pub fn star(k: usize) -> Self {
        let mut e = Vec::new();
        for i in 1..=k as u32 {
            e.push((0, i));
            e.push((i, 0));
        }
        Self::from_edges(k + 1, &e)
    }

    /// Undirected cycle of n vertices.
    pub fn cycle(n: usize) -> Self {
        let mut e = Vec::new();
        for i in 0..n as u32 {
            let j = ((i + 1) as usize % n) as u32;
            e.push((i, j));
            e.push((j, i));
        }
        Self::from_edges(n, &e)
    }

    /// The paper's §2.6.1 degenerate imbalance graph: edge `(i, j)` iff
    /// `i < j`. "The work associated with vertex 1 is much more than the
    /// work associated with vertex N."
    pub fn triangular(n: usize) -> Self {
        let mut e = Vec::new();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                e.push((i, j));
            }
        }
        Self::from_edges(n, &e)
    }

    /// Two disconnected undirected cliques of sizes `a` and `b` — used to
    /// test early-exit behaviour on small components.
    pub fn two_cliques(a: usize, b: usize) -> Self {
        let mut e = Vec::new();
        for i in 0..a as u32 {
            for j in 0..a as u32 {
                if i != j {
                    e.push((i, j));
                }
            }
        }
        for i in 0..b as u32 {
            for j in 0..b as u32 {
                if i != j {
                    e.push((a as u32 + i, a as u32 + j));
                }
            }
        }
        Self::from_edges(a + b, &e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_roundtrip() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (2, 3), (0, 1), (1, 1)]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 3, "dup (0,1) and self-loop (1,1) dropped");
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[] as &[u32]);
        assert_eq!(g.degree(2), 1);
    }

    #[test]
    fn rmat_shape() {
        let g = Graph::rmat(RmatParams { scale: 8, ..Default::default() });
        assert_eq!(g.n(), 256);
        // After dedup, edge count is below n*ef but should stay substantial.
        assert!(g.m() > 800, "m={}", g.m());
        assert!(g.m() <= 256 * 8);
    }

    #[test]
    fn rmat_is_deterministic_and_seed_sensitive() {
        let a = Graph::rmat(RmatParams { scale: 7, ..Default::default() });
        let b = Graph::rmat(RmatParams { scale: 7, ..Default::default() });
        assert_eq!(a.targets, b.targets);
        let c = Graph::rmat(RmatParams { scale: 7, seed: 99, ..Default::default() });
        assert_ne!(a.targets, c.targets);
    }

    #[test]
    fn rmat_is_skewed() {
        // Power-law: the max degree should far exceed the mean.
        let g = Graph::rmat(RmatParams { scale: 10, ..Default::default() });
        let mean = g.m() as f64 / g.n() as f64;
        let max = (0..g.n() as u32).map(|v| g.degree(v)).max().unwrap();
        assert!(max as f64 > 5.0 * mean, "max={max} mean={mean}");
    }

    #[test]
    fn triangular_degrees_decrease() {
        let g = Graph::triangular(10);
        assert_eq!(g.degree(0), 9);
        assert_eq!(g.degree(9), 0);
    }

    #[test]
    fn dense_adjacency_matches_csr() {
        let g = Graph::path(4);
        let adj = g.dense_adjacency();
        assert_eq!(adj.len(), 16);
        assert_eq!(adj[0 * 4 + 1], 1.0);
        assert_eq!(adj[1 * 4 + 0], 1.0);
        assert_eq!(adj[0 * 4 + 2], 0.0);
        let ones = adj.iter().filter(|&&x| x == 1.0).count();
        assert_eq!(ones, g.m());
    }

    #[test]
    fn two_cliques_disconnected() {
        let g = Graph::two_cliques(3, 4);
        assert_eq!(g.n(), 7);
        for v in 0..3u32 {
            assert!(g.neighbors(v).iter().all(|&t| t < 3));
        }
        for v in 3..7u32 {
            assert!(g.neighbors(v).iter().all(|&t| t >= 3));
        }
    }
}
