//! Betweenness Centrality (paper §2.6) — SSCA2 kernel 4.
//!
//! The graph is "small enough to fit in the memory of a single place" and
//! is replicated; the *work* is the per-source Brandes computation, which
//! GLB balances as vertex-interval tasks. Two compute engines drain those
//! tasks:
//!
//! * [`brandes`] — the sparse CPU Brandes (reference semantics, f64);
//! * the **dense batched PJRT engine** ([`queue::BcEngine::Dense`]) — the
//!   L2 JAX / L1 Pallas batched Brandes executed through
//!   [`crate::runtime::DeviceHandle`], the paper's compute re-thought for
//!   the MXU (see DESIGN.md §Hardware-Adaptation).

pub mod bag;
pub mod brandes;
pub mod graph;
pub mod interruptible;
pub mod queue;

pub use bag::BcBag;
pub use interruptible::InterruptibleBcQueue;
pub use brandes::{brandes_source, BrandesScratch};
pub use graph::{Graph, RmatParams};
pub use queue::{BcEngine, BcQueue};

/// Full sequential BC over all sources (validation + baselines). Returns
/// (betweenness map, total edges traversed).
pub fn sequential_bc(g: &Graph) -> (Vec<f64>, u64) {
    let mut bc = vec![0.0; g.n()];
    let mut scratch = BrandesScratch::new(g.n());
    let mut edges = 0;
    for s in 0..g.n() as u32 {
        edges += brandes_source(g, s, &mut bc, &mut scratch);
    }
    (bc, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_bc_on_path() {
        // Undirected path 0-1-2: ordered pairs (0,2) and (2,0) pass
        // through 1 ⇒ BC(1) = 2.
        let g = Graph::path(3);
        let (bc, edges) = sequential_bc(&g);
        assert_eq!(bc, vec![0.0, 2.0, 0.0]);
        assert!(edges > 0);
    }

    #[test]
    fn sequential_bc_on_star() {
        // Undirected star with center 0 and k = 4 leaves: every ordered
        // leaf pair routes through the center ⇒ BC(0) = k(k-1) = 12.
        let g = Graph::star(4);
        let (bc, _) = sequential_bc(&g);
        assert_eq!(bc[0], 12.0);
        assert!(bc[1..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sequential_bc_on_cycle() {
        // Symmetric graph: all vertices equal betweenness.
        let g = Graph::cycle(6);
        let (bc, _) = sequential_bc(&g);
        for &v in &bc[1..] {
            assert!((v - bc[0]).abs() < 1e-9, "{bc:?}");
        }
        assert!(bc[0] > 0.0);
    }
}
