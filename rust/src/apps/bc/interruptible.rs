//! Interruptible Brandes (paper §2.6.2).
//!
//! "We then realized that it took a Worker too long before it responded
//! to the work stealing requests even when its task granularity is
//! **one** vertex. So we changed the code that computes each vertex to
//! an interruptable state machine. In this way, a Worker can respond to
//! stealing requests without completing one vertex computation."
//!
//! [`InterruptibleBcQueue`] is that state machine: `process(n)` spends an
//! *edge* budget (`n` edges) instead of a source budget, suspending
//! mid-BFS (or mid-backward-sweep) when the budget runs out. Chunk
//! latency becomes `O(n)` edges regardless of how expensive the current
//! source is — the responsiveness the paper needed for BC's σ collapse
//! (Figs 6/8/10). The in-progress source is not relocatable (exactly as
//! in the paper); only pending sources move.

use std::sync::Arc;

use super::bag::BcBag;
use super::graph::Graph;
use crate::glb::task_bag::TaskBag;
use crate::glb::task_queue::{ProcessOutcome, TaskQueue};

/// Phase of the suspended per-source computation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Forward BFS: scanning `order[cursor]`'s adjacency.
    Bfs,
    /// Backward dependency sweep at `order[cursor]` (descending).
    Back,
}

/// A per-source Brandes computation that can stop and resume at vertex
/// granularity within both sweeps.
struct Suspended {
    source: u32,
    dist: Vec<i32>,
    sigma: Vec<f64>,
    delta: Vec<f64>,
    order: Vec<u32>,
    phase: Phase,
    /// Index into `order`: next vertex to scan (Bfs ascending, Back
    /// descending).
    cursor: usize,
}

impl Suspended {
    fn start(g: &Graph, source: u32) -> Self {
        let n = g.n();
        let mut s = Self {
            source,
            dist: vec![-1; n],
            sigma: vec![0.0; n],
            delta: vec![0.0; n],
            order: Vec::with_capacity(64),
            phase: Phase::Bfs,
            cursor: 0,
        };
        s.dist[source as usize] = 0;
        s.sigma[source as usize] = 1.0;
        s.order.push(source);
        s
    }

    /// Run until `budget` edge *scans* are spent (both sweeps consume
    /// budget) or the source completes. Returns `(forward_bfs_edges,
    /// scans_spent, finished)` — only forward edges count toward the
    /// TEPS/work metric, matching `brandes_source` (the backward sweep's
    /// cost is folded into the calibrated ns/edge).
    fn run(&mut self, g: &Graph, bc: &mut [f64], budget: u64) -> (u64, u64, bool) {
        let mut edges = 0u64;
        let mut scans = 0u64;
        if self.phase == Phase::Bfs {
            while self.cursor < self.order.len() {
                if scans >= budget {
                    return (edges, scans, false);
                }
                let v = self.order[self.cursor];
                self.cursor += 1;
                let dv = self.dist[v as usize];
                let sv = self.sigma[v as usize];
                for &w in g.neighbors(v) {
                    edges += 1;
                    scans += 1;
                    if self.dist[w as usize] < 0 {
                        self.dist[w as usize] = dv + 1;
                        self.order.push(w);
                    }
                    if self.dist[w as usize] == dv + 1 {
                        self.sigma[w as usize] += sv;
                    }
                }
            }
            self.phase = Phase::Back;
            self.cursor = self.order.len();
        }
        // Backward sweep.
        while self.cursor > 0 {
            if scans >= budget {
                return (edges, scans, false);
            }
            let v = self.order[self.cursor - 1];
            self.cursor -= 1;
            let dv = self.dist[v as usize];
            let sv = self.sigma[v as usize];
            let mut acc = 0.0;
            for &w in g.neighbors(v) {
                scans += 1;
                if self.dist[w as usize] == dv + 1 {
                    acc += sv / self.sigma[w as usize] * (1.0 + self.delta[w as usize]);
                }
            }
            self.delta[v as usize] += acc;
            if v != self.source {
                bc[v as usize] += self.delta[v as usize];
            }
        }
        (edges, scans, true)
    }
}

/// BC task queue with the paper's interruptible-vertex state machine.
pub struct InterruptibleBcQueue {
    graph: Arc<Graph>,
    bag: BcBag,
    bc: Vec<f64>,
    edges: u64,
    current: Option<Suspended>,
}

impl InterruptibleBcQueue {
    pub fn new(graph: Arc<Graph>) -> Self {
        let n = graph.n();
        Self { graph, bag: BcBag::new(), bc: vec![0.0; n], edges: 0, current: None }
    }

    /// Statically assign the interval `[lo, hi)` (see `BcQueue::assign`).
    pub fn assign(&mut self, lo: u32, hi: u32) {
        TaskBag::merge(&mut self.bag, BcBag::interval(lo, hi));
    }
}

impl TaskQueue for InterruptibleBcQueue {
    type Bag = BcBag;
    type Result = Vec<f64>;

    /// `n` is the **edge budget** for this chunk (paper: sub-vertex
    /// granularity). Units reported are edges, like `BcQueue`.
    fn process(&mut self, n: usize) -> ProcessOutcome {
        let budget = n as u64;
        let mut spent = 0u64;
        let mut fwd_edges = 0u64;
        let mut taken = Vec::new();
        while spent < budget {
            let mut cur = match self.current.take() {
                Some(c) => c,
                None => {
                    taken.clear();
                    self.bag.take(1, &mut taken);
                    match taken.first() {
                        Some(&s) => Suspended::start(&self.graph, s),
                        None => break,
                    }
                }
            };
            let (e, scans, finished) = cur.run(&self.graph, &mut self.bc, budget - spent);
            spent += scans.max(1); // a zero-degree source still makes progress
            fwd_edges += e;
            if !finished {
                self.current = Some(cur);
            }
        }
        self.edges += fwd_edges;
        let more = self.current.is_some() || self.bag.size() > 0;
        // Work units: half the scans — a completed source spends 2E scans
        // (forward + backward) and must report E units like `BcQueue`, and
        // a suspended backward-only chunk must still be charged by the
        // simulator's cost model.
        ProcessOutcome::new(more, spent.div_ceil(2))
    }

    fn split(&mut self) -> Option<BcBag> {
        self.bag.split()
    }

    fn merge(&mut self, bag: BcBag) {
        TaskBag::merge(&mut self.bag, bag);
    }

    fn result(&self) -> Vec<f64> {
        debug_assert!(self.current.is_none(), "result() before completion");
        self.bc.clone()
    }

    /// Pending *sources* (the in-progress one is not relocatable and is
    /// not counted — it cannot be stolen).
    fn bag_size(&self) -> usize {
        self.bag.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::bc::{sequential_bc, RmatParams};
    use crate::glb::task_queue::VecSumReducer;
    use crate::glb::{GlbConfig, GlbParams};
    use crate::place::run_threads;

    fn close(a: &[f64], b: &[f64]) {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-6 * (1.0 + y.abs()), "bc[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn single_queue_matches_sequential_for_any_budget() {
        let g = Arc::new(Graph::rmat(RmatParams { scale: 6, ..Default::default() }));
        let (want, want_edges) = sequential_bc(&g);
        for budget in [1usize, 7, 64, 100_000] {
            let mut q = InterruptibleBcQueue::new(g.clone());
            q.assign(0, g.n() as u32);
            let mut guard = 0;
            while q.process(budget).has_more {
                guard += 1;
                assert!(guard < 5_000_000, "diverged at budget {budget}");
            }
            close(&q.result(), &want);
            assert_eq!(q.edges, want_edges, "budget={budget}");
        }
    }

    #[test]
    fn suspension_preserves_partial_state() {
        // Tiny budget forces suspension mid-BFS on every chunk; the final
        // map must be identical to the uninterrupted run.
        let g = Arc::new(Graph::triangular(24));
        let (want, _) = sequential_bc(&g);
        let mut q = InterruptibleBcQueue::new(g.clone());
        q.assign(0, 24);
        while q.process(3).has_more {}
        close(&q.result(), &want);
    }

    #[test]
    fn glb_run_with_interruptible_queue() {
        let g = Arc::new(Graph::rmat(RmatParams { scale: 7, ..Default::default() }));
        let (want, _) = sequential_bc(&g);
        let n = g.n() as u32;
        let gg = g.clone();
        let cfg = GlbConfig::new(4, GlbParams::default().with_n(500).with_l(2));
        let out = run_threads(
            &cfg,
            move |i, np| {
                let mut q = InterruptibleBcQueue::new(gg.clone());
                let per = n / np as u32;
                let lo = i as u32 * per;
                let hi = if i == np - 1 { n } else { lo + per };
                q.assign(lo, hi);
                q
            },
            |_| {},
            &VecSumReducer,
        );
        close(&out.result, &want);
    }

    #[test]
    fn in_progress_source_is_not_stealable() {
        let g = Arc::new(Graph::rmat(RmatParams { scale: 6, ..Default::default() }));
        let mut q = InterruptibleBcQueue::new(g.clone());
        q.assign(0, 2);
        // Start the first source with a tiny budget so it suspends.
        q.process(1);
        assert!(q.current.is_some());
        // Bag now holds only the other source -> too small to split.
        assert_eq!(q.bag_size(), 1);
        assert!(q.split().is_none());
    }
}
