//! Sparse single-source Brandes (the reference BC engine).
//!
//! Brandes' algorithm (2001) for unweighted graphs: a BFS from the source
//! accumulating shortest-path counts `sigma`, then a reverse sweep of the
//! BFS order accumulating dependencies `delta`:
//!
//! `delta[v] = Σ_{w : (v,w) ∈ E, dist[w] = dist[v]+1} sigma[v]/sigma[w] · (1 + delta[w])`
//!
//! and `BC(v) += delta[v]` for `v ≠ s`. Predecessor lists are not stored;
//! successors are re-discovered in the reverse sweep via the distance
//! test (halves the memory, same asymptotics — the SSCA2 reference does
//! the same).

use super::graph::Graph;

/// Reusable per-worker scratch (allocation-free hot loop).
#[derive(Debug)]
pub struct BrandesScratch {
    dist: Vec<i32>,
    sigma: Vec<f64>,
    delta: Vec<f64>,
    /// BFS visit order (stack for the reverse sweep).
    order: Vec<u32>,
    /// BFS queue.
    queue: Vec<u32>,
}

impl BrandesScratch {
    pub fn new(n: usize) -> Self {
        Self {
            dist: vec![-1; n],
            sigma: vec![0.0; n],
            delta: vec![0.0; n],
            order: Vec::with_capacity(n),
            queue: Vec::with_capacity(n),
        }
    }

    fn reset(&mut self, touched: &[u32]) {
        // Only clear what the previous source touched: sources in small
        // components pay proportionally (this is the imbalance the paper
        // exploits).
        for &v in touched {
            self.dist[v as usize] = -1;
            self.sigma[v as usize] = 0.0;
            self.delta[v as usize] = 0.0;
        }
        self.order.clear();
        self.queue.clear();
    }
}

/// Run Brandes from source `s`, accumulating into `bc`. Returns the number
/// of edges traversed (the paper's BC work/throughput unit).
pub fn brandes_source(g: &Graph, s: u32, bc: &mut [f64], scratch: &mut BrandesScratch) -> u64 {
    debug_assert_eq!(bc.len(), g.n());
    let mut edges = 0u64;

    scratch.dist[s as usize] = 0;
    scratch.sigma[s as usize] = 1.0;
    scratch.queue.push(s);
    scratch.order.push(s);
    let mut head = 0;
    while head < scratch.queue.len() {
        let v = scratch.queue[head];
        head += 1;
        let dv = scratch.dist[v as usize];
        let sv = scratch.sigma[v as usize];
        for &w in g.neighbors(v) {
            edges += 1;
            let dw = &mut scratch.dist[w as usize];
            if *dw < 0 {
                *dw = dv + 1;
                scratch.queue.push(w);
                scratch.order.push(w);
            }
            if scratch.dist[w as usize] == dv + 1 {
                scratch.sigma[w as usize] += sv;
            }
        }
    }

    // Reverse sweep: order holds vertices in non-decreasing distance.
    for idx in (0..scratch.order.len()).rev() {
        let v = scratch.order[idx];
        let dv = scratch.dist[v as usize];
        let sv = scratch.sigma[v as usize];
        let mut dv_acc = 0.0;
        for &w in g.neighbors(v) {
            if scratch.dist[w as usize] == dv + 1 {
                dv_acc += sv / scratch.sigma[w as usize] * (1.0 + scratch.delta[w as usize]);
            }
        }
        scratch.delta[v as usize] += dv_acc;
        if v != s {
            bc[v as usize] += scratch.delta[v as usize];
        }
    }

    // O(|touched|) cleanup for the next source.
    let touched = std::mem::take(&mut scratch.order);
    scratch.reset(&touched);
    scratch.order = touched;
    scratch.order.clear();
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bc_all(g: &Graph) -> Vec<f64> {
        let mut bc = vec![0.0; g.n()];
        let mut sc = BrandesScratch::new(g.n());
        for s in 0..g.n() as u32 {
            brandes_source(g, s, &mut bc, &mut sc);
        }
        bc
    }

    #[test]
    fn path5_analytic() {
        // Undirected path 0-1-2-3-4. For ordered pairs (s,t), vertex v in
        // the middle of the unique path: BC(1) = |{(0,2),(0,3),(0,4)}|*2
        // = 6; BC(2) = pairs crossing the middle = (0,3),(0,4),(1,3),
        // (1,4) *2 = 8.
        let g = Graph::path(5);
        let bc = bc_all(&g);
        assert_eq!(bc, vec![0.0, 6.0, 8.0, 6.0, 0.0]);
    }

    #[test]
    fn diamond_split_paths() {
        // 0 -> 1 -> 3, 0 -> 2 -> 3 (directed): two shortest paths 0->3,
        // each middle vertex carries 1/2.
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let bc = bc_all(&g);
        assert_eq!(bc[1], 0.5);
        assert_eq!(bc[2], 0.5);
        assert_eq!(bc[0], 0.0);
        assert_eq!(bc[3], 0.0);
    }

    #[test]
    fn edges_traversed_counts_component_only() {
        let g = Graph::two_cliques(3, 5);
        let mut bc = vec![0.0; g.n()];
        let mut sc = BrandesScratch::new(g.n());
        // Source in the 3-clique touches 3*2 = 6 directed edges.
        let e_small = brandes_source(&g, 0, &mut bc, &mut sc);
        // Source in the 5-clique touches 5*4 = 20.
        let e_large = brandes_source(&g, 3, &mut bc, &mut sc);
        assert_eq!(e_small, 6);
        assert_eq!(e_large, 20);
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // Running the same source twice (fresh accumulator) must agree —
        // guards the partial-reset optimization.
        let g = Graph::rmat(super::super::graph::RmatParams {
            scale: 6,
            ..Default::default()
        });
        let mut sc = BrandesScratch::new(g.n());
        let mut bc1 = vec![0.0; g.n()];
        brandes_source(&g, 5, &mut bc1, &mut sc);
        let mut bc2 = vec![0.0; g.n()];
        brandes_source(&g, 5, &mut bc2, &mut sc);
        assert_eq!(bc1, bc2);
    }

    #[test]
    fn isolated_source_is_free() {
        let g = Graph::from_edges(3, &[(1, 2)]);
        let mut bc = vec![0.0; 3];
        let mut sc = BrandesScratch::new(3);
        let e = brandes_source(&g, 0, &mut bc, &mut sc);
        assert_eq!(e, 0);
        assert_eq!(bc, vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn triangular_imbalance() {
        // Paper §2.6.1: in the i<j DAG, early sources do far more work.
        let g = Graph::triangular(64);
        let mut bc = vec![0.0; g.n()];
        let mut sc = BrandesScratch::new(g.n());
        let e0 = brandes_source(&g, 0, &mut bc, &mut sc);
        let e_last = brandes_source(&g, 63, &mut bc, &mut sc);
        assert!(e0 > 100 * (e_last + 1), "e0={e0} e_last={e_last}");
    }
}
