//! The BC task bag (paper §2.6.2): "Each vertex interval is a task item.
//! We use a tuple (low, high) to represent a vertex interval. Each task
//! bag is an array of such tuples. To split a TaskBag, we divide each
//! tuple evenly. To merge a BC taskbag, we simply concatenate."

use crate::glb::task_bag::TaskBag;
use crate::glb::wire::{self, Reader, WireCodec, WireError};

/// A bag of half-open source-vertex intervals `[lo, hi)`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BcBag {
    intervals: Vec<(u32, u32)>,
}

impl BcBag {
    /// Serialized bytes per interval on the socket wire (`lo` + `hi`).
    pub const WIRE_BYTES_PER_INTERVAL: usize = 8;

    pub fn new() -> Self {
        Self { intervals: Vec::new() }
    }

    /// A bag from explicit intervals (codec round-trips, tests). Every
    /// interval must be non-empty.
    pub fn from_intervals(intervals: Vec<(u32, u32)>) -> Self {
        debug_assert!(intervals.iter().all(|&(lo, hi)| lo < hi), "empty interval");
        Self { intervals }
    }

    /// A bag holding one interval.
    pub fn interval(lo: u32, hi: u32) -> Self {
        assert!(lo <= hi);
        let mut b = Self::new();
        if lo < hi {
            b.intervals.push((lo, hi));
        }
        b
    }

    pub fn intervals(&self) -> &[(u32, u32)] {
        &self.intervals
    }

    /// Total vertices pending.
    pub fn vertices(&self) -> u64 {
        self.intervals.iter().map(|&(l, h)| (h - l) as u64).sum()
    }

    /// Take up to `k` source vertices off the bag (from the back — newest
    /// intervals first, matching the LIFO discipline of the other bags).
    pub fn take(&mut self, k: usize, out: &mut Vec<u32>) {
        let mut need = k;
        while need > 0 {
            match self.intervals.last_mut() {
                Some((lo, hi)) => {
                    let width = (*hi - *lo) as usize;
                    let grab = width.min(need);
                    for v in (*hi - grab as u32)..*hi {
                        out.push(v);
                    }
                    *hi -= grab as u32;
                    need -= grab;
                    if lo == hi {
                        self.intervals.pop();
                    }
                }
                None => break,
            }
        }
    }
}

impl TaskBag for BcBag {
    fn size(&self) -> usize {
        self.vertices() as usize
    }

    fn split(&mut self) -> Option<Self> {
        // Paper: divide each tuple evenly. Singleton intervals stay local;
        // additionally, when everything is singletons but there are at
        // least two of them, give away every other interval (keeps the
        // bag splittable down to single vertices, which §2.6 needs when
        // responsiveness matters).
        let mut loot = Vec::new();
        for iv in self.intervals.iter_mut() {
            let (lo, hi) = *iv;
            if hi - lo >= 2 {
                let mid = lo + (hi - lo) / 2;
                loot.push((mid, hi));
                iv.1 = mid;
            }
        }
        if loot.is_empty() && self.intervals.len() >= 2 {
            let give = self.intervals.len() / 2;
            loot = self.intervals.drain(..give).collect();
        }
        if loot.is_empty() {
            return None;
        }
        Some(Self { intervals: loot })
    }

    fn merge(&mut self, other: Self) {
        let mut incoming = other.intervals;
        std::mem::swap(&mut self.intervals, &mut incoming);
        self.intervals.extend(incoming);
    }
}

/// Wire form: `count:u32` then `lo`/`hi` per interval
/// ([`BcBag::WIRE_BYTES_PER_INTERVAL`] bytes each). Empty intervals are
/// rejected on decode — the bag invariant keeps them popped.
impl WireCodec for BcBag {
    fn encode(&self, out: &mut Vec<u8>) {
        wire::put_u32(out, self.intervals.len() as u32);
        for &(lo, hi) in &self.intervals {
            wire::put_u32(out, lo);
            wire::put_u32(out, hi);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let count = r.u32()? as usize;
        let mut intervals = Vec::new();
        for _ in 0..count {
            let lo = r.u32()?;
            let hi = r.u32()?;
            if lo >= hi {
                return Err(WireError::Invalid("empty BC vertex interval"));
            }
            intervals.push((lo, hi));
        }
        Ok(Self { intervals })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_halves_every_interval() {
        let mut b = BcBag { intervals: vec![(0, 10), (20, 24)] };
        let loot = b.split().unwrap();
        assert_eq!(b.intervals(), &[(0, 5), (20, 22)]);
        assert_eq!(loot.intervals(), &[(5, 10), (22, 24)]);
        assert_eq!(b.vertices() + loot.vertices(), 14);
    }

    #[test]
    fn split_singletons_partitions_list() {
        let mut b = BcBag { intervals: vec![(1, 2), (5, 6), (9, 10)] };
        let loot = b.split().unwrap();
        assert_eq!(loot.vertices() + b.vertices(), 3);
        assert!(loot.vertices() >= 1);
    }

    #[test]
    fn split_refuses_single_vertex() {
        let mut b = BcBag::interval(3, 4);
        assert!(b.split().is_none());
        let mut empty = BcBag::new();
        assert!(empty.split().is_none());
    }

    #[test]
    fn take_pulls_from_back() {
        let mut b = BcBag::interval(0, 10);
        let mut out = Vec::new();
        b.take(3, &mut out);
        assert_eq!(out, vec![7, 8, 9]);
        assert_eq!(b.vertices(), 7);
        out.clear();
        b.take(100, &mut out);
        assert_eq!(out.len(), 7);
        assert!(b.vertices() == 0);
    }

    #[test]
    fn take_spans_intervals() {
        let mut b = BcBag { intervals: vec![(0, 2), (10, 12)] };
        let mut out = Vec::new();
        b.take(3, &mut out);
        assert_eq!(out, vec![10, 11, 1]);
        assert_eq!(b.intervals(), &[(0, 1)]);
    }

    #[test]
    fn merge_concatenates() {
        let mut a = BcBag::interval(0, 4);
        a.merge(BcBag::interval(8, 12));
        assert_eq!(a.vertices(), 8);
    }

    #[test]
    fn every_vertex_appears_exactly_once_under_splits() {
        let mut b = BcBag::interval(0, 100);
        let mut parts = vec![];
        // Split recursively into many bags.
        for _ in 0..5 {
            if let Some(l) = b.split() {
                parts.push(l);
            }
        }
        let mut seen = vec![false; 100];
        let mut mark = |bag: &BcBag| {
            for &(lo, hi) in bag.intervals() {
                for v in lo..hi {
                    assert!(!seen[v as usize], "vertex {v} duplicated");
                    seen[v as usize] = true;
                }
            }
        };
        mark(&b);
        for p in &parts {
            mark(p);
        }
        assert!(seen.iter().all(|&s| s), "no vertex lost");
    }
}
