//! The UTS task queue (paper §2.5.2): `process(n)` counts at most `n`
//! tree nodes; `reduce()` is a sum over per-place counts.

use super::bag::UtsBag;
use super::tree::{UtsParams, UtsTree};
use crate::glb::task_bag::TaskBag;
use crate::glb::task_queue::{ProcessOutcome, TaskQueue};

/// Per-place UTS state: the frontier bag + the local node count.
pub struct UtsQueue {
    tree: UtsTree,
    bag: UtsBag,
    count: u64,
}

impl UtsQueue {
    /// An empty queue (work arrives by stealing).
    pub fn new(params: UtsParams) -> Self {
        Self { tree: UtsTree::new(params), bag: UtsBag::new(), count: 0 }
    }

    /// Root initialization (place 0): seed the root node. The root itself
    /// is counted here (children are counted as they are expanded).
    pub fn init_root(&mut self) {
        self.bag = UtsBag::with_root(&self.tree);
        self.count = 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn bag(&self) -> &UtsBag {
        &self.bag
    }
}

impl TaskQueue for UtsQueue {
    type Bag = UtsBag;
    type Result = u64;

    fn process(&mut self, n: usize) -> ProcessOutcome {
        let (c, more) = self.bag.expand_some(&self.tree, n);
        self.count += c;
        ProcessOutcome::new(more, c)
    }

    fn split(&mut self) -> Option<UtsBag> {
        self.bag.split()
    }

    fn merge(&mut self, bag: UtsBag) {
        TaskBag::merge(&mut self.bag, bag);
    }

    fn result(&self) -> u64 {
        self.count
    }

    fn bag_size(&self) -> usize {
        self.bag.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::sequential_count;
    use crate::glb::task_queue::SumReducer;
    use crate::glb::{GlbConfig, GlbParams};
    use crate::place::run_threads;
    use crate::sim::{run_sim, CostModel, BGQ};

    fn params(d: u32) -> UtsParams {
        UtsParams { b0: 4.0, seed: 19, max_depth: d }
    }

    #[test]
    fn glb_threads_match_sequential() {
        let up = params(6);
        let expect = sequential_count(&up);
        for &p in &[1usize, 2, 4, 8] {
            let cfg = GlbConfig::new(p, GlbParams::default().with_n(64).with_l(2));
            let out =
                run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
            assert_eq!(out.result, expect, "p={p}");
        }
    }

    #[test]
    fn glb_sim_matches_sequential() {
        let up = params(6);
        let expect = sequential_count(&up);
        for &p in &[1usize, 4, 32] {
            let cfg = GlbConfig::new(p, GlbParams::default().with_n(64).with_l(2));
            let (out, _) = run_sim(
                &cfg,
                &BGQ,
                CostModel::new(180.0, 60, 28),
                |_, _| UtsQueue::new(up),
                |q| q.init_root(),
                &SumReducer,
            );
            assert_eq!(out.result, expect, "p={p}");
        }
    }

    #[test]
    fn different_granularities_same_count() {
        let up = params(5);
        let expect = sequential_count(&up);
        for &n in &[1usize, 7, 511, 10_000] {
            let cfg = GlbConfig::new(3, GlbParams::default().with_n(n).with_l(2));
            let out =
                run_threads(&cfg, |_, _| UtsQueue::new(up), |q| q.init_root(), &SumReducer);
            assert_eq!(out.result, expect, "n={n}");
        }
    }
}
