//! Unbalanced Tree Search (paper §2.5).
//!
//! A synthetic tree is generated on the fly from a splittable random
//! number generator; the benchmark metric is nodes counted per second.
//! Per the paper we implement the *fixed geometric law*: every node at
//! depth < `d` has a child count drawn from a geometric distribution with
//! mean `b0`; nodes at depth ≥ `d` are leaves. The default parameters are
//! the paper's (`b0 = 4`, `r = 19`), with `d` varied by the harness.

pub mod bag;
pub mod queue;
pub mod sha1rand;
pub mod tree;

pub use bag::{UtsBag, UtsNode};
pub use queue::UtsQueue;
pub use tree::{UtsParams, UtsTree};

/// Sequentially count the whole tree (validation + single-place baseline).
pub fn sequential_count(params: &UtsParams) -> u64 {
    let tree = UtsTree::new(*params);
    let mut bag = UtsBag::with_root(&tree);
    let mut count = 1; // the root itself
    loop {
        let (c, more) = bag.expand_some(&tree, 1 << 16);
        count += c;
        if !more {
            return count;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_counts_are_stable() {
        // Regression anchors: fixed (b0, r, d) triples must always produce
        // the same tree (the descriptor chain is SHA-1-deterministic).
        let c1 = sequential_count(&UtsParams { b0: 4.0, seed: 19, max_depth: 4 });
        let c2 = sequential_count(&UtsParams { b0: 4.0, seed: 19, max_depth: 4 });
        assert_eq!(c1, c2);
        assert!(c1 > 50, "a b0=4 depth-4 tree has hundreds of nodes, got {c1}");
    }

    #[test]
    fn deeper_trees_are_larger() {
        let p = |d| UtsParams { b0: 4.0, seed: 19, max_depth: d };
        let c4 = sequential_count(&p(4));
        let c6 = sequential_count(&p(6));
        assert!(c6 > 4 * c4, "expected roughly b0^2 growth: {c4} -> {c6}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = sequential_count(&UtsParams { b0: 4.0, seed: 19, max_depth: 5 });
        let b = sequential_count(&UtsParams { b0: 4.0, seed: 42, max_depth: 5 });
        assert_ne!(a, b);
    }
}
