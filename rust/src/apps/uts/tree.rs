//! UTS tree parameters and node expansion.

use super::sha1rand::{child_descriptor, root_descriptor, to_prob, Descriptor};

/// Tree-shape parameters (paper §2.5.1: fixed geometric law, `b0 = 4`,
/// seed `r = 19`, depth `d` varying 13–20 by core count; our harness uses
/// smaller `d` scaled to the testbed, see EXPERIMENTS.md).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtsParams {
    /// Expected branching factor of the geometric law.
    pub b0: f64,
    /// Tree seed (`r`).
    pub seed: u32,
    /// Depth cut-off (`d`): nodes at this depth are leaves.
    pub max_depth: u32,
}

impl Default for UtsParams {
    fn default() -> Self {
        Self { b0: 4.0, seed: 19, max_depth: 10 }
    }
}

/// Expansion rules for one tree (cheap, `Copy`-able capture of params).
#[derive(Debug, Clone, Copy)]
pub struct UtsTree {
    params: UtsParams,
    /// Precomputed `ln(1 - p)` with `p = 1/(1+b0)` — constant per tree,
    /// hoisted out of the per-node geometric draw (§Perf: one `ln()`
    /// fewer per node; bit-identical result since the division by it is
    /// unchanged).
    log_q: f64,
}

impl UtsTree {
    pub fn new(params: UtsParams) -> Self {
        let p = 1.0 / (1.0 + params.b0);
        Self { params, log_q: (1.0 - p).ln() }
    }

    pub fn params(&self) -> &UtsParams {
        &self.params
    }

    /// Root descriptor + child count.
    pub fn root(&self) -> (Descriptor, u32) {
        let d = root_descriptor(self.params.seed);
        let c = self.num_children(&d, 0);
        (d, c)
    }

    /// Child count for a node at `depth` with descriptor `d`.
    #[inline]
    pub fn num_children(&self, d: &Descriptor, depth: u32) -> u32 {
        if depth >= self.params.max_depth {
            return 0;
        }
        let u = to_prob(d);
        if u <= 0.0 {
            return 0;
        }
        ((1.0 - u).ln() / self.log_q).floor() as u32
    }

    /// Descriptor of child `i`.
    #[inline]
    pub fn child(&self, d: &Descriptor, i: u32) -> Descriptor {
        child_descriptor(d, i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_cutoff_makes_leaves() {
        let t = UtsTree::new(UtsParams { b0: 4.0, seed: 19, max_depth: 3 });
        let (root, _) = t.root();
        assert_eq!(t.num_children(&root, 3), 0);
        assert_eq!(t.num_children(&root, 99), 0);
    }

    #[test]
    fn fast_child_count_matches_reference_formula() {
        // The precomputed-log fast path must agree with the reference
        // geometric draw for every descriptor (same operands, same ops).
        use super::super::sha1rand::geometric_children;
        let t = UtsTree::new(UtsParams { b0: 4.0, seed: 19, max_depth: 100 });
        let mut d = t.root().0;
        for i in 0..10_000u32 {
            d = t.child(&d, i % 6);
            assert_eq!(t.num_children(&d, 1), geometric_children(to_prob(&d), 4.0));
        }
    }

    #[test]
    fn all_depths_use_same_law() {
        // Paper: "all nodes are treated equally, irrespective of the
        // current depth" — the child count depends only on the descriptor.
        let t = UtsTree::new(UtsParams { b0: 4.0, seed: 19, max_depth: 100 });
        let (root, _) = t.root();
        assert_eq!(t.num_children(&root, 0), t.num_children(&root, 50));
    }
}
