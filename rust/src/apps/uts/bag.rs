//! The UTS task bag (paper §2.5.2).
//!
//! "The internal representation of a UTS tree node is a triple
//! (descriptor, low, high) ... The representation of a UTS tree is thus
//! an array of UTS tree nodes." `low..high` is the range of this node's
//! still-unexplored children.
//!
//! * **split**: "we evenly split each UTS node n(d,l,h) to two nodes
//!   n1(d,l,h1) and n2(d,h2,h) ... If none of the UTS tree nodes has more
//!   than one child node, then we do not split" — stealing child *ranges*
//!   rather than single nodes is what lets a thief receive a large chunk
//!   of frontier with O(1) bytes per entry.
//! * **merge**: "simply concatenate the incoming TaskBag's UTS node array
//!   to the local one".

use super::sha1rand::Descriptor;
use super::tree::UtsTree;
use crate::glb::task_bag::TaskBag;
use crate::glb::wire::{self, Reader, WireCodec, WireError};

/// One frontier entry: a node with unexplored children `lo..hi`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UtsNode {
    pub desc: Descriptor,
    pub depth: u32,
    pub lo: u32,
    pub hi: u32,
}

impl UtsNode {
    /// Unexplored children.
    #[inline]
    pub fn width(&self) -> u32 {
        self.hi - self.lo
    }
}

/// The UTS frontier: an array of nodes with pending child ranges.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct UtsBag {
    nodes: Vec<UtsNode>,
}

impl UtsBag {
    /// Serialized bytes per frontier entry on the socket wire
    /// (descriptor + depth + child range).
    pub const WIRE_BYTES_PER_NODE: usize = 20 + 4 + 4 + 4;

    pub fn new() -> Self {
        Self { nodes: Vec::new() }
    }

    /// A bag from explicit frontier entries (codec round-trips, tests).
    /// Every entry must have a non-empty child range.
    pub fn from_nodes(nodes: Vec<UtsNode>) -> Self {
        debug_assert!(nodes.iter().all(|n| n.lo < n.hi), "empty child range");
        Self { nodes }
    }

    /// A bag holding the tree root's children range.
    pub fn with_root(tree: &UtsTree) -> Self {
        let (desc, children) = tree.root();
        let mut bag = Self::new();
        if children > 0 {
            bag.nodes.push(UtsNode { desc, depth: 0, lo: 0, hi: children });
        }
        bag
    }

    pub fn nodes(&self) -> &[UtsNode] {
        &self.nodes
    }

    /// Total unexplored children across all entries (a better work
    /// estimate than the entry count).
    pub fn pending_children(&self) -> u64 {
        self.nodes.iter().map(|n| n.width() as u64).sum()
    }

    /// Expand up to `limit` tree nodes (depth-first: always the last
    /// entry), returning `(nodes_counted, has_more)`. Each expansion
    /// counts one child node and pushes it if it has children of its own.
    pub fn expand_some(&mut self, tree: &UtsTree, limit: usize) -> (u64, bool) {
        let mut counted = 0u64;
        while (counted as usize) < limit {
            let Some(top) = self.nodes.last_mut() else { break };
            debug_assert!(top.lo < top.hi);
            let i = top.lo;
            let (desc, depth) = (top.desc, top.depth);
            top.lo += 1;
            let exhausted = top.lo == top.hi;
            if exhausted {
                self.nodes.pop();
            }
            let child = tree.child(&desc, i);
            let c = tree.num_children(&child, depth + 1);
            counted += 1;
            if c > 0 {
                self.nodes.push(UtsNode { desc: child, depth: depth + 1, lo: 0, hi: c });
            }
        }
        (counted, !self.nodes.is_empty())
    }
}

impl TaskBag for UtsBag {
    /// GLB sizes bags by task items; for UTS the natural unit is the
    /// number of unexplored children (what a steal can take half of).
    fn size(&self) -> usize {
        self.pending_children() as usize
    }

    fn split(&mut self) -> Option<Self> {
        // Paper: halve every entry's child range; entries with a single
        // child are not split ("it is cheaper to count the node locally
        // than move it to a remote place").
        let mut loot = Vec::new();
        for n in self.nodes.iter_mut() {
            if n.width() >= 2 {
                let mid = n.lo + n.width() / 2;
                loot.push(UtsNode { desc: n.desc, depth: n.depth, lo: mid, hi: n.hi });
                n.hi = mid;
            }
        }
        if loot.is_empty() {
            return None;
        }
        Some(Self { nodes: loot })
    }

    fn merge(&mut self, other: Self) {
        // Concatenate *under* the local frontier so depth-first descent
        // continues on local work first.
        let mut incoming = other.nodes;
        std::mem::swap(&mut self.nodes, &mut incoming);
        self.nodes.extend(incoming);
    }
}

/// Wire form: `count:u32` then per entry the 20-byte descriptor, `depth`,
/// `lo`, `hi` — [`UtsBag::WIRE_BYTES_PER_NODE`] bytes each. Child ranges
/// are validated on decode (an empty range would corrupt expansion).
impl WireCodec for UtsBag {
    fn encode(&self, out: &mut Vec<u8>) {
        wire::put_u32(out, self.nodes.len() as u32);
        for n in &self.nodes {
            out.extend_from_slice(&n.desc);
            wire::put_u32(out, n.depth);
            wire::put_u32(out, n.lo);
            wire::put_u32(out, n.hi);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let count = r.u32()? as usize;
        let mut nodes = Vec::new();
        for _ in 0..count {
            let desc: Descriptor = r.bytes(20)?.try_into().unwrap();
            let depth = r.u32()?;
            let lo = r.u32()?;
            let hi = r.u32()?;
            if lo >= hi {
                return Err(WireError::Invalid("empty UTS child range"));
            }
            nodes.push(UtsNode { desc, depth, lo, hi });
        }
        Ok(Self { nodes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::tree::UtsParams;

    fn tree() -> UtsTree {
        UtsTree::new(UtsParams { b0: 4.0, seed: 19, max_depth: 5 })
    }

    #[test]
    fn split_preserves_total_children() {
        let t = tree();
        let mut bag = UtsBag::with_root(&t);
        bag.expand_some(&t, 50);
        let before = bag.pending_children();
        assert!(before > 2);
        let loot = bag.split().expect("wide bag splits");
        assert_eq!(bag.pending_children() + loot.pending_children(), before);
        // Each loot entry pairs with the retained entry it was split from
        // (same descriptor, adjacent non-overlapping ranges). Entries with
        // a single pending child stay local and have no loot counterpart.
        let mut loot_iter = loot.nodes().iter().peekable();
        for a in bag.nodes() {
            if let Some(b) = loot_iter.peek() {
                if a.desc == b.desc && a.depth == b.depth {
                    assert_eq!(a.hi, b.lo, "ranges must partition");
                    loot_iter.next();
                }
            }
        }
        assert!(loot_iter.next().is_none(), "every loot entry has a local origin");
    }

    #[test]
    fn split_refuses_singletons() {
        let t = tree();
        let mut bag = UtsBag::new();
        bag.nodes.push(UtsNode { desc: t.root().0, depth: 0, lo: 0, hi: 1 });
        assert!(bag.split().is_none(), "all-singleton bag must not split");
    }

    #[test]
    fn split_then_merge_counts_the_same_tree() {
        let t = tree();
        // Expand fully in one bag.
        let mut whole = UtsBag::with_root(&t);
        let mut count_whole = 1u64;
        loop {
            let (c, more) = whole.expand_some(&t, 1 << 20);
            count_whole += c;
            if !more {
                break;
            }
        }
        // Expand with a split/merge round-trip in the middle.
        let mut a = UtsBag::with_root(&t);
        let mut count_split = 1u64;
        let (c, _) = a.expand_some(&t, 30);
        count_split += c;
        let mut b = a.split().expect("should split after 30 expansions");
        loop {
            let (c, more) = b.expand_some(&t, 1000);
            count_split += c;
            if !more {
                break;
            }
        }
        loop {
            let (c, more) = a.expand_some(&t, 1000);
            count_split += c;
            if !more {
                break;
            }
        }
        assert_eq!(count_whole, count_split, "partitioned traversal must count the same tree");
    }

    #[test]
    fn merge_keeps_local_on_top() {
        let t = tree();
        let mut a = UtsBag::with_root(&t);
        a.expand_some(&t, 3);
        let top_before = *a.nodes().last().unwrap();
        let incoming = UtsBag::with_root(&t);
        TaskBag::merge(&mut a, incoming);
        assert_eq!(*a.nodes().last().unwrap(), top_before);
    }

    #[test]
    fn expansion_respects_limit() {
        let t = tree();
        let mut bag = UtsBag::with_root(&t);
        let (c, _) = bag.expand_some(&t, 7);
        assert!(c <= 7);
    }

    #[test]
    fn empty_bag_expands_to_nothing() {
        let t = tree();
        let mut bag = UtsBag::new();
        let (c, more) = bag.expand_some(&t, 10);
        assert_eq!(c, 0);
        assert!(!more);
    }
}
