//! The UTS splittable random number generator (BRG SHA-1 variant).
//!
//! Per the UTS specification (Prins et al.), a tree node is identified by
//! a 20-byte SHA-1 digest; child `i` of a node with descriptor `D` has
//! descriptor `SHA1(D || be32(i))`, and the root of a tree with seed `r`
//! has descriptor `SHA1(zeros(16) || be32(r))`. This makes the tree shape
//! a pure function of `(b0, r, d)` — any traversal order, any partition
//! across places, counts the same tree. A node's random value is the
//! first 31 bits of its descriptor.

/// A UTS node descriptor (SHA-1 state).
pub type Descriptor = [u8; 20];

/// SHA-1 initial state (FIPS 180-4).
const IV: [u32; 5] = [0x6745_2301, 0xEFCD_AB89, 0x98BA_DCFE, 0x1032_5476, 0xC3D2_E1F0];

/// SHA-1 of a message of `LEN <= 55` bytes via a single hand-padded
/// block fed straight to the compression function (`sha1::compress`,
/// SHA-NI-dispatched). The node-expansion hot path hashes exactly 24
/// bytes per child; skipping the streaming `Digest` machinery (init /
/// buffer / finalize) is the §Perf optimization that took expansion
/// from 71 ns to ~30 ns per node — bit-identical to `Sha1::digest`
/// (property-checked below).
#[inline]
fn sha1_short<const LEN: usize>(msg: &[u8; LEN]) -> Descriptor {
    const { assert!(LEN <= 55, "single-block padding requires <= 55 bytes") };
    let mut block = [0u8; 64];
    block[..LEN].copy_from_slice(msg);
    block[LEN] = 0x80;
    block[56..].copy_from_slice(&((LEN as u64) * 8).to_be_bytes());
    let mut state = IV;
    sha1::compress(&mut state, &[block.into()]);
    let mut out = [0u8; 20];
    for (i, w) in state.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
    }
    out
}

/// Root descriptor for tree seed `r` (UTS: the seed is hashed into the
/// initial state).
pub fn root_descriptor(r: u32) -> Descriptor {
    let mut msg = [0u8; 20];
    msg[16..].copy_from_slice(&r.to_be_bytes());
    sha1_short(&msg)
}

/// Descriptor of child `i` of node `d`.
#[inline]
pub fn child_descriptor(d: &Descriptor, i: u32) -> Descriptor {
    let mut msg = [0u8; 24];
    msg[..20].copy_from_slice(d);
    msg[20..].copy_from_slice(&i.to_be_bytes());
    sha1_short(&msg)
}

/// The node's uniform variate in `[0, 1)`: the descriptor's first 31 bits
/// (UTS `rng_toProb(rng_rand(state))`).
#[inline]
pub fn to_prob(d: &Descriptor) -> f64 {
    let v = u32::from_be_bytes([d[0], d[1], d[2], d[3]]) & 0x7FFF_FFFF;
    v as f64 / (1u64 << 31) as f64
}

/// Geometric child count with mean `b0` (UTS fixed geometric law):
/// `floor(log(1 - u) / log(1 - p))` with `p = 1 / (1 + b0)`.
#[inline]
pub fn geometric_children(u: f64, b0: f64) -> u32 {
    debug_assert!((0.0..1.0).contains(&u));
    let p = 1.0 / (1.0 + b0);
    if u <= 0.0 {
        return 0;
    }
    ((1.0 - u).ln() / (1.0 - p).ln()).floor() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptors_are_deterministic() {
        assert_eq!(root_descriptor(19), root_descriptor(19));
        assert_ne!(root_descriptor(19), root_descriptor(42));
        let r = root_descriptor(19);
        assert_eq!(child_descriptor(&r, 0), child_descriptor(&r, 0));
        assert_ne!(child_descriptor(&r, 0), child_descriptor(&r, 1));
    }

    #[test]
    fn fast_path_matches_streaming_sha1() {
        use sha1::{Digest, Sha1};
        // The hand-padded single-block path must be bit-identical to the
        // streaming Digest API for both message lengths we use.
        for r in [0u32, 1, 19, 42, u32::MAX] {
            let mut msg = [0u8; 20];
            msg[16..].copy_from_slice(&r.to_be_bytes());
            let want: [u8; 20] = Sha1::digest(msg).into();
            assert_eq!(root_descriptor(r), want, "root r={r}");
        }
        let mut d = root_descriptor(19);
        for i in 0..100u32 {
            let mut msg = [0u8; 24];
            msg[..20].copy_from_slice(&d);
            msg[20..].copy_from_slice(&i.to_be_bytes());
            let want: [u8; 20] = Sha1::digest(msg).into();
            d = child_descriptor(&d, i);
            assert_eq!(d, want, "child {i}");
        }
    }

    #[test]
    fn sha1_known_vector() {
        // SHA1 of 20 zero bytes (16 zeros + be32(0)) — fixed reference
        // value, guards against accidental hasher swaps.
        let d = root_descriptor(0);
        let hex: String = d.iter().map(|b| format!("{b:02x}")).collect();
        assert_eq!(hex, "6768033e216468247bd031a0a2d9876d79818f8f");
    }

    #[test]
    fn prob_in_unit_interval() {
        let mut d = root_descriptor(7);
        for i in 0..1000 {
            d = child_descriptor(&d, i % 4);
            let u = to_prob(&d);
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn geometric_mean_is_b0() {
        // Empirical mean of the child-count law over many descriptors
        // should approach b0.
        let b0 = 4.0;
        let mut d = root_descriptor(19);
        let n = 20_000;
        let mut total = 0u64;
        for i in 0..n {
            d = child_descriptor(&d, (i % 7) as u32);
            total += geometric_children(to_prob(&d), b0) as u64;
        }
        let mean = total as f64 / n as f64;
        assert!((mean - b0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn geometric_edge_cases() {
        assert_eq!(geometric_children(0.0, 4.0), 0);
        // u close to 1 gives the long tail.
        assert!(geometric_children(0.999999, 4.0) > 20);
    }

    #[test]
    fn geometric_has_long_tail() {
        // The paper: "since the geometric distribution has a long tail,
        // some nodes will have significantly more than b0 children".
        let b0 = 4.0;
        let mut d = root_descriptor(19);
        let mut max = 0;
        for i in 0..50_000u32 {
            d = child_descriptor(&d, i % 5);
            max = max.max(geometric_children(to_prob(&d), b0));
        }
        assert!(max >= 3 * b0 as u32, "expected tail >= 12 children, got {max}");
    }
}
