//! N-Queens as a GLB application (paper §2.1: "All state space search
//! algorithms from AI fall in the GLB problem domain ... An example of
//! such an application is the famous N-Queens problem").
//!
//! A task is a partial placement encoded as three bitmasks (columns, both
//! diagonal directions) plus the row index — O(1) state per task, ideal
//! for bag shipping. Processing a task either counts a solution (all rows
//! placed) or pushes one child task per legal placement in the next row.

use crate::glb::task_bag::{ArrayListTaskBag, TaskBag};
use crate::glb::task_queue::{ProcessOutcome, TaskQueue};
use crate::glb::wire::{self, Reader, WireCodec, WireError};

/// A partial placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Occupied columns.
    cols: u32,
    /// Occupied "/" diagonals (shifted left per row).
    diag1: u32,
    /// Occupied "\" diagonals (shifted right per row).
    diag2: u32,
    /// Rows already placed.
    row: u8,
}

impl Placement {
    pub fn root() -> Self {
        Self { cols: 0, diag1: 0, diag2: 0, row: 0 }
    }
}

/// Wire form: the three bitmasks then the row — 13 bytes per task. With
/// this, `ArrayListTaskBag<Placement>` picks up the blanket counted-array
/// codec and the app runs under `--transport tcp` like uts/bc/fib.
impl WireCodec for Placement {
    fn encode(&self, out: &mut Vec<u8>) {
        wire::put_u32(out, self.cols);
        wire::put_u32(out, self.diag1);
        wire::put_u32(out, self.diag2);
        wire::put_u8(out, self.row);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(Self { cols: r.u32()?, diag1: r.u32()?, diag2: r.u32()?, row: r.u8()? })
    }
}

/// N-Queens task queue; result = number of solutions.
pub struct NQueensQueue {
    n: u8,
    bag: ArrayListTaskBag<Placement>,
    solutions: u64,
}

impl NQueensQueue {
    pub fn new(n: u8) -> Self {
        assert!((1..=16).contains(&n), "board size 1..=16");
        Self { n, bag: ArrayListTaskBag::new(), solutions: 0 }
    }

    /// Root initialization: the empty board.
    pub fn init_root(&mut self) {
        self.bag.push(Placement::root());
    }

    pub fn solutions(&self) -> u64 {
        self.solutions
    }
}

impl TaskQueue for NQueensQueue {
    type Bag = ArrayListTaskBag<Placement>;
    type Result = u64;

    fn process(&mut self, n: usize) -> ProcessOutcome {
        let full = (1u32 << self.n) - 1;
        let mut done = 0u64;
        while (done as usize) < n {
            let Some(p) = self.bag.pop() else { break };
            done += 1;
            if p.row == self.n {
                self.solutions += 1;
                continue;
            }
            let mut free = full & !(p.cols | p.diag1 | p.diag2);
            while free != 0 {
                let bit = free & free.wrapping_neg();
                free ^= bit;
                self.bag.push(Placement {
                    cols: p.cols | bit,
                    diag1: (p.diag1 | bit) << 1,
                    diag2: (p.diag2 | bit) >> 1,
                    row: p.row + 1,
                });
            }
        }
        ProcessOutcome::new(self.bag.size() > 0, done)
    }

    fn split(&mut self) -> Option<Self::Bag> {
        self.bag.split()
    }

    fn merge(&mut self, bag: Self::Bag) {
        TaskBag::merge(&mut self.bag, bag);
    }

    fn result(&self) -> u64 {
        self.solutions
    }

    fn bag_size(&self) -> usize {
        self.bag.size()
    }
}

/// Known solution counts for n = 0..=12.
pub const KNOWN: [u64; 13] = [1, 1, 0, 0, 2, 10, 4, 40, 92, 352, 724, 2680, 14200];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glb::task_queue::SumReducer;
    use crate::glb::{GlbConfig, GlbParams};
    use crate::place::run_threads;
    use crate::sim::{run_sim, CostModel, K};

    fn solve(p: usize, n: u8) -> u64 {
        let cfg = GlbConfig::new(p, GlbParams::default().with_n(64).with_l(2));
        run_threads(&cfg, move |_, _| NQueensQueue::new(n), |q| q.init_root(), &SumReducer)
            .result
    }

    #[test]
    fn known_counts_sequential() {
        for n in 4..=9u8 {
            assert_eq!(solve(1, n), KNOWN[n as usize], "n={n}");
        }
    }

    #[test]
    fn known_counts_parallel() {
        assert_eq!(solve(4, 8), 92);
        assert_eq!(solve(8, 9), 352);
    }

    #[test]
    fn placement_bag_round_trips_on_the_wire() {
        // Drive a real queue a few steps so the bag holds nontrivial
        // masks, then check encode∘decode is the identity.
        let mut q = NQueensQueue::new(8);
        q.init_root();
        q.process(5);
        let bag = q.split().expect("expanded bag splits");
        assert!(bag.size() > 0);
        let mut buf = Vec::new();
        bag.encode(&mut buf);
        let (back, used) = <ArrayListTaskBag<Placement>>::decode_slice(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back.items(), bag.items());
    }

    #[test]
    fn truncated_placement_bag_is_an_error() {
        let mut q = NQueensQueue::new(8);
        q.init_root();
        q.process(3);
        let bag = q.split().expect("expanded bag splits");
        let mut buf = Vec::new();
        bag.encode(&mut buf);
        // Every proper prefix must fail cleanly, never panic: either the
        // count is cut short or some placement is.
        for cut in 0..buf.len() {
            let mut r = Reader::new(&buf[..cut]);
            let err = <ArrayListTaskBag<Placement>>::decode(&mut r)
                .expect_err("truncated bag must not decode");
            assert_eq!(err, WireError::Truncated, "cut at {cut}");
        }
    }

    #[test]
    fn sim_matches_known() {
        let cfg = GlbConfig::new(32, GlbParams::default().with_n(32).with_l(2));
        let (out, _) = run_sim(
            &cfg,
            &K,
            CostModel::new(25.0, 30, 16),
            |_, _| NQueensQueue::new(9),
            |q| q.init_root(),
            &SumReducer,
        );
        assert_eq!(out.result, 352);
    }
}
