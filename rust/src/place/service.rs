//! The resident fleet service: GLB-as-a-service over warm ranks.
//!
//! One-shot socket runs ([`crate::place::run_sockets`]) pay the full
//! fleet bootstrap — process launch, handshake, mesh knitting — per
//! computation. This module separates the *fleet* lifecycle from the
//! *job* lifecycle: `glb serve` boots every rank once and keeps it
//! resident, and each `glb submit` ships one job (a [`JobSpec`] plus an
//! optional serialized root bag) to rank 0 over a client control link.
//!
//! Per job, the fleet runs the unmodified lifeline protocol:
//!
//! 1. rank 0 assigns the job a fresh **epoch** (monotonic from 1; 0 is
//!    reserved for one-shot runs) and forwards the submission to every
//!    spoke over the retained control links;
//! 2. every rank builds a fresh queue/worker/ledger, the fleet runs a
//!    Ready/Go barrier over the (momentarily blocking) control links,
//!    and each rank spawns a per-job reactor in resident mode
//!    ([`crate::place::socket`]'s `run_resident`) over the *same*
//!    sockets;
//! 3. every data and credit frame is stamped with the epoch, so a stray
//!    frame from a previous job is dropped and counted
//!    ([`crate::place::socket::cross_epoch_frames`]) instead of
//!    corrupting the current one — and per-job Mattern termination runs
//!    against a fresh per-epoch credit root;
//! 4. end-of-job epoch fences mark the last frame of the job on every
//!    mesh link (links are never closed), the reactors hand their
//!    sockets back, and rank 0 streams the reduced result to the client
//!    as a [`Ctrl::JobResult`] frame.
//!
//! Cross-epoch isolation is structural, not just counted: a rank's
//! job-N reactor exits only after every peer's job-N fence arrived, and
//! TCP links are FIFO, so every job-N frame is consumed within job N.
//! The epoch stamps (and the counter the serve tests assert stays zero)
//! are belt and braces.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::apps::bc::bag::BcBag;
use crate::apps::bc::graph::{Graph, RmatParams};
use crate::apps::bc::queue::BcQueue;
use crate::apps::fib::FibQueue;
use crate::apps::uts::bag::UtsBag;
use crate::apps::uts::queue::UtsQueue;
use crate::apps::uts::tree::UtsParams;
use crate::glb::message::Msg;
use crate::glb::task_bag::{ArrayListTaskBag, TaskBag};
use crate::glb::task_queue::{ProcessOutcome, Reducer, TaskQueue};
use crate::glb::termination::{CreditLedger, CreditRoot, INITIAL_RANK_ATOMS};
use crate::glb::wire::{self, BufferPool, Ctrl, FrameAssembler, Reader, WireCodec, WireError};
use crate::glb::worker::Worker;
use crate::glb::{GlbConfig, GlbParams, WorkerStats};
use crate::place::reactor::{OutQueue, Poller};
use crate::place::runtime::run_threads;
use crate::place::socket::{
    accept_handshake, connect_retry, handshake_bytes, pump, socket_place_main, ConnKind,
    FleetGate, FleetLedger, GatherWire, Mailboxes, NetCore, QueueHome, Reactor, ReactorConn,
    ReactorRole, ResidentReactor, ResultPlan, ResultSlots, RootHome, SocketRunOpts,
    SocketTransport, HS_CLIENT, HS_CTRL, HS_MESH,
};
use crate::testkit::chaos;

// ---------------------------------------------------------------------------
// Job specification
// ---------------------------------------------------------------------------

/// Which application a submitted job runs, with its app parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum JobApp {
    /// Unbalanced Tree Search (geometric law).
    Uts(UtsParams),
    /// Naive recursive Fibonacci.
    Fib { n: u64 },
    /// Betweenness centrality over an SSCA2 R-MAT graph of `2^scale`
    /// vertices. The fleet caches the generated graph per scale, so
    /// repeated submissions at one scale pay generation once.
    Bc { scale: u32 },
}

/// One submitted job: the application plus the GLB knobs of the run.
/// Travels inside [`Ctrl::Submit`] as a space-separated `key=value`
/// string (see [`JobSpec::format`] / [`JobSpec::parse`]) so the wire
/// format stays app-agnostic.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub app: JobApp,
    /// GLB parameters for the run. `workers_per_node` is always 1 in a
    /// resident fleet (one place per rank).
    pub glb: GlbParams,
}

impl JobSpec {
    pub fn uts(params: UtsParams, glb: GlbParams) -> Self {
        Self { app: JobApp::Uts(params), glb: Self::flat(glb) }
    }

    pub fn fib(n: u64, glb: GlbParams) -> Self {
        Self { app: JobApp::Fib { n }, glb: Self::flat(glb) }
    }

    pub fn bc(scale: u32, glb: GlbParams) -> Self {
        Self { app: JobApp::Bc { scale }, glb: Self::flat(glb) }
    }

    fn flat(mut glb: GlbParams) -> GlbParams {
        glb.workers_per_node = 1;
        glb
    }

    /// The wire form carried by [`Ctrl::Submit`]'s `spec` field.
    pub fn format(&self) -> String {
        let g = &self.glb;
        let app = match &self.app {
            JobApp::Uts(u) => {
                format!("app=uts depth={} b0={} seed-tree={}", u.max_depth, u.b0, u.seed)
            }
            JobApp::Fib { n } => format!("app=fib fib-n={n}"),
            JobApp::Bc { scale } => format!("app=bc scale={scale}"),
        };
        format!("{app} n={} w={} l={} z={} seed={}", g.n, g.w, g.l, g.z, g.seed)
    }

    /// Parse the wire form back. Unknown keys are rejected so a client
    /// typo (or a newer client's knob) fails loudly instead of silently
    /// running a different job than asked.
    pub fn parse(s: &str) -> Result<Self> {
        let mut kv: HashMap<&str, &str> = HashMap::new();
        for tok in s.split_whitespace() {
            let (k, v) = tok.split_once('=').ok_or_else(|| anyhow!("bad spec token {tok:?}"))?;
            if kv.insert(k, v).is_some() {
                bail!("duplicate spec key {k:?}");
            }
        }
        let mut take = |k: &str| kv.remove(k);
        let app = match take("app") {
            Some("uts") => {
                let mut u = UtsParams::default();
                if let Some(v) = take("depth") {
                    u.max_depth = v.parse().context("spec depth")?;
                }
                if let Some(v) = take("b0") {
                    u.b0 = v.parse().context("spec b0")?;
                }
                if let Some(v) = take("seed-tree") {
                    u.seed = v.parse().context("spec seed-tree")?;
                }
                JobApp::Uts(u)
            }
            Some("fib") => {
                let n = take("fib-n").map(|v| v.parse()).transpose().context("spec fib-n")?;
                JobApp::Fib { n: n.unwrap_or(24) }
            }
            Some("bc") => {
                let s = take("scale").map(|v| v.parse()).transpose().context("spec scale")?;
                JobApp::Bc { scale: s.unwrap_or(9) }
            }
            Some(a) => bail!("unknown app {a:?} in job spec"),
            None => bail!("job spec has no app=... key"),
        };
        let mut glb = GlbParams { workers_per_node: 1, ..GlbParams::default() };
        if let Some(v) = take("n") {
            glb.n = v.parse().context("spec n")?;
        }
        if let Some(v) = take("w") {
            glb.w = v.parse().context("spec w")?;
        }
        if let Some(v) = take("l") {
            glb.l = v.parse().context("spec l")?;
        }
        if let Some(v) = take("z") {
            glb.z = v.parse().context("spec z")?;
        }
        if let Some(v) = take("seed") {
            glb.seed = v.parse().context("spec seed")?;
        }
        if let Some(k) = kv.keys().next() {
            bail!("unknown job spec key {k:?}");
        }
        Ok(Self { app, glb })
    }

    /// The root bag a client ships inside [`Ctrl::Submit`]. Only fib
    /// expresses its root work as a plain bag; UTS must *not* ship one
    /// (`UtsQueue::init_root` also counts the root node, which a bag
    /// merge would miss) and BC's per-rank vertex slices are derived
    /// from the spec on every rank.
    pub fn root_bag(&self) -> Option<ServiceBag> {
        match &self.app {
            JobApp::Fib { n } => Some(ServiceBag::Fib(ArrayListTaskBag::from_vec(vec![*n]))),
            JobApp::Uts(_) | JobApp::Bc { .. } => None,
        }
    }
}

// ---------------------------------------------------------------------------
// The polymorphic queue the fleet runs every job through
// ---------------------------------------------------------------------------

/// The task bag of a resident fleet: a tagged union of every app's bag,
/// so one fleet (one mesh, one bag type on the wire) can run any
/// supported app per job. Wire form: a 1-byte app discriminant followed
/// by the app bag's own encoding.
#[derive(Debug, Clone)]
pub enum ServiceBag {
    Uts(UtsBag),
    Fib(ArrayListTaskBag<u64>),
    Bc(BcBag),
}

const BAG_UTS: u8 = 0;
const BAG_FIB: u8 = 1;
const BAG_BC: u8 = 2;

impl TaskBag for ServiceBag {
    fn size(&self) -> usize {
        match self {
            ServiceBag::Uts(b) => b.size(),
            ServiceBag::Fib(b) => b.size(),
            ServiceBag::Bc(b) => b.size(),
        }
    }

    fn split(&mut self) -> Option<Self> {
        match self {
            ServiceBag::Uts(b) => b.split().map(ServiceBag::Uts),
            ServiceBag::Fib(b) => b.split().map(ServiceBag::Fib),
            ServiceBag::Bc(b) => b.split().map(ServiceBag::Bc),
        }
    }

    fn merge(&mut self, other: Self) {
        match (self, other) {
            (ServiceBag::Uts(a), ServiceBag::Uts(b)) => a.merge(b),
            (ServiceBag::Fib(a), ServiceBag::Fib(b)) => a.merge(b),
            (ServiceBag::Bc(a), ServiceBag::Bc(b)) => a.merge(b),
            // Epoch fencing makes cross-app loot structurally impossible:
            // every rank switches apps in lockstep at the job boundary.
            _ => panic!("cross-app loot merged into a service bag"),
        }
    }
}

impl WireCodec for ServiceBag {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ServiceBag::Uts(b) => {
                wire::put_u8(out, BAG_UTS);
                b.encode(out);
            }
            ServiceBag::Fib(b) => {
                wire::put_u8(out, BAG_FIB);
                b.encode(out);
            }
            ServiceBag::Bc(b) => {
                wire::put_u8(out, BAG_BC);
                b.encode(out);
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            BAG_UTS => Ok(ServiceBag::Uts(UtsBag::decode(r)?)),
            BAG_FIB => Ok(ServiceBag::Fib(ArrayListTaskBag::decode(r)?)),
            BAG_BC => Ok(ServiceBag::Bc(BcBag::decode(r)?)),
            t => Err(WireError::BadTag(t)),
        }
    }
}

/// Per-place result of a service job, mirroring [`ServiceBag`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceResult {
    /// UTS node count / fib value.
    U64(u64),
    /// BC per-vertex centrality shares.
    VecF64(Vec<f64>),
}

const RES_U64: u8 = 0;
const RES_VEC: u8 = 1;

impl ServiceResult {
    /// A one-line human form for logs and the `glb submit` CLI.
    pub fn summary(&self) -> String {
        match self {
            ServiceResult::U64(v) => format!("{v}"),
            ServiceResult::VecF64(v) => {
                format!("vec[{}] sum={:.6e}", v.len(), v.iter().sum::<f64>())
            }
        }
    }
}

impl WireCodec for ServiceResult {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            ServiceResult::U64(v) => {
                wire::put_u8(out, RES_U64);
                v.encode(out);
            }
            ServiceResult::VecF64(v) => {
                wire::put_u8(out, RES_VEC);
                v.encode(out);
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            RES_U64 => Ok(ServiceResult::U64(u64::decode(r)?)),
            RES_VEC => Ok(ServiceResult::VecF64(Vec::<f64>::decode(r)?)),
            t => Err(WireError::BadTag(t)),
        }
    }
}

/// Folds per-place [`ServiceResult`]s: sums for the counting apps,
/// elementwise vector sum for BC (each place holds the full-length
/// vector with its sources' contributions, exactly like
/// [`crate::glb::VecSumReducer`]).
pub struct ServiceReducer;

impl Reducer<ServiceResult> for ServiceReducer {
    fn identity(&self) -> ServiceResult {
        ServiceResult::U64(0)
    }

    fn reduce(&self, a: ServiceResult, b: ServiceResult) -> ServiceResult {
        match (a, b) {
            (ServiceResult::U64(a), ServiceResult::U64(b)) => ServiceResult::U64(a + b),
            (ServiceResult::VecF64(mut a), ServiceResult::VecF64(b)) => {
                if a.is_empty() {
                    return ServiceResult::VecF64(b);
                }
                if b.is_empty() {
                    return ServiceResult::VecF64(a);
                }
                for (x, y) in a.iter_mut().zip(&b) {
                    *x += y;
                }
                ServiceResult::VecF64(a)
            }
            // The identity is U64(0); let it vanish against vectors so
            // reduce_all works for BC jobs too.
            (ServiceResult::U64(0), v) | (v, ServiceResult::U64(0)) => v,
            _ => panic!("cross-app results reduced together"),
        }
    }
}

/// The queue a resident rank runs a job through: dispatches to the
/// app's own queue, moving work in [`ServiceBag`]s.
pub enum ServiceQueue {
    Uts(UtsQueue),
    Fib(FibQueue),
    Bc(BcQueue),
}

impl TaskQueue for ServiceQueue {
    type Bag = ServiceBag;
    type Result = ServiceResult;

    fn process(&mut self, n: usize) -> ProcessOutcome {
        match self {
            ServiceQueue::Uts(q) => q.process(n),
            ServiceQueue::Fib(q) => q.process(n),
            ServiceQueue::Bc(q) => q.process(n),
        }
    }

    fn split(&mut self) -> Option<ServiceBag> {
        match self {
            ServiceQueue::Uts(q) => q.split().map(ServiceBag::Uts),
            ServiceQueue::Fib(q) => q.split().map(ServiceBag::Fib),
            ServiceQueue::Bc(q) => q.split().map(ServiceBag::Bc),
        }
    }

    fn merge(&mut self, bag: ServiceBag) {
        match (self, bag) {
            (ServiceQueue::Uts(q), ServiceBag::Uts(b)) => q.merge(b),
            (ServiceQueue::Fib(q), ServiceBag::Fib(b)) => q.merge(b),
            (ServiceQueue::Bc(q), ServiceBag::Bc(b)) => q.merge(b),
            _ => panic!("cross-app loot merged into a service queue"),
        }
    }

    fn result(&self) -> ServiceResult {
        match self {
            ServiceQueue::Uts(q) => ServiceResult::U64(q.result()),
            ServiceQueue::Fib(q) => ServiceResult::U64(q.result()),
            ServiceQueue::Bc(q) => ServiceResult::VecF64(q.result()),
        }
    }

    fn bag_size(&self) -> usize {
        match self {
            ServiceQueue::Uts(q) => q.bag_size(),
            ServiceQueue::Fib(q) => q.bag_size(),
            ServiceQueue::Bc(q) => q.bag_size(),
        }
    }
}

/// Build this rank's queue for one job, seeded exactly like the
/// corresponding one-shot run so results are bit-identical:
///
/// - UTS: rank 0 calls `init_root()` (bag *and* node count);
/// - fib: rank 0 merges the client-shipped root bag (or derives it from
///   the spec when the client sent none);
/// - BC: every rank self-assigns its vertex slice `[i*per, ...)` over
///   the cached graph, mirroring the one-shot `seeded_queue`.
fn build_queue(
    spec: &JobSpec,
    rank: usize,
    ranks: usize,
    graph: Option<&Arc<Graph>>,
    root_bag: &[u8],
) -> Result<ServiceQueue> {
    match &spec.app {
        JobApp::Uts(u) => {
            if !root_bag.is_empty() {
                bail!("uts jobs derive their root from the spec; unexpected root bag");
            }
            let mut q = UtsQueue::new(*u);
            if rank == 0 {
                q.init_root();
            }
            Ok(ServiceQueue::Uts(q))
        }
        JobApp::Fib { n } => {
            let mut q = FibQueue::new();
            if rank == 0 {
                if root_bag.is_empty() {
                    q.init(*n);
                } else {
                    let (bag, used) = ServiceBag::decode_slice(root_bag)
                        .map_err(|e| anyhow!("decode root bag: {e}"))?;
                    if used != root_bag.len() {
                        bail!("trailing bytes after the root bag");
                    }
                    match bag {
                        ServiceBag::Fib(b) => {
                            let mut sq = ServiceQueue::Fib(q);
                            sq.merge(ServiceBag::Fib(b));
                            return Ok(sq);
                        }
                        _ => bail!("fib job shipped a non-fib root bag"),
                    }
                }
            }
            Ok(ServiceQueue::Fib(q))
        }
        JobApp::Bc { .. } => {
            if !root_bag.is_empty() {
                bail!("bc jobs derive their vertex slices from the spec; unexpected root bag");
            }
            let g = graph.expect("bc jobs resolve their graph before queue construction");
            let n = g.n() as u32;
            let mut q = BcQueue::sparse(g.clone());
            let per = n / ranks as u32;
            let lo = rank as u32 * per;
            let hi = if rank == ranks - 1 { n } else { lo + per };
            q.assign(lo, hi);
            Ok(ServiceQueue::Bc(q))
        }
    }
}

/// Resolve (generating + caching on first use) the graph a BC job runs
/// over. Non-BC jobs have no graph.
fn resolve_graph(
    spec: &JobSpec,
    graphs: &mut HashMap<u32, Arc<Graph>>,
) -> Option<Arc<Graph>> {
    match &spec.app {
        JobApp::Bc { scale } => Some(
            graphs
                .entry(*scale)
                .or_insert_with(|| {
                    Arc::new(Graph::rmat(RmatParams { scale: *scale, ..Default::default() }))
                })
                .clone(),
        ),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Per-job accounting
// ---------------------------------------------------------------------------

/// What one rank did for one job — handed to the observer of
/// [`serve_with`] after every job (the serve tests sum loot counters
/// across ranks per epoch to assert fleet TX == RX).
#[derive(Debug, Clone)]
pub struct JobReport {
    /// The fleet-internal job epoch.
    pub epoch: u64,
    /// The job's wire spec, as submitted.
    pub spec: String,
    /// The reporting rank.
    pub rank: usize,
    /// This rank's worker counters for the job.
    pub stats: WorkerStats,
    /// Wall-clock for the job on this rank.
    pub elapsed_ns: u64,
    /// The fleet-wide reduced result (rank 0 only).
    pub result: Option<ServiceResult>,
}

// ---------------------------------------------------------------------------
// Retained fleet links
// ---------------------------------------------------------------------------

/// One fleet socket retained across jobs, with its staged read buffer
/// (a frame may straddle a job boundary).
struct Link {
    stream: TcpStream,
    asm: FrameAssembler,
}

impl Link {
    fn fresh(stream: TcpStream) -> Self {
        Self { stream, asm: FrameAssembler::new(wire::MAX_FRAME_BYTES) }
    }

    /// Blocking control-frame write (between jobs the stream may still
    /// be nonblocking from the previous reactor's tenure).
    fn write_ctrl(&mut self, c: &Ctrl) -> Result<()> {
        self.stream.set_nonblocking(false)?;
        wire::write_frame(&mut self.stream, &c.to_body())?;
        Ok(())
    }

    /// Blocking control-frame read through the staged buffer. `None`
    /// means the peer closed cleanly at a frame boundary.
    fn read_ctrl(&mut self) -> Result<Option<Ctrl>> {
        self.stream.set_nonblocking(false)?;
        self.stream.set_read_timeout(None)?;
        loop {
            if let Some(body) = self.asm.next_frame().map_err(|e| anyhow!("fleet frame: {e}"))? {
                let c = Ctrl::decode(body).map_err(|e| anyhow!("fleet control frame: {e}"))?;
                return Ok(Some(c));
            }
            let n = {
                let space = self.asm.read_space(4096);
                match self.stream.read(space) {
                    Ok(n) => n,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e.into()),
                }
            };
            if n == 0 {
                if self.asm.buffered() != 0 {
                    bail!("fleet link closed mid-frame");
                }
                return Ok(None);
            }
            self.asm.commit(n);
        }
    }
}

/// Every socket a resident rank retains across jobs.
struct FleetLinks {
    /// Mesh data links, indexed by peer rank.
    mesh: Vec<Option<Link>>,
    /// Rank 0 only: control links to each spoke.
    to_spokes: Vec<Option<Link>>,
    /// Spokes only: the control link to rank 0.
    to_root: Option<Link>,
}

// ---------------------------------------------------------------------------
// One job on one rank
// ---------------------------------------------------------------------------

/// Run one job's share on this rank: fresh queue/worker/credit, a
/// Ready/Go barrier over the blocking control links, then a per-job
/// resident reactor over the retained sockets. Returns the (fleet-wide
/// on rank 0, local elsewhere) result, this rank's worker counters, and
/// any next-job control frames the reactor picked up early.
fn run_job(
    epoch: u64,
    spec: &JobSpec,
    root_bag: &[u8],
    rank: usize,
    ranks: usize,
    links: &mut FleetLinks,
    graphs: &mut HashMap<u32, Arc<Graph>>,
) -> Result<(ServiceResult, WorkerStats, Vec<Ctrl>)> {
    let cfg = GlbConfig::new(ranks, spec.glb);
    let topo = cfg.topology();

    // -- per-job mailbox + net core --------------------------------------
    let mut local_tx: Vec<Option<Sender<Msg<ServiceBag>>>> = (0..ranks).map(|_| None).collect();
    let (tx, rx) = channel();
    local_tx[rank] = Some(tx);
    let local: Mailboxes<ServiceBag> = Arc::new(local_tx);

    let pool = Arc::new(BufferPool::default());
    let mut net = NetCore::new(ranks, pool);
    for (r, l) in links.mesh.iter().enumerate() {
        if l.is_some() {
            net.mesh[r] = Some(Arc::new(OutQueue::new()));
        }
    }
    let results: ResultSlots = Arc::new(Mutex::new((0..ranks).map(|_| None).collect()));
    let mut root: Option<Arc<CreditRoot>> = None;
    let mut grant_tx: Option<Sender<u64>> = None;
    let mut grants_rx: Option<Receiver<u64>> = None;
    if rank == 0 {
        for (r, l) in links.to_spokes.iter().enumerate() {
            if l.is_some() {
                net.ctrl_peers[r] = Some(Arc::new(OutQueue::new()));
            }
        }
        let cr = CreditRoot::for_epoch(epoch);
        cr.grant(ranks as u64 * INITIAL_RANK_ATOMS);
        root = Some(cr);
    } else {
        net.ctrl = Some(Arc::new(OutQueue::new()));
        let (gtx, grx) = channel();
        grant_tx = Some(gtx);
        grants_rx = Some(grx);
    }
    let net = Arc::new(net);

    let ledger = if rank == 0 {
        let cr = root.clone().expect("rank 0 hosts the credit root");
        FleetLedger::Credit(CreditLedger::new(Arc::new(RootHome { root: cr }), INITIAL_RANK_ATOMS))
    } else {
        let grants = grants_rx.take().expect("spokes hold the grant channel");
        FleetLedger::Credit(CreditLedger::new(
            Arc::new(QueueHome { net: net.clone(), grants: Mutex::new(grants), job: epoch }),
            INITIAL_RANK_ATOMS,
        ))
    };

    let transport: SocketTransport<ServiceBag> = SocketTransport {
        rank,
        topo,
        p: ranks,
        local: local.clone(),
        net: net.clone(),
        recovery: None,
        job: epoch,
    };
    if let Some(cr) = &root {
        let t = transport.clone();
        cr.on_quiescent(move || t.terminate_fleet());
    }

    // -- queue + worker (tokens acquired before the barrier) -------------
    let graph = resolve_graph(spec, graphs);
    let queue = build_queue(spec, rank, ranks, graph.as_ref(), root_bag)?;
    let mut worker = Worker::new(rank, ranks, spec.glb, queue, ledger);

    // -- per-job Ready/Go barrier over the blocking control links --------
    // No Ready/Go ever flows through a resident reactor: the barrier
    // completes before the reactors take the sockets.
    if rank == 0 {
        for r in 1..ranks {
            let l = links.to_spokes[r].as_mut().expect("resident fleet keeps every spoke link");
            match l.read_ctrl()? {
                Some(Ctrl::Ready { rank: rr }) if rr as usize == r => {}
                other => bail!("rank {r}: expected job readiness, got {other:?}"),
            }
        }
        // Arm before any Go: deposits only start after Go, so detection
        // can never race the job start.
        root.as_ref().expect("rank 0 hosts the credit root").arm();
        for l in links.to_spokes.iter_mut().flatten() {
            l.write_ctrl(&Ctrl::Go)?;
        }
    } else {
        let l = links.to_root.as_mut().expect("spokes keep their root link");
        l.write_ctrl(&Ctrl::Ready { rank: rank as u64 })?;
        match l.read_ctrl()? {
            Some(Ctrl::Go) => {}
            other => bail!("expected job go, got {other:?}"),
        }
    }

    // -- per-job reactor over the retained sockets -----------------------
    let mut conns: Vec<ReactorConn> = Vec::new();
    for (r, l) in links.mesh.iter_mut().enumerate() {
        if let Some(l) = l.take() {
            let q = net.mesh[r].clone().expect("mesh link has a queue");
            conns.push(ReactorConn::resume(l.stream, ConnKind::Mesh { peer: r }, l.asm, q));
        }
    }
    let role = if rank == 0 {
        for (r, l) in links.to_spokes.iter_mut().enumerate() {
            if let Some(l) = l.take() {
                let q = net.ctrl_peers[r].clone().expect("control link has a queue");
                conns.push(ReactorConn::resume(l.stream, ConnKind::CtrlRoot { peer: r }, l.asm, q));
            }
        }
        ReactorRole::Root {
            root: root.clone().expect("rank 0 hosts the credit root"),
            results: results.clone(),
            gate: Arc::new(FleetGate::default()),
            tol: None,
        }
    } else {
        let l = links.to_root.take().expect("spokes keep their root link");
        let q = net.ctrl.clone().expect("spokes hold a control queue");
        conns.push(ReactorConn::resume(l.stream, ConnKind::CtrlSpoke, l.asm, q));
        ReactorRole::Spoke {
            gate: Arc::new(FleetGate::default()),
            grant_tx: grant_tx.take(),
            tolerant: false,
            leave_tx: None,
        }
    };
    let reactor = Reactor::<ServiceBag> {
        poller: Poller::new().context("create job reactor poller")?,
        conns,
        core: net.clone(),
        my_rank: rank,
        topo,
        local,
        recovery: None,
        role,
        stats: None,
        job: epoch,
        resident: Some(ResidentReactor::new(ranks)),
    };
    let io = std::thread::Builder::new()
        .name(format!("glb-serve-io-{rank}"))
        .spawn(move || reactor.run_resident())
        .context("spawn job reactor")?;

    // -- run the job's share ---------------------------------------------
    let mut fx = Vec::new();
    worker.kick_if_empty(&mut fx);
    pump(rank, &mut fx, &transport);
    let (result, stats) = socket_place_main(worker, rx, transport, None, GatherWire, None, false);

    if rank != 0 {
        let sent = net.send_ctrl(&Ctrl::Result { job: epoch, bytes: GatherWire.encode(&result) });
        if !sent {
            bail!("fleet control link closed before the job result was sent");
        }
    }

    // -- end of job: fence, drain, reclaim the sockets -------------------
    net.shutdown.store(true, Ordering::Release);
    net.waker.wake();
    let exit = io.join().map_err(|_| anyhow!("job reactor panicked"))?;
    for c in exit.conns {
        let link = Link { stream: c.stream, asm: c.asm };
        match c.kind {
            ConnKind::Mesh { peer } => links.mesh[peer] = Some(link),
            ConnKind::CtrlRoot { peer } => links.to_spokes[peer] = Some(link),
            ConnKind::CtrlSpoke => links.to_root = Some(link),
        }
    }

    let fleet_result = if rank == 0 {
        let cr = root.expect("rank 0 hosts the credit root");
        debug_assert!(cr.quiescent(), "job ended without credit quiescence");
        let mut all = vec![result];
        let mut slots = results.lock().expect("result slots poisoned");
        for (r, slot) in slots.iter_mut().enumerate().skip(1) {
            let bytes =
                slot.take().with_context(|| format!("rank {r} sent no result for job {epoch}"))?;
            all.push(GatherWire.decode(&bytes)?);
        }
        ServiceReducer.reduce_all(all)
    } else {
        result
    };
    Ok((fleet_result, stats, exit.carryover))
}

// ---------------------------------------------------------------------------
// The resident service
// ---------------------------------------------------------------------------

/// Boot this rank of a resident fleet and serve jobs until a client
/// sends [`Ctrl::Shutdown`]. Rank 0 additionally owns the client plane:
/// it accepts `glb submit` connections on the fleet's rendezvous port
/// and streams each job's reduced result back as a
/// [`Ctrl::JobResult`].
pub fn serve(opts: &SocketRunOpts) -> Result<()> {
    serve_with(opts, |_| {})
}

/// [`serve`] with a per-job observer — called on every rank after every
/// job with that rank's [`JobReport`]. The serve integration tests use
/// it to cross-check per-epoch loot conservation fleet-wide.
pub fn serve_with(opts: &SocketRunOpts, mut on_job: impl FnMut(&JobReport)) -> Result<()> {
    let (rank, ranks) = (opts.rank, opts.ranks);
    if ranks == 0 {
        bail!("a fleet needs at least one rank");
    }
    if rank >= ranks {
        bail!("--rank {rank} out of range for --peers {ranks}");
    }
    if opts.tolerate_failures > 0 {
        bail!("glb serve does not support --tolerate-failures yet");
    }
    if opts.stats_interval.is_some() || opts.adapt {
        bail!("glb serve does not support --stats/--adapt yet");
    }
    chaos::arm(rank);
    if rank == 0 {
        serve_root(opts, &mut on_job)
    } else {
        serve_spoke(opts, &mut on_job)
    }
}

/// Rank 0: boot the fleet once, then loop accepting clients and running
/// their jobs.
fn serve_root(opts: &SocketRunOpts, on_job: &mut dyn FnMut(&JobReport)) -> Result<()> {
    let ranks = opts.ranks;
    let deadline = Instant::now() + opts.handshake_timeout;

    // -- one-time fleet bootstrap (the one-shot handshake, with the
    //    listener retained for the client plane) ------------------------
    let bind_addr = opts.bind.clone().unwrap_or_else(|| opts.host.clone());
    let listener = TcpListener::bind((bind_addr.as_str(), opts.port))
        .with_context(|| format!("bind fleet bootstrap on {bind_addr}:{}", opts.port))?;
    listener.set_nonblocking(true)?;
    let port = listener.local_addr()?.port();

    let mut links = FleetLinks {
        mesh: (0..ranks).map(|_| None).collect(),
        to_spokes: (0..ranks).map(|_| None).collect(),
        to_root: None,
    };
    if ranks > 1 {
        let mut addrs: Vec<Option<String>> = (0..ranks).map(|_| None).collect();
        addrs[0] = Some(format!("{}:{port}", opts.host));
        for _ in 0..2 * (ranks - 1) {
            let (mut s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            if r == 0 || r >= ranks {
                bail!("fleet handshake from invalid rank {r}");
            }
            match kind {
                HS_CTRL => {
                    if links.to_spokes[r].is_some() {
                        bail!("duplicate control link from rank {r}");
                    }
                    let body = wire::read_frame(&mut s, wire::MAX_FRAME_BYTES)
                        .context("read rank registration")?
                        .ok_or_else(|| anyhow!("rank {r} closed before registering"))?;
                    match Ctrl::decode(&body) {
                        Ok(Ctrl::Register { rank: rr, addr }) if rr as usize == r => {
                            addrs[r] = Some(addr);
                        }
                        other => bail!("rank {r}: expected registration, got {other:?}"),
                    }
                    s.set_read_timeout(None)?;
                    links.to_spokes[r] = Some(Link::fresh(s));
                }
                HS_MESH => {
                    if links.mesh[r].is_some() {
                        bail!("duplicate mesh link from rank {r}");
                    }
                    s.set_read_timeout(None)?;
                    links.mesh[r] = Some(Link::fresh(s));
                }
                k => bail!("bad fleet handshake kind {k}"),
            }
        }
        let addrs: Vec<String> = addrs
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .context("fleet bootstrap finished with unregistered ranks")?;
        let map = Ctrl::PeerMap { epoch: 0, addrs };
        for r in 1..ranks {
            let l = links.to_spokes[r].as_mut().expect("every spoke registered");
            l.write_ctrl(&map).with_context(|| format!("send peer map to rank {r}"))?;
        }
    }
    println!("glb serve: fleet of {ranks} rank(s) resident on port {port}");

    // -- the client plane ------------------------------------------------
    let mut graphs: HashMap<u32, Arc<Graph>> = HashMap::new();
    let mut epoch: u64 = 0;
    loop {
        let mut client = accept_client(&listener)?;
        'jobs: loop {
            let body = match wire::read_frame(&mut client, wire::MAX_FRAME_BYTES) {
                Ok(Some(b)) => b,
                Ok(None) => break 'jobs, // clean goodbye; next client
                Err(e) => {
                    eprintln!("glb serve: client read failed: {e}");
                    break 'jobs;
                }
            };
            let ctrl = match Ctrl::decode(&body) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("glb serve: bad client frame: {e}");
                    break 'jobs;
                }
            };
            match ctrl {
                Ctrl::Submit { job: client_job, spec, bag } => {
                    let parsed = match JobSpec::parse(&spec) {
                        Ok(s) => s,
                        Err(e) => {
                            eprintln!("glb serve: rejected job spec {spec:?}: {e}");
                            break 'jobs;
                        }
                    };
                    epoch += 1;
                    for r in 1..ranks {
                        let l = links.to_spokes[r].as_mut().expect("resident spoke link");
                        l.write_ctrl(&Ctrl::Submit {
                            job: epoch,
                            spec: spec.clone(),
                            bag: bag.clone(),
                        })
                        .with_context(|| format!("forward job {epoch} to rank {r}"))?;
                    }
                    let t0 = Instant::now();
                    let (result, stats) = if ranks == 1 {
                        run_job_single(&parsed, &bag, &mut graphs)?
                    } else {
                        let (result, stats, carry) =
                            run_job(epoch, &parsed, &bag, 0, ranks, &mut links, &mut graphs)?;
                        debug_assert!(carry.is_empty(), "rank 0 never sees early submissions");
                        (result, stats)
                    };
                    let elapsed_ns = t0.elapsed().as_nanos() as u64;
                    println!(
                        "glb serve: job {epoch} [{spec}] -> {} in {:.1} ms",
                        result.summary(),
                        elapsed_ns as f64 / 1e6,
                    );
                    print_job_report(epoch, &spec, ranks, elapsed_ns, &result);
                    on_job(&JobReport {
                        epoch,
                        spec: spec.clone(),
                        rank: 0,
                        stats,
                        elapsed_ns,
                        result: Some(result.clone()),
                    });
                    let reply =
                        Ctrl::JobResult { job: client_job, bytes: GatherWire.encode(&result) };
                    if let Err(e) = wire::write_frame(&mut client, &reply.to_body()) {
                        eprintln!("glb serve: client went away before job {epoch}'s result: {e}");
                        break 'jobs;
                    }
                }
                Ctrl::Shutdown => {
                    for r in 1..ranks {
                        let l = links.to_spokes[r].as_mut().expect("resident spoke link");
                        l.write_ctrl(&Ctrl::Shutdown)
                            .with_context(|| format!("forward shutdown to rank {r}"))?;
                    }
                    println!("glb serve: fleet shut down after {epoch} job(s)");
                    return Ok(());
                }
                other => {
                    eprintln!("glb serve: unexpected client frame {other:?}");
                    break 'jobs;
                }
            }
        }
    }
}

/// A spoke: boot once, then run every job rank 0 forwards until the
/// shutdown frame arrives.
fn serve_spoke(opts: &SocketRunOpts, on_job: &mut dyn FnMut(&JobReport)) -> Result<()> {
    let (rank, ranks) = (opts.rank, opts.ranks);
    let deadline = Instant::now() + opts.handshake_timeout;

    // -- one-time fleet bootstrap (identical to the one-shot spoke) ------
    let listener = TcpListener::bind(("0.0.0.0", 0)).context("bind mesh listener")?;
    let mesh_port = listener.local_addr()?.port();
    let mut ctrl = connect_retry(&opts.host, opts.port, deadline)?;
    ctrl.write_all(&handshake_bytes(HS_CTRL, rank)).context("send control handshake")?;
    let advertise_ip = match &opts.advertise {
        Some(a) => a.clone(),
        None => ctrl.local_addr()?.ip().to_string(),
    };
    let mut links = FleetLinks {
        mesh: (0..ranks).map(|_| None).collect(),
        to_spokes: Vec::new(),
        to_root: None,
    };
    let mut to_hub = connect_retry(&opts.host, opts.port, deadline)?;
    to_hub.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
    links.mesh[0] = Some(Link::fresh(to_hub));
    let reg = Ctrl::Register { rank: rank as u64, addr: format!("{advertise_ip}:{mesh_port}") };
    wire::write_frame(&mut ctrl, &reg.to_body()).context("send registration")?;
    ctrl.set_read_timeout(Some(opts.handshake_timeout))?;
    let body = wire::read_frame(&mut ctrl, wire::MAX_FRAME_BYTES)
        .context("read peer map")?
        .ok_or_else(|| anyhow!("bootstrap closed before the peer map"))?;
    let addrs = match Ctrl::decode(&body) {
        Ok(Ctrl::PeerMap { epoch: 0, addrs }) if addrs.len() == ranks => addrs,
        other => bail!("expected a {ranks}-rank peer map, got {other:?}"),
    };
    for (r, addr) in addrs.iter().enumerate().take(rank).skip(1) {
        let (host, port) = addr
            .rsplit_once(':')
            .ok_or_else(|| anyhow!("malformed mesh address {addr:?} for rank {r}"))?;
        let port: u16 = port.parse().with_context(|| format!("mesh port in {addr:?}"))?;
        let mut s = connect_retry(host, port, deadline)?;
        s.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
        links.mesh[r] = Some(Link::fresh(s));
    }
    listener.set_nonblocking(true)?;
    for _ in 0..ranks - 1 - rank {
        let (s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
        s.set_read_timeout(None)?;
        if kind != HS_MESH || r <= rank || r >= ranks {
            bail!("bad mesh handshake (kind {kind}, rank {r})");
        }
        if links.mesh[r].is_some() {
            bail!("duplicate mesh link from rank {r}");
        }
        links.mesh[r] = Some(Link::fresh(s));
    }
    ctrl.set_read_timeout(None)?;
    links.to_root = Some(Link::fresh(ctrl));

    // -- the job loop ----------------------------------------------------
    let mut graphs: HashMap<u32, Arc<Graph>> = HashMap::new();
    let mut pending: VecDeque<Ctrl> = VecDeque::new();
    loop {
        let next = match pending.pop_front() {
            Some(c) => c,
            None => {
                let l = links.to_root.as_mut().expect("spokes keep their root link");
                l.read_ctrl()?
                    .ok_or_else(|| anyhow!("lost the fleet control link while resident"))?
            }
        };
        match next {
            Ctrl::Submit { job, spec, bag } => {
                let parsed = JobSpec::parse(&spec)
                    .with_context(|| format!("rank {rank}: job {job} spec"))?;
                let t0 = Instant::now();
                let (_local, stats, carry) =
                    run_job(job, &parsed, &bag, rank, ranks, &mut links, &mut graphs)?;
                pending.extend(carry);
                on_job(&JobReport {
                    epoch: job,
                    spec,
                    rank,
                    stats,
                    elapsed_ns: t0.elapsed().as_nanos() as u64,
                    result: None,
                });
            }
            Ctrl::Shutdown => return Ok(()),
            other => bail!("rank {rank}: unexpected control frame between jobs: {other:?}"),
        }
    }
}

/// A single-rank fleet runs each job in-process (there is no mesh), with
/// the same seeding as a one-shot single-rank run.
fn run_job_single(
    spec: &JobSpec,
    root_bag: &[u8],
    graphs: &mut HashMap<u32, Arc<Graph>>,
) -> Result<(ServiceResult, WorkerStats)> {
    let cfg = GlbConfig::new(1, spec.glb);
    let graph = resolve_graph(spec, graphs);
    let spec2 = spec.clone();
    let bag2 = root_bag.to_vec();
    let out = run_threads(
        &cfg,
        move |i, np| {
            build_queue(&spec2, i, np, graph.as_ref(), &bag2)
                .expect("validated job spec builds a queue")
        },
        |_| {},
        &ServiceReducer,
    );
    Ok((out.result, out.log.total()))
}

/// Print the per-job machine-readable fleet report marker (schema
/// `glb-serve-report/v1`, documented in `docs/operations.md`).
fn print_job_report(epoch: u64, spec: &str, ranks: usize, elapsed_ns: u64, result: &ServiceResult) {
    let result_json = match result {
        ServiceResult::U64(v) => format!("{{\"kind\":\"u64\",\"value\":{v}}}"),
        ServiceResult::VecF64(v) => format!(
            "{{\"kind\":\"vec_f64\",\"len\":{},\"sum\":{:.17e}}}",
            v.len(),
            v.iter().sum::<f64>()
        ),
    };
    println!(
        "GLB-SERVE-REPORT {{\"schema\":\"glb-serve-report/v1\",\"job\":{epoch},\
         \"spec\":\"{spec}\",\"ranks\":{ranks},\"elapsed_ns\":{elapsed_ns},\
         \"result\":{result_json}}}"
    );
}

/// Accept one `glb submit` client on the retained rendezvous listener
/// (blocking indefinitely — a resident fleet waits for work).
fn accept_client(listener: &TcpListener) -> Result<TcpStream> {
    loop {
        match listener.accept() {
            Ok((mut s, _addr)) => {
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                s.set_read_timeout(Some(Duration::from_secs(30)))?;
                let mut hs = [0u8; 9];
                if s.read_exact(&mut hs).is_err() {
                    continue; // port scanner / dead dialer
                }
                if hs[0] != HS_CLIENT {
                    eprintln!(
                        "glb serve: rejected non-client handshake (kind {}) after bootstrap",
                        hs[0]
                    );
                    continue;
                }
                s.set_read_timeout(None)?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

// ---------------------------------------------------------------------------
// The submit client
// ---------------------------------------------------------------------------

/// A `glb submit` connection to a resident fleet's rank 0. Jobs run
/// sequentially: [`SubmitClient::submit`] blocks until the fleet
/// streams the job's reduced result back.
pub struct SubmitClient {
    stream: TcpStream,
    next_job: u64,
}

impl SubmitClient {
    /// Dial the fleet (retrying until `timeout` so a submit racing the
    /// fleet boot just waits) and handshake as a client.
    pub fn connect(host: &str, port: u16, timeout: Duration) -> Result<Self> {
        let mut stream = connect_retry(host, port, Instant::now() + timeout)?;
        stream.write_all(&handshake_bytes(HS_CLIENT, 0)).context("send client handshake")?;
        Ok(Self { stream, next_job: 1 })
    }

    /// Ship one job and block for its fleet-wide result.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<ServiceResult> {
        let job = self.next_job;
        self.next_job += 1;
        let mut bag = Vec::new();
        if let Some(b) = spec.root_bag() {
            b.encode(&mut bag);
        }
        let frame = Ctrl::Submit { job, spec: spec.format(), bag };
        wire::write_frame(&mut self.stream, &frame.to_body()).context("submit job")?;
        let body = wire::read_frame(&mut self.stream, wire::MAX_FRAME_BYTES)
            .context("read job result")?
            .ok_or_else(|| anyhow!("fleet closed before the job result"))?;
        match Ctrl::decode(&body) {
            Ok(Ctrl::JobResult { job: j, bytes }) if j == job => GatherWire.decode(&bytes),
            other => bail!("expected the result of job {job}, got {other:?}"),
        }
    }

    /// Shut the whole fleet down (every rank exits cleanly).
    pub fn shutdown(mut self) -> Result<()> {
        wire::write_frame(&mut self.stream, &Ctrl::Shutdown.to_body()).context("send shutdown")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn glb_defaults() -> GlbParams {
        GlbParams::default()
    }

    #[test]
    fn job_spec_round_trips() {
        let specs = [
            JobSpec::uts(UtsParams { b0: 4.0, seed: 19, max_depth: 8 }, glb_defaults()),
            JobSpec::fib(24, glb_defaults()),
            JobSpec::bc(7, GlbParams { n: 127, w: 2, ..GlbParams::default() }),
        ];
        for s in specs {
            let wire = s.format();
            let back = JobSpec::parse(&wire).expect("round trip");
            assert_eq!(back, s, "spec {wire:?}");
        }
    }

    #[test]
    fn job_spec_rejects_junk() {
        assert!(JobSpec::parse("depth=8").is_err(), "missing app");
        assert!(JobSpec::parse("app=quux").is_err(), "unknown app");
        assert!(JobSpec::parse("app=fib fib-n=3 bogus=1").is_err(), "unknown key");
        assert!(JobSpec::parse("app=fib fib-n=3 fib-n=4").is_err(), "duplicate key");
    }

    #[test]
    fn service_bag_codec_round_trips() {
        let bags = [
            ServiceBag::Fib(ArrayListTaskBag::from_vec(vec![24, 7, 3])),
            ServiceBag::Uts(UtsBag::new()),
            ServiceBag::Bc(BcBag::from_intervals(vec![(3, 9)])),
        ];
        for b in bags {
            let mut buf = Vec::new();
            b.encode(&mut buf);
            let (back, used) = ServiceBag::decode_slice(&buf).expect("decode");
            assert_eq!(used, buf.len());
            assert_eq!(back.size(), b.size());
            let mut buf2 = Vec::new();
            back.encode(&mut buf2);
            assert_eq!(buf, buf2, "canonical re-encode");
        }
    }

    #[test]
    fn service_reducer_folds_both_kinds() {
        let r = ServiceReducer;
        assert_eq!(
            r.reduce_all([ServiceResult::U64(2), ServiceResult::U64(5)]),
            ServiceResult::U64(7)
        );
        let v = r.reduce_all([
            ServiceResult::VecF64(vec![1.0, 2.0]),
            ServiceResult::VecF64(vec![0.5, 0.25]),
        ]);
        assert_eq!(v, ServiceResult::VecF64(vec![1.5, 2.25]));
    }

    #[test]
    #[should_panic(expected = "cross-app")]
    fn service_reducer_rejects_cross_app() {
        ServiceReducer.reduce(ServiceResult::U64(1), ServiceResult::VecF64(vec![1.0]));
    }

    #[test]
    fn single_rank_jobs_match_one_shot() {
        let mut graphs = HashMap::new();
        let spec = JobSpec::fib(16, glb_defaults());
        let bag = spec.root_bag().map(|b| {
            let mut buf = Vec::new();
            b.encode(&mut buf);
            buf
        });
        let (res, _) = run_job_single(&spec, bag.as_deref().unwrap_or(&[]), &mut graphs).unwrap();
        assert_eq!(res, ServiceResult::U64(crate::apps::fib::fib(16)));
    }
}
