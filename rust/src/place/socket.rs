//! TCP socket place-runtime: one OS **process** per GLB node, wired as a
//! direct spoke-to-spoke **mesh** with credit-based distributed
//! termination.
//!
//! This is the process-spanning `Transport` the ROADMAP calls for: the
//! same [`Worker`] protocol engine as the thread runtime and the
//! simulator, but with nodes living in separate OS processes that talk
//! over length-prefixed TCP frames ([`crate::glb::wire`]). A fleet of
//! `ranks` processes runs one GLB *node* each (so with
//! `workers_per_node > 1` every process hosts several worker threads
//! sharing a [`NodeBag`], and only the node's representative speaks the
//! inter-node protocol).
//!
//! ## Fleet wiring (bootstrap star, steady-state mesh)
//!
//! Rank 0 is **bootstrap and discovery only** — after the start barrier
//! no steal/loot/refusal byte transits it on behalf of other ranks:
//!
//! 1. every rank binds its own mesh listener; spokes dial rank 0 and
//!    [`Ctrl::Register`] their advertised `ip:port`;
//! 2. rank 0 answers with the [`Ctrl::PeerMap`]; each rank then dials
//!    every lower rank and accepts every higher one, building one duplex
//!    TCP link per pair (dials succeed through listen backlogs, so the
//!    strict ordering cannot deadlock);
//! 3. data frames are `[to: u64][job: u64][msg body]` under a length
//!    prefix, sent on the pair's own link and decoded only at the
//!    destination — a frame for a place the receiving rank does not host
//!    is a protocol violation (counted in [`misrouted_frames`], asserted
//!    zero by the fleet tests), and a frame whose job epoch differs from
//!    the receiver's current job is dropped and counted in
//!    [`cross_epoch_frames`] (one-shot runs use epoch 0 everywhere; the
//!    resident service of [`crate::place::service`] stamps every job
//!    with its own epoch).
//!
//! Rank 0 keeps binding separate from advertising: it binds
//! [`SocketRunOpts::bind`] (default: the advertised host) so
//! `--host <public-ip>` works on machines where that address is not
//! locally bindable (`--bind 0.0.0.0`).
//!
//! ## Termination: credit throwing instead of a hub ledger
//!
//! The work-token count (paper §2.4 item 3) is distributed via
//! Mattern-style credit throwing ([`crate::glb::termination`]): every
//! rank runs a [`CreditLedger`] whose `incr`/`decr` are **local** (no
//! I/O), loot messages carry credit atoms in their wire envelope, and a
//! rank that goes idle deposits its atoms to rank 0's [`CreditRoot`]
//! asynchronously on the control link. The root observes
//! `recovered == total` exactly when no rank holds a token and no loot
//! is in flight, then broadcasts `Terminate` to every place over the
//! mesh. The only synchronous credit operation left is the
//! pool-exhaustion [`Ctrl::Replenish`], amortized over many cross-rank
//! loot sends (worst-case cadence documented at
//! [`crate::glb::termination::MAX_ATTACH_ATOMS`]) — nothing here does a
//! synchronous RPC per steal/loot event the way the old hub ledger did.
//!
//! A fleet-wide start barrier ([`Ctrl::Ready`]/[`Ctrl::Go`] on the
//! control link) preserves the thread runtime's sequential-setup
//! guarantee: no rank enters the steal protocol until every rank has
//! constructed its workers and holds its initial tokens and credit.
//!
//! ## One I/O thread per rank: the readiness reactor
//!
//! All post-bootstrap sockets of a rank — every mesh link plus its
//! control link(s) — are owned by a single `glb-io-{rank}` event-loop
//! thread built on [`crate::place::reactor`]: a hand-rolled epoll
//! (Linux; `poll(2)` elsewhere) readiness loop with per-peer staged
//! read buffers ([`FrameAssembler`]) that decode frames in place, and
//! per-peer write queues ([`OutQueue`]) that coalesce small frames into
//! `writev` batches. Workers never touch a socket: sends encode into
//! pooled buffers ([`BufferPool`]) and enqueue; the reactor flushes
//! when the socket is writable and recycles the buffer once it is on
//! the wire (or, in tolerant mode, once the retention ledger lets go of
//! it too). The per-rank OS thread count is therefore O(workers), not
//! O(peers) — the property that lets fleets grow past 64 ranks without
//! the ~2N reader threads per rank of the previous design.
//!
//! Teardown mirrors the protocol's own guarantee that no message is in
//! flight after `Terminate`: each rank closes its write queues (the
//! reactor drains them to the socket, then half-closes), drains every
//! peer to EOF, and exits; rank 0 treats a spoke's control-link EOF as
//! that rank's orderly goodbye (after optionally collecting its encoded
//! result for the fleet-wide reduction of [`run_sockets_reduced`]) —
//! or, in tolerant fleets, as a death if no result arrived first.

use std::collections::{HashMap, VecDeque};
use std::io::{self, ErrorKind, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::glb::autotune::{AdaptiveConfig, AdaptiveController, ControllerSample};
use crate::glb::message::{Effect, Msg, PlaceId};
use crate::glb::metrics::{MetricsHub, StatsBank, StatsSnapshot};
use crate::glb::task_queue::{Reducer, TaskQueue};
use crate::glb::termination::{
    AtomicLedger, CreditHome, CreditLedger, CreditRoot, Ledger, INITIAL_RANK_ATOMS,
};
use crate::glb::topology::{NodeBag, Topology};
use crate::glb::wire::{self, BufferPool, Ctrl, FrameAssembler, WireCodec};
use crate::glb::worker::{Phase, Worker};
use crate::glb::{GlbConfig, RunLog, RunOutput};
use crate::place::membership::{DynamicMembership, MembershipProvider};
use crate::place::reactor::{lock_clean, Event, OutQueue, Poller, Waker};
use crate::testkit::chaos;

/// How this process joins the fleet.
#[derive(Debug, Clone)]
pub struct SocketRunOpts {
    /// This process's rank (= its GLB node id). Rank 0 is bootstrap +
    /// credit root.
    pub rank: usize,
    /// Total processes in the fleet (= GLB node count).
    pub ranks: usize,
    /// Rank 0's *advertised* host: what every other rank dials for
    /// bootstrap, and what the peer map lists as rank 0's mesh address.
    pub host: String,
    /// Rank 0's rendezvous port. `0` (rank 0 only, single-rank fleets)
    /// binds an ephemeral port.
    pub port: u16,
    /// Rank 0's *bind* address. `None` binds `host`; set it (CLI default
    /// `0.0.0.0` when `--host` is given) when the advertised address is
    /// not locally bindable — NAT'd hosts, load-balanced VIPs, or plain
    /// `--host <public-ip>` on a box that only has the private interface.
    pub bind: Option<String>,
    /// This rank's advertised mesh IP (spokes). `None` advertises the
    /// interface this host reaches rank 0 from — right for localhost
    /// fleets and single-homed hosts alike.
    pub advertise: Option<String>,
    /// How long to wait for the whole fleet to connect / handshake.
    pub handshake_timeout: Duration,
    /// Per-place worker thread stack size in bytes.
    pub stack_bytes: usize,
    /// How many rank deaths (rank 0 excluded — the bootstrap/credit root
    /// dying is always fatal) the fleet absorbs by reconfiguring instead
    /// of failing. `0` (default) keeps the historical fail-fast
    /// semantics byte-for-byte; `> 0` requires a gathered run
    /// ([`run_sockets_reduced`]) with one worker per node.
    pub tolerate_failures: usize,
    /// Live telemetry: sample this rank's gauges every interval and ship
    /// them to rank 0 as [`Ctrl::Stats`] frames riding the batched
    /// control link. Rank 0 prints one aggregated fleet line per
    /// interval plus a machine-readable `GLB-LIVE-STATS` marker the
    /// launcher folds into its report. `None` (default) keeps the
    /// telemetry plane fully disarmed — zero hot-path cost.
    pub stats_interval: Option<Duration>,
    /// Close the telemetry loop: each worker runs an
    /// [`AdaptiveController`] over its own live gauges and retunes loot
    /// granularity / lifeline arity mid-run when they show persistent
    /// starvation. Off by default; incompatible with
    /// `tolerate_failures` (a retune re-knits lifelines over the full
    /// static fleet shape, which a shrinking membership invalidates).
    pub adapt: bool,
}

impl Default for SocketRunOpts {
    fn default() -> Self {
        Self {
            rank: 0,
            ranks: 1,
            host: "127.0.0.1".into(),
            port: 0,
            bind: None,
            advertise: None,
            handshake_timeout: Duration::from_secs(30),
            stack_bytes: 2 << 20,
            tolerate_failures: 0,
            stats_interval: None,
            adapt: false,
        }
    }
}

// Handshake connection kinds.
pub(crate) const HS_CTRL: u8 = 0;
pub(crate) const HS_MESH: u8 = 1;
/// A `glb submit` client dialing a resident fleet's rank 0 (see
/// [`crate::place::service`]).
pub(crate) const HS_CLIENT: u8 = 2;

/// Data frames that arrived at a rank not hosting their destination
/// place — star-style relay traffic, which the mesh must never produce.
/// Monotonic per process; the fleet integration tests assert it stays
/// zero on every rank.
static MISROUTED_FRAMES: AtomicU64 = AtomicU64::new(0);

/// Data frames this process received for places it does not host (see
/// [`MISROUTED_FRAMES`]). Zero on every rank of a healthy mesh.
pub fn misrouted_frames() -> u64 {
    MISROUTED_FRAMES.load(Ordering::Relaxed)
}

/// Frames whose job epoch did not match the receiver's current job —
/// dropped on arrival so one job's loot or credit can never leak into
/// another. The epoch fences of the resident service make a non-zero
/// count structurally impossible; the serve integration tests assert it
/// stays zero on every rank.
static CROSS_EPOCH_FRAMES: AtomicU64 = AtomicU64::new(0);

/// Frames this process dropped for carrying another job's epoch (see
/// [`CROSS_EPOCH_FRAMES`]). Zero on every rank of a healthy fleet.
pub fn cross_epoch_frames() -> u64 {
    CROSS_EPOCH_FRAMES.load(Ordering::Relaxed)
}

/// Bytes this process has put on / taken off the wire through the
/// reactor (frame bodies plus their 4-byte length prefix, mesh and
/// control links alike; the blocking bootstrap handshake is excluded —
/// symmetrically on both ends, so fleet-wide TX still equals RX).
/// Monotonic per process — one GLB run per process, so the totals are
/// per-run in practice; the fleet launcher rolls them into its report.
static WIRE_TX_BYTES: AtomicU64 = AtomicU64::new(0);
static WIRE_RX_BYTES: AtomicU64 = AtomicU64::new(0);

/// `(sent, received)` post-bootstrap wire bytes for this process (see
/// [`WIRE_TX_BYTES`]).
pub fn wire_bytes() -> (u64, u64) {
    (WIRE_TX_BYTES.load(Ordering::Relaxed), WIRE_RX_BYTES.load(Ordering::Relaxed))
}

/// Frames flushed to / dispatched from the reactor (mesh + control),
/// `writev` batches issued, and steal round-trip latency samples
/// (Steal enqueued → matching Loot/refusal dispatched). Monotonic per
/// process, like [`WIRE_TX_BYTES`].
static FRAMES_TX: AtomicU64 = AtomicU64::new(0);
static FRAMES_RX: AtomicU64 = AtomicU64::new(0);
static BATCHES: AtomicU64 = AtomicU64::new(0);
static STEAL_LAT_NS_SUM: AtomicU64 = AtomicU64::new(0);
static STEAL_LAT_COUNT: AtomicU64 = AtomicU64::new(0);

/// Reactor threads this process has spawned / still has running. A
/// healthy N-rank fleet spawns exactly one per rank (zero for
/// single-rank runs) and joins it before the run returns — the
/// O(workers)-not-O(peers) thread-count property the launcher report
/// asserts.
static IO_THREADS: AtomicU64 = AtomicU64::new(0);
static IO_THREADS_LIVE: AtomicU64 = AtomicU64::new(0);

/// Reactor-level transport counters for this process's socket runs.
/// All zeros for thread/sim transports (nothing hits a wire).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetStats {
    /// Frames flushed onto sockets (mesh data + post-bootstrap control).
    pub frames_tx: u64,
    /// Frames decoded off sockets.
    pub frames_rx: u64,
    /// `writev` calls that moved at least one byte — `frames_tx /
    /// batches` is the mean coalescing factor.
    pub batches: u64,
    /// Mean steal round-trip in microseconds (Steal enqueued → Loot or
    /// refusal dispatched), 0.0 when no samples.
    pub steal_latency_us: f64,
    /// Completed steal round-trips behind `steal_latency_us`.
    pub steal_samples: u64,
    /// Reactor threads this process ever spawned (1 per multi-rank
    /// socket run — the O(workers)-not-O(peers) property).
    pub io_threads: u64,
}

/// Snapshot of this process's reactor counters (see [`NetStats`]).
pub fn net_stats() -> NetStats {
    let samples = STEAL_LAT_COUNT.load(Ordering::Relaxed);
    let sum_ns = STEAL_LAT_NS_SUM.load(Ordering::Relaxed);
    NetStats {
        frames_tx: FRAMES_TX.load(Ordering::Relaxed),
        frames_rx: FRAMES_RX.load(Ordering::Relaxed),
        batches: BATCHES.load(Ordering::Relaxed),
        steal_latency_us: if samples == 0 {
            0.0
        } else {
            sum_ns as f64 / samples as f64 / 1_000.0
        },
        steal_samples: samples,
        io_threads: IO_THREADS.load(Ordering::Relaxed),
    }
}

/// Reactor threads ever spawned by this process.
pub fn io_threads_spawned() -> u64 {
    IO_THREADS.load(Ordering::Relaxed)
}

/// Reactor threads currently running (0 once every socket run returned).
pub fn io_threads_live() -> u64 {
    IO_THREADS_LIVE.load(Ordering::Relaxed)
}

/// Mailbox sender per *global* place id (`None` for remote places).
pub(crate) type Mailboxes<B> = Arc<Vec<Option<Sender<Msg<B>>>>>;
/// Per-rank slots for gathered result payloads (rank 0 only).
pub(crate) type ResultSlots = Arc<Mutex<Vec<Option<Vec<u8>>>>>;

/// One rank's handle on its reactor: per-peer write queues, the waker
/// that nudges the event loop after an enqueue, and the frame-buffer
/// pool every send encodes into. Shared by workers, service threads,
/// and the reactor itself; the sockets live inside the reactor only.
pub(crate) struct NetCore {
    /// Mesh write queue per peer rank (`None` for self / unconnected).
    pub(crate) mesh: Vec<Option<Arc<OutQueue>>>,
    /// Spoke → rank 0 control queue (`None` on rank 0).
    pub(crate) ctrl: Option<Arc<OutQueue>>,
    /// Rank 0 → spoke control queues (`None` slots on spokes; slot 0
    /// always `None`).
    pub(crate) ctrl_peers: Vec<Option<Arc<OutQueue>>>,
    /// Wakes the reactor out of `epoll_wait` after a queue push.
    pub(crate) waker: Waker,
    /// Recycled frame buffers: encode paths `get()`, the reactor
    /// `put_arc()`s once a frame is flushed and unretained.
    pub(crate) pool: Arc<BufferPool>,
    /// Set by teardown; tells the reactor to drain queues, half-close,
    /// read every peer to EOF, and exit.
    pub(crate) shutdown: AtomicBool,
    /// Outstanding steal round-trips: `(victim place, nonce)` → enqueue
    /// time, resolved when the matching Loot/refusal is dispatched.
    steal_marks: Mutex<HashMap<(u64, u64), Instant>>,
}

impl NetCore {
    pub(crate) fn new(ranks: usize, pool: Arc<BufferPool>) -> Self {
        Self {
            mesh: (0..ranks).map(|_| None).collect(),
            ctrl: None,
            ctrl_peers: (0..ranks).map(|_| None).collect(),
            waker: Waker::new().expect("socketpair for reactor waker"),
            pool,
            shutdown: AtomicBool::new(false),
            steal_marks: Mutex::new(HashMap::new()),
        }
    }

    /// Enqueue a control frame to rank 0 (spokes). `false` when the
    /// queue is gone or already closed — the fleet is tearing down.
    pub(crate) fn send_ctrl(&self, c: &Ctrl) -> bool {
        let Some(q) = &self.ctrl else { return false };
        let mut buf = self.pool.get();
        wire::encode_ctrl_frame_into(c, &mut buf);
        let ok = q.push(Arc::new(buf));
        if ok {
            self.waker.wake();
        }
        ok
    }

    /// Enqueue a control frame to spoke `rank` (rank 0 only).
    pub(crate) fn send_ctrl_to(&self, rank: usize, c: &Ctrl) -> bool {
        let Some(q) = self.ctrl_peers.get(rank).and_then(|q| q.as_ref()) else {
            return false;
        };
        let mut buf = self.pool.get();
        wire::encode_ctrl_frame_into(c, &mut buf);
        let ok = q.push(Arc::new(buf));
        if ok {
            self.waker.wake();
        }
        ok
    }
}

/// Drop every outstanding steal mark whose victim lives on `peer`: the
/// link (or the rank) is gone, so the marked round-trips can never
/// complete. A surviving mark would lie in wait for a later steal that
/// reuses the same `(victim, nonce)` key and pair it against the stale
/// enqueue time, skewing `steal_latency_us` — the latency books must
/// only ever see completed round-trips.
fn purge_peer_marks(marks: &Mutex<HashMap<(u64, u64), Instant>>, topo: &Topology, peer: usize) {
    lock_clean(marks).retain(|&(victim, _), _| topo.node_of(victim as usize) != peer);
}

/// One rank's armed telemetry plane (`--stats`): the worker gauge hub,
/// the sequence counter behind every outbound snapshot, and the bank
/// where rank 0 folds the fleet view. Shared by the worker threads, the
/// reactor's sample timer, and the teardown path.
pub(crate) struct StatsShared {
    rank: usize,
    interval: Duration,
    hub: MetricsHub,
    ledger: FleetLedger,
    start: Instant,
    seq: AtomicU64,
    /// Latest snapshot per rank. Only rank 0 receives remote snapshots;
    /// every rank banks its own final one so the single-rank degenerate
    /// case still yields a series.
    bank: StatsBank,
}

impl StatsShared {
    fn new(
        rank: usize,
        ranks: usize,
        workers: usize,
        interval: Duration,
        ledger: FleetLedger,
    ) -> Arc<Self> {
        Arc::new(Self {
            rank,
            interval,
            hub: MetricsHub::new(workers),
            ledger,
            start: Instant::now(),
            seq: AtomicU64::new(0),
            bank: StatsBank::new(ranks),
        })
    }

    /// Assemble this rank's snapshot: worker gauges from the hub plus
    /// the rank-level fields (credit pool, wire counters, out-queue
    /// depths).
    fn snapshot(&self, net: &NetCore, last: bool) -> StatsSnapshot {
        let mut s = self.hub.fold();
        s.rank = self.rank as u64;
        s.seq = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        s.elapsed_ms = self.start.elapsed().as_millis() as u64;
        s.credit_pool = self.ledger.pool_level();
        let (tx, rx) = wire_bytes();
        s.wire_tx = tx;
        s.wire_rx = rx;
        s.frames_tx = FRAMES_TX.load(Ordering::Relaxed);
        s.frames_rx = FRAMES_RX.load(Ordering::Relaxed);
        s.out_queue = net
            .mesh
            .iter()
            .flatten()
            .chain(net.ctrl.iter())
            .chain(net.ctrl_peers.iter().flatten())
            .map(|q| q.len() as u64)
            .sum();
        s.last = last;
        s
    }
}

/// Print one fleet-wide stats line (rank 0): a human summary plus the
/// `GLB-LIVE-STATS` marker the launcher captures into its report's
/// `"live_stats"` series (marker lines are filtered from echoed rank
/// output, so users see only the summary).
fn print_fleet_stats(
    fleet: &StatsSnapshot,
    heard: usize,
    ranks: usize,
    prev: &Option<StatsSnapshot>,
) {
    let p = prev.unwrap_or_default();
    let dt_ms = fleet.elapsed_ms.saturating_sub(p.elapsed_ms);
    let rate = |now: u64, then: u64| {
        if dt_ms == 0 {
            0.0
        } else {
            now.saturating_sub(then) as f64 * 1e3 / dt_ms as f64
        }
    };
    println!(
        "glb stats t={:.1}s ranks={heard}/{ranks} tasks={} ({:.0}/s) bag={} \
         steals={}out/{}in loot={}tx/{}rx starv={} credit={} wire={:.0}B/s \
         frames={:.0}/s outq={}",
        fleet.elapsed_ms as f64 / 1e3,
        fleet.items,
        rate(fleet.items, p.items),
        fleet.bag_depth,
        fleet.steals_out,
        fleet.steals_in,
        fleet.loot_sent,
        fleet.loot_recv,
        fleet.starvations,
        fleet.credit_pool,
        rate(fleet.wire_tx + fleet.wire_rx, p.wire_tx + p.wire_rx),
        rate(fleet.frames_tx + fleet.frames_rx, p.frames_tx + p.frames_rx),
        fleet.out_queue,
    );
    println!(
        "GLB-LIVE-STATS {{\"t_ms\":{},\"seq\":{},\"ranks_heard\":{heard},\"ranks\":{ranks},\
         \"tasks\":{},\"bag_depth\":{},\"steals_out\":{},\"steals_in\":{},\"loot_sent\":{},\
         \"loot_recv\":{},\"starvations\":{},\"credit_pool\":{},\"wire_tx\":{},\"wire_rx\":{},\
         \"frames_tx\":{},\"frames_rx\":{},\"out_queue\":{},\"last\":{}}}",
        fleet.elapsed_ms,
        fleet.seq,
        fleet.items,
        fleet.bag_depth,
        fleet.steals_out,
        fleet.steals_in,
        fleet.loot_sent,
        fleet.loot_recv,
        fleet.starvations,
        fleet.credit_pool,
        fleet.wire_tx,
        fleet.wire_rx,
        fleet.frames_tx,
        fleet.frames_rx,
        fleet.out_queue,
        fleet.last,
    );
}

/// The work-token ledger, as seen from one fleet process.
#[derive(Clone)]
pub(crate) enum FleetLedger {
    /// Single-rank fleet: the plain in-process counter.
    Local(Arc<AtomicLedger>),
    /// Mesh member: rank-local credit ledger (see module docs).
    Credit(Arc<CreditLedger>),
}

impl FleetLedger {
    /// Credit atoms currently pooled locally — the live-telemetry
    /// `credit_pool` gauge (the plain single-rank counter has no pool).
    fn pool_level(&self) -> u64 {
        match self {
            FleetLedger::Local(_) => 0,
            FleetLedger::Credit(l) => l.pool(),
        }
    }
}

impl Ledger for FleetLedger {
    fn incr(&self) {
        match self {
            FleetLedger::Local(l) => l.incr(),
            FleetLedger::Credit(l) => l.incr(),
        }
    }

    fn decr(&self) -> bool {
        match self {
            FleetLedger::Local(l) => l.decr(),
            FleetLedger::Credit(l) => l.decr(),
        }
    }

    fn value(&self) -> i64 {
        match self {
            FleetLedger::Local(l) => l.value(),
            FleetLedger::Credit(l) => l.value(),
        }
    }

    fn export_credit(&self) -> u64 {
        match self {
            FleetLedger::Local(l) => l.export_credit(),
            FleetLedger::Credit(l) => l.export_credit(),
        }
    }

    fn import_credit(&self, atoms: u64) {
        match self {
            FleetLedger::Local(l) => l.import_credit(atoms),
            FleetLedger::Credit(l) => l.import_credit(atoms),
        }
    }
}

/// A spoke's credit home: async deposits and the rare synchronous
/// replenish, both enqueued on the control queue (the reactor owns the
/// socket; grants come back through a channel the reactor feeds).
/// Panics when the control path is gone mid-run — a dead control link
/// loses termination credit, which is unrecoverable (the fleet could
/// never quiesce), and all credit traffic stops before teardown.
pub(crate) struct QueueHome {
    pub(crate) net: Arc<NetCore>,
    pub(crate) grants: Mutex<Receiver<u64>>,
    /// The job epoch stamped on every credit frame (0 for one-shot
    /// fleets; the resident service threads each job's epoch through).
    pub(crate) job: u64,
}

impl CreditHome for QueueHome {
    fn deposit(&self, atoms: u64) {
        if !self.net.send_ctrl(&Ctrl::Deposit { job: self.job, atoms }) {
            panic!("fleet control link lost (deposit)");
        }
        chaos::die_point(chaos::DURING_DEPOSIT);
    }

    fn replenish(&self, want: u64) -> u64 {
        // Hold the grant receiver across the request so concurrent
        // replenishes (one worker per node today, but cheap to keep
        // correct) pair each Grant with its Replenish.
        let rx = self.grants.lock().unwrap();
        if !self.net.send_ctrl(&Ctrl::Replenish { job: self.job, want }) {
            panic!("fleet control link lost (replenish)");
        }
        rx.recv().expect("fleet control link closed awaiting grant")
    }
}

/// Rank 0's credit home: the root lives in-process.
pub(crate) struct RootHome {
    pub(crate) root: Arc<CreditRoot>,
}

impl CreditHome for RootHome {
    fn deposit(&self, atoms: u64) {
        self.root.deposit(atoms);
    }

    fn replenish(&self, want: u64) -> u64 {
        self.root.mint(want)
    }
}

/// One retained loot send: the serialized stolen bag, kept until the
/// destination acknowledges having merged it (or dies, at which point
/// the bag is re-imported locally so its work is never lost).
struct RetainedLoot {
    /// 1-based send sequence number toward this peer.
    seq: u64,
    /// Credit atoms the message carried ([`Ledger::export_credit`]).
    credit: u64,
    /// The *wire frame* of the send (length prefix + route + message),
    /// sharing the pooled buffer the reactor flushes — retention costs
    /// a refcount, not a second serialization. Bytes keep the
    /// bookkeeping non-generic; decoded only on re-import, where the
    /// bag type is known.
    frame: Arc<Vec<u8>>,
}

/// This rank's outbound loot book for one peer. Mesh links and mailboxes
/// are FIFO, so the receiver's cumulative merged-bag count identifies
/// exactly which retained entries its banked result already covers.
#[derive(Default)]
struct PeerLedger {
    /// Set once the peer is known dead: entries drained, sends guarded.
    dead: bool,
    /// Loot bags sent to this peer (the `seq` counter).
    sent: u64,
    /// Credit atoms ever attached to loot for this peer.
    attached: u64,
    /// Unacknowledged sends, in `seq` order.
    entries: VecDeque<RetainedLoot>,
}

impl PeerLedger {
    /// The peer banked `upto` merged bags: drop the covered entries,
    /// recycling each frame buffer once the reactor has let go of it.
    fn prune(&mut self, upto: u64, pool: &BufferPool) {
        while self.entries.front().is_some_and(|e| e.seq <= upto) {
            let e = self.entries.pop_front().unwrap();
            pool.put_arc(e.frame);
        }
    }
}

/// The one steal request this rank's worker may have in flight, mirrored
/// outside the worker so a dead victim's never-coming response can be
/// synthesized as a refusal. Cleared by the mesh reader the moment the
/// real response is delivered, so a surviving record is always fresh.
struct PendingSteal {
    dest_rank: usize,
    victim: PlaceId,
    lifeline: bool,
    nonce: u64,
}

/// A latch the recovery path waits on: the reactor must drain a dead
/// peer's mesh link to EOF (delivering every frame the peer managed to
/// send) before the retention ledger is reconciled.
#[derive(Default)]
struct ReaderDone {
    done: Mutex<bool>,
    cv: Condvar,
}

impl ReaderDone {
    fn mark(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut d = self.done.lock().unwrap();
        while !*d {
            d = self.cv.wait(d).unwrap();
        }
    }
}

/// Everything a crash-tolerant rank tracks beyond the normal runtime:
/// the membership view, per-peer retention ledgers, inbound credit and
/// merge books, and the mirrored outstanding steal. Shared (non-generic)
/// across the worker thread, mesh readers, and the recovery thread.
///
/// The credit/merge books (`recv_credit`, `merged`) deliberately stay
/// `SeqCst`: a reconcile solves `granted − deposited + Σsent − Σreceived`
/// across *several* counters updated by different threads, and the
/// single total order is the cheapest way to keep those cross-variable
/// reads mutually consistent without a lock (`glb lint` flags any
/// attempt to relax them).
pub(crate) struct RankRecovery {
    rank: usize,
    membership: Arc<DynamicMembership>,
    ledgers: Vec<Mutex<PeerLedger>>,
    /// Credit atoms delivered *from* each peer, counted at the mesh
    /// reader (not at merge): a bag still sitting in the mailbox is
    /// already this rank's responsibility, and the reconcile books must
    /// say so or the root would reclaim its credit twice.
    recv_credit: Vec<AtomicU64>,
    /// Cross-rank loot bags merged per victim rank — the cumulative
    /// counts banked in every [`Ctrl::Ack`].
    merged: Vec<AtomicU64>,
    pending: Mutex<Option<PendingSteal>>,
    reader_done: Vec<ReaderDone>,
    /// Recycles acknowledged retention frames (shared with the reactor).
    pool: Arc<BufferPool>,
}

impl RankRecovery {
    fn new(
        rank: usize,
        ranks: usize,
        membership: Arc<DynamicMembership>,
        pool: Arc<BufferPool>,
    ) -> Arc<Self> {
        let rec = Arc::new(Self {
            rank,
            membership,
            ledgers: (0..ranks).map(|_| Mutex::new(PeerLedger::default())).collect(),
            recv_credit: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            merged: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            pending: Mutex::new(None),
            reader_done: (0..ranks).map(|_| ReaderDone::default()).collect(),
            pool,
        });
        rec.reader_done[rank].mark(); // no link to ourselves
        rec
    }

    /// Is `rank` still a member? (Cheap enough for the send path: one
    /// short mutex hold on the per-peer ledger.)
    fn peer_dead(&self, rank: usize) -> bool {
        self.ledgers[rank].lock().unwrap().dead
    }

    /// The peer acknowledged `upto` merged bags from us.
    fn prune(&self, peer: usize, upto: u64) {
        self.ledgers[peer].lock().unwrap().prune(upto, &self.pool);
    }

    /// Mark `dead` dead and take its unacknowledged entries. Returns the
    /// entries plus this rank's net reconcile books for the dead peer:
    /// `(sent, received)` credit, with the re-imported (returned) entries
    /// already subtracted from `sent`.
    fn drain(&self, dead: usize) -> (Vec<RetainedLoot>, u64, u64) {
        self.reader_done[dead].wait();
        let (entries, sent) = {
            let mut l = self.ledgers[dead].lock().unwrap();
            l.dead = true;
            let entries: Vec<RetainedLoot> = std::mem::take(&mut l.entries).into();
            let reimported: u64 = entries.iter().map(|e| e.credit).sum();
            (entries, l.attached - reimported)
        };
        let received = self.recv_credit[dead].load(Ordering::SeqCst);
        (entries, sent, received)
    }
}

/// The fleet start barrier, reactor-shaped: all ranks construct their
/// workers (holding their initial tokens and credit) before any rank
/// steals. Spokes enqueue [`Ctrl::Ready`] and wait for [`Ctrl::Go`];
/// rank 0's reactor counts the Readys, and rank 0's main thread sends
/// Go to every spoke once all have arrived *and* its own workers exist.
#[derive(Default)]
pub(crate) struct FleetGate {
    st: Mutex<GateSt>,
    cv: Condvar,
}

#[derive(Default)]
struct GateSt {
    ready: usize,
    go: bool,
    failed: bool,
}

impl FleetGate {
    /// Rank 0's reactor saw one spoke's `Ready`.
    fn ready_arrived(&self) {
        self.st.lock().unwrap().ready += 1;
        self.cv.notify_all();
    }

    /// Rank 0 blocks until `n` spokes are ready (forever if a spoke
    /// died pre-barrier — the launcher's fail-fast handles that, as it
    /// always has).
    fn wait_ready(&self, n: usize) {
        let mut st = self.st.lock().unwrap();
        while st.ready < n {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// A spoke's reactor saw `Go`.
    fn go(&self) {
        self.st.lock().unwrap().go = true;
        self.cv.notify_all();
    }

    /// The spoke's control link died before `Go`.
    fn fail(&self) {
        self.st.lock().unwrap().failed = true;
        self.cv.notify_all();
    }

    /// A spoke blocks for `Go`; `false` means the control link died
    /// first.
    fn wait_go(&self) -> bool {
        let mut st = self.st.lock().unwrap();
        while !st.go && !st.failed {
            st = self.cv.wait(st).unwrap();
        }
        st.go
    }
}

/// The per-process message fabric: local mailboxes for this rank's
/// places, one direct mesh write queue per remote rank (the reactor
/// flushes them).
pub(crate) struct SocketTransport<B> {
    pub(crate) rank: usize,
    pub(crate) topo: Topology,
    pub(crate) p: usize,
    pub(crate) local: Mailboxes<B>,
    pub(crate) net: Arc<NetCore>,
    /// Crash-tolerance books; `None` keeps the fail-fast send path.
    pub(crate) recovery: Option<Arc<RankRecovery>>,
    /// The job epoch stamped on every outbound data frame (0 for
    /// one-shot fleets).
    pub(crate) job: u64,
}

impl<B> Clone for SocketTransport<B> {
    fn clone(&self) -> Self {
        Self {
            rank: self.rank,
            topo: self.topo,
            p: self.p,
            local: self.local.clone(),
            net: self.net.clone(),
            recovery: self.recovery.clone(),
            job: self.job,
        }
    }
}

impl<B: WireCodec> SocketTransport<B> {
    /// Send `msg` to place `to` — the local mailbox, or the destination
    /// rank's own mesh link (never a relay). Best-effort on I/O failure:
    /// writes only fail once the peer is gone, at which point the run is
    /// already lost, exactly like the thread runtime's mailbox sends.
    fn send(&self, to: PlaceId, msg: Msg<B>) {
        let dest_rank = self.topo.node_of(to);
        if dest_rank == self.rank {
            self.deliver_local(to, msg);
            return;
        }
        match &self.recovery {
            Some(rec) => self.send_guarded(&rec.clone(), dest_rank, to, msg),
            None => {
                let is_steal = matches!(msg, Msg::Steal { .. });
                self.send_wire(dest_rank, to, &msg);
                if is_steal {
                    chaos::die_point(chaos::MID_STEAL);
                }
            }
        }
    }

    fn deliver_local(&self, to: PlaceId, msg: Msg<B>) {
        if let Some(tx) = &self.local[to] {
            let _ = tx.send(msg);
        }
    }

    /// Encode `msg` into a pooled buffer and enqueue it toward
    /// `dest_rank`. Best-effort like the old blocking write: frames to
    /// a closed queue or over the length cap are silently dropped (the
    /// run is already lost / the frame was never writable).
    fn send_wire(&self, dest_rank: usize, to: PlaceId, msg: &Msg<B>) {
        let Some(q) = self.net.mesh.get(dest_rank).and_then(|q| q.as_ref()) else {
            return;
        };
        let mut buf = self.net.pool.get();
        let body_len = wire::encode_data_frame_into(to, self.job, msg, &mut buf);
        if body_len > wire::MAX_FRAME_BYTES {
            self.net.pool.put(buf);
            return;
        }
        if let Msg::Steal { nonce, .. } = msg {
            lock_clean(&self.net.steal_marks).insert((to as u64, *nonce), Instant::now());
        }
        if q.push(Arc::new(buf)) {
            self.net.waker.wake();
        }
    }

    /// The crash-tolerant send path. Loot bags to live peers are
    /// retained (serialized) until acknowledged; traffic to a dead peer
    /// is answered on its behalf — a steal gets an instant refusal, a
    /// loot bag is re-imported locally (with its credit), refusals and
    /// `Terminate` evaporate.
    fn send_guarded(&self, rec: &Arc<RankRecovery>, dest_rank: usize, to: PlaceId, msg: Msg<B>) {
        // Tolerant fleets run one worker per node, so this rank's only
        // place doubles as its node representative.
        let me = self.topo.representative(self.rank);
        match msg {
            Msg::Steal { thief, lifeline, nonce } => {
                let guard = rec.ledgers[dest_rank].lock().unwrap();
                if guard.dead {
                    drop(guard);
                    self.deliver_local(
                        me,
                        Msg::Loot {
                            victim: to,
                            bag: None,
                            lifeline,
                            nonce: Some(nonce),
                            credit: 0,
                        },
                    );
                    return;
                }
                // Mirror the outstanding request while the ledger lock
                // orders us against the drain: either the drain sees this
                // record, or we saw `dead` above — never neither.
                *rec.pending.lock().unwrap() =
                    Some(PendingSteal { dest_rank, victim: to, lifeline, nonce });
                self.send_wire(dest_rank, to, &Msg::Steal { thief, lifeline, nonce });
                drop(guard);
                chaos::die_point(chaos::MID_STEAL);
            }
            Msg::Loot { victim, bag: Some(bag), lifeline, nonce, credit } => {
                let mut guard = rec.ledgers[dest_rank].lock().unwrap();
                if guard.dead {
                    drop(guard);
                    self.deliver_local(
                        me,
                        Msg::Loot {
                            victim: me,
                            bag: Some(bag),
                            lifeline: false,
                            nonce: None,
                            credit,
                        },
                    );
                    return;
                }
                // One encode serves both the wire and the retention
                // ledger: the entry keeps an `Arc` on the very frame
                // the reactor flushes. Entry is pushed under the ledger
                // lock so a concurrent drain either takes it or we saw
                // `dead` above — never neither.
                let msg = Msg::Loot { victim, bag: Some(bag), lifeline, nonce, credit };
                let mut buf = self.net.pool.get();
                let body_len = wire::encode_data_frame_into(to, self.job, &msg, &mut buf);
                let frame = Arc::new(buf);
                guard.sent += 1;
                guard.attached += credit;
                let seq = guard.sent;
                guard.entries.push_back(RetainedLoot { seq, credit, frame: frame.clone() });
                if body_len <= wire::MAX_FRAME_BYTES {
                    if let Some(q) = self.net.mesh.get(dest_rank).and_then(|q| q.as_ref()) {
                        if q.push(frame) {
                            self.net.waker.wake();
                        }
                    }
                }
                drop(guard);
            }
            Msg::Loot { bag: None, .. } | Msg::Terminate => {
                if !rec.peer_dead(dest_rank) {
                    self.send_wire(dest_rank, to, &msg);
                }
            }
        }
    }

    /// A peer died: pull back every unacknowledged loot bag this rank
    /// sent it (re-delivering each to our own mailbox with its credit),
    /// synthesize the refusal for a steal still outstanding toward it,
    /// and return the `(sent, received)` credit books for the
    /// [`Ctrl::Reconcile`] — `sent` net of the re-imported entries.
    fn recover_dead_peer(&self, rec: &Arc<RankRecovery>, dead: usize) -> (u64, u64) {
        let me = self.topo.representative(self.rank);
        let (entries, sent, received) = rec.drain(dead);
        for e in entries {
            // The entry is the full wire frame: skip the length prefix,
            // decode route + message, and lift the bag back out.
            let decoded = wire::decode_data_frame_body::<B>(&e.frame[wire::FRAME_LEN_BYTES..]);
            let bag = match decoded {
                Ok((_, _, Msg::Loot { bag: Some(b), .. })) => b,
                Ok(_) => {
                    eprintln!("glb: retained frame for dead rank {dead} is not a loot bag");
                    std::process::exit(1);
                }
                Err(err) => {
                    eprintln!("glb: retained bag for dead rank {dead} is corrupt: {err}");
                    std::process::exit(1);
                }
            };
            self.deliver_local(
                me,
                Msg::Loot {
                    victim: me,
                    bag: Some(bag),
                    lifeline: false,
                    nonce: None,
                    credit: e.credit,
                },
            );
            rec.pool.put_arc(e.frame);
        }
        let pending = {
            let mut p = rec.pending.lock().unwrap();
            if p.as_ref().is_some_and(|ps| ps.dest_rank == dead) {
                p.take()
            } else {
                None
            }
        };
        if let Some(ps) = pending {
            self.deliver_local(
                me,
                Msg::Loot {
                    victim: ps.victim,
                    bag: None,
                    lifeline: ps.lifeline,
                    nonce: Some(ps.nonce),
                    credit: 0,
                },
            );
        }
        (sent, received)
    }

    /// The worker-observed quiescence broadcast — only reachable in
    /// single-rank fleets (mesh fleets detect at the credit root).
    fn broadcast_terminate(&self, me: PlaceId) {
        for i in (0..self.p).filter(|&i| i != me) {
            self.send(i, Msg::Terminate);
        }
    }

    /// The credit root observed global quiescence: tell every place in
    /// the fleet (rank 0's own included) to finish.
    fn terminate_fleet(&self) {
        for i in 0..self.p {
            self.send(i, Msg::Terminate);
        }
    }
}

/// Carry out a worker's requested effects.
pub(crate) fn pump<B: WireCodec>(
    me: PlaceId,
    fx: &mut Vec<Effect<B>>,
    transport: &SocketTransport<B>,
) {
    for e in fx.drain(..) {
        match e {
            Effect::Send { to, msg } => {
                debug_assert_ne!(to, me, "no self-sends in the protocol");
                transport.send(to, msg);
            }
            Effect::Quiescent => transport.broadcast_terminate(me),
        }
    }
}

/// The crash-tolerance hooks one worker thread carries.
pub(crate) struct TolerantWorker {
    rec: Arc<RankRecovery>,
    ack: AckOut,
}

/// Where a worker's idle-point acks go.
enum AckOut {
    /// A spoke acks on its own control queue: a result snapshot plus the
    /// cumulative per-victim merged-bag counts (the victims prune their
    /// retention ledgers; the root banks the result for the gather in
    /// case this rank dies later).
    Spoke(Arc<NetCore>),
    /// Rank 0 acks straight to each victim spoke's control queue — merge
    /// counts only, since the root's own death is always fatal and its
    /// partial result is never needed from a bank.
    Root(Arc<NetCore>),
}

/// Count a cross-rank loot bag against its victim's rank *before* the
/// worker merges it: these cumulative counts are what the next ack
/// banks, so they must never run ahead of the banked result snapshot —
/// and they cannot, because the snapshot is taken after the merge.
fn note_merge<B: WireCodec>(
    tol: &Option<TolerantWorker>,
    transport: &SocketTransport<B>,
    my_rank: usize,
    msg: &Msg<B>,
) {
    let Some(t) = tol else { return };
    if let Msg::Loot { victim, bag: Some(_), .. } = msg {
        let vr = transport.topo.node_of(*victim);
        if vr != my_rank {
            t.rec.merged[vr].fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Bank an idle-point checkpoint. Called at every Working-exit edge,
/// where the local bag is empty — so the result snapshot covers exactly
/// the acked merges, and a death any time before the *next* merge loses
/// nothing: senders re-import everything past these counts.
fn emit_ack<Q, P>(
    worker: &Worker<Q, FleetLedger>,
    tol: &Option<TolerantWorker>,
    plan: P,
    my_rank: usize,
    acked_upto: &mut [u64],
) where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    P: ResultPlan<Q::Result>,
{
    let Some(t) = tol else { return };
    match &t.ack {
        AckOut::Spoke(net) => {
            let mut acked = Vec::new();
            for (r, m) in t.rec.merged.iter().enumerate() {
                let m = m.load(Ordering::SeqCst);
                if m > 0 && r != my_rank {
                    acked.push((r as u64, m));
                }
            }
            let result = plan.encode(&worker.queue().result());
            // Best-effort: a refused push means teardown already closed
            // the queue (the root no longer needs acks) — and a root
            // death surfaces through the reactor, not here.
            net.send_ctrl(&Ctrl::Ack { rank: my_rank as u64, result, acked });
        }
        AckOut::Root(net) => {
            for (r, m) in t.rec.merged.iter().enumerate() {
                let m = m.load(Ordering::SeqCst);
                if m > acked_upto[r] {
                    acked_upto[r] = m;
                    net.send_ctrl_to(
                        r,
                        &Ctrl::Ack { rank: 0, result: Vec::new(), acked: vec![(r as u64, m)] },
                    );
                }
            }
        }
    }
}

/// How often an adaptive worker feeds its gauges to the controller —
/// coarse enough to stay off the hot path, fine enough that the dwell
/// (3 windows by default) reacts within ~100ms of persistent starvation.
const ADAPT_OBS_INTERVAL: Duration = Duration::from_millis(20);

/// Per-place worker thread body (mirror of the thread runtime's
/// `place_main`, driving the same engine over the socket fabric).
pub(crate) fn socket_place_main<Q, P>(
    mut worker: Worker<Q, FleetLedger>,
    rx: Receiver<Msg<Q::Bag>>,
    transport: SocketTransport<Q::Bag>,
    tol: Option<TolerantWorker>,
    plan: P,
    stats: Option<(Arc<StatsShared>, usize)>,
    adapt: bool,
) -> (Q::Result, crate::glb::WorkerStats)
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    P: ResultPlan<Q::Result>,
{
    let me = worker.id();
    let my_rank = transport.rank;
    let mut fx: Vec<Effect<Q::Bag>> = Vec::with_capacity(8);
    let mut acked_upto: Vec<u64> =
        tol.as_ref().map(|t| vec![0; t.rec.merged.len()]).unwrap_or_default();
    let mut seen_epoch = 0u64;
    let mut tuner = adapt.then(|| AdaptiveController::new(AdaptiveConfig::default()));
    let mut last_obs = Instant::now();
    loop {
        // Publish this worker's gauges (a handful of relaxed stores; the
        // reactor's stats timer samples them). The loop reaches here with
        // `Phase::Done` too, so the slot's terminal values equal the
        // RunLog totals exactly by the time the final snapshot is taken.
        if let Some((shared, slot)) = &stats {
            shared.hub.publish(*slot, worker.queue().bag_size(), worker.stats());
        }
        // Closed-loop tuning: feed the controller a throttled observation
        // and apply its recommendation at the next protocol-safe moment
        // (an unapplied recommendation simply repeats next window).
        if let Some(t) = &mut tuner {
            if last_obs.elapsed() >= ADAPT_OBS_INTERVAL {
                last_obs = Instant::now();
                let s = worker.stats();
                let sample = ControllerSample {
                    items: s.items_processed,
                    starvations: s.starvations,
                    bag_depth: worker.queue().bag_size() as u64,
                };
                if let Some(r) = t.observe(sample, worker.params().n) {
                    if worker.try_retune(r.l, r.n) {
                        t.confirm();
                    }
                }
            }
        }
        // Safe-point re-knit: only between protocol episodes (Working /
        // Idle — never with a steal outstanding, whose response still
        // references the old victim set). A Wait* phase defers to the
        // next episode; liveness holds because a dead victim's response
        // is synthesized by the recovery path.
        if let Some(t) = &tol {
            if matches!(worker.phase(), Phase::Working | Phase::Idle)
                && t.rec.membership.epoch() != seen_epoch
            {
                let view = t.rec.membership.view();
                seen_epoch = view.epoch;
                worker.rewire(&view.members());
            }
        }
        match worker.phase() {
            Phase::Working => {
                let t0 = Instant::now();
                while let Ok(m) = rx.try_recv() {
                    note_merge(&tol, &transport, my_rank, &m);
                    worker.on_msg(m, &mut fx);
                    pump(me, &mut fx, &transport);
                }
                worker.stats_mut().distribute_ns += t0.elapsed().as_nanos() as u64;
                if worker.phase() != Phase::Working {
                    emit_ack(&worker, &tol, plan, my_rank, &mut acked_upto);
                    continue;
                }
                let t0 = Instant::now();
                worker.step(&mut fx);
                worker.stats_mut().process_ns += t0.elapsed().as_nanos() as u64;
                if worker.phase() != Phase::Working {
                    // Bank the exit-point snapshot *before* the pending
                    // steal below leaves this rank: a mid-steal death
                    // then loses only work that senders still retain.
                    emit_ack(&worker, &tol, plan, my_rank, &mut acked_upto);
                }
                pump(me, &mut fx, &transport);
            }
            Phase::WaitRandom { .. } | Phase::WaitLifeline { .. } | Phase::Idle => {
                if worker.phase() == Phase::Idle {
                    chaos::die_point(chaos::WHILE_IDLE);
                }
                let t0 = Instant::now();
                let m = rx.recv().expect("mailbox closed while waiting");
                worker.stats_mut().wait_ns += t0.elapsed().as_nanos() as u64;
                let t0 = Instant::now();
                note_merge(&tol, &transport, my_rank, &m);
                worker.on_msg(m, &mut fx);
                pump(me, &mut fx, &transport);
                worker.stats_mut().distribute_ns += t0.elapsed().as_nanos() as u64;
            }
            Phase::Done => break,
        }
    }
    let (queue, stats) = worker.into_parts();
    (queue.result(), stats)
}

/// Which fleet socket a reactor connection is.
#[derive(Clone, Copy)]
pub(crate) enum ConnKind {
    /// Mesh data link to `peer`.
    Mesh { peer: usize },
    /// Rank 0's control link to spoke `peer`.
    CtrlRoot { peer: usize },
    /// A spoke's control link to rank 0.
    CtrlSpoke,
}

/// One socket inside the reactor: the stream, its staged read buffer,
/// and its write queue.
pub(crate) struct ReactorConn {
    pub(crate) stream: TcpStream,
    pub(crate) kind: ConnKind,
    pub(crate) asm: FrameAssembler,
    pub(crate) out: Arc<OutQueue>,
    /// `EPOLLOUT` currently armed (the last flush hit `WouldBlock`).
    out_armed: bool,
    /// Peer EOF / error / protocol violation: reads are over.
    read_done: bool,
    /// Write side shut down (queue drained after close, or send error).
    wr_closed: bool,
    /// The fd left the poller (both directions finished).
    deregistered: bool,
    /// `CtrlRoot` only: the spoke's result arrived, so a later EOF is a
    /// clean goodbye rather than a death.
    saw_result: bool,
}

impl ReactorConn {
    fn new(stream: TcpStream, kind: ConnKind, out: Arc<OutQueue>) -> Self {
        Self::resume(stream, kind, FrameAssembler::new(wire::MAX_FRAME_BYTES), out)
    }

    /// Rebuild a connection around a stream retained across jobs by the
    /// resident service, carrying its staged read buffer (a frame may
    /// straddle the job boundary) into the next job's reactor.
    pub(crate) fn resume(
        stream: TcpStream,
        kind: ConnKind,
        asm: FrameAssembler,
        out: Arc<OutQueue>,
    ) -> Self {
        Self {
            stream,
            kind,
            asm,
            out,
            out_armed: false,
            read_done: false,
            wr_closed: false,
            deregistered: false,
            saw_result: false,
        }
    }
}

/// Rank 0's crash-tolerance handles inside the reactor. The channel
/// senders live only here, so the coordinator's `death_rx` disconnects
/// — and its thread exits — exactly when the reactor does.
pub(crate) struct RootReactorTol {
    shared: Arc<RootTolerant>,
    death_tx: Sender<usize>,
    reconcile_tx: Sender<(usize, u64, u64)>,
}

/// The reactor's rank-specific control-plane duties.
pub(crate) enum ReactorRole {
    /// Rank 0: inline credit root, result slots, barrier bookkeeping.
    Root {
        root: Arc<CreditRoot>,
        results: ResultSlots,
        gate: Arc<FleetGate>,
        tol: Option<RootReactorTol>,
    },
    /// A spoke: route grants and `Go` to the main thread, deaths to the
    /// recovery thread.
    Spoke {
        gate: Arc<FleetGate>,
        /// `Option` so a dead control link can drop the sender — a
        /// worker blocked in `replenish` then panics instead of hanging.
        grant_tx: Option<Sender<u64>>,
        tolerant: bool,
        /// Tolerant spokes only: feeds the `glb-recovery-{rank}` thread.
        leave_tx: Option<Sender<usize>>,
    },
}

/// A frame lifted off a connection, owned (so the staged buffer borrow
/// ends before any dispatch side effect).
enum Parsed<B> {
    Data(PlaceId, u64, Msg<B>),
    /// A resident fleet's end-of-job fence on a mesh link (see
    /// [`wire::encode_fence_frame_into`]), carrying its job epoch.
    Fence(u64),
    Ctrl(Ctrl),
    /// Undecodable: protocol violation, drop the link's read side.
    Bad,
}

/// The reactor's live-telemetry duties (`--stats`): when the next
/// sample is due, how many ranks the fleet has (for the `heard/ranks`
/// display), and the previously printed fleet sample (rank 0 derives
/// rates from consecutive cumulative samples).
pub(crate) struct ReactorStats {
    shared: Arc<StatsShared>,
    next: Instant,
    ranks: usize,
    prev: Option<StatsSnapshot>,
}

/// Poller token for the waker's read end (connections use their index).
const WAKE_TOKEN: u64 = u64::MAX;

/// Decrements [`IO_THREADS_LIVE`] when the reactor exits, panic-safe.
struct IoLiveGuard;

impl Drop for IoLiveGuard {
    fn drop(&mut self) {
        // Relaxed: spawn accounting only — readers observe it after the
        // reactor thread is joined, and the join edge already orders the
        // write (see IO_THREADS in the lint allowlist).
        IO_THREADS_LIVE.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The per-rank I/O event loop (`glb-io-{rank}`): owns every
/// post-bootstrap socket, decodes inbound frames from staged per-peer
/// buffers straight into mailboxes / control handling, and flushes the
/// per-peer write queues in `writev` batches. Never blocks on anything
/// but the poller: blocking recovery work is handed to dedicated
/// threads over channels.
pub(crate) struct Reactor<B> {
    pub(crate) poller: Poller,
    pub(crate) conns: Vec<ReactorConn>,
    pub(crate) core: Arc<NetCore>,
    pub(crate) my_rank: usize,
    pub(crate) topo: Topology,
    pub(crate) local: Mailboxes<B>,
    pub(crate) recovery: Option<Arc<RankRecovery>>,
    pub(crate) role: ReactorRole,
    /// Armed by `--stats`: the periodic sample/ship/print timer.
    pub(crate) stats: Option<ReactorStats>,
    /// The job epoch this reactor serves: inbound frames stamped with a
    /// different epoch are dropped (and counted). One-shot fleets run
    /// everything as job 0.
    pub(crate) job: u64,
    /// `Some` puts the reactor in resident mode ([`Reactor::run_resident`]):
    /// links are kept open across jobs and the end of a job is marked by
    /// epoch fences instead of EOFs.
    pub(crate) resident: Option<ResidentReactor>,
}

/// The resident-mode bookkeeping of a per-job reactor (see
/// [`Reactor::run_resident`]).
pub(crate) struct ResidentReactor {
    /// Per-rank: this job's fence arrived on the mesh link from that
    /// peer (self and unconnected slots count as already fenced).
    fences: Vec<bool>,
    /// Control frames that belong to the *next* job (a `Submit` or
    /// `Shutdown` the root sent while our current job was still
    /// draining), handed back to the service loop at exit.
    carryover: Vec<Ctrl>,
}

impl ResidentReactor {
    pub(crate) fn new(ranks: usize) -> Self {
        Self { fences: vec![false; ranks], carryover: Vec::new() }
    }
}

/// What a resident reactor hands back to the service loop when its job
/// ends: every fleet socket (with staged read bytes intact) for the
/// next job's reactor, plus any next-job control frames that arrived
/// early.
pub(crate) struct ResidentExit {
    pub(crate) conns: Vec<ReactorConn>,
    pub(crate) carryover: Vec<Ctrl>,
}

impl<B> Reactor<B>
where
    B: WireCodec + Send + 'static,
{
    /// One-time poller registration for the waker and every fleet
    /// socket. Split out of [`Reactor::run`] so the event loop proper
    /// stays free of panicking calls (the hot-path lint walks `run`):
    /// a failure here is a bootstrap error, reported once and fatal.
    fn arm(&mut self) -> io::Result<()> {
        self.poller.add(self.core.waker.rx_fd(), WAKE_TOKEN, true, false)?;
        for i in 0..self.conns.len() {
            let c = &mut self.conns[i];
            c.stream.set_nonblocking(true)?;
            self.poller.add(c.stream.as_raw_fd(), i as u64, true, false)?;
        }
        Ok(())
    }

    fn run(mut self) {
        // A rank whose reactor cannot register (or later poll) its
        // sockets can never hear the fleet again; fail the process fast
        // — the launcher's watchdog turns that into a clean fleet abort
        // — instead of panicking this thread and hanging the join.
        if let Err(e) = self.arm() {
            eprintln!("glb: rank {}: reactor setup failed: {e}", self.my_rank);
            std::process::exit(1);
        }
        let mut events: Vec<Event> = Vec::new();
        loop {
            // Teardown: close write queues so they drain and half-close.
            // A root→spoke control queue waits for that spoke's own EOF
            // first — its grants and result collection must outlive our
            // decision to shut down, and an earlier close could sever a
            // spoke that has not yet entered teardown itself (tolerant
            // spokes treat an unexpected control EOF as fatal).
            // Acquire pairs with teardown's Release store: everything
            // enqueued before the flag (final result/stats frames) is
            // visible once the reactor observes the shutdown.
            let shutdown = self.core.shutdown.load(Ordering::Acquire);
            if shutdown {
                for c in &self.conns {
                    match c.kind {
                        ConnKind::CtrlRoot { .. } if !c.read_done => {}
                        _ => c.out.close(),
                    }
                }
            }
            for i in 0..self.conns.len() {
                self.flush_one(i);
            }
            if shutdown && self.conns.iter().all(|c| c.read_done && c.wr_closed) {
                break;
            }
            if let Err(e) = self.poller.wait(&mut events, self.stats_timeout_ms()) {
                eprintln!("glb: rank {}: reactor poll failed: {e}", self.my_rank);
                std::process::exit(1);
            }
            self.sample_stats_if_due();
            for ev in events.iter().copied() {
                if ev.token == WAKE_TOKEN {
                    self.core.waker.drain();
                } else if ev.readable && !self.conns[ev.token as usize].read_done {
                    self.read_ready(ev.token as usize);
                }
            }
        }
        // Teardown: any surviving steal mark belongs to a round-trip the
        // fleet tore down underneath — it must be discarded, never
        // sampled (the latency books count completed round-trips only).
        lock_clean(&self.core.steal_marks).clear();
    }

    /// The resident-fleet variant of [`Reactor::run`]: drive one job to
    /// completion *without* ever closing a fleet socket, then hand every
    /// stream back for the next job.
    ///
    /// End-of-job differs from one-shot teardown in exactly one way: no
    /// link is half-closed and no EOF is expected. Instead, when this
    /// rank's workers are done (the shutdown flag flips) the reactor
    /// enqueues one epoch fence behind everything already queued on each
    /// mesh link; FIFO delivery means a peer that has seen our fence has
    /// seen every frame our job sent it. The loop exits once the flag is
    /// set, our fences are out and fully flushed, every mesh peer's
    /// fence arrived, every spoke's result arrived (root only), and all
    /// write queues are empty. An EOF on any link mid-service means a
    /// rank died — always fatal, as for a one-shot root.
    pub(crate) fn run_resident(mut self) -> ResidentExit {
        if let Err(e) = self.arm() {
            eprintln!("glb: rank {}: reactor setup failed: {e}", self.my_rank);
            std::process::exit(1);
        }
        let mut events: Vec<Event> = Vec::new();
        let mut fences_sent = false;
        loop {
            let shutdown = self.core.shutdown.load(Ordering::Acquire);
            if shutdown && !fences_sent {
                fences_sent = true;
                for c in &self.conns {
                    if let ConnKind::Mesh { .. } = c.kind {
                        let mut buf = self.core.pool.get();
                        wire::encode_fence_frame_into(self.job, &mut buf);
                        c.out.push(Arc::new(buf));
                    }
                }
            }
            for i in 0..self.conns.len() {
                self.flush_one(i);
            }
            if self.conns.iter().any(|c| c.read_done || c.wr_closed) {
                eprintln!("glb: rank {}: lost a fleet link mid-service", self.my_rank);
                std::process::exit(1);
            }
            if shutdown && fences_sent && self.resident_quiet() {
                break;
            }
            if let Err(e) = self.poller.wait(&mut events, -1) {
                eprintln!("glb: rank {}: reactor poll failed: {e}", self.my_rank);
                std::process::exit(1);
            }
            for ev in events.iter().copied() {
                if ev.token == WAKE_TOKEN {
                    self.core.waker.drain();
                } else if ev.readable && !self.conns[ev.token as usize].read_done {
                    self.read_ready(ev.token as usize);
                }
            }
        }
        // Same mark hygiene as one-shot teardown; a fresh NetCore serves
        // the next job, but the latency books are process-wide.
        lock_clean(&self.core.steal_marks).clear();
        for c in &self.conns {
            let _ = self.poller.remove(c.stream.as_raw_fd());
        }
        let carryover = match self.resident.take() {
            Some(res) => res.carryover,
            None => Vec::new(),
        };
        ResidentExit { conns: self.conns, carryover }
    }

    /// Resident end-of-job condition beyond the shutdown flag and our
    /// own fences being enqueued: every peer fence and spoke result is
    /// in, and every write queue is fully on the wire
    /// ([`OutQueue::flush`] pops a frame only once its last byte is
    /// written, so an empty, unarmed queue has nothing in flight).
    fn resident_quiet(&self) -> bool {
        let Some(res) = &self.resident else { return false };
        let fenced = self.conns.iter().all(|c| match c.kind {
            ConnKind::Mesh { peer } => res.fences.get(peer).copied().unwrap_or(true),
            _ => true,
        });
        let results_in = self.conns.iter().all(|c| match c.kind {
            ConnKind::CtrlRoot { .. } => c.saw_result,
            _ => true,
        });
        let flushed = self.conns.iter().all(|c| c.out.is_empty() && !c.out_armed);
        fenced && results_in && flushed
    }

    /// `epoll_wait` timeout: indefinite without `--stats`, else the time
    /// to the next sample tick (floored at 1ms so a due tick never
    /// converts the event loop into a busy spin).
    fn stats_timeout_ms(&self) -> i32 {
        match &self.stats {
            None => -1,
            Some(st) => {
                let until = st.next.saturating_duration_since(Instant::now());
                (until.as_millis() as i64).clamp(1, i32::MAX as i64) as i32
            }
        }
    }

    /// Fire the stats timer when due: sample this rank's gauges; rank 0
    /// banks its own snapshot and prints the fleet view, spokes ship
    /// theirs to rank 0 on the control queue. Advisory either way — a
    /// push refused during teardown loses nothing, because the exact
    /// final snapshot rides the teardown path instead.
    fn sample_stats_if_due(&mut self) {
        let Some(st) = &mut self.stats else { return };
        let now = Instant::now();
        if now < st.next {
            return;
        }
        while st.next <= now {
            st.next += st.shared.interval;
        }
        let snap = st.shared.snapshot(&self.core, false);
        if self.my_rank == 0 {
            st.shared.bank.bank(snap);
            let (fleet, heard) = st.shared.bank.fleet();
            print_fleet_stats(&fleet, heard, st.ranks, &st.prev);
            st.prev = Some(fleet);
        } else {
            self.core.send_ctrl(&Ctrl::Stats(snap));
        }
    }

    /// Flush one connection's write queue; arm/disarm `EPOLLOUT` around
    /// socket backpressure, half-close once a closed queue drains, and
    /// fold the flush outcome into the process-wide wire counters.
    fn flush_one(&mut self, i: usize) {
        if self.conns[i].wr_closed {
            return;
        }
        let fd = self.conns[i].stream.as_raw_fd();
        match self.conns[i].out.flush(fd, &self.core.pool) {
            Ok(out) => {
                WIRE_TX_BYTES.fetch_add(out.bytes, Ordering::Relaxed);
                FRAMES_TX.fetch_add(out.frames_done, Ordering::Relaxed);
                BATCHES.fetch_add(out.batches, Ordering::Relaxed);
                let mut touched = false;
                if out.blocked != self.conns[i].out_armed {
                    self.conns[i].out_armed = out.blocked;
                    touched = true;
                }
                if out.drained {
                    let _ = self.conns[i].stream.shutdown(Shutdown::Write);
                    self.conns[i].wr_closed = true;
                    self.conns[i].out_armed = false;
                    touched = true;
                }
                if touched {
                    self.update_interest(i);
                }
            }
            Err(_) => {
                // Peer gone mid-run: abandon what's queued (the old
                // blocking writer ignored these errors too — recovery,
                // if any, rides the retention ledgers).
                self.conns[i].out.close();
                self.conns[i].wr_closed = true;
                self.conns[i].out_armed = false;
                self.update_interest(i);
            }
        }
    }

    /// Drain a readable socket into its staged buffer and dispatch every
    /// complete frame.
    fn read_ready(&mut self, i: usize) {
        loop {
            let res = {
                let c = &mut self.conns[i];
                let space = c.asm.read_space(16 * 1024);
                c.stream.read(space)
            };
            match res {
                Ok(0) => {
                    self.close_read(i);
                    return;
                }
                Ok(n) => {
                    self.conns[i].asm.commit(n);
                    if !self.drain_frames(i) {
                        self.close_read(i);
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_read(i);
                    return;
                }
            }
        }
    }

    /// Dispatch every complete frame staged on connection `i`; `false`
    /// on a protocol violation (undecodable or misrouted frame), which
    /// drops the link's read side like the old per-link readers did.
    fn drain_frames(&mut self, i: usize) -> bool {
        let kind = self.conns[i].kind;
        loop {
            let parsed: Parsed<B> = {
                let c = &mut self.conns[i];
                match c.asm.next_frame() {
                    Ok(None) => return true,
                    Err(_) => return false, // oversized length prefix
                    Ok(Some(body)) => {
                        WIRE_RX_BYTES.fetch_add(
                            (body.len() + wire::FRAME_LEN_BYTES) as u64,
                            Ordering::Relaxed,
                        );
                        FRAMES_RX.fetch_add(1, Ordering::Relaxed);
                        match kind {
                            ConnKind::Mesh { .. } => match wire::fence_job(body) {
                                Ok(Some(job)) => Parsed::Fence(job),
                                Ok(None) => match wire::decode_data_frame_body::<B>(body) {
                                    Ok((to, job, msg)) => Parsed::Data(to, job, msg),
                                    Err(_) => Parsed::Bad,
                                },
                                Err(_) => Parsed::Bad,
                            },
                            _ => match Ctrl::decode(body) {
                                Ok(c) => Parsed::Ctrl(c),
                                Err(_) => Parsed::Bad,
                            },
                        }
                    }
                }
            };
            let ok = match (parsed, kind) {
                (Parsed::Bad, _) => false,
                (Parsed::Data(to, job, msg), ConnKind::Mesh { peer }) => {
                    self.on_mesh_msg(peer, to, job, msg)
                }
                (Parsed::Fence(job), ConnKind::Mesh { peer }) => self.on_fence(peer, job),
                (Parsed::Ctrl(c), ConnKind::CtrlRoot { peer }) => self.on_root_ctrl(i, peer, c),
                (Parsed::Ctrl(c), ConnKind::CtrlSpoke) => self.on_spoke_ctrl(c),
                _ => false,
            };
            if !ok {
                return false;
            }
        }
    }

    /// A mesh data frame: deliver to the destination mailbox. Under
    /// crash tolerance also keep the recovery books — clear the mirrored
    /// outstanding steal when the real response lands (so a later
    /// synthesized refusal can never be stale) and count the credit
    /// delivered from this peer.
    fn on_mesh_msg(&mut self, peer: usize, to: PlaceId, job: u64, msg: Msg<B>) -> bool {
        if to >= self.topo.places() || self.topo.node_of(to) != self.my_rank {
            // A frame for a place this rank does not host would need
            // star-style forwarding — which the mesh must never produce.
            MISROUTED_FRAMES.fetch_add(1, Ordering::Relaxed);
            debug_assert!(false, "data frame for place {to} arrived at rank {}", self.my_rank);
            return false;
        }
        if job != self.job {
            // Another job's loot or steal can never enter this job's
            // books: drop the frame, keep the link (the epoch fences
            // make this structurally unreachable; the counter is the
            // belt-and-braces audit the serve tests assert zero).
            CROSS_EPOCH_FRAMES.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        if let Msg::Loot { victim, nonce: Some(n), .. } = &msg {
            // Loot or refusal, the steal round-trip is complete.
            let mark = lock_clean(&self.core.steal_marks).remove(&(*victim as u64, *n));
            if let Some(t0) = mark {
                STEAL_LAT_NS_SUM.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                STEAL_LAT_COUNT.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(rec) = &self.recovery {
            if let Msg::Loot { nonce: Some(n), .. } = &msg {
                let mut p = lock_clean(&rec.pending);
                if p.as_ref().is_some_and(|ps| ps.dest_rank == peer && ps.nonce == *n) {
                    *p = None;
                }
            }
            if let Msg::Loot { bag: Some(_), credit, .. } = &msg {
                rec.recv_credit[peer].fetch_add(*credit, Ordering::SeqCst);
            }
        }
        if let Some(tx) = &self.local[to] {
            let _ = tx.send(msg);
        }
        true
    }

    /// A mesh epoch fence: in resident mode it marks the peer's job-N
    /// traffic as fully delivered (FIFO links put it after every data
    /// frame of the job). A one-shot fleet must never see one.
    fn on_fence(&mut self, peer: usize, job: u64) -> bool {
        let Some(res) = &mut self.resident else {
            return false; // protocol violation outside resident mode
        };
        if job != self.job {
            CROSS_EPOCH_FRAMES.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        if let Some(f) = res.fences.get_mut(peer) {
            *f = true;
        }
        true
    }

    /// Rank 0's control-plane duties, inline (every handler is
    /// non-blocking): barrier arrivals, credit deposits/replenishes,
    /// result collection, ack banking/forwarding, reconcile routing.
    fn on_root_ctrl(&mut self, i: usize, peer: usize, c: Ctrl) -> bool {
        let ReactorRole::Root { root, results, gate, tol } = &mut self.role else {
            return false;
        };
        match c {
            Ctrl::Ready { .. } => {
                gate.ready_arrived();
                true
            }
            Ctrl::Deposit { job, atoms } => {
                if job != self.job {
                    CROSS_EPOCH_FRAMES.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                if let Some(t) = tol {
                    t.shared.deposited[peer].fetch_add(atoms, Ordering::SeqCst);
                }
                // May observe fleet quiescence, whose callback enqueues
                // the Terminate broadcast — an enqueue+wake, safe here.
                root.deposit(atoms);
                true
            }
            Ctrl::Replenish { job, want } => {
                if job != self.job {
                    CROSS_EPOCH_FRAMES.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                let atoms = root.mint(want);
                if let Some(t) = tol {
                    t.shared.granted[peer].fetch_add(atoms, Ordering::SeqCst);
                }
                self.core.send_ctrl_to(peer, &Ctrl::Grant { job, atoms })
            }
            Ctrl::Result { job, bytes } => {
                if job != self.job {
                    CROSS_EPOCH_FRAMES.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                lock_clean(results)[peer] = Some(bytes);
                self.conns[i].saw_result = true;
                true
            }
            Ctrl::Ack { rank: _, result, acked } if tol.is_some() => {
                // Bank the spoke's idle-point snapshot, then forward each
                // (victim, merged-count) to its victim so retention
                // ledgers shrink. Forwarding is best-effort: a victim
                // already gone keeps (or loses) its ledger harmlessly.
                let Some(t) = tol.as_ref() else { return false };
                lock_clean(&t.shared.ack_bank)[peer] = Some(result);
                for (victim, merged) in acked {
                    if victim == 0 {
                        t.shared.recovery.prune(peer, merged);
                    } else {
                        let fwd = Ctrl::Ack {
                            rank: peer as u64,
                            result: Vec::new(),
                            acked: vec![(victim, merged)],
                        };
                        self.core.send_ctrl_to(victim as usize, &fwd);
                    }
                }
                true
            }
            Ctrl::Reconcile { rank: r, sent, received } if tol.is_some() => match tol.as_ref() {
                Some(t) => t.reconcile_tx.send((r as usize, sent, received)).is_ok(),
                None => false,
            },
            Ctrl::Stats(s) => {
                // Advisory telemetry: banked when the root's own stats
                // plane is armed, harmlessly dropped otherwise (a spoke
                // may run `--stats` against a root launched without it).
                if let Some(st) = &self.stats {
                    st.shared.bank.bank(s);
                }
                true
            }
            _ => false, // protocol violation; drop the link
        }
    }

    /// A spoke's control-plane duties: `Go` and grants to the main /
    /// worker threads, `Leave` to the recovery thread, ack prunes
    /// inline.
    fn on_spoke_ctrl(&mut self, c: Ctrl) -> bool {
        let ReactorRole::Spoke { gate, grant_tx, leave_tx, .. } = &mut self.role else {
            return false;
        };
        match c {
            Ctrl::Go => {
                gate.go();
                true
            }
            Ctrl::Grant { job, atoms } => {
                if job != self.job {
                    CROSS_EPOCH_FRAMES.fetch_add(1, Ordering::Relaxed);
                    return true;
                }
                // Receiver gone means no ledger is waiting: ignore.
                if let Some(tx) = grant_tx {
                    let _ = tx.send(atoms);
                }
                true
            }
            Ctrl::Leave { rank: dead, .. } => {
                // The dead rank's steal responses will never arrive:
                // purge its marks *before* recovery synthesizes the
                // refusals those marks were waiting for, so a later
                // steal reusing a (victim, nonce) key cannot pair with
                // a stale enqueue time.
                purge_peer_marks(&self.core.steal_marks, &self.topo, dead as usize);
                if let Some(tx) = leave_tx {
                    let _ = tx.send(dead as usize);
                }
                true
            }
            Ctrl::Ack { rank: thief, acked, .. } => {
                if let Some(rec) = &self.recovery {
                    for (victim, merged) in acked {
                        if victim as usize == self.my_rank && (thief as usize) < rec.ledgers.len()
                        {
                            rec.prune(thief as usize, merged);
                        }
                    }
                }
                true
            }
            Ctrl::PeerMap { .. } => {
                // Post-recovery epoch republication: informational (the
                // Leave already carried the transition); accepted so a
                // future join path can reuse the frame.
                true
            }
            queued @ (Ctrl::Submit { .. } | Ctrl::Shutdown) if self.resident.is_some() => {
                // The root already moved on to the next job while ours
                // still drains: park the frame for the service loop.
                if let Some(res) = &mut self.resident {
                    res.carryover.push(queued);
                }
                true
            }
            other => {
                eprintln!("glb rank {}: unexpected control frame {other:?}", self.my_rank);
                std::process::exit(1);
            }
        }
    }

    /// A connection's read side is over (EOF, error, or violation):
    /// latch/report what the rank's role demands.
    fn close_read(&mut self, i: usize) {
        if self.conns[i].read_done {
            return;
        }
        self.conns[i].read_done = true;
        match self.conns[i].kind {
            ConnKind::Mesh { peer } => {
                // Reads from this peer are over, so any steal still
                // marked toward it can never complete. Purge before the
                // reader-done latch releases recovery (which synthesizes
                // the refusal the mark was waiting for).
                purge_peer_marks(&self.core.steal_marks, &self.topo, peer);
                if let Some(rec) = &self.recovery {
                    rec.reader_done[peer].mark();
                }
            }
            ConnKind::CtrlRoot { peer } => {
                if let ReactorRole::Root { tol: Some(t), .. } = &self.role {
                    if !self.conns[i].saw_result {
                        let _ = t.death_tx.send(peer);
                    }
                }
            }
            ConnKind::CtrlSpoke => {
                // Acquire: pairs with teardown's Release store (only the
                // flag itself matters here, but keep one ordering story
                // for every shutdown read).
                if self.core.shutdown.load(Ordering::Acquire) {
                    // Orderly teardown: the root answered our EOF.
                } else if let ReactorRole::Spoke { tolerant: true, .. } = &self.role {
                    // The root died (or dropped us): always fatal.
                    eprintln!("glb rank {}: lost the fleet control link", self.my_rank);
                    std::process::exit(1);
                } else if let ReactorRole::Spoke { gate, grant_tx, .. } = &mut self.role {
                    // Pre-Go this fails the fleet gate ("bootstrap
                    // closed before go"); post-Go it is the historical
                    // hang-until-launcher-failfast, except a worker
                    // blocked awaiting a grant now panics (the sender
                    // dies here) instead of hanging.
                    gate.fail();
                    grant_tx.take();
                }
            }
        }
        self.update_interest(i);
    }

    /// Re-register connection `i`'s poller interest from its state, and
    /// drop it from the poller entirely once both directions finished.
    fn update_interest(&mut self, i: usize) {
        let c = &mut self.conns[i];
        let fd = c.stream.as_raw_fd();
        if c.read_done && c.wr_closed {
            if !c.deregistered {
                c.deregistered = true;
                let _ = self.poller.remove(fd);
            }
        } else if !c.deregistered {
            let _ = self.poller.modify(fd, i as u64, !c.read_done, c.out_armed && !c.wr_closed);
        }
    }
}

/// Rank 0's shared crash-tolerance state (tolerant fleets only).
/// `granted`/`deposited` stay `SeqCst` for the same reason as
/// [`RankRecovery`]'s books: recovery subtracts them across threads as
/// one consistent set when reclaiming a dead rank's credit.
struct RootTolerant {
    recovery: Arc<RankRecovery>,
    /// Credit atoms granted to each rank (initial endowment + mints).
    granted: Vec<AtomicU64>,
    /// Credit atoms each rank deposited back to the root's pool.
    deposited: Vec<AtomicU64>,
    /// Latest acked result snapshot per rank: what the gather falls
    /// back to when the rank dies after its last idle point.
    ack_bank: Mutex<Vec<Option<Vec<u8>>>>,
}

/// A tolerant spoke's recovery servant (`glb-recovery-{rank}`): the
/// reactor hands it each `Leave` (rank death) so the blocking work —
/// waiting for the dead peer's mesh link to drain to EOF, re-importing
/// retained loot — never stalls the event loop. Exits when the reactor
/// does (the sole `leave_tx` lives in the reactor's role).
fn spoke_recovery<B>(
    leave_rx: Receiver<usize>,
    my_rank: usize,
    transport: SocketTransport<B>,
    rec: Arc<RankRecovery>,
) where
    B: WireCodec + Send + 'static,
{
    while let Ok(dead) = leave_rx.recv() {
        rec.membership.leave(dead);
        let (sent, received) = transport.recover_dead_peer(&rec, dead);
        if !transport.net.send_ctrl(&Ctrl::Reconcile { rank: my_rank as u64, sent, received }) {
            panic!("fleet control link lost (reconcile)");
        }
    }
}

/// Rank 0's recovery coordinator: serializes rank deaths. For each
/// death — detected by that rank's control servant exiting resultless —
/// it retires the rank, broadcasts the Leave, runs the root's own
/// recovery, collects every survivor's Reconcile, audits the dead
/// rank's credit books, and reclaims the missing atoms so the credit
/// proof (and with it exact termination) survives the crash.
fn root_coordinator<B>(
    transport: SocketTransport<B>,
    tol: Arc<RootTolerant>,
    root: Arc<CreditRoot>,
    death_rx: Receiver<usize>,
    reconcile_rx: Receiver<(usize, u64, u64)>,
    tolerate: usize,
    reconcile_timeout: Duration,
) where
    B: WireCodec + Send + 'static,
{
    let rec = &tol.recovery;
    let mut deaths = 0usize;
    while let Ok(dead) = death_rx.recv() {
        deaths += 1;
        if deaths > tolerate {
            eprintln!(
                "glb fleet: rank {dead} died; {deaths} death(s) exceeds --tolerate-failures"
            );
            std::process::exit(1);
        }
        let Some(view) = rec.membership.leave(dead) else { continue };
        eprintln!(
            "glb fleet: rank {dead} died; re-knitting {} survivor(s) at epoch {}",
            view.members().len(),
            view.epoch,
        );
        let leave = Ctrl::Leave { epoch: view.epoch, rank: dead as u64 };
        for r in view.members() {
            if r == 0 {
                continue;
            }
            transport.net.send_ctrl_to(r, &leave);
        }
        // The root's own books for the dead peer, then every survivor's.
        let (sent0, recv0) = transport.recover_dead_peer(rec, dead);
        let mut net = sent0 as i128 - recv0 as i128;
        let deadline = Instant::now() + reconcile_timeout;
        for _ in 0..view.members().len().saturating_sub(1) {
            let wait = deadline.saturating_duration_since(Instant::now());
            match reconcile_rx.recv_timeout(wait) {
                Ok((_, sent, received)) => net += sent as i128 - received as i128,
                Err(_) => {
                    eprintln!("glb fleet: reconcile after rank {dead}'s death timed out");
                    std::process::exit(1);
                }
            }
        }
        // Atoms the dead rank held = granted − deposited ± in-flight.
        let atoms = tol.granted[dead].load(Ordering::SeqCst) as i128
            - tol.deposited[dead].load(Ordering::SeqCst) as i128
            + net;
        if atoms < 0 {
            eprintln!("glb fleet: credit books negative after rank {dead}'s death");
            std::process::exit(1);
        }
        root.reclaim(atoms as u64);
        // Republish the epoch-stamped view (informational; the Leave
        // frames already drove every survivor's transition).
        let map = Ctrl::PeerMap {
            epoch: view.epoch,
            addrs: view.addrs.iter().map(|a| a.clone().unwrap_or_default()).collect(),
        };
        for r in view.members() {
            if r == 0 {
                continue;
            }
            transport.net.send_ctrl_to(r, &map);
        }
    }
}

/// Accept one fleet connection from a nonblocking `listener` before
/// `deadline`: the stream comes back blocking, nodelay, with its
/// 9-byte `[kind, rank]` handshake already read (under `timeout`, which
/// is left set — callers clear it once their per-kind setup is done).
pub(crate) fn accept_handshake(
    listener: &TcpListener,
    deadline: Instant,
    timeout: Duration,
) -> Result<(TcpStream, u8, usize)> {
    loop {
        match listener.accept() {
            Ok((mut s, _addr)) => {
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                s.set_read_timeout(Some(timeout))?;
                let mut hs = [0u8; 9];
                s.read_exact(&mut hs).context("read fleet handshake")?;
                let r = u64::from_le_bytes(hs[1..].try_into().unwrap()) as usize;
                return Ok((s, hs[0], r));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    bail!("timed out waiting for fleet connection(s)");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

pub(crate) fn connect_retry(host: &str, port: u16, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect((host, port)) {
            Ok(s) => {
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() > deadline {
                    bail!("could not reach fleet peer at {host}:{port}: {e}");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

pub(crate) fn handshake_bytes(kind: u8, rank: usize) -> [u8; 9] {
    let mut hs = [0u8; 9];
    hs[0] = kind;
    hs[1..].copy_from_slice(&(rank as u64).to_le_bytes());
    hs
}

/// How (whether) per-rank results funnel to rank 0 after the run.
pub(crate) trait ResultPlan<R>: Copy {
    const GATHER: bool;
    fn encode(&self, result: &R) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> Result<R>;
}

/// [`run_sockets`]: every rank keeps its local reduction.
#[derive(Clone, Copy)]
struct LocalOnly;

impl<R> ResultPlan<R> for LocalOnly {
    const GATHER: bool = false;
    fn encode(&self, _result: &R) -> Vec<u8> {
        unreachable!("no result gathering")
    }
    fn decode(&self, _bytes: &[u8]) -> Result<R> {
        unreachable!("no result gathering")
    }
}

/// [`run_sockets_reduced`]: results travel the control link as their
/// wire encoding and rank 0 folds the fleet.
#[derive(Clone, Copy)]
pub(crate) struct GatherWire;

impl<R: WireCodec> ResultPlan<R> for GatherWire {
    const GATHER: bool = true;
    fn encode(&self, result: &R) -> Vec<u8> {
        let mut out = Vec::new();
        result.encode(&mut out);
        out
    }
    fn decode(&self, bytes: &[u8]) -> Result<R> {
        let mut r = wire::Reader::new(bytes);
        let v = R::decode(&mut r).map_err(|e| anyhow!("decode fleet result: {e}"))?;
        if r.remaining() != 0 {
            bail!("trailing bytes after fleet result");
        }
        Ok(v)
    }
}

/// Run this process's share of a fleet-wide GLB computation.
///
/// The factory/root-init/reducer contract matches
/// [`crate::place::run_threads`], with two distributed twists: `factory`
/// is called only for this rank's places (still with global `(place, p)`
/// arguments), and the returned [`RunOutput`] holds the reduction of
/// **this rank's** per-place results plus the local [`RunLog`] — the
/// caller (or the `testkit::fleet` harness) combines ranks. Use
/// [`run_sockets_reduced`] to get the fleet-wide reduction at rank 0
/// instead.
pub fn run_sockets<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sockets_plan(cfg, opts, factory, root_init, reducer, LocalOnly)
}

/// [`run_sockets`] plus a fleet-wide result reduction: every spoke ships
/// its locally reduced result (as its [`WireCodec`] encoding) to rank 0
/// over the control link after the run, and rank 0's [`RunOutput`] holds
/// the reduction over **all** ranks. Spokes still return their local
/// share.
pub fn run_sockets_reduced<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    Q::Result: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sockets_plan(cfg, opts, factory, root_init, reducer, GatherWire)
}

fn run_sockets_plan<Q, R, FQ, FI, P>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    mut factory: FQ,
    root_init: FI,
    reducer: &R,
    plan: P,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
    P: ResultPlan<Q::Result>,
{
    let p = cfg.p;
    let topo = cfg.topology();
    let (rank, ranks) = (opts.rank, opts.ranks);
    if ranks == 0 {
        bail!("a fleet needs at least one rank");
    }
    if rank >= ranks {
        bail!("--rank {rank} out of range for --peers {ranks}");
    }
    if topo.nodes() != ranks {
        bail!(
            "fleet shape mismatch: {p} places at {} workers-per-node is {} nodes, \
             but the fleet has {ranks} ranks",
            cfg.params.workers_per_node,
            topo.nodes(),
        );
    }
    if opts.adapt && opts.tolerate_failures > 0 {
        bail!(
            "--adapt cannot be combined with --tolerate-failures: a mid-run retune \
             re-knits lifelines over the full static fleet shape"
        );
    }
    let tolerant = opts.tolerate_failures > 0 && ranks > 1;
    if tolerant && !P::GATHER {
        bail!(
            "--tolerate-failures needs a gathered run (run_sockets_reduced): \
             recovery banks per-rank result snapshots at rank 0"
        );
    }
    if tolerant && cfg.params.workers_per_node != 1 {
        bail!("--tolerate-failures requires one worker per node");
    }
    chaos::arm(rank);

    // -- local mailboxes (one per place this rank hosts) ----------------
    let my_places: Vec<PlaceId> = topo.workers_of(rank).collect();
    let mut local_tx: Vec<Option<Sender<Msg<Q::Bag>>>> = (0..p).map(|_| None).collect();
    let mut rxs: Vec<Receiver<Msg<Q::Bag>>> = Vec::with_capacity(my_places.len());
    for &i in &my_places {
        let (tx, rx) = channel();
        local_tx[i] = Some(tx);
        rxs.push(rx);
    }
    let local_tx: Mailboxes<Q::Bag> = Arc::new(local_tx);

    // -- fleet wiring ----------------------------------------------------
    let deadline = Instant::now() + opts.handshake_timeout;
    let results: ResultSlots = Arc::new(Mutex::new((0..ranks).map(|_| None).collect()));
    let pool = Arc::new(BufferPool::default());
    let mut net = NetCore::new(ranks, pool.clone());
    let gate = Arc::new(FleetGate::default());

    let mut mesh_streams: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
    let mut ctrl_streams: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
    let mut ctrl_stream: Option<TcpStream> = None;
    let mut root: Option<Arc<CreditRoot>> = None;
    let mut grants_rx: Option<Receiver<u64>> = None;
    let mut grant_tx: Option<Sender<u64>> = None;

    // Crash-tolerance state (all `None`/unused unless `tolerant`).
    let mut recovery: Option<Arc<RankRecovery>> = None;
    let mut root_tol: Option<Arc<RootTolerant>> = None;
    let mut death_tx: Option<Sender<usize>> = None;
    let mut death_rx: Option<Receiver<usize>> = None;
    let mut reconcile_tx: Option<Sender<(usize, u64, u64)>> = None;
    let mut reconcile_rx: Option<Receiver<(usize, u64, u64)>> = None;

    if ranks == 1 {
        // Single-rank fleet: nothing to wire, no reactor.
    } else if rank == 0 {
        // --- bootstrap: accept every control + mesh connection ----------
        let bind_addr = opts.bind.clone().unwrap_or_else(|| opts.host.clone());
        let listener = TcpListener::bind((bind_addr.as_str(), opts.port))
            .with_context(|| format!("bind fleet bootstrap on {bind_addr}:{}", opts.port))?;
        listener.set_nonblocking(true)?;
        let mut addrs: Vec<Option<String>> = (0..ranks).map(|_| None).collect();
        addrs[0] = Some(format!("{}:{}", opts.host, listener.local_addr()?.port()));
        for _ in 0..2 * (ranks - 1) {
            let (mut s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            if r == 0 || r >= ranks {
                bail!("fleet handshake from invalid rank {r}");
            }
            match kind {
                HS_CTRL => {
                    if ctrl_streams[r].is_some() {
                        bail!("duplicate control link from rank {r}");
                    }
                    let body = wire::read_frame(&mut s, wire::MAX_FRAME_BYTES)
                        .context("read rank registration")?
                        .ok_or_else(|| anyhow!("rank {r} closed before registering"))?;
                    match Ctrl::decode(&body) {
                        Ok(Ctrl::Register { rank: rr, addr }) if rr as usize == r => {
                            addrs[r] = Some(addr);
                        }
                        other => bail!("rank {r}: expected registration, got {other:?}"),
                    }
                    s.set_read_timeout(None)?;
                    ctrl_streams[r] = Some(s);
                }
                HS_MESH => {
                    if mesh_streams[r].is_some() {
                        bail!("duplicate mesh link from rank {r}");
                    }
                    s.set_read_timeout(None)?;
                    mesh_streams[r] = Some(s);
                }
                k => bail!("bad fleet handshake kind {k}"),
            }
        }
        // --- publish the peer map; spokes then dial each other ----------
        // (Still blocking bootstrap I/O: the reactor takes the sockets
        // over only once the fleet is fully knitted.)
        let addrs: Vec<String> = addrs
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .context("fleet bootstrap finished with unregistered ranks")?;
        let map = Ctrl::PeerMap { epoch: 0, addrs: addrs.clone() }.to_body();
        for (r, conn) in ctrl_streams.iter_mut().enumerate() {
            if let Some(s) = conn {
                wire::write_frame(s, &map).with_context(|| format!("send peer map to rank {r}"))?;
            }
        }
        // --- credit root (its control plane runs inside the reactor) ----
        let credit_root = CreditRoot::new();
        credit_root.grant(ranks as u64 * INITIAL_RANK_ATOMS);
        if tolerant {
            let membership = Arc::new(DynamicMembership::new(addrs));
            let rec = RankRecovery::new(rank, ranks, membership, pool.clone());
            let shared = Arc::new(RootTolerant {
                recovery: rec.clone(),
                granted: (0..ranks).map(|_| AtomicU64::new(INITIAL_RANK_ATOMS)).collect(),
                deposited: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
                ack_bank: Mutex::new((0..ranks).map(|_| None).collect()),
            });
            let (dtx, drx) = channel();
            let (rtx, rrx) = channel();
            recovery = Some(rec);
            root_tol = Some(shared);
            death_tx = Some(dtx);
            death_rx = Some(drx);
            reconcile_tx = Some(rtx);
            reconcile_rx = Some(rrx);
        }
        for r in 1..ranks {
            if ctrl_streams[r].is_some() {
                net.ctrl_peers[r] = Some(Arc::new(OutQueue::new()));
            }
        }
        root = Some(credit_root);
    } else {
        // --- spoke: own mesh listener + control link to rank 0 ----------
        let listener = TcpListener::bind(("0.0.0.0", 0)).context("bind mesh listener")?;
        let mesh_port = listener.local_addr()?.port();
        let mut ctrl = connect_retry(&opts.host, opts.port, deadline)?;
        ctrl.write_all(&handshake_bytes(HS_CTRL, rank)).context("send control handshake")?;
        let advertise_ip = match &opts.advertise {
            Some(a) => a.clone(),
            None => ctrl.local_addr()?.ip().to_string(),
        };
        // Mesh link to rank 0 (its address is already known).
        let mut to_hub = connect_retry(&opts.host, opts.port, deadline)?;
        to_hub.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
        mesh_streams[0] = Some(to_hub);
        // Register our mesh address, receive everyone's.
        let reg = Ctrl::Register { rank: rank as u64, addr: format!("{advertise_ip}:{mesh_port}") };
        wire::write_frame(&mut ctrl, &reg.to_body()).context("send registration")?;
        ctrl.set_read_timeout(Some(opts.handshake_timeout))?;
        let body = wire::read_frame(&mut ctrl, wire::MAX_FRAME_BYTES)
            .context("read peer map")?
            .ok_or_else(|| anyhow!("bootstrap closed before the peer map"))?;
        let addrs = match Ctrl::decode(&body) {
            Ok(Ctrl::PeerMap { epoch: 0, addrs }) if addrs.len() == ranks => addrs,
            other => bail!("expected a {ranks}-rank peer map, got {other:?}"),
        };
        // Dial every lower spoke; accept every higher one. Dials complete
        // through the targets' listen backlogs even before their accept
        // loops run, so the strict ordering cannot deadlock.
        for (r, addr) in addrs.iter().enumerate().take(rank).skip(1) {
            let (host, port) = addr
                .rsplit_once(':')
                .ok_or_else(|| anyhow!("malformed mesh address {addr:?} for rank {r}"))?;
            let port: u16 = port.parse().with_context(|| format!("mesh port in {addr:?}"))?;
            let mut s = connect_retry(host, port, deadline)?;
            s.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
            mesh_streams[r] = Some(s);
        }
        listener.set_nonblocking(true)?;
        for _ in 0..ranks - 1 - rank {
            let (s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            s.set_read_timeout(None)?;
            if kind != HS_MESH || r <= rank || r >= ranks {
                bail!("bad mesh handshake (kind {kind}, rank {r})");
            }
            if mesh_streams[r].is_some() {
                bail!("duplicate mesh link from rank {r}");
            }
            mesh_streams[r] = Some(s);
        }
        ctrl.set_read_timeout(None)?;
        if tolerant {
            let membership = Arc::new(DynamicMembership::new(addrs));
            recovery = Some(RankRecovery::new(rank, ranks, membership, pool.clone()));
        }
        // Grants arrive via the reactor and this channel; the replenish
        // RPC blocks on it inside `QueueHome`.
        let (gtx, grx) = channel();
        grant_tx = Some(gtx);
        grants_rx = Some(grx);
        net.ctrl = Some(Arc::new(OutQueue::new()));
        ctrl_stream = Some(ctrl);
    }
    for r in 0..ranks {
        if mesh_streams[r].is_some() {
            net.mesh[r] = Some(Arc::new(OutQueue::new()));
        }
    }
    let net = Arc::new(net);

    let ledger = if ranks == 1 {
        FleetLedger::Local(AtomicLedger::new())
    } else if rank == 0 {
        let credit_root = root.clone().expect("rank 0 hosts the credit root");
        FleetLedger::Credit(CreditLedger::new(
            Arc::new(RootHome { root: credit_root }),
            INITIAL_RANK_ATOMS,
        ))
    } else {
        let grants = grants_rx.take().expect("spokes hold the grant channel");
        FleetLedger::Credit(CreditLedger::new(
            Arc::new(QueueHome { net: net.clone(), grants: Mutex::new(grants), job: 0 }),
            INITIAL_RANK_ATOMS,
        ))
    };

    // Arm the telemetry plane before the reactor takes the sockets, so
    // its very first poll can already carry a sample timer.
    let stats: Option<Arc<StatsShared>> = opts
        .stats_interval
        .map(|iv| StatsShared::new(rank, ranks, my_places.len(), iv, ledger.clone()));

    // --- the reactor: one I/O thread owning every fleet socket ----------
    let mut reactor: Option<std::thread::JoinHandle<()>> = None;
    let mut leave_rx: Option<Receiver<usize>> = None;
    if ranks > 1 {
        let mut conns: Vec<ReactorConn> = Vec::new();
        for (r, s) in mesh_streams.iter_mut().enumerate() {
            if let Some(s) = s.take() {
                let q = net.mesh[r].clone().expect("mesh stream has a queue");
                conns.push(ReactorConn::new(s, ConnKind::Mesh { peer: r }, q));
            }
        }
        let role = if rank == 0 {
            for (r, s) in ctrl_streams.iter_mut().enumerate() {
                if let Some(s) = s.take() {
                    let q = net.ctrl_peers[r].clone().expect("control stream has a queue");
                    conns.push(ReactorConn::new(s, ConnKind::CtrlRoot { peer: r }, q));
                }
            }
            let tol = root_tol.as_ref().map(|shared| RootReactorTol {
                shared: shared.clone(),
                death_tx: death_tx.take().expect("tolerant root death sender"),
                reconcile_tx: reconcile_tx.take().expect("tolerant root reconcile sender"),
            });
            ReactorRole::Root {
                root: root.clone().expect("rank 0 hosts the credit root"),
                results: results.clone(),
                gate: gate.clone(),
                tol,
            }
        } else {
            let s = ctrl_stream.take().expect("spokes hold a control link");
            let q = net.ctrl.clone().expect("spokes hold a control queue");
            conns.push(ReactorConn::new(s, ConnKind::CtrlSpoke, q));
            let leave = if tolerant {
                let (ltx, lrx) = channel();
                leave_rx = Some(lrx);
                Some(ltx)
            } else {
                None
            };
            ReactorRole::Spoke {
                gate: gate.clone(),
                grant_tx: grant_tx.take(),
                tolerant,
                leave_tx: leave,
            }
        };
        let r = Reactor::<Q::Bag> {
            poller: Poller::new().context("create fleet reactor poller")?,
            conns,
            core: net.clone(),
            my_rank: rank,
            topo,
            local: local_tx.clone(),
            recovery: recovery.clone(),
            role,
            stats: stats.as_ref().map(|sh| ReactorStats {
                shared: sh.clone(),
                next: Instant::now() + sh.interval,
                ranks,
                prev: None,
            }),
            job: 0,
            resident: None,
        };
        // Relaxed: spawn accounting only. The spawn below and the final
        // join are the synchronization edges any reader runs behind.
        IO_THREADS.fetch_add(1, Ordering::Relaxed);
        IO_THREADS_LIVE.fetch_add(1, Ordering::Relaxed);
        reactor = Some(
            std::thread::Builder::new()
                .name(format!("glb-io-{rank}"))
                .spawn(move || {
                    let _live = IoLiveGuard;
                    r.run();
                })
                .expect("spawn fleet reactor"),
        );
    }

    let transport: SocketTransport<Q::Bag> = SocketTransport {
        rank,
        topo,
        p,
        local: local_tx,
        net: net.clone(),
        recovery: recovery.clone(),
        job: 0,
    };

    // The detector broadcasts Terminate to every place the moment all
    // credit is recovered — the distributed stand-in for the
    // worker-observed zero of the single-process ledgers.
    if let Some(credit_root) = &root {
        let t = transport.clone();
        credit_root.on_quiescent(move || t.terminate_fleet());
    }

    // -- sequential local setup ------------------------------------------
    // Queues and workers are constructed (acquiring initial work tokens
    // against this rank's credit pool) *before* the start barrier, so no
    // rank can be stolen from while half-built.
    let mut queues: Vec<Q> = my_places.iter().map(|&i| factory(i, p)).collect();
    if rank == 0 {
        root_init(&mut queues[0]);
    }
    let node_bag: Option<Arc<NodeBag<Q::Bag>>> =
        if topo.is_flat() { None } else { Some(Arc::new(NodeBag::new())) };
    let mut workers: Vec<Worker<Q, FleetLedger>> = queues
        .into_iter()
        .zip(&my_places)
        .map(|(q, &i)| Worker::with_node_bag(i, p, cfg.params, q, ledger.clone(), node_bag.clone()))
        .collect();

    // -- fleet-wide start barrier ----------------------------------------
    if ranks > 1 {
        if rank == 0 {
            // Arm before any GO can reach a spoke: deposits only start
            // after GO, so detection can never race the fleet start.
            root.as_ref().expect("rank 0 hosts the credit root").arm();
            gate.wait_ready(ranks - 1);
            for r in 1..ranks {
                net.send_ctrl_to(r, &Ctrl::Go);
            }
        } else {
            if !net.send_ctrl(&Ctrl::Ready { rank: rank as u64 }) {
                bail!("bootstrap closed before go");
            }
            if !gate.wait_go() {
                bail!("bootstrap closed before go");
            }
        }
    }

    // -- crash-tolerance service threads ---------------------------------
    // Blocking recovery work (bag re-import, reconcile collection) stays
    // off the reactor; the reactor feeds these threads over channels and
    // they exit when it drops the senders.
    let mut spoke_recovery_thread: Option<std::thread::JoinHandle<()>> = None;
    let mut coordinator: Option<std::thread::JoinHandle<()>> = None;
    if tolerant {
        if rank == 0 {
            let t = transport.clone();
            let tolr = root_tol.clone().expect("tolerant root state");
            let rt = root.clone().expect("rank 0 hosts the credit root");
            let drx = death_rx.take().expect("tolerant root death channel");
            let rrx = reconcile_rx.take().expect("tolerant root reconcile channel");
            let tolerate = opts.tolerate_failures;
            let timeout = opts.handshake_timeout;
            coordinator = Some(
                std::thread::Builder::new()
                    .name("glb-fleet-recovery".into())
                    .spawn(move || {
                        root_coordinator::<Q::Bag>(t, tolr, rt, drx, rrx, tolerate, timeout)
                    })
                    .expect("spawn recovery coordinator"),
            );
        } else {
            let lrx = leave_rx.take().expect("tolerant spokes hold the leave channel");
            let t = transport.clone();
            let rec = recovery.clone().expect("tolerant spokes hold recovery state");
            spoke_recovery_thread = Some(
                std::thread::Builder::new()
                    .name(format!("glb-recovery-{rank}"))
                    .spawn(move || spoke_recovery::<Q::Bag>(lrx, rank, t, rec))
                    .expect("spawn spoke recovery thread"),
            );
        }
    }

    // Kick empty places into the steal protocol (now safe: every rank's
    // workers are constructed and credited).
    let mut fx = Vec::new();
    for w in workers.iter_mut() {
        let me = w.id();
        w.kick_if_empty(&mut fx);
        pump(me, &mut fx, &transport);
    }

    // -- run ---------------------------------------------------------------
    let t0 = Instant::now();
    let mut tol_worker: Option<TolerantWorker> = recovery.as_ref().map(|rec| TolerantWorker {
        rec: rec.clone(),
        ack: if rank == 0 { AckOut::Root(net.clone()) } else { AckOut::Spoke(net.clone()) },
    });
    let handles: Vec<_> = workers
        .into_iter()
        .zip(rxs)
        .enumerate()
        .map(|(slot, (worker, rx))| {
            let transport = transport.clone();
            let tol = tol_worker.take(); // tolerant fleets run one worker per rank
            let wstats = stats.clone().map(|sh| (sh, slot));
            let adapt = opts.adapt;
            std::thread::Builder::new()
                .name(format!("glb-sock-{}", worker.id()))
                .stack_size(opts.stack_bytes)
                .spawn(move || socket_place_main(worker, rx, transport, tol, plan, wstats, adapt))
                .expect("spawn place thread")
        })
        .collect();

    let mut per_place: Vec<(Q::Result, crate::glb::WorkerStats)> =
        handles.into_iter().map(|h| h.join().expect("place thread panicked")).collect();
    let elapsed_ns = t0.elapsed().as_nanos() as u64;

    let stats: Vec<_> = per_place.iter().map(|(_, s)| *s).collect();
    let local_results: Vec<Q::Result> = per_place.drain(..).map(|(r, _)| r).collect();
    let mut result = reducer.reduce_all(local_results);

    // -- final telemetry snapshot -----------------------------------------
    // Every worker has published its terminal gauges, so this snapshot's
    // worker-sourced fields equal the RunLog totals exactly. Spokes ship
    // it ahead of their Result frame (the control queue is FIFO and the
    // teardown drain guarantees delivery); rank 0 banks its own.
    if let Some(sh) = &stats {
        let snap = sh.snapshot(&net, true);
        if rank == 0 {
            sh.bank.bank(snap);
        } else {
            let _ = net.send_ctrl(&Ctrl::Stats(snap));
        }
    }

    // -- result gathering (spoke side; rides the control queue) ----------
    if P::GATHER && ranks > 1 && rank != 0 {
        let sent = net.send_ctrl(&Ctrl::Result { job: 0, bytes: plan.encode(&result) });
        if !sent {
            bail!("fleet control link closed before the result was sent");
        }
    }

    // -- teardown ----------------------------------------------------------
    // Flip the shutdown flag and wake the reactor: it drains every write
    // queue, half-closes, and reads every peer to EOF before exiting, so
    // joining it means the fleet's last frames (including the Result
    // above) have landed. From here a control-link EOF is an orderly
    // shutdown, not a death. (Release: pairs with the reactor's Acquire
    // loads, publishing everything enqueued above — the weakest ordering
    // that still guarantees the Result frame is visible to the drain.)
    net.shutdown.store(true, Ordering::Release);
    net.waker.wake();
    if let Some(h) = reactor {
        let _ = h.join();
    }
    if let Some(h) = coordinator {
        // Joins cleanly: the reactor's exit dropped the death sender, so
        // the coordinator's recv loop has ended.
        let _ = h.join();
    }
    if let Some(h) = spoke_recovery_thread {
        let _ = h.join();
    }

    // The reactor has drained every peer to EOF, so every rank's final
    // (`last: true`) snapshot is banked: print the closing fleet line.
    if let Some(sh) = &stats {
        if rank == 0 {
            let (fleet, heard) = sh.bank.fleet();
            print_fleet_stats(&fleet, heard, ranks, &None);
        }
    }

    if let Some(credit_root) = &root {
        debug_assert!(credit_root.quiescent(), "all termination credit must be recovered");
        debug_assert_eq!(credit_root.outstanding(), 0, "credit books must balance");
        if P::GATHER {
            let view = recovery.as_ref().map(|rec| rec.membership.view());
            let mut banked =
                root_tol.as_ref().map(|t| std::mem::take(&mut *t.ack_bank.lock().unwrap()));
            let mut slots = results.lock().unwrap();
            let mut all = vec![result];
            for (r, slot) in slots.iter_mut().enumerate().skip(1) {
                match slot.take() {
                    Some(bytes) => all
                        .push(plan.decode(&bytes).with_context(|| format!("result of rank {r}"))?),
                    None if view.as_ref().is_some_and(|v| !v.alive(r)) => {
                        // Dead rank: its last banked idle-point snapshot
                        // covers exactly its acked merges. Everything it
                        // merged after that ack stayed in the senders'
                        // retention ledgers and was re-imported, so even
                        // a rank that never acked folds in as nothing.
                        if let Some(bytes) = banked.as_mut().and_then(|b| b[r].take()) {
                            all.push(
                                plan.decode(&bytes)
                                    .with_context(|| format!("banked result of rank {r}"))?,
                            );
                        }
                    }
                    None => bail!("rank {r} sent no result"),
                }
            }
            result = reducer.reduce_all(all);
        }
    }
    debug_assert_eq!(ledger.value(), 0, "local tokens must balance at termination");

    let log = RunLog::with_topology(stats, cfg.params.workers_per_node);
    Ok(RunOutput { result, log, elapsed_ns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::{sequential_count, UtsParams, UtsQueue};
    use crate::glb::task_queue::SumReducer;
    use crate::glb::GlbParams;
    use crate::testkit::fleet::free_port;

    fn up(depth: u32) -> UtsParams {
        UtsParams { b0: 4.0, seed: 19, max_depth: depth }
    }

    fn run_rank(
        rank: usize,
        ranks: usize,
        port: u16,
        params: GlbParams,
        p: usize,
        depth: u32,
    ) -> RunOutput<u64> {
        let cfg = GlbConfig::new(p, params);
        let opts = SocketRunOpts { rank, ranks, port, ..Default::default() };
        run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(depth)), |q| q.init_root(), &SumReducer)
            .expect("fleet rank failed")
    }

    #[test]
    fn single_rank_fleet_matches_sequential() {
        let out = run_rank(0, 1, 0, GlbParams::default().with_n(64), 1, 5);
        assert_eq!(out.result, sequential_count(&up(5)));
    }

    #[test]
    fn two_rank_in_process_fleet_sums_to_sequential() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 2, 6));
        let r0 = run_rank(0, 2, port, params, 2, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        // Loot accounting balances fleet-wide.
        let (t0, t1) = (r0.log.total(), r1.log.total());
        assert_eq!(
            t0.loot_bags_sent + t1.loot_bags_sent,
            t0.loot_bags_received + t1.loot_bags_received,
        );
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn three_rank_mesh_exchanges_directly() {
        // With three ranks every spoke pair owns a direct link; the
        // misrouted counter proves no frame ever needed rank 0's help.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 3, port, params, 3, 6));
        let t2 = std::thread::spawn(move || run_rank(2, 3, port, params, 3, 6));
        let r0 = run_rank(0, 3, port, params, 3, 6);
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();
        assert_eq!(r0.result + r1.result + r2.result, sequential_count(&up(6)));
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn hierarchical_two_rank_fleet_sums_to_sequential() {
        // 2 processes × 2 workers: reps 0 and 2 own the inter-node
        // sockets; workers 1 and 3 share through their process's NodeBag.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2).with_workers_per_node(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 4, 6));
        let r0 = run_rank(0, 2, port, params, 4, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        for out in [&r0, &r1] {
            let t = out.log.total();
            // Node-bag traffic never crosses a process boundary, so it
            // balances within each rank on its own.
            assert_eq!(t.node_donations, t.node_takes);
            assert_eq!(out.log.per_place.len(), 2);
        }
    }

    #[test]
    fn tolerant_fleet_without_deaths_matches_sequential() {
        // The crash-tolerant machinery (retention ledgers, idle-point
        // acks, channel-routed grants) engaged but unexercised: the
        // gathered result must match the fail-fast path exactly.
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let run = move |rank: usize| {
            let cfg = GlbConfig::new(3, params);
            let opts =
                SocketRunOpts { rank, ranks: 3, port, tolerate_failures: 1, ..Default::default() };
            run_sockets_reduced(
                &cfg,
                &opts,
                |_, _| UtsQueue::new(up(6)),
                |q| q.init_root(),
                &SumReducer,
            )
            .expect("tolerant fleet rank failed")
        };
        let t1 = std::thread::spawn(move || run(1));
        let t2 = std::thread::spawn(move || run(2));
        let r0 = run(0);
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(r0.result, sequential_count(&up(6)));
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn tolerant_mode_requires_a_gathered_flat_run() {
        // Recovery banks result snapshots at rank 0 and mirrors the
        // (single) worker's outstanding steal, so both preconditions are
        // checked up front instead of failing subtly mid-crash.
        let params = GlbParams::default().with_l(2);
        let cfg = GlbConfig::new(2, params);
        let opts =
            SocketRunOpts { rank: 0, ranks: 2, port: 1, tolerate_failures: 1, ..Default::default() };
        let err = run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer)
            .expect_err("ungathered tolerant run must be refused");
        assert!(err.to_string().contains("tolerate-failures"), "{err}");

        let params = GlbParams::default().with_l(2).with_workers_per_node(2);
        let cfg = GlbConfig::new(4, params);
        let opts =
            SocketRunOpts { rank: 0, ranks: 2, port: 1, tolerate_failures: 1, ..Default::default() };
        let err =
            run_sockets_reduced(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer)
                .expect_err("hierarchical tolerant run must be refused");
        assert!(err.to_string().contains("one worker per node"), "{err}");
    }

    #[test]
    fn empty_fleet_terminates_cleanly() {
        // No root work anywhere: every worker kicks, all steals are
        // refused across the wire, the last credit deposit reaches the
        // root, and the detector's Terminate reaches both processes.
        let port = free_port();
        let params = GlbParams::default().with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts { rank: 0, ranks: 2, port, ..Default::default() };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, 0);
    }

    #[test]
    fn bind_address_splits_from_advertised_host() {
        // The rank-0 bind/advertise fix: bind the wildcard while
        // advertising (and dialing) loopback — before the split this
        // required --host to be locally bindable.
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(5)), |q| q.init_root(), &SumReducer)
                .unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts {
            rank: 0,
            ranks: 2,
            port,
            host: "127.0.0.1".into(),
            bind: Some("0.0.0.0".into()),
            ..Default::default()
        };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(5)), |q| q.init_root(), &SumReducer)
                .unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(5)));
    }

    #[test]
    fn reduced_run_folds_the_fleet_at_rank0() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let spawn_rank = move |rank: usize| {
            std::thread::spawn(move || {
                let cfg = GlbConfig::new(3, params);
                let opts = SocketRunOpts { rank, ranks: 3, port, ..Default::default() };
                run_sockets_reduced(
                    &cfg,
                    &opts,
                    |_, _| UtsQueue::new(up(6)),
                    |q| q.init_root(),
                    &SumReducer,
                )
                .unwrap()
            })
        };
        let t1 = spawn_rank(1);
        let t2 = spawn_rank(2);
        let cfg = GlbConfig::new(3, params);
        let opts = SocketRunOpts { rank: 0, ranks: 3, port, ..Default::default() };
        let r0 = run_sockets_reduced(
            &cfg,
            &opts,
            |_, _| UtsQueue::new(up(6)),
            |q| q.init_root(),
            &SumReducer,
        )
        .unwrap();
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();
        let expect = sequential_count(&up(6));
        assert_eq!(r0.result, expect, "rank 0 holds the fleet-wide reduction");
        assert!(r1.result <= expect && r2.result <= expect, "spokes keep local shares");
    }

    #[test]
    fn fleet_shape_mismatch_is_an_error() {
        let cfg = GlbConfig::new(4, GlbParams::default());
        let opts = SocketRunOpts { rank: 0, ranks: 3, ..Default::default() };
        let err =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(3)), |q| q.init_root(), &SumReducer)
                .unwrap_err();
        assert!(format!("{err:#}").contains("fleet shape"), "{err:#}");
    }

    #[test]
    fn dead_peer_marks_are_purged_not_sampled() {
        // The steal-latency mark-leak regression at the unit level: a
        // peer's death must drop exactly its own marks, so a later steal
        // reusing the (victim, nonce) key can never pair with the stale
        // enqueue time.
        let marks = Mutex::new(HashMap::new());
        let topo = Topology::new(4, 1); // flat: place i lives on rank i
        marks.lock().unwrap().insert((1u64, 7u64), Instant::now());
        marks.lock().unwrap().insert((1u64, 8u64), Instant::now());
        marks.lock().unwrap().insert((2u64, 7u64), Instant::now());
        purge_peer_marks(&marks, &topo, 1);
        let m = marks.lock().unwrap();
        assert!(!m.contains_key(&(1, 7)) && !m.contains_key(&(1, 8)), "dead victim purged");
        assert!(m.contains_key(&(2, 7)), "other peers' marks survive, same nonce or not");
    }

    #[test]
    fn stats_enabled_fleet_matches_sequential() {
        // The telemetry plane is strictly observational: with a fast
        // sample timer shipping Ctrl::Stats throughout, the reduction
        // must be bit-identical to a stats-less run.
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let run = move |rank: usize| {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts {
                rank,
                ranks: 2,
                port,
                stats_interval: Some(Duration::from_millis(2)),
                ..Default::default()
            };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(6)), |q| q.init_root(), &SumReducer)
                .expect("stats fleet rank failed")
        };
        let t1 = std::thread::spawn(move || run(1));
        let r0 = run(0);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
    }

    #[test]
    fn adaptive_fleet_result_is_unchanged() {
        // A deliberately coarse static point on an irregular tree — the
        // controller's favorite prey. Whatever it retunes mid-run, the
        // reduction is invariant.
        let port = free_port();
        let params = GlbParams::default().with_n(256);
        let run = move |rank: usize| {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank, ranks: 2, port, adapt: true, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(7)), |q| q.init_root(), &SumReducer)
                .expect("adaptive fleet rank failed")
        };
        let t1 = std::thread::spawn(move || run(1));
        let r0 = run(0);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(7)));
    }

    #[test]
    fn adapt_and_tolerate_are_mutually_exclusive() {
        let cfg = GlbConfig::new(2, GlbParams::default().with_l(2));
        let opts = SocketRunOpts {
            rank: 0,
            ranks: 2,
            port: 1,
            adapt: true,
            tolerate_failures: 1,
            ..Default::default()
        };
        let err =
            run_sockets_reduced(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer)
                .expect_err("adaptive tolerant run must be refused");
        assert!(err.to_string().contains("--adapt"), "{err}");
    }
}
