//! TCP socket place-runtime: one OS **process** per GLB node, wired as a
//! direct spoke-to-spoke **mesh** with credit-based distributed
//! termination.
//!
//! This is the process-spanning `Transport` the ROADMAP calls for: the
//! same [`Worker`] protocol engine as the thread runtime and the
//! simulator, but with nodes living in separate OS processes that talk
//! over length-prefixed TCP frames ([`crate::glb::wire`]). A fleet of
//! `ranks` processes runs one GLB *node* each (so with
//! `workers_per_node > 1` every process hosts several worker threads
//! sharing a [`NodeBag`], and only the node's representative speaks the
//! inter-node protocol).
//!
//! ## Fleet wiring (bootstrap star, steady-state mesh)
//!
//! Rank 0 is **bootstrap and discovery only** — after the start barrier
//! no steal/loot/refusal byte transits it on behalf of other ranks:
//!
//! 1. every rank binds its own mesh listener; spokes dial rank 0 and
//!    [`Ctrl::Register`] their advertised `ip:port`;
//! 2. rank 0 answers with the [`Ctrl::PeerMap`]; each rank then dials
//!    every lower rank and accepts every higher one, building one duplex
//!    TCP link per pair (dials succeed through listen backlogs, so the
//!    strict ordering cannot deadlock);
//! 3. data frames are `[to: u64][msg body]` under a length prefix, sent
//!    on the pair's own link and decoded only at the destination — a
//!    frame for a place the receiving rank does not host is a protocol
//!    violation (counted in [`misrouted_frames`], asserted zero by the
//!    fleet tests).
//!
//! Rank 0 keeps binding separate from advertising: it binds
//! [`SocketRunOpts::bind`] (default: the advertised host) so
//! `--host <public-ip>` works on machines where that address is not
//! locally bindable (`--bind 0.0.0.0`).
//!
//! ## Termination: credit throwing instead of a hub ledger
//!
//! The work-token count (paper §2.4 item 3) is distributed via
//! Mattern-style credit throwing ([`crate::glb::termination`]): every
//! rank runs a [`CreditLedger`] whose `incr`/`decr` are **local** (no
//! I/O), loot messages carry credit atoms in their wire envelope, and a
//! rank that goes idle deposits its atoms to rank 0's [`CreditRoot`]
//! asynchronously on the control link. The root observes
//! `recovered == total` exactly when no rank holds a token and no loot
//! is in flight, then broadcasts `Terminate` to every place over the
//! mesh. The only synchronous credit operation left is the
//! pool-exhaustion [`Ctrl::Replenish`], amortized over many cross-rank
//! loot sends (worst-case cadence documented at
//! [`crate::glb::termination::MAX_ATTACH_ATOMS`]) — nothing here does a
//! synchronous RPC per steal/loot event the way the old hub ledger did.
//!
//! A fleet-wide start barrier ([`Ctrl::Ready`]/[`Ctrl::Go`] on the
//! control link) preserves the thread runtime's sequential-setup
//! guarantee: no rank enters the steal protocol until every rank has
//! constructed its workers and holds its initial tokens and credit.
//!
//! Teardown mirrors the protocol's own guarantee that no message is in
//! flight after `Terminate`: every rank half-closes the write side of
//! all its links; mesh readers drain to EOF; rank 0's control servers
//! exit on their spoke's EOF (after optionally collecting the rank's
//! encoded result for the fleet-wide reduction of
//! [`run_sockets_reduced`]).

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::glb::message::{Effect, Msg, PlaceId};
use crate::glb::task_queue::{Reducer, TaskQueue};
use crate::glb::termination::{
    AtomicLedger, CreditHome, CreditLedger, CreditRoot, Ledger, INITIAL_RANK_ATOMS,
};
use crate::glb::topology::{NodeBag, Topology};
use crate::glb::wire::{self, Ctrl, WireCodec};
use crate::glb::worker::{Phase, Worker};
use crate::glb::{GlbConfig, RunLog, RunOutput};

/// How this process joins the fleet.
#[derive(Debug, Clone)]
pub struct SocketRunOpts {
    /// This process's rank (= its GLB node id). Rank 0 is bootstrap +
    /// credit root.
    pub rank: usize,
    /// Total processes in the fleet (= GLB node count).
    pub ranks: usize,
    /// Rank 0's *advertised* host: what every other rank dials for
    /// bootstrap, and what the peer map lists as rank 0's mesh address.
    pub host: String,
    /// Rank 0's rendezvous port. `0` (rank 0 only, single-rank fleets)
    /// binds an ephemeral port.
    pub port: u16,
    /// Rank 0's *bind* address. `None` binds `host`; set it (CLI default
    /// `0.0.0.0` when `--host` is given) when the advertised address is
    /// not locally bindable — NAT'd hosts, load-balanced VIPs, or plain
    /// `--host <public-ip>` on a box that only has the private interface.
    pub bind: Option<String>,
    /// This rank's advertised mesh IP (spokes). `None` advertises the
    /// interface this host reaches rank 0 from — right for localhost
    /// fleets and single-homed hosts alike.
    pub advertise: Option<String>,
    /// How long to wait for the whole fleet to connect / handshake.
    pub handshake_timeout: Duration,
    /// Per-place worker thread stack size in bytes.
    pub stack_bytes: usize,
}

impl Default for SocketRunOpts {
    fn default() -> Self {
        Self {
            rank: 0,
            ranks: 1,
            host: "127.0.0.1".into(),
            port: 0,
            bind: None,
            advertise: None,
            handshake_timeout: Duration::from_secs(30),
            stack_bytes: 2 << 20,
        }
    }
}

// Handshake connection kinds.
const HS_CTRL: u8 = 0;
const HS_MESH: u8 = 1;

/// Data frames that arrived at a rank not hosting their destination
/// place — star-style relay traffic, which the mesh must never produce.
/// Monotonic per process; the fleet integration tests assert it stays
/// zero on every rank.
static MISROUTED_FRAMES: AtomicU64 = AtomicU64::new(0);

/// Data frames this process received for places it does not host (see
/// [`MISROUTED_FRAMES`]). Zero on every rank of a healthy mesh.
pub fn misrouted_frames() -> u64 {
    MISROUTED_FRAMES.load(Ordering::Relaxed)
}

/// Mesh data-plane bytes this process has put on / taken off the wire
/// (frame bodies plus their 4-byte length prefix; control-link traffic
/// is bootstrap-only and excluded). Monotonic per process — one GLB run
/// per process, so the totals are per-run in practice; the fleet
/// launcher rolls them into its report.
static WIRE_TX_BYTES: AtomicU64 = AtomicU64::new(0);
static WIRE_RX_BYTES: AtomicU64 = AtomicU64::new(0);

/// `(sent, received)` mesh data bytes for this process (see
/// [`WIRE_TX_BYTES`]).
pub fn wire_bytes() -> (u64, u64) {
    (WIRE_TX_BYTES.load(Ordering::Relaxed), WIRE_RX_BYTES.load(Ordering::Relaxed))
}

/// A shared, mutex-serialized write half of a TCP link.
type Link = Arc<Mutex<TcpStream>>;
/// Mailbox sender per *global* place id (`None` for remote places).
type Mailboxes<B> = Arc<Vec<Option<Sender<Msg<B>>>>>;
/// Per-rank slots for gathered result payloads (rank 0 only).
type ResultSlots = Arc<Mutex<Vec<Option<Vec<u8>>>>>;

/// The work-token ledger, as seen from one fleet process.
#[derive(Clone)]
enum FleetLedger {
    /// Single-rank fleet: the plain in-process counter.
    Local(Arc<AtomicLedger>),
    /// Mesh member: rank-local credit ledger (see module docs).
    Credit(Arc<CreditLedger>),
}

impl Ledger for FleetLedger {
    fn incr(&self) {
        match self {
            FleetLedger::Local(l) => l.incr(),
            FleetLedger::Credit(l) => l.incr(),
        }
    }

    fn decr(&self) -> bool {
        match self {
            FleetLedger::Local(l) => l.decr(),
            FleetLedger::Credit(l) => l.decr(),
        }
    }

    fn value(&self) -> i64 {
        match self {
            FleetLedger::Local(l) => l.value(),
            FleetLedger::Credit(l) => l.value(),
        }
    }

    fn export_credit(&self) -> u64 {
        match self {
            FleetLedger::Local(l) => l.export_credit(),
            FleetLedger::Credit(l) => l.export_credit(),
        }
    }

    fn import_credit(&self, atoms: u64) {
        match self {
            FleetLedger::Local(l) => l.import_credit(atoms),
            FleetLedger::Credit(l) => l.import_credit(atoms),
        }
    }
}

/// A spoke's credit home: async deposits and the rare synchronous
/// replenish, both on the control link. Panics on I/O failure — a dead
/// control link loses termination credit, which is unrecoverable (the
/// fleet could never quiesce), and all credit traffic stops before
/// teardown.
struct CtrlHome {
    link: Link,
}

impl CreditHome for CtrlHome {
    fn deposit(&self, atoms: u64) {
        let mut s = self.link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Deposit { atoms }.to_body())
            .expect("fleet control link lost (deposit)");
    }

    fn replenish(&self, want: u64) -> u64 {
        let mut s = self.link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Replenish { want }.to_body())
            .expect("fleet control link lost (replenish)");
        let body = wire::read_frame(&mut *s, wire::MAX_FRAME_BYTES)
            .expect("fleet control link lost (grant)")
            .expect("fleet control link closed awaiting grant");
        match Ctrl::decode(&body) {
            Ok(Ctrl::Grant { atoms }) => atoms,
            other => panic!("expected credit grant, got {other:?}"),
        }
    }
}

/// Rank 0's credit home: the root lives in-process.
struct RootHome {
    root: Arc<CreditRoot>,
}

impl CreditHome for RootHome {
    fn deposit(&self, atoms: u64) {
        self.root.deposit(atoms);
    }

    fn replenish(&self, want: u64) -> u64 {
        self.root.mint(want)
    }
}

/// All ranks construct their workers (holding their initial tokens and
/// credit) before any rank steals.
struct StartBarrier {
    arrived: Mutex<usize>,
    cv: Condvar,
    total: usize,
}

impl StartBarrier {
    fn new(total: usize) -> Self {
        Self { arrived: Mutex::new(0), cv: Condvar::new(), total }
    }

    fn arrive_and_wait(&self) {
        let mut n = self.arrived.lock().unwrap();
        *n += 1;
        if *n >= self.total {
            self.cv.notify_all();
        }
        while *n < self.total {
            n = self.cv.wait(n).unwrap();
        }
    }
}

/// The per-process message fabric: local mailboxes for this rank's
/// places, one direct mesh link per remote rank.
struct SocketTransport<B> {
    rank: usize,
    topo: Topology,
    p: usize,
    local: Mailboxes<B>,
    links: Arc<Vec<Option<Link>>>,
}

impl<B> Clone for SocketTransport<B> {
    fn clone(&self) -> Self {
        Self {
            rank: self.rank,
            topo: self.topo,
            p: self.p,
            local: self.local.clone(),
            links: self.links.clone(),
        }
    }
}

impl<B: WireCodec> SocketTransport<B> {
    /// Send `msg` to place `to` — the local mailbox, or the destination
    /// rank's own mesh link (never a relay). Best-effort on I/O failure:
    /// writes only fail once the peer is gone, at which point the run is
    /// already lost, exactly like the thread runtime's mailbox sends.
    fn send(&self, to: PlaceId, msg: Msg<B>) {
        let dest_rank = self.topo.node_of(to);
        if dest_rank == self.rank {
            if let Some(tx) = &self.local[to] {
                let _ = tx.send(msg);
            }
            return;
        }
        let body = wire::encode_data_frame_body(to, &msg);
        if let Some(link) = &self.links[dest_rank] {
            let mut s = link.lock().unwrap();
            if wire::write_frame(&mut *s, &body).is_ok() {
                WIRE_TX_BYTES.fetch_add(body.len() as u64 + 4, Ordering::Relaxed);
            }
        }
    }

    /// The worker-observed quiescence broadcast — only reachable in
    /// single-rank fleets (mesh fleets detect at the credit root).
    fn broadcast_terminate(&self, me: PlaceId) {
        for i in (0..self.p).filter(|&i| i != me) {
            self.send(i, Msg::Terminate);
        }
    }

    /// The credit root observed global quiescence: tell every place in
    /// the fleet (rank 0's own included) to finish.
    fn terminate_fleet(&self) {
        for i in 0..self.p {
            self.send(i, Msg::Terminate);
        }
    }
}

/// Carry out a worker's requested effects.
fn pump<B: WireCodec>(me: PlaceId, fx: &mut Vec<Effect<B>>, transport: &SocketTransport<B>) {
    for e in fx.drain(..) {
        match e {
            Effect::Send { to, msg } => {
                debug_assert_ne!(to, me, "no self-sends in the protocol");
                transport.send(to, msg);
            }
            Effect::Quiescent => transport.broadcast_terminate(me),
        }
    }
}

/// Per-place worker thread body (mirror of the thread runtime's
/// `place_main`, driving the same engine over the socket fabric).
fn socket_place_main<Q>(
    mut worker: Worker<Q, FleetLedger>,
    rx: Receiver<Msg<Q::Bag>>,
    transport: SocketTransport<Q::Bag>,
) -> (Q::Result, crate::glb::WorkerStats)
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
{
    let me = worker.id();
    let mut fx: Vec<Effect<Q::Bag>> = Vec::with_capacity(8);
    loop {
        match worker.phase() {
            Phase::Working => {
                let t = Instant::now();
                while let Ok(m) = rx.try_recv() {
                    worker.on_msg(m, &mut fx);
                    pump(me, &mut fx, &transport);
                }
                worker.stats_mut().distribute_ns += t.elapsed().as_nanos() as u64;
                if worker.phase() != Phase::Working {
                    continue;
                }
                let t = Instant::now();
                worker.step(&mut fx);
                worker.stats_mut().process_ns += t.elapsed().as_nanos() as u64;
                pump(me, &mut fx, &transport);
            }
            Phase::WaitRandom { .. } | Phase::WaitLifeline { .. } | Phase::Idle => {
                let t = Instant::now();
                let m = rx.recv().expect("mailbox closed while waiting");
                worker.stats_mut().wait_ns += t.elapsed().as_nanos() as u64;
                let t = Instant::now();
                worker.on_msg(m, &mut fx);
                pump(me, &mut fx, &transport);
                worker.stats_mut().distribute_ns += t.elapsed().as_nanos() as u64;
            }
            Phase::Done => break,
        }
    }
    let (queue, stats) = worker.into_parts();
    (queue.result(), stats)
}

/// A mesh link's read side: decode frames from one peer rank straight
/// into this rank's mailboxes. Exits on the peer's EOF (clean teardown)
/// or a protocol violation.
fn mesh_reader<B>(mut stream: TcpStream, my_rank: usize, topo: Topology, local: Mailboxes<B>)
where
    B: WireCodec + Send + 'static,
{
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => return,
        };
        WIRE_RX_BYTES.fetch_add(body.len() as u64 + 4, Ordering::Relaxed);
        let (to, msg) = match wire::decode_data_frame_body::<B>(&body) {
            Ok(x) => x,
            Err(_) => return, // malformed peer; drop the link
        };
        if to >= topo.places() || topo.node_of(to) != my_rank {
            // A frame for a place this rank does not host would need
            // star-style forwarding — which the mesh must never produce.
            MISROUTED_FRAMES.fetch_add(1, Ordering::Relaxed);
            debug_assert!(false, "data frame for place {to} arrived at rank {my_rank}");
            return;
        }
        if let Some(tx) = &local[to] {
            let _ = tx.send(msg);
        }
    }
}

/// Rank 0's per-spoke control servant: barrier arrivals, credit
/// deposits/replenishes, and result collection. Exits on the spoke's
/// clean half-close (after its workers finished) or a violation.
fn control_server(
    mut stream: TcpStream,
    rank: usize,
    root: Arc<CreditRoot>,
    barrier: Arc<StartBarrier>,
    results: ResultSlots,
) {
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => return,
        };
        let ok = match Ctrl::decode(&body) {
            Ok(Ctrl::Ready { .. }) => {
                barrier.arrive_and_wait();
                wire::write_frame(&mut stream, &Ctrl::Go.to_body()).is_ok()
            }
            Ok(Ctrl::Deposit { atoms }) => {
                root.deposit(atoms);
                true
            }
            Ok(Ctrl::Replenish { want }) => {
                let atoms = root.mint(want);
                wire::write_frame(&mut stream, &Ctrl::Grant { atoms }.to_body()).is_ok()
            }
            Ok(Ctrl::Result { bytes }) => {
                results.lock().unwrap()[rank] = Some(bytes);
                true
            }
            _ => false, // protocol violation; drop the link
        };
        if !ok {
            return;
        }
    }
}

/// Accept one fleet connection from a nonblocking `listener` before
/// `deadline`: the stream comes back blocking, nodelay, with its
/// 9-byte `[kind, rank]` handshake already read (under `timeout`, which
/// is left set — callers clear it once their per-kind setup is done).
fn accept_handshake(
    listener: &TcpListener,
    deadline: Instant,
    timeout: Duration,
) -> Result<(TcpStream, u8, usize)> {
    loop {
        match listener.accept() {
            Ok((mut s, _addr)) => {
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                s.set_read_timeout(Some(timeout))?;
                let mut hs = [0u8; 9];
                s.read_exact(&mut hs).context("read fleet handshake")?;
                let r = u64::from_le_bytes(hs[1..].try_into().unwrap()) as usize;
                return Ok((s, hs[0], r));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    bail!("timed out waiting for fleet connection(s)");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn connect_retry(host: &str, port: u16, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect((host, port)) {
            Ok(s) => {
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() > deadline {
                    bail!("could not reach fleet peer at {host}:{port}: {e}");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

fn handshake_bytes(kind: u8, rank: usize) -> [u8; 9] {
    let mut hs = [0u8; 9];
    hs[0] = kind;
    hs[1..].copy_from_slice(&(rank as u64).to_le_bytes());
    hs
}

/// How (whether) per-rank results funnel to rank 0 after the run.
trait ResultPlan<R>: Copy {
    const GATHER: bool;
    fn encode(&self, result: &R) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> Result<R>;
}

/// [`run_sockets`]: every rank keeps its local reduction.
#[derive(Clone, Copy)]
struct LocalOnly;

impl<R> ResultPlan<R> for LocalOnly {
    const GATHER: bool = false;
    fn encode(&self, _result: &R) -> Vec<u8> {
        unreachable!("no result gathering")
    }
    fn decode(&self, _bytes: &[u8]) -> Result<R> {
        unreachable!("no result gathering")
    }
}

/// [`run_sockets_reduced`]: results travel the control link as their
/// wire encoding and rank 0 folds the fleet.
#[derive(Clone, Copy)]
struct GatherWire;

impl<R: WireCodec> ResultPlan<R> for GatherWire {
    const GATHER: bool = true;
    fn encode(&self, result: &R) -> Vec<u8> {
        let mut out = Vec::new();
        result.encode(&mut out);
        out
    }
    fn decode(&self, bytes: &[u8]) -> Result<R> {
        let mut r = wire::Reader::new(bytes);
        let v = R::decode(&mut r).map_err(|e| anyhow!("decode fleet result: {e}"))?;
        if r.remaining() != 0 {
            bail!("trailing bytes after fleet result");
        }
        Ok(v)
    }
}

/// Run this process's share of a fleet-wide GLB computation.
///
/// The factory/root-init/reducer contract matches
/// [`crate::place::run_threads`], with two distributed twists: `factory`
/// is called only for this rank's places (still with global `(place, p)`
/// arguments), and the returned [`RunOutput`] holds the reduction of
/// **this rank's** per-place results plus the local [`RunLog`] — the
/// caller (or the `testkit::fleet` harness) combines ranks. Use
/// [`run_sockets_reduced`] to get the fleet-wide reduction at rank 0
/// instead.
pub fn run_sockets<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sockets_plan(cfg, opts, factory, root_init, reducer, LocalOnly)
}

/// [`run_sockets`] plus a fleet-wide result reduction: every spoke ships
/// its locally reduced result (as its [`WireCodec`] encoding) to rank 0
/// over the control link after the run, and rank 0's [`RunOutput`] holds
/// the reduction over **all** ranks. Spokes still return their local
/// share.
pub fn run_sockets_reduced<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    Q::Result: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sockets_plan(cfg, opts, factory, root_init, reducer, GatherWire)
}

fn run_sockets_plan<Q, R, FQ, FI, P>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    mut factory: FQ,
    root_init: FI,
    reducer: &R,
    plan: P,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
    P: ResultPlan<Q::Result>,
{
    let p = cfg.p;
    let topo = cfg.topology();
    let (rank, ranks) = (opts.rank, opts.ranks);
    if ranks == 0 {
        bail!("a fleet needs at least one rank");
    }
    if rank >= ranks {
        bail!("--rank {rank} out of range for --peers {ranks}");
    }
    if topo.nodes() != ranks {
        bail!(
            "fleet shape mismatch: {p} places at {} workers-per-node is {} nodes, \
             but the fleet has {ranks} ranks",
            cfg.params.workers_per_node,
            topo.nodes(),
        );
    }

    // -- local mailboxes (one per place this rank hosts) ----------------
    let my_places: Vec<PlaceId> = topo.workers_of(rank).collect();
    let mut local_tx: Vec<Option<Sender<Msg<Q::Bag>>>> = (0..p).map(|_| None).collect();
    let mut rxs: Vec<Receiver<Msg<Q::Bag>>> = Vec::with_capacity(my_places.len());
    for &i in &my_places {
        let (tx, rx) = channel();
        local_tx[i] = Some(tx);
        rxs.push(rx);
    }
    let local_tx: Mailboxes<Q::Bag> = Arc::new(local_tx);

    // -- fleet wiring ----------------------------------------------------
    let deadline = Instant::now() + opts.handshake_timeout;
    let mut mesh_readers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut control_servers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let results: ResultSlots = Arc::new(Mutex::new((0..ranks).map(|_| None).collect()));

    let mut links: Vec<Option<Link>> = (0..ranks).map(|_| None).collect();
    let mut mesh_read: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
    let mut ctrl_link: Option<Link> = None;
    let mut root: Option<Arc<CreditRoot>> = None;
    let mut hub_barrier: Option<Arc<StartBarrier>> = None;

    let ledger = if ranks == 1 {
        FleetLedger::Local(AtomicLedger::new())
    } else if rank == 0 {
        // --- bootstrap: accept every control + mesh connection ----------
        let bind_addr = opts.bind.clone().unwrap_or_else(|| opts.host.clone());
        let listener = TcpListener::bind((bind_addr.as_str(), opts.port))
            .with_context(|| format!("bind fleet bootstrap on {bind_addr}:{}", opts.port))?;
        listener.set_nonblocking(true)?;
        let mut ctrl_conns: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
        let mut addrs: Vec<Option<String>> = (0..ranks).map(|_| None).collect();
        addrs[0] = Some(format!("{}:{}", opts.host, listener.local_addr()?.port()));
        for _ in 0..2 * (ranks - 1) {
            let (mut s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            if r == 0 || r >= ranks {
                bail!("fleet handshake from invalid rank {r}");
            }
            match kind {
                HS_CTRL => {
                    if ctrl_conns[r].is_some() {
                        bail!("duplicate control link from rank {r}");
                    }
                    let body = wire::read_frame(&mut s, wire::MAX_FRAME_BYTES)
                        .context("read rank registration")?
                        .ok_or_else(|| anyhow!("rank {r} closed before registering"))?;
                    match Ctrl::decode(&body) {
                        Ok(Ctrl::Register { rank: rr, addr }) if rr as usize == r => {
                            addrs[r] = Some(addr);
                        }
                        other => bail!("rank {r}: expected registration, got {other:?}"),
                    }
                    s.set_read_timeout(None)?;
                    ctrl_conns[r] = Some(s);
                }
                HS_MESH => {
                    if links[r].is_some() {
                        bail!("duplicate mesh link from rank {r}");
                    }
                    s.set_read_timeout(None)?;
                    mesh_read[r] = Some(s.try_clone()?);
                    links[r] = Some(Arc::new(Mutex::new(s)));
                }
                k => bail!("bad fleet handshake kind {k}"),
            }
        }
        // --- publish the peer map; spokes then dial each other ----------
        let addrs: Vec<String> = addrs
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .context("fleet bootstrap finished with unregistered ranks")?;
        let map = Ctrl::PeerMap { addrs }.to_body();
        for (r, conn) in ctrl_conns.iter_mut().enumerate() {
            if let Some(s) = conn {
                wire::write_frame(s, &map).with_context(|| format!("send peer map to rank {r}"))?;
            }
        }
        // --- credit root + per-spoke control servants -------------------
        // Servants must be live before any spoke can replenish or deposit
        // (both possible as soon as that spoke is past the barrier).
        let credit_root = CreditRoot::new();
        credit_root.grant(ranks as u64 * INITIAL_RANK_ATOMS);
        let barrier = Arc::new(StartBarrier::new(ranks));
        for (r, conn) in ctrl_conns.into_iter().enumerate() {
            let Some(conn) = conn else { continue };
            let (rt, b, res) = (credit_root.clone(), barrier.clone(), results.clone());
            control_servers.push(
                std::thread::Builder::new()
                    .name(format!("glb-fleet-ctrl-{r}"))
                    .spawn(move || control_server(conn, r, rt, b, res))
                    .expect("spawn control server"),
            );
        }
        hub_barrier = Some(barrier);
        root = Some(credit_root.clone());
        FleetLedger::Credit(CreditLedger::new(
            Arc::new(RootHome { root: credit_root }),
            INITIAL_RANK_ATOMS,
        ))
    } else {
        // --- spoke: own mesh listener + control link to rank 0 ----------
        let listener = TcpListener::bind(("0.0.0.0", 0)).context("bind mesh listener")?;
        let mesh_port = listener.local_addr()?.port();
        let mut ctrl = connect_retry(&opts.host, opts.port, deadline)?;
        ctrl.write_all(&handshake_bytes(HS_CTRL, rank)).context("send control handshake")?;
        let advertise_ip = match &opts.advertise {
            Some(a) => a.clone(),
            None => ctrl.local_addr()?.ip().to_string(),
        };
        // Mesh link to rank 0 (its address is already known).
        let mut to_hub = connect_retry(&opts.host, opts.port, deadline)?;
        to_hub.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
        mesh_read[0] = Some(to_hub.try_clone()?);
        links[0] = Some(Arc::new(Mutex::new(to_hub)));
        // Register our mesh address, receive everyone's.
        let reg = Ctrl::Register { rank: rank as u64, addr: format!("{advertise_ip}:{mesh_port}") };
        wire::write_frame(&mut ctrl, &reg.to_body()).context("send registration")?;
        ctrl.set_read_timeout(Some(opts.handshake_timeout))?;
        let body = wire::read_frame(&mut ctrl, wire::MAX_FRAME_BYTES)
            .context("read peer map")?
            .ok_or_else(|| anyhow!("bootstrap closed before the peer map"))?;
        let addrs = match Ctrl::decode(&body) {
            Ok(Ctrl::PeerMap { addrs }) if addrs.len() == ranks => addrs,
            other => bail!("expected a {ranks}-rank peer map, got {other:?}"),
        };
        // Dial every lower spoke; accept every higher one. Dials complete
        // through the targets' listen backlogs even before their accept
        // loops run, so the strict ordering cannot deadlock.
        for (r, addr) in addrs.iter().enumerate().take(rank).skip(1) {
            let (host, port) = addr
                .rsplit_once(':')
                .ok_or_else(|| anyhow!("malformed mesh address {addr:?} for rank {r}"))?;
            let port: u16 = port.parse().with_context(|| format!("mesh port in {addr:?}"))?;
            let mut s = connect_retry(host, port, deadline)?;
            s.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
            mesh_read[r] = Some(s.try_clone()?);
            links[r] = Some(Arc::new(Mutex::new(s)));
        }
        listener.set_nonblocking(true)?;
        for _ in 0..ranks - 1 - rank {
            let (s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            s.set_read_timeout(None)?;
            if kind != HS_MESH || r <= rank || r >= ranks {
                bail!("bad mesh handshake (kind {kind}, rank {r})");
            }
            if links[r].is_some() {
                bail!("duplicate mesh link from rank {r}");
            }
            mesh_read[r] = Some(s.try_clone()?);
            links[r] = Some(Arc::new(Mutex::new(s)));
        }
        ctrl.set_read_timeout(None)?;
        let link = Arc::new(Mutex::new(ctrl));
        ctrl_link = Some(link.clone());
        FleetLedger::Credit(CreditLedger::new(Arc::new(CtrlHome { link }), INITIAL_RANK_ATOMS))
    };

    // --- mesh readers: decode peers' frames into our mailboxes ----------
    for (r, read_half) in mesh_read.into_iter().enumerate() {
        let Some(read_half) = read_half else { continue };
        let lt = local_tx.clone();
        mesh_readers.push(
            std::thread::Builder::new()
                .name(format!("glb-mesh-{rank}-{r}"))
                .spawn(move || mesh_reader::<Q::Bag>(read_half, rank, topo, lt))
                .expect("spawn mesh reader"),
        );
    }

    let transport: SocketTransport<Q::Bag> =
        SocketTransport { rank, topo, p, local: local_tx, links: Arc::new(links) };

    // The detector broadcasts Terminate to every place the moment all
    // credit is recovered — the distributed stand-in for the
    // worker-observed zero of the single-process ledgers.
    if let Some(credit_root) = &root {
        let t = transport.clone();
        credit_root.on_quiescent(move || t.terminate_fleet());
    }

    // -- sequential local setup ------------------------------------------
    // Queues and workers are constructed (acquiring initial work tokens
    // against this rank's credit pool) *before* the start barrier, so no
    // rank can be stolen from while half-built.
    let mut queues: Vec<Q> = my_places.iter().map(|&i| factory(i, p)).collect();
    if rank == 0 {
        root_init(&mut queues[0]);
    }
    let node_bag: Option<Arc<NodeBag<Q::Bag>>> =
        if topo.is_flat() { None } else { Some(Arc::new(NodeBag::new())) };
    let mut workers: Vec<Worker<Q, FleetLedger>> = queues
        .into_iter()
        .zip(&my_places)
        .map(|(q, &i)| Worker::with_node_bag(i, p, cfg.params, q, ledger.clone(), node_bag.clone()))
        .collect();

    // -- fleet-wide start barrier ----------------------------------------
    if ranks > 1 {
        if rank == 0 {
            // Arm before any GO can reach a spoke: deposits only start
            // after GO, so detection can never race the fleet start.
            root.as_ref().expect("rank 0 hosts the credit root").arm();
            hub_barrier.as_ref().expect("rank 0 owns the barrier").arrive_and_wait();
        } else {
            let link = ctrl_link.as_ref().expect("spokes hold a control link");
            let mut s = link.lock().unwrap();
            wire::write_frame(&mut *s, &Ctrl::Ready { rank: rank as u64 }.to_body())
                .context("send fleet ready")?;
            let body = wire::read_frame(&mut *s, wire::MAX_FRAME_BYTES)
                .context("await fleet go")?
                .ok_or_else(|| anyhow!("bootstrap closed before go"))?;
            if !matches!(Ctrl::decode(&body), Ok(Ctrl::Go)) {
                bail!("expected the fleet go signal, got another control frame");
            }
        }
    }

    // Kick empty places into the steal protocol (now safe: every rank's
    // workers are constructed and credited).
    let mut fx = Vec::new();
    for w in workers.iter_mut() {
        let me = w.id();
        w.kick_if_empty(&mut fx);
        pump(me, &mut fx, &transport);
    }

    // -- run ---------------------------------------------------------------
    let t0 = Instant::now();
    let handles: Vec<_> = workers
        .into_iter()
        .zip(rxs)
        .map(|(worker, rx)| {
            let transport = transport.clone();
            std::thread::Builder::new()
                .name(format!("glb-sock-{}", worker.id()))
                .stack_size(opts.stack_bytes)
                .spawn(move || socket_place_main(worker, rx, transport))
                .expect("spawn place thread")
        })
        .collect();

    let mut per_place: Vec<(Q::Result, crate::glb::WorkerStats)> =
        handles.into_iter().map(|h| h.join().expect("place thread panicked")).collect();
    let elapsed_ns = t0.elapsed().as_nanos() as u64;

    let stats: Vec<_> = per_place.iter().map(|(_, s)| *s).collect();
    let local_results: Vec<Q::Result> = per_place.drain(..).map(|(r, _)| r).collect();
    let mut result = reducer.reduce_all(local_results);

    // -- result gathering (spoke side; on the still-open control link) ----
    if P::GATHER && ranks > 1 && rank != 0 {
        let link = ctrl_link.as_ref().expect("spokes hold a control link");
        let mut s = link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Result { bytes: plan.encode(&result) }.to_body())
            .context("send fleet result")?;
    }

    // -- teardown ----------------------------------------------------------
    // Half-close everything we write to; readers drain peers to EOF.
    if let Some(link) = &ctrl_link {
        let _ = link.lock().unwrap().shutdown(Shutdown::Write);
    }
    for link in transport.links.iter().flatten() {
        let _ = link.lock().unwrap().shutdown(Shutdown::Write);
    }
    for h in mesh_readers {
        let _ = h.join();
    }
    for h in control_servers {
        let _ = h.join();
    }

    if let Some(credit_root) = &root {
        debug_assert!(credit_root.quiescent(), "all termination credit must be recovered");
        debug_assert_eq!(credit_root.outstanding(), 0, "credit books must balance");
        if P::GATHER {
            let mut slots = results.lock().unwrap();
            let mut all = vec![result];
            for (r, slot) in slots.iter_mut().enumerate().skip(1) {
                let bytes = slot.take().ok_or_else(|| anyhow!("rank {r} sent no result"))?;
                all.push(plan.decode(&bytes).with_context(|| format!("result of rank {r}"))?);
            }
            result = reducer.reduce_all(all);
        }
    }
    debug_assert_eq!(ledger.value(), 0, "local tokens must balance at termination");

    let log = RunLog::with_topology(stats, cfg.params.workers_per_node);
    Ok(RunOutput { result, log, elapsed_ns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::{sequential_count, UtsParams, UtsQueue};
    use crate::glb::task_queue::SumReducer;
    use crate::glb::GlbParams;
    use crate::testkit::fleet::free_port;

    fn up(depth: u32) -> UtsParams {
        UtsParams { b0: 4.0, seed: 19, max_depth: depth }
    }

    fn run_rank(
        rank: usize,
        ranks: usize,
        port: u16,
        params: GlbParams,
        p: usize,
        depth: u32,
    ) -> RunOutput<u64> {
        let cfg = GlbConfig::new(p, params);
        let opts = SocketRunOpts { rank, ranks, port, ..Default::default() };
        run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(depth)), |q| q.init_root(), &SumReducer)
            .expect("fleet rank failed")
    }

    #[test]
    fn single_rank_fleet_matches_sequential() {
        let out = run_rank(0, 1, 0, GlbParams::default().with_n(64), 1, 5);
        assert_eq!(out.result, sequential_count(&up(5)));
    }

    #[test]
    fn two_rank_in_process_fleet_sums_to_sequential() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 2, 6));
        let r0 = run_rank(0, 2, port, params, 2, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        // Loot accounting balances fleet-wide.
        let (t0, t1) = (r0.log.total(), r1.log.total());
        assert_eq!(
            t0.loot_bags_sent + t1.loot_bags_sent,
            t0.loot_bags_received + t1.loot_bags_received,
        );
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn three_rank_mesh_exchanges_directly() {
        // With three ranks every spoke pair owns a direct link; the
        // misrouted counter proves no frame ever needed rank 0's help.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 3, port, params, 3, 6));
        let t2 = std::thread::spawn(move || run_rank(2, 3, port, params, 3, 6));
        let r0 = run_rank(0, 3, port, params, 3, 6);
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();
        assert_eq!(r0.result + r1.result + r2.result, sequential_count(&up(6)));
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn hierarchical_two_rank_fleet_sums_to_sequential() {
        // 2 processes × 2 workers: reps 0 and 2 own the inter-node
        // sockets; workers 1 and 3 share through their process's NodeBag.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2).with_workers_per_node(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 4, 6));
        let r0 = run_rank(0, 2, port, params, 4, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        for out in [&r0, &r1] {
            let t = out.log.total();
            // Node-bag traffic never crosses a process boundary, so it
            // balances within each rank on its own.
            assert_eq!(t.node_donations, t.node_takes);
            assert_eq!(out.log.per_place.len(), 2);
        }
    }

    #[test]
    fn empty_fleet_terminates_cleanly() {
        // No root work anywhere: every worker kicks, all steals are
        // refused across the wire, the last credit deposit reaches the
        // root, and the detector's Terminate reaches both processes.
        let port = free_port();
        let params = GlbParams::default().with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts { rank: 0, ranks: 2, port, ..Default::default() };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, 0);
    }

    #[test]
    fn bind_address_splits_from_advertised_host() {
        // The rank-0 bind/advertise fix: bind the wildcard while
        // advertising (and dialing) loopback — before the split this
        // required --host to be locally bindable.
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(5)), |q| q.init_root(), &SumReducer)
                .unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts {
            rank: 0,
            ranks: 2,
            port,
            host: "127.0.0.1".into(),
            bind: Some("0.0.0.0".into()),
            ..Default::default()
        };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(5)), |q| q.init_root(), &SumReducer)
                .unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(5)));
    }

    #[test]
    fn reduced_run_folds_the_fleet_at_rank0() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let spawn_rank = move |rank: usize| {
            std::thread::spawn(move || {
                let cfg = GlbConfig::new(3, params);
                let opts = SocketRunOpts { rank, ranks: 3, port, ..Default::default() };
                run_sockets_reduced(
                    &cfg,
                    &opts,
                    |_, _| UtsQueue::new(up(6)),
                    |q| q.init_root(),
                    &SumReducer,
                )
                .unwrap()
            })
        };
        let t1 = spawn_rank(1);
        let t2 = spawn_rank(2);
        let cfg = GlbConfig::new(3, params);
        let opts = SocketRunOpts { rank: 0, ranks: 3, port, ..Default::default() };
        let r0 = run_sockets_reduced(
            &cfg,
            &opts,
            |_, _| UtsQueue::new(up(6)),
            |q| q.init_root(),
            &SumReducer,
        )
        .unwrap();
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();
        let expect = sequential_count(&up(6));
        assert_eq!(r0.result, expect, "rank 0 holds the fleet-wide reduction");
        assert!(r1.result <= expect && r2.result <= expect, "spokes keep local shares");
    }

    #[test]
    fn fleet_shape_mismatch_is_an_error() {
        let cfg = GlbConfig::new(4, GlbParams::default());
        let opts = SocketRunOpts { rank: 0, ranks: 3, ..Default::default() };
        let err =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(3)), |q| q.init_root(), &SumReducer)
                .unwrap_err();
        assert!(format!("{err:#}").contains("fleet shape"), "{err:#}");
    }
}
