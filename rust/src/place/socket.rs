//! TCP socket place-runtime: one OS **process** per GLB node, wired as a
//! direct spoke-to-spoke **mesh** with credit-based distributed
//! termination.
//!
//! This is the process-spanning `Transport` the ROADMAP calls for: the
//! same [`Worker`] protocol engine as the thread runtime and the
//! simulator, but with nodes living in separate OS processes that talk
//! over length-prefixed TCP frames ([`crate::glb::wire`]). A fleet of
//! `ranks` processes runs one GLB *node* each (so with
//! `workers_per_node > 1` every process hosts several worker threads
//! sharing a [`NodeBag`], and only the node's representative speaks the
//! inter-node protocol).
//!
//! ## Fleet wiring (bootstrap star, steady-state mesh)
//!
//! Rank 0 is **bootstrap and discovery only** — after the start barrier
//! no steal/loot/refusal byte transits it on behalf of other ranks:
//!
//! 1. every rank binds its own mesh listener; spokes dial rank 0 and
//!    [`Ctrl::Register`] their advertised `ip:port`;
//! 2. rank 0 answers with the [`Ctrl::PeerMap`]; each rank then dials
//!    every lower rank and accepts every higher one, building one duplex
//!    TCP link per pair (dials succeed through listen backlogs, so the
//!    strict ordering cannot deadlock);
//! 3. data frames are `[to: u64][msg body]` under a length prefix, sent
//!    on the pair's own link and decoded only at the destination — a
//!    frame for a place the receiving rank does not host is a protocol
//!    violation (counted in [`misrouted_frames`], asserted zero by the
//!    fleet tests).
//!
//! Rank 0 keeps binding separate from advertising: it binds
//! [`SocketRunOpts::bind`] (default: the advertised host) so
//! `--host <public-ip>` works on machines where that address is not
//! locally bindable (`--bind 0.0.0.0`).
//!
//! ## Termination: credit throwing instead of a hub ledger
//!
//! The work-token count (paper §2.4 item 3) is distributed via
//! Mattern-style credit throwing ([`crate::glb::termination`]): every
//! rank runs a [`CreditLedger`] whose `incr`/`decr` are **local** (no
//! I/O), loot messages carry credit atoms in their wire envelope, and a
//! rank that goes idle deposits its atoms to rank 0's [`CreditRoot`]
//! asynchronously on the control link. The root observes
//! `recovered == total` exactly when no rank holds a token and no loot
//! is in flight, then broadcasts `Terminate` to every place over the
//! mesh. The only synchronous credit operation left is the
//! pool-exhaustion [`Ctrl::Replenish`], amortized over many cross-rank
//! loot sends (worst-case cadence documented at
//! [`crate::glb::termination::MAX_ATTACH_ATOMS`]) — nothing here does a
//! synchronous RPC per steal/loot event the way the old hub ledger did.
//!
//! A fleet-wide start barrier ([`Ctrl::Ready`]/[`Ctrl::Go`] on the
//! control link) preserves the thread runtime's sequential-setup
//! guarantee: no rank enters the steal protocol until every rank has
//! constructed its workers and holds its initial tokens and credit.
//!
//! Teardown mirrors the protocol's own guarantee that no message is in
//! flight after `Terminate`: every rank half-closes the write side of
//! all its links; mesh readers drain to EOF; rank 0's control servers
//! exit on their spoke's EOF (after optionally collecting the rank's
//! encoded result for the fleet-wide reduction of
//! [`run_sockets_reduced`]).

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::glb::message::{Effect, Msg, PlaceId};
use crate::glb::task_queue::{Reducer, TaskQueue};
use crate::glb::termination::{
    AtomicLedger, CreditHome, CreditLedger, CreditRoot, Ledger, INITIAL_RANK_ATOMS,
};
use crate::glb::topology::{NodeBag, Topology};
use crate::glb::wire::{self, Ctrl, WireCodec};
use crate::glb::worker::{Phase, Worker};
use crate::glb::{GlbConfig, RunLog, RunOutput};
use crate::place::membership::{DynamicMembership, MembershipProvider};
use crate::testkit::chaos;

/// How this process joins the fleet.
#[derive(Debug, Clone)]
pub struct SocketRunOpts {
    /// This process's rank (= its GLB node id). Rank 0 is bootstrap +
    /// credit root.
    pub rank: usize,
    /// Total processes in the fleet (= GLB node count).
    pub ranks: usize,
    /// Rank 0's *advertised* host: what every other rank dials for
    /// bootstrap, and what the peer map lists as rank 0's mesh address.
    pub host: String,
    /// Rank 0's rendezvous port. `0` (rank 0 only, single-rank fleets)
    /// binds an ephemeral port.
    pub port: u16,
    /// Rank 0's *bind* address. `None` binds `host`; set it (CLI default
    /// `0.0.0.0` when `--host` is given) when the advertised address is
    /// not locally bindable — NAT'd hosts, load-balanced VIPs, or plain
    /// `--host <public-ip>` on a box that only has the private interface.
    pub bind: Option<String>,
    /// This rank's advertised mesh IP (spokes). `None` advertises the
    /// interface this host reaches rank 0 from — right for localhost
    /// fleets and single-homed hosts alike.
    pub advertise: Option<String>,
    /// How long to wait for the whole fleet to connect / handshake.
    pub handshake_timeout: Duration,
    /// Per-place worker thread stack size in bytes.
    pub stack_bytes: usize,
    /// How many rank deaths (rank 0 excluded — the bootstrap/credit root
    /// dying is always fatal) the fleet absorbs by reconfiguring instead
    /// of failing. `0` (default) keeps the historical fail-fast
    /// semantics byte-for-byte; `> 0` requires a gathered run
    /// ([`run_sockets_reduced`]) with one worker per node.
    pub tolerate_failures: usize,
}

impl Default for SocketRunOpts {
    fn default() -> Self {
        Self {
            rank: 0,
            ranks: 1,
            host: "127.0.0.1".into(),
            port: 0,
            bind: None,
            advertise: None,
            handshake_timeout: Duration::from_secs(30),
            stack_bytes: 2 << 20,
            tolerate_failures: 0,
        }
    }
}

// Handshake connection kinds.
const HS_CTRL: u8 = 0;
const HS_MESH: u8 = 1;

/// Data frames that arrived at a rank not hosting their destination
/// place — star-style relay traffic, which the mesh must never produce.
/// Monotonic per process; the fleet integration tests assert it stays
/// zero on every rank.
static MISROUTED_FRAMES: AtomicU64 = AtomicU64::new(0);

/// Data frames this process received for places it does not host (see
/// [`MISROUTED_FRAMES`]). Zero on every rank of a healthy mesh.
pub fn misrouted_frames() -> u64 {
    MISROUTED_FRAMES.load(Ordering::Relaxed)
}

/// Mesh data-plane bytes this process has put on / taken off the wire
/// (frame bodies plus their 4-byte length prefix; control-link traffic
/// is bootstrap-only and excluded). Monotonic per process — one GLB run
/// per process, so the totals are per-run in practice; the fleet
/// launcher rolls them into its report.
static WIRE_TX_BYTES: AtomicU64 = AtomicU64::new(0);
static WIRE_RX_BYTES: AtomicU64 = AtomicU64::new(0);

/// `(sent, received)` mesh data bytes for this process (see
/// [`WIRE_TX_BYTES`]).
pub fn wire_bytes() -> (u64, u64) {
    (WIRE_TX_BYTES.load(Ordering::Relaxed), WIRE_RX_BYTES.load(Ordering::Relaxed))
}

/// A shared, mutex-serialized write half of a TCP link.
type Link = Arc<Mutex<TcpStream>>;
/// Mailbox sender per *global* place id (`None` for remote places).
type Mailboxes<B> = Arc<Vec<Option<Sender<Msg<B>>>>>;
/// Per-rank slots for gathered result payloads (rank 0 only).
type ResultSlots = Arc<Mutex<Vec<Option<Vec<u8>>>>>;

/// The work-token ledger, as seen from one fleet process.
#[derive(Clone)]
enum FleetLedger {
    /// Single-rank fleet: the plain in-process counter.
    Local(Arc<AtomicLedger>),
    /// Mesh member: rank-local credit ledger (see module docs).
    Credit(Arc<CreditLedger>),
}

impl Ledger for FleetLedger {
    fn incr(&self) {
        match self {
            FleetLedger::Local(l) => l.incr(),
            FleetLedger::Credit(l) => l.incr(),
        }
    }

    fn decr(&self) -> bool {
        match self {
            FleetLedger::Local(l) => l.decr(),
            FleetLedger::Credit(l) => l.decr(),
        }
    }

    fn value(&self) -> i64 {
        match self {
            FleetLedger::Local(l) => l.value(),
            FleetLedger::Credit(l) => l.value(),
        }
    }

    fn export_credit(&self) -> u64 {
        match self {
            FleetLedger::Local(l) => l.export_credit(),
            FleetLedger::Credit(l) => l.export_credit(),
        }
    }

    fn import_credit(&self, atoms: u64) {
        match self {
            FleetLedger::Local(l) => l.import_credit(atoms),
            FleetLedger::Credit(l) => l.import_credit(atoms),
        }
    }
}

/// A spoke's credit home: async deposits and the rare synchronous
/// replenish, both on the control link. Panics on I/O failure — a dead
/// control link loses termination credit, which is unrecoverable (the
/// fleet could never quiesce), and all credit traffic stops before
/// teardown.
struct CtrlHome {
    link: Link,
}

impl CreditHome for CtrlHome {
    fn deposit(&self, atoms: u64) {
        let mut s = self.link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Deposit { atoms }.to_body())
            .expect("fleet control link lost (deposit)");
        drop(s);
        chaos::die_point(chaos::DURING_DEPOSIT);
    }

    fn replenish(&self, want: u64) -> u64 {
        let mut s = self.link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Replenish { want }.to_body())
            .expect("fleet control link lost (replenish)");
        let body = wire::read_frame(&mut *s, wire::MAX_FRAME_BYTES)
            .expect("fleet control link lost (grant)")
            .expect("fleet control link closed awaiting grant");
        match Ctrl::decode(&body) {
            Ok(Ctrl::Grant { atoms }) => atoms,
            other => panic!("expected credit grant, got {other:?}"),
        }
    }
}

/// A tolerant spoke's credit home. The synchronous [`CtrlHome`] cannot
/// be used once the control link carries asynchronous recovery traffic
/// ([`Ctrl::Leave`], forwarded [`Ctrl::Ack`]s): a blocking read-for-grant
/// would swallow them. The spoke's control reader thread owns the read
/// half instead and routes every [`Ctrl::Grant`] through a channel.
struct TolerantCtrlHome {
    link: Link,
    grants: Mutex<Receiver<u64>>,
}

impl CreditHome for TolerantCtrlHome {
    fn deposit(&self, atoms: u64) {
        let mut s = self.link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Deposit { atoms }.to_body())
            .expect("fleet control link lost (deposit)");
        drop(s);
        chaos::die_point(chaos::DURING_DEPOSIT);
    }

    fn replenish(&self, want: u64) -> u64 {
        let rx = self.grants.lock().unwrap();
        {
            let mut s = self.link.lock().unwrap();
            wire::write_frame(&mut *s, &Ctrl::Replenish { want }.to_body())
                .expect("fleet control link lost (replenish)");
        }
        rx.recv().expect("fleet control link closed awaiting grant")
    }
}

/// Rank 0's credit home: the root lives in-process.
struct RootHome {
    root: Arc<CreditRoot>,
}

impl CreditHome for RootHome {
    fn deposit(&self, atoms: u64) {
        self.root.deposit(atoms);
    }

    fn replenish(&self, want: u64) -> u64 {
        self.root.mint(want)
    }
}

/// One retained loot send: the serialized stolen bag, kept until the
/// destination acknowledges having merged it (or dies, at which point
/// the bag is re-imported locally so its work is never lost).
struct RetainedLoot {
    /// 1-based send sequence number toward this peer.
    seq: u64,
    /// Credit atoms the message carried ([`Ledger::export_credit`]).
    credit: u64,
    /// The bag's [`WireCodec`] encoding (bytes, so the bookkeeping stays
    /// non-generic; decoded only on re-import, where the bag type is
    /// known).
    body: Vec<u8>,
}

/// This rank's outbound loot book for one peer. Mesh links and mailboxes
/// are FIFO, so the receiver's cumulative merged-bag count identifies
/// exactly which retained entries its banked result already covers.
#[derive(Default)]
struct PeerLedger {
    /// Set once the peer is known dead: entries drained, sends guarded.
    dead: bool,
    /// Loot bags sent to this peer (the `seq` counter).
    sent: u64,
    /// Credit atoms ever attached to loot for this peer.
    attached: u64,
    /// Unacknowledged sends, in `seq` order.
    entries: VecDeque<RetainedLoot>,
}

impl PeerLedger {
    /// The peer banked `upto` merged bags: drop the covered entries.
    fn prune(&mut self, upto: u64) {
        while self.entries.front().is_some_and(|e| e.seq <= upto) {
            self.entries.pop_front();
        }
    }
}

/// The one steal request this rank's worker may have in flight, mirrored
/// outside the worker so a dead victim's never-coming response can be
/// synthesized as a refusal. Cleared by the mesh reader the moment the
/// real response is delivered, so a surviving record is always fresh.
struct PendingSteal {
    dest_rank: usize,
    victim: PlaceId,
    lifeline: bool,
    nonce: u64,
}

/// A latch the recovery path waits on: the mesh reader from a dead peer
/// must drain to EOF (delivering every frame the peer managed to send)
/// before the retention ledger is reconciled.
#[derive(Default)]
struct ReaderDone {
    done: Mutex<bool>,
    cv: Condvar,
}

impl ReaderDone {
    fn mark(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let mut d = self.done.lock().unwrap();
        while !*d {
            d = self.cv.wait(d).unwrap();
        }
    }
}

/// Everything a crash-tolerant rank tracks beyond the normal runtime:
/// the membership view, per-peer retention ledgers, inbound credit and
/// merge books, and the mirrored outstanding steal. Shared (non-generic)
/// across the worker thread, mesh readers, and the recovery thread.
struct RankRecovery {
    rank: usize,
    membership: Arc<DynamicMembership>,
    ledgers: Vec<Mutex<PeerLedger>>,
    /// Credit atoms delivered *from* each peer, counted at the mesh
    /// reader (not at merge): a bag still sitting in the mailbox is
    /// already this rank's responsibility, and the reconcile books must
    /// say so or the root would reclaim its credit twice.
    recv_credit: Vec<AtomicU64>,
    /// Cross-rank loot bags merged per victim rank — the cumulative
    /// counts banked in every [`Ctrl::Ack`].
    merged: Vec<AtomicU64>,
    pending: Mutex<Option<PendingSteal>>,
    reader_done: Vec<ReaderDone>,
}

impl RankRecovery {
    fn new(rank: usize, ranks: usize, membership: Arc<DynamicMembership>) -> Arc<Self> {
        let rec = Arc::new(Self {
            rank,
            membership,
            ledgers: (0..ranks).map(|_| Mutex::new(PeerLedger::default())).collect(),
            recv_credit: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            merged: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
            pending: Mutex::new(None),
            reader_done: (0..ranks).map(|_| ReaderDone::default()).collect(),
        });
        rec.reader_done[rank].mark(); // no link to ourselves
        rec
    }

    /// Is `rank` still a member? (Cheap enough for the send path: one
    /// short mutex hold on the per-peer ledger.)
    fn peer_dead(&self, rank: usize) -> bool {
        self.ledgers[rank].lock().unwrap().dead
    }

    /// The peer acknowledged `upto` merged bags from us.
    fn prune(&self, peer: usize, upto: u64) {
        self.ledgers[peer].lock().unwrap().prune(upto);
    }

    /// Mark `dead` dead and take its unacknowledged entries. Returns the
    /// entries plus this rank's net reconcile books for the dead peer:
    /// `(sent, received)` credit, with the re-imported (returned) entries
    /// already subtracted from `sent`.
    fn drain(&self, dead: usize) -> (Vec<RetainedLoot>, u64, u64) {
        self.reader_done[dead].wait();
        let (entries, sent) = {
            let mut l = self.ledgers[dead].lock().unwrap();
            l.dead = true;
            let entries: Vec<RetainedLoot> = std::mem::take(&mut l.entries).into();
            let reimported: u64 = entries.iter().map(|e| e.credit).sum();
            (entries, l.attached - reimported)
        };
        let received = self.recv_credit[dead].load(Ordering::SeqCst);
        (entries, sent, received)
    }
}

/// All ranks construct their workers (holding their initial tokens and
/// credit) before any rank steals.
struct StartBarrier {
    arrived: Mutex<usize>,
    cv: Condvar,
    total: usize,
}

impl StartBarrier {
    fn new(total: usize) -> Self {
        Self { arrived: Mutex::new(0), cv: Condvar::new(), total }
    }

    fn arrive_and_wait(&self) {
        let mut n = self.arrived.lock().unwrap();
        *n += 1;
        if *n >= self.total {
            self.cv.notify_all();
        }
        while *n < self.total {
            n = self.cv.wait(n).unwrap();
        }
    }
}

/// The per-process message fabric: local mailboxes for this rank's
/// places, one direct mesh link per remote rank.
struct SocketTransport<B> {
    rank: usize,
    topo: Topology,
    p: usize,
    local: Mailboxes<B>,
    links: Arc<Vec<Option<Link>>>,
    /// Crash-tolerance books; `None` keeps the fail-fast send path.
    recovery: Option<Arc<RankRecovery>>,
}

impl<B> Clone for SocketTransport<B> {
    fn clone(&self) -> Self {
        Self {
            rank: self.rank,
            topo: self.topo,
            p: self.p,
            local: self.local.clone(),
            links: self.links.clone(),
            recovery: self.recovery.clone(),
        }
    }
}

impl<B: WireCodec> SocketTransport<B> {
    /// Send `msg` to place `to` — the local mailbox, or the destination
    /// rank's own mesh link (never a relay). Best-effort on I/O failure:
    /// writes only fail once the peer is gone, at which point the run is
    /// already lost, exactly like the thread runtime's mailbox sends.
    fn send(&self, to: PlaceId, msg: Msg<B>) {
        let dest_rank = self.topo.node_of(to);
        if dest_rank == self.rank {
            self.deliver_local(to, msg);
            return;
        }
        match &self.recovery {
            Some(rec) => self.send_guarded(&rec.clone(), dest_rank, to, msg),
            None => {
                let is_steal = matches!(msg, Msg::Steal { .. });
                self.send_wire(dest_rank, to, &msg);
                if is_steal {
                    chaos::die_point(chaos::MID_STEAL);
                }
            }
        }
    }

    fn deliver_local(&self, to: PlaceId, msg: Msg<B>) {
        if let Some(tx) = &self.local[to] {
            let _ = tx.send(msg);
        }
    }

    fn send_wire(&self, dest_rank: usize, to: PlaceId, msg: &Msg<B>) {
        let body = wire::encode_data_frame_body(to, msg);
        if let Some(link) = &self.links[dest_rank] {
            let mut s = link.lock().unwrap();
            if wire::write_frame(&mut *s, &body).is_ok() {
                WIRE_TX_BYTES.fetch_add(body.len() as u64 + 4, Ordering::Relaxed);
            }
        }
    }

    /// The crash-tolerant send path. Loot bags to live peers are
    /// retained (serialized) until acknowledged; traffic to a dead peer
    /// is answered on its behalf — a steal gets an instant refusal, a
    /// loot bag is re-imported locally (with its credit), refusals and
    /// `Terminate` evaporate.
    fn send_guarded(&self, rec: &Arc<RankRecovery>, dest_rank: usize, to: PlaceId, msg: Msg<B>) {
        // Tolerant fleets run one worker per node, so this rank's only
        // place doubles as its node representative.
        let me = self.topo.representative(self.rank);
        match msg {
            Msg::Steal { thief, lifeline, nonce } => {
                let guard = rec.ledgers[dest_rank].lock().unwrap();
                if guard.dead {
                    drop(guard);
                    self.deliver_local(
                        me,
                        Msg::Loot {
                            victim: to,
                            bag: None,
                            lifeline,
                            nonce: Some(nonce),
                            credit: 0,
                        },
                    );
                    return;
                }
                // Mirror the outstanding request while the ledger lock
                // orders us against the drain: either the drain sees this
                // record, or we saw `dead` above — never neither.
                *rec.pending.lock().unwrap() =
                    Some(PendingSteal { dest_rank, victim: to, lifeline, nonce });
                self.send_wire(dest_rank, to, &Msg::Steal { thief, lifeline, nonce });
                drop(guard);
                chaos::die_point(chaos::MID_STEAL);
            }
            Msg::Loot { victim, bag: Some(bag), lifeline, nonce, credit } => {
                let mut body = Vec::new();
                bag.encode(&mut body);
                let mut guard = rec.ledgers[dest_rank].lock().unwrap();
                if guard.dead {
                    drop(guard);
                    self.deliver_local(
                        me,
                        Msg::Loot {
                            victim: me,
                            bag: Some(bag),
                            lifeline: false,
                            nonce: None,
                            credit,
                        },
                    );
                    return;
                }
                guard.sent += 1;
                guard.attached += credit;
                let seq = guard.sent;
                guard.entries.push_back(RetainedLoot { seq, credit, body });
                self.send_wire(
                    dest_rank,
                    to,
                    &Msg::Loot { victim, bag: Some(bag), lifeline, nonce, credit },
                );
            }
            Msg::Loot { bag: None, .. } | Msg::Terminate => {
                if !rec.peer_dead(dest_rank) {
                    self.send_wire(dest_rank, to, &msg);
                }
            }
        }
    }

    /// A peer died: pull back every unacknowledged loot bag this rank
    /// sent it (re-delivering each to our own mailbox with its credit),
    /// synthesize the refusal for a steal still outstanding toward it,
    /// and return the `(sent, received)` credit books for the
    /// [`Ctrl::Reconcile`] — `sent` net of the re-imported entries.
    fn recover_dead_peer(&self, rec: &Arc<RankRecovery>, dead: usize) -> (u64, u64) {
        let me = self.topo.representative(self.rank);
        let (entries, sent, received) = rec.drain(dead);
        for e in entries {
            let mut r = wire::Reader::new(&e.body);
            let bag = match B::decode(&mut r) {
                Ok(b) => b,
                Err(err) => {
                    eprintln!("glb: retained bag for dead rank {dead} is corrupt: {err}");
                    std::process::exit(1);
                }
            };
            self.deliver_local(
                me,
                Msg::Loot { victim: me, bag: Some(bag), lifeline: false, nonce: None, credit: e.credit },
            );
        }
        let pending = {
            let mut p = rec.pending.lock().unwrap();
            if p.as_ref().is_some_and(|ps| ps.dest_rank == dead) {
                p.take()
            } else {
                None
            }
        };
        if let Some(ps) = pending {
            self.deliver_local(
                me,
                Msg::Loot {
                    victim: ps.victim,
                    bag: None,
                    lifeline: ps.lifeline,
                    nonce: Some(ps.nonce),
                    credit: 0,
                },
            );
        }
        (sent, received)
    }

    /// The worker-observed quiescence broadcast — only reachable in
    /// single-rank fleets (mesh fleets detect at the credit root).
    fn broadcast_terminate(&self, me: PlaceId) {
        for i in (0..self.p).filter(|&i| i != me) {
            self.send(i, Msg::Terminate);
        }
    }

    /// The credit root observed global quiescence: tell every place in
    /// the fleet (rank 0's own included) to finish.
    fn terminate_fleet(&self) {
        for i in 0..self.p {
            self.send(i, Msg::Terminate);
        }
    }
}

/// Carry out a worker's requested effects.
fn pump<B: WireCodec>(me: PlaceId, fx: &mut Vec<Effect<B>>, transport: &SocketTransport<B>) {
    for e in fx.drain(..) {
        match e {
            Effect::Send { to, msg } => {
                debug_assert_ne!(to, me, "no self-sends in the protocol");
                transport.send(to, msg);
            }
            Effect::Quiescent => transport.broadcast_terminate(me),
        }
    }
}

/// The crash-tolerance hooks one worker thread carries.
struct TolerantWorker {
    rec: Arc<RankRecovery>,
    ack: AckOut,
}

/// Where a worker's idle-point acks go.
enum AckOut {
    /// A spoke acks on its own control link: a result snapshot plus the
    /// cumulative per-victim merged-bag counts (the victims prune their
    /// retention ledgers; the root banks the result for the gather in
    /// case this rank dies later).
    Spoke(Link),
    /// Rank 0 acks straight to each victim spoke's control link — merge
    /// counts only, since the root's own death is always fatal and its
    /// partial result is never needed from a bank.
    Root(Arc<Vec<Option<Link>>>),
}

/// Count a cross-rank loot bag against its victim's rank *before* the
/// worker merges it: these cumulative counts are what the next ack
/// banks, so they must never run ahead of the banked result snapshot —
/// and they cannot, because the snapshot is taken after the merge.
fn note_merge<B: WireCodec>(
    tol: &Option<TolerantWorker>,
    transport: &SocketTransport<B>,
    my_rank: usize,
    msg: &Msg<B>,
) {
    let Some(t) = tol else { return };
    if let Msg::Loot { victim, bag: Some(_), .. } = msg {
        let vr = transport.topo.node_of(*victim);
        if vr != my_rank {
            t.rec.merged[vr].fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Bank an idle-point checkpoint. Called at every Working-exit edge,
/// where the local bag is empty — so the result snapshot covers exactly
/// the acked merges, and a death any time before the *next* merge loses
/// nothing: senders re-import everything past these counts.
fn emit_ack<Q, P>(
    worker: &Worker<Q, FleetLedger>,
    tol: &Option<TolerantWorker>,
    plan: P,
    my_rank: usize,
    acked_upto: &mut [u64],
) where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    P: ResultPlan<Q::Result>,
{
    let Some(t) = tol else { return };
    match &t.ack {
        AckOut::Spoke(link) => {
            let mut acked = Vec::new();
            for (r, m) in t.rec.merged.iter().enumerate() {
                let m = m.load(Ordering::SeqCst);
                if m > 0 && r != my_rank {
                    acked.push((r as u64, m));
                }
            }
            let result = plan.encode(&worker.queue().result());
            let frame = Ctrl::Ack { rank: my_rank as u64, result, acked }.to_body();
            wire::write_frame(&mut *link.lock().unwrap(), &frame)
                .expect("fleet control link lost (ack)");
        }
        AckOut::Root(links) => {
            for (r, m) in t.rec.merged.iter().enumerate() {
                let m = m.load(Ordering::SeqCst);
                if m > acked_upto[r] {
                    acked_upto[r] = m;
                    if let Some(link) = &links[r] {
                        let frame =
                            Ctrl::Ack { rank: 0, result: Vec::new(), acked: vec![(r as u64, m)] }
                                .to_body();
                        let _ = wire::write_frame(&mut *link.lock().unwrap(), &frame);
                    }
                }
            }
        }
    }
}

/// Per-place worker thread body (mirror of the thread runtime's
/// `place_main`, driving the same engine over the socket fabric).
fn socket_place_main<Q, P>(
    mut worker: Worker<Q, FleetLedger>,
    rx: Receiver<Msg<Q::Bag>>,
    transport: SocketTransport<Q::Bag>,
    tol: Option<TolerantWorker>,
    plan: P,
) -> (Q::Result, crate::glb::WorkerStats)
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    P: ResultPlan<Q::Result>,
{
    let me = worker.id();
    let my_rank = transport.rank;
    let mut fx: Vec<Effect<Q::Bag>> = Vec::with_capacity(8);
    let mut acked_upto: Vec<u64> =
        tol.as_ref().map(|t| vec![0; t.rec.merged.len()]).unwrap_or_default();
    let mut seen_epoch = 0u64;
    loop {
        // Safe-point re-knit: only between protocol episodes (Working /
        // Idle — never with a steal outstanding, whose response still
        // references the old victim set). A Wait* phase defers to the
        // next episode; liveness holds because a dead victim's response
        // is synthesized by the recovery path.
        if let Some(t) = &tol {
            if matches!(worker.phase(), Phase::Working | Phase::Idle)
                && t.rec.membership.epoch() != seen_epoch
            {
                let view = t.rec.membership.view();
                seen_epoch = view.epoch;
                worker.rewire(&view.members());
            }
        }
        match worker.phase() {
            Phase::Working => {
                let t0 = Instant::now();
                while let Ok(m) = rx.try_recv() {
                    note_merge(&tol, &transport, my_rank, &m);
                    worker.on_msg(m, &mut fx);
                    pump(me, &mut fx, &transport);
                }
                worker.stats_mut().distribute_ns += t0.elapsed().as_nanos() as u64;
                if worker.phase() != Phase::Working {
                    emit_ack(&worker, &tol, plan, my_rank, &mut acked_upto);
                    continue;
                }
                let t0 = Instant::now();
                worker.step(&mut fx);
                worker.stats_mut().process_ns += t0.elapsed().as_nanos() as u64;
                if worker.phase() != Phase::Working {
                    // Bank the exit-point snapshot *before* the pending
                    // steal below leaves this rank: a mid-steal death
                    // then loses only work that senders still retain.
                    emit_ack(&worker, &tol, plan, my_rank, &mut acked_upto);
                }
                pump(me, &mut fx, &transport);
            }
            Phase::WaitRandom { .. } | Phase::WaitLifeline { .. } | Phase::Idle => {
                if worker.phase() == Phase::Idle {
                    chaos::die_point(chaos::WHILE_IDLE);
                }
                let t0 = Instant::now();
                let m = rx.recv().expect("mailbox closed while waiting");
                worker.stats_mut().wait_ns += t0.elapsed().as_nanos() as u64;
                let t0 = Instant::now();
                note_merge(&tol, &transport, my_rank, &m);
                worker.on_msg(m, &mut fx);
                pump(me, &mut fx, &transport);
                worker.stats_mut().distribute_ns += t0.elapsed().as_nanos() as u64;
            }
            Phase::Done => break,
        }
    }
    let (queue, stats) = worker.into_parts();
    (queue.result(), stats)
}

/// A mesh link's read side: decode frames from one peer rank straight
/// into this rank's mailboxes. Exits on the peer's EOF (clean teardown,
/// or the peer's death), a connection error, or a protocol violation.
/// Under crash tolerance it additionally keeps the recovery books: it
/// clears the mirrored outstanding steal when the real response lands
/// (so a later synthesized refusal can never be stale) and counts the
/// credit delivered from this peer; its exit latch gates the drain.
fn mesh_reader<B>(
    stream: TcpStream,
    my_rank: usize,
    peer: usize,
    topo: Topology,
    local: Mailboxes<B>,
    recovery: Option<Arc<RankRecovery>>,
) where
    B: WireCodec + Send + 'static,
{
    mesh_reader_loop(stream, my_rank, peer, topo, local, recovery.as_ref());
    if let Some(rec) = &recovery {
        rec.reader_done[peer].mark();
    }
}

fn mesh_reader_loop<B>(
    mut stream: TcpStream,
    my_rank: usize,
    peer: usize,
    topo: Topology,
    local: Mailboxes<B>,
    recovery: Option<&Arc<RankRecovery>>,
) where
    B: WireCodec + Send + 'static,
{
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => return,
        };
        WIRE_RX_BYTES.fetch_add(body.len() as u64 + 4, Ordering::Relaxed);
        let (to, msg) = match wire::decode_data_frame_body::<B>(&body) {
            Ok(x) => x,
            Err(_) => return, // malformed peer; drop the link
        };
        if to >= topo.places() || topo.node_of(to) != my_rank {
            // A frame for a place this rank does not host would need
            // star-style forwarding — which the mesh must never produce.
            MISROUTED_FRAMES.fetch_add(1, Ordering::Relaxed);
            debug_assert!(false, "data frame for place {to} arrived at rank {my_rank}");
            return;
        }
        if let Some(rec) = recovery {
            if let Msg::Loot { nonce: Some(n), .. } = &msg {
                let mut p = rec.pending.lock().unwrap();
                if p.as_ref().is_some_and(|ps| ps.dest_rank == peer && ps.nonce == *n) {
                    *p = None;
                }
            }
            if let Msg::Loot { bag: Some(_), credit, .. } = &msg {
                rec.recv_credit[peer].fetch_add(*credit, Ordering::SeqCst);
            }
        }
        if let Some(tx) = &local[to] {
            let _ = tx.send(msg);
        }
    }
}

/// Rank 0's shared crash-tolerance state (tolerant fleets only).
struct RootTolerant {
    recovery: Arc<RankRecovery>,
    /// Write halves of every spoke's control link (slot 0 is `None`):
    /// the coordinator broadcasts Leave/PeerMap here, and control
    /// servants forward acks victim-ward.
    ctrl_links: Arc<Vec<Option<Link>>>,
    /// Credit atoms granted to each rank (initial endowment + mints).
    granted: Vec<AtomicU64>,
    /// Credit atoms each rank deposited back to the root's pool.
    deposited: Vec<AtomicU64>,
    /// Latest acked result snapshot per rank: what the gather falls
    /// back to when the rank dies after its last idle point.
    ack_bank: Mutex<Vec<Option<Vec<u8>>>>,
}

/// Per-control-servant handle on the tolerant state. The channel
/// senders live *only* in servant threads (plus the pre-spawn original,
/// dropped immediately), so the coordinator's `death_rx` disconnects —
/// and its thread exits — exactly when the last servant does.
#[derive(Clone)]
struct CtrlTol {
    shared: Arc<RootTolerant>,
    death_tx: Sender<usize>,
    reconcile_tx: Sender<(usize, u64, u64)>,
}

/// Rank 0's per-spoke control servant: barrier arrivals, credit
/// deposits/replenishes, and result collection. Exits on the spoke's
/// clean half-close (after its workers finished) or a violation — in a
/// tolerant fleet, a close *before* the spoke's result arrived is
/// reported to the coordinator as that rank's death.
fn control_server(
    mut stream: TcpStream,
    link: Link,
    rank: usize,
    root: Arc<CreditRoot>,
    barrier: Arc<StartBarrier>,
    results: ResultSlots,
    tol: Option<CtrlTol>,
) {
    let mut saw_result = false;
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => break,
        };
        let ok = match Ctrl::decode(&body) {
            Ok(Ctrl::Ready { .. }) => {
                barrier.arrive_and_wait();
                wire::write_frame(&mut *link.lock().unwrap(), &Ctrl::Go.to_body()).is_ok()
            }
            Ok(Ctrl::Deposit { atoms }) => {
                if let Some(t) = &tol {
                    t.shared.deposited[rank].fetch_add(atoms, Ordering::SeqCst);
                }
                root.deposit(atoms);
                true
            }
            Ok(Ctrl::Replenish { want }) => {
                let atoms = root.mint(want);
                if let Some(t) = &tol {
                    t.shared.granted[rank].fetch_add(atoms, Ordering::SeqCst);
                }
                wire::write_frame(&mut *link.lock().unwrap(), &Ctrl::Grant { atoms }.to_body())
                    .is_ok()
            }
            Ok(Ctrl::Result { bytes }) => {
                results.lock().unwrap()[rank] = Some(bytes);
                saw_result = true;
                true
            }
            Ok(Ctrl::Ack { rank: _, result, acked }) if tol.is_some() => {
                // Bank the spoke's idle-point snapshot, then forward each
                // (victim, merged-count) to its victim so retention
                // ledgers shrink. Forwarding is best-effort: a victim
                // already gone keeps (or loses) its ledger harmlessly.
                let t = tol.as_ref().unwrap();
                t.shared.ack_bank.lock().unwrap()[rank] = Some(result);
                for (victim, merged) in acked {
                    if victim == 0 {
                        t.shared.recovery.prune(rank, merged);
                    } else if let Some(vl) =
                        t.shared.ctrl_links.get(victim as usize).and_then(|l| l.as_ref())
                    {
                        let fwd = Ctrl::Ack {
                            rank: rank as u64,
                            result: Vec::new(),
                            acked: vec![(victim, merged)],
                        }
                        .to_body();
                        let _ = wire::write_frame(&mut *vl.lock().unwrap(), &fwd);
                    }
                }
                true
            }
            Ok(Ctrl::Reconcile { rank: r, sent, received }) if tol.is_some() => tol
                .as_ref()
                .unwrap()
                .reconcile_tx
                .send((r as usize, sent, received))
                .is_ok(),
            _ => false, // protocol violation; drop the link
        };
        if !ok {
            break;
        }
    }
    if let Some(t) = &tol {
        if !saw_result {
            let _ = t.death_tx.send(rank);
        }
    }
}

/// A tolerant spoke's control-link reader, spawned once the barrier has
/// released: grants for the replenish RPC, ack forwards, and the root's
/// Leave broadcasts (which trigger local recovery + a Reconcile reply).
fn spoke_ctrl_reader<B>(
    mut stream: TcpStream,
    my_rank: usize,
    transport: SocketTransport<B>,
    rec: Arc<RankRecovery>,
    grant_tx: Sender<u64>,
    link: Link,
    shutting_down: Arc<AtomicBool>,
) where
    B: WireCodec + Send + 'static,
{
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => {
                if shutting_down.load(Ordering::SeqCst) {
                    return;
                }
                // The root died (or dropped us): always fatal.
                eprintln!("glb rank {my_rank}: lost the fleet control link");
                std::process::exit(1);
            }
        };
        match Ctrl::decode(&body) {
            Ok(Ctrl::Grant { atoms }) => {
                // Receiver gone means no ledger is waiting: ignore.
                let _ = grant_tx.send(atoms);
            }
            Ok(Ctrl::Leave { rank: dead, .. }) => {
                let dead = dead as usize;
                rec.membership.leave(dead);
                let (sent, received) = transport.recover_dead_peer(&rec, dead);
                let reply =
                    Ctrl::Reconcile { rank: my_rank as u64, sent, received }.to_body();
                wire::write_frame(&mut *link.lock().unwrap(), &reply)
                    .expect("fleet control link lost (reconcile)");
            }
            Ok(Ctrl::Ack { rank: thief, acked, .. }) => {
                for (victim, merged) in acked {
                    if victim as usize == my_rank && (thief as usize) < rec.ledgers.len() {
                        rec.prune(thief as usize, merged);
                    }
                }
            }
            Ok(Ctrl::PeerMap { .. }) => {
                // Post-recovery epoch republication: informational (the
                // Leave already carried the transition); accepted so a
                // future join path can reuse the frame.
            }
            other => {
                eprintln!("glb rank {my_rank}: unexpected control frame {other:?}");
                std::process::exit(1);
            }
        }
    }
}

/// Rank 0's recovery coordinator: serializes rank deaths. For each
/// death — detected by that rank's control servant exiting resultless —
/// it retires the rank, broadcasts the Leave, runs the root's own
/// recovery, collects every survivor's Reconcile, audits the dead
/// rank's credit books, and reclaims the missing atoms so the credit
/// proof (and with it exact termination) survives the crash.
fn root_coordinator<B>(
    transport: SocketTransport<B>,
    tol: Arc<RootTolerant>,
    root: Arc<CreditRoot>,
    death_rx: Receiver<usize>,
    reconcile_rx: Receiver<(usize, u64, u64)>,
    tolerate: usize,
    reconcile_timeout: Duration,
) where
    B: WireCodec + Send + 'static,
{
    let rec = &tol.recovery;
    let mut deaths = 0usize;
    while let Ok(dead) = death_rx.recv() {
        deaths += 1;
        if deaths > tolerate {
            eprintln!(
                "glb fleet: rank {dead} died; {deaths} death(s) exceeds --tolerate-failures"
            );
            std::process::exit(1);
        }
        let Some(view) = rec.membership.leave(dead) else { continue };
        eprintln!(
            "glb fleet: rank {dead} died; re-knitting {} survivor(s) at epoch {}",
            view.members().len(),
            view.epoch,
        );
        let leave = Ctrl::Leave { epoch: view.epoch, rank: dead as u64 }.to_body();
        for r in view.members() {
            if r == 0 {
                continue;
            }
            if let Some(link) = &tol.ctrl_links[r] {
                let _ = wire::write_frame(&mut *link.lock().unwrap(), &leave);
            }
        }
        // The root's own books for the dead peer, then every survivor's.
        let (sent0, recv0) = transport.recover_dead_peer(rec, dead);
        let mut net = sent0 as i128 - recv0 as i128;
        let deadline = Instant::now() + reconcile_timeout;
        for _ in 0..view.members().len().saturating_sub(1) {
            let wait = deadline.saturating_duration_since(Instant::now());
            match reconcile_rx.recv_timeout(wait) {
                Ok((_, sent, received)) => net += sent as i128 - received as i128,
                Err(_) => {
                    eprintln!("glb fleet: reconcile after rank {dead}'s death timed out");
                    std::process::exit(1);
                }
            }
        }
        // Atoms the dead rank held = granted − deposited ± in-flight.
        let atoms = tol.granted[dead].load(Ordering::SeqCst) as i128
            - tol.deposited[dead].load(Ordering::SeqCst) as i128
            + net;
        if atoms < 0 {
            eprintln!("glb fleet: credit books negative after rank {dead}'s death");
            std::process::exit(1);
        }
        root.reclaim(atoms as u64);
        // Republish the epoch-stamped view (informational; the Leave
        // frames already drove every survivor's transition).
        let map = Ctrl::PeerMap {
            epoch: view.epoch,
            addrs: view.addrs.iter().map(|a| a.clone().unwrap_or_default()).collect(),
        }
        .to_body();
        for r in view.members() {
            if r == 0 {
                continue;
            }
            if let Some(link) = &tol.ctrl_links[r] {
                let _ = wire::write_frame(&mut *link.lock().unwrap(), &map);
            }
        }
    }
}

/// Accept one fleet connection from a nonblocking `listener` before
/// `deadline`: the stream comes back blocking, nodelay, with its
/// 9-byte `[kind, rank]` handshake already read (under `timeout`, which
/// is left set — callers clear it once their per-kind setup is done).
fn accept_handshake(
    listener: &TcpListener,
    deadline: Instant,
    timeout: Duration,
) -> Result<(TcpStream, u8, usize)> {
    loop {
        match listener.accept() {
            Ok((mut s, _addr)) => {
                s.set_nonblocking(false)?;
                s.set_nodelay(true)?;
                s.set_read_timeout(Some(timeout))?;
                let mut hs = [0u8; 9];
                s.read_exact(&mut hs).context("read fleet handshake")?;
                let r = u64::from_le_bytes(hs[1..].try_into().unwrap()) as usize;
                return Ok((s, hs[0], r));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() > deadline {
                    bail!("timed out waiting for fleet connection(s)");
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn connect_retry(host: &str, port: u16, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect((host, port)) {
            Ok(s) => {
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() > deadline {
                    bail!("could not reach fleet peer at {host}:{port}: {e}");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

fn handshake_bytes(kind: u8, rank: usize) -> [u8; 9] {
    let mut hs = [0u8; 9];
    hs[0] = kind;
    hs[1..].copy_from_slice(&(rank as u64).to_le_bytes());
    hs
}

/// How (whether) per-rank results funnel to rank 0 after the run.
trait ResultPlan<R>: Copy {
    const GATHER: bool;
    fn encode(&self, result: &R) -> Vec<u8>;
    fn decode(&self, bytes: &[u8]) -> Result<R>;
}

/// [`run_sockets`]: every rank keeps its local reduction.
#[derive(Clone, Copy)]
struct LocalOnly;

impl<R> ResultPlan<R> for LocalOnly {
    const GATHER: bool = false;
    fn encode(&self, _result: &R) -> Vec<u8> {
        unreachable!("no result gathering")
    }
    fn decode(&self, _bytes: &[u8]) -> Result<R> {
        unreachable!("no result gathering")
    }
}

/// [`run_sockets_reduced`]: results travel the control link as their
/// wire encoding and rank 0 folds the fleet.
#[derive(Clone, Copy)]
struct GatherWire;

impl<R: WireCodec> ResultPlan<R> for GatherWire {
    const GATHER: bool = true;
    fn encode(&self, result: &R) -> Vec<u8> {
        let mut out = Vec::new();
        result.encode(&mut out);
        out
    }
    fn decode(&self, bytes: &[u8]) -> Result<R> {
        let mut r = wire::Reader::new(bytes);
        let v = R::decode(&mut r).map_err(|e| anyhow!("decode fleet result: {e}"))?;
        if r.remaining() != 0 {
            bail!("trailing bytes after fleet result");
        }
        Ok(v)
    }
}

/// Run this process's share of a fleet-wide GLB computation.
///
/// The factory/root-init/reducer contract matches
/// [`crate::place::run_threads`], with two distributed twists: `factory`
/// is called only for this rank's places (still with global `(place, p)`
/// arguments), and the returned [`RunOutput`] holds the reduction of
/// **this rank's** per-place results plus the local [`RunLog`] — the
/// caller (or the `testkit::fleet` harness) combines ranks. Use
/// [`run_sockets_reduced`] to get the fleet-wide reduction at rank 0
/// instead.
pub fn run_sockets<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sockets_plan(cfg, opts, factory, root_init, reducer, LocalOnly)
}

/// [`run_sockets`] plus a fleet-wide result reduction: every spoke ships
/// its locally reduced result (as its [`WireCodec`] encoding) to rank 0
/// over the control link after the run, and rank 0's [`RunOutput`] holds
/// the reduction over **all** ranks. Spokes still return their local
/// share.
pub fn run_sockets_reduced<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    Q::Result: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_sockets_plan(cfg, opts, factory, root_init, reducer, GatherWire)
}

fn run_sockets_plan<Q, R, FQ, FI, P>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    mut factory: FQ,
    root_init: FI,
    reducer: &R,
    plan: P,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
    P: ResultPlan<Q::Result>,
{
    let p = cfg.p;
    let topo = cfg.topology();
    let (rank, ranks) = (opts.rank, opts.ranks);
    if ranks == 0 {
        bail!("a fleet needs at least one rank");
    }
    if rank >= ranks {
        bail!("--rank {rank} out of range for --peers {ranks}");
    }
    if topo.nodes() != ranks {
        bail!(
            "fleet shape mismatch: {p} places at {} workers-per-node is {} nodes, \
             but the fleet has {ranks} ranks",
            cfg.params.workers_per_node,
            topo.nodes(),
        );
    }
    let tolerant = opts.tolerate_failures > 0 && ranks > 1;
    if tolerant && !P::GATHER {
        bail!(
            "--tolerate-failures needs a gathered run (run_sockets_reduced): \
             recovery banks per-rank result snapshots at rank 0"
        );
    }
    if tolerant && cfg.params.workers_per_node != 1 {
        bail!("--tolerate-failures requires one worker per node");
    }
    chaos::arm(rank);

    // -- local mailboxes (one per place this rank hosts) ----------------
    let my_places: Vec<PlaceId> = topo.workers_of(rank).collect();
    let mut local_tx: Vec<Option<Sender<Msg<Q::Bag>>>> = (0..p).map(|_| None).collect();
    let mut rxs: Vec<Receiver<Msg<Q::Bag>>> = Vec::with_capacity(my_places.len());
    for &i in &my_places {
        let (tx, rx) = channel();
        local_tx[i] = Some(tx);
        rxs.push(rx);
    }
    let local_tx: Mailboxes<Q::Bag> = Arc::new(local_tx);

    // -- fleet wiring ----------------------------------------------------
    let deadline = Instant::now() + opts.handshake_timeout;
    let mut mesh_readers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut control_servers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let results: ResultSlots = Arc::new(Mutex::new((0..ranks).map(|_| None).collect()));

    let mut links: Vec<Option<Link>> = (0..ranks).map(|_| None).collect();
    let mut mesh_read: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
    let mut ctrl_link: Option<Link> = None;
    let mut root: Option<Arc<CreditRoot>> = None;
    let mut hub_barrier: Option<Arc<StartBarrier>> = None;

    // Crash-tolerance state (all `None`/unused unless `tolerant`).
    let mut recovery: Option<Arc<RankRecovery>> = None;
    let mut root_tol: Option<Arc<RootTolerant>> = None;
    let mut death_rx: Option<Receiver<usize>> = None;
    let mut reconcile_rx: Option<Receiver<(usize, u64, u64)>> = None;
    let mut spoke_ctrl_read: Option<TcpStream> = None;
    let mut grant_tx: Option<Sender<u64>> = None;

    let ledger = if ranks == 1 {
        FleetLedger::Local(AtomicLedger::new())
    } else if rank == 0 {
        // --- bootstrap: accept every control + mesh connection ----------
        let bind_addr = opts.bind.clone().unwrap_or_else(|| opts.host.clone());
        let listener = TcpListener::bind((bind_addr.as_str(), opts.port))
            .with_context(|| format!("bind fleet bootstrap on {bind_addr}:{}", opts.port))?;
        listener.set_nonblocking(true)?;
        let mut ctrl_conns: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
        let mut addrs: Vec<Option<String>> = (0..ranks).map(|_| None).collect();
        addrs[0] = Some(format!("{}:{}", opts.host, listener.local_addr()?.port()));
        for _ in 0..2 * (ranks - 1) {
            let (mut s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            if r == 0 || r >= ranks {
                bail!("fleet handshake from invalid rank {r}");
            }
            match kind {
                HS_CTRL => {
                    if ctrl_conns[r].is_some() {
                        bail!("duplicate control link from rank {r}");
                    }
                    let body = wire::read_frame(&mut s, wire::MAX_FRAME_BYTES)
                        .context("read rank registration")?
                        .ok_or_else(|| anyhow!("rank {r} closed before registering"))?;
                    match Ctrl::decode(&body) {
                        Ok(Ctrl::Register { rank: rr, addr }) if rr as usize == r => {
                            addrs[r] = Some(addr);
                        }
                        other => bail!("rank {r}: expected registration, got {other:?}"),
                    }
                    s.set_read_timeout(None)?;
                    ctrl_conns[r] = Some(s);
                }
                HS_MESH => {
                    if links[r].is_some() {
                        bail!("duplicate mesh link from rank {r}");
                    }
                    s.set_read_timeout(None)?;
                    mesh_read[r] = Some(s.try_clone()?);
                    links[r] = Some(Arc::new(Mutex::new(s)));
                }
                k => bail!("bad fleet handshake kind {k}"),
            }
        }
        // --- publish the peer map; spokes then dial each other ----------
        let addrs: Vec<String> = addrs
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .context("fleet bootstrap finished with unregistered ranks")?;
        let map = Ctrl::PeerMap { epoch: 0, addrs: addrs.clone() }.to_body();
        for (r, conn) in ctrl_conns.iter_mut().enumerate() {
            if let Some(s) = conn {
                wire::write_frame(s, &map).with_context(|| format!("send peer map to rank {r}"))?;
            }
        }
        // Write halves of the spokes' control links, shared between each
        // servant and (tolerant fleets) the coordinator + rank 0's acks.
        let mut ctrl_writers: Vec<Option<Link>> = Vec::with_capacity(ranks);
        for conn in &ctrl_conns {
            ctrl_writers.push(match conn {
                Some(s) => Some(Arc::new(Mutex::new(
                    s.try_clone().context("clone control link write half")?,
                ))),
                None => None,
            });
        }
        let ctrl_links: Arc<Vec<Option<Link>>> = Arc::new(ctrl_writers);
        // --- credit root + per-spoke control servants -------------------
        // Servants must be live before any spoke can replenish or deposit
        // (both possible as soon as that spoke is past the barrier).
        let credit_root = CreditRoot::new();
        credit_root.grant(ranks as u64 * INITIAL_RANK_ATOMS);
        let barrier = Arc::new(StartBarrier::new(ranks));
        let mut ctrl_tol: Option<CtrlTol> = None;
        if tolerant {
            let membership = Arc::new(DynamicMembership::new(addrs));
            let rec = RankRecovery::new(rank, ranks, membership);
            let shared = Arc::new(RootTolerant {
                recovery: rec.clone(),
                ctrl_links: ctrl_links.clone(),
                granted: (0..ranks).map(|_| AtomicU64::new(INITIAL_RANK_ATOMS)).collect(),
                deposited: (0..ranks).map(|_| AtomicU64::new(0)).collect(),
                ack_bank: Mutex::new((0..ranks).map(|_| None).collect()),
            });
            let (dtx, drx) = channel();
            let (rtx, rrx) = channel();
            ctrl_tol = Some(CtrlTol { shared: shared.clone(), death_tx: dtx, reconcile_tx: rtx });
            recovery = Some(rec);
            root_tol = Some(shared);
            death_rx = Some(drx);
            reconcile_rx = Some(rrx);
        }
        for (r, conn) in ctrl_conns.into_iter().enumerate() {
            let Some(conn) = conn else { continue };
            let link = ctrl_links[r].clone().expect("registered rank has a control link");
            let (rt, b, res) = (credit_root.clone(), barrier.clone(), results.clone());
            let tol = ctrl_tol.clone();
            control_servers.push(
                std::thread::Builder::new()
                    .name(format!("glb-fleet-ctrl-{r}"))
                    .spawn(move || control_server(conn, link, r, rt, b, res, tol))
                    .expect("spawn control server"),
            );
        }
        // Drop the pre-spawn senders: from here the coordinator's
        // death_rx disconnects exactly when the last servant exits.
        drop(ctrl_tol);
        hub_barrier = Some(barrier);
        root = Some(credit_root.clone());
        FleetLedger::Credit(CreditLedger::new(
            Arc::new(RootHome { root: credit_root }),
            INITIAL_RANK_ATOMS,
        ))
    } else {
        // --- spoke: own mesh listener + control link to rank 0 ----------
        let listener = TcpListener::bind(("0.0.0.0", 0)).context("bind mesh listener")?;
        let mesh_port = listener.local_addr()?.port();
        let mut ctrl = connect_retry(&opts.host, opts.port, deadline)?;
        ctrl.write_all(&handshake_bytes(HS_CTRL, rank)).context("send control handshake")?;
        let advertise_ip = match &opts.advertise {
            Some(a) => a.clone(),
            None => ctrl.local_addr()?.ip().to_string(),
        };
        // Mesh link to rank 0 (its address is already known).
        let mut to_hub = connect_retry(&opts.host, opts.port, deadline)?;
        to_hub.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
        mesh_read[0] = Some(to_hub.try_clone()?);
        links[0] = Some(Arc::new(Mutex::new(to_hub)));
        // Register our mesh address, receive everyone's.
        let reg = Ctrl::Register { rank: rank as u64, addr: format!("{advertise_ip}:{mesh_port}") };
        wire::write_frame(&mut ctrl, &reg.to_body()).context("send registration")?;
        ctrl.set_read_timeout(Some(opts.handshake_timeout))?;
        let body = wire::read_frame(&mut ctrl, wire::MAX_FRAME_BYTES)
            .context("read peer map")?
            .ok_or_else(|| anyhow!("bootstrap closed before the peer map"))?;
        let addrs = match Ctrl::decode(&body) {
            Ok(Ctrl::PeerMap { epoch: 0, addrs }) if addrs.len() == ranks => addrs,
            other => bail!("expected a {ranks}-rank peer map, got {other:?}"),
        };
        // Dial every lower spoke; accept every higher one. Dials complete
        // through the targets' listen backlogs even before their accept
        // loops run, so the strict ordering cannot deadlock.
        for (r, addr) in addrs.iter().enumerate().take(rank).skip(1) {
            let (host, port) = addr
                .rsplit_once(':')
                .ok_or_else(|| anyhow!("malformed mesh address {addr:?} for rank {r}"))?;
            let port: u16 = port.parse().with_context(|| format!("mesh port in {addr:?}"))?;
            let mut s = connect_retry(host, port, deadline)?;
            s.write_all(&handshake_bytes(HS_MESH, rank)).context("send mesh handshake")?;
            mesh_read[r] = Some(s.try_clone()?);
            links[r] = Some(Arc::new(Mutex::new(s)));
        }
        listener.set_nonblocking(true)?;
        for _ in 0..ranks - 1 - rank {
            let (s, kind, r) = accept_handshake(&listener, deadline, opts.handshake_timeout)?;
            s.set_read_timeout(None)?;
            if kind != HS_MESH || r <= rank || r >= ranks {
                bail!("bad mesh handshake (kind {kind}, rank {r})");
            }
            if links[r].is_some() {
                bail!("duplicate mesh link from rank {r}");
            }
            mesh_read[r] = Some(s.try_clone()?);
            links[r] = Some(Arc::new(Mutex::new(s)));
        }
        ctrl.set_read_timeout(None)?;
        if tolerant {
            let membership = Arc::new(DynamicMembership::new(addrs));
            recovery = Some(RankRecovery::new(rank, ranks, membership));
            spoke_ctrl_read = Some(ctrl.try_clone().context("clone control link read half")?);
        }
        let link = Arc::new(Mutex::new(ctrl));
        ctrl_link = Some(link.clone());
        if tolerant {
            // A dedicated reader thread owns the link post-barrier, so
            // grants arrive via a channel instead of a synchronous read.
            let (gtx, grx) = channel();
            grant_tx = Some(gtx);
            FleetLedger::Credit(CreditLedger::new(
                Arc::new(TolerantCtrlHome { link, grants: Mutex::new(grx) }),
                INITIAL_RANK_ATOMS,
            ))
        } else {
            FleetLedger::Credit(CreditLedger::new(Arc::new(CtrlHome { link }), INITIAL_RANK_ATOMS))
        }
    };

    // --- mesh readers: decode peers' frames into our mailboxes ----------
    for (r, read_half) in mesh_read.into_iter().enumerate() {
        let Some(read_half) = read_half else { continue };
        let lt = local_tx.clone();
        let rec = recovery.clone();
        mesh_readers.push(
            std::thread::Builder::new()
                .name(format!("glb-mesh-{rank}-{r}"))
                .spawn(move || mesh_reader::<Q::Bag>(read_half, rank, r, topo, lt, rec))
                .expect("spawn mesh reader"),
        );
    }

    let transport: SocketTransport<Q::Bag> = SocketTransport {
        rank,
        topo,
        p,
        local: local_tx,
        links: Arc::new(links),
        recovery: recovery.clone(),
    };

    // The detector broadcasts Terminate to every place the moment all
    // credit is recovered — the distributed stand-in for the
    // worker-observed zero of the single-process ledgers.
    if let Some(credit_root) = &root {
        let t = transport.clone();
        credit_root.on_quiescent(move || t.terminate_fleet());
    }

    // -- sequential local setup ------------------------------------------
    // Queues and workers are constructed (acquiring initial work tokens
    // against this rank's credit pool) *before* the start barrier, so no
    // rank can be stolen from while half-built.
    let mut queues: Vec<Q> = my_places.iter().map(|&i| factory(i, p)).collect();
    if rank == 0 {
        root_init(&mut queues[0]);
    }
    let node_bag: Option<Arc<NodeBag<Q::Bag>>> =
        if topo.is_flat() { None } else { Some(Arc::new(NodeBag::new())) };
    let mut workers: Vec<Worker<Q, FleetLedger>> = queues
        .into_iter()
        .zip(&my_places)
        .map(|(q, &i)| Worker::with_node_bag(i, p, cfg.params, q, ledger.clone(), node_bag.clone()))
        .collect();

    // -- fleet-wide start barrier ----------------------------------------
    if ranks > 1 {
        if rank == 0 {
            // Arm before any GO can reach a spoke: deposits only start
            // after GO, so detection can never race the fleet start.
            root.as_ref().expect("rank 0 hosts the credit root").arm();
            hub_barrier.as_ref().expect("rank 0 owns the barrier").arrive_and_wait();
        } else {
            let link = ctrl_link.as_ref().expect("spokes hold a control link");
            let mut s = link.lock().unwrap();
            wire::write_frame(&mut *s, &Ctrl::Ready { rank: rank as u64 }.to_body())
                .context("send fleet ready")?;
            loop {
                let body = wire::read_frame(&mut *s, wire::MAX_FRAME_BYTES)
                    .context("await fleet go")?
                    .ok_or_else(|| anyhow!("bootstrap closed before go"))?;
                match Ctrl::decode(&body) {
                    Ok(Ctrl::Go) => break,
                    // Rank 0's worker can reach an idle point (and ack)
                    // before our Go write lands; pre-Go this rank has
                    // sent no loot, so there is nothing to prune.
                    Ok(Ctrl::Ack { .. }) if tolerant => continue,
                    _ => bail!("expected the fleet go signal, got another control frame"),
                }
            }
        }
    }

    // -- crash-tolerance service threads ---------------------------------
    let shutting_down = Arc::new(AtomicBool::new(false));
    let mut spoke_reader: Option<std::thread::JoinHandle<()>> = None;
    let mut coordinator: Option<std::thread::JoinHandle<()>> = None;
    if tolerant {
        if rank == 0 {
            let t = transport.clone();
            let tolr = root_tol.clone().expect("tolerant root state");
            let rt = root.clone().expect("rank 0 hosts the credit root");
            let drx = death_rx.take().expect("tolerant root death channel");
            let rrx = reconcile_rx.take().expect("tolerant root reconcile channel");
            let tolerate = opts.tolerate_failures;
            let timeout = opts.handshake_timeout;
            coordinator = Some(
                std::thread::Builder::new()
                    .name("glb-fleet-recovery".into())
                    .spawn(move || {
                        root_coordinator::<Q::Bag>(t, tolr, rt, drx, rrx, tolerate, timeout)
                    })
                    .expect("spawn recovery coordinator"),
            );
        } else {
            let stream = spoke_ctrl_read.take().expect("tolerant spokes hold a reader clone");
            let t = transport.clone();
            let rec = recovery.clone().expect("tolerant spokes hold recovery state");
            let gtx = grant_tx.take().expect("tolerant spokes hold the grant sender");
            let link = ctrl_link.clone().expect("spokes hold a control link");
            let sd = shutting_down.clone();
            spoke_reader = Some(
                std::thread::Builder::new()
                    .name(format!("glb-fleet-ctrl-rx-{rank}"))
                    .spawn(move || {
                        spoke_ctrl_reader::<Q::Bag>(stream, rank, t, rec, gtx, link, sd)
                    })
                    .expect("spawn spoke control reader"),
            );
        }
    }

    // Kick empty places into the steal protocol (now safe: every rank's
    // workers are constructed and credited).
    let mut fx = Vec::new();
    for w in workers.iter_mut() {
        let me = w.id();
        w.kick_if_empty(&mut fx);
        pump(me, &mut fx, &transport);
    }

    // -- run ---------------------------------------------------------------
    let t0 = Instant::now();
    let mut tol_worker: Option<TolerantWorker> = recovery.as_ref().map(|rec| TolerantWorker {
        rec: rec.clone(),
        ack: if rank == 0 {
            AckOut::Root(root_tol.as_ref().expect("tolerant root state").ctrl_links.clone())
        } else {
            AckOut::Spoke(ctrl_link.clone().expect("spokes hold a control link"))
        },
    });
    let handles: Vec<_> = workers
        .into_iter()
        .zip(rxs)
        .map(|(worker, rx)| {
            let transport = transport.clone();
            let tol = tol_worker.take(); // tolerant fleets run one worker per rank
            std::thread::Builder::new()
                .name(format!("glb-sock-{}", worker.id()))
                .stack_size(opts.stack_bytes)
                .spawn(move || socket_place_main(worker, rx, transport, tol, plan))
                .expect("spawn place thread")
        })
        .collect();

    let mut per_place: Vec<(Q::Result, crate::glb::WorkerStats)> =
        handles.into_iter().map(|h| h.join().expect("place thread panicked")).collect();
    let elapsed_ns = t0.elapsed().as_nanos() as u64;

    let stats: Vec<_> = per_place.iter().map(|(_, s)| *s).collect();
    let local_results: Vec<Q::Result> = per_place.drain(..).map(|(r, _)| r).collect();
    let mut result = reducer.reduce_all(local_results);

    // -- result gathering (spoke side; on the still-open control link) ----
    if P::GATHER && ranks > 1 && rank != 0 {
        let link = ctrl_link.as_ref().expect("spokes hold a control link");
        let mut s = link.lock().unwrap();
        wire::write_frame(&mut *s, &Ctrl::Result { bytes: plan.encode(&result) }.to_body())
            .context("send fleet result")?;
    }

    // -- teardown ----------------------------------------------------------
    // Half-close everything we write to; readers drain peers to EOF.
    // From here a control-link EOF is an orderly shutdown, not a death.
    shutting_down.store(true, Ordering::SeqCst);
    if let Some(link) = &ctrl_link {
        let _ = link.lock().unwrap().shutdown(Shutdown::Write);
    }
    for link in transport.links.iter().flatten() {
        let _ = link.lock().unwrap().shutdown(Shutdown::Write);
    }
    for h in mesh_readers {
        let _ = h.join();
    }
    for h in control_servers {
        let _ = h.join();
    }
    if let Some(h) = coordinator {
        // Joins cleanly: the last control servant's exit dropped the last
        // death sender, so the coordinator's recv loop has ended.
        let _ = h.join();
    }
    if let Some(tolr) = &root_tol {
        // Hand surviving spokes' control readers their EOF.
        for link in tolr.ctrl_links.iter().flatten() {
            let _ = link.lock().unwrap().shutdown(Shutdown::Write);
        }
    }
    if let Some(h) = spoke_reader {
        let _ = h.join();
    }

    if let Some(credit_root) = &root {
        debug_assert!(credit_root.quiescent(), "all termination credit must be recovered");
        debug_assert_eq!(credit_root.outstanding(), 0, "credit books must balance");
        if P::GATHER {
            let view = recovery.as_ref().map(|rec| rec.membership.view());
            let mut banked =
                root_tol.as_ref().map(|t| std::mem::take(&mut *t.ack_bank.lock().unwrap()));
            let mut slots = results.lock().unwrap();
            let mut all = vec![result];
            for (r, slot) in slots.iter_mut().enumerate().skip(1) {
                match slot.take() {
                    Some(bytes) => all
                        .push(plan.decode(&bytes).with_context(|| format!("result of rank {r}"))?),
                    None if view.as_ref().is_some_and(|v| !v.alive(r)) => {
                        // Dead rank: its last banked idle-point snapshot
                        // covers exactly its acked merges. Everything it
                        // merged after that ack stayed in the senders'
                        // retention ledgers and was re-imported, so even
                        // a rank that never acked folds in as nothing.
                        if let Some(bytes) = banked.as_mut().and_then(|b| b[r].take()) {
                            all.push(
                                plan.decode(&bytes)
                                    .with_context(|| format!("banked result of rank {r}"))?,
                            );
                        }
                    }
                    None => bail!("rank {r} sent no result"),
                }
            }
            result = reducer.reduce_all(all);
        }
    }
    debug_assert_eq!(ledger.value(), 0, "local tokens must balance at termination");

    let log = RunLog::with_topology(stats, cfg.params.workers_per_node);
    Ok(RunOutput { result, log, elapsed_ns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::{sequential_count, UtsParams, UtsQueue};
    use crate::glb::task_queue::SumReducer;
    use crate::glb::GlbParams;
    use crate::testkit::fleet::free_port;

    fn up(depth: u32) -> UtsParams {
        UtsParams { b0: 4.0, seed: 19, max_depth: depth }
    }

    fn run_rank(
        rank: usize,
        ranks: usize,
        port: u16,
        params: GlbParams,
        p: usize,
        depth: u32,
    ) -> RunOutput<u64> {
        let cfg = GlbConfig::new(p, params);
        let opts = SocketRunOpts { rank, ranks, port, ..Default::default() };
        run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(depth)), |q| q.init_root(), &SumReducer)
            .expect("fleet rank failed")
    }

    #[test]
    fn single_rank_fleet_matches_sequential() {
        let out = run_rank(0, 1, 0, GlbParams::default().with_n(64), 1, 5);
        assert_eq!(out.result, sequential_count(&up(5)));
    }

    #[test]
    fn two_rank_in_process_fleet_sums_to_sequential() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 2, 6));
        let r0 = run_rank(0, 2, port, params, 2, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        // Loot accounting balances fleet-wide.
        let (t0, t1) = (r0.log.total(), r1.log.total());
        assert_eq!(
            t0.loot_bags_sent + t1.loot_bags_sent,
            t0.loot_bags_received + t1.loot_bags_received,
        );
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn three_rank_mesh_exchanges_directly() {
        // With three ranks every spoke pair owns a direct link; the
        // misrouted counter proves no frame ever needed rank 0's help.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 3, port, params, 3, 6));
        let t2 = std::thread::spawn(move || run_rank(2, 3, port, params, 3, 6));
        let r0 = run_rank(0, 3, port, params, 3, 6);
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();
        assert_eq!(r0.result + r1.result + r2.result, sequential_count(&up(6)));
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn hierarchical_two_rank_fleet_sums_to_sequential() {
        // 2 processes × 2 workers: reps 0 and 2 own the inter-node
        // sockets; workers 1 and 3 share through their process's NodeBag.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2).with_workers_per_node(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 4, 6));
        let r0 = run_rank(0, 2, port, params, 4, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        for out in [&r0, &r1] {
            let t = out.log.total();
            // Node-bag traffic never crosses a process boundary, so it
            // balances within each rank on its own.
            assert_eq!(t.node_donations, t.node_takes);
            assert_eq!(out.log.per_place.len(), 2);
        }
    }

    #[test]
    fn tolerant_fleet_without_deaths_matches_sequential() {
        // The crash-tolerant machinery (retention ledgers, idle-point
        // acks, channel-routed grants) engaged but unexercised: the
        // gathered result must match the fail-fast path exactly.
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let run = move |rank: usize| {
            let cfg = GlbConfig::new(3, params);
            let opts =
                SocketRunOpts { rank, ranks: 3, port, tolerate_failures: 1, ..Default::default() };
            run_sockets_reduced(
                &cfg,
                &opts,
                |_, _| UtsQueue::new(up(6)),
                |q| q.init_root(),
                &SumReducer,
            )
            .expect("tolerant fleet rank failed")
        };
        let t1 = std::thread::spawn(move || run(1));
        let t2 = std::thread::spawn(move || run(2));
        let r0 = run(0);
        t1.join().unwrap();
        t2.join().unwrap();
        assert_eq!(r0.result, sequential_count(&up(6)));
        assert_eq!(misrouted_frames(), 0, "a mesh never relays");
    }

    #[test]
    fn tolerant_mode_requires_a_gathered_flat_run() {
        // Recovery banks result snapshots at rank 0 and mirrors the
        // (single) worker's outstanding steal, so both preconditions are
        // checked up front instead of failing subtly mid-crash.
        let params = GlbParams::default().with_l(2);
        let cfg = GlbConfig::new(2, params);
        let opts =
            SocketRunOpts { rank: 0, ranks: 2, port: 1, tolerate_failures: 1, ..Default::default() };
        let err = run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer)
            .expect_err("ungathered tolerant run must be refused");
        assert!(err.to_string().contains("tolerate-failures"), "{err}");

        let params = GlbParams::default().with_l(2).with_workers_per_node(2);
        let cfg = GlbConfig::new(4, params);
        let opts =
            SocketRunOpts { rank: 0, ranks: 2, port: 1, tolerate_failures: 1, ..Default::default() };
        let err =
            run_sockets_reduced(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer)
                .expect_err("hierarchical tolerant run must be refused");
        assert!(err.to_string().contains("one worker per node"), "{err}");
    }

    #[test]
    fn empty_fleet_terminates_cleanly() {
        // No root work anywhere: every worker kicks, all steals are
        // refused across the wire, the last credit deposit reaches the
        // root, and the detector's Terminate reaches both processes.
        let port = free_port();
        let params = GlbParams::default().with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts { rank: 0, ranks: 2, port, ..Default::default() };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, 0);
    }

    #[test]
    fn bind_address_splits_from_advertised_host() {
        // The rank-0 bind/advertise fix: bind the wildcard while
        // advertising (and dialing) loopback — before the split this
        // required --host to be locally bindable.
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(5)), |q| q.init_root(), &SumReducer)
                .unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts {
            rank: 0,
            ranks: 2,
            port,
            host: "127.0.0.1".into(),
            bind: Some("0.0.0.0".into()),
            ..Default::default()
        };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(5)), |q| q.init_root(), &SumReducer)
                .unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(5)));
    }

    #[test]
    fn reduced_run_folds_the_fleet_at_rank0() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let spawn_rank = move |rank: usize| {
            std::thread::spawn(move || {
                let cfg = GlbConfig::new(3, params);
                let opts = SocketRunOpts { rank, ranks: 3, port, ..Default::default() };
                run_sockets_reduced(
                    &cfg,
                    &opts,
                    |_, _| UtsQueue::new(up(6)),
                    |q| q.init_root(),
                    &SumReducer,
                )
                .unwrap()
            })
        };
        let t1 = spawn_rank(1);
        let t2 = spawn_rank(2);
        let cfg = GlbConfig::new(3, params);
        let opts = SocketRunOpts { rank: 0, ranks: 3, port, ..Default::default() };
        let r0 = run_sockets_reduced(
            &cfg,
            &opts,
            |_, _| UtsQueue::new(up(6)),
            |q| q.init_root(),
            &SumReducer,
        )
        .unwrap();
        let r1 = t1.join().unwrap();
        let r2 = t2.join().unwrap();
        let expect = sequential_count(&up(6));
        assert_eq!(r0.result, expect, "rank 0 holds the fleet-wide reduction");
        assert!(r1.result <= expect && r2.result <= expect, "spokes keep local shares");
    }

    #[test]
    fn fleet_shape_mismatch_is_an_error() {
        let cfg = GlbConfig::new(4, GlbParams::default());
        let opts = SocketRunOpts { rank: 0, ranks: 3, ..Default::default() };
        let err =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(3)), |q| q.init_root(), &SumReducer)
                .unwrap_err();
        assert!(format!("{err:#}").contains("fleet shape"), "{err:#}");
    }
}
