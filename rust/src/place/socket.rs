//! TCP socket place-runtime: one OS **process** per GLB node.
//!
//! This is the process-spanning `Transport` the ROADMAP calls for: the
//! same [`Worker`] protocol engine as the thread runtime and the
//! simulator, but with nodes living in separate OS processes that talk
//! over length-prefixed TCP frames ([`crate::glb::wire`]). A fleet of
//! `ranks` processes runs one GLB *node* each (so with
//! `workers_per_node > 1` every process hosts several worker threads
//! sharing a [`NodeBag`], and only the node's representative speaks the
//! inter-node protocol — the representative owns the sockets in the
//! sense that all cross-node traffic is its protocol traffic).
//!
//! ## Fleet wiring (star over rank 0)
//!
//! * **rank 0 listens**; every other rank dials it and handshakes
//!   `[kind, rank]` twice — once for the *data* link (message frames)
//!   and once for the *ledger* link (termination-token RPCs).
//! * Data frames are `[to: u64][msg body]` under a length prefix. Rank 0
//!   delivers frames addressed to its own places and **forwards** the
//!   raw bytes of everything else to the destination rank's link, so
//!   spokes never connect to each other and the codec is decoded only at
//!   the destination.
//! * The work-token ledger ([`crate::glb::termination`]) must be a
//!   *global* counter, so rank 0 hosts the authoritative
//!   [`AtomicLedger`] and remote ranks run every `incr`/`decr` as a
//!   synchronous RPC over their ledger link. Synchrony is load-bearing:
//!   a victim's token increment must be applied **before** its loot
//!   message can be observed by the thief, or the count could
//!   transiently hit zero and terminate a live computation.
//! * A **start barrier** (an RPC on the ledger link) keeps the thread
//!   runtime's sequential-setup guarantee: no rank enters the steal
//!   protocol until every rank has constructed its workers and
//!   registered their initial tokens.
//!
//! Teardown mirrors the protocol's own guarantee that no message is in
//! flight after `Terminate`: a finished spoke half-closes its links
//! (`shutdown(Write)`), rank 0's per-link threads drain to EOF, and rank
//! 0 returns only after every forwarder has exited — so a broadcast
//! `Terminate` is always forwarded before the hub goes away.
//!
//! Known trade-offs (documented, deliberate): ledger RPCs serialize on
//! one link per process (fine — ledger traffic is per steal/loot event,
//! not per task), and the star topology routes spoke-to-spoke traffic
//! through rank 0 (two hops). Direct mesh links and a distributed
//! (credit-based) ledger are the natural follow-ons once fleets span
//! real hosts.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::glb::message::{Effect, Msg, PlaceId};
use crate::glb::task_queue::{Reducer, TaskQueue};
use crate::glb::termination::{AtomicLedger, Ledger};
use crate::glb::topology::{NodeBag, Topology};
use crate::glb::wire::{self, WireCodec};
use crate::glb::worker::{Phase, Worker};
use crate::glb::{GlbConfig, RunLog, RunOutput};

/// How this process joins the fleet.
#[derive(Debug, Clone)]
pub struct SocketRunOpts {
    /// This process's rank (= its GLB node id). Rank 0 is the hub.
    pub rank: usize,
    /// Total processes in the fleet (= GLB node count).
    pub ranks: usize,
    /// Rank 0's host, for binding (rank 0) and dialing (everyone else).
    pub host: String,
    /// Rank 0's rendezvous port. `0` (rank 0 only, single-rank fleets)
    /// binds an ephemeral port.
    pub port: u16,
    /// How long to wait for the whole fleet to connect / handshake.
    pub handshake_timeout: Duration,
    /// Per-place worker thread stack size in bytes.
    pub stack_bytes: usize,
}

impl Default for SocketRunOpts {
    fn default() -> Self {
        Self {
            rank: 0,
            ranks: 1,
            host: "127.0.0.1".into(),
            port: 0,
            handshake_timeout: Duration::from_secs(30),
            stack_bytes: 2 << 20,
        }
    }
}

// Handshake connection kinds.
const HS_DATA: u8 = 0;
const HS_LEDGER: u8 = 1;

// Ledger RPC opcodes and the generic acknowledgement byte.
const OP_INCR: u8 = 1;
const OP_DECR: u8 = 2;
const OP_VALUE: u8 = 3;
const OP_BARRIER: u8 = 4;
const OP_ACK: u8 = 0xA5;

/// Bytes of a routed data-frame prefix (the destination place id).
const ROUTE_BYTES: usize = 8;

/// A shared, mutex-serialized write half of a TCP link.
type Link = Arc<Mutex<TcpStream>>;
/// Rank 0's per-rank link table (index = rank; `[0]` unused).
type LinkTable = Arc<Vec<Option<Link>>>;
/// Mailbox sender per *global* place id (`None` for remote places).
type Mailboxes<B> = Arc<Vec<Option<Sender<Msg<B>>>>>;

/// The global work-token counter, as seen from one fleet process.
enum FleetLedger {
    /// Rank 0: the authoritative counter, updated in-process.
    Local(Arc<AtomicLedger>),
    /// Other ranks: synchronous RPCs over the ledger link to rank 0.
    Remote(Link),
}

impl Clone for FleetLedger {
    fn clone(&self) -> Self {
        match self {
            FleetLedger::Local(l) => FleetLedger::Local(l.clone()),
            FleetLedger::Remote(s) => FleetLedger::Remote(s.clone()),
        }
    }
}

impl FleetLedger {
    /// One synchronous request/reply on the ledger link. Panics on I/O
    /// failure: a dead ledger link mid-run is unrecoverable (the global
    /// count is gone), and all ledger traffic stops before teardown.
    fn rpc(stream: &Mutex<TcpStream>, op: u8, reply: &mut [u8]) {
        let mut s = stream.lock().unwrap();
        s.write_all(&[op]).expect("fleet ledger link lost (write)");
        s.read_exact(reply).expect("fleet ledger link lost (read)");
    }

    /// Rank > 0 only: arrive at the fleet-wide start barrier and block
    /// until every rank has registered its initial tokens.
    fn barrier(&self) {
        match self {
            FleetLedger::Local(_) => unreachable!("rank 0 arrives at the barrier in-process"),
            FleetLedger::Remote(s) => {
                let mut ack = [0u8; 1];
                Self::rpc(s, OP_BARRIER, &mut ack);
                debug_assert_eq!(ack[0], OP_ACK);
            }
        }
    }
}

impl Ledger for FleetLedger {
    fn incr(&self) {
        match self {
            FleetLedger::Local(l) => l.incr(),
            FleetLedger::Remote(s) => {
                let mut ack = [0u8; 1];
                Self::rpc(s, OP_INCR, &mut ack);
                debug_assert_eq!(ack[0], OP_ACK);
            }
        }
    }

    fn decr(&self) -> bool {
        match self {
            FleetLedger::Local(l) => l.decr(),
            FleetLedger::Remote(s) => {
                let mut reply = [0u8; 1];
                Self::rpc(s, OP_DECR, &mut reply);
                reply[0] == 1
            }
        }
    }

    fn value(&self) -> i64 {
        match self {
            FleetLedger::Local(l) => l.value(),
            FleetLedger::Remote(s) => {
                let mut reply = [0u8; 8];
                Self::rpc(s, OP_VALUE, &mut reply);
                i64::from_le_bytes(reply)
            }
        }
    }
}

/// All ranks register their initial work tokens before any rank steals.
struct StartBarrier {
    arrived: Mutex<usize>,
    cv: Condvar,
    total: usize,
}

impl StartBarrier {
    fn new(total: usize) -> Self {
        Self { arrived: Mutex::new(0), cv: Condvar::new(), total }
    }

    fn arrive_and_wait(&self) {
        let mut n = self.arrived.lock().unwrap();
        *n += 1;
        if *n >= self.total {
            self.cv.notify_all();
        }
        while *n < self.total {
            n = self.cv.wait(n).unwrap();
        }
    }
}

/// Where remote frames leave this process.
#[derive(Clone)]
enum Links {
    /// Rank 0: one write link per remote rank.
    Hub(LinkTable),
    /// Rank > 0: everything remote goes to the hub, which forwards.
    Spoke(Link),
}

/// The per-process message fabric: local mailboxes for this rank's
/// places, TCP links for everyone else.
struct SocketTransport<B> {
    rank: usize,
    topo: Topology,
    p: usize,
    local: Mailboxes<B>,
    links: Links,
}

impl<B> Clone for SocketTransport<B> {
    fn clone(&self) -> Self {
        Self {
            rank: self.rank,
            topo: self.topo,
            p: self.p,
            local: self.local.clone(),
            links: self.links.clone(),
        }
    }
}

impl<B: WireCodec> SocketTransport<B> {
    /// Send `msg` to place `to` (best-effort; write failures only occur
    /// during post-termination teardown, exactly like the thread
    /// runtime's mailbox sends).
    fn send(&self, to: PlaceId, msg: Msg<B>) {
        let dest_rank = self.topo.node_of(to);
        if dest_rank == self.rank {
            if let Some(tx) = &self.local[to] {
                let _ = tx.send(msg);
            }
            return;
        }
        let mut body = Vec::with_capacity(ROUTE_BYTES + wire::MSG_FIXED_BYTES);
        wire::put_u64(&mut body, to as u64);
        wire::encode_msg_body(&msg, &mut body);
        let link = match &self.links {
            Links::Hub(links) => match &links[dest_rank] {
                Some(l) => l.clone(),
                None => return, // unreachable: every remote rank has a link
            },
            Links::Spoke(hub) => hub.clone(),
        };
        let mut s = link.lock().unwrap();
        let _ = wire::write_frame(&mut *s, &body);
    }

    /// The one broadcast in the protocol, issued by the worker that
    /// observed global quiescence.
    fn broadcast_terminate(&self, me: PlaceId) {
        for i in (0..self.p).filter(|&i| i != me) {
            self.send(i, Msg::Terminate);
        }
    }
}

/// Carry out a worker's requested effects.
fn pump<B: WireCodec>(me: PlaceId, fx: &mut Vec<Effect<B>>, transport: &SocketTransport<B>) {
    for e in fx.drain(..) {
        match e {
            Effect::Send { to, msg } => {
                debug_assert_ne!(to, me, "no self-sends in the protocol");
                transport.send(to, msg);
            }
            Effect::Quiescent => transport.broadcast_terminate(me),
        }
    }
}

/// Per-place worker thread body (mirror of the thread runtime's
/// `place_main`, driving the same engine over the socket fabric).
fn socket_place_main<Q>(
    mut worker: Worker<Q, FleetLedger>,
    rx: Receiver<Msg<Q::Bag>>,
    transport: SocketTransport<Q::Bag>,
) -> (Q::Result, crate::glb::WorkerStats)
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
{
    let me = worker.id();
    let mut fx: Vec<Effect<Q::Bag>> = Vec::with_capacity(8);
    loop {
        match worker.phase() {
            Phase::Working => {
                let t = Instant::now();
                while let Ok(m) = rx.try_recv() {
                    worker.on_msg(m, &mut fx);
                    pump(me, &mut fx, &transport);
                }
                worker.stats_mut().distribute_ns += t.elapsed().as_nanos() as u64;
                if worker.phase() != Phase::Working {
                    continue;
                }
                let t = Instant::now();
                worker.step(&mut fx);
                worker.stats_mut().process_ns += t.elapsed().as_nanos() as u64;
                pump(me, &mut fx, &transport);
            }
            Phase::WaitRandom { .. } | Phase::WaitLifeline { .. } | Phase::Idle => {
                let t = Instant::now();
                let m = rx.recv().expect("mailbox closed while waiting");
                worker.stats_mut().wait_ns += t.elapsed().as_nanos() as u64;
                let t = Instant::now();
                worker.on_msg(m, &mut fx);
                pump(me, &mut fx, &transport);
                worker.stats_mut().distribute_ns += t.elapsed().as_nanos() as u64;
            }
            Phase::Done => break,
        }
    }
    let (queue, stats) = worker.into_parts();
    (queue.result(), stats)
}

/// Rank 0's per-remote-rank data thread: deliver frames addressed to
/// rank 0's places, forward everything else (raw bytes, no decode) to
/// the destination rank's link. Exits on the remote's EOF.
fn hub_reader<B>(mut stream: TcpStream, topo: Topology, links: LinkTable, local: Mailboxes<B>)
where
    B: WireCodec + Send + 'static,
{
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => return,
        };
        if body.len() < ROUTE_BYTES {
            return; // malformed peer; drop the link
        }
        let to = u64::from_le_bytes(body[..ROUTE_BYTES].try_into().unwrap()) as usize;
        if to >= topo.places() {
            return;
        }
        if topo.node_of(to) == 0 {
            match wire::decode_msg_body::<B>(&body[ROUTE_BYTES..]) {
                Ok(msg) => {
                    if let Some(tx) = &local[to] {
                        let _ = tx.send(msg);
                    }
                }
                Err(_) => return,
            }
        } else if let Some(link) = &links[topo.node_of(to)] {
            let mut s = link.lock().unwrap();
            let _ = wire::write_frame(&mut *s, &body);
        }
    }
}

/// A spoke's data thread: decode frames from the hub into the local
/// mailboxes. Exits on the hub's EOF (or process exit).
fn spoke_reader<B>(mut stream: TcpStream, local: Mailboxes<B>)
where
    B: WireCodec + Send + 'static,
{
    loop {
        let body = match wire::read_frame(&mut stream, wire::MAX_FRAME_BYTES) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => return,
        };
        if body.len() < ROUTE_BYTES {
            return;
        }
        let to = u64::from_le_bytes(body[..ROUTE_BYTES].try_into().unwrap()) as usize;
        match wire::decode_msg_body::<B>(&body[ROUTE_BYTES..]) {
            Ok(msg) => {
                if let Some(tx) = local.get(to).and_then(|o| o.as_ref()) {
                    let _ = tx.send(msg);
                }
            }
            Err(_) => return,
        }
    }
}

/// Rank 0's per-remote-rank ledger thread: apply token RPCs to the
/// authoritative counter, in arrival order, one reply per request.
fn ledger_server(mut stream: TcpStream, ledger: Arc<AtomicLedger>, barrier: Arc<StartBarrier>) {
    let mut op = [0u8; 1];
    loop {
        if stream.read_exact(&mut op).is_err() {
            return; // peer finished (clean half-close) or died
        }
        let written = match op[0] {
            OP_INCR => {
                ledger.incr();
                stream.write_all(&[OP_ACK])
            }
            OP_DECR => {
                let zero = ledger.decr();
                stream.write_all(&[zero as u8])
            }
            OP_VALUE => stream.write_all(&ledger.value().to_le_bytes()),
            OP_BARRIER => {
                barrier.arrive_and_wait();
                stream.write_all(&[OP_ACK])
            }
            _ => return,
        };
        if written.is_err() {
            return;
        }
    }
}

fn connect_retry(host: &str, port: u16, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect((host, port)) {
            Ok(s) => {
                s.set_nodelay(true)?;
                return Ok(s);
            }
            Err(e) => {
                if Instant::now() > deadline {
                    bail!("could not reach fleet hub at {host}:{port}: {e}");
                }
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
}

fn handshake_bytes(kind: u8, rank: usize) -> [u8; 9] {
    let mut hs = [0u8; 9];
    hs[0] = kind;
    hs[1..].copy_from_slice(&(rank as u64).to_le_bytes());
    hs
}

/// Run this process's share of a fleet-wide GLB computation.
///
/// The factory/root-init/reducer contract matches
/// [`crate::place::run_threads`], with two distributed twists: `factory`
/// is called only for this rank's places (still with global `(place, p)`
/// arguments), and the returned [`RunOutput`] holds the reduction of
/// **this rank's** per-place results plus the local [`RunLog`] — the
/// caller (or the `testkit::fleet` harness) combines ranks.
pub fn run_sockets<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    opts: &SocketRunOpts,
    mut factory: FQ,
    root_init: FI,
    reducer: &R,
) -> Result<RunOutput<Q::Result>>
where
    Q: TaskQueue,
    Q::Bag: WireCodec,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    let p = cfg.p;
    let topo = cfg.topology();
    let (rank, ranks) = (opts.rank, opts.ranks);
    if ranks == 0 {
        bail!("a fleet needs at least one rank");
    }
    if rank >= ranks {
        bail!("--rank {rank} out of range for --peers {ranks}");
    }
    if topo.nodes() != ranks {
        bail!(
            "fleet shape mismatch: {p} places at {} workers-per-node is {} nodes, \
             but the fleet has {ranks} ranks",
            cfg.params.workers_per_node,
            topo.nodes(),
        );
    }

    // -- local mailboxes (one per place this rank hosts) ----------------
    let my_places: Vec<PlaceId> = topo.workers_of(rank).collect();
    let mut local_tx: Vec<Option<Sender<Msg<Q::Bag>>>> = (0..p).map(|_| None).collect();
    let mut rxs: Vec<Receiver<Msg<Q::Bag>>> = Vec::with_capacity(my_places.len());
    for &i in &my_places {
        let (tx, rx) = channel();
        local_tx[i] = Some(tx);
        rxs.push(rx);
    }
    let local_tx = Arc::new(local_tx);

    // -- fleet wiring ----------------------------------------------------
    let deadline = Instant::now() + opts.handshake_timeout;
    let mut hub_readers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut ledger_threads: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut spoke_streams: Option<(Link, Link)> = None;

    let (links, ledger, hub_barrier, hub_atomic) = if rank == 0 {
        let atomic = AtomicLedger::new();
        let barrier = Arc::new(StartBarrier::new(ranks));
        let mut data_read: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
        let mut data_write: Vec<Option<Link>> = (0..ranks).map(|_| None).collect();
        let mut ledger_slots: Vec<Option<TcpStream>> = (0..ranks).map(|_| None).collect();
        if ranks > 1 {
            let listener = TcpListener::bind((opts.host.as_str(), opts.port))
                .with_context(|| format!("bind fleet hub on {}:{}", opts.host, opts.port))?;
            listener.set_nonblocking(true)?;
            let mut need = 2 * (ranks - 1);
            while need > 0 {
                match listener.accept() {
                    Ok((mut s, _addr)) => {
                        s.set_nonblocking(false)?;
                        s.set_nodelay(true)?;
                        s.set_read_timeout(Some(opts.handshake_timeout))?;
                        let mut hs = [0u8; 9];
                        s.read_exact(&mut hs).context("read fleet handshake")?;
                        s.set_read_timeout(None)?;
                        let r = u64::from_le_bytes(hs[1..].try_into().unwrap()) as usize;
                        if r == 0 || r >= ranks {
                            bail!("fleet handshake from invalid rank {r}");
                        }
                        match hs[0] {
                            HS_DATA => {
                                if data_write[r].is_some() {
                                    bail!("duplicate data link from rank {r}");
                                }
                                data_read[r] = Some(s.try_clone()?);
                                data_write[r] = Some(Arc::new(Mutex::new(s)));
                            }
                            HS_LEDGER => {
                                if ledger_slots[r].is_some() {
                                    bail!("duplicate ledger link from rank {r}");
                                }
                                ledger_slots[r] = Some(s);
                            }
                            k => bail!("bad fleet handshake kind {k}"),
                        }
                        need -= 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if Instant::now() > deadline {
                            bail!("timed out waiting for {need} more fleet connection(s)");
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        // Ledger service must be live before remote ranks construct
        // workers (their initial-token increments are RPCs).
        for conn in ledger_slots.into_iter().flatten() {
            let (l, b) = (atomic.clone(), barrier.clone());
            ledger_threads.push(
                std::thread::Builder::new()
                    .name("glb-fleet-ledger".into())
                    .spawn(move || ledger_server(conn, l, b))
                    .expect("spawn ledger server"),
            );
        }
        let links = Links::Hub(Arc::new(data_write));
        // Data delivery + forwarding, one thread per remote rank. Spawned
        // before the start barrier so the first post-barrier steal finds
        // a live fabric.
        if let Links::Hub(link_vec) = &links {
            for (r, read_half) in data_read.into_iter().enumerate() {
                let Some(read_half) = read_half else { continue };
                let (lv, lt) = (link_vec.clone(), local_tx.clone());
                hub_readers.push(
                    std::thread::Builder::new()
                        .name(format!("glb-fleet-hub-{r}"))
                        .spawn(move || hub_reader::<Q::Bag>(read_half, topo, lv, lt))
                        .expect("spawn hub reader"),
                );
            }
        }
        (links, FleetLedger::Local(atomic.clone()), Some(barrier), Some(atomic))
    } else {
        let mut data = connect_retry(&opts.host, opts.port, deadline)?;
        data.write_all(&handshake_bytes(HS_DATA, rank)).context("send data handshake")?;
        let mut ledger_stream = connect_retry(&opts.host, opts.port, deadline)?;
        ledger_stream
            .write_all(&handshake_bytes(HS_LEDGER, rank))
            .context("send ledger handshake")?;
        let read_half = data.try_clone()?;
        let hub_write = Arc::new(Mutex::new(data));
        let ledger_stream = Arc::new(Mutex::new(ledger_stream));
        spoke_streams = Some((hub_write.clone(), ledger_stream.clone()));
        let lt = local_tx.clone();
        // Detached on purpose: it exits on the hub's EOF, which arrives
        // only after every rank has finished (see module docs).
        std::thread::Builder::new()
            .name("glb-fleet-spoke".into())
            .spawn(move || spoke_reader::<Q::Bag>(read_half, lt))
            .expect("spawn spoke reader");
        (Links::Spoke(hub_write), FleetLedger::Remote(ledger_stream), None, None)
    };

    let transport: SocketTransport<Q::Bag> =
        SocketTransport { rank, topo, p, local: local_tx, links };

    // -- sequential local setup ------------------------------------------
    // Queues and workers are constructed (registering initial work
    // tokens, remotely via synchronous RPC) *before* the start barrier;
    // no rank can observe an incomplete global ledger.
    let mut queues: Vec<Q> = my_places.iter().map(|&i| factory(i, p)).collect();
    if rank == 0 {
        root_init(&mut queues[0]);
    }
    let node_bag: Option<Arc<NodeBag<Q::Bag>>> =
        if topo.is_flat() { None } else { Some(Arc::new(NodeBag::new())) };
    let mut workers: Vec<Worker<Q, FleetLedger>> = queues
        .into_iter()
        .zip(&my_places)
        .map(|(q, &i)| Worker::with_node_bag(i, p, cfg.params, q, ledger.clone(), node_bag.clone()))
        .collect();

    // -- start barrier ---------------------------------------------------
    match (&hub_barrier, &ledger) {
        (Some(b), _) => b.arrive_and_wait(),
        (None, l) => l.barrier(),
    }

    // Kick empty places into the steal protocol (now safe: every rank's
    // initial tokens are on the global ledger).
    let mut fx = Vec::new();
    for w in workers.iter_mut() {
        let me = w.id();
        w.kick_if_empty(&mut fx);
        pump(me, &mut fx, &transport);
    }

    // -- run ---------------------------------------------------------------
    let t0 = Instant::now();
    let handles: Vec<_> = workers
        .into_iter()
        .zip(rxs)
        .map(|(worker, rx)| {
            let transport = transport.clone();
            std::thread::Builder::new()
                .name(format!("glb-sock-{}", worker.id()))
                .stack_size(opts.stack_bytes)
                .spawn(move || socket_place_main(worker, rx, transport))
                .expect("spawn place thread")
        })
        .collect();

    let mut per_place: Vec<(Q::Result, crate::glb::WorkerStats)> =
        handles.into_iter().map(|h| h.join().expect("place thread panicked")).collect();
    let elapsed_ns = t0.elapsed().as_nanos() as u64;

    // -- teardown ----------------------------------------------------------
    if let Some((data, ledger_stream)) = spoke_streams {
        // Half-close both links: the hub's threads see EOF and know this
        // rank is done; the hub's eventual close unblocks our reader.
        let _ = data.lock().unwrap().shutdown(Shutdown::Write);
        let _ = ledger_stream.lock().unwrap().shutdown(Shutdown::Write);
    }
    for h in hub_readers {
        let _ = h.join();
    }
    for h in ledger_threads {
        let _ = h.join();
    }
    if let Some(atomic) = hub_atomic {
        debug_assert_eq!(atomic.value(), 0, "global tokens must balance at termination");
    }

    let stats: Vec<_> = per_place.iter().map(|(_, s)| *s).collect();
    let results: Vec<Q::Result> = per_place.drain(..).map(|(r, _)| r).collect();
    let log = RunLog::with_topology(stats, cfg.params.workers_per_node);
    Ok(RunOutput { result: reducer.reduce_all(results), log, elapsed_ns })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::uts::{sequential_count, UtsParams, UtsQueue};
    use crate::glb::task_queue::SumReducer;
    use crate::glb::GlbParams;
    use crate::testkit::fleet::free_port;

    fn up(depth: u32) -> UtsParams {
        UtsParams { b0: 4.0, seed: 19, max_depth: depth }
    }

    fn run_rank(
        rank: usize,
        ranks: usize,
        port: u16,
        params: GlbParams,
        p: usize,
        depth: u32,
    ) -> RunOutput<u64> {
        let cfg = GlbConfig::new(p, params);
        let opts = SocketRunOpts { rank, ranks, port, ..Default::default() };
        run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(depth)), |q| q.init_root(), &SumReducer)
            .expect("fleet rank failed")
    }

    #[test]
    fn single_rank_fleet_matches_sequential() {
        let out = run_rank(0, 1, 0, GlbParams::default().with_n(64), 1, 5);
        assert_eq!(out.result, sequential_count(&up(5)));
    }

    #[test]
    fn two_rank_in_process_fleet_sums_to_sequential() {
        let port = free_port();
        let params = GlbParams::default().with_n(64).with_l(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 2, 6));
        let r0 = run_rank(0, 2, port, params, 2, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        // Loot accounting balances fleet-wide.
        let (t0, t1) = (r0.log.total(), r1.log.total());
        assert_eq!(
            t0.loot_bags_sent + t1.loot_bags_sent,
            t0.loot_bags_received + t1.loot_bags_received,
        );
    }

    #[test]
    fn hierarchical_two_rank_fleet_sums_to_sequential() {
        // 2 processes × 2 workers: reps 0 and 2 own the inter-node
        // sockets; workers 1 and 3 share through their process's NodeBag.
        let port = free_port();
        let params = GlbParams::default().with_n(32).with_l(2).with_workers_per_node(2);
        let t1 = std::thread::spawn(move || run_rank(1, 2, port, params, 4, 6));
        let r0 = run_rank(0, 2, port, params, 4, 6);
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, sequential_count(&up(6)));
        for out in [&r0, &r1] {
            let t = out.log.total();
            // Node-bag traffic never crosses a process boundary, so it
            // balances within each rank on its own.
            assert_eq!(t.node_donations, t.node_takes);
            assert_eq!(out.log.per_place.len(), 2);
        }
    }

    #[test]
    fn empty_fleet_terminates_cleanly() {
        // No root work anywhere: every worker kicks, all steals are
        // refused across the wire, the last release observes global
        // quiescence and Terminate reaches both processes.
        let port = free_port();
        let params = GlbParams::default().with_l(2);
        let t1 = std::thread::spawn(move || {
            let cfg = GlbConfig::new(2, params);
            let opts = SocketRunOpts { rank: 1, ranks: 2, port, ..Default::default() };
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap()
        });
        let cfg = GlbConfig::new(2, params);
        let opts = SocketRunOpts { rank: 0, ranks: 2, port, ..Default::default() };
        let r0 =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(4)), |_| {}, &SumReducer).unwrap();
        let r1 = t1.join().unwrap();
        assert_eq!(r0.result + r1.result, 0);
    }

    #[test]
    fn fleet_shape_mismatch_is_an_error() {
        let cfg = GlbConfig::new(4, GlbParams::default());
        let opts = SocketRunOpts { rank: 0, ranks: 3, ..Default::default() };
        let err =
            run_sockets(&cfg, &opts, |_, _| UtsQueue::new(up(3)), |q| q.init_root(), &SumReducer)
                .unwrap_err();
        assert!(format!("{err:#}").contains("fleet shape"), "{err:#}");
    }
}
