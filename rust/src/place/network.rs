//! Latency injection for the thread place-runtime.
//!
//! By default places exchange messages directly over their mailboxes
//! (zero added latency — the shared-memory analogue of X10's intra-host
//! transport). With [`Transport::delayed`], every message is routed
//! through a router thread that holds it for a fixed delay before
//! forwarding — a wall-clock analogue of an interconnect round-trip,
//! used by the stress tests to shake out timing-dependent protocol bugs
//! on real threads (the virtual-time equivalent lives in the simulator's
//! architecture profiles, which model latency *structurally*).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::glb::message::{Msg, PlaceId};

/// How messages travel between places.
pub enum Transport<B> {
    /// Deliver straight into the destination mailbox.
    Direct(Vec<Sender<Msg<B>>>),
    /// Deliver via a router thread after a fixed delay.
    Delayed(Sender<Routed<B>>),
}

impl<B> Clone for Transport<B> {
    fn clone(&self) -> Self {
        match self {
            Transport::Direct(txs) => Transport::Direct(txs.clone()),
            Transport::Delayed(tx) => Transport::Delayed(tx.clone()),
        }
    }
}

/// A message in flight through the router.
pub struct Routed<B> {
    pub due: Instant,
    pub to: PlaceId,
    pub msg: Msg<B>,
}

impl<B> Transport<B> {
    /// Send `msg` to `to` (best-effort; failures only occur during
    /// post-termination teardown and are ignored by the protocol).
    pub fn send(&self, to: PlaceId, msg: Msg<B>, delay: Duration) {
        match self {
            Transport::Direct(txs) => {
                let _ = txs[to].send(msg);
            }
            Transport::Delayed(tx) => {
                let _ = tx.send(Routed { due: Instant::now() + delay, to, msg });
            }
        }
    }

    /// Fan `Terminate` out to every place except `me` — the one broadcast
    /// in the protocol, issued by the worker that observed global
    /// quiescence. Terminate travels like any other message (so it also
    /// honours injected latency).
    pub fn broadcast_terminate(&self, me: PlaceId, p: usize, delay: Duration) {
        for i in (0..p).filter(|&i| i != me) {
            self.send(i, Msg::Terminate, delay);
        }
    }
}

/// Router thread body: hold each message until its due time, then
/// forward to the destination mailbox. Exits when all senders hang up
/// and the heap drains.
///
/// An idle router (empty heap) **blocks** on `recv()` — it used to poll
/// on a 50 ms timeout forever, burning a wakeup per tick for the whole
/// (possibly long) stretch of a run in which no latency-injected message
/// is in flight. [`router_main_counting`] exposes the spurious-wakeup
/// counter the regression test pins to zero.
pub fn router_main<B: Send>(rx: Receiver<Routed<B>>, mailboxes: Vec<Sender<Msg<B>>>) {
    router_loop(rx, mailboxes, None)
}

/// [`router_main`] with instrumentation: `spurious` is incremented every
/// time the router wakes from a timed wait and finds nothing due to
/// forward (the failure mode of the old idle-polling loop).
pub fn router_main_counting<B: Send>(
    rx: Receiver<Routed<B>>,
    mailboxes: Vec<Sender<Msg<B>>>,
    spurious: Arc<AtomicU64>,
) {
    router_loop(rx, mailboxes, Some(spurious))
}

fn router_loop<B: Send>(
    rx: Receiver<Routed<B>>,
    mailboxes: Vec<Sender<Msg<B>>>,
    spurious: Option<Arc<AtomicU64>>,
) {
    struct Entry<B>(Instant, u64, PlaceId, Msg<B>);
    impl<B> PartialEq for Entry<B> {
        fn eq(&self, o: &Self) -> bool {
            (self.0, self.1) == (o.0, o.1)
        }
    }
    impl<B> Eq for Entry<B> {}
    impl<B> PartialOrd for Entry<B> {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl<B> Ord for Entry<B> {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            (self.0, self.1).cmp(&(o.0, o.1))
        }
    }

    let mut heap: BinaryHeap<Reverse<Entry<B>>> = BinaryHeap::new();
    let mut seq = 0u64;
    loop {
        // Forward everything due.
        let now = Instant::now();
        while heap.peek().is_some_and(|Reverse(e)| e.0 <= now) {
            let Reverse(Entry(_, _, to, msg)) = heap.pop().unwrap();
            let _ = mailboxes[to].send(msg);
        }
        match heap.peek().map(|Reverse(e)| e.0) {
            // Idle: nothing in flight, so block until traffic arrives or
            // every sender hangs up — zero wakeups in between.
            None => match rx.recv() {
                Ok(r) => {
                    heap.push(Reverse(Entry(r.due, seq, r.to, r.msg)));
                    seq += 1;
                }
                Err(_) => return,
            },
            // Something is in flight: wait for its due time or for the
            // next incoming message, whichever comes first.
            Some(next_due) => {
                let timeout = next_due.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(r) => {
                        heap.push(Reverse(Entry(r.due, seq, r.to, r.msg)));
                        seq += 1;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        // A due-time wakeup; the next loop iteration
                        // forwards it. Waking with nothing due would be
                        // the old idle-poll bug.
                        if let Some(spurious_wakeups) = &spurious {
                            let now = Instant::now();
                            if !heap.peek().is_some_and(|Reverse(e)| e.0 <= now) {
                                spurious_wakeups.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        // Senders gone: deliver the remaining in-flight
                        // messages at their due times, then exit (the old
                        // loop busy-spun on the disconnected channel here).
                        while let Some(Reverse(Entry(due, _, to, msg))) = heap.pop() {
                            let wait = due.saturating_duration_since(Instant::now());
                            if !wait.is_zero() {
                                std::thread::sleep(wait);
                            }
                            let _ = mailboxes[to].send(msg);
                        }
                        return;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn direct_transport_delivers() {
        let (tx, rx) = channel::<Msg<Vec<u8>>>();
        let t = Transport::Direct(vec![tx]);
        t.send(0, Msg::Terminate, Duration::ZERO);
        assert!(matches!(rx.recv().unwrap(), Msg::Terminate));
    }

    #[test]
    fn delayed_transport_holds_messages() {
        let (mb_tx, mb_rx) = channel::<Msg<Vec<u8>>>();
        let (rt_tx, rt_rx) = channel();
        let router = std::thread::spawn(move || router_main(rt_rx, vec![mb_tx]));
        let t = Transport::Delayed(rt_tx);
        let delay = Duration::from_millis(30);
        let t0 = Instant::now();
        t.send(0, Msg::Terminate, delay);
        match mb_rx.recv_timeout(Duration::from_secs(2)) {
            Ok(Msg::Terminate) => {}
            other => panic!("unexpected {other:?}"),
        }
        assert!(t0.elapsed() >= delay, "message arrived early: {:?}", t0.elapsed());
        drop(t);
        router.join().unwrap();
    }

    #[test]
    fn broadcast_terminate_skips_self() {
        let (tx0, rx0) = channel::<Msg<Vec<u8>>>();
        let (tx1, rx1) = channel::<Msg<Vec<u8>>>();
        let (tx2, rx2) = channel::<Msg<Vec<u8>>>();
        let t = Transport::Direct(vec![tx0, tx1, tx2]);
        t.broadcast_terminate(1, 3, Duration::ZERO);
        assert!(matches!(rx0.try_recv(), Ok(Msg::Terminate)));
        assert!(rx1.try_recv().is_err(), "no self-terminate");
        assert!(matches!(rx2.try_recv(), Ok(Msg::Terminate)));
    }

    #[test]
    fn idle_router_makes_no_spurious_wakeups() {
        // Regression: an idle router used to wake every 50 ms forever.
        // Now it blocks on `recv()`, so a long idle stretch followed by
        // real traffic must record zero empty wakeups.
        let (mb_tx, mb_rx) = channel::<Msg<Vec<u8>>>();
        let (rt_tx, rt_rx) = channel();
        let wakeups = Arc::new(AtomicU64::new(0));
        let counter = wakeups.clone();
        let router =
            std::thread::spawn(move || router_main_counting(rt_rx, vec![mb_tx], counter));
        // Idle far longer than the old 50 ms poll interval.
        std::thread::sleep(Duration::from_millis(260));
        assert_eq!(wakeups.load(Ordering::Relaxed), 0, "idle router must sleep");
        // It still forwards traffic promptly after the idle stretch.
        let t = Transport::Delayed(rt_tx);
        t.send(0, Msg::Terminate, Duration::from_millis(5));
        match mb_rx.recv_timeout(Duration::from_secs(2)) {
            Ok(Msg::Terminate) => {}
            other => panic!("unexpected {other:?}"),
        }
        drop(t);
        router.join().unwrap();
        assert_eq!(wakeups.load(Ordering::Relaxed), 0, "due-time waits are not spurious");
    }

    #[test]
    fn delayed_transport_preserves_order_per_equal_delay() {
        let (mb_tx, mb_rx) = channel::<Msg<Vec<u8>>>();
        let (rt_tx, rt_rx) = channel();
        let router = std::thread::spawn(move || router_main(rt_rx, vec![mb_tx]));
        let t = Transport::Delayed(rt_tx);
        let d = Duration::from_millis(5);
        for i in 0..10u64 {
            t.send(0, Msg::Steal { thief: i as usize, lifeline: false, nonce: i }, d);
        }
        for i in 0..10u64 {
            match mb_rx.recv_timeout(Duration::from_secs(2)).unwrap() {
                Msg::Steal { nonce, .. } => assert_eq!(nonce, i, "FIFO within equal delays"),
                other => panic!("unexpected {other:?}"),
            }
        }
        drop(t);
        router.join().unwrap();
    }
}
