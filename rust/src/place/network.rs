//! Latency injection for the thread place-runtime.
//!
//! By default places exchange messages directly over their mailboxes
//! (zero added latency — the shared-memory analogue of X10's intra-host
//! transport). With [`Transport::delayed`], every message is routed
//! through a router thread that holds it for a fixed delay before
//! forwarding — a wall-clock analogue of an interconnect round-trip,
//! used by the stress tests to shake out timing-dependent protocol bugs
//! on real threads (the virtual-time equivalent lives in the simulator's
//! architecture profiles, which model latency *structurally*).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use crate::glb::message::{Msg, PlaceId};

/// How messages travel between places.
pub enum Transport<B> {
    /// Deliver straight into the destination mailbox.
    Direct(Vec<Sender<Msg<B>>>),
    /// Deliver via a router thread after a fixed delay.
    Delayed(Sender<Routed<B>>),
}

impl<B> Clone for Transport<B> {
    fn clone(&self) -> Self {
        match self {
            Transport::Direct(txs) => Transport::Direct(txs.clone()),
            Transport::Delayed(tx) => Transport::Delayed(tx.clone()),
        }
    }
}

/// A message in flight through the router.
pub struct Routed<B> {
    pub due: Instant,
    pub to: PlaceId,
    pub msg: Msg<B>,
}

impl<B> Transport<B> {
    /// Send `msg` to `to` (best-effort; failures only occur during
    /// post-termination teardown and are ignored by the protocol).
    pub fn send(&self, to: PlaceId, msg: Msg<B>, delay: Duration) {
        match self {
            Transport::Direct(txs) => {
                let _ = txs[to].send(msg);
            }
            Transport::Delayed(tx) => {
                let _ = tx.send(Routed { due: Instant::now() + delay, to, msg });
            }
        }
    }

    /// Fan `Terminate` out to every place except `me` — the one broadcast
    /// in the protocol, issued by the worker that observed global
    /// quiescence. Terminate travels like any other message (so it also
    /// honours injected latency).
    pub fn broadcast_terminate(&self, me: PlaceId, p: usize, delay: Duration) {
        for i in (0..p).filter(|&i| i != me) {
            self.send(i, Msg::Terminate, delay);
        }
    }
}

/// Router thread body: hold each message until its due time, then
/// forward to the destination mailbox. Exits when all senders hang up
/// and the heap drains.
pub fn router_main<B: Send>(rx: Receiver<Routed<B>>, mailboxes: Vec<Sender<Msg<B>>>) {
    struct Entry<B>(Instant, u64, PlaceId, Msg<B>);
    impl<B> PartialEq for Entry<B> {
        fn eq(&self, o: &Self) -> bool {
            (self.0, self.1) == (o.0, o.1)
        }
    }
    impl<B> Eq for Entry<B> {}
    impl<B> PartialOrd for Entry<B> {
        fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(o))
        }
    }
    impl<B> Ord for Entry<B> {
        fn cmp(&self, o: &Self) -> std::cmp::Ordering {
            (self.0, self.1).cmp(&(o.0, o.1))
        }
    }

    let mut heap: BinaryHeap<Reverse<Entry<B>>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut closed = false;
    loop {
        // Forward everything due.
        let now = Instant::now();
        while heap.peek().is_some_and(|Reverse(e)| e.0 <= now) {
            let Reverse(Entry(_, _, to, msg)) = heap.pop().unwrap();
            let _ = mailboxes[to].send(msg);
        }
        if closed && heap.is_empty() {
            return;
        }
        // Wait for the next due time or the next incoming message.
        let timeout = heap
            .peek()
            .map(|Reverse(e)| e.0.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(r) => {
                heap.push(Reverse(Entry(r.due, seq, r.to, r.msg)));
                seq += 1;
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => closed = true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn direct_transport_delivers() {
        let (tx, rx) = channel::<Msg<Vec<u8>>>();
        let t = Transport::Direct(vec![tx]);
        t.send(0, Msg::Terminate, Duration::ZERO);
        assert!(matches!(rx.recv().unwrap(), Msg::Terminate));
    }

    #[test]
    fn delayed_transport_holds_messages() {
        let (mb_tx, mb_rx) = channel::<Msg<Vec<u8>>>();
        let (rt_tx, rt_rx) = channel();
        let router = std::thread::spawn(move || router_main(rt_rx, vec![mb_tx]));
        let t = Transport::Delayed(rt_tx);
        let delay = Duration::from_millis(30);
        let t0 = Instant::now();
        t.send(0, Msg::Terminate, delay);
        match mb_rx.recv_timeout(Duration::from_secs(2)) {
            Ok(Msg::Terminate) => {}
            other => panic!("unexpected {other:?}"),
        }
        assert!(t0.elapsed() >= delay, "message arrived early: {:?}", t0.elapsed());
        drop(t);
        router.join().unwrap();
    }

    #[test]
    fn broadcast_terminate_skips_self() {
        let (tx0, rx0) = channel::<Msg<Vec<u8>>>();
        let (tx1, rx1) = channel::<Msg<Vec<u8>>>();
        let (tx2, rx2) = channel::<Msg<Vec<u8>>>();
        let t = Transport::Direct(vec![tx0, tx1, tx2]);
        t.broadcast_terminate(1, 3, Duration::ZERO);
        assert!(matches!(rx0.try_recv(), Ok(Msg::Terminate)));
        assert!(rx1.try_recv().is_err(), "no self-terminate");
        assert!(matches!(rx2.try_recv(), Ok(Msg::Terminate)));
    }

    #[test]
    fn delayed_transport_preserves_order_per_equal_delay() {
        let (mb_tx, mb_rx) = channel::<Msg<Vec<u8>>>();
        let (rt_tx, rt_rx) = channel();
        let router = std::thread::spawn(move || router_main(rt_rx, vec![mb_tx]));
        let t = Transport::Delayed(rt_tx);
        let d = Duration::from_millis(5);
        for i in 0..10u64 {
            t.send(0, Msg::Steal { thief: i as usize, lifeline: false, nonce: i }, d);
        }
        for i in 0..10u64 {
            match mb_rx.recv_timeout(Duration::from_secs(2)).unwrap() {
                Msg::Steal { nonce, .. } => assert_eq!(nonce, i, "FIFO within equal delays"),
                other => panic!("unexpected {other:?}"),
            }
        }
        drop(t);
        router.join().unwrap();
    }
}
