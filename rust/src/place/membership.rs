//! Fleet membership: who is in the mesh, under which epoch.
//!
//! Peer discovery used to be a frozen bootstrap array inside
//! `place/socket.rs`; crash tolerance needs the view to *change* while
//! the fleet runs. [`MembershipProvider`] abstracts the difference:
//!
//! * [`FixedMembership`] — today's semantics: the bootstrap peer map is
//!   the membership, forever. [`MembershipProvider::leave`] refuses, so
//!   a rank death stays what it always was — fatal.
//! * [`DynamicMembership`] — the `--tolerate-failures` mode: the root
//!   retires crashed ranks ([`MembershipProvider::leave`]) and publishes
//!   the new view as an epoch-stamped [`crate::glb::wire::Ctrl::Leave`] /
//!   [`crate::glb::wire::Ctrl::PeerMap`]; spokes replay the same
//!   transitions, so every survivor converges on the same
//!   [`MembershipView`] at the same epoch. Join frames
//!   ([`crate::glb::wire::Ctrl::Join`]) feed the same provider; the
//!   socket runtime does not accept mid-run joins yet, but the provider
//!   and wire format are ready for the persistent-fleet-service work.
//!
//! A view keeps every rank's *slot* (dead ranks become `None`), so rank
//! ids — and with them place ids, lifeline node ids, and the credit
//! books — stay stable across reconfigurations. Only the *alive* subset
//! shrinks; `glb/lifeline.rs` re-knits its cube over that subset.

use std::sync::Mutex;

/// One consistent snapshot of fleet membership.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipView {
    /// Monotonic view counter: 0 = the bootstrap map, +1 per change.
    pub epoch: u64,
    /// Mesh address per rank slot; `None` once the rank has left.
    pub addrs: Vec<Option<String>>,
}

impl MembershipView {
    /// The bootstrap view (epoch 0) over a fully-populated address map.
    pub fn bootstrap(addrs: Vec<String>) -> Self {
        Self { epoch: 0, addrs: addrs.into_iter().map(Some).collect() }
    }

    /// Total rank slots, dead ones included.
    pub fn slots(&self) -> usize {
        self.addrs.len()
    }

    /// Is `rank` a current member?
    pub fn alive(&self, rank: usize) -> bool {
        self.addrs.get(rank).is_some_and(|a| a.is_some())
    }

    /// Sorted ids of the current members.
    pub fn members(&self) -> Vec<usize> {
        (0..self.addrs.len()).filter(|&r| self.alive(r)).collect()
    }
}

/// How a fleet process learns (and, at the root, decides) who its peers
/// are. Implementations are shared across the runtime's threads.
pub trait MembershipProvider: Send + Sync {
    /// The current view (a consistent snapshot).
    fn view(&self) -> MembershipView;

    /// Current epoch — cheap enough to poll from worker loops.
    fn epoch(&self) -> u64;

    /// Retire `rank` from the membership. Returns the new view, or
    /// `None` if this provider cannot reconfigure (fixed bootstrap
    /// membership — the caller must treat the death as fatal).
    fn leave(&self, rank: usize) -> Option<MembershipView>;

    /// (Re)admit `rank` at `addr`. Returns the new view, or `None` if
    /// this provider cannot reconfigure.
    fn join(&self, rank: usize, addr: String) -> Option<MembershipView>;
}

/// The frozen bootstrap membership: exactly the pre-crash-tolerance
/// semantics of the socket runtime.
pub struct FixedMembership {
    view: MembershipView,
}

impl FixedMembership {
    pub fn new(addrs: Vec<String>) -> Self {
        Self { view: MembershipView::bootstrap(addrs) }
    }
}

impl MembershipProvider for FixedMembership {
    fn view(&self) -> MembershipView {
        self.view.clone()
    }

    fn epoch(&self) -> u64 {
        self.view.epoch
    }

    fn leave(&self, _rank: usize) -> Option<MembershipView> {
        None
    }

    fn join(&self, _rank: usize, _addr: String) -> Option<MembershipView> {
        None
    }
}

/// Mutable membership fed by join/leave transitions. The root applies
/// transitions first and broadcasts them; spokes replay the identical
/// transitions in the identical order (the control link is FIFO), so
/// every survivor steps through the same sequence of epochs.
pub struct DynamicMembership {
    state: Mutex<MembershipView>,
}

impl DynamicMembership {
    pub fn new(addrs: Vec<String>) -> Self {
        Self { state: Mutex::new(MembershipView::bootstrap(addrs)) }
    }
}

impl MembershipProvider for DynamicMembership {
    fn view(&self) -> MembershipView {
        self.state.lock().unwrap().clone()
    }

    fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    fn leave(&self, rank: usize) -> Option<MembershipView> {
        let mut v = self.state.lock().unwrap();
        if !v.alive(rank) {
            return None; // unknown or already-retired rank: no transition
        }
        v.addrs[rank] = None;
        v.epoch += 1;
        Some(v.clone())
    }

    fn join(&self, rank: usize, addr: String) -> Option<MembershipView> {
        let mut v = self.state.lock().unwrap();
        if rank >= v.addrs.len() || v.alive(rank) {
            return None; // out-of-range slot, or the slot is occupied
        }
        v.addrs[rank] = Some(addr);
        v.epoch += 1;
        Some(v.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|r| format!("127.0.0.1:{}", 9000 + r)).collect()
    }

    #[test]
    fn fixed_membership_never_reconfigures() {
        let m = FixedMembership::new(addrs(3));
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.view().members(), vec![0, 1, 2]);
        assert!(m.leave(1).is_none(), "fixed membership treats death as fatal");
        assert!(m.join(1, "x:1".into()).is_none());
        assert_eq!(m.epoch(), 0, "refused transitions do not advance the epoch");
        assert_eq!(m.view().members(), vec![0, 1, 2]);
    }

    #[test]
    fn dynamic_leave_retires_the_slot_and_bumps_the_epoch() {
        let m = DynamicMembership::new(addrs(4));
        let v = m.leave(2).expect("dynamic membership reconfigures");
        assert_eq!(v.epoch, 1);
        assert_eq!(v.members(), vec![0, 1, 3]);
        assert!(!v.alive(2));
        assert_eq!(v.slots(), 4, "dead ranks keep their slot: ids stay stable");
        assert!(m.leave(2).is_none(), "a rank leaves once");
        assert_eq!(m.epoch(), 1);
    }

    #[test]
    fn dynamic_join_refills_a_retired_slot() {
        let m = DynamicMembership::new(addrs(3));
        assert!(m.join(1, "x:1".into()).is_none(), "occupied slot refuses a join");
        m.leave(1).unwrap();
        let v = m.join(1, "10.0.0.9:7".into()).expect("retired slot accepts a rejoin");
        assert_eq!(v.epoch, 2);
        assert_eq!(v.members(), vec![0, 1, 2]);
        assert_eq!(v.addrs[1].as_deref(), Some("10.0.0.9:7"));
        assert!(m.join(3, "x:1".into()).is_none(), "no out-of-range slots");
    }

    #[test]
    fn replayed_transitions_converge_to_the_same_view() {
        // A spoke replaying the root's transitions in order reaches a
        // bit-identical view at the same epoch.
        let root = DynamicMembership::new(addrs(5));
        let spoke = DynamicMembership::new(addrs(5));
        root.leave(4).unwrap();
        root.leave(1).unwrap();
        spoke.leave(4).unwrap();
        spoke.leave(1).unwrap();
        assert_eq!(root.view(), spoke.view());
        assert_eq!(root.epoch(), 2);
    }
}
