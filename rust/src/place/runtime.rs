//! The thread place-runtime implementation. See module docs in
//! [`crate::place`].

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::network::{router_main, Transport};
use crate::glb::message::{Effect, Msg, PlaceId};
use crate::glb::task_queue::{Reducer, TaskQueue};
use crate::glb::termination::{AtomicLedger, Ledger};
use crate::glb::worker::{Phase, Worker};
use crate::glb::{GlbConfig, RunLog, RunOutput};

/// Options beyond the GLB parameters.
#[derive(Debug, Clone, Copy)]
pub struct ThreadRunOpts {
    /// Per-place thread stack size in bytes (places are many and shallow).
    pub stack_bytes: usize,
    /// Inject a fixed wall-clock delay on every inter-place message
    /// (routed through a delay thread). `None` = direct delivery. Used
    /// by stress tests to widen race windows; the simulator models
    /// latency structurally instead.
    pub latency: Option<Duration>,
}

impl Default for ThreadRunOpts {
    fn default() -> Self {
        Self { stack_bytes: 2 << 20, latency: None }
    }
}

/// Run a GLB computation with one thread per place.
///
/// * `factory(place, p)` builds the (statically initialized) queue for
///   each place — statically balanced apps seed per-place work here;
/// * `root_init` runs once on place 0's queue — dynamically balanced apps
///   seed the root task here (paper §2.3: "If the workload cannot be
///   statically scheduled across places, users need to provide an
///   initialize method ... at place 0");
/// * `reducer` folds per-place results (paper: the type-`Z` reduction).
pub fn run_threads<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    factory: FQ,
    root_init: FI,
    reducer: &R,
) -> RunOutput<Q::Result>
where
    Q: TaskQueue,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    run_threads_opts(cfg, factory, root_init, reducer, ThreadRunOpts::default())
}

/// [`run_threads`] with explicit [`ThreadRunOpts`].
pub fn run_threads_opts<Q, R, FQ, FI>(
    cfg: &GlbConfig,
    mut factory: FQ,
    root_init: FI,
    reducer: &R,
    opts: ThreadRunOpts,
) -> RunOutput<Q::Result>
where
    Q: TaskQueue,
    R: Reducer<Q::Result>,
    FQ: FnMut(usize, usize) -> Q,
    FI: FnOnce(&mut Q),
{
    let p = cfg.p;
    let ledger = AtomicLedger::new();

    // -- sequential setup: queues, workers, mailboxes, initial kicks -----
    let mut queues: Vec<Q> = (0..p).map(|i| factory(i, p)).collect();
    root_init(&mut queues[0]);

    let mut txs: Vec<Sender<Msg<Q::Bag>>> = Vec::with_capacity(p);
    let mut rxs: Vec<Receiver<Msg<Q::Bag>>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    // Optional latency injection: a router thread that holds every
    // message for `latency` before forwarding.
    let (transport, delay, router) = match opts.latency {
        None => (Transport::Direct(txs.clone()), Duration::ZERO, None),
        Some(d) => {
            let (rt_tx, rt_rx) = channel();
            let mailboxes = txs.clone();
            let router = std::thread::Builder::new()
                .name("glb-router".into())
                .spawn(move || router_main(rt_rx, mailboxes))
                .expect("spawn router");
            (Transport::Delayed(rt_tx), d, Some(router))
        }
    };

    // Hierarchical topology: one shared node bag per node, handed to
    // every worker of that node (flat runs never allocate any).
    let topo = cfg.topology();
    let node_bags = topo.make_node_bags::<Q::Bag>();
    let mut workers: Vec<Worker<Q, Arc<AtomicLedger>>> = queues
        .into_iter()
        .enumerate()
        .map(|(i, q)| {
            let nb = node_bags.as_ref().map(|bags| bags[topo.node_of(i)].clone());
            Worker::with_node_bag(i, p, cfg.params, q, ledger.clone(), nb)
        })
        .collect();

    // Kick empty places into the steal protocol *before* any thread runs
    // so the ledger is complete (no thread can observe a transient zero).
    let mut fx = Vec::new();
    for w in workers.iter_mut() {
        w.kick_if_empty(&mut fx);
        for e in fx.drain(..) {
            match e {
                Effect::Send { to, msg } => {
                    transport.send(to, msg, delay);
                }
                // An all-empty run with nobody to steal from (p == 1, or
                // every worker on one hierarchical node): the kick
                // acquires a token, finds no victim, and releases it —
                // validly observing quiescence before any thread runs.
                // The `ledger.value() == 0` early return below finishes
                // the run.
                Effect::Quiescent => debug_assert_eq!(ledger.value(), 0),
            }
        }
    }

    // Nothing to do at all? (every queue empty and nobody to steal from:
    // p == 1, or a hierarchical run whose workers all share one node —
    // either way the kicks above already drained every token.)
    if ledger.value() == 0 {
        let results: Vec<Q::Result> = workers.iter().map(|w| w.queue().result()).collect();
        let log = RunLog::with_topology(
            workers.iter().map(|w| *w.stats()).collect(),
            cfg.params.workers_per_node,
        );
        return RunOutput { result: reducer.reduce_all(results), log, elapsed_ns: 0 };
    }

    // -- run ---------------------------------------------------------------
    let t0 = Instant::now();
    let handles: Vec<_> = workers
        .into_iter()
        .zip(rxs)
        .map(|(worker, rx)| {
            let transport = transport.clone();
            std::thread::Builder::new()
                .name(format!("glb-place-{}", worker.id()))
                .stack_size(opts.stack_bytes)
                .spawn(move || place_main(worker, rx, transport, delay))
                .expect("spawn place thread")
        })
        .collect();
    drop(txs);
    drop(transport);

    let mut per_place: Vec<(Q::Result, crate::glb::WorkerStats)> = handles
        .into_iter()
        .map(|h| h.join().expect("place thread panicked"))
        .collect();
    if let Some(r) = router {
        r.join().expect("router thread panicked");
    }
    let elapsed_ns = t0.elapsed().as_nanos() as u64;

    debug_assert_eq!(ledger.value(), 0, "tokens must balance at termination");

    let stats: Vec<_> = per_place.iter().map(|(_, s)| *s).collect();
    let results: Vec<Q::Result> = per_place.drain(..).map(|(r, _)| r).collect();
    let log = RunLog::with_topology(stats, cfg.params.workers_per_node);
    RunOutput { result: reducer.reduce_all(results), log, elapsed_ns }
}

/// Per-place thread body: drive the worker until `Done`.
fn place_main<Q: TaskQueue>(
    mut worker: Worker<Q, Arc<AtomicLedger>>,
    rx: Receiver<Msg<Q::Bag>>,
    transport: Transport<Q::Bag>,
    delay: Duration,
) -> (Q::Result, crate::glb::WorkerStats) {
    let me = worker.id();
    let p = worker.places();
    let mut fx: Vec<Effect<Q::Bag>> = Vec::with_capacity(8);

    loop {
        match worker.phase() {
            Phase::Working => {
                // Probe: answer everything pending, then one chunk.
                let t = Instant::now();
                while let Ok(m) = rx.try_recv() {
                    worker.on_msg(m, &mut fx);
                    pump(me, p, &mut fx, &transport, delay);
                }
                let probe_ns = t.elapsed().as_nanos() as u64;
                worker.stats_mut().distribute_ns += probe_ns;
                if worker.phase() != Phase::Working {
                    continue; // a message moved us (cannot happen today, defensive)
                }
                let t = Instant::now();
                worker.step(&mut fx);
                worker.stats_mut().process_ns += t.elapsed().as_nanos() as u64;
                pump(me, p, &mut fx, &transport, delay);
            }
            Phase::WaitRandom { .. } | Phase::WaitLifeline { .. } | Phase::Idle => {
                let t = Instant::now();
                let m = rx.recv().expect("mailbox closed while waiting");
                worker.stats_mut().wait_ns += t.elapsed().as_nanos() as u64;
                let t = Instant::now();
                worker.on_msg(m, &mut fx);
                pump(me, p, &mut fx, &transport, delay);
                worker.stats_mut().distribute_ns += t.elapsed().as_nanos() as u64;
            }
            Phase::Done => break,
        }
    }
    let (queue, stats) = worker.into_parts();
    (queue.result(), stats)
}

/// Carry out the worker's requested effects.
fn pump<B>(me: PlaceId, p: usize, fx: &mut Vec<Effect<B>>, transport: &Transport<B>, delay: Duration) {
    for e in fx.drain(..) {
        match e {
            Effect::Send { to, msg } => {
                debug_assert_ne!(to, me, "no self-sends in the protocol");
                transport.send(to, msg, delay);
            }
            Effect::Quiescent => transport.broadcast_terminate(me, p, delay),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::glb::task_bag::{ArrayListTaskBag, TaskBag};
    use crate::glb::task_queue::{ProcessOutcome, SumReducer};
    use crate::glb::GlbParams;

    /// Queue whose tasks are integers; processing a task of value v > 0
    /// spawns two tasks of value v - 1 (so the total number of processed
    /// tasks for a root r is 2^(r+1) - 1) — a tiny irregular workload.
    struct TreeQueue {
        bag: ArrayListTaskBag<u32>,
        processed: u64,
    }

    impl TreeQueue {
        fn empty() -> Self {
            Self { bag: ArrayListTaskBag::new(), processed: 0 }
        }
    }

    impl TaskQueue for TreeQueue {
        type Bag = ArrayListTaskBag<u32>;
        type Result = u64;

        fn process(&mut self, n: usize) -> ProcessOutcome {
            let mut c = 0u64;
            while (c as usize) < n {
                match self.bag.pop() {
                    Some(v) => {
                        self.processed += 1;
                        c += 1;
                        if v > 0 {
                            self.bag.push(v - 1);
                            self.bag.push(v - 1);
                        }
                    }
                    None => break,
                }
            }
            ProcessOutcome::new(self.bag.size() > 0, c)
        }
        fn split(&mut self) -> Option<Self::Bag> {
            self.bag.split()
        }
        fn merge(&mut self, bag: Self::Bag) {
            TaskBag::merge(&mut self.bag, bag)
        }
        fn result(&self) -> u64 {
            self.processed
        }
        fn bag_size(&self) -> usize {
            self.bag.size()
        }
    }

    fn run(p: usize, root: u32, params: GlbParams) -> RunOutput<u64> {
        let cfg = GlbConfig::new(p, params);
        run_threads(&cfg, |_, _| TreeQueue::empty(), |q| q.bag.push(root), &SumReducer)
    }

    #[test]
    fn single_place_counts_tree() {
        let out = run(1, 10, GlbParams::default().with_n(8));
        assert_eq!(out.result, (1 << 11) - 1);
    }

    #[test]
    fn two_places_match_single() {
        let out = run(2, 12, GlbParams::default().with_n(8).with_l(2));
        assert_eq!(out.result, (1 << 13) - 1);
    }

    #[test]
    fn many_places_various_params() {
        for &(p, n, w, l) in
            &[(3usize, 4usize, 1usize, 2usize), (4, 16, 2, 2), (7, 1, 1, 3), (8, 64, 3, 2)]
        {
            let params = GlbParams::default().with_n(n).with_w(w).with_l(l);
            let out = run(p, 11, params);
            assert_eq!(out.result, (1 << 12) - 1, "p={p} n={n} w={w} l={l}");
            // Every place's stats row exists.
            assert_eq!(out.log.per_place.len(), p);
        }
    }

    #[test]
    fn work_actually_moves_across_places() {
        // On a single hardware core the OS may legitimately run place 0
        // to completion before the thieves are ever scheduled, so spread
        // is probabilistic here (the *deterministic* spread assertion
        // lives in the simulator tests). Retry a few times; at least one
        // run must show loot movement.
        for attempt in 0..10 {
            let out = run(4, 14, GlbParams::default().with_n(4).with_l(2));
            assert_eq!(out.result, (1 << 15) - 1, "attempt {attempt}");
            let total_loot: u64 = out.log.per_place.iter().map(|s| s.loot_bags_received).sum();
            if total_loot > 0 {
                return;
            }
        }
        panic!("no loot moved in any of 10 runs");
    }

    #[test]
    fn empty_root_terminates_cleanly() {
        let cfg = GlbConfig::new(1, GlbParams::default());
        let out = run_threads(&cfg, |_, _| TreeQueue::empty(), |_| {}, &SumReducer);
        assert_eq!(out.result, 0);
    }

    #[test]
    fn empty_root_multi_place_terminates() {
        // All places start empty and kick into stealing; everyone refuses
        // everyone; the tokens drain and someone observes quiescence.
        let cfg = GlbConfig::new(4, GlbParams::default().with_l(2));
        let out = run_threads(&cfg, |_, _| TreeQueue::empty(), |_| {}, &SumReducer);
        assert_eq!(out.result, 0);
    }

    #[test]
    fn hierarchical_nodes_match_flat_result() {
        // Same tree, same reduction, any node grouping (incl. a ragged
        // last node at wpn=3) — the topology changes who moves work,
        // never what is computed.
        for wpn in [2usize, 3, 4] {
            let params = GlbParams::default().with_n(8).with_l(2).with_workers_per_node(wpn);
            let out = run(4, 12, params);
            assert_eq!(out.result, (1 << 13) - 1, "wpn={wpn}");
            assert_eq!(out.log.workers_per_node, wpn);
            let t = out.log.total();
            assert_eq!(t.node_donations, t.node_takes, "every parked shard is reclaimed");
            assert_eq!(t.node_loot_sent, t.node_loot_received, "every local push lands");
        }
    }

    #[test]
    fn hierarchical_root_node_feeds_its_hungry_workers() {
        // p = 4, wpn = 4: a single node. The non-representatives register
        // hungry during the pre-thread kicks, so the root worker's first
        // surplus deterministically wakes them with local pushes.
        let params = GlbParams::default().with_n(8).with_workers_per_node(4);
        let out = run(4, 12, params);
        assert_eq!(out.result, (1 << 13) - 1);
        let t = out.log.total();
        assert!(t.node_loot_sent > 0, "hungry locals must be fed by pushes");
        assert_eq!(
            t.random_steals_sent + t.lifeline_steals_sent,
            0,
            "a single node never steals across nodes"
        );
    }

    #[test]
    fn hierarchical_empty_root_terminates() {
        let params = GlbParams::default().with_l(2).with_workers_per_node(2);
        let cfg = GlbConfig::new(4, params);
        let out = run_threads(&cfg, |_, _| TreeQueue::empty(), |_| {}, &SumReducer);
        assert_eq!(out.result, 0);
    }

    #[test]
    fn statically_seeded_places_all_contribute() {
        // factory seeds every place (the BC pattern) — no root init.
        let cfg = GlbConfig::new(4, GlbParams::default().with_n(8).with_l(2));
        let out = run_threads(
            &cfg,
            |_i, _p| {
                let mut q = TreeQueue::empty();
                q.bag.push(9);
                q
            },
            |_| {},
            &SumReducer,
        );
        assert_eq!(out.result, 4 * ((1 << 10) - 1));
    }
}
